"""Disaggregated prefill/decode cluster benchmark: router + prefill +
decode workers vs one colocated engine (DESIGN.md §12).

Three gates, one artifact:

* throughput — a 1-prefill + 2-decode LocalBus cluster must move >=
  ``SPEEDUP_GATE``x the tokens/s of a single engine with the same total
  slot count on a long-prompt-heavy mixed workload.  The win is
  structural, not parallelism (LocalBus steps workers sequentially in one
  process): compiled shapes are fixed at ``(num_slots, ...)``, so every
  monolithic admission on the colocated 10-slot engine pays a
  ``(10, bucket)`` slab for one admitted row, while the cluster's 2-slot
  prefill worker pays ``(2, bucket)`` for the same prompt — decode
  capacity stops inflating prompt processing the moment the roles split.
* fault tolerance — SIGKILL-equivalent loss of a decode worker mid-stream
  (LocalBus ``failure_hook`` + virtual-time heartbeat timeout) must lose
  zero requests and change zero tokens: every result is compared
  token-for-token against the synchronous ``lm.generate`` path, and the
  Done dedup must report no duplicate results.
* elasticity — queue pressure on a 1-decode fleet must emit a
  ``scale_up`` (worker spawned mid-run), and the drained idle fleet must
  emit a ``scale_down``.

Also asserts the per-worker compile contract from heartbeat telemetry:
decode workers compile decode 1 / install <= 1 and never admit; prefill
workers compile admit 1 / <= 1 shape per bucket and never decode.

Emits CSV rows ``serving_cluster,<name>,<tok_s>,<ttft_mean_ms>,
<n_requests>,<restarts>,<replayed>`` and writes
``experiments/BENCH_serving_cluster.json``.
"""
from __future__ import annotations

import json
import os
import time

ARTIFACT = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "experiments", "BENCH_serving_cluster.json")

PAGE = 16            # KV page size everywhere
MAX_PROMPT = 96      # long prompts pad to the 128 bucket
GEN = 6              # short generations: the workload is prefill-bound
D_MODEL = 256        # wide enough that slab FLOPs dominate dispatch overhead
PREFILL_SLOTS = 2
DECODE_SLOTS = 4     # 1 prefill + 2 decode = 10 slots, vs a 10-slot engine
SPEEDUP_GATE = 1.5
KILL_PROMPT = 32     # fixed-shape kill run: lm.generate compiles once


def _ecfg(num_slots: int, *, max_prompt: int = MAX_PROMPT, seed: int = 0):
    from repro.serving import EngineConfig
    return EngineConfig(num_slots=num_slots, max_len=max_prompt + GEN + 1,
                        max_prompt_len=max_prompt, page_size=PAGE, seed=seed)


def make_workload(n: int, seed: int, *, rid0: int = 0):
    """Long-prompt-heavy mix: 3 of 4 prompts land in the top bucket."""
    import numpy as np

    from repro.serving import Request
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        if i % 4 == 3:
            plen = int(rng.integers(8, 13))               # 16 bucket
        else:
            plen = int(rng.integers(72, MAX_PROMPT - 7))  # 128 bucket
        reqs.append(Request(rid=rid0 + i,
                            prompt=rng.integers(1, 256, plen),
                            max_new_tokens=GEN))
    return reqs


def build_cluster(params, cfg, *, n_prefill: int, n_decode: int, clock,
                  control=None, failure_hooks=None, tick_dt: float = 0.0,
                  heartbeat_every: int = 1):
    """LocalBus fleet sharing one param tree; every engine on ``clock``."""
    from repro.cluster import ClusterConfig, ClusterWorker, LocalBus, Router
    from repro.cluster.control import ControlConfig
    from repro.serving import ContinuousBatchingEngine
    engines = {}

    def factory(wid, role):
        slots = PREFILL_SLOTS if role == "prefill" else DECODE_SLOTS
        eng = ContinuousBatchingEngine(params, cfg, _ecfg(slots),
                                       clock=clock)
        engines[wid] = eng
        hook = (failure_hooks or {}).get(wid)
        return ClusterWorker(wid, role, eng, failure_hook=hook,
                             heartbeat_every=heartbeat_every)

    bus = LocalBus(factory, clock=clock, tick_dt=tick_dt)
    ctrl = control or ControlConfig(heartbeat_timeout=1e9,
                                    scale_up_watermark=1e9,
                                    scale_down_watermark=-1.0)
    router = Router(bus, ClusterConfig(n_prefill=n_prefill,
                                       n_decode=n_decode, page_size=PAGE,
                                       control=ctrl), clock=clock)
    router.start()
    return router, engines


def run_throughput(params, cfg, n_requests: int, seed: int):
    """Gate (a): cluster vs colocated engine, equal total slots, wall
    clock, compiles burned by a warmup pass on both sides."""
    from repro.serving import ContinuousBatchingEngine
    warm = make_workload(8, seed + 50, rid0=10_000)
    reqs = make_workload(n_requests, seed)

    total = PREFILL_SLOTS + 2 * DECODE_SLOTS
    single = ContinuousBatchingEngine(params, cfg, _ecfg(total))
    single.run(warm)
    t0 = time.monotonic()
    _, m_single = single.run(reqs)
    single_s = time.monotonic() - t0

    router, engines = build_cluster(params, cfg, n_prefill=1, n_decode=2,
                                    clock=time.monotonic, heartbeat_every=2)
    router.run(make_workload(8, seed + 50, rid0=20_000))   # warmup
    router.results.clear()        # Router.run returns accumulated results
    t0 = time.monotonic()
    results = router.run(make_workload(n_requests, seed))  # same workload
    cluster_s = time.monotonic() - t0
    m_cluster = router.metrics(elapsed_s=cluster_s)
    assert len(results) == n_requests, "cluster lost requests"

    shapes = {w: dict(e.compiled_shapes())
              for w, e in sorted(engines.items()) if router.bus.alive(w)}
    compile_ok = True
    for wid, s in shapes.items():
        if wid.startswith("d"):
            compile_ok &= (s.get("decode", 0) == 1
                           and s.get("admit", 0) == 0
                           and s.get("install", 0) <= 1)
        else:
            compile_ok &= (s.get("admit", 0) == 1
                           and s.get("decode", 0) == 0 and all(
                               v <= 1 for k, v in s.items()
                               if k.startswith("prefill_")))
    return m_single, single_s, m_cluster, cluster_s, shapes, compile_ok


def run_kill(params, cfg, n_requests: int, seed: int):
    """Gate (b): lose a decode worker mid-stream; zero lost requests and
    exact per-request ``lm.generate`` parity.  Virtual time drives the
    heartbeat timeout so the run has no sleeps; prompts share one fixed
    length so the parity check compiles a single shape."""
    import jax.numpy as jnp
    import numpy as np

    from repro.cluster.control import ControlConfig
    from repro.serving import Request
    from repro.serving.engine import VirtualClock

    rng = np.random.default_rng(seed)
    reqs = [Request(rid=i, prompt=rng.integers(1, 256, KILL_PROMPT),
                    max_new_tokens=GEN) for i in range(n_requests)]
    vc = VirtualClock()
    ctrl = ControlConfig(heartbeat_timeout=0.05, max_restarts=3,
                         scale_up_watermark=1e9, scale_down_watermark=-1.0)
    router, _ = build_cluster(
        params, cfg, n_prefill=1, n_decode=2, clock=vc, control=ctrl,
        failure_hooks={"d0": lambda n: n == 6}, tick_dt=0.01)
    results = router.run(reqs, max_ticks=20_000)

    lost = {r.rid for r in reqs} - {r.rid for r in results}
    max_len = KILL_PROMPT + GEN + 1
    from repro.models import lm
    n_parity = 0
    for r in sorted(results, key=lambda r: r.rid):
        want = lm.generate(params, cfg, jnp.asarray(r.prompt[None]),
                           steps=r.n_generated, max_len=max_len)
        np.testing.assert_array_equal(
            np.asarray(want)[0, :len(r.prompt) + r.n_generated],
            np.concatenate([r.prompt, r.tokens]), err_msg=f"rid {r.rid}")
        n_parity += 1
    cm = router.cluster_metrics()
    kill_ok = (not lost and cm["worker_restarts"] == 1
               and cm["replayed_requests"] >= 1
               and cm["duplicate_results"] == 0)
    return router.metrics(), cm, kill_ok, n_parity, sorted(lost)


def run_elastic(params, cfg, n_requests: int, seed: int):
    """Gate (c): queue pressure on a 1-decode fleet spawns a worker; the
    drained idle fleet sheds it again."""
    from repro.cluster.control import ControlConfig
    from repro.serving.engine import VirtualClock

    vc = VirtualClock()
    ctrl = ControlConfig(heartbeat_timeout=1e9, scale_up_watermark=3.0,
                         scale_down_watermark=0.5, watermark_ewma=1.0,
                         scale_cooldown=0.02, min_decode=1, max_decode=2)
    router, engines = build_cluster(params, cfg, n_prefill=1, n_decode=1,
                                    clock=vc, control=ctrl, tick_dt=0.01)
    results = router.run(make_workload(n_requests, seed), max_ticks=20_000)
    for _ in range(600):                       # idle ticks: let it shed
        if "scale_down" in [e["action"] for e in router.monitor.scale_events]:
            break
        router.step()
    events = list(router.cluster_metrics()["scale_events"])
    actions = [e["action"] for e in events]
    scale_ok = (len(results) == n_requests and "scale_up" in actions
                and "scale_down" in actions)
    return router.metrics(), events, scale_ok


def main(quick: bool = True) -> None:
    import jax

    from repro.configs import registry
    from repro.models import lm

    seed = 0
    n_requests = 24 if quick else 64
    cfg = registry.get_config("internlm2-20b", ffn="fff").reduced(
        d_model=D_MODEL, seq=MAX_PROMPT + GEN + 1)
    params = lm.init(jax.random.PRNGKey(seed), cfg)

    print("# name,tok_s,ttft_mean_ms,n_requests,restarts,replayed")
    m_single, single_s, m_cluster, cluster_s, shapes, compile_ok = \
        run_throughput(params, cfg, n_requests, seed + 1)
    runs = {}
    for name, m, el in [("single", m_single, single_s),
                        ("cluster", m_cluster, cluster_s)]:
        print(f"serving_cluster,{name},{m.throughput_tok_s:.1f},"
              f"{m.ttft.mean_ms:.2f},{m.n_requests},0,0", flush=True)
        runs[name] = {"elapsed_wall_s": el, **m.as_dict()}
    speedup = (m_cluster.throughput_tok_s
               / max(m_single.throughput_tok_s, 1e-9))
    speedup_ok = speedup >= SPEEDUP_GATE
    print(f"# throughput {m_single.throughput_tok_s:.1f} -> "
          f"{m_cluster.throughput_tok_s:.1f} tok/s = {speedup:.2f}x "
          f"({'PASS' if speedup_ok else 'FAIL'} vs {SPEEDUP_GATE}x gate)")
    print(f"# compiled shapes {shapes} -> "
          f"{'PASS' if compile_ok else 'FAIL'} (per-role contract)")

    m_kill, cm, kill_ok, n_parity, lost = run_kill(
        params, cfg, 12 if quick else 24, seed + 2)
    print(f"serving_cluster,kill,{m_kill.throughput_tok_s:.1f},"
          f"{m_kill.ttft.mean_ms:.2f},{m_kill.n_requests},"
          f"{cm['worker_restarts']},{cm['replayed_requests']}", flush=True)
    runs["kill"] = {"elapsed_wall_s": 0.0, **m_kill.as_dict()}
    print(f"# kill: lost={lost} restarts={cm['worker_restarts']} "
          f"replayed={cm['replayed_requests']} "
          f"dups={cm['duplicate_results']} parity={n_parity} exact -> "
          f"{'PASS' if kill_ok else 'FAIL'}")

    m_el, events, scale_ok = run_elastic(params, cfg, 10 if quick else 20,
                                         seed + 3)
    print(f"serving_cluster,elastic,{m_el.throughput_tok_s:.1f},"
          f"{m_el.ttft.mean_ms:.2f},{m_el.n_requests},0,0", flush=True)
    runs["elastic"] = {"elapsed_wall_s": 0.0, **m_el.as_dict()}
    print(f"# elastic: {[e['action'] for e in events]} -> "
          f"{'PASS' if scale_ok else 'FAIL'} (scale_up + scale_down)")

    with open(ARTIFACT, "w") as f:
        json.dump({"bench": "serving_cluster", "quick": quick,
                   "topology": {"n_prefill": 1, "n_decode": 2,
                                "prefill_slots": PREFILL_SLOTS,
                                "decode_slots": DECODE_SLOTS,
                                "single_slots": PREFILL_SLOTS
                                + 2 * DECODE_SLOTS},
                   "page_size": PAGE, "gen": GEN,
                   "speedup": speedup, "speedup_gate": SPEEDUP_GATE,
                   "speedup_ok": speedup_ok,
                   "kill_ok": kill_ok, "lost_requests": lost,
                   "parity_checked": n_parity,
                   "worker_restarts": cm["worker_restarts"],
                   "replayed_requests": cm["replayed_requests"],
                   "duplicate_results": cm["duplicate_results"],
                   "scale_ok": scale_ok, "scale_events": events,
                   "compile_ok": compile_ok, "compiled_shapes": shapes,
                   "runs": runs}, f, indent=1)
    print(f"# wrote {ARTIFACT}")
    if not (speedup_ok and kill_ok and scale_ok and compile_ok):
        raise AssertionError(
            f"serving_cluster gates failed: speedup_ok={speedup_ok} "
            f"kill_ok={kill_ok} scale_ok={scale_ok} "
            f"compile_ok={compile_ok}")


if __name__ == "__main__":
    main()
