"""The paper's primary contribution: fast feedforward networks, with their
baselines (vanilla FF, noisy-top-k MoE), routing/dispatch machinery and
region-partition utilities."""
from repro.core import ff, fff, moe, regions, routing
from repro.core.fff import (FFFConfig, bernoulli_entropy, decisive_fraction,
                            forward_hard, forward_train, hardening_loss,
                            mixture_weights, route_hard)

__all__ = [
    "ff", "fff", "moe", "regions", "routing",
    "FFFConfig", "forward_train", "forward_hard", "route_hard",
    "mixture_weights", "hardening_loss", "bernoulli_entropy",
    "decisive_fraction",
]
