"""Documentation lint (ISSUE 4 satellite; the CI docs job runs just this
file).

* intra-repo markdown links in README.md / DESIGN.md / docs/ must resolve;
* `§N` section references must exist in DESIGN.md;
* doc drift: every flag documented in docs/serving.md's flag table must
  exist in `launch/serve.py`'s argparse, and every serve.py flag must be
  documented there.
"""
import pathlib
import re

import pytest

REPO = pathlib.Path(__file__).resolve().parent.parent
DOC_FILES = [REPO / "README.md", REPO / "DESIGN.md",
             *sorted((REPO / "docs").glob("*.md"))]

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SECTION_RE = re.compile(r"§(\d+)")


def test_doc_files_exist():
    for f in DOC_FILES:
        assert f.is_file(), f"missing doc file {f}"


@pytest.mark.parametrize("doc", DOC_FILES, ids=lambda p: p.name)
def test_intra_repo_links_resolve(doc):
    broken = []
    for target in LINK_RE.findall(doc.read_text()):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        path = target.split("#", 1)[0]
        if not path:                       # pure-anchor link
            continue
        if not (doc.parent / path).resolve().exists():
            broken.append(target)
    assert not broken, f"{doc.name}: broken intra-repo links {broken}"


@pytest.mark.parametrize("doc", DOC_FILES, ids=lambda p: p.name)
def test_design_section_refs_exist(doc):
    design = (REPO / "DESIGN.md").read_text()
    have = {m.group(1) for m in re.finditer(r"^## §(\d+)", design, re.M)}
    wanted = set(SECTION_RE.findall(doc.read_text()))
    assert wanted <= have, (f"{doc.name} references DESIGN.md sections "
                            f"{sorted(wanted - have)} that do not exist")


def _serve_flags():
    from repro.launch import serve
    return {opt for action in serve.build_parser()._actions
            for opt in action.option_strings
            if opt.startswith("--") and opt != "--help"}


def test_documented_flags_exist_in_serve():
    """Every flag row in docs/serving.md's flag table names a real
    serve.py option (doc drift, direction 1)."""
    text = (REPO / "docs" / "serving.md").read_text()
    rows = re.findall(r"^\| `(--[a-z][a-z0-9-]*)`", text, re.M)
    assert rows, "docs/serving.md flag table not found"
    missing = sorted(set(rows) - _serve_flags())
    assert not missing, (f"docs/serving.md documents flags that serve.py "
                         f"does not define: {missing}")


def test_serve_flags_are_documented():
    """Every serve.py option appears in docs/serving.md (doc drift,
    direction 2: adding a flag without documenting it fails CI)."""
    text = (REPO / "docs" / "serving.md").read_text()
    undocumented = sorted(f for f in _serve_flags() if f not in text)
    assert not undocumented, (f"serve.py flags missing from "
                              f"docs/serving.md: {undocumented}")
