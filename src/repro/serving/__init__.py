"""Continuous-batching serving engine with FFF leaf-occupancy-aware
scheduling (DESIGN.md §9)."""
from repro.serving.engine import ContinuousBatchingEngine, EngineConfig
from repro.serving.metrics import EngineMetrics, LatencySummary, summarize, \
    tokens_per_second
from repro.serving.request import Request, RequestResult
from repro.serving.scheduler import SCHEDULERS, FCFSScheduler, \
    LeafAwareScheduler, Scheduler, SchedulerView, make_scheduler

__all__ = [
    "ContinuousBatchingEngine", "EngineConfig", "EngineMetrics",
    "LatencySummary", "summarize", "tokens_per_second",
    "Request", "RequestResult",
    "SCHEDULERS", "FCFSScheduler", "LeafAwareScheduler", "Scheduler",
    "SchedulerView", "make_scheduler",
]
