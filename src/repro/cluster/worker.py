"""Role-restricted engine wrapper + the ProcBus process entrypoint
(DESIGN.md §12).

A ``ClusterWorker`` owns one ``ContinuousBatchingEngine`` and drives it in
exactly one role:

* **prefill** — admits router-submitted requests (monolithic admit or the
  chunk slab), and the moment a slot's prompt is consumed and its first
  token sampled, ``handoff.extract``s the KV pages and releases the slot
  WITHOUT minting a result — the request leaves as a ``PrefillDone`` and
  ownership moves to a decode worker.  The engine's decode dispatch never
  runs, so a prefill worker's compile ledger is admit/chunk-slab only.
* **decode** — installs router-placed handoffs into free slots
  (``handoff.install``; an install the pool can't fund stays queued —
  backpressure the next heartbeat advertises as queue_depth) and steps the
  engine, whose queue is permanently empty: its ledger is decode (or
  spec_round) plus the single ``install`` dispatch.

The per-role split is what keeps the per-worker compile contract at the
single-engine counts (decode 1 / chunk slab 1 / spec_round 1 / admit 1):
disaggregation adds processes, not compiled programs.

``worker_main`` is the ProcBus child entrypoint: module-level (picklable
by the spawn context), rebuilds params from ``(cfg, seed)`` — bit-exact,
init is deterministic — and loops inbox → handle → tick → outbox until
``Stop``.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, List, Optional

from repro.cluster import bus as bus_lib
from repro.cluster import handoff as handoff_lib


@dataclasses.dataclass
class WorkerSpec:
    """Everything a spawned process needs to rebuild its engine (picklable:
    configs + seed, never params)."""
    wid: str
    role: str                      # "prefill" | "decode"
    cfg: object                    # model Config
    ecfg: object                   # EngineConfig (already role-sized)
    seed: int = 0
    heartbeat_every: int = 1
    draft_cfg: object = None       # draft model Config when spec decoding


def build_engine(spec: WorkerSpec):
    """Rebuild (params, engine) from a spec — used by ``worker_main`` and by
    LocalBus factories that want spec-identical engines in-process."""
    import jax
    from repro.models import lm
    from repro.serving.engine import ContinuousBatchingEngine

    params = lm.init(jax.random.PRNGKey(spec.seed), spec.cfg)
    draft = None
    if spec.draft_cfg is not None:
        draft = (lm.init(jax.random.PRNGKey(spec.seed + 1), spec.draft_cfg),
                 spec.draft_cfg)
    return params, ContinuousBatchingEngine(params, spec.cfg, spec.ecfg,
                                            draft=draft)


class ClusterWorker:
    """One engine, one role, message-driven (module docstring)."""

    def __init__(self, wid: str, role: str, engine, *,
                 heartbeat_every: int = 1,
                 failure_hook: Optional[Callable[[int], bool]] = None):
        if role not in ("prefill", "decode"):
            raise ValueError(f"unknown role {role!r}")
        self.wid = wid
        self.role = role
        self.engine = engine
        self.heartbeat_every = max(1, heartbeat_every)
        self.failure_hook = failure_hook
        self.inbox: deque = deque()
        self.pending_installs: deque = deque()
        self.draining = False
        self.stopped = False
        self.n_ticks = 0
        self.handoff_bytes = 0

    # -- message handling --------------------------------------------------

    def _handle(self, msg) -> None:
        if isinstance(msg, bus_lib.Submit):
            if self.role != "prefill":
                raise ValueError(f"{self.wid}: decode worker got Submit")
            self.engine.submit(msg.req)
        elif isinstance(msg, bus_lib.Install):
            if self.role != "decode":
                raise ValueError(f"{self.wid}: prefill worker got Install")
            self.pending_installs.append(msg.handoff)
        elif isinstance(msg, bus_lib.Drain):
            self.draining = True
        elif isinstance(msg, bus_lib.Stop):
            self.stopped = True
        else:
            raise ValueError(f"{self.wid}: unknown message {type(msg)}")

    def _heartbeat(self) -> bus_lib.Heartbeat:
        e = self.engine
        occ = e.occupancy_snapshot()
        profiles = e.profiles.as_dict() if e.profiles is not None else None
        return bus_lib.Heartbeat(
            wid=self.wid, role=self.role, t=e.now(), n_ticks=self.n_ticks,
            pages_free=e.pool.pages_free, pages_total=e.pool.num_pages,
            queue_depth=len(e.queue) + len(self.pending_installs),
            active_slots=sum(s is not None for s in e.slots),
            num_slots=e.ecfg.num_slots, occupancy=occ, profiles=profiles,
            compiled_shapes=e.compiled_shapes(),
            handoff_bytes=self.handoff_bytes, draining=self.draining)

    @property
    def idle(self) -> bool:
        return (not self.engine.has_work() and not self.pending_installs
                and not self.inbox)

    # -- the tick ----------------------------------------------------------

    def tick(self) -> List[object]:
        """Drain inbox, advance the engine one step for this role, return
        the outbound messages.  Raises WorkerKilled when the failure hook
        fires — LocalBus turns that into a dropped worker."""
        if self.stopped:
            return []
        out: List[object] = []
        while self.inbox:
            self._handle(self.inbox.popleft())
            if self.stopped:
                out.append(bus_lib.Bye(self.wid,
                                       self.engine.compiled_shapes(),
                                       {"n_ticks": self.n_ticks,
                                        "handoff_bytes": self.handoff_bytes}))
                return out
        self.n_ticks += 1
        if self.failure_hook is not None and self.failure_hook(self.n_ticks):
            raise bus_lib.WorkerKilled(self.wid)
        if self.role == "decode":
            self._tick_decode()
        else:
            out.extend(self._tick_prefill())
        for r in self.engine.results:
            out.append(bus_lib.Done(self.wid, r))
        del self.engine.results[:]
        if self.n_ticks % self.heartbeat_every == 0:
            out.append(self._heartbeat())
        if self.draining and self.idle:
            out.append(bus_lib.Drained(self.wid))
            self.draining = False          # report once; router stops us
        return out

    def _tick_decode(self) -> None:
        while self.pending_installs:
            slot = handoff_lib.install(self.engine,
                                       self.pending_installs[0])
            if slot is None:
                break                       # no slot/pages yet: backpressure
            self.handoff_bytes += self.pending_installs.popleft().nbytes
        self.engine.step()                  # queue empty: decode/evict only

    def _tick_prefill(self) -> List[object]:
        e = self.engine
        e._evict_finished()
        if not self.draining:
            e._admit()                      # monolithic: full prefill here
        if e.ecfg.prefill_chunk:
            for _ in range(e.ecfg.prefill_budget):
                e._chunk_prefill()
        out: List[object] = []
        for i, st in enumerate(e.slots):
            if st is None or st.prefilling or not st.tokens:
                continue
            if st.done:
                continue                    # finished at prefill: evict path
            h = handoff_lib.extract(e, i)
            e.release_slot(i, record_result=False)
            out.append(bus_lib.PrefillDone(self.wid, h))
        return out


def worker_main(spec: WorkerSpec, inbox, outbox) -> None:
    """ProcBus child entrypoint: rebuild the engine, serve messages until
    ``Stop`` (or SIGKILL, which needs no goodbye)."""
    import queue as queue_lib

    _, engine = build_engine(spec)
    worker = ClusterWorker(spec.wid, spec.role, engine,
                           heartbeat_every=spec.heartbeat_every)
    while not worker.stopped:
        try:
            if worker.idle:
                worker.inbox.append(inbox.get(timeout=0.02))
            while True:
                worker.inbox.append(inbox.get_nowait())
        except queue_lib.Empty:
            pass
        for msg in worker.tick():       # tick emits the Bye on Stop
            outbox.put(msg)
