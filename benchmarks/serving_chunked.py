"""Chunked-prefill benchmark: decode latency under long-prompt admission
(ISSUE 4 tentpole; DESIGN.md §9).

Workload: short interactive requests decode continuously while BURSTS of
long prompts (batch-job shape: 256-token prompt, few output tokens) arrive
mid-run.  Under monolithic prefill a burst runs up to ``burst`` full-prompt
dispatches back-to-back between two decode steps, so every in-flight
request's inter-token latency spikes by the whole burst's prefill cost;
chunked prefill advances all of the burst's prompts *together* through one
``(num_slots, chunk)`` slab per step — the per-step added work is one slab
whatever the burst size, and the concurrent prefills amortize the slab's
fixed rows.

The headline comparison is **decode-interval p99** (gap between consecutive
decode dispatches while work is in flight — what a streaming client
experiences as a stall) at equal offered load: same request list, same
arrivals, same slot count.  Throughput and TTFT ride along so the trade is
visible.

Measurement note: this container throttles CPU in bursts (a bare decode
dispatch jitters 5ms p50 -> 35ms p95), so a single run's p99 mostly samples
the scheduler, not the engine.  Each mode therefore runs ``REPEATS`` times
and the BEST (minimum-p99) run is compared: the monolithic admission stall
is *structural* — its burst-prefill gap is real work and survives
minimization — while throttle noise does not.  The JSON artifact also
records the deterministic per-gap admission bound in tokens
(``stall_bound_tokens``): burst x prompt_len for monolithic vs
budget x num_slots x chunk for chunked — the structural claim independent
of wall-clock noise.

Emits CSV rows
``serving_chunked,<mode>,<tok_s>,<interval_p50_ms>,<interval_p99_ms>,
<ttft_p50_ms>,<n_chunks>`` and writes
``experiments/BENCH_serving_chunked.json``.
"""
from __future__ import annotations

import json
import os

import jax
import numpy as np

ARTIFACT = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "experiments", "BENCH_serving_chunked.json")

LONG_LEN = 256          # long prompts: the stall source
SHORT_LO, SHORT_HI = 4, 16
CHUNK = 32
PREFILL_BUDGET = 1      # chunk-slab dispatches per engine step
REPEATS = 5             # best-of-N per mode (see measurement note above)


def _model(seed: int = 0, max_len: int = 288):
    from repro.configs import registry
    from repro.models import lm
    # d_model=512 (8x the test-reduced size): the admission stall must
    # dwarf both per-dispatch host overhead AND this container's ~25ms
    # sporadic dispatch-latency tail for p99 to measure prefill policy
    # rather than OS noise — at the smoke-test size a full 256-token
    # prefill costs about the same as one chunk slab and there is nothing
    # to win (measured: prefill(1,256) ~14ms, chunk slab ~9ms, decode ~3ms
    # at this size, so a 4-prompt monolithic burst stalls ~60ms)
    cfg = registry.get_config("internlm2-20b", ffn="fff").reduced(
        d_model=512, n_heads=8, seq=max(320, max_len))
    params = lm.init(jax.random.PRNGKey(seed), cfg)
    return cfg, params


def make_workload(vocab: int, *, n_short: int, gen_short: int, burst: int,
                  n_bursts: int, gen_long: int, seed: int):
    """``n_short`` interactive requests arrive at t=0 and decode throughout;
    ``n_bursts`` bursts of ``burst`` long prompts land while they are
    mid-decode — each burst is the admission-stall event."""
    from repro.data import tokens as tokens_lib
    from repro.serving import Request
    rng = np.random.default_rng(seed)
    src = tokens_lib.MarkovTokenSource(vocab, seed=seed)
    reqs = []
    for i in range(n_short):
        L = int(rng.integers(SHORT_LO, SHORT_HI + 1))
        reqs.append(Request(
            rid=i, prompt=src.sample(1, L, seed=seed + 1 + i)[0, :L],
            max_new_tokens=gen_short, arrival_time=0.0))
    rid = n_short
    for b in range(n_bursts):
        for _ in range(burst):
            reqs.append(Request(
                rid=rid,
                prompt=src.sample(1, LONG_LEN,
                                  seed=seed + 100 + rid)[0, :LONG_LEN],
                max_new_tokens=gen_long,
                arrival_time=0.05 + 0.22 * b))
            rid += 1
    return reqs


def run_one(params, cfg, reqs, *, chunk: int, slots: int, max_len: int,
            seed: int):
    """Serve ``reqs`` REPEATS times on a warm engine; return the run with
    the best decode-interval p99 (plus the compiled-shape counts)."""
    from repro.serving import ContinuousBatchingEngine, EngineConfig
    ecfg = EngineConfig(
        num_slots=slots,
        max_len=max_len,
        max_prompt_len=LONG_LEN,
        prefill_chunk=chunk,
        prefill_budget=PREFILL_BUDGET,
        max_prefills_per_step=slots,
        seed=seed)
    engine = ContinuousBatchingEngine(params, cfg, ecfg)
    # warmup: compile every entry point on a throwaway request so the timed
    # runs measure steady-state dispatches, not compiles
    warm = [type(reqs[0])(rid=10_000, prompt=reqs[-1].prompt.copy(),
                          max_new_tokens=2),
            type(reqs[0])(rid=10_001, prompt=reqs[0].prompt.copy(),
                          max_new_tokens=2)]
    engine.run(warm)
    runs = [engine.run(reqs)[1] for _ in range(REPEATS)]
    # best-of-N: structural admission stalls survive minimization, CPU
    # throttle windows do not (module docstring, measurement note)
    best = min(runs, key=lambda m: m.decode_interval.p99_ms)
    return best, engine.compiled_shapes()


def main(quick: bool = True) -> None:
    seed = 0
    # 2 interactive streams + 4 spare slots: a whole burst prefills
    # concurrently, sharing (and filling) the chunk slab's fixed rows —
    # and the 4-prompt monolithic burst (~60ms at this size) clears the
    # container's throttle-noise ceiling
    n_short, burst = 2, 4
    slots = n_short + burst
    gen_short = 192 if quick else 384
    n_bursts = 4 if quick else 8
    gen_long = 4
    max_len = max(SHORT_HI + gen_short, LONG_LEN + gen_long) + 1

    cfg, params = _model(seed, max_len=max_len)
    reqs = make_workload(cfg.vocab_size, n_short=n_short,
                         gen_short=gen_short, burst=burst,
                         n_bursts=n_bursts, gen_long=gen_long, seed=seed + 1)
    print(f"# {n_short} short (len {SHORT_LO}-{SHORT_HI}, gen {gen_short}) + "
          f"{n_bursts} bursts of {burst} long (len {LONG_LEN}, gen "
          f"{gen_long}), {slots} slots, chunk {CHUNK}")
    print("# name,mode,tok_s,interval_p50_ms,interval_p99_ms,ttft_p50_ms,"
          "n_chunks")

    runs = {}
    for mode, chunk in (("monolithic", 0), ("chunked", CHUNK)):
        m, shapes = run_one(params, cfg, reqs, chunk=chunk, slots=slots,
                            max_len=max_len, seed=seed)
        print(f"serving_chunked,{mode},{m.throughput_tok_s:.1f},"
              f"{m.decode_interval.p50_ms:.2f},{m.decode_interval.p99_ms:.2f},"
              f"{m.ttft.p50_ms:.2f},{m.n_chunks}", flush=True)
        runs[mode] = {"prefill_chunk": chunk, "compiled_shapes": shapes,
                      **m.as_dict()}

    mono, chk = runs["monolithic"], runs["chunked"]
    p99_drop = 1.0 - chk["decode_interval_ms"]["p99_ms"] / max(
        mono["decode_interval_ms"]["p99_ms"], 1e-9)
    tput_ratio = chk["throughput_tok_s"] / max(mono["throughput_tok_s"], 1e-9)
    verdict = p99_drop > 0.0
    print(f"# decode-interval p99: chunked "
          f"{chk['decode_interval_ms']['p99_ms']:.2f}ms vs monolithic "
          f"{mono['decode_interval_ms']['p99_ms']:.2f}ms "
          f"({p99_drop:+.0%} change) at {tput_ratio:.2f}x throughput -> "
          f"{'LOWER' if verdict else 'NOT LOWER'}")

    # the deterministic structural claim: max admission tokens that can land
    # between two decode dispatches (independent of wall-clock noise)
    stall_bound = {"monolithic": burst * LONG_LEN,
                   "chunked": PREFILL_BUDGET * slots * CHUNK}
    print(f"# structural stall bound (admission tokens per decode gap): "
          f"monolithic {stall_bound['monolithic']} vs chunked "
          f"{stall_bound['chunked']}")

    with open(ARTIFACT, "w") as f:
        json.dump({"bench": "serving_chunked", "quick": quick,
                   "slots": slots, "chunk": CHUNK, "long_len": LONG_LEN,
                   "n_short": n_short, "burst": burst, "n_bursts": n_bursts,
                   "gen_short": gen_short, "gen_long": gen_long,
                   "decode_interval_p99_drop": p99_drop,
                   "throughput_ratio_chunked_over_mono": tput_ratio,
                   "stall_bound_tokens": stall_bound,
                   "runs": runs}, f, indent=1)
    print(f"# wrote {ARTIFACT}")


if __name__ == "__main__":
    main()
