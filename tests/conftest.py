"""Test fixtures.  NOTE: no XLA_FLAGS here on purpose — smoke tests and
benchmarks must see the real single CPU device; only launch/dryrun.py forces
512 placeholder devices (and tests exercise it via a subprocess)."""
import jax
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
