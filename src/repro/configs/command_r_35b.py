"""command-r-35b [dense] — 40L d_model=8192 64H (GQA kv=8) d_ff=22528
vocab=256000, GQA, no biases, tied embeddings.
[hf:CohereForAI/c4ai-command-r-v01; unverified]"""
import jax.numpy as jnp

from repro.configs.base import BlockSpec, FFNSpec, ModelConfig

CONFIG = ModelConfig(
    arch_id="command-r-35b",
    family="dense",
    d_model=8192,
    n_layers=40,
    n_heads=64,
    n_kv_heads=8,
    vocab_size=256000,
    max_seq_len=32768,
    norm="layernorm",
    attn_bias=False,
    tie_embeddings=True,
    rope_theta=8_000_000.0,
    period=(BlockSpec(mixer="attn",
                      ffn=FFNSpec(kind="dense", d_ff=22528,
                                  activation="swiglu")),),
    param_dtype=jnp.bfloat16,
    accum_dtype=jnp.bfloat16,
    remat="full",
    grad_accum=16,
)

# 16 leaves x 1408 = 22528 (exact width match; 1408 = 11*128, MXU-aligned)
FFF_CONFIG = CONFIG.with_ffn_kind("fff", leaf_width=1408)
