"""Learning-rate schedules, including the paper's plateau-halving rule."""
from __future__ import annotations

from typing import Callable

import jax.numpy as jnp
import numpy as np

Schedule = Callable


def constant(lr: float) -> Schedule:
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_warmup(peak_lr: float, warmup_steps: int, total_steps: int,
                  final_frac: float = 0.1) -> Schedule:
    def fn(step):
        step = step.astype(jnp.float32) if hasattr(step, "astype") \
            else jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / max(warmup_steps, 1)
        prog = jnp.clip((step - warmup_steps)
                        / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = peak_lr * (final_frac + (1 - final_frac)
                         * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup_steps, warm, cos)
    return fn


class PlateauHalver:
    """Host-side plateau halving: the paper halves the lr on N-epoch training
    accuracy plateaus (Table 2/3 experiments).  Stateful; feed it the metric
    each epoch and read ``lr``."""

    def __init__(self, lr: float, patience: int, mode: str = "max",
                 min_lr: float = 1e-6):
        self.lr = lr
        self.patience = patience
        self.mode = mode
        self.min_lr = min_lr
        self.best = -np.inf if mode == "max" else np.inf
        self.bad = 0

    def step(self, metric: float) -> float:
        better = metric > self.best if self.mode == "max" else metric < self.best
        if better:
            self.best = metric
            self.bad = 0
        else:
            self.bad += 1
            if self.bad >= self.patience:
                self.lr = max(self.lr * 0.5, self.min_lr)
                self.bad = 0
        return self.lr


def plateau_halving(lr: float, patience: int, **kw) -> PlateauHalver:
    return PlateauHalver(lr, patience, **kw)


class EarlyStopper:
    """Early stopping on a validation metric (paper: 350-epoch patience)."""

    def __init__(self, patience: int, mode: str = "max"):
        self.patience = patience
        self.mode = mode
        self.best = -np.inf if mode == "max" else np.inf
        self.bad = 0
        self.best_step = 0

    def step(self, metric: float, step: int) -> bool:
        """Returns True when training should stop."""
        better = metric > self.best if self.mode == "max" else metric < self.best
        if better:
            self.best = metric
            self.best_step = step
            self.bad = 0
            return False
        self.bad += 1
        return self.bad >= self.patience
