"""Optimizer interface (optax-style init/update pairs) and shared transforms."""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro import utils

PyTree = Any


class Optimizer(NamedTuple):
    init: Callable[[PyTree], PyTree]
    update: Callable[..., tuple[PyTree, PyTree]]   # (grads, state, params)


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    """params + updates, preserving each param's dtype (bf16-safe)."""
    return jax.tree_util.tree_map(
        lambda p, u: (p.astype(jnp.float32) + u.astype(jnp.float32)).astype(p.dtype),
        params, updates)


def clip_by_global_norm(grads: PyTree, max_norm: float) -> tuple[PyTree, jax.Array]:
    norm = utils.tree_global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), norm


def chain_clip(opt: Optimizer, max_norm: float) -> Optimizer:
    """Wrap an optimizer with global-norm gradient clipping."""
    def update(grads, state, params=None, **kw):
        grads, _ = clip_by_global_norm(grads, max_norm)
        return opt.update(grads, state, params, **kw)
    return Optimizer(opt.init, update)
