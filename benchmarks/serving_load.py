"""Serving-load benchmark: continuous-batching engine under synthetic
Poisson arrivals, per scheduler (ISSUE 3; first entry in the serving perf
trajectory).

Workload: a *skewed-routing* request mix — requests come in per-class bursts
where each class's prompt routes (near-)entirely to one FFF leaf (classes are
discovered by a calibration probe against the model's own routing, and each
request carries its class footprint as ``leaf_hint`` — the per-tenant
routing-profile story from DESIGN.md §9).  Under the capacity-bounded
``grouped`` backend the decode batch composition then decides
overflow_fraction: FCFS admits bursts wholesale (one hot leaf), while the
``leaf_aware`` scheduler interleaves classes to balance leaf load.

Emits CSV rows
``serving,<sched>,<rate>,<tok_s>,<ttft_p50_ms>,<per_tok_p50_ms>,<ovf>,<ovf_decode>``
and writes ``experiments/BENCH_serving.json``.
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

ARTIFACT = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "experiments", "BENCH_serving.json")

PROMPT_LEN = 16
GEN = 12
N_CLASSES = 4


def _model(seed: int = 0):
    from repro.configs import registry
    from repro.models import lm
    cfg = registry.get_config("internlm2-20b", ffn="fff").reduced()
    params = lm.init(jax.random.PRNGKey(seed), cfg)
    return cfg, params


def calibrate_classes(params, cfg, n_classes: int, max_probe: int = 64):
    """Find ``n_classes`` prompt tokens whose repeated-token prompts route
    dominantly to *distinct* leaves; returns [(token, footprint (E,))].

    This is the offline per-tenant routing-profile measurement: one padded
    prefill per candidate under an ``api.collect_routing`` tap."""
    from repro.core import api
    from repro.models import lm

    probe = jax.jit(lambda p, t, c: lm.prefill_padded(
        p, cfg, {"tokens": t}, c, jnp.full((1,), PROMPT_LEN, jnp.int32)))

    def footprint(tok: int) -> np.ndarray:
        caches = lm.init_caches(cfg, 1, PROMPT_LEN + 1)
        with api.collect_routing(), api.use_backend("grouped", mode="infer"):
            _, _, stats = probe(params,
                                jnp.full((1, PROMPT_LEN), tok, jnp.int32),
                                caches)
        c = np.asarray(next(s.leaf_counts[0] for s in stats if s is not None),
                       np.float64)
        return c / max(c.sum(), 1e-9)

    classes, seen = [], set()
    for tok in range(1, max_probe):
        f = footprint(tok)
        lead = int(f.argmax())
        if f[lead] > 0.5 and lead not in seen:
            seen.add(lead)
            classes.append((tok, f))
        if len(classes) == n_classes:
            break
    if len(classes) < n_classes:
        raise RuntimeError(f"calibration found only {len(classes)} distinct "
                           f"leaf classes in {max_probe} probe tokens")
    return classes


def make_workload(classes, *, n_requests: int, burst: int, rate: float,
                  seed: int, gen: int = GEN, prompt_len: int = PROMPT_LEN):
    """Per-class bursts of ``burst`` requests with Poisson arrivals at
    ``rate`` req/s (rate <= 0: everything arrives at t=0)."""
    from repro.serving import Request
    rng = np.random.default_rng(seed)
    gaps = (np.zeros(n_requests) if rate <= 0
            else rng.exponential(1.0 / rate, n_requests))
    arrivals = np.cumsum(gaps)
    reqs = []
    for rid in range(n_requests):
        tok, fp = classes[(rid // burst) % len(classes)]
        reqs.append(Request(
            rid=rid, prompt=np.full((prompt_len,), tok, np.int32),
            max_new_tokens=gen, arrival_time=float(arrivals[rid]),
            leaf_hint=fp.copy()))
    return reqs


def run_one(params, cfg, *, scheduler: str, slots: int, reqs, seed: int):
    from repro.serving import ContinuousBatchingEngine, EngineConfig
    kw = {"window": 4 * slots} if scheduler == "leaf_aware" else {}
    ecfg = EngineConfig(
        num_slots=slots, max_len=PROMPT_LEN + GEN + 1,
        max_prompt_len=PROMPT_LEN, scheduler=scheduler, scheduler_kw=kw,
        fff_backend="grouped",          # capacity-bounded dispatch: the
        max_prefills_per_step=slots,    # regime where composition matters
        seed=seed)
    engine = ContinuousBatchingEngine(params, cfg, ecfg)
    _, m = engine.run(reqs)
    return m


def main(quick: bool = True) -> None:
    seed = 0
    slots = 16 if quick else 32
    n_requests = (8 if quick else 16) * slots // 2
    rates = [16.0, 64.0, 0.0] if quick else [8.0, 16.0, 32.0, 64.0, 0.0]

    cfg, params = _model(seed)
    classes = calibrate_classes(params, cfg, N_CLASSES)
    print(f"# classes (token -> leaf): "
          f"{[(t, int(f.argmax())) for t, f in classes]}")
    print("# name,sched,rate_req_s,tok_s,ttft_p50_ms,per_token_p50_ms,"
          "overflow_mean,overflow_decode_mean")

    runs = []
    for rate in rates:
        for sched in ("fcfs", "leaf_aware"):
            reqs = make_workload(classes, n_requests=n_requests, burst=slots,
                                 rate=rate, seed=seed + 1)
            m = run_one(params, cfg, scheduler=sched, slots=slots,
                        reqs=reqs, seed=seed)
            rate_label = rate if rate > 0 else float("inf")
            print(f"serving,{sched},{rate_label},{m.throughput_tok_s:.1f},"
                  f"{m.ttft.p50_ms:.2f},{m.per_token.p50_ms:.2f},"
                  f"{m.overflow_fraction_mean:.4f},"
                  f"{m.overflow_decode_mean:.4f}", flush=True)
            runs.append({"scheduler": sched, "rate_req_s": rate,
                         "slots": slots, "n_requests": n_requests,
                         **m.as_dict()})

    # the acceptance comparison: at saturating load (every arrival pattern
    # shares the same token budget, so throughput is decode-bound and equal),
    # leaf-aware admission must cut capacity overflow on this skewed mix
    sat = [r for r in runs if r["rate_req_s"] == 0.0]
    fcfs = next(r for r in sat if r["scheduler"] == "fcfs")
    aware = next(r for r in sat if r["scheduler"] == "leaf_aware")
    verdict = aware["overflow_decode_mean"] < fcfs["overflow_decode_mean"]
    print(f"# leaf_aware decode overflow {aware['overflow_decode_mean']:.4f} "
          f"vs fcfs {fcfs['overflow_decode_mean']:.4f} at "
          f"{aware['throughput_tok_s']:.0f}/{fcfs['throughput_tok_s']:.0f} "
          f"tok/s -> {'LOWER' if verdict else 'NOT LOWER'}")

    with open(ARTIFACT, "w") as f:
        json.dump({"bench": "serving_load", "quick": quick, "slots": slots,
                   "prompt_len": PROMPT_LEN, "gen": GEN,
                   "classes": [(int(t), int(fp.argmax()))
                               for t, fp in classes],
                   "runs": runs}, f, indent=1)
    print(f"# wrote {ARTIFACT}")


if __name__ == "__main__":
    main()
