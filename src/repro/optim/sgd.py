"""Plain SGD (+momentum) — the paper's explorative experiments use pure SGD
with lr 0.2."""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional, Union

import jax
import jax.numpy as jnp

from repro.optim.common import Optimizer

PyTree = Any
ScheduleOrFloat = Union[float, Callable[[jax.Array], jax.Array]]


class SGDState(NamedTuple):
    step: jax.Array
    momentum: PyTree


def sgd(lr: ScheduleOrFloat, momentum: float = 0.0) -> Optimizer:
    def lr_at(step):
        return lr(step) if callable(lr) else jnp.asarray(lr, jnp.float32)

    def init(params: PyTree) -> SGDState:
        mom = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params) \
            if momentum else jax.tree_util.tree_map(lambda p: jnp.zeros(()), params)
        return SGDState(jnp.zeros((), jnp.int32), mom)

    def update(grads: PyTree, state: SGDState, params: Optional[PyTree] = None
               ) -> tuple[PyTree, SGDState]:
        step = state.step + 1
        lr_t = lr_at(step)
        if momentum:
            mom = jax.tree_util.tree_map(
                lambda m, g: momentum * m + g.astype(jnp.float32),
                state.momentum, grads)
            updates = jax.tree_util.tree_map(lambda m: -lr_t * m, mom)
            return updates, SGDState(step, mom)
        updates = jax.tree_util.tree_map(
            lambda g: -lr_t * g.astype(jnp.float32), grads)
        return updates, SGDState(step, state.momentum)

    return Optimizer(init, update)
