"""Rotary position embeddings (RoPE), plus sinusoidal absolute embeddings."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rope_frequencies(head_dim: int, theta: float = 10000.0) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0
               ) -> jax.Array:
    """Rotate (..., S, H, head_dim) by per-position angles.

    positions: (..., S) int32 absolute positions (supports KV-cache decode by
    passing the cache offsets)."""
    freqs = rope_frequencies(x.shape[-1], theta)                 # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs    # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]                          # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_embedding(seq_len: int, dim: int, max_timescale: float = 10000.0
                         ) -> jax.Array:
    """Classic transformer sinusoidal table (S, D) — whisper encoder style."""
    half = dim // 2
    inv = 1.0 / (max_timescale ** (jnp.arange(half, dtype=jnp.float32) / max(half - 1, 1)))
    pos = jnp.arange(seq_len, dtype=jnp.float32)[:, None] * inv[None, :]
    return jnp.concatenate([jnp.sin(pos), jnp.cos(pos)], axis=-1)
