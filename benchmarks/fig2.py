"""Paper Figure 2: FFFs vs FFs at equal *inference size*.

For depths d in {2, 6} and leaf sizes l in {2, 4, 8, 16, 32}, the FFF
inference size is l + d; FFs of width equal to that inference size are the
baselines.  Claim reproduced: FFFs outperform FFs of the same inference size
on both M_A and G_A (they bring 2^d * l training neurons to bear).
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.data import synthetic

DEPTHS = (2, 6)
LEAVES = (2, 4, 8, 16, 32)


def run(steps: int = 250, quick: bool = False) -> list[dict]:
    ds = synthetic.make("cifar10_like")
    rows = []
    depths = DEPTHS if not quick else (2,)
    leaves = LEAVES if not quick else (4, 16)
    for d in depths:
        for leaf in leaves:
            inf_size = leaf + d
            cfg, p, tr, fw = common.build_fff(ds.dim, ds.num_classes, d, leaf)
            p, _ = common.train_classifier(tr, p, ds, steps=steps)
            ma = common.accuracy(fw, p, ds.x_train[:2048], ds.y_train[:2048])
            ga = common.accuracy(fw, p, ds.x_test, ds.y_test)
            rows.append(dict(model="fff", depth=d, leaf=leaf,
                             inference_size=inf_size, ma=ma, ga=ga))
            # FF with width == FFF inference size
            _, p_ff, tr_ff, fw_ff = common.build_ff(ds.dim, ds.num_classes,
                                                    inf_size)
            p_ff, _ = common.train_classifier(tr_ff, p_ff, ds, steps=steps)
            rows.append(dict(
                model="ff", depth=0, leaf=0, inference_size=inf_size,
                ma=common.accuracy(fw_ff, p_ff, ds.x_train[:2048],
                                   ds.y_train[:2048]),
                ga=common.accuracy(fw_ff, p_ff, ds.x_test, ds.y_test)))
    return rows


def main(quick: bool = True):
    rows = run(steps=120 if quick else 400, quick=quick)
    print("name,us_per_call,derived")
    for r in rows:
        name = (f"fig2/{r['model']}_d{r['depth']}_l{r['leaf']}"
                f"_inf{r['inference_size']}")
        print(f"{name},0.0,ma={r['ma']:.3f};ga={r['ga']:.3f}")
    return rows


if __name__ == "__main__":
    main(quick=False)
