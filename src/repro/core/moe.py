"""Sparsely-gated mixture-of-experts baseline (Shazeer et al., 2017).

The paper's direct contender: noisy top-k gating over ``E`` expert blocks with
importance and load-balancing auxiliary losses.  Kept faithful to the original
formulation (noise = softplus(x @ Wn) * N(0,1); load loss via the normal-CDF
inclusion probability) because Table 2 compares against exactly this.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.scipy.stats import norm

from repro import utils

Params = dict


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    dim_in: int
    dim_out: int
    num_experts: int
    expert_width: int
    top_k: int = 2
    activation: str = "gelu"
    noisy_gating: bool = True
    w_importance: float = 0.1      # paper's comparison uses 0.1 for both
    w_load: float = 0.1
    bias: bool = True
    param_dtype: Any = jnp.float32
    accum_dtype: Any = jnp.float32

    @property
    def training_width(self) -> int:
        return self.num_experts * self.expert_width

    @property
    def inference_width(self) -> int:
        return self.top_k * self.expert_width


def init(key: jax.Array, cfg: MoEConfig) -> Params:
    E, D, H, O = cfg.num_experts, cfg.dim_in, cfg.expert_width, cfg.dim_out
    ks = jax.random.split(key, 5)
    pd = cfg.param_dtype
    p: Params = {
        "gate_w": jnp.zeros((D, E), pd),          # Shazeer: zero-init gates
        "noise_w": jnp.zeros((D, E), pd),
        "expert_w1": utils.he_normal(ks[0], (E, D, H), pd, fan_in_axis=-2),
        "expert_w2": utils.lecun_normal(ks[1], (E, H, O), pd, fan_in_axis=-2),
    }
    if cfg.bias:
        p["expert_b1"] = jnp.zeros((E, H), pd)
        p["expert_b2"] = jnp.zeros((E, O), pd)
    return p


def _cv_squared(x: jax.Array, eps: float = 1e-10) -> jax.Array:
    """Squared coefficient of variation — the balancing loss shape."""
    x = x.astype(jnp.float32)
    return x.var() / (x.mean() ** 2 + eps)


def _top_k_gates(clean: jax.Array, noisy: jax.Array, noise_std: jax.Array,
                 k: int, train: bool, num_experts: int
                 ) -> tuple[jax.Array, jax.Array]:
    """Gates (B, E) plus the differentiable load estimate (E,)."""
    logits = noisy if train else clean
    kk = min(k + 1, num_experts)
    top_vals, top_idx = jax.lax.top_k(logits, kk)
    topk_vals = top_vals[:, :k]
    gates_k = jax.nn.softmax(topk_vals, axis=-1)
    gates = jnp.zeros_like(logits).at[
        jnp.arange(logits.shape[0])[:, None], top_idx[:, :k]].set(gates_k)

    if not train or kk <= k:
        load = (gates > 0).astype(jnp.float32).sum(axis=0)
        return gates, load

    # P(expert e stays in the top-k when its noise alone is resampled):
    # threshold is the k-th highest *other* noisy logit (Shazeer App. A).
    in_topk = (jnp.zeros_like(logits, dtype=bool).at[
        jnp.arange(logits.shape[0])[:, None], top_idx[:, :k]].set(True))
    thr_if_in = top_vals[:, k][:, None]        # displaced by the (k+1)-th
    thr_if_out = top_vals[:, k - 1][:, None]   # must beat the current k-th
    threshold = jnp.where(in_topk, thr_if_in, thr_if_out)
    prob = norm.cdf((clean - threshold) / jnp.maximum(noise_std, 1e-4))
    return gates, prob.sum(axis=0)


def forward(params: Params, cfg: MoEConfig, x: jax.Array,
            rng: Optional[jax.Array] = None, train: bool = True
            ) -> tuple[jax.Array, dict]:
    """x (..., D) -> (..., O), aux: gates, aux_loss (importance + load)."""
    ad = cfg.accum_dtype
    xf, lead = utils.flatten_leading(x)
    xf = xf.astype(ad)
    clean = jnp.einsum("bd,de->be", xf, params["gate_w"], preferred_element_type=ad)
    if cfg.noisy_gating and train and rng is not None:
        raw = jnp.einsum("bd,de->be", xf, params["noise_w"], preferred_element_type=ad)
        noise_std = jax.nn.softplus(raw) + 1e-2
        noisy = clean + jax.random.normal(rng, clean.shape) * noise_std
    else:
        noise_std = jnp.ones_like(clean)
        noisy = clean
    gates, load = _top_k_gates(clean, noisy, noise_std, cfg.top_k,
                               train and cfg.noisy_gating, cfg.num_experts)
    importance = gates.sum(axis=0)
    aux_loss = cfg.w_importance * _cv_squared(importance) \
        + cfg.w_load * _cv_squared(load)

    # Dense combine: evaluate all experts, weight by gates.  (The serving path
    # reuses the same sorted-dispatch machinery as FFF; see core/routing.py.)
    act = utils.get_activation(cfg.activation)
    h = jnp.einsum("bd,edh->beh", xf, params["expert_w1"], preferred_element_type=ad)
    if "expert_b1" in params:
        h = h + params["expert_b1"][None].astype(ad)
    h = act(h)
    y_e = jnp.einsum("beh,eho->beo", h, params["expert_w2"], preferred_element_type=ad)
    if "expert_b2" in params:
        y_e = y_e + params["expert_b2"][None].astype(ad)
    y = jnp.einsum("be,beo->bo", gates, y_e)
    aux = {"gates": gates, "aux_loss": aux_loss, "load": load,
           "importance": importance}
    return utils.unflatten_leading(y, lead), aux


def forward_sparse(params: Params, cfg: MoEConfig, x: jax.Array) -> tuple[jax.Array, dict]:
    """Inference path: clean top-k, only selected experts evaluated (gathered).

    Complexity is O(g) = O(E) in the gate — the linear cost the paper contrasts
    with FFF's O(log E) descent (Figures 3-4)."""
    ad = cfg.accum_dtype
    xf, lead = utils.flatten_leading(x)
    xf = xf.astype(ad)
    clean = jnp.einsum("bd,de->be", xf, params["gate_w"], preferred_element_type=ad)
    top_vals, top_idx = jax.lax.top_k(clean, cfg.top_k)          # (B, k)
    gates_k = jax.nn.softmax(top_vals, axis=-1)

    def eval_expert(idx):                                        # idx (B,)
        w1 = jnp.take(params["expert_w1"], idx, axis=0)          # (B, D, H)
        w2 = jnp.take(params["expert_w2"], idx, axis=0)
        h = jnp.einsum("bd,bdh->bh", xf, w1, preferred_element_type=ad)
        if "expert_b1" in params:
            h = h + jnp.take(params["expert_b1"], idx, axis=0).astype(ad)
        h = utils.get_activation(cfg.activation)(h)
        y = jnp.einsum("bh,bho->bo", h, w2, preferred_element_type=ad)
        if "expert_b2" in params:
            y = y + jnp.take(params["expert_b2"], idx, axis=0).astype(ad)
        return y

    y = sum(eval_expert(top_idx[:, j]) * gates_k[:, j:j + 1]
            for j in range(cfg.top_k))
    return utils.unflatten_leading(y, lead), {"expert_idx": top_idx}
