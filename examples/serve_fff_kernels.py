"""Serving with the TPU kernel path: routes a batch through the Pallas
tree-router + grouped leaf GEMM (interpret mode on CPU) and cross-checks
against the pure-JAX oracle — the production inference dataflow end to end.

Every path is one ``api.apply()`` call; only ``ExecutionSpec.backend``
changes (``reference`` oracle vs the ``pallas`` kernels), which is the whole
point of the backend registry (core/api.py, DESIGN.md §2).

Run:  PYTHONPATH=src python examples/serve_fff_kernels.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import api, fff, routing

# a transformer-FFN-sized FFF layer: d_model 512, 16 leaves x 256 = 4096 width
cfg = fff.FFFConfig(dim_in=512, dim_out=512, depth=4, leaf_width=256,
                    activation="swiglu", leaf_bias=False)
params = fff.init(jax.random.PRNGKey(0), cfg)
x = jax.random.normal(jax.random.PRNGKey(1), (256, 512))

print(f"FFF layer: {cfg.num_leaves} leaves x {cfg.leaf_width} wide "
      f"(training width {cfg.training_width}, inference width "
      f"{cfg.inference_width})")

# --- oracle ------------------------------------------------------------
t0 = time.time()
y_ref, out = api.apply(params, cfg, x,
                       api.ExecutionSpec(mode="infer", backend="reference"))
print(f"apply(backend='reference')  {1e3*(time.time()-t0):7.1f}ms")

# --- batch path: router kernel + sorted-dispatch ragged GEMM ------------
# (256 tokens > decode threshold, so the pallas backend takes the grouped
# leaf_gemm kernels; interpret=True executes the kernel bodies on CPU)
t0 = time.time()
y_pallas, out_k = api.apply(params, cfg, x, api.ExecutionSpec(
    mode="infer", backend="pallas", interpret=True))
err = float(jnp.abs(y_pallas - y_ref).max())
print(f"apply(backend='pallas')     {1e3*(time.time()-t0):7.1f}ms   "
      f"max|err| vs oracle = {err:.2e}")
# untrained random params put some tokens near decision boundaries where
# f32 reduction order can legitimately flip a routing sign; require near-
# total agreement rather than exact (hardened networks agree exactly)
route_agree = float((out_k.leaf_idx == out.leaf_idx).mean())
assert route_agree > 0.99, f"routing agreement {route_agree:.4f}"

# --- decode path: per-token gathered weights (the offset-load) ----------
# small batches route to the fused_fff gathered kernels automatically
xd = x[:8]
y_dec, _ = api.apply(params, cfg, xd, api.ExecutionSpec(
    mode="infer", backend="pallas", interpret=True))
y_dec_ref, _ = api.apply(params, cfg, xd,
                         api.ExecutionSpec(mode="infer", backend="reference"))
print(f"apply(backend='pallas', decode batch)  max|err| vs oracle = "
      f"{float(jnp.abs(y_dec - y_dec_ref).max()):.2e}")

# --- routing statistics --------------------------------------------------
leaf_idx = out.leaf_idx[:, 0]
hist = np.asarray(routing.leaf_histogram(leaf_idx, cfg.num_leaves))
skew = float(routing.routing_skew(leaf_idx, cfg.num_leaves))
print(f"\nrouting: leaf loads {hist.tolist()}  skew={skew:.2f} "
      f"(1.0 = perfectly balanced; capacity dispatch bounds the worst case)")
print("note: interpret=True executes the Pallas kernel bodies on CPU; on a "
      "TPU the same calls lower to MXU code (see DESIGN.md §3).  On TPU, "
      "backend='auto' selects the pallas path by itself.")
