"""Data pipeline: synthetic image-like and token-stream sources (the
environment is offline; datasets are procedurally generated with fixed seeds
so memorization/generalization semantics match the paper's protocol)."""
from repro.data import pipeline, synthetic, tokens
