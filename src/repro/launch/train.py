"""Training driver: data pipeline -> pjit train step -> checkpoint manager ->
fault supervisor.  Runs real steps on whatever devices exist (CPU here; the
same code path pjit-partitions on a pod — launch with the production mesh via
--mesh prod).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch olmoe-1b-7b --ffn fff \
      --steps 20 --batch 8 --seq 128 --reduced
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import optim, utils
from repro.checkpoint import CheckpointManager
from repro.configs import registry
from repro.data import tokens as tokens_lib
from repro.distributed import act, fault, sharding, straggler
from repro.launch import mesh as mesh_lib
from repro.models import lm


def _with_fff_training_opts(cfg, *, balance: float = 0.0,
                            master: bool = False):
    """Turn on the balance aux weight and/or master leaf on every FFF site
    of ``cfg`` (decoder and encoder periods alike; DESIGN.md §14)."""
    def upd(b):
        if b.ffn.kind != "fff":
            return b
        return dataclasses.replace(b, ffn=dataclasses.replace(
            b.ffn, balance_scale=balance, fff_master_leaf=master))

    cfg = dataclasses.replace(cfg,
                              period=tuple(upd(b) for b in cfg.period))
    if cfg.encoder is not None and cfg.encoder.period:
        cfg = dataclasses.replace(cfg, encoder=dataclasses.replace(
            cfg.encoder, period=tuple(upd(b) for b in cfg.encoder.period)))
    return cfg


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmoe-1b-7b",
                    choices=list(registry.ARCH_IDS))
    ap.add_argument("--ffn", default="fff", choices=["fff", "native", "dense"])
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--reduced", action="store_true",
                    help="tiny same-family config (CPU-runnable)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--mesh", default="host", choices=["host", "prod"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--balance-weight", type=float, default=0.0,
                    help="load-balancing aux weight over FFF soft leaf usage "
                         "(DESIGN.md §14); 0 = off")
    ap.add_argument("--master-leaf", action="store_true",
                    help="train with the always-on master leaf "
                         "(arxiv 2405.16836) — enables master_leaf overflow "
                         "repair at serving time")
    args = ap.parse_args()

    cfg = registry.get_config(args.arch, ffn=args.ffn)
    if args.reduced:
        cfg = cfg.reduced()
    if args.balance_weight or args.master_leaf:
        cfg = _with_fff_training_opts(cfg, balance=args.balance_weight,
                                      master=args.master_leaf)
    mesh = (mesh_lib.make_production_mesh() if args.mesh == "prod"
            else mesh_lib.make_host_mesh())
    rules = sharding.activation_rules(mesh)

    key = jax.random.PRNGKey(args.seed)
    params = lm.init(key, cfg)
    print(f"{cfg.arch_id}: {utils.tree_size(params)/1e6:.1f}M params, "
          f"mesh={dict(mesh.shape)}")
    params = sharding.shard_params(params, mesh, fsdp=cfg.zero_stage >= 3)

    opt = optim.chain_clip(
        optim.adamw(optim.cosine_warmup(args.lr, args.steps // 10 + 1,
                                        args.steps)), 1.0)
    opt_state = opt.init(params)
    source = tokens_lib.MarkovTokenSource(cfg.vocab_size, seed=args.seed)

    def train_step(params, opt_state, batch, rng):
        def loss(p):
            return lm.loss_fn(p, cfg, batch, rng)
        (l, metrics), grads = jax.value_and_grad(loss, has_aux=True)(params)
        updates, opt_state = opt.update(grads, opt_state, params)
        return optim.apply_updates(params, updates), opt_state, metrics

    with act.use_mesh(mesh, rules):
        step_jit = jax.jit(train_step, donate_argnums=(0, 1))

        manager = CheckpointManager(args.ckpt_dir, keep=2)
        tracker = straggler.StepTimeTracker(1)

        state = {"params": params, "opt": opt_state}

        def do_step(state, i):
            batch = source.batch(args.batch, args.seq, seed=args.seed + i)
            if cfg.frontend != "none" and cfg.encoder is None:
                emb = np.random.default_rng(i).normal(
                    0, 1, (args.batch, args.seq, cfg.d_model)).astype(np.float32)
                batch = {"embeds": emb, "labels": batch["labels"]}
            if cfg.encoder is not None:
                enc = np.random.default_rng(i).normal(
                    0, 1, (args.batch, cfg.encoder.seq_len,
                           cfg.d_model)).astype(np.float32)
                batch["enc_embeds"] = enc
            t0 = time.time()
            p2, o2, metrics = step_jit(state["params"], state["opt"], batch,
                                       jax.random.fold_in(key, i))
            loss = float(metrics["loss"])
            dt = time.time() - t0
            tracker.record([dt])
            print(f"step {i:4d} loss {loss:8.4f} ce {float(metrics['ce']):8.4f} "
                  f"harden {float(metrics['hardening']):6.3f} "
                  f"balance {float(metrics['balance']):7.4f} {dt*1e3:7.1f}ms",
                  flush=True)
            return {"params": p2, "opt": o2}

        sup = fault.TrainSupervisor(
            manager, fault.SupervisorConfig(ckpt_every=args.ckpt_every))
        result = sup.run(state, do_step, args.steps)
        print(f"done at step {result.step} (restarts={result.restarts})")


if __name__ == "__main__":
    main()
