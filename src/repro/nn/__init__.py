"""Neural substrate: attention, recurrent mixers, norms, FFN sites, stacks."""
from repro.nn import attention, embeddings, mamba, mlp, norms, rope, transformer, xlstm
