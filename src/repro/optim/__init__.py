"""Optimizers (no optax in this environment — own implementations)."""
from repro.optim.adamw import adamw
from repro.optim.sgd import sgd
from repro.optim.schedules import (constant, cosine_warmup, plateau_halving,
                                   Schedule)
from repro.optim.common import (Optimizer, apply_updates, clip_by_global_norm,
                                chain_clip)
from repro.optim.accum import gradient_accumulation
