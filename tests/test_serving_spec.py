"""Speculative-decoding tests (DESIGN.md §10).

Three tiers, mirroring tests/test_serving.py:
* host-only — rejection sampling (greedy chain + exact-distribution
  property), draft construction/slicing, the capacity-factor override;
* engine tier on the reduced config — greedy parity with ``lm.generate``
  in both prefill modes, the one-compile spec_round contract, acceptance
  telemetry, sampling determinism, the free-slot validity-mask regression,
  and config validation;
* a subprocess tier driving ``launch/serve.py --spec-k`` under a
  ``--model-parallel`` mesh with the ``grouped_ep`` backend.

The reduced target has one period, so the default ``self`` draft is the
full target sharing parameters — acceptance is ~1 by construction, which
is what makes greedy parity and the telemetry bounds deterministic."""
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.core import api, fff
from repro.models import lm
from repro.serving import (ContinuousBatchingEngine, EngineConfig, Request,
                           build_draft, rejection_sample, self_draft_config,
                           slice_draft_params)

from test_sharding import run_with_fake_devices


# ---------------------------------------------------------------------------
# host-only tier: rejection sampling
# ---------------------------------------------------------------------------

def _softmax(z):
    z = np.asarray(z, np.float64)
    e = np.exp(z - z.max())
    return e / e.sum()


def test_rejection_sample_greedy_is_target_argmax_chain():
    """Greedy: accepted prefix + correction = the target argmax at every
    position, token for token — agreement beyond the first mismatch is
    irrelevant."""
    rng = np.random.default_rng(0)
    V, m = 11, 4
    p = rng.normal(size=(m + 1, V))
    argmax = p.argmax(1)
    # drafts agree on the first two positions, diverge at the third
    drafts = argmax[:m].copy()
    drafts[2] = (drafts[2] + 1) % V
    emitted, n_acc = rejection_sample(p, rng.normal(size=(m, V)), drafts, 0.0)
    assert n_acc == 2
    assert emitted == [int(a) for a in argmax[:3]]
    # full agreement: all m accepted plus the bonus token
    emitted, n_acc = rejection_sample(p, rng.normal(size=(m, V)),
                                      argmax[:m], 0.0)
    assert n_acc == m
    assert emitted == [int(a) for a in argmax]


def test_rejection_sample_preserves_target_distribution():
    """The Leviathan guarantee: whatever the draft proposes, the first
    emitted token is distributed exactly as the target's softmax.  Checked
    empirically against a deliberately mismatched draft."""
    rng = np.random.default_rng(1)
    V, temp, n = 6, 0.7, 4000
    p_logits = rng.normal(size=(2, V))
    q_logits = rng.normal(size=(1, V)) * 2.0       # badly calibrated draft
    q = _softmax(q_logits[0] / temp)
    counts = np.zeros(V)
    for i in range(n):
        r = np.random.default_rng(1000 + i)
        d = np.array([r.choice(V, p=q)])           # draft samples from q
        emitted, _ = rejection_sample(p_logits, q_logits, d, temp, r)
        counts[emitted[0]] += 1
    want = _softmax(p_logits[0] / temp)
    np.testing.assert_allclose(counts / n, want, atol=0.03)


def test_rejection_sample_accept_rate_matches_overlap():
    """When draft == target the acceptance probability is 1 exactly (the
    min(1, p/q) ratio is 1 for every token)."""
    rng = np.random.default_rng(2)
    V = 8
    logits = rng.normal(size=(3, V))
    p = np.concatenate([logits, rng.normal(size=(1, V))])
    for i in range(50):
        r = np.random.default_rng(i)
        drafts = np.array([np.random.default_rng(7 + j).choice(
            V, p=_softmax(logits[j])) for j in range(3)])
        _, n_acc = rejection_sample(p, logits, drafts, 1.0, r)
        assert n_acc == 3


# ---------------------------------------------------------------------------
# host-only tier: draft construction
# ---------------------------------------------------------------------------

def test_self_draft_slices_share_target_leaves():
    cfg = registry.get_config("internlm2-20b", ffn="fff").reduced()
    cfg2 = self_draft_config(cfg, 1)
    assert cfg2.n_layers == len(cfg.period)
    params = lm.init(jax.random.PRNGKey(0), cfg)
    sliced = slice_draft_params(params, cfg, 1)
    assert sliced["embed"] is params["embed"]          # shared, not copied
    for p in sliced["stack"]:
        assert all(a.shape[0] == 1
                   for a in jax.tree_util.tree_leaves(p))
    with pytest.raises(ValueError, match="out of range"):
        self_draft_config(cfg, cfg.n_periods + 1)
    with pytest.raises(ValueError, match="out of range"):
        slice_draft_params(params, cfg, 0)


def test_build_draft_specs():
    cfg = registry.get_config("internlm2-20b", ffn="fff").reduced()
    params = lm.init(jax.random.PRNGKey(0), cfg)
    dp, dcfg = build_draft(None, params, cfg)          # None = "self"
    assert dcfg.n_layers == len(cfg.period)
    dp2, dcfg2 = build_draft("starcoder2-15b", params, cfg, seed=3)
    assert dcfg2.vocab_size == cfg.vocab_size
    assert dp2["embed"] is not params["embed"]         # independent init
    with pytest.raises(KeyError):
        build_draft("no-such-arch", params, cfg)


# ---------------------------------------------------------------------------
# host-only tier: the capacity-factor override (core/api)
# ---------------------------------------------------------------------------

def test_use_capacity_factor_scales_grouped_dispatch():
    """All tokens routed to one leaf: the default capacity drops half the
    batch; under the override the dispatch becomes loss-free and matches
    the exact reference output (the spec verify-slab contract)."""
    cfg = fff.FFFConfig(dim_in=8, dim_out=8, depth=2, leaf_width=4,
                        leaf_bias=False)
    params = fff.init(jax.random.PRNGKey(0), cfg)
    x = jnp.tile(jax.random.normal(jax.random.PRNGKey(1), (1, 8)), (64, 1))
    spec = api.ExecutionSpec(mode="infer", backend="grouped")
    _, out = api.apply(params, cfg, x, spec)
    assert float(out.overflow_fraction) == pytest.approx(0.5)
    with api.use_capacity_factor(16.0):
        y, out = api.apply(params, cfg, x, spec)
    assert float(out.overflow_fraction) == 0.0
    want, _ = api.apply(params, cfg, x, api.ExecutionSpec(
        mode="infer", backend="reference"))
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    # an explicit per-spec capacity factor wins over the context
    with api.use_capacity_factor(16.0):
        _, out = api.apply(params, cfg, x, api.ExecutionSpec(
            mode="infer", backend="grouped", capacity_factor=2.0))
    assert float(out.overflow_fraction) == pytest.approx(0.5)
    with pytest.raises(ValueError, match="positive"):
        with api.use_capacity_factor(0.0):
            pass


# ---------------------------------------------------------------------------
# engine tier (reduced config, single device)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def model():
    cfg = registry.get_config("internlm2-20b", ffn="fff").reduced()
    params = lm.init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _engine(cfg, params, **kw):
    defaults = dict(num_slots=4, max_len=48, max_prompt_len=16, spec_k=4,
                    seed=0)
    defaults.update(kw)
    return ContinuousBatchingEngine(params, cfg, EngineConfig(**defaults))


def _mixed_requests(n, rng, max_new=6):
    return [Request(rid=i,
                    prompt=rng.integers(1, 256, int(rng.integers(3, 17))),
                    max_new_tokens=max_new + int(rng.integers(0, 3)))
            for i in range(n)]


@pytest.mark.parametrize("chunk", [0, 8], ids=["monolithic", "chunked"])
def test_spec_engine_matches_lm_generate(model, chunk):
    """Greedy speculative serving must emit exactly the target argmax chain
    — the same tokens as the synchronous lm.generate oracle — in both
    prefill modes, whatever the per-round acceptance pattern was."""
    cfg, params = model
    eng = _engine(cfg, params, prefill_chunk=chunk)
    results, m = eng.run(_mixed_requests(6, np.random.default_rng(2)))
    assert m.draft_tokens > 0
    for r in results:
        want = lm.generate(params, cfg, jnp.asarray(r.prompt[None]),
                           steps=r.n_generated, max_len=48)
        np.testing.assert_array_equal(
            np.asarray(want)[0], np.concatenate([r.prompt, r.tokens]),
            err_msg=f"rid {r.rid}")


def test_spec_fixed_compiled_shapes(model):
    """The spec-mode compile contract: two waves of mixed requests compile
    exactly ONE fused spec_round (and no plain decode at all) — wired into
    the PR 5 compile-count gate in CI."""
    cfg, params = model
    eng = _engine(cfg, params, prefill_buckets=(8, 16))
    eng.run(_mixed_requests(5, np.random.default_rng(4)))
    warm = eng.compiled_shapes()
    eng.run(_mixed_requests(7, np.random.default_rng(5)))
    after = eng.compiled_shapes()
    assert after == warm, "recompilation after warmup"
    assert after["spec_round"] == 1
    assert after["decode"] == 0                       # replaced by the round
    assert after["admit"] == 1
    assert all(v <= 1 for k, v in after.items() if k.startswith("prefill_"))


def test_spec_acceptance_telemetry(model):
    """Self-draft on the one-period reduced target IS the target: greedy
    acceptance must be ~1, and the per-request counters must reconcile with
    the run totals."""
    cfg, params = model
    eng = _engine(cfg, params)
    results, m = eng.run(_mixed_requests(6, np.random.default_rng(6)))
    assert m.draft_tokens > 0
    assert m.spec_acceptance >= 0.9
    assert m.accepted_tokens + m.wasted_tokens == m.draft_tokens
    assert sum(r.n_drafted for r in results) == m.draft_tokens
    assert sum(r.n_accepted for r in results) == m.accepted_tokens
    snap = eng.poll_metrics()
    assert snap.draft_tokens == m.draft_tokens
    assert snap.spec_acceptance == pytest.approx(m.spec_acceptance)


def test_spec_sampling_deterministic(model):
    """Stochastic spec serving is a function of (seed, rid, position): two
    fresh engines produce identical outputs, and the draft PRNG stream must
    not alias the rejection stream."""
    cfg, params = model
    rng = np.random.default_rng(7)
    prompts = [rng.integers(1, 256, 7) for _ in range(4)]

    def run():
        eng = _engine(cfg, params)
        reqs = [Request(rid=i, prompt=p, max_new_tokens=5, temperature=0.8)
                for i, p in enumerate(prompts)]
        results, _ = eng.run(reqs)
        return [r.tokens.tolist() for r in results]

    assert run() == run()


def test_spec_free_slots_stay_phantom(model):
    """Validity-mask regression: one live request on a 4-slot spec engine
    must produce the exact same tokens AND the exact same per-phase overflow
    telemetry as a 1-slot engine — the three free rows route to the FFF
    sentinel leaf, outside capacity and outside the counters."""
    cfg, params = model

    def run(slots):
        eng = _engine(cfg, params, num_slots=slots, scheduler="leaf_aware",
                      fff_backend="grouped")
        rng = np.random.default_rng(8)
        reqs = [Request(rid=0, prompt=rng.integers(1, 256, 9),
                        max_new_tokens=6)]
        results, m = eng.run(reqs)
        return results[0], m, {k: tuple(v) for k, v in eng._overflow.items()}

    r4, m4, ovf4 = run(4)                             # 1 live row, 3 free
    r1, m1, ovf1 = run(1)                             # no free rows at all
    np.testing.assert_array_equal(r4.tokens, r1.tokens)
    assert ovf4 == ovf1
    assert m4.overflow_decode_mean == 0.0
    want = lm.generate(params, cfg, jnp.asarray(r4.prompt[None]),
                       steps=r4.n_generated, max_len=48)
    np.testing.assert_array_equal(np.asarray(want)[0],
                                  np.concatenate([r4.prompt, r4.tokens]))


def test_spec_config_validation(model):
    cfg, params = model
    with pytest.raises(ValueError, match="spec_k"):
        _engine(cfg, params, spec_k=-1)
    with pytest.raises(ValueError, match="draft_config"):
        _engine(cfg, params, spec_k=0, draft_config="self")
    bad_cfg = registry.get_config("internlm2-20b", ffn="fff").reduced(
        vocab=128)
    bad = lm.init(jax.random.PRNGKey(0), bad_cfg)
    with pytest.raises(ValueError, match="vocab"):
        ContinuousBatchingEngine(params, cfg, EngineConfig(
            num_slots=4, max_len=48, max_prompt_len=16, spec_k=2, seed=0),
            draft=(bad, bad_cfg))


def test_spec_draft_histograms_feed_scheduler_occupancy(model):
    """The FFF co-scheduling hook: draft rollouts must land leaf histograms
    in the engine's occupancy EWMA (phase "draft"), marked unmeasured so
    they never promote into persistent tenant profiles."""
    cfg, params = model
    eng = _engine(cfg, params)
    eng.run(_mixed_requests(3, np.random.default_rng(9)))
    assert eng._overflow["draft"][1] > 0               # draft phase recorded
    assert eng.overflow_mean("draft") >= 0.0
    # decode-phase telemetry (the scheduler's feedback signal) must not be
    # polluted by draft-model dispatches
    assert eng._overflow["decode"][1] > 0


# ---------------------------------------------------------------------------
# subprocess tier: spec e2e under the expert-parallel mesh
# ---------------------------------------------------------------------------

def test_spec_e2e_model_parallel_grouped_ep():
    """serve --engine continuous --spec-k 4 --model-parallel 4
    --fff-backend grouped_ep: the fused spec round traces under the
    (data, model) mesh; the self-draft keeps acceptance at ~1."""
    code = textwrap.dedent("""
        import sys
        sys.argv = ["serve", "--arch", "internlm2-20b", "--reduced",
                    "--engine", "continuous", "--scheduler", "leaf_aware",
                    "--batch", "4", "--requests", "6", "--prompt-len", "16",
                    "--gen", "4", "--fff-backend", "grouped_ep",
                    "--model-parallel", "4", "--spec-k", "4"]
        from repro.launch import serve
        serve.main()
    """)
    out = run_with_fake_devices(code)
    assert "speculative" in out
    assert "served 6 requests" in out
    assert "acceptance" in out
