"""Serving driver: batched prefill + decode with KV/state caches.

Demonstrates the FFF serving path end-to-end: hard tree routing per FFN site,
grouped leaf execution, per-step latency stats.  Runs reduced configs on CPU;
the same step functions pjit onto the pod meshes (see dryrun.py for the
compile proof at the production shapes).

Model code invokes every FFF site through ``api.apply(..., backend="auto")``;
this driver steers the whole stack's execution strategy with
``--fff-backend`` via ``api.use_backend`` — the launch-layer end of the
backend-registry seam (core/api.py, DESIGN.md §2).

``--model-parallel M`` installs an (all-devices/M, M) (data, model) mesh and
shards the params onto it — the expert-parallel serving topology the
``grouped_ep`` backend exchanges tokens over (DESIGN.md §5).  On a CPU host,
combine with ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` to
exercise the collective path.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch internlm2-20b --reduced \
      --batch 4 --prompt-len 32 --gen 16 [--fff-backend grouped_ep] \
      [--model-parallel 4]
"""
from __future__ import annotations

import argparse
import contextlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import utils
from repro.configs import registry
from repro.core import api
from repro.data import tokens as tokens_lib
from repro.models import lm


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-20b",
                    choices=list(registry.ARCH_IDS))
    ap.add_argument("--ffn", default="fff", choices=["fff", "native", "dense"])
    ap.add_argument("--fff-backend", default="auto",
                    choices=["auto"] + api.list_backends("infer"),
                    help="execution backend for every FFF site (auto = "
                         "per-site resolution; see core/api.py)")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--model-parallel", type=int, default=1,
                    help="model-axis size of the serving mesh; >1 installs "
                         "a (data, model) mesh over all devices so FFF "
                         "sites serve expert-parallel (grouped_ep)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = registry.get_config(args.arch, ffn=args.ffn)
    if args.reduced:
        cfg = cfg.reduced()
    key = jax.random.PRNGKey(args.seed)
    params = lm.init(key, cfg)
    print(f"{cfg.arch_id}: {utils.tree_size(params)/1e6:.1f}M params")

    if args.model_parallel > 1:
        from repro.distributed import act, sharding
        from repro.launch import mesh as mesh_lib
        mesh = mesh_lib.make_serving_mesh(args.model_parallel)
        rules = sharding.activation_rules(mesh)
        params = sharding.shard_params(params, mesh, fsdp=False)
        print(f"mesh: {dict(mesh.shape)} (expert-parallel serving)")

        def mesh_ctx():
            return act.use_mesh(mesh, rules)
    else:
        mesh_ctx = contextlib.nullcontext

    src = tokens_lib.MarkovTokenSource(cfg.vocab_size, seed=args.seed)
    prompt = jnp.asarray(src.sample(args.batch, args.prompt_len, seed=1)
                         [:, :args.prompt_len])
    max_len = args.prompt_len + args.gen + 1

    batch = {"tokens": prompt}
    if cfg.encoder is not None:
        batch["enc_embeds"] = jnp.asarray(np.random.default_rng(0).normal(
            0, 1, (args.batch, cfg.encoder.seq_len, cfg.d_model)),
            jnp.float32)
    if cfg.frontend != "none" and cfg.encoder is None:
        batch = {"embeds": jnp.asarray(np.random.default_rng(0).normal(
            0, 1, (args.batch, args.prompt_len, cfg.d_model)), jnp.float32)}

    prefill_jit = jax.jit(lambda p, b, c: lm.prefill(p, cfg, b, c))
    decode_jit = jax.jit(lambda p, t, c, off: lm.decode_step(p, cfg, t, c, off))

    # the backend override is read at trace time; wrap every call since any
    # shape change retraces
    def backend_ctx():
        # mode="infer": never let a serving override redirect train-mode math
        return (api.use_backend(args.fff_backend, mode="infer")
                if args.fff_backend != "auto" else contextlib.nullcontext())

    caches = lm.init_caches(cfg, args.batch, max_len)
    t0 = time.time()
    with mesh_ctx(), backend_ctx():
        logits, caches = prefill_jit(params, batch, caches)
    logits.block_until_ready()
    t_prefill = time.time() - t0
    # "requested": ineligible sites fall through to auto heuristics
    # (core/api.py supports predicates), so the label is the override, not
    # a per-site guarantee
    print(f"prefill: {args.batch}x{args.prompt_len} in {t_prefill*1e3:.1f}ms "
          f"(incl. compile, fff backend={args.fff_backend} requested)")

    tok = logits.argmax(-1)[:, None].astype(jnp.int32)
    out = [tok]
    lat = []
    for i in range(args.gen):
        t0 = time.time()
        with mesh_ctx(), backend_ctx():
            logits, caches = decode_jit(params, tok, caches,
                                        jnp.int32(args.prompt_len + i))
        logits.block_until_ready()
        lat.append(time.time() - t0)
        tok = logits.argmax(-1)[:, None].astype(jnp.int32)
        out.append(tok)
    gen = jnp.concatenate(out, axis=1)
    lat_steady = lat[1:] if len(lat) > 1 else lat
    print(f"decode: {args.gen} steps; first {lat[0]*1e3:.1f}ms (compile), "
          f"steady p50 {np.median(lat_steady)*1e3:.2f}ms "
          f"p95 {np.percentile(lat_steady, 95)*1e3:.2f}ms")
    print("sample continuation:", np.asarray(gen[0])[:12].tolist())


if __name__ == "__main__":
    main()
