"""Pallas TPU kernels for the FFF hot spots (DESIGN.md §3):

  tree_router  — fused multi-level tree descent (routing)
  leaf_gemm    — ragged grouped GEMM over sorted tokens (batch serving)
  fused_fff    — per-token gathered leaf matmul (decode; the paper's
                 offset-load, expressed as a scalar-prefetch index map)
  fused_decode — the decode MEGAKERNEL: routing + selected-leaf MLP +
                 forest combine in ONE dispatch for the serving engine's
                 (num_slots, 1) shape (DESIGN.md §13)

Each kernel ships ops.py (jit wrapper) and ref.py (pure-jnp oracle); tests
sweep shapes x dtypes in interpret mode against the oracle.

Consumers do not call these directly: the packages are wired into the
execution-backend registry as the ``"pallas"`` and ``"pallas_decode"``
backends of ``repro.core.api.apply()`` (selected automatically on TPU for
kernel-eligible configs, or explicitly via ``ExecutionSpec(backend=...)``).
The raw ``fff_infer`` / ``fff_decode`` / ``fused_decode`` wrappers remain
exported for kernel-level tests and benchmarking.
"""
from repro.kernels import fused_decode, fused_fff, leaf_gemm, tree_router
