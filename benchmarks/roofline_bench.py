"""Roofline summary: renders the dry-run artifact (experiments/dryrun_full.json)
into the per-(arch x shape x mesh) three-term table used by EXPERIMENTS.md
§Roofline.  Run ``python -m repro.launch.dryrun --all --out
experiments/dryrun_full.json`` first (hours of compiles); this benchmark only
formats and sanity-checks the stored records.
"""
from __future__ import annotations

import json
import os

ARTIFACT = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "experiments", "dryrun_full.json")


def load(path: str = ARTIFACT) -> list[dict]:
    if not os.path.exists(path):
        return []
    with open(path) as f:
        return json.load(f)


def main(quick: bool = True):
    recs = load()
    print("name,us_per_call,derived")
    if not recs:
        print("roofline/missing,0.0,run_dryrun_first=1")
        return []
    ok = [r for r in recs if r.get("status") == "ok"]
    for r in ok:
        name = f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}"
        t_max = max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"])
        print(f"{name},{t_max*1e6:.0f},"
              f"tc={r['t_compute_s']:.3f};tm={r['t_memory_s']:.3f};"
              f"tx={r['t_collective_s']:.3f};dom={r['dominant']};"
              f"rf={r['roofline_fraction']:.4f};"
              f"useful={r['useful_ratio']:.3f};"
              f"fits={int(r.get('fits_v5e_16g', False))}")
    n_skip = sum(r.get("status") == "skipped" for r in recs)
    n_err = sum(r.get("status") == "error" for r in recs)
    print(f"roofline/summary,0.0,ok={len(ok)};skipped={n_skip};errors={n_err}")
    return recs


if __name__ == "__main__":
    main()
