"""olmoe-1b-7b [moe] — 16L d_model=2048 16H (GQA kv=16) d_ff=1024 (per expert)
vocab=50304, MoE 64 experts top-8.  [arXiv:2409.02060; hf]

FFF-for-MoE showcase: 64 experts = 2^6 exactly, so the tree replacement is
width-exact — forest of 8 trees (matching top-8), each depth 3 with leaf width
1024: 8 * 8 * 1024 = 65536 = 64 * 1024.  Inference width 8*1024 = top-8 active
width, but routing is O(8*3) node dots instead of an O(64) gate."""
import jax.numpy as jnp

from repro.configs.base import BlockSpec, FFNSpec, ModelConfig

CONFIG = ModelConfig(
    arch_id="olmoe-1b-7b",
    family="moe",
    d_model=2048,
    n_layers=16,
    n_heads=16,
    n_kv_heads=16,
    vocab_size=50304,
    max_seq_len=32768,
    period=(BlockSpec(mixer="attn",
                      ffn=FFNSpec(kind="moe", d_ff=1024, activation="swiglu",
                                  moe_experts=64, moe_top_k=8)),),
    param_dtype=jnp.bfloat16,
    accum_dtype=jnp.bfloat16,
    remat="full",
    grad_accum=16,
)

FFF_CONFIG = CONFIG.with_ffn_kind("fff", leaf_width=1024, trees=8)
