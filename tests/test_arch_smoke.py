"""Per-arch smoke tests: every assigned architecture instantiates a REDUCED
same-family config and runs one forward/train step + prefill/decode on CPU,
asserting output shapes and finiteness (assignment requirement)."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import registry
from repro.models import lm
from repro import optim


def _batch(cfg, B, S, key):
    batch = {"labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.encoder is not None:
        batch["tokens"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
        batch["enc_embeds"] = jax.random.normal(
            key, (B, cfg.encoder.seq_len, cfg.d_model))
    elif cfg.frontend != "none":
        batch["embeds"] = jax.random.normal(key, (B, S, cfg.d_model))
    else:
        batch["tokens"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    return batch


@pytest.mark.parametrize("arch", registry.ARCH_IDS)
@pytest.mark.parametrize("ffn", ["fff", "native"])
def test_reduced_forward_and_train_step(arch, ffn):
    cfg = registry.get_config(arch, ffn=ffn).reduced()
    key = jax.random.PRNGKey(0)
    params = lm.init(key, cfg)
    B, S = 2, 16
    batch = _batch(cfg, B, S, jax.random.PRNGKey(1))

    loss, metrics = lm.loss_fn(params, cfg, batch, rng=jax.random.PRNGKey(2))
    assert jnp.isfinite(loss), arch
    assert float(loss) > 0

    # one SGD step decreases nothing catastrophic and keeps params finite
    opt = optim.sgd(1e-2)
    state = opt.init(params)
    grads = jax.grad(lambda p: lm.loss_fn(p, cfg, batch)[0])(params)
    updates, state = opt.update(grads, state, params)
    params2 = optim.apply_updates(params, updates)
    for leaf in jax.tree_util.tree_leaves(params2):
        assert jnp.isfinite(leaf).all(), arch


@pytest.mark.parametrize("arch", registry.ARCH_IDS)
def test_reduced_prefill_decode(arch):
    cfg = registry.get_config(arch, ffn="fff").reduced()
    key = jax.random.PRNGKey(0)
    params = lm.init(key, cfg)
    B, S = 2, 12
    batch = _batch(cfg, B, S, jax.random.PRNGKey(1))
    batch.pop("labels")
    caches = lm.init_caches(cfg, B, S + 8)
    logits, caches = lm.prefill(params, cfg, batch, caches)
    assert logits.shape == (B, cfg.vocab_size)
    assert jnp.isfinite(logits).all(), arch
    tok = logits.argmax(-1)[:, None].astype(jnp.int32)
    for i in range(2):
        logits, caches = lm.decode_step(params, cfg, tok, caches,
                                        pos_offset=S + i)
        assert jnp.isfinite(logits).all(), arch
        tok = logits.argmax(-1)[:, None].astype(jnp.int32)


def test_scan_matches_unrolled():
    cfg = registry.get_config("internlm2-20b", ffn="fff").reduced()
    cfg_s = dataclasses.replace(cfg, scan_layers=True, n_layers=4)
    cfg_u = dataclasses.replace(cfg, scan_layers=False, n_layers=4)
    params = lm.init(jax.random.PRNGKey(0), cfg_s)
    batch = _batch(cfg_s, 2, 16, jax.random.PRNGKey(1))
    l1, _ = lm.loss_fn(params, cfg_s, batch)
    l2, _ = lm.loss_fn(params, cfg_u, batch)
    assert abs(float(l1) - float(l2)) < 1e-4


def test_ffn_variant_switching_preserves_training_width():
    for arch in ("internlm2-20b", "olmoe-1b-7b", "jamba-1.5-large-398b"):
        native = registry.get_config(arch, ffn="native")
        fffv = registry.get_config(arch, ffn="fff")
        for b_n, b_f in zip(native.period, fffv.period):
            if b_n.ffn.kind == "none":
                continue
            assert b_f.ffn.kind == "fff"
            # FFF training width >= native (paper allows growth to next pow2)
            assert b_f.ffn.training_width >= b_n.ffn.training_width
            # and the active (inference) width never exceeds the native active
            assert b_f.ffn.active_width <= max(b_n.ffn.active_width,
                                               b_f.ffn.fff_leaf_width
                                               * b_f.ffn.fff_trees)


def test_xlstm_has_no_ffn_sites():
    cfg = registry.get_config("xlstm-1.3b", ffn="fff")
    assert all(b.ffn.kind == "none" for b in cfg.period)
