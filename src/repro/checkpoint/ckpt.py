"""Checkpoint serialization: a pytree -> directory of .npz shards + manifest.

Format:
  <dir>/manifest.json   {"step", "leaf_paths", "treedef", "meta"}
  <dir>/arrays-<k>.npz  flat leaf arrays, keyed by escaped path strings

Arrays are gathered to host before writing (on multi-host pods each process
writes its addressable shards; the single-process degenerate case writes the
whole array).  Restore is sharding-agnostic: arrays are loaded on host and
re-placed by the caller (see elastic.py), which is what makes N->M device
count changes trivial.
"""
from __future__ import annotations

import json
import os
from typing import Any, Optional

import jax
import ml_dtypes
import numpy as np

PyTree = Any

_SEP = "/"

# numpy can't round-trip ml_dtypes (bf16 etc.) through npz: store the raw bits
# in a same-width integer view and restore via the manifest's dtype string.
_BITCAST = {
    "bfloat16": (ml_dtypes.bfloat16, np.uint16),
    "float8_e4m3fn": (ml_dtypes.float8_e4m3fn, np.uint8),
    "float8_e5m2": (ml_dtypes.float8_e5m2, np.uint8),
}


def _flatten_with_paths(tree: PyTree) -> tuple[list[tuple[str, Any]], Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = _SEP.join(_path_str(p) for p in path)
        out.append((key, leaf))
    return out, treedef


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def save_tree(directory: str, tree: PyTree, step: int = 0,
              meta: Optional[dict] = None, max_shard_mb: int = 512) -> None:
    os.makedirs(directory, exist_ok=True)
    flat, _ = _flatten_with_paths(tree)
    manifest = {"step": int(step), "meta": meta or {}, "shards": [],
                "leaves": []}
    shard: dict[str, np.ndarray] = {}
    shard_bytes = 0
    shard_id = 0

    def flush():
        nonlocal shard, shard_bytes, shard_id
        if not shard:
            return
        fname = f"arrays-{shard_id}.npz"
        np.savez(os.path.join(directory, fname), **shard)
        manifest["shards"].append(fname)
        shard = {}
        shard_bytes = 0
        shard_id += 1

    for key, leaf in flat:
        arr = np.asarray(jax.device_get(leaf))
        dtype_name = str(arr.dtype)
        if dtype_name in _BITCAST:
            arr = arr.view(_BITCAST[dtype_name][1])
        manifest["leaves"].append(
            {"key": key, "shape": list(arr.shape), "dtype": dtype_name,
             "shard": shard_id})
        shard[key] = arr
        shard_bytes += arr.nbytes
        if shard_bytes >= max_shard_mb * 1024 * 1024:
            flush()
    flush()
    with open(os.path.join(directory, "manifest.json"), "w") as f:
        json.dump(manifest, f)


def restore_tree(directory: str, like: PyTree) -> tuple[PyTree, int, dict]:
    """Restore into the structure of ``like``; returns (tree, step, meta)."""
    with open(os.path.join(directory, "manifest.json")) as f:
        manifest = json.load(f)
    arrays: dict[str, np.ndarray] = {}
    for fname in manifest["shards"]:
        with np.load(os.path.join(directory, fname)) as z:
            for k in z.files:
                arrays[k] = z[k]
    dtypes = {l["key"]: l["dtype"] for l in manifest["leaves"]}
    flat, treedef = _flatten_with_paths(like)
    leaves = []
    for key, leaf in flat:
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = arrays[key]
        dtype_name = dtypes.get(key, "")
        if dtype_name in _BITCAST:
            arr = arr.view(_BITCAST[dtype_name][0])
        want = tuple(np.shape(leaf))
        if tuple(arr.shape) != want:
            raise ValueError(f"shape mismatch for {key}: ckpt {arr.shape} "
                             f"vs model {want}")
        leaves.append(arr)
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    return tree, manifest["step"], manifest.get("meta", {})
