"""Paged-KV / prefix-sharing benchmark: page-pool cache with cross-request
prefix sharing vs the contiguous per-slot cache (DESIGN.md §11).

Workload: the shared-system-prompt pattern paging exists for — every
request opens with the same ``SHARED_LEN``-token system prompt and adds a
short unique suffix.  Under the contiguous cache each admission re-prefills
the whole prompt; under paging the first admission publishes the system
prompt's pages into the prefix index and every later admission maps them
(refcounted, copy-on-write past the shared boundary) and prefills only its
suffix.

Rows (same model, same requests, same seed):
  * contiguous — ``page_size=0``: the degenerate one-page-per-slot layout,
    numerically the PR 2 slot-pooled cache
  * paged      — ``page_size=PAGE``: pool + page tables + prefix index

Gates (printed + recorded in the artifact):
  * paged prefills >= ``PREFILL_GATE``x fewer tokens than contiguous
    (``prefill_tokens`` telemetry; the compute the prefix index avoids)
  * paged mean TTFT < contiguous mean TTFT (less prefill work before the
    first token, measured compile-free via a warmup run)
  * parity — greedy paged-engine output must equal ``lm.generate`` exactly
    for every request (sharing pages must not change a single token)
  * compile contract — decode 1 / admit 1 / <= 1 shape per prefill bucket
    after the timed run (paging adds no retracing)

Emits CSV rows
``serving_paged,<name>,<page>,<prefill_tokens>,<prefix_hit_tokens>,
<ttft_mean_ms>,<tok_s>`` and writes
``experiments/BENCH_serving_paged.json``.
"""
from __future__ import annotations

import json
import os

ARTIFACT = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "experiments", "BENCH_serving_paged.json")

PAGE = 16           # tokens per page in the paged row
SHARED_LEN = 48     # shared system prompt (3 full pages)
SUFFIX_MAX = 8      # unique per-request tail: 1..SUFFIX_MAX tokens
GEN = 8             # short generations: the bench is prefill-bound
PREFILL_GATE = 5.0  # paged must prefill >= this factor fewer tokens


def make_workload(cfg, n_requests: int, seed: int):
    """Shared-system-prompt requests: SHARED_LEN common tokens + a unique
    1..SUFFIX_MAX-token suffix each."""
    import numpy as np

    from repro.data import tokens as tokens_lib
    from repro.serving import Request

    src = tokens_lib.MarkovTokenSource(cfg.vocab_size, seed=seed)
    system = src.sample(1, SHARED_LEN, seed=seed)[0, :SHARED_LEN]
    rng = np.random.default_rng(seed + 1)
    reqs = []
    for i in range(n_requests):
        s = int(rng.integers(1, SUFFIX_MAX + 1))
        suffix = src.sample(1, s, seed=seed + 10 + i)[0, :s]
        reqs.append(Request(rid=i,
                            prompt=np.concatenate([system, suffix]),
                            max_new_tokens=GEN))
    return reqs


def run_one(params, cfg, *, slots: int, reqs, seed: int, page_size: int,
            warmup_reqs=None):
    from repro.serving import ContinuousBatchingEngine, EngineConfig
    max_prompt = SHARED_LEN + SUFFIX_MAX
    ecfg = EngineConfig(
        num_slots=slots, max_len=max_prompt + GEN + 1,
        max_prompt_len=max_prompt, page_size=page_size, seed=seed)
    engine = ContinuousBatchingEngine(params, cfg, ecfg)
    if warmup_reqs:
        # burn every compile (and, for the paged row, seed the prefix index
        # with the system prompt) outside the timed run: the TTFT gate
        # compares steady-state admission, not XLA
        engine.run(warmup_reqs)
    _, m = engine.run(reqs)
    return engine, m


def check_parity(params, cfg, results) -> int:
    """Greedy paged-engine output vs the synchronous ``lm.generate`` path —
    exact, token for token.  Returns requests checked."""
    import jax.numpy as jnp
    import numpy as np

    from repro.models import lm
    max_len = SHARED_LEN + SUFFIX_MAX + GEN + 1
    for r in results:
        want = lm.generate(params, cfg, jnp.asarray(r.prompt[None]),
                           steps=r.n_generated, max_len=max_len)
        np.testing.assert_array_equal(
            np.asarray(want)[0], np.concatenate([r.prompt, r.tokens]),
            err_msg=f"rid {r.rid}")
    return len(results)


def main(quick: bool = True) -> None:
    import jax

    from repro.configs import registry
    from repro.models import lm

    seed = 0
    slots = 8 if quick else 16
    n_requests = 2 * slots
    cfg = registry.get_config("internlm2-20b", ffn="fff").reduced(
        seq=SHARED_LEN + SUFFIX_MAX + GEN + 1)
    params = lm.init(jax.random.PRNGKey(seed), cfg)

    print("# name,page,prefill_tokens,prefix_hit_tokens,ttft_mean_ms,tok_s")
    reqs = make_workload(cfg, n_requests, seed + 1)
    warm = make_workload(cfg, slots, seed + 2)
    runs = {}
    engines = {}
    for name, page in [("contiguous", 0), ("paged", PAGE)]:
        engine, m = run_one(params, cfg, slots=slots, reqs=list(reqs),
                            seed=seed, page_size=page, warmup_reqs=warm)
        print(f"serving_paged,{name},{page},{m.prefill_tokens},"
              f"{m.prefix_hit_tokens},{m.ttft.mean_ms:.2f},"
              f"{m.throughput_tok_s:.1f}", flush=True)
        runs[name] = {"page_size": page, "slots": slots,
                      "n_requests": n_requests, **m.as_dict()}
        engines[name] = engine

    base, paged = runs["contiguous"], runs["paged"]
    prefill_ratio = base["prefill_tokens"] / max(paged["prefill_tokens"], 1)
    prefill_ok = prefill_ratio >= PREFILL_GATE
    ttft_ok = (paged["ttft_ms"]["mean_ms"] < base["ttft_ms"]["mean_ms"])
    print(f"# prefill tokens {base['prefill_tokens']} -> "
          f"{paged['prefill_tokens']} = {prefill_ratio:.1f}x fewer "
          f"({'PASS' if prefill_ok else 'FAIL'} vs {PREFILL_GATE}x gate)")
    print(f"# ttft mean {base['ttft_ms']['mean_ms']:.2f}ms -> "
          f"{paged['ttft_ms']['mean_ms']:.2f}ms "
          f"({'PASS' if ttft_ok else 'FAIL'}: paged must improve)")

    # parity: sharing pages must not change one token of one request
    results, _ = engines["paged"].run(make_workload(cfg, slots, seed + 3))
    n_parity = check_parity(params, cfg, results)
    print(f"# parity: {n_parity} paged requests match lm.generate exactly")

    shapes = engines["paged"].compiled_shapes()
    compile_ok = (shapes["decode"] == 1 and shapes["admit"] == 1 and all(
        v <= 1 for k, v in shapes.items() if k.startswith("prefill_")))
    print(f"# compiled shapes {shapes} -> "
          f"{'PASS' if compile_ok else 'FAIL'} (decode 1 / admit 1 / <=1 "
          f"per bucket)")

    with open(ARTIFACT, "w") as f:
        json.dump({"bench": "serving_paged", "quick": quick, "slots": slots,
                   "page_size": PAGE, "shared_len": SHARED_LEN, "gen": GEN,
                   "prefill_ratio": prefill_ratio,
                   "prefill_gate": PREFILL_GATE, "prefill_ok": prefill_ok,
                   "ttft_ok": ttft_ok, "parity_checked": n_parity,
                   "compile_ok": compile_ok, "compiled_shapes": shapes,
                   "runs": runs}, f, indent=1)
    print(f"# wrote {ARTIFACT}")
    if not (prefill_ok and ttft_ok and compile_ok):
        raise AssertionError(
            f"serving_paged gates failed: prefill_ok={prefill_ok} "
            f"ttft_ok={ttft_ok} compile_ok={compile_ok}")


if __name__ == "__main__":
    main()
