"""Activation-sharding constraint points.

Model code calls ``shard(x, kind)`` at block boundaries; the launch layer
installs a (mesh, rules) context so the same model code runs unsharded on one
CPU device and fully sharded under pjit on the production mesh.  With no
context installed this is a no-op.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


def _ctx() -> Optional[tuple]:
    return getattr(_state, "ctx", None)


@contextlib.contextmanager
def use_mesh(mesh: Mesh, rules: dict[str, P]):
    """Install activation sharding rules for the dynamic extent of a trace."""
    prev = _ctx()
    _state.ctx = (mesh, rules)
    try:
        yield
    finally:
        _state.ctx = prev


def mesh_installed() -> bool:
    """Whether a (mesh, rules) context is active for the current trace."""
    return _ctx() is not None


def current_mesh() -> Optional[Mesh]:
    """The installed mesh, or None when tracing unsharded.  Backends that
    enter manual (shard_map) regions — e.g. the grouped_ep serving path —
    read it here at trace time (DESIGN.md §5)."""
    ctx = _ctx()
    return None if ctx is None else ctx[0]


def model_shard_count() -> int:
    """Size of the model axis of the installed mesh (1 when tracing
    unsharded or with no model axis)."""
    ctx = _ctx()
    if ctx is None:
        return 1
    mesh, _ = ctx
    return mesh.shape["model"] if "model" in mesh.axis_names else 1


def data_shard_count() -> int:
    """Number of data-parallel shards in the installed mesh context (1 when
    tracing unsharded).  Model code uses this to block token axes so that
    data-dependent dispatch stays shard-local (DESIGN.md §5)."""
    ctx = _ctx()
    if ctx is None:
        return 1
    mesh, _ = ctx
    n = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            n *= mesh.shape[a]
    return n


def shard(x: jax.Array, kind: str) -> jax.Array:
    ctx = _ctx()
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = rules.get(kind)
    if spec is None:
        return x
    # pad/truncate the spec to the array rank
    spec = P(*(tuple(spec) + (None,) * x.ndim)[:x.ndim])
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# canonical rule keys used by the model code
TOKENS_BS = "tokens_bs"          # (B, S) token ids
ACT_BSD = "act_bsd"              # (B, S, D) residual stream
LOGITS_BSV = "logits_bsv"        # (B, S, V)
KV_CACHE = "kv_cache"            # (B, S, K, hd)
EXPERT_BLD = "expert_bld"        # (B, leaves/experts, ...) mixtures
DISPATCH_ECD = "dispatch_ecd"    # (G, E, capacity, D) grouped-dispatch
                                 # buffers, training: G on the data axes so
                                 # per-leaf GEMMs stay data-parallel
NODE_BTN = "node_btn"           # (B, T, N) FFF node logits: data-parallel
DISPATCH_SERVE = "dispatch_serve"  # serving: E on the model axis — tokens
                                   # travel to the (expert-parallel) leaf
                                   # shards instead of weights being gathered
                                   # to tokens (decode reads O(B*l*D) weight
                                   # bytes, not O(2^d*l*D))
TOKENS_EP = "tokens_ep"            # (B, D) flat tokens split over EVERY mesh
                                   # axis (data *and* model) — the entry
                                   # layout of the grouped_ep shard_map
                                   # region, so the a2a sees B/(G*M) tokens
                                   # per shard (DESIGN.md §5)
