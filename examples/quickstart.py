"""Quickstart: the FFF layer as a drop-in feedforward replacement.

Trains a small fast-feedforward network on a synthetic image task, watches
the hardening process, then serves it with hard (FORWARD_I) routing — the
whole paper in ~60 lines of user code.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.core import fff
from repro.data import synthetic

# --- 1. data ---------------------------------------------------------------
ds = synthetic.make("mnist_like")
print(f"dataset: {ds.x_train.shape[0]} train / {ds.x_test.shape[0]} test, "
      f"dim={ds.dim}, classes={ds.num_classes}")

# --- 2. an FFF layer: depth 4, leaf width 8 => training width 128,
#        inference width 8 (the paper's headline trade) -----------------------
cfg = fff.FFFConfig(dim_in=ds.dim, dim_out=ds.num_classes, depth=4,
                    leaf_width=8, activation="relu", hardening_scale=3.0)
params = fff.init(jax.random.PRNGKey(0), cfg)
print(f"FFF: training width {cfg.training_width}, inference width "
      f"{cfg.inference_width}, {cfg.num_leaves} leaves")

# --- 3. train with the hardening loss (paper: L_total = L_pred + h*L_harden)
opt = optim.sgd(0.2)
state = opt.init(params)


def loss_fn(p, x, y):
    logits, aux = fff.forward_train(p, cfg, x)                 # FORWARD_T
    ce = -jnp.mean(jnp.take_along_axis(jax.nn.log_softmax(logits),
                                       y[:, None], 1))
    return ce + cfg.hardening_scale * fff.hardening_loss(aux["node_probs"]), \
        aux["entropy"]


@jax.jit
def step(p, s, x, y):
    (l, ent), g = jax.value_and_grad(loss_fn, has_aux=True)(p, x, y)
    u, s = opt.update(g, s, p)
    return optim.apply_updates(p, u), s, l, ent


rng = np.random.default_rng(0)
for i in range(300):
    sel = rng.integers(0, len(ds.x_train), 256)
    params, state, l, ent = step(params, state, jnp.asarray(ds.x_train[sel]),
                                 jnp.asarray(ds.y_train[sel]))
    if i % 50 == 0:
        print(f"step {i:3d}  loss {float(l):.3f}  "
              f"mean node entropy {float(ent):.3f}  (hardening toward 0)")

# --- 4. serve with hard routing (FORWARD_I): one leaf per input -------------
logits_hard, aux = fff.forward_hard(params, cfg, jnp.asarray(ds.x_test))
acc = float((np.asarray(logits_hard.argmax(-1)) == ds.y_test).mean())
logits_soft, _ = fff.forward_train(params, cfg, jnp.asarray(ds.x_test))
agree = float((logits_soft.argmax(-1) == logits_hard.argmax(-1)).mean())
print(f"\nhard-inference accuracy: {acc:.3f}  "
      f"(soft/hard agreement {agree:.3f} — hardening carried over)")

# --- 5. the learned partition of the input space (paper §Regionalization) ---
hist = np.bincount(np.asarray(aux["leaf_idx"][:, 0]),
                   minlength=cfg.num_leaves)
print(f"leaf load histogram over test set: {hist.tolist()}")
