"""jamba-1.5-large-398b [hybrid] — 72L d_model=8192 64H (GQA kv=8) d_ff=24576
vocab=65536, MoE 16e top-2, Mamba+attn 1:7 interleave.
[arXiv:2403.19887; hf]

Period of 8: seven Mamba blocks then one attention block (1:7); MoE FFN on
every other block, dense FFN otherwise (Jamba's alternating pattern).
Hybrid constant-state Mamba + 1/8 attention => sub-quadratic: runs long_500k."""
import jax.numpy as jnp

from repro.configs.base import BlockSpec, FFNSpec, ModelConfig

_MOE = FFNSpec(kind="moe", d_ff=24576, activation="swiglu",
               moe_experts=16, moe_top_k=2)
_DENSE = FFNSpec(kind="dense", d_ff=24576, activation="swiglu")

CONFIG = ModelConfig(
    arch_id="jamba-1.5-large-398b",
    family="hybrid",
    d_model=8192,
    n_layers=72,
    n_heads=64,
    n_kv_heads=8,
    vocab_size=65536,
    max_seq_len=524288,
    mamba_d_state=16,
    mamba_d_conv=4,
    mamba_expand=2,
    subquadratic=True,
    period=(
        BlockSpec(mixer="mamba", ffn=_MOE),
        BlockSpec(mixer="mamba", ffn=_DENSE),
        BlockSpec(mixer="mamba", ffn=_MOE),
        BlockSpec(mixer="mamba", ffn=_DENSE),
        BlockSpec(mixer="mamba", ffn=_MOE),
        BlockSpec(mixer="mamba", ffn=_DENSE),
        BlockSpec(mixer="mamba", ffn=_MOE),
        BlockSpec(mixer="attn", ffn=_DENSE),
    ),
    param_dtype=jnp.bfloat16,
    accum_dtype=jnp.bfloat16,
    remat="full",
    grad_accum=16,
    zero_stage=3,
)

# MoE sites -> forest-2 (top-2), depth 3 (8 leaves) x leaf 24576: width-exact
# (2*8*24576 = 16*24576).  Dense sites -> single tree, 16 leaves x 1536.
FFF_CONFIG = CONFIG.with_ffn_kind("fff", leaf_width=0, trees=0)
