"""Straggler detection & mitigation policy (host-side control plane).

At 1000+ nodes, slow hosts dominate step time (synchronous SPMD waits for the
slowest).  This module implements the control logic:

  * ``StepTimeTracker`` — per-host rolling step-time stats with outlier
    flagging (p50 * factor rule, robust to global slowdowns).
  * ``MitigationPolicy`` — escalation ladder: observe -> warn -> eject.
    Ejection triggers an elastic re-mesh (checkpoint/elastic.py) onto the
    surviving hosts; the data pipeline re-shards via its process-local feed.

The decision logic is deterministic and unit-tested; the actuation (restart
with a smaller host set) is the supervisor's job (fault.py).
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass
class StragglerConfig:
    window: int = 50              # steps of history per host
    slow_factor: float = 1.5      # flagged if host_p50 > global_p50 * factor
    eject_after: int = 20         # consecutive flagged steps before ejection
    min_history: int = 10


class StepTimeTracker:
    def __init__(self, num_hosts: int, cfg: StragglerConfig = StragglerConfig()):
        self.cfg = cfg
        self.history = [collections.deque(maxlen=cfg.window)
                        for _ in range(num_hosts)]
        self.flagged_streak = np.zeros(num_hosts, dtype=int)

    def record(self, host_times: list[float]) -> None:
        for h, t in enumerate(host_times):
            self.history[h].append(t)

    def host_p50(self, h: int) -> Optional[float]:
        if len(self.history[h]) < self.cfg.min_history:
            return None
        return float(np.median(self.history[h]))

    def global_p50(self) -> Optional[float]:
        vals = [t for h in self.history for t in h]
        if len(vals) < self.cfg.min_history:
            return None
        return float(np.median(vals))

    def update_flags(self) -> list[int]:
        """Returns currently-flagged host ids and advances eject streaks."""
        g = self.global_p50()
        flagged = []
        if g is None:
            return flagged
        for h in range(len(self.history)):
            p = self.host_p50(h)
            if p is not None and p > g * self.cfg.slow_factor:
                flagged.append(h)
                self.flagged_streak[h] += 1
            else:
                self.flagged_streak[h] = 0
        return flagged

    def to_eject(self) -> list[int]:
        return [h for h in range(len(self.history))
                if self.flagged_streak[h] >= self.cfg.eject_after]


@dataclasses.dataclass
class MitigationDecision:
    action: str                   # "none" | "warn" | "eject"
    hosts: list[int]


class MitigationPolicy:
    """observe -> warn -> eject escalation with hysteresis."""

    def __init__(self, tracker: StepTimeTracker):
        self.tracker = tracker

    def step(self, host_times: list[float]) -> MitigationDecision:
        self.tracker.record(host_times)
        flagged = self.tracker.update_flags()
        eject = self.tracker.to_eject()
        if eject:
            return MitigationDecision("eject", eject)
        if flagged:
            return MitigationDecision("warn", flagged)
        return MitigationDecision("none", [])
