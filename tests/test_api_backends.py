"""Backend-parity matrix for the ``api.apply()`` registry: every registered
backend must agree with the reference path, across mode x backend x dtype x
depth (incl. depth=0) x forest size, Pallas running in interpret mode.

Parity across *all* modes is checked in the hardened limit (node logits
scaled up, tokens filtered to a decision margin): there FORWARD_T's soft
mixture collapses onto the single routed leaf, so train and infer backends
must produce the same outputs — paper §Hardening, and exactly the regime the
serving stack relies on.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import api, fff

CASES = [(mode, backend)
         for mode in api.MODES
         for backend in api.list_backends(mode)]


def _hardened_case(depth, trees, dtype, din=16, dout=12, leaf=8, batch=64,
                   pool=512, seed=0, master=False):
    """Bias-free FFF params with decisively-hardened node boundaries, plus
    tokens filtered to a decision margin at every node (so bf16 rounding
    cannot flip a routing decision between backends; threshold probed
    empirically — routing still agrees at 0.02 across all backends)."""
    cfg = fff.FFFConfig(dim_in=din, dim_out=dout, depth=depth,
                        leaf_width=leaf, activation="gelu", trees=trees,
                        leaf_bias=False, param_dtype=dtype,
                        master_leaf=master)
    params = fff.init(jax.random.PRNGKey(seed), cfg)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (pool, din))
    if depth > 0:
        logits = fff._node_logits_all(
            {k: v.astype(jnp.float32) for k, v in params.items()},
            cfg, x.astype(jnp.float32))
        margin = np.asarray(jnp.abs(logits).min(axis=(1, 2)))
        x = x[margin > 0.02][:batch]
        assert x.shape[0] >= 8, "margin filter left too few tokens"
        for k in ("node_w1", "node_b1"):
            params[k] = (params[k].astype(jnp.float32) * 5e4).astype(dtype)
    else:
        x = x[:batch]
    return cfg, params, x.astype(dtype)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16],
                         ids=["f32", "bf16"])
@pytest.mark.parametrize("depth,trees", [(0, 1), (3, 1), (2, 3)])
@pytest.mark.parametrize("mode,backend", CASES,
                         ids=[f"{m}-{b}" for m, b in CASES])
def test_backend_parity(mode, backend, depth, trees, dtype):
    cfg, params, x = _hardened_case(depth, trees, dtype)
    want, want_out = api.apply(params, cfg, x, api.ExecutionSpec(
        mode="infer", backend="reference"))
    spec = api.ExecutionSpec(mode=mode, backend=backend, capacity_factor=8.0,
                             interpret=True)
    got, out = api.apply(params, cfg, x, spec)
    assert got.shape == want.shape
    tol = 1e-1 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)
    if out.leaf_idx is not None:
        np.testing.assert_array_equal(np.asarray(out.leaf_idx),
                                      np.asarray(want_out.leaf_idx))
    if out.overflow_fraction is not None:
        assert float(out.overflow_fraction) == 0.0
    if mode == "train":
        assert out.node_probs is not None and out.entropy is not None


@pytest.mark.parametrize("depth,trees", [(0, 1), (3, 2)])
@pytest.mark.parametrize("mode,backend", CASES,
                         ids=[f"{m}-{b}" for m, b in CASES])
def test_backend_parity_master_leaf(mode, backend, depth, trees):
    """The master-leaf rows of the parity matrix: the always-on master term
    must be added exactly once on EVERY backend (centrally by api.apply, or
    fused in-kernel for pallas_decode) — double- or zero-addition shows up
    as a systematic offset against the reference."""
    cfg, params, x = _hardened_case(depth, trees, jnp.float32, master=True)
    want, _ = api.apply(params, cfg, x, api.ExecutionSpec(
        mode="infer", backend="reference"))
    got, _ = api.apply(params, cfg, x, api.ExecutionSpec(
        mode=mode, backend=backend, capacity_factor=8.0, interpret=True))
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=1e-4, atol=1e-4)


def test_auto_resolves_to_registered_backends():
    for depth, trees, st in [(0, 1, False), (3, 1, False), (3, 2, True)]:
        cfg = fff.FFFConfig(dim_in=8, dim_out=8, depth=depth, leaf_width=4,
                            activation="gelu", trees=trees, leaf_bias=False,
                            st_training=st)
        params = fff.init(jax.random.PRNGKey(0), cfg)
        for mode in api.MODES:
            name = api._resolve_auto(params, cfg, mode)
            assert name in api.list_backends(mode), (mode, name)


def test_auto_picks_st_grouped_training():
    cfg = fff.FFFConfig(dim_in=8, dim_out=8, depth=3, leaf_width=4,
                        activation="gelu", leaf_bias=False, st_training=True)
    params = fff.init(jax.random.PRNGKey(0), cfg)
    assert api._resolve_auto(params, cfg, "train") == "grouped"
    # depth 0 has no tree to descend: faithful dense FORWARD_T
    cfg0 = fff.FFFConfig(dim_in=8, dim_out=8, depth=0, leaf_width=4,
                         activation="gelu", leaf_bias=False, st_training=True)
    assert api._resolve_auto(params, cfg0, "train") == "reference"


def test_register_and_use_custom_backend():
    calls = []

    def tagged(params, cfg, x, spec):
        calls.append("_test_tagged")
        return api.get_backend("infer", "reference")(params, cfg, x, spec)

    api.register_backend("infer", "_test_tagged", tagged)
    try:
        cfg = fff.FFFConfig(dim_in=8, dim_out=4, depth=2, leaf_width=4,
                            activation="relu")
        params = fff.init(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (16, 8))
        y, out = api.apply(params, cfg, x, api.ExecutionSpec(
            mode="infer", backend="_test_tagged"))
        want, _ = api.apply(params, cfg, x, api.ExecutionSpec(
            mode="infer", backend="reference"))
        assert calls == ["_test_tagged"]
        np.testing.assert_array_equal(np.asarray(y), np.asarray(want))
        assert "_test_tagged" in api.list_backends("infer")
        # use_backend steers auto-resolution to the new backend...
        with api.use_backend("_test_tagged"):
            api.apply(params, cfg, x, api.ExecutionSpec(mode="infer"))
        assert calls == ["_test_tagged", "_test_tagged"]
        # ...but falls through for modes it is not registered for
        with api.use_backend("_test_tagged"):
            name = api._resolve_auto(params, cfg, "train")
        assert name == "reference"
        # a mode restriction keeps the override away from other modes even
        # when the name IS registered there ("grouped" means exact dispatch
        # for infer but the ST estimator for train)
        with api.use_backend("grouped", mode="infer"):
            assert api._resolve_auto(params, cfg, "infer") == "grouped"
            assert api._resolve_auto(params, cfg, "train") == "reference"
        with pytest.raises(ValueError, match="mode"):
            with api.use_backend("grouped", mode="decode"):
                pass
    finally:
        del api._REGISTRY[("infer", "_test_tagged")]


def test_use_backend_rejects_names_registered_nowhere():
    with pytest.raises(KeyError, match="any mode"):
        with api.use_backend("palas"):  # typo must not silently run auto
            pass


def test_override_honours_supports_predicate():
    """use_backend('pallas') must fall through for kernel-ineligible configs
    (biased leaves) instead of crashing inside the kernels."""
    cfg = fff.FFFConfig(dim_in=8, dim_out=4, depth=2, leaf_width=4,
                        activation="gelu", leaf_bias=True)
    params = fff.init(jax.random.PRNGKey(0), cfg)
    with api.use_backend("pallas"):
        assert api._resolve_auto(params, cfg, "infer") == "reference"
    cfg_ok = fff.FFFConfig(dim_in=8, dim_out=4, depth=2, leaf_width=4,
                           activation="gelu", leaf_bias=False)
    params_ok = fff.init(jax.random.PRNGKey(0), cfg_ok)
    with api.use_backend("pallas"):
        assert api._resolve_auto(params_ok, cfg_ok, "infer") == "pallas"


def test_capacity_factor_defaults_preserve_seed_values():
    """spec.capacity_factor=None must hand each backend its pre-registry
    default: 1.5 for ST training, 2.0 for capacity-bounded inference."""
    seen = {}
    orig_st = fff._forward_st_grouped
    orig_hard = fff._forward_hard_grouped

    def spy_st(*a, **kw):
        seen["train"] = kw["capacity_factor"]
        return orig_st(*a, **kw)

    def spy_hard(*a, **kw):
        seen["infer"] = kw["capacity_factor"]
        return orig_hard(*a, **kw)

    cfg = fff.FFFConfig(dim_in=8, dim_out=4, depth=2, leaf_width=4,
                        activation="gelu", leaf_bias=False, st_training=True)
    params = fff.init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (16, 8))
    fff._forward_st_grouped = spy_st
    fff._forward_hard_grouped = spy_hard
    try:
        api.apply(params, cfg, x, api.ExecutionSpec(mode="train"))
        api.apply(params, cfg, x, api.ExecutionSpec(mode="infer",
                                                    backend="grouped"))
    finally:
        fff._forward_st_grouped = orig_st
        fff._forward_hard_grouped = orig_hard
    assert seen == {"train": 1.5, "infer": 2.0}


def test_unknown_backend_raises_with_catalogue():
    cfg = fff.FFFConfig(dim_in=8, dim_out=4, depth=1, leaf_width=4)
    params = fff.init(jax.random.PRNGKey(0), cfg)
    x = jnp.zeros((4, 8))
    with pytest.raises(KeyError, match="reference"):
        api.apply(params, cfg, x, api.ExecutionSpec(mode="infer",
                                                    backend="bogus"))
    with pytest.raises(ValueError, match="mode"):
        api.apply(params, cfg, x, api.ExecutionSpec(mode="decode"))
    with pytest.raises(ValueError):
        api.register_backend("infer", "auto", lambda *a: None)


def test_apply_under_jit_returns_pytree_output():
    cfg = fff.FFFConfig(dim_in=8, dim_out=4, depth=2, leaf_width=4,
                        activation="relu")
    params = fff.init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (16, 8))
    spec = api.ExecutionSpec(mode="train")
    y, out = jax.jit(lambda p, x: api.apply(p, cfg, x, spec))(params, x)
    y2, out2 = api.apply(params, cfg, x, spec)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y2),
                               rtol=2e-5, atol=2e-5)
    assert isinstance(out, api.FFFOutput)
    np.testing.assert_allclose(np.asarray(out.mixture),
                               np.asarray(out2.mixture),
                               rtol=2e-5, atol=2e-5)
