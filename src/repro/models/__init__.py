"""Model-level wrappers: causal LM (incl. enc-dec, stub frontends), ViT."""
from repro.models import lm
