"""Disaggregated prefill/decode serving cluster (DESIGN.md §12).

Layers: transport (bus.py) < worker (worker.py) < control plane
(router.py + placement.py + control.py), with handoff.py carrying KV
pages across the prefill→decode boundary.
"""
from repro.cluster.bus import LocalBus, ProcBus, WorkerKilled
from repro.cluster.control import ClusterMonitor, ControlConfig
from repro.cluster.handoff import KVHandoff
from repro.cluster.placement import WorkerView, choose_decode, choose_prefill
from repro.cluster.router import ClusterConfig, GlobalPrefixMap, Router
from repro.cluster.worker import ClusterWorker, WorkerSpec, build_engine
