"""Pallas TPU kernel: per-token gathered leaf matmul (decode path).

The most literal TPU analogue of the paper's CUDA observation that selective
weight indexing is "a simple offset in the data load": the scalar-prefetched
``leaf_idx`` drives the weight BlockSpec ``index_map``, so the pipeline DMAs
exactly one leaf's weight tiles from HBM per token — HBM traffic is
O(l * D) per token instead of O(2^d * l * D).  Decode is memory-bound, so this
IS the paper's speedup mechanism on TPU (roofline: memory term, §Perf).

Used for small decode batches where the sort/scatter of the grouped path
costs more than it saves; the crossover is measured in EXPERIMENTS.md §Perf.

Grid: (B, H/bh, D/bk), k innermost, accumulation in a VMEM f32 scratch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_ACTS = {
    "none": lambda x: x,
    "relu": jax.nn.relu,
    "gelu": jax.nn.gelu,
    "silu": jax.nn.silu,
}


def _gathered_kernel(idx_ref, x_ref, w_ref, o_ref, acc_ref, *, act: str,
                     out_dtype):
    k = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w_ref[0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _flush():
        o_ref[...] = _ACTS[act](acc_ref[...]).astype(out_dtype)


def gathered_matmul(x: jax.Array, w: jax.Array, leaf_idx: jax.Array, *,
                    act: str = "none", block_h: int = 512, block_k: int = 512,
                    interpret: bool = False, out_dtype=None) -> jax.Array:
    """y[i] = act(x[i] @ w[leaf_idx[i]]).  x (B, D), w (E, D, H) -> (B, H).

    The weight tile fetched at grid step (i, h, k) is w[leaf_idx[i], k, h] —
    the scalar-prefetch index map is the offset-load."""
    B, D = x.shape
    E, _, H = w.shape
    out_dtype = out_dtype or x.dtype
    bh = min(block_h, H)
    bk = min(block_k, D)
    while H % bh:
        bh -= 1
    while D % bk:
        bk -= 1
    grid = (B, H // bh, D // bk)
    return pl.pallas_call(
        functools.partial(_gathered_kernel, act=act, out_dtype=out_dtype),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, bk), lambda i, h, k, idx: (i, k)),
                pl.BlockSpec((1, bk, bh), lambda i, h, k, idx: (idx[i], k, h)),
            ],
            out_specs=pl.BlockSpec((1, bh), lambda i, h, k, idx: (i, h)),
            scratch_shapes=[pltpu.VMEM((1, bh), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((B, H), out_dtype),
        interpret=interpret,
    )(leaf_idx, x, w)


def _gathered_dual_kernel(idx_ref, x_ref, wg_ref, wu_ref, o_ref, accg_ref,
                          accu_ref, *, out_dtype):
    k = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(k == 0)
    def _init():
        accg_ref[...] = jnp.zeros_like(accg_ref)
        accu_ref[...] = jnp.zeros_like(accu_ref)

    xt = x_ref[...]
    accg_ref[...] += jax.lax.dot_general(
        xt, wg_ref[0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    accu_ref[...] += jax.lax.dot_general(
        xt, wu_ref[0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _flush():
        o_ref[...] = (jax.nn.silu(accg_ref[...])
                      * accu_ref[...]).astype(out_dtype)


def gathered_matmul_dual(x: jax.Array, wg: jax.Array, wu: jax.Array,
                         leaf_idx: jax.Array, *, block_h: int = 512,
                         block_k: int = 512, interpret: bool = False,
                         out_dtype=None) -> jax.Array:
    """SwiGLU up with per-token leaf selection: (B, D) -> (B, H)."""
    B, D = x.shape
    E, _, H = wg.shape
    out_dtype = out_dtype or x.dtype
    bh = min(block_h, H)
    bk = min(block_k, D)
    while H % bh:
        bh -= 1
    while D % bk:
        bk -= 1
    grid = (B, H // bh, D // bk)
    return pl.pallas_call(
        functools.partial(_gathered_dual_kernel, out_dtype=out_dtype),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, bk), lambda i, h, k, idx: (i, k)),
                pl.BlockSpec((1, bk, bh), lambda i, h, k, idx: (idx[i], k, h)),
                pl.BlockSpec((1, bk, bh), lambda i, h, k, idx: (idx[i], k, h)),
            ],
            out_specs=pl.BlockSpec((1, bh), lambda i, h, k, idx: (i, h)),
            scratch_shapes=[pltpu.VMEM((1, bh), jnp.float32),
                            pltpu.VMEM((1, bh), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((B, H), out_dtype),
        interpret=interpret,
    )(leaf_idx, x, wg, wu)
