"""The paper's primary contribution: fast feedforward networks, with their
baselines (vanilla FF, noisy-top-k MoE), routing/dispatch machinery and
region-partition utilities.

The FFF execution surface is ``api``: one ``apply(params, cfg, x, spec)``
entry point dispatching over a registry of execution backends (reference /
grouped / pallas / user-registered) — see ``core/api.py`` and DESIGN.md §2.
"""
from repro.core import api, ff, fff, moe, regions, routing
from repro.core.api import (ExecutionSpec, FFFOutput, apply, get_backend,
                            list_backends, overrides, register_backend,
                            use_backend, use_capacity_factor,
                            use_overflow_policy)
from repro.core.fff import (FFFConfig, balance_loss, bernoulli_entropy,
                            decisive_fraction, hardening_loss, leaf_usage,
                            master_apply, mixture_weights, route_hard)

__all__ = [
    "api", "ff", "fff", "moe", "regions", "routing",
    # the FFF execution API
    "apply", "ExecutionSpec", "FFFOutput",
    "register_backend", "get_backend", "list_backends", "overrides",
    # deprecated single-purpose override aliases (use ``overrides``)
    "use_backend", "use_capacity_factor", "use_overflow_policy",
    # layer config + math
    "FFFConfig", "route_hard",
    "mixture_weights", "hardening_loss", "bernoulli_entropy",
    "balance_loss", "leaf_usage", "master_apply",
    "decisive_fraction",
]
