"""Multi-tenant QoS benchmark: weighted-fair admission + online routing
profiles under a skewed two-tenant workload (ISSUE 5 tentpole; DESIGN.md
§9).

Two tenants whose prompts route to *different* FFF leaves (classes from the
offline ``calibrate_classes`` probe) hammer an overloaded engine:

* **Fairness.**  Both tenants stay backlogged while the engine serves with
  ``weighted_leaf_aware`` (weights gold=3, free=1).  Per-step generated
  tokens are attributed per tenant and accumulated only over steps where
  BOTH tenants still have waiting requests — over that saturated window the
  tokens/s ratio must track the weight ratio within tolerance (10%).
  (Whole-run totals would be meaningless: the run serves every request, so
  lifetime token counts are fixed by the workload, not the scheduler.)
* **Online profiles.**  The QoS runs carry NO ``leaf_hint``: the engine
  learns each tenant's footprint from finished requests
  (``RoutingProfileStore``).  After the run the learned profiles must agree
  with the offline calibration footprints (dominant leaf + L1 tolerance),
  and the burst workload's decode overflow under ``weighted_leaf_aware``
  (hint-less, profile-driven) must undercut hint-less FCFS.

Emits CSV rows
``serving_qos,<case>,<tok_s>,<ovf_decode>,...`` and writes
``experiments/BENCH_serving_qos.json`` (schema-checked in CI by
``benchmarks/check_schema.py``).
"""
from __future__ import annotations

import json
import os

import numpy as np

from benchmarks.serving_load import _model, calibrate_classes

ARTIFACT = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "experiments", "BENCH_serving_qos.json")

PROMPT_LEN = 16
GEN = 8
WEIGHTS = {"gold": 3.0, "free": 1.0}
FAIRNESS_TOL = 0.10          # acceptance: tokens/s ratio within 10% of 3.0
PROFILE_L1_TOL = 0.5         # learned-vs-offline footprint L1 tolerance


def _ecfg(scheduler: str, slots: int, seed: int, **sched_kw):
    from repro.serving import EngineConfig
    return EngineConfig(
        num_slots=slots, max_len=PROMPT_LEN + GEN + 1,
        max_prompt_len=PROMPT_LEN, scheduler=scheduler,
        scheduler_kw=sched_kw,
        fff_backend="grouped",          # capacity-bounded dispatch: the
        max_prefills_per_step=slots,    # regime where composition matters
        seed=seed)


def _tenant_requests(classes, counts: dict, *, hints: bool):
    """``counts[tenant]`` requests per tenant, interleaved round-robin so
    arrival order favors nobody; tenant i's prompts are its class token."""
    from repro.serving import Request
    tenants = sorted(counts)
    toks = {t: classes[i % len(classes)] for i, t in enumerate(tenants)}
    reqs, rid, left = [], 0, dict(counts)
    while any(left.values()):
        for t in tenants:
            if left[t] <= 0:
                continue
            tok, fp = toks[t]
            reqs.append(Request(
                rid=rid, prompt=np.full((PROMPT_LEN,), tok, np.int32),
                max_new_tokens=GEN, tenant=t,
                leaf_hint=fp.copy() if hints else None))
            rid += 1
            left[t] -= 1
    return reqs, {t: toks[t] for t in tenants}


def run_fairness(params, cfg, classes, *, slots: int, seed: int):
    """Overloaded weighted run, manual step loop: count per-tenant token
    production only while BOTH tenants are backlogged."""
    from repro.serving import ContinuousBatchingEngine
    counts = {t: int(slots * w / min(WEIGHTS.values()))
              for t, w in WEIGHTS.items()}          # backlog ∝ weight
    reqs, _ = _tenant_requests(classes, counts, hints=False)
    eng = ContinuousBatchingEngine(params, cfg, _ecfg(
        "weighted_leaf_aware", slots, seed, weights=WEIGHTS,
        window=4 * slots))
    for r in reqs:
        eng.submit(r)

    def tokens(tenant):
        done = sum(r.n_generated for r in eng.results
                   if r.tenant == tenant)
        live = sum(len(s.tokens) for s in eng.slots
                   if s is not None and s.request.tenant == tenant)
        return done + live

    window = {t: 0 for t in WEIGHTS}
    saturated_steps = 0
    while eng.has_work():
        both_backlogged = all(eng.queue.depth(t) > 0 for t in WEIGHTS)
        before = {t: tokens(t) for t in WEIGHTS}
        eng.step()
        if both_backlogged:
            saturated_steps += 1
            for t in WEIGHTS:
                window[t] += tokens(t) - before[t]
    m = eng.poll_metrics()
    ratio = window["gold"] / max(window["free"], 1)
    target = WEIGHTS["gold"] / WEIGHTS["free"]
    ok = abs(ratio / target - 1.0) <= FAIRNESS_TOL
    return {"weights": WEIGHTS, "n_requests": counts,
            "saturated_steps": saturated_steps,
            "saturated_window_tokens": window,
            "tokens_ratio_gold_over_free": ratio,
            "target_ratio": target, "tolerance": FAIRNESS_TOL,
            "within_tolerance": bool(ok),
            "throughput_tok_s": m.throughput_tok_s,
            "tenants": m.tenants}, eng


def run_bursts(params, cfg, classes, *, scheduler: str, slots: int,
               seed: int):
    """Per-tenant bursts (the overflow-adversarial arrival pattern), NO
    hints: fcfs admits each burst wholesale (one hot leaf); the weighted
    scheduler interleaves tenants and — once profiles converge — composes
    by learned footprint."""
    from repro.serving import ContinuousBatchingEngine, Request
    tenants = sorted(WEIGHTS)
    reqs, rid = [], 0
    for burst in range(4):
        tok, _ = classes[burst % len(classes)]
        t = tenants[burst % len(tenants)]
        for _ in range(slots):
            reqs.append(Request(
                rid=rid, prompt=np.full((PROMPT_LEN,), tok, np.int32),
                max_new_tokens=GEN, tenant=t))
            rid += 1
    kw = ({"weights": WEIGHTS, "window": 4 * slots}
          if scheduler == "weighted_leaf_aware" else {})
    eng = ContinuousBatchingEngine(params, cfg,
                                   _ecfg(scheduler, slots, seed, **kw))
    _, m = eng.run(reqs)
    return m, eng


def main(quick: bool = True) -> None:
    seed = 0
    slots = 16 if quick else 32

    cfg, params = _model(seed)
    classes = calibrate_classes(params, cfg, len(WEIGHTS))
    offline = {t: classes[i % len(classes)]
               for i, t in enumerate(sorted(WEIGHTS))}
    print(f"# classes (tenant -> token, leaf): "
          f"{[(t, tok, int(fp.argmax())) for t, (tok, fp) in offline.items()]}")

    # (a) weighted fairness under overload
    fairness, _ = run_fairness(params, cfg, classes, slots=slots, seed=seed)
    print("# name,case,tokens_ratio,target,within_tol,saturated_steps")
    print(f"serving_qos,fairness,{fairness['tokens_ratio_gold_over_free']:.3f},"
          f"{fairness['target_ratio']:.1f},"
          f"{fairness['within_tolerance']},{fairness['saturated_steps']}",
          flush=True)

    # (b) hint-less burst workload: fcfs baseline vs weighted + online
    # profiles, plus learned-profile convergence vs the offline probe
    print("# name,case,tok_s,overflow_decode_mean,n_steps")
    runs = {}
    for sched in ("fcfs", "weighted_leaf_aware"):
        m, eng = run_bursts(params, cfg, classes, scheduler=sched,
                            slots=slots, seed=seed)
        runs[sched] = {"scheduler": sched, "slots": slots, **m.as_dict()}
        print(f"serving_qos,bursts_{sched},{m.throughput_tok_s:.1f},"
              f"{m.overflow_decode_mean:.4f},{m.n_steps}", flush=True)
        if sched == "weighted_leaf_aware":
            qos_engine = eng

    convergence = {}
    for t, (tok, fp) in offline.items():
        learned = (qos_engine.profiles.lookup(t)
                   if qos_engine.profiles is not None else None)
        if learned is None:
            convergence[t] = {"learned": None, "converged": False}
            continue
        learned = learned / learned.sum()
        l1 = float(np.abs(learned - fp).sum())
        convergence[t] = {
            "offline_dominant_leaf": int(fp.argmax()),
            "learned_dominant_leaf": int(learned.argmax()),
            "l1_distance": l1, "l1_tolerance": PROFILE_L1_TOL,
            "n_updates": qos_engine.profiles.n_updates(t),
            "converged": bool(l1 <= PROFILE_L1_TOL
                              and learned.argmax() == fp.argmax()),
        }
    ovf_fcfs = runs["fcfs"]["overflow_decode_mean"]
    ovf_qos = runs["weighted_leaf_aware"]["overflow_decode_mean"]
    overflow_cut = ovf_qos < ovf_fcfs
    print(f"# profiles converged: "
          f"{ {t: c['converged'] for t, c in convergence.items()} }")
    print(f"# decode overflow: weighted+profiles {ovf_qos:.4f} vs no-hint "
          f"fcfs {ovf_fcfs:.4f} -> "
          f"{'LOWER' if overflow_cut else 'NOT LOWER'}")
    print(f"# fairness ratio {fairness['tokens_ratio_gold_over_free']:.3f} "
          f"vs target {fairness['target_ratio']:.1f} -> "
          f"{'WITHIN' if fairness['within_tolerance'] else 'OUTSIDE'} "
          f"{FAIRNESS_TOL:.0%}")

    # the acceptance predicates GATE (benchmarks/run.py turns the raise into
    # a failing exit, so the CI bench-smoke job goes red on a fairness or
    # profile regression instead of shipping a green artifact that says
    # false inside).  All three are deterministic token/leaf counts, not
    # wall-clock measurements — safe to assert on a noisy CI runner.
    failures = []
    if not fairness["within_tolerance"]:
        failures.append(
            f"fairness ratio {fairness['tokens_ratio_gold_over_free']:.3f} "
            f"outside {FAIRNESS_TOL:.0%} of target "
            f"{fairness['target_ratio']:.1f}")
    for t, c in convergence.items():
        if not c["converged"]:
            failures.append(f"tenant {t!r} profile did not converge: {c}")
    if not overflow_cut:
        failures.append(f"weighted+profiles decode overflow {ovf_qos:.4f} "
                        f"not below no-hint fcfs {ovf_fcfs:.4f}")

    with open(ARTIFACT, "w") as f:
        json.dump({"bench": "serving_qos", "quick": quick, "slots": slots,
                   "prompt_len": PROMPT_LEN, "gen": GEN,
                   "classes": {t: [int(tok), int(fp.argmax())]
                               for t, (tok, fp) in offline.items()},
                   "fairness": fairness,
                   "profile_convergence": convergence,
                   "overflow_decode": {"fcfs_no_hint": ovf_fcfs,
                                       "weighted_online_profiles": ovf_qos,
                                       "reduced": bool(overflow_cut)},
                   "runs": runs}, f, indent=1)
    print(f"# wrote {ARTIFACT}")
    if failures:
        raise RuntimeError("serving_qos acceptance failed: "
                           + "; ".join(failures))


if __name__ == "__main__":
    main()
