"""internlm2-20b [dense] — 48L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=92544, SwiGLU, RMSNorm, RoPE.  [arXiv:2403.17297; hf]"""
import jax.numpy as jnp

from repro.configs.base import BlockSpec, FFNSpec, ModelConfig

CONFIG = ModelConfig(
    arch_id="internlm2-20b",
    family="dense",
    d_model=6144,
    n_layers=48,
    n_heads=48,
    n_kv_heads=8,
    vocab_size=92544,
    max_seq_len=32768,
    rope_theta=1_000_000.0,
    period=(BlockSpec(mixer="attn",
                      ffn=FFNSpec(kind="dense", d_ff=16384,
                                  activation="swiglu")),),
    param_dtype=jnp.bfloat16,
    accum_dtype=jnp.bfloat16,
    remat="full",
    grad_accum=16,
)

# The paper's technique, applied per DESIGN.md §4 (Case 1, exact width match):
# 16 leaves x 1024 = 16384 training width; inference width 1024 (1/16).
FFF_CONFIG = CONFIG.with_ffn_kind("fff", leaf_width=1024)
