"""Paper Figures 3-4: inference latency scaling — FFF's O(d) = O(log n_leaves)
internal mechanism vs MoE's O(n_experts) gate, at BERT-base dimensions
(dim_in = dim_out = 768), expert/leaf width 32, k = 1.

The paper's claim is the SCALING SHAPE: MoE inference time grows linearly
with the number of experts (exponentially in the depth exponent), FFF grows
linearly in the depth d itself.  We measure both mechanisms' per-call time
and additionally report the mechanism FLOPs (gate vs descent) which are
hardware-independent.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import api, fff, moe

DIM = 768
WIDTH = 32
BATCH = 256


def run(max_exp: int = 10, quick: bool = False) -> list[dict]:
    exps = range(1, (6 if quick else max_exp) + 1)
    x = jax.random.normal(jax.random.PRNGKey(0), (BATCH, DIM))
    rows = []
    for e in exps:
        n_blocks = 2 ** e
        # --- MoE with k=1 (paper: not trainable, but measures the gate) ---
        mcfg = moe.MoEConfig(dim_in=DIM, dim_out=DIM, num_experts=n_blocks,
                             expert_width=WIDTH, top_k=1)
        mp = moe.init(jax.random.PRNGKey(e), mcfg)
        f_moe = jax.jit(lambda p, x: moe.forward_sparse(p, mcfg, x)[0])
        t_moe, s_moe = common.time_fn(f_moe, mp, x, iters=10 if quick else 20)
        moe_gate_flops = BATCH * DIM * n_blocks          # the O(n) gate
        rows.append(dict(model="moe", blocks=n_blocks, us=t_moe, std=s_moe,
                         mech_flops=moe_gate_flops))
        # --- FFF with depth e ---
        fcfg = fff.FFFConfig(dim_in=DIM, dim_out=DIM, depth=e,
                             leaf_width=WIDTH, activation="relu",
                             leaf_bias=False)
        fp = fff.init(jax.random.PRNGKey(e + 100), fcfg)
        f_fff = jax.jit(lambda p, x: api.apply(
            p, fcfg, x, api.ExecutionSpec(mode="infer"))[0])
        # pin one mechanism across the whole sweep (the exact gather the
        # paper times); otherwise auto switches algorithms at wide depths
        # and the scaling curve gains a backend-selection kink
        with api.use_backend("reference"):
            t_fff, s_fff = common.time_fn(f_fff, fp, x,
                                          iters=10 if quick else 20)
        fff_desc_flops = BATCH * DIM * e                 # the O(d) descent
        rows.append(dict(model="fff", blocks=n_blocks, us=t_fff, std=s_fff,
                         mech_flops=fff_desc_flops))
        # --- FF baseline of the same training width (small widths only) ---
        if n_blocks * WIDTH <= 1024:
            from repro.core import ff
            fcfg2 = ff.FFConfig(dim_in=DIM, dim_out=DIM,
                                width=n_blocks * WIDTH, activation="relu")
            pp = ff.init(jax.random.PRNGKey(e + 200), fcfg2)
            f_ff = jax.jit(lambda p, x: ff.forward(p, fcfg2, x))
            t_ff, s_ff = common.time_fn(f_ff, pp, x, iters=10 if quick else 20)
            rows.append(dict(model="ff", blocks=n_blocks, us=t_ff, std=s_ff,
                             mech_flops=2 * BATCH * DIM * n_blocks * WIDTH))
    return rows


def scaling_exponents(rows: list[dict]) -> dict:
    """log-log slope of mechanism cost vs block count: ~1.0 for MoE (linear),
    ~0 (log) for FFF."""
    out = {}
    for model in ("moe", "fff"):
        pts = [(r["blocks"], r["mech_flops"]) for r in rows
               if r["model"] == model]
        lx = np.log2([p[0] for p in pts])
        ly = np.log2([p[1] for p in pts])
        out[model] = float(np.polyfit(lx, ly, 1)[0])
    return out


def main(quick: bool = True):
    rows = run(quick=quick)
    print("name,us_per_call,derived")
    for r in rows:
        print(f"fig34/{r['model']}_n{r['blocks']},{r['us']:.1f},"
              f"mech_flops={r['mech_flops']}")
    exps = scaling_exponents(rows)
    print(f"fig34/scaling_exponent_moe,0.0,slope={exps['moe']:.2f}")
    print(f"fig34/scaling_exponent_fff,0.0,slope={exps['fff']:.2f}")
    return rows


if __name__ == "__main__":
    main(quick=False)
