"""Pure-jnp oracle for the gathered (per-token) leaf matmul."""
from __future__ import annotations

import jax
import jax.numpy as jnp

_ACTS = {
    "none": lambda x: x,
    "relu": jax.nn.relu,
    "gelu": jax.nn.gelu,
    "silu": jax.nn.silu,
}


def gathered_matmul_ref(x: jax.Array, w: jax.Array, leaf_idx: jax.Array, *,
                        act: str = "none") -> jax.Array:
    wg = jnp.take(w, leaf_idx, axis=0)                    # (B, D, H)
    y = jnp.einsum("bd,bdh->bh", x.astype(jnp.float32), wg.astype(jnp.float32))
    return _ACTS[act](y).astype(x.dtype)


def gathered_matmul_dual_ref(x: jax.Array, wg: jax.Array, wu: jax.Array,
                             leaf_idx: jax.Array) -> jax.Array:
    g = gathered_matmul_ref(x, wg, leaf_idx, act="none").astype(jnp.float32)
    u = gathered_matmul_ref(x, wu, leaf_idx, act="none").astype(jnp.float32)
    return (jax.nn.silu(g) * u).astype(x.dtype)
