"""Pure-jnp oracles for the grouped leaf GEMM kernels."""
from __future__ import annotations

import jax
import jax.numpy as jnp

_ACTS = {
    "none": lambda x: x,
    "relu": jax.nn.relu,
    "gelu": jax.nn.gelu,
    "silu": jax.nn.silu,
}


def grouped_matmul_ref(x: jax.Array, w: jax.Array, group_sizes: jax.Array, *,
                       act: str = "none") -> jax.Array:
    """x (E, C, D) @ w (E, D, H) -> (E, C, H); rows beyond each group's size
    produce zeros (matching the kernel's skip semantics at tile granularity is
    up to the caller — the oracle zeroes *exactly* at group_sizes)."""
    y = jnp.einsum("ecd,edh->ech", x.astype(jnp.float32),
                   w.astype(jnp.float32))
    y = _ACTS[act](y)
    C = x.shape[1]
    mask = jnp.arange(C)[None, :] < group_sizes[:, None]
    return (y * mask[..., None]).astype(x.dtype)


def grouped_matmul_dual_ref(x: jax.Array, wg: jax.Array, wu: jax.Array,
                            group_sizes: jax.Array) -> jax.Array:
    g = jnp.einsum("ecd,edh->ech", x.astype(jnp.float32), wg.astype(jnp.float32))
    u = jnp.einsum("ecd,edh->ech", x.astype(jnp.float32), wu.astype(jnp.float32))
    y = jax.nn.silu(g) * u
    C = x.shape[1]
    mask = jnp.arange(C)[None, :] < group_sizes[:, None]
    return (y * mask[..., None]).astype(x.dtype)
