"""Host-side page allocator + prefix index for the paged KV cache
(DESIGN.md §11).

The device side (``nn/attention.KVCache``) is a dumb page pool indexed by
per-row tables; everything stateful lives here, on the host, where the
engine already runs its admission loop:

* ``PagePool`` — a free list + per-page refcounts.  A page is owned by
  every slot whose table maps it plus (for published prompt pages) the
  prefix index; it returns to the free list when the last reference drops.
* ``PrefixIndex`` — a radix trie over page-sized token-id chunks.  A node
  per full prompt page, holding the page id that caches that chunk's K/V.
  Admissions walk it to find the longest already-cached prefix and map
  those pages read-only (refcounted) instead of re-prefilling them.

Lifecycle (engine-side, ``serving/engine.py``):

1. admission: ``match()`` the prompt -> shared pages; incref them for the
   slot; allocate fresh pages for the rest of ``len(prompt) + max_new``;
   if the pool is short, ``reclaim()`` LRU index entries first, and if
   still short the request stays queued (scheduler back-pressure signal).
2. prefill completion: ``insert()`` publishes the row's full prompt pages
   so later admissions can share them.  Publishing only after the K/V are
   actually written keeps racing admissions from attending to garbage —
   they simply miss and prefill themselves.
3. eviction: decref every page the slot held.  No device dispatch.

Invariants (property-tested in tests/test_serving_paged.py): no page is
ever on the free list with a nonzero refcount, no page is referenced by
two live slots unless it was handed out by ``match()`` (shared), and
alloc/decref are conservation-exact (no leaks, no double frees).
"""
from __future__ import annotations

from collections import deque
from typing import Iterable, Optional

import numpy as np


class PagePool:
    """Free list + refcounts over ``num_pages`` fixed-size pages."""

    def __init__(self, num_pages: int, page_size: int):
        if num_pages <= 0 or page_size <= 0:
            raise ValueError("num_pages and page_size must be positive")
        self.num_pages = int(num_pages)
        self.page_size = int(page_size)
        self._ref = np.zeros((self.num_pages,), np.int64)
        self._free: deque[int] = deque(range(self.num_pages))

    @property
    def pages_free(self) -> int:
        return len(self._free)

    @property
    def pages_in_use(self) -> int:
        return self.num_pages - len(self._free)

    def refcount(self, page: int) -> int:
        return int(self._ref[page])

    def alloc(self, n: int) -> Optional[list[int]]:
        """Take ``n`` pages off the free list at refcount 1, or None if the
        pool can't cover the request (all-or-nothing)."""
        if n < 0:
            raise ValueError("n must be >= 0")
        if n > len(self._free):
            return None
        pages = [self._free.popleft() for _ in range(n)]
        for p in pages:
            self._ref[p] = 1
        return pages

    def incref(self, pages: Iterable[int]) -> None:
        for p in pages:
            if self._ref[p] <= 0:
                raise RuntimeError(f"incref of free page {p}")
            self._ref[p] += 1

    def decref(self, pages: Iterable[int]) -> list[int]:
        """Drop one reference per page; returns the pages that hit zero and
        went back on the free list."""
        freed = []
        for p in pages:
            if self._ref[p] <= 0:
                raise RuntimeError(f"double free of page {p}")
            self._ref[p] -= 1
            if self._ref[p] == 0:
                self._free.append(p)
                freed.append(p)
        return freed


class _Node:
    __slots__ = ("parent", "key", "children", "page", "stamp")

    def __init__(self, parent: Optional["_Node"], key):
        self.parent = parent
        self.key = key
        self.children: dict = {}
        self.page: Optional[int] = None
        self.stamp = 0


class PrefixIndex:
    """Radix trie over page-sized token-id chunks -> cached page ids.

    Each indexed node holds one index-owned reference on its page, so a
    published page outlives the slot that prefilled it until ``reclaim()``
    evicts the entry (LRU, childless leaves first — an interior entry is
    never dropped before its descendants, which keeps every held page
    reachable from the root and reclaimable)."""

    def __init__(self, pool: PagePool):
        self.pool = pool
        self._root = _Node(None, None)
        self._clock = 0
        self.n_entries = 0

    def _chunks(self, tokens):
        p = self.pool.page_size
        for i in range(0, (len(tokens) // p) * p, p):
            yield tuple(int(t) for t in tokens[i:i + p])

    def match(self, tokens) -> list[int]:
        """Longest indexed full-page prefix of ``tokens`` -> page ids (the
        caller increfs them; a bare match holds no reference)."""
        self._clock += 1
        node, pages = self._root, []
        for key in self._chunks(tokens):
            node = node.children.get(key)
            if node is None or node.page is None:
                break
            node.stamp = self._clock
            pages.append(node.page)
        return pages

    def insert(self, tokens, pages: list[int]) -> int:
        """Publish a completed prompt's full pages: ``pages[i]`` holds the
        K/V of chunk i.  Newly indexed pages gain an index-owned reference;
        chunks already indexed (possibly under a different page id from a
        racing admission) are left alone.  Returns entries added."""
        self._clock += 1
        node, added = self._root, 0
        for key, pid in zip(self._chunks(tokens), pages):
            child = node.children.get(key)
            if child is None:
                child = _Node(node, key)
                node.children[key] = child
            if child.page is None:
                child.page = pid
                self.pool.incref([pid])
                self.n_entries += 1
                added += 1
            child.stamp = self._clock
            node = child
        return added

    def reclaim(self, pages_needed: int) -> int:
        """Evict LRU leaf entries until the pool has ``pages_needed`` free
        pages (or nothing is left to evict).  Dropping the index reference
        only frees a page if no live slot still maps it.  Returns entries
        evicted."""
        evicted = 0
        while self.pool.pages_free < pages_needed:
            best = None
            stack = [self._root]
            while stack:
                n = stack.pop()
                for c in n.children.values():
                    if c.children:
                        stack.append(c)
                    elif c.page is not None and (best is None
                                                 or c.stamp < best.stamp):
                        best = c
            if best is None:
                break
            self.pool.decref([best.page])
            best.page = None
            self.n_entries -= 1
            evicted += 1
            node = best
            while (node is not self._root and not node.children
                   and node.page is None):
                parent = node.parent
                del parent.children[node.key]
                node = parent
        return evicted
