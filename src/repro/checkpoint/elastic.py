"""Elastic restore: load a checkpoint onto a mesh with a *different* device
count / topology than the one it was saved from.

Because ckpt.py serializes host-gathered global arrays, resharding is a pure
placement decision: we restore on host and re-place every leaf with the
sharding rules evaluated against the *new* mesh.  Tested 1 -> 8 -> 4 fake
devices in tests/test_checkpoint.py.
"""
from __future__ import annotations

from typing import Any, Callable, Optional

import jax
from jax.sharding import Mesh, NamedSharding

from repro.checkpoint import ckpt

PyTree = Any


def reshard_restore(directory: str, like: PyTree, mesh: Optional[Mesh],
                    spec_fn: Optional[Callable] = None
                    ) -> tuple[PyTree, int, dict]:
    """Restore + re-place.  ``spec_fn(path, leaf) -> PartitionSpec`` decides
    the new sharding; None places everything uncommitted (single device)."""
    tree, step, meta = ckpt.restore_tree(directory, like)
    if mesh is None:
        return jax.tree_util.tree_map(jax.numpy.asarray, tree), step, meta

    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    placed = []
    for path, leaf in flat:
        if spec_fn is not None:
            spec = spec_fn(path, leaf)
            placed.append(jax.device_put(leaf, NamedSharding(mesh, spec)))
        else:
            placed.append(jax.device_put(leaf))
    return jax.tree_util.tree_unflatten(treedef, placed), step, meta
