"""ShapeDtypeStruct stand-ins for every model input of every (arch x shape)
cell — weak-type-correct, shardable, no device allocation.

``input_specs(cfg, shape)`` returns the batch pytree the corresponding entry
point consumes:
  train:   {tokens|embeds [, enc_embeds], labels}
  prefill: {tokens|embeds [, enc_embeds]}
  decode:  (token, caches, pos_offset) — caches at seq_len capacity
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeSpec
from repro.models import lm

Struct = jax.ShapeDtypeStruct


def _token_batch(cfg: ModelConfig, B: int, S: int, with_labels: bool) -> dict:
    batch: dict[str, Any] = {}
    if cfg.encoder is not None:
        batch["tokens"] = Struct((B, S), jnp.int32)
        batch["enc_embeds"] = Struct((B, cfg.encoder.seq_len, cfg.d_model),
                                     cfg.param_dtype)
    elif cfg.frontend != "none":
        # stub frontend: precomputed frame/patch embeddings (assignment)
        batch["embeds"] = Struct((B, S, cfg.d_model), cfg.param_dtype)
    else:
        batch["tokens"] = Struct((B, S), jnp.int32)
    if with_labels:
        batch["labels"] = Struct((B, S), jnp.int32)
    return batch


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """Batch structs for train/prefill modes."""
    if shape.mode == "train":
        return _token_batch(cfg, shape.global_batch, shape.seq_len, True)
    if shape.mode == "prefill":
        return _token_batch(cfg, shape.global_batch, shape.seq_len, False)
    raise ValueError(f"decode shapes use decode_specs: {shape.name}")


def decode_specs(cfg: ModelConfig, shape: ShapeSpec) -> tuple:
    """(token, caches, pos_offset) structs for one serve_step with a KV/state
    cache of ``shape.seq_len`` already filled."""
    B = shape.global_batch
    token = Struct((B, 1), jnp.int32)
    caches = jax.eval_shape(
        lambda: lm.init_caches(cfg, B, shape.seq_len, dtype=cfg.param_dtype))
    pos = Struct((), jnp.int32)
    return token, caches, pos
