"""End-to-end driver: train a ~100M-param decoder-only LM whose FFN sites are
fast-feedforward layers, for a few hundred steps, with checkpointing and
restart — the framework's train path at example scale.

Run:  PYTHONPATH=src python examples/train_lm_fff.py [--steps 300]
(~100M params is CPU-heavy; --small drops to a ~10M model for a fast demo.)
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim, utils
from repro.checkpoint import CheckpointManager
from repro.configs.base import BlockSpec, FFNSpec, ModelConfig
from repro.data import tokens as tokens_lib
from repro.distributed import fault
from repro.models import lm


def make_config(small: bool) -> ModelConfig:
    if small:
        d_model, n_layers, d_ff, vocab = 256, 4, 1024, 2048
    else:
        d_model, n_layers, d_ff, vocab = 768, 12, 3072, 32768   # ~100M params
    ffn = FFNSpec(kind="dense", d_ff=d_ff, activation="swiglu").as_fff(
        leaf_width=d_ff // 8)
    return ModelConfig(
        arch_id="example-lm-fff",
        family="dense",
        d_model=d_model,
        n_layers=n_layers,
        n_heads=d_model // 64,
        n_kv_heads=d_model // 64,
        vocab_size=vocab,
        max_seq_len=1024,
        period=(BlockSpec(mixer="attn", ffn=ffn),),
        scan_layers=True,
        attn_chunk=256,
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--small", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_example_lm")
    args = ap.parse_args()

    cfg = make_config(args.small)
    params = lm.init(jax.random.PRNGKey(0), cfg)
    n_params = utils.tree_size(params)
    print(f"model: {n_params/1e6:.1f}M params "
          f"({cfg.n_layers}L d{cfg.d_model}, FFF "
          f"{cfg.period[0].ffn.fff_depth}-deep "
          f"{cfg.period[0].ffn.fff_leaf_width}-wide leaves)")

    opt = optim.chain_clip(
        optim.adamw(optim.cosine_warmup(3e-4, 20, args.steps)), 1.0)
    opt_state = opt.init(params)
    src = tokens_lib.MarkovTokenSource(cfg.vocab_size, seed=0)

    @jax.jit
    def train_step(params, opt_state, batch, rng):
        (l, m), g = jax.value_and_grad(
            lambda p: lm.loss_fn(p, cfg, batch, rng), has_aux=True)(params)
        u, opt_state = opt.update(g, opt_state, params)
        return optim.apply_updates(params, u), opt_state, m

    mgr = CheckpointManager(args.ckpt_dir, keep=2)
    sup = fault.TrainSupervisor(mgr, fault.SupervisorConfig(ckpt_every=50))
    key = jax.random.PRNGKey(1)

    def do_step(state, i):
        batch = src.batch(args.batch, args.seq, seed=i)
        t0 = time.time()
        p2, o2, m = train_step(state["params"], state["opt"], batch,
                               jax.random.fold_in(key, i))
        if i % 10 == 0:
            print(f"step {i:4d}  ce {float(m['ce']):7.4f}  "
                  f"harden {float(m['hardening']):6.4f}  "
                  f"acc {float(m['accuracy']):5.3f}  "
                  f"{(time.time()-t0)*1e3:7.0f}ms", flush=True)
        return {"params": p2, "opt": o2}

    res = sup.run({"params": params, "opt": opt_state}, do_step, args.steps)
    print(f"finished at step {res.step}; checkpoints in {args.ckpt_dir}")

    # quick sample
    out = lm.generate(res.state["params"], cfg,
                      jnp.asarray(src.sample(1, 8, seed=9)[:, :8]),
                      steps=16, max_len=64)
    print("greedy sample:", np.asarray(out)[0].tolist())


if __name__ == "__main__":
    main()
