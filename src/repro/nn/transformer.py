"""Transformer block + stack assembly.

A model is a repeated *period* of heterogeneous blocks (attn / mamba / mlstm /
slstm, each with an optional FFN site).  Parameters of each period position
are stacked over the ``n_periods`` axis so the whole stack lowers to one
``lax.scan`` — small HLO, fast multi-pod compiles, and a natural remat point.

Modes: ``train`` (no cache), ``prefill`` (build caches over a prefix),
``decode`` (one token against the caches).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import BlockSpec, ModelConfig
from repro.core import api
from repro.distributed import act
from repro.nn import attention, mamba, mlp, norms, xlstm

Params = dict
Cache = dict


def _routing_weighted(r: "api.RoutingStats | None"):
    """Pre-weight overflow by slot count so per-layer records sum correctly
    across the period scan (finalized back to a fraction below)."""
    if r is None:
        return None
    return api.RoutingStats(r.leaf_counts, r.overflow * r.slots, r.slots)


def _routing_finalize(r: "api.RoutingStats | None"):
    if r is None:
        return None
    return api.RoutingStats(r.leaf_counts,
                            r.overflow / jnp.maximum(r.slots, 1.0), r.slots)


# ---------------------------------------------------------------------------
# per-block
# ---------------------------------------------------------------------------

def make_attn_config(cfg: ModelConfig, spec: BlockSpec, *, causal: bool = True
                     ) -> attention.AttnConfig:
    return attention.AttnConfig(
        d_model=cfg.d_model, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.resolved_head_dim, bias=cfg.attn_bias,
        rope_theta=cfg.rope_theta, use_rope=(cfg.pos_emb == "rope"),
        causal=causal, sliding_window=spec.sliding_window, chunk=cfg.attn_chunk,
        param_dtype=cfg.param_dtype, accum_dtype=cfg.accum_dtype)


def make_mamba_config(cfg: ModelConfig) -> mamba.MambaConfig:
    return mamba.MambaConfig(
        d_model=cfg.d_model, d_state=cfg.mamba_d_state, d_conv=cfg.mamba_d_conv,
        expand=cfg.mamba_expand, param_dtype=cfg.param_dtype,
        accum_dtype=cfg.accum_dtype)


def make_xlstm_config(cfg: ModelConfig) -> xlstm.XLSTMConfig:
    return xlstm.XLSTMConfig(
        d_model=cfg.d_model, n_heads=cfg.lstm_heads,
        param_dtype=cfg.param_dtype, accum_dtype=cfg.accum_dtype)


def block_init(key: jax.Array, cfg: ModelConfig, spec: BlockSpec, *,
               causal: bool = True) -> Params:
    ks = jax.random.split(key, 4)
    p: Params = {"norm1": norms.norm_init(cfg.norm, cfg.d_model, cfg.param_dtype)}
    if spec.mixer == "attn":
        p["mixer"] = attention.init(ks[0], make_attn_config(cfg, spec, causal=causal))
    elif spec.mixer == "mamba":
        p["mixer"] = mamba.init(ks[0], make_mamba_config(cfg))
    elif spec.mixer == "mlstm":
        p["mixer"] = xlstm.mlstm_init(ks[0], make_xlstm_config(cfg))
    elif spec.mixer == "slstm":
        p["mixer"] = xlstm.slstm_init(ks[0], make_xlstm_config(cfg))
    elif spec.mixer != "none":
        raise ValueError(f"unknown mixer {spec.mixer!r}")
    if spec.cross_attention:
        p["norm_x"] = norms.norm_init(cfg.norm, cfg.d_model, cfg.param_dtype)
        p["cross"] = attention.init(ks[1], make_attn_config(cfg, spec, causal=False))
    if spec.ffn.kind != "none":
        p["norm2"] = norms.norm_init(cfg.norm, cfg.d_model, cfg.param_dtype)
        p["ffn"] = mlp.init(ks[2], spec.ffn, cfg.d_model,
                            param_dtype=cfg.param_dtype, accum_dtype=cfg.accum_dtype)
    return p


def init_block_cache(cfg: ModelConfig, spec: BlockSpec, batch: int,
                     max_len: int, enc_len: int = 0, dtype=None, *,
                     page_size: int = 0, num_pages: int = 0,
                     prealloc: bool = True) -> Cache:
    dtype = dtype or cfg.param_dtype
    c: Cache = {}
    if spec.mixer == "attn":
        c["kv"] = attention.init_cache(batch, max_len,
                                       make_attn_config(cfg, spec), dtype,
                                       page_size=page_size,
                                       num_pages=num_pages, prealloc=prealloc)
    elif spec.mixer == "mamba":
        c["mamba"] = mamba.init_state(batch, make_mamba_config(cfg), cfg.accum_dtype)
    elif spec.mixer == "mlstm":
        c["mlstm"] = xlstm.mlstm_init_state(batch, make_xlstm_config(cfg),
                                            cfg.accum_dtype)
    elif spec.mixer == "slstm":
        c["slstm"] = xlstm.slstm_init_state(batch, cfg.d_model, cfg.accum_dtype)
    if spec.cross_attention:
        K, hd = cfg.n_kv_heads, cfg.resolved_head_dim
        c["cross_k"] = jnp.zeros((batch, enc_len, K, hd), dtype)
        c["cross_v"] = jnp.zeros((batch, enc_len, K, hd), dtype)
    return c


def block_forward(params: Params, cfg: ModelConfig, spec: BlockSpec,
                  x: jax.Array, *, mode: str = "train",
                  cache: Optional[Cache] = None,
                  rng: Optional[jax.Array] = None,
                  enc_out: Optional[jax.Array] = None,
                  causal: bool = True,
                  chunk_valid: Optional[jax.Array] = None,
                  decode_mask: Optional[jax.Array] = None,
                  token_valid: Optional[jax.Array] = None
                  ) -> tuple[jax.Array, Optional[Cache], dict]:
    """One block: pre-norm mixer + residual, [cross-attn], pre-norm FFN + residual.

    ``mode="chunk"`` (chunked prefill, DESIGN.md §9) consumes a (B, C) slab
    against each row's cache at its current length; ``chunk_valid`` (B,)
    gives each row's real token count.  ``decode_mask`` (B,) bool, decode
    mode only: rows where it is False do not write/advance their KV cache.
    Both are attention-mixer features — recurrent mixers have no
    length-masked state to protect (the serving engine rejects them).

    ``token_valid`` (bool, broadcastable to x's leading (B, S) shape) marks
    phantom tokens for the FFN dispatch: capacity-bounded FFF backends route
    them to the sentinel leaf so they never consume grouped capacity or
    pollute routing telemetry (DESIGN.md §9).  In chunk mode it is derived
    from ``chunk_valid`` when not given; it is deliberately separate from
    ``decode_mask`` so a caller can keep KV writes on for every row (the
    monolithic engine's fixed-shape contract) while still masking the FFF
    dispatch."""
    new_cache: Cache = {} if cache is not None else None
    h = norms.norm_apply(cfg.norm, params["norm1"], x)

    if mode == "chunk" and (spec.mixer != "attn" or spec.cross_attention):
        raise ValueError("chunked prefill requires plain attention mixers "
                         "(recurrent state folds pad garbage in; cross-attn "
                         "slabs are unsupported)")
    if spec.mixer == "attn":
        acfg = make_attn_config(cfg, spec, causal=causal)
        if mode in ("train", "eval"):      # eval: full attn, hard FFN routing
            y = attention.forward(params["mixer"], acfg, h)
        elif mode == "prefill":
            y, kv = attention.forward_prefill(params["mixer"], acfg, h, cache["kv"])
            new_cache["kv"] = kv
        elif mode == "chunk":
            y, kv = attention.forward_chunk(params["mixer"], acfg, h,
                                            cache["kv"], chunk_valid)
            new_cache["kv"] = kv
        else:
            y, kv = attention.forward_decode(params["mixer"], acfg, h,
                                             cache["kv"], decode_mask)
            new_cache["kv"] = kv
    elif spec.mixer == "mamba":
        mcfg = make_mamba_config(cfg)
        st = cache["mamba"] if cache is not None else None
        y, st2 = mamba.forward(params["mixer"], mcfg, h, st)
        if cache is not None:
            new_cache["mamba"] = st2
    elif spec.mixer == "mlstm":
        xcfg = make_xlstm_config(cfg)
        st = cache["mlstm"] if cache is not None else None
        y, st2 = xlstm.mlstm_block(params["mixer"], xcfg, h, st)
        if cache is not None:
            new_cache["mlstm"] = st2
    elif spec.mixer == "slstm":
        xcfg = make_xlstm_config(cfg)
        st = cache["slstm"] if cache is not None else None
        y, st2 = xlstm.slstm_block(params["mixer"], xcfg, h, st)
        if cache is not None:
            new_cache["slstm"] = st2
    else:
        y = jnp.zeros_like(h)
    x = x + y
    x = act.shard(x, act.ACT_BSD)

    if spec.cross_attention:
        acfg = make_attn_config(cfg, spec, causal=False)
        hx = norms.norm_apply(cfg.norm, params["norm_x"], x)
        if mode in ("train", "eval"):
            ek, ev = attention.cross_kv(params["cross"], acfg, enc_out)
        elif mode == "prefill":
            ek, ev = attention.cross_kv(params["cross"], acfg, enc_out)
            new_cache["cross_k"], new_cache["cross_v"] = ek, ev
        else:
            ek, ev = cache["cross_k"], cache["cross_v"]
            new_cache["cross_k"], new_cache["cross_v"] = ek, ev
        x = x + attention.forward_cross(params["cross"], acfg, hx, ek, ev)
        x = act.shard(x, act.ACT_BSD)

    aux = {"hardening": jnp.zeros((), jnp.float32),
           "moe_aux": jnp.zeros((), jnp.float32),
           "balance": jnp.zeros((), jnp.float32)}
    if spec.ffn.kind != "none":
        if token_valid is None and mode == "chunk" and chunk_valid is not None:
            token_valid = (jnp.arange(x.shape[1]) < chunk_valid[:, None])
        h2 = norms.norm_apply(cfg.norm, params["norm2"], x)
        y2, aux = mlp.forward(params["ffn"], spec.ffn, cfg.d_model, h2,
                              param_dtype=cfg.param_dtype,
                              accum_dtype=cfg.accum_dtype,
                              train=(mode == "train"), rng=rng,
                              valid=token_valid)
        x = x + y2
        x = act.shard(x, act.ACT_BSD)
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# the stack: scan over periods
# ---------------------------------------------------------------------------

def stack_init(key: jax.Array, cfg: ModelConfig, *, causal: bool = True,
               period: tuple[BlockSpec, ...] | None = None,
               n_layers: int | None = None) -> list[Params]:
    """Returns a list (one entry per period position) of param trees whose
    leaves carry a leading ``n_periods`` axis."""
    period = period or cfg.period
    n_layers = n_layers or cfg.n_layers
    n_periods = n_layers // len(period)
    keys = jax.random.split(key, n_layers)
    out = []
    for pos, spec in enumerate(period):
        per = [block_init(keys[i * len(period) + pos], cfg, spec, causal=causal)
               for i in range(n_periods)]
        out.append(jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *per))
    return out


def init_caches(cfg: ModelConfig, batch: int, max_len: int, *,
                period: tuple[BlockSpec, ...] | None = None,
                n_layers: int | None = None, enc_len: int = 0,
                dtype=None, page_size: int = 0, num_pages: int = 0,
                prealloc: bool = True) -> list[Cache]:
    """Stacked caches, mirroring stack_init's layout."""
    period = period or cfg.period
    n_layers = n_layers or cfg.n_layers
    n_periods = n_layers // len(period)
    out = []
    for spec in period:
        one = init_block_cache(cfg, spec, batch, max_len, enc_len, dtype,
                               page_size=page_size, num_pages=num_pages,
                               prealloc=prealloc)
        out.append(jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x, (n_periods,) + x.shape), one))
    return out


def stack_forward(params: list[Params], cfg: ModelConfig, x: jax.Array, *,
                  mode: str = "train", caches: Optional[list[Cache]] = None,
                  rng: Optional[jax.Array] = None,
                  enc_out: Optional[jax.Array] = None,
                  causal: bool = True,
                  period: tuple[BlockSpec, ...] | None = None,
                  chunk_valid: Optional[jax.Array] = None,
                  decode_mask: Optional[jax.Array] = None,
                  token_valid: Optional[jax.Array] = None
                  ) -> tuple[jax.Array, Optional[list[Cache]], dict]:
    """Run the whole stack (scan over periods).  ``chunk_valid`` /
    ``decode_mask`` / ``token_valid`` ride through to every block (see
    ``block_forward``); they are loop-invariant, so the scan closes over
    them."""
    period = period or cfg.period
    n_periods = jax.tree_util.tree_leaves(params[0])[0].shape[0]
    use_rng = rng is not None
    if use_rng:
        flat = jax.random.split(rng, n_periods * len(period))
        rngs = flat.reshape(n_periods, len(period), *flat.shape[1:])
    else:
        rngs = jnp.zeros((n_periods, len(period)), jnp.uint32)

    def period_body(x, per_params, per_caches, per_rngs):
        new_caches = []
        aux_h = jnp.zeros((), jnp.float32)
        aux_m = jnp.zeros((), jnp.float32)
        aux_b = jnp.zeros((), jnp.float32)
        routing = []
        for pos, spec in enumerate(period):
            r = per_rngs[pos] if use_rng else None
            c = per_caches[pos] if per_caches is not None else None
            x, nc, aux = block_forward(
                per_params[pos], cfg, spec, x, mode=mode, cache=c, rng=r,
                enc_out=enc_out, causal=causal, chunk_valid=chunk_valid,
                decode_mask=decode_mask, token_valid=token_valid)
            new_caches.append(nc)
            aux_h = aux_h + aux["hardening"]
            aux_m = aux_m + aux["moe_aux"]
            aux_b = aux_b + aux["balance"]
            # per-position (not summed across positions): sites in one period
            # may have different leaf counts; summation happens across
            # *periods*, where position specs are identical
            routing.append(_routing_weighted(aux.get("routing")))
        return x, new_caches, (aux_h, aux_m, aux_b, tuple(routing))

    def finish_aux(aux_h, aux_m, aux_b, routing):
        aux = {"hardening": aux_h, "moe_aux": aux_m, "balance": aux_b}
        if any(r is not None for r in routing):
            aux["routing"] = tuple(_routing_finalize(r) for r in routing)
        return aux

    if cfg.scan_layers:
        def scan_body(carry, xs):
            x = carry
            per_params, per_caches, per_rngs = xs
            x, new_caches, aux = period_body(x, per_params, per_caches, per_rngs)
            if new_caches[0] is None:
                new_caches = [{} for _ in new_caches]
            return x, (new_caches, aux)

        body = scan_body
        if cfg.remat == "dots" and mode == "train":
            body = jax.checkpoint(
                scan_body,
                policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
        elif cfg.remat == "full" and mode == "train":
            body = jax.checkpoint(scan_body)
        xs = (params, caches, rngs)
        x, (new_caches, (aux_h, aux_m, aux_b, routing)) = jax.lax.scan(
            body, x, xs)
        routing = jax.tree_util.tree_map(lambda a: a.sum(0), routing)
        aux = finish_aux(aux_h.sum(), aux_m.sum(), aux_b.sum(), routing)
        return x, (new_caches if caches is not None else None), aux

    # unrolled path (smoke tests / tiny models)
    aux_h = jnp.zeros((), jnp.float32)
    aux_m = jnp.zeros((), jnp.float32)
    aux_b = jnp.zeros((), jnp.float32)
    routing_acc = None
    new_caches_acc = [[] for _ in period]
    for i in range(n_periods):
        per_params = [jax.tree_util.tree_map(lambda a: a[i], p) for p in params]
        per_caches = ([jax.tree_util.tree_map(lambda a: a[i], c) for c in caches]
                      if caches is not None else None)
        per_rngs = rngs[i]
        x, ncs, (h_, m_, b_, routing) = period_body(x, per_params, per_caches,
                                                    per_rngs)
        aux_h += h_
        aux_m += m_
        aux_b += b_
        routing_acc = (routing if routing_acc is None else
                       jax.tree_util.tree_map(jnp.add, routing_acc, routing))
        for pos, nc in enumerate(ncs):
            new_caches_acc[pos].append(nc)
    new_caches = None
    if caches is not None:
        new_caches = [jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *ncs)
                      for ncs in new_caches_acc]
    return x, new_caches, finish_aux(aux_h, aux_m, aux_b, routing_acc)
