"""Hypothesis property tests on the FFF system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import api, fff, regions, routing  # noqa: E402

SETTINGS = dict(max_examples=25, deadline=None)


@st.composite
def fff_case(draw, max_depth=5):
    depth = draw(st.integers(0, max_depth))
    leaf = draw(st.sampled_from([1, 2, 4, 8]))
    din = draw(st.sampled_from([3, 8, 17]))
    dout = draw(st.sampled_from([1, 5]))
    seed = draw(st.integers(0, 2 ** 16))
    batch = draw(st.integers(1, 33))
    cfg = fff.FFFConfig(dim_in=din, dim_out=dout, depth=depth,
                        leaf_width=leaf, activation="relu")
    params = fff.init(jax.random.PRNGKey(seed), cfg)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (batch, din))
    return cfg, params, x


@given(fff_case())
@settings(**SETTINGS)
def test_mixture_is_distribution(case):
    cfg, params, x = case
    _, out = api.apply(params, cfg, x, api.ExecutionSpec(mode="train"))
    mix = np.asarray(out.mixture)
    assert (mix >= -1e-6).all()
    np.testing.assert_allclose(mix.sum(-1), 1.0, atol=1e-4)


@given(fff_case())
@settings(**SETTINGS)
def test_routed_leaf_in_range_and_locally_greedy(case):
    """FORWARD_I takes the >=1/2 branch at every node along its own path."""
    cfg, params, x = case
    leaf_idx = np.asarray(fff.route_hard(params, cfg, x))[:, 0]
    assert (leaf_idx >= 0).all() and (leaf_idx < cfg.num_leaves).all()
    probs = np.asarray(jax.nn.sigmoid(
        fff._node_logits_all(params, cfg, x.astype(jnp.float32))))[:, 0]
    for b in range(min(x.shape[0], 8)):
        idx = 0
        for m in range(cfg.depth):
            g = 2 ** m - 1 + idx
            bit = (leaf_idx[b] >> (cfg.depth - 1 - m)) & 1
            p = probs[b, g]
            assert (p >= 0.5) == bool(bit), (b, m, p, bit)
            idx = 2 * idx + bit
        assert idx == leaf_idx[b]


@given(fff_case(max_depth=4))
@settings(**SETTINGS)
def test_regions_partition_input_space(case):
    """Every sample lies in exactly one leaf region, and it is the routed
    leaf's region (paper §Regions of responsibility)."""
    cfg, params, x = case
    assert regions.is_partition(params, cfg, x)


@given(fff_case())
@settings(**SETTINGS)
def test_entropy_nonneg_and_bounded(case):
    cfg, params, x = case
    _, out = api.apply(params, cfg, x, api.ExecutionSpec(mode="train"))
    ent = float(out.entropy)
    assert -1e-6 <= ent <= np.log(2) + 1e-6


@given(fff_case(max_depth=4), st.integers(0, 2 ** 16))
@settings(**SETTINGS)
def test_sorted_dispatch_roundtrip(case, seed):
    cfg, params, x = case
    leaf_idx = fff.route_hard(params, cfg, x)[:, 0]
    plan = routing.make_sorted_dispatch(leaf_idx, cfg.num_leaves)
    xs = routing.apply_sorted(x, plan)
    xr = routing.unapply_sorted(xs, plan)
    np.testing.assert_array_equal(np.asarray(xr), np.asarray(x))
    # sorted leaf ids are monotone
    ls = np.asarray(plan.leaf_ids_sorted)
    assert (np.diff(ls) >= 0).all()
    assert int(plan.group_sizes.sum()) == x.shape[0]


@given(st.integers(1, 64), st.integers(1, 6), st.integers(0, 2 ** 16),
       st.floats(1.0, 4.0))
@settings(**SETTINGS)
def test_capacity_dispatch_conservation(batch, depth_pow, seed, cap):
    E = 2 ** (depth_pow - 1)
    rng = np.random.default_rng(seed)
    leaf_idx = jnp.asarray(rng.integers(0, E, batch))
    plan = routing.make_capacity_dispatch(leaf_idx, E, capacity_factor=cap)
    C = plan.capacity
    kept = np.asarray(plan.kept)
    flat = np.asarray(plan.flat_idx)
    # each kept token occupies exactly one slot, inside its own leaf's block;
    # dropped tokens carry the uniform out-of-bounds sentinel
    assert len(set(flat[kept].tolist())) == int(kept.sum())
    np.testing.assert_array_equal(flat[kept] // C,
                                  np.asarray(leaf_idx)[kept])
    assert (flat[kept] % C < C).all()
    np.testing.assert_array_equal(flat[~kept], E * C)
    # per leaf, kept count == min(routed count, capacity)
    counts = np.bincount(np.asarray(leaf_idx), minlength=E)
    kept_counts = np.bincount(np.asarray(leaf_idx)[kept], minlength=E)
    np.testing.assert_array_equal(kept_counts, np.minimum(counts, C))
    # gather/scatter round-trip: kept tokens come back exactly, dropped zero
    x = jnp.asarray(rng.normal(0, 1, (batch, 7)), jnp.float32)
    back = routing.capacity_scatter(routing.capacity_gather(x, plan), plan)
    np.testing.assert_allclose(np.asarray(back)[kept],
                               np.asarray(x)[kept], rtol=1e-6)
    if (~kept).any():
        assert float(jnp.abs(back[~kept]).max()) == 0.0


@given(fff_case(max_depth=4))
@settings(**SETTINGS)
def test_train_forward_jit_consistent(case):
    cfg, params, x = case
    spec = api.ExecutionSpec(mode="train")
    y1, _ = api.apply(params, cfg, x, spec)
    y2, _ = jax.jit(lambda p, x: api.apply(p, cfg, x, spec))(params, x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-5, atol=2e-5)
