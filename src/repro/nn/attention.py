"""Attention: GQA with RoPE, flash-style chunked softmax, KV-cache decode.

Design notes (DESIGN.md §5):

* ``flash_attention`` is pure JAX: an online-softmax scan over a *static*
  list of (q-chunk, kv-chunk) pairs.  Causal masking is done by enumerating
  only the lower-triangle chunk pairs at trace time — no wasted upper-triangle
  FLOPs in the lowered HLO (this is what the roofline counts).  Sliding-window
  attention additionally drops chunk pairs outside the band, statically.
* GQA never materializes repeated KV heads: q is shaped (B, S, K, G, hd) and
  contractions carry the group axis.
* ``decode_attend`` attends one new token against a (possibly
  sequence-sharded) KV cache; softmax over a sharded S axis lowers to
  all-reduce(max)/all-reduce(sum) under SPMD — the long-context decode path.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro import utils
from repro.nn import rope as rope_lib

Params = dict

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    bias: bool = False
    rope_theta: float = 10000.0
    use_rope: bool = True
    causal: bool = True
    sliding_window: int = 0          # 0 = full
    chunk: int = 1024                # flash chunk size
    param_dtype: Any = jnp.float32
    accum_dtype: Any = jnp.float32

    @property
    def groups(self) -> int:
        return self.n_heads // self.n_kv_heads


def init(key: jax.Array, cfg: AttnConfig) -> Params:
    D, H, K, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    pd = cfg.param_dtype
    std = 1.0 / math.sqrt(D)
    p: Params = {
        "wq": utils.truncated_init(ks[0], (D, H, hd), std, pd),
        "wk": utils.truncated_init(ks[1], (D, K, hd), std, pd),
        "wv": utils.truncated_init(ks[2], (D, K, hd), std, pd),
        "wo": utils.truncated_init(ks[3], (H, hd, D), 1.0 / math.sqrt(H * hd), pd),
    }
    if cfg.bias:
        p["bq"] = jnp.zeros((H, hd), pd)
        p["bk"] = jnp.zeros((K, hd), pd)
        p["bv"] = jnp.zeros((K, hd), pd)
        p["bo"] = jnp.zeros((D,), pd)
    return p


def qkv(params: Params, cfg: AttnConfig, x: jax.Array,
        positions: Optional[jax.Array]) -> tuple[jax.Array, jax.Array, jax.Array]:
    """x (B, S, D) -> q (B, S, H, hd), k/v (B, S, K, hd), RoPE applied."""
    ad = cfg.accum_dtype
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"], preferred_element_type=ad)
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"], preferred_element_type=ad)
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"], preferred_element_type=ad)
    if cfg.bias:
        q = q + params["bq"].astype(ad)
        k = k + params["bk"].astype(ad)
        v = v + params["bv"].astype(ad)
    if cfg.use_rope and positions is not None:
        q = rope_lib.apply_rope(q, positions, cfg.rope_theta)
        k = rope_lib.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def out_proj(params: Params, cfg: AttnConfig, o: jax.Array) -> jax.Array:
    y = jnp.einsum("bshk,hkd->bsd", o, params["wo"],
                   preferred_element_type=cfg.accum_dtype)
    if cfg.bias:
        y = y + params["bo"].astype(cfg.accum_dtype)
    return y


# ---------------------------------------------------------------------------
# flash attention over static chunk pairs
# ---------------------------------------------------------------------------

def _chunk_pairs(n_q: int, n_k: int, causal: bool, window_chunks: int
                 ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Static (qi, kj, needs_mask) schedule.

    causal: only kj <= qi (equal-length q/k assumed); diagonal chunk masked.
    window_chunks w > 0: additionally require qi - kj <= w (band)."""
    qs, ks, masked = [], [], []
    for qi in range(n_q):
        for kj in range(n_k):
            if causal and kj > qi:
                continue
            if window_chunks > 0 and qi - kj > window_chunks:
                continue
            qs.append(qi)
            ks.append(kj)
            masked.append(causal and kj == qi or window_chunks > 0
                          and qi - kj == window_chunks)
    return (jnp.asarray(qs, jnp.int32), jnp.asarray(ks, jnp.int32),
            jnp.asarray(masked, jnp.bool_))


def _expand_kv(kv: jax.Array, groups: int) -> jax.Array:
    """(B, S, K, hd) -> (B, S, K*G, hd): materialize KV per q-head.

    Sharding rationale (DESIGN.md §5): GQA KV-head counts (4-16) do not divide
    the 16-way model axis, and the (K, G) head-grouping reshape forces the
    SPMD partitioner into involuntary rematerialization.  Expanding KV keeps
    every attention tensor sharded on the full H axis; the duplicated KV bytes
    are per-layer transients and are the cheaper trade (measured: §Perf)."""
    if groups == 1:
        return kv
    return jnp.repeat(kv, groups, axis=2)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, chunk: int = 1024,
                    sliding_window: int = 0) -> jax.Array:
    """Online-softmax attention.

    q (B, S, H, hd); k, v (B, S, K, hd) with H = K * G.  Returns (B, S, H, hd).
    The scan carries full-size (m, l, acc) accumulators and visits only the
    statically scheduled chunk pairs, updating the q-chunk rows in place.
    """
    B, S, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    chunk = min(chunk, S)
    if S % chunk:
        chunk = math.gcd(S, chunk) or S
    n_chunks = S // chunk
    scale = 1.0 / math.sqrt(hd)
    window_chunks = 0
    if sliding_window > 0:
        window_chunks = max(1, utils.cdiv(sliding_window, chunk))
    qi_l, kj_l, mk_l = _chunk_pairs(n_chunks, n_chunks, causal, window_chunks)

    qf = q.astype(jnp.float32)
    kf = _expand_kv(k, G).astype(jnp.float32)
    vf = _expand_kv(v, G).astype(jnp.float32)

    m0 = jnp.full((B, S, H), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, S, H), jnp.float32)
    a0 = jnp.zeros((B, S, H, hd), jnp.float32)

    col = jnp.arange(chunk)

    def body(carry, step):
        m, l, acc = carry
        qi, kj, needs_mask = step
        qs = qi * chunk
        ks_ = kj * chunk
        qc = jax.lax.dynamic_slice_in_dim(qf, qs, chunk, axis=1)      # (B,c,H,hd)
        kc = jax.lax.dynamic_slice_in_dim(kf, ks_, chunk, axis=1)
        vc = jax.lax.dynamic_slice_in_dim(vf, ks_, chunk, axis=1)
        s = jnp.einsum("bqhd,bphd->bhqp", qc, kc) * scale             # (B,H,c,c)
        if causal or sliding_window > 0:
            qpos = qs + col[:, None]
            kpos = ks_ + col[None, :]
            ok = jnp.ones((chunk, chunk), bool)
            if causal:
                ok &= kpos <= qpos
            if sliding_window > 0:
                ok &= qpos - kpos < sliding_window
            s = jnp.where(needs_mask, jnp.where(ok, s, NEG_INF), s)
        m_chunk = jax.lax.dynamic_slice_in_dim(m, qs, chunk, axis=1)  # (B,c,H)
        l_chunk = jax.lax.dynamic_slice_in_dim(l, qs, chunk, axis=1)
        a_chunk = jax.lax.dynamic_slice_in_dim(acc, qs, chunk, axis=1)
        m_cur = m_chunk.transpose(0, 2, 1)                            # (B,H,c)
        m_new = jnp.maximum(m_cur, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_cur - m_new)
        l_new = l_chunk.transpose(0, 2, 1) * corr + p.sum(axis=-1)
        pv = jnp.einsum("bhqp,bphd->bhqd", p, vc)
        a_new = a_chunk.transpose(0, 2, 1, 3) * corr[..., None] + pv
        m = jax.lax.dynamic_update_slice_in_dim(
            m, m_new.transpose(0, 2, 1), qs, axis=1)
        l = jax.lax.dynamic_update_slice_in_dim(
            l, l_new.transpose(0, 2, 1), qs, axis=1)
        acc = jax.lax.dynamic_update_slice_in_dim(
            acc, a_new.transpose(0, 2, 1, 3), qs, axis=1)
        return (m, l, acc), None

    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (qi_l, kj_l, mk_l))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.astype(q.dtype)


def full_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                   causal: bool = True, bias_mask: Optional[jax.Array] = None
                   ) -> jax.Array:
    """Plain materialized-scores attention — oracle and short-sequence path."""
    B, S, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    Sk = k.shape[1]
    kf = _expand_kv(k, G).astype(jnp.float32)
    vf = _expand_kv(v, G).astype(jnp.float32)
    s = jnp.einsum("bqhd,bphd->bhqp", q.astype(jnp.float32), kf)
    s = s / math.sqrt(hd)
    if causal:
        mask = jnp.tril(jnp.ones((S, Sk), bool), k=Sk - S)
        s = jnp.where(mask, s, NEG_INF)
    if bias_mask is not None:
        s = jnp.where(bias_mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqp,bphd->bqhd", p, vf)
    return o.astype(q.dtype)


# ---------------------------------------------------------------------------
# KV cache + decode
# ---------------------------------------------------------------------------

class KVCache(NamedTuple):
    """Paged KV cache: a fixed page pool indexed through per-row page tables.

    ``k``/``v`` are pools of ``num_pages`` fixed-size pages shared by all
    rows; row b's logical positions ``p`` live at
    ``pool[table[b, p // page_size], p % page_size]``.  Unmapped table
    entries hold the sentinel ``num_pages`` — writes through them are
    dropped and reads clamp to an arbitrary page whose values are masked
    out by the ``length`` check.  Two rows may map the same page (shared
    prompt prefix, DESIGN.md §11); the host-side allocator guarantees a
    shared page is never written.

    The contiguous cache of earlier revisions is the degenerate case
    ``page_size == max_len`` with an identity table (row b owns page b) —
    ``init_cache``'s default — and is bit-for-bit unchanged.
    """
    k: jax.Array          # (num_pages, page_size, K, hd) page pool
    v: jax.Array          # (num_pages, page_size, K, hd)
    table: jax.Array      # (B, pages_per_row) int32 page ids
    length: jax.Array     # (B,) int32 filled positions


def init_cache(batch: int, max_len: int, cfg: AttnConfig, dtype=None, *,
               page_size: int = 0, num_pages: int = 0,
               prealloc: bool = True) -> KVCache:
    """``page_size <= 0`` selects the degenerate contiguous layout (one
    ``max_len``-sized page per row).  ``prealloc`` maps row b to pages
    ``[b*ppr, (b+1)*ppr)`` identity-style — standalone callers (generate,
    tests) need a ready-to-write table; the serving engine passes
    ``prealloc=False`` and installs allocator-managed tables per admission."""
    dtype = dtype or cfg.param_dtype
    if page_size <= 0:
        page_size = max_len
    ppr = utils.cdiv(max_len, page_size)                 # pages per row
    if num_pages <= 0:
        num_pages = batch * ppr
    if prealloc:
        if num_pages < batch * ppr:
            raise ValueError(f"prealloc needs {batch * ppr} pages, "
                             f"pool has {num_pages}")
        table = (jnp.arange(batch, dtype=jnp.int32)[:, None] * ppr
                 + jnp.arange(ppr, dtype=jnp.int32)[None, :])
    else:
        table = jnp.full((batch, ppr), num_pages, jnp.int32)
    shp = (num_pages, page_size, cfg.n_kv_heads, cfg.head_dim)
    return KVCache(jnp.zeros(shp, dtype), jnp.zeros(shp, dtype), table,
                   jnp.zeros((batch,), jnp.int32))


def gather_cache_kv(cache: KVCache) -> tuple[jax.Array, jax.Array]:
    """Materialize per-row K/V views (B, ppr*page, K, hd) from the pool.

    Sentinel table entries clamp to the last page; the garbage they gather
    is finite (pools are zero-initialized) and always masked by the
    caller's ``pos < length`` check."""
    num_pages, page = cache.k.shape[:2]
    tbl = jnp.minimum(cache.table, num_pages - 1)
    B, ppr = tbl.shape
    shp = (B, ppr * page) + cache.k.shape[2:]
    return cache.k[tbl].reshape(shp), cache.v[tbl].reshape(shp)


def prefill_into_cache(cache: KVCache, k: jax.Array, v: jax.Array) -> KVCache:
    """Write a full prefix (B, S, K, hd) at position 0."""
    S = k.shape[1]
    zeroed = cache._replace(length=jnp.zeros_like(cache.length))
    return chunk_into_cache(zeroed, k, v, jnp.full_like(cache.length, S))


def append_to_cache(cache: KVCache, k1: jax.Array, v1: jax.Array,
                    write_mask: Optional[jax.Array] = None) -> KVCache:
    """Append one token (B, 1, K, hd) at each sequence's current length.

    ``write_mask`` (B,) bool, optional: rows where it is False neither write
    K/V nor advance ``length`` (their write index is pushed out of bounds and
    dropped).  The continuous-batching engine uses this to decode a full
    ``(num_slots, 1)`` batch while some slots are mid-chunked-prefill — those
    rows' caches must not be perturbed by the dummy decode token.

    This is the C = 1 case of ``chunk_into_cache`` (write_mask == that
    row's valid_len), delegated so the masked-scatter/length invariant
    lives in one place."""
    B = k1.shape[0]
    valid = (jnp.ones((B,), jnp.int32) if write_mask is None
             else write_mask.astype(jnp.int32))
    return chunk_into_cache(cache, k1, v1, valid)


def chunk_into_cache(cache: KVCache, k: jax.Array, v: jax.Array,
                     valid_len: jax.Array) -> KVCache:
    """Write a chunk (B, C, K, hd) at each row's current length (chunked
    prefill, DESIGN.md §9).

    Row b's first ``valid_len[b]`` positions land at logical positions
    ``length[b] .. length[b] + valid_len[b] - 1``, scattered through the
    row's page table into the pool; the rest of the chunk is padding whose
    page ids are pushed to the ``num_pages`` sentinel and dropped, so rows
    with no prefill work this step (``valid_len == 0``) are untouched.
    Positions past the row's mapped pages are likewise dropped (second
    line of defense — unmapped table entries already hold the sentinel).
    ``length`` advances by ``valid_len``."""
    B, C = k.shape[:2]
    num_pages, page = cache.k.shape[:2]
    ppr = cache.table.shape[1]
    col = jnp.arange(C)[None, :]                                  # (1, C)
    pos = cache.length[:, None] + col                             # (B, C)
    pg = pos // page                                              # (B, C)
    pid = jnp.take_along_axis(cache.table, jnp.minimum(pg, ppr - 1), axis=1)
    ok = (col < valid_len[:, None]) & (pg < ppr)
    pid = jnp.where(ok, pid, num_pages)                # pad/inactive: drop
    off = pos % page
    new_k = cache.k.at[pid, off].set(k.astype(cache.k.dtype), mode="drop")
    new_v = cache.v.at[pid, off].set(v.astype(cache.v.dtype), mode="drop")
    return cache._replace(
        k=new_k, v=new_v,
        length=cache.length + valid_len.astype(cache.length.dtype))


def decode_attend(q1: jax.Array, cache: KVCache, *, sliding_window: int = 0
                  ) -> jax.Array:
    """One-token attention against the cache.

    q1 (B, 1, H, hd) -> (B, 1, H, hd).  Valid-length masking uses the cache's
    per-sequence ``length``.  With a sequence-sharded cache the max/sum over S
    lower to all-reduces under SPMD (long-context decode path).

    The GQA contraction stays on the K axis here (no KV expansion): decode is
    memory-bound on the cache read, and the score tensor is tiny."""
    B, _, H, hd = q1.shape
    K = cache.k.shape[2]
    G = H // K
    kc, vc = gather_cache_kv(cache)                    # (B, ppr*page, K, hd)
    S = kc.shape[1]
    qg = q1.reshape(B, K, G, hd).astype(jnp.float32)
    s = jnp.einsum("bkgd,bpkd->bkgp", qg, kc.astype(jnp.float32))
    s = s / math.sqrt(hd)
    pos = jnp.arange(S)[None, :]                                  # (1, S)
    valid = pos < cache.length[:, None]
    if sliding_window > 0:
        valid &= pos >= (cache.length[:, None] - sliding_window)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgp,bpkd->bkgd", p, vc.astype(jnp.float32))
    return o.reshape(B, 1, H, hd).astype(q1.dtype)


def chunk_attend(q: jax.Array, cache: KVCache, start: jax.Array, *,
                 sliding_window: int = 0) -> jax.Array:
    """Chunk attention against the cache with per-row positions.

    q (B, C, H, hd) holds the chunk's queries; row b's query i sits at
    absolute position ``start[b] + i`` and attends to cache positions
    ``<= start[b] + i`` — the row's history (previous chunks, already in the
    cache) plus the chunk's own causal prefix (this chunk's K/V must already
    be written at ``start[b]..``; see ``chunk_into_cache``).  Like
    ``decode_attend``, the GQA contraction stays on the K axis and the mask
    is per-row, so rows of one batch may sit at different offsets."""
    B, C, H, hd = q.shape
    K = cache.k.shape[2]
    G = H // K
    kc, vc = gather_cache_kv(cache)                    # (B, ppr*page, K, hd)
    S = kc.shape[1]
    qg = q.reshape(B, C, K, G, hd).astype(jnp.float32)
    s = jnp.einsum("bckgd,bpkd->bkgcp", qg, kc.astype(jnp.float32))
    s = s / math.sqrt(hd)
    qpos = start[:, None] + jnp.arange(C)[None, :]                # (B, C)
    kpos = jnp.arange(S)[None, None, :]                           # (1, 1, S)
    valid = kpos <= qpos[:, :, None]                              # (B, C, S)
    if sliding_window > 0:
        valid &= kpos > qpos[:, :, None] - sliding_window
    s = jnp.where(valid[:, None, None, :, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgcp,bpkd->bckgd", p, vc.astype(jnp.float32))
    return o.reshape(B, C, H, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# block-level entry points
# ---------------------------------------------------------------------------

def forward(params: Params, cfg: AttnConfig, x: jax.Array,
            positions: Optional[jax.Array] = None,
            use_flash_above: int = 2048) -> jax.Array:
    """Self-attention over a full sequence (train / prefill without cache)."""
    B, S, _ = x.shape
    if positions is None and cfg.use_rope:
        positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    q, k, v = qkv(params, cfg, x, positions)
    if S > use_flash_above:
        o = flash_attention(q, k, v, causal=cfg.causal, chunk=cfg.chunk,
                            sliding_window=cfg.sliding_window)
    else:
        band = None
        if cfg.sliding_window > 0 and S > cfg.sliding_window:
            band = (jnp.arange(S)[:, None] - jnp.arange(S)[None, :]) \
                < cfg.sliding_window
        o = full_attention(q, k, v, causal=cfg.causal, bias_mask=band)
    return out_proj(params, cfg, o)


def forward_prefill(params: Params, cfg: AttnConfig, x: jax.Array,
                    cache: KVCache, use_flash_above: int = 2048
                    ) -> tuple[jax.Array, KVCache]:
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    q, k, v = qkv(params, cfg, x, positions if cfg.use_rope else None)
    cache = prefill_into_cache(cache, k, v)
    if S > use_flash_above:
        o = flash_attention(q, k, v, causal=cfg.causal, chunk=cfg.chunk,
                            sliding_window=cfg.sliding_window)
    else:
        o = full_attention(q, k, v, causal=cfg.causal)
    return out_proj(params, cfg, o), cache


def forward_decode(params: Params, cfg: AttnConfig, x1: jax.Array,
                   cache: KVCache, write_mask: Optional[jax.Array] = None
                   ) -> tuple[jax.Array, KVCache]:
    """One decode step: x1 (B, 1, D).  Rows where ``write_mask`` (B,) is
    False leave the cache untouched (see ``append_to_cache``) — their
    attention output is still computed but the caller ignores it."""
    positions = cache.length[:, None] if cfg.use_rope else None   # (B, 1)
    q, k, v = qkv(params, cfg, x1, positions)
    cache = append_to_cache(cache, k, v, write_mask)
    o = decode_attend(q, cache, sliding_window=cfg.sliding_window)
    return out_proj(params, cfg, o), cache


def forward_chunk(params: Params, cfg: AttnConfig, x: jax.Array,
                  cache: KVCache, valid_len: jax.Array
                  ) -> tuple[jax.Array, KVCache]:
    """Chunked prefill: x (B, C, D) continues each row's sequence at its
    current cache length (DESIGN.md §9).

    Row b's first ``valid_len[b]`` chunk positions are real tokens — written
    into the cache and attended causally against the row's full history —
    while pad positions (and rows with ``valid_len == 0``) write nothing and
    produce garbage outputs the caller ignores.  RoPE positions are absolute:
    ``cache.length[b] + i``."""
    B, C, _ = x.shape
    start = cache.length                                          # (B,)
    positions = start[:, None] + jnp.arange(C)[None, :]           # (B, C)
    q, k, v = qkv(params, cfg, x, positions if cfg.use_rope else None)
    cache = chunk_into_cache(cache, k, v, valid_len)
    o = chunk_attend(q, cache, start, sliding_window=cfg.sliding_window)
    return out_proj(params, cfg, o), cache


def forward_cross(params: Params, cfg: AttnConfig, x: jax.Array,
                  enc_k: jax.Array, enc_v: jax.Array) -> jax.Array:
    """Cross-attention: queries from x (B, S, D), precomputed encoder K/V."""
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"],
                   preferred_element_type=cfg.accum_dtype)
    if cfg.bias:
        q = q + params["bq"].astype(cfg.accum_dtype)
    o = full_attention(q, enc_k, enc_v, causal=False)
    return out_proj(params, cfg, o)


def cross_kv(params: Params, cfg: AttnConfig, enc_out: jax.Array
             ) -> tuple[jax.Array, jax.Array]:
    k = jnp.einsum("bsd,dhk->bshk", enc_out, params["wk"],
                   preferred_element_type=cfg.accum_dtype)
    v = jnp.einsum("bsd,dhk->bshk", enc_out, params["wv"],
                   preferred_element_type=cfg.accum_dtype)
    if cfg.bias:
        k = k + params["bk"].astype(cfg.accum_dtype)
        v = v + params["bv"].astype(cfg.accum_dtype)
    return k, v
