"""Token -> leaf dispatch machinery for FFF serving on TPU.

The paper's CUDA implementation exploits per-token offset loads.  On TPU the
equivalent-cost primitive is *sorted dispatch*: sort tokens by their routed
leaf id, run a ragged grouped GEMM over contiguous per-leaf token runs, and
scatter results back (DESIGN.md §3).  This module provides the host-side
dispatch plan; the GEMM itself lives in ``repro.kernels.leaf_gemm``.

Also provides Switch-style capacity-bounded dispatch (with an optional
overflow-to-dense fallback) used when serving under adversarial routing skew.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro import utils
from repro.distributed import act as dist_act


class SortedDispatch(NamedTuple):
    """A plan for grouped execution of tokens sorted by leaf id.

    sort_idx:    (B,) permutation; x_sorted = x[sort_idx]
    unsort_idx:  (B,) inverse permutation
    group_sizes: (E,) tokens routed to each leaf (sums to B)
    group_offsets: (E+1,) exclusive prefix sums of group_sizes
    leaf_ids_sorted: (B,) leaf id per sorted slot
    """
    sort_idx: jax.Array
    unsort_idx: jax.Array
    group_sizes: jax.Array
    group_offsets: jax.Array
    leaf_ids_sorted: jax.Array


def make_sorted_dispatch(leaf_idx: jax.Array, num_leaves: int) -> SortedDispatch:
    """Build the sorted-dispatch plan from per-token leaf ids (B,)."""
    B = leaf_idx.shape[0]
    sort_idx = jnp.argsort(leaf_idx, stable=True)
    leaf_sorted = jnp.take(leaf_idx, sort_idx)
    unsort_idx = jnp.argsort(sort_idx)
    group_sizes = jnp.bincount(leaf_idx, length=num_leaves)
    group_offsets = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(group_sizes).astype(jnp.int32)])
    return SortedDispatch(sort_idx.astype(jnp.int32), unsort_idx.astype(jnp.int32),
                          group_sizes.astype(jnp.int32), group_offsets,
                          leaf_sorted.astype(jnp.int32))


def apply_sorted(x: jax.Array, plan: SortedDispatch) -> jax.Array:
    return jnp.take(x, plan.sort_idx, axis=0)


def unapply_sorted(y_sorted: jax.Array, plan: SortedDispatch) -> jax.Array:
    return jnp.take(y_sorted, plan.unsort_idx, axis=0)


# ---------------------------------------------------------------------------
# capacity-bounded dispatch (Switch-transformer style; beyond-paper hardening
# of FFF serving against routing skew)
# ---------------------------------------------------------------------------

class CapacityDispatch(NamedTuple):
    """Dense dispatch/combine plan bounded by per-leaf capacity C.

    dispatch: (B, E, C) one-hot: token b occupies slot (e, c)
    kept:     (B,) bool; False = token overflowed its leaf's capacity
    """
    dispatch: jax.Array
    kept: jax.Array
    capacity: int


def make_capacity_dispatch(leaf_idx: jax.Array, num_leaves: int,
                           capacity_factor: float = 1.25) -> CapacityDispatch:
    B = leaf_idx.shape[0]
    capacity = max(1, int(capacity_factor * utils.cdiv(B, num_leaves)))
    onehot = jax.nn.one_hot(leaf_idx, num_leaves, dtype=jnp.int32)     # (B, E)
    pos = jnp.cumsum(onehot, axis=0) * onehot - onehot                 # slot per token
    slot = jnp.take_along_axis(pos, leaf_idx[:, None], axis=1)[:, 0]
    kept = slot < capacity
    slot = jnp.where(kept, slot, 0)
    dispatch = (jax.nn.one_hot(leaf_idx, num_leaves, dtype=jnp.float32)
                * kept[:, None])[..., None] * jax.nn.one_hot(
                    slot, capacity, dtype=jnp.float32)[:, None, :]
    return CapacityDispatch(dispatch, kept, capacity)


def capacity_gather(x: jax.Array, plan: CapacityDispatch) -> jax.Array:
    """x (B, D) -> per-leaf buffers (E, C, D)."""
    return jnp.einsum("bec,bd->ecd", plan.dispatch, x)


def capacity_scatter(y: jax.Array, plan: CapacityDispatch) -> jax.Array:
    """(E, C, O) -> (B, O); dropped tokens receive zeros (caller may fall back
    to a dense path for them — overflow-to-dense, DESIGN.md §8)."""
    return jnp.einsum("bec,eco->bo", plan.dispatch, y)


# ---------------------------------------------------------------------------
# grouped leaf execution over a sorted plan (pure-jnp reference; the Pallas
# ragged GEMM in kernels/leaf_gemm implements the same contract)
# ---------------------------------------------------------------------------

def grouped_leaf_matmul_ref(x_sorted: jax.Array, leaf_ids_sorted: jax.Array,
                            w: jax.Array) -> jax.Array:
    """Reference grouped GEMM: y[i] = x_sorted[i] @ w[leaf_ids_sorted[i]].

    x_sorted (B, D), w (E, D, H) -> (B, H).  O(B*D*H) with a per-token gather
    of the weight block — the oracle for kernels/leaf_gemm.
    """
    w_g = jnp.take(w, leaf_ids_sorted, axis=0)          # (B, D, H)
    return jnp.einsum("bd,bdh->bh", x_sorted, w_g,
                      preferred_element_type=jnp.float32)


def group_slots(leaf_idx: jax.Array, num_groups: int) -> jax.Array:
    """Per-token slot index within its routed group, O(B log B).

    slot[i] = |{j : leaf[j] == leaf[i], j < i in sorted order}| — computed
    from sort ranks: rank_in_sorted(i) - group_offset(leaf[i])."""
    B = leaf_idx.shape[0]
    sort_idx = jnp.argsort(leaf_idx, stable=True)
    rank = jnp.zeros((B,), jnp.int32).at[sort_idx].set(
        jnp.arange(B, dtype=jnp.int32))
    sizes = jnp.bincount(leaf_idx, length=num_groups)
    offsets = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                               jnp.cumsum(sizes)[:-1].astype(jnp.int32)])
    return rank - jnp.take(offsets, leaf_idx)


def grouped_leaf_apply(x: jax.Array, leaf_idx: jax.Array, params: dict,
                       activation: str, capacity_factor: float = 1.5,
                       accum_dtype=jnp.float32, serving: bool = False,
                       return_kept: bool = False):
    """Differentiable capacity-bounded grouped leaf execution (pure jnp).

    The scale path for both ST training and batched serving of MoE-sized FFF
    layers.  LOCAL dispatch semantics (DESIGN.md §5, §Perf iter 1): the token
    axis is blocked by the data-shard count G so every scatter/gather stays
    shard-local under SPMD — capacity is per (shard, leaf), exactly like a
    production MoE.  Per-leaf GEMMs are batched over (G-data, E-model); the
    only cross-shard traffic is what the leaf-weight sharding itself implies.

    Tokens over their shard's capacity contribute zeros (standard MoE-style
    drop; exactness, when needed, comes from the kernels' overflow-to-dense
    fallback).

    x (B, D); params: single-tree leaf weights {leaf_w1/leaf_w2} or
    {leaf_wg/leaf_wu/leaf_wd}; returns (B, dim_out), or with
    ``return_kept=True`` a ``(y, kept)`` pair where ``kept`` (B,) bool marks
    tokens that fit under capacity (False = dropped to zeros).
    """
    B, D = x.shape
    swiglu = "leaf_wg" in params
    E = (params["leaf_wg"] if swiglu else params["leaf_w1"]).shape[0]
    G = dist_act.data_shard_count()
    if B % G:
        G = 1
    Bg = B // G
    capacity = max(8, utils.round_up(int(capacity_factor * utils.cdiv(Bg, E)), 8))

    xg_ = x.reshape(G, Bg, D)
    idx_g = leaf_idx.reshape(G, Bg)
    # slot-within-(shard, leaf) via sort ranks, NOT cumsum(one_hot): XLA
    # lowers a (B, E) token-axis cumsum to an O(B^2) reduce-window
    # (measured 260x FLOP inflation at 64 experts — §Perf iter 1).
    slot = jax.vmap(lambda i: group_slots(i, E))(idx_g)           # (G, Bg)
    kept = slot < capacity
    # dropped tokens scatter OUT OF BOUNDS (mode="drop"): clamping them onto
    # slot capacity-1 would collide with the kept token legitimately there,
    # and duplicate-index scatter-set resolution is nondeterministic
    flat_idx = jnp.where(kept, idx_g * capacity + slot, E * capacity)

    def scatter_one(xg, fi):
        buf = jnp.zeros((E * capacity, D), x.dtype)
        return buf.at[fi].set(xg, mode="drop")

    xbuf = jax.vmap(scatter_one)(xg_, flat_idx)                   # (G, E*C, D)
    xbuf = xbuf.reshape(G, E, capacity, D)
    dispatch_kind = dist_act.DISPATCH_SERVE if serving else dist_act.DISPATCH_ECD
    xbuf = dist_act.shard(xbuf, dispatch_kind)
    ad = accum_dtype
    if swiglu:
        g = jnp.einsum("gecd,edh->gech", xbuf, params["leaf_wg"],
                       preferred_element_type=ad)
        u = jnp.einsum("gecd,edh->gech", xbuf, params["leaf_wu"],
                       preferred_element_type=ad)
        yg = jnp.einsum("gech,eho->geco", jax.nn.silu(g) * u,
                        params["leaf_wd"], preferred_element_type=ad)
    else:
        h = jnp.einsum("gecd,edh->gech", xbuf, params["leaf_w1"],
                       preferred_element_type=ad)
        if "leaf_b1" in params:
            h = h + params["leaf_b1"][None, :, None].astype(ad)
        h = utils.get_activation(activation)(h)
        yg = jnp.einsum("gech,eho->geco", h, params["leaf_w2"],
                        preferred_element_type=ad)
        if "leaf_b2" in params:
            yg = yg + params["leaf_b2"][None, :, None].astype(ad)
    yg = dist_act.shard(yg, dispatch_kind)
    O = yg.shape[-1]

    def gather_one(yb, fi, kp):
        out = jnp.take(yb.reshape(E * capacity, O), fi, axis=0)
        return jnp.where(kp[:, None], out, 0.0)

    y = jax.vmap(gather_one)(yg, flat_idx, kept)                  # (G, Bg, O)
    if return_kept:
        return y.reshape(B, O), kept.reshape(B)
    return y.reshape(B, O)


def leaf_histogram(leaf_idx: jax.Array, num_leaves: int) -> jax.Array:
    """Load histogram over leaves; FFF needs no balancing loss (regions are
    learned geometrically) but serving wants visibility into skew."""
    return jnp.bincount(leaf_idx.reshape(-1), length=num_leaves)


def routing_skew(leaf_idx: jax.Array, num_leaves: int) -> jax.Array:
    """max-load / mean-load; 1.0 = perfectly balanced."""
    h = leaf_histogram(leaf_idx, num_leaves).astype(jnp.float32)
    return h.max() / jnp.maximum(h.mean(), 1e-9)
