"""Dispatch-locality curve for the expert-parallel ``grouped_ep`` serving
backend (DESIGN.md §5): tokens/s and cross-shard bytes moved vs. model-axis
shard count.

Each shard count runs in a SUBPROCESS with 8 forced host devices (the main
process keeps the real single CPU device, same constraint as
tests/test_sharding.py); the mesh is (8/M data, M model) so the device count
is constant across the sweep and only the dispatch locality changes.  M = 1
is the shard-local ``grouped`` baseline (zero cross-shard dispatch bytes).

Timing caveat as everywhere in benchmarks/: CPU wall-clock of the same XLA
programs — the locality TREND (bytes moved growing with (M-1)/M, per-shard
capacity shrinking with 1/M) is the product, not TPU latencies.
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEVICES = 8
BATCH, DIM, DEPTH, LEAF = 2048, 128, 5, 16       # E = 32 leaves
CAPACITY_FACTOR = 1.25

_WORKER = textwrap.dedent("""
    import jax, jax.numpy as jnp, numpy as np
    from benchmarks import common
    from repro.core import api, fff
    from repro.distributed import act, sharding
    from repro.launch import mesh as mesh_lib

    M = {m}
    cfg = fff.FFFConfig(dim_in={dim}, dim_out={dim}, depth={depth},
                        leaf_width={leaf}, activation="gelu", leaf_bias=False)
    params = fff.init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), ({batch}, {dim}))
    backend = "grouped_ep" if M > 1 else "grouped"
    spec = api.ExecutionSpec(mode="infer", backend=backend,
                             capacity_factor={cf})
    mesh = mesh_lib.make_serving_mesh(M)
    rules = sharding.activation_rules(mesh)
    p_sh = sharding.shard_params(params, mesh, fsdp=False)
    with act.use_mesh(mesh, rules):
        f = jax.jit(lambda p, xx: api.apply(p, cfg, xx, spec)[0])
        us, std = common.time_fn(f, p_sh, x, iters={iters}, warmup=2)
    print(f"RESULT,{{us:.1f}},{{std:.1f}}")
""")


def run(ms: list[int], quick: bool = False) -> list[dict]:
    from repro.distributed import dispatch as dispatch_lib

    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={DEVICES}"
    env["PYTHONPATH"] = REPO + os.pathsep + os.path.join(REPO, "src")
    E = 2 ** DEPTH
    rows = []
    for m in ms:
        code = _WORKER.format(m=m, dim=DIM, depth=DEPTH, leaf=LEAF,
                              batch=BATCH, cf=CAPACITY_FACTOR,
                              iters=5 if quick else 15)
        out = subprocess.run([sys.executable, "-c", code], env=env, cwd=REPO,
                             capture_output=True, text=True, timeout=560)
        if out.returncode != 0:
            raise RuntimeError(f"M={m} worker failed:\n{out.stderr[-2000:]}")
        us = float(out.stdout.strip().rsplit("RESULT,", 1)[1].split(",")[0])
        # per-(source shard, leaf) capacity and the a2a round-trip bytes that
        # actually leave each shard — the locality cost the curve is about.
        # G*M == DEVICES throughout, so tokens-per-shard is constant and the
        # sweep isolates dispatch locality from arithmetic.
        tokens_per_shard = BATCH // DEVICES
        cap = dispatch_lib.ep_capacity(tokens_per_shard, E, CAPACITY_FACTOR)
        moved = (dispatch_lib.ep_bytes_moved(E, m, DIM, DIM, cap)
                 if m > 1 else 0)
        # overflow-policy traffic accounting (DESIGN.md §14): exact_dense
        # pays a worst-case repair round on top of the two all_to_alls;
        # master_leaf / drop statically omit it
        repair_exact = (dispatch_lib.ep_bytes_moved(
            E, m, DIM, DIM, cap, overflow_policy="exact_dense",
            tokens_per_shard=tokens_per_shard) - moved if m > 1 else 0)
        repair_master = (dispatch_lib.ep_bytes_moved(
            E, m, DIM, DIM, cap, overflow_policy="master_leaf",
            tokens_per_shard=tokens_per_shard) - moved if m > 1 else 0)
        rows.append(dict(m=m, us=us, tokens_per_s=BATCH / (us * 1e-6),
                         capacity=cap, bytes_moved=moved,
                         repair_bytes_exact_dense=repair_exact,
                         repair_bytes_master_leaf=repair_master))
    return rows


def main(quick: bool = True):
    ms = [1, 2, 4] if quick else [1, 2, 4, 8]
    rows = run(ms, quick=quick)
    print("name,us_per_call,derived")
    for r in rows:
        print(f"ep_dispatch/model_shards_{r['m']},{r['us']:.1f},"
              f"tokens_per_s={r['tokens_per_s']:.0f};"
              f"per_shard_capacity={r['capacity']};"
              f"bytes_moved_per_shard={r['bytes_moved']};"
              f"repair_bytes_exact_dense={r['repair_bytes_exact_dense']};"
              f"repair_bytes_master_leaf={r['repair_bytes_master_leaf']}")
    # the policy gate (DESIGN.md §14): master_leaf must report ZERO repair
    # bytes on every sharded point while exact_dense pays a real round —
    # the static-omission claim of grouped_leaf_apply_ep, in numbers
    sharded = [r for r in rows if r["m"] > 1]
    bad = [r["m"] for r in sharded if r["repair_bytes_master_leaf"] != 0]
    assert not bad, f"master_leaf repair bytes nonzero at M={bad}"
    assert all(r["repair_bytes_exact_dense"] > 0 for r in sharded), \
        "exact_dense repair round reported as free"
    print(f"# overflow-policy gate: master_leaf repair bytes == 0 on "
          f"{len(sharded)} sharded points (exact_dense pays "
          f"{[r['repair_bytes_exact_dense'] for r in sharded]})")
    return rows


if __name__ == "__main__":
    main(quick=True)
