"""AdamW with float32 moments (params may be bf16; moments are kept f32 so
mixed-precision training is stable — the standard LLM recipe)."""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional, Union

import jax
import jax.numpy as jnp

from repro.optim.common import Optimizer

PyTree = Any
ScheduleOrFloat = Union[float, Callable[[jax.Array], jax.Array]]


class AdamWState(NamedTuple):
    step: jax.Array
    mu: PyTree     # first moment, f32
    nu: PyTree     # second moment, f32


def adamw(lr: ScheduleOrFloat, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.0) -> Optimizer:
    def lr_at(step):
        return lr(step) if callable(lr) else jnp.asarray(lr, jnp.float32)

    def init(params: PyTree) -> AdamWState:
        f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamWState(jnp.zeros((), jnp.int32),
                          jax.tree_util.tree_map(f32, params),
                          jax.tree_util.tree_map(f32, params))

    def update(grads: PyTree, state: AdamWState, params: Optional[PyTree] = None
               ) -> tuple[PyTree, AdamWState]:
        step = state.step + 1
        t = step.astype(jnp.float32)
        c1 = 1.0 - b1 ** t
        c2 = 1.0 - b2 ** t
        lr_t = lr_at(step)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * jnp.square(g)
            u = -lr_t * ((m / c1) / (jnp.sqrt(v / c2) + eps))
            if weight_decay > 0.0 and p is not None and p.ndim >= 2:
                u = u - lr_t * weight_decay * p.astype(jnp.float32)
            return u, m, v

        ps = params if params is not None else jax.tree_util.tree_map(
            lambda g: None, grads)
        flat = jax.tree_util.tree_map(upd, grads, state.mu, state.nu, ps)
        updates = jax.tree_util.tree_map(lambda t3: t3[0], flat,
                                         is_leaf=lambda x: isinstance(x, tuple))
        mu = jax.tree_util.tree_map(lambda t3: t3[1], flat,
                                    is_leaf=lambda x: isinstance(x, tuple))
        nu = jax.tree_util.tree_map(lambda t3: t3[2], flat,
                                    is_leaf=lambda x: isinstance(x, tuple))
        return updates, AdamWState(step, mu, nu)

    return Optimizer(init, update)
