"""Distribution: meshes, sharding rules, compression, fault tolerance."""
from repro.distributed import act, compression, fault, sharding, straggler
