"""Optimizers, checkpointing (incl. elastic), fault supervisor, straggler
policy, data pipeline, gradient compression."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim, utils
from repro.checkpoint import CheckpointManager, reshard_restore, save_tree
from repro.data import pipeline, synthetic, tokens
from repro.distributed import compression, fault, straggler


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------

def _rosenbrockish(p):
    return jnp.sum((p["a"] - 1.0) ** 2) + 0.5 * jnp.sum(p["b"] ** 2)


@pytest.mark.parametrize("make_opt", [
    lambda: optim.sgd(0.05, momentum=0.9),
    lambda: optim.adamw(0.05),
    lambda: optim.chain_clip(optim.adamw(0.05), 1.0),
    lambda: compression.ef_compress(optim.adamw(0.05)),
])
def test_optimizers_converge(make_opt):
    opt = make_opt()
    params = {"a": jnp.zeros((4,)), "b": jnp.ones((3,)) * 2}
    state = opt.init(params)
    for _ in range(300):
        g = jax.grad(_rosenbrockish)(params)
        u, state = opt.update(g, state, params)
        params = optim.apply_updates(params, u)
    assert float(_rosenbrockish(params)) < 1e-3


def test_adamw_bf16_params_f32_moments():
    opt = optim.adamw(0.01)
    params = {"w": jnp.ones((4,), jnp.bfloat16)}
    state = opt.init(params)
    assert state.mu["w"].dtype == jnp.float32
    g = {"w": jnp.ones((4,), jnp.bfloat16)}
    u, state = opt.update(g, state, params)
    p2 = optim.apply_updates(params, u)
    assert p2["w"].dtype == jnp.bfloat16


def test_grad_accum_matches_full_batch():
    def loss(p, batch, rng=None):
        pred = batch["x"] @ p["w"]
        return jnp.mean((pred - batch["y"]) ** 2), {}
    p = {"w": jnp.ones((8, 2))}
    batch = {"x": jax.random.normal(jax.random.PRNGKey(0), (16, 8)),
             "y": jax.random.normal(jax.random.PRNGKey(1), (16, 2))}
    g_full, _ = optim.gradient_accumulation(loss, 1)(p, batch)
    g_micro, _ = optim.gradient_accumulation(loss, 4)(p, batch)
    np.testing.assert_allclose(np.asarray(g_full["w"]),
                               np.asarray(g_micro["w"]), rtol=1e-5, atol=1e-6)


def test_schedules():
    s = optim.cosine_warmup(1.0, 10, 100)
    assert float(s(jnp.array(0))) == 0.0
    assert float(s(jnp.array(10))) == pytest.approx(1.0)
    assert float(s(jnp.array(100))) == pytest.approx(0.1, rel=1e-2)
    ph = optim.plateau_halving(0.2, patience=2)
    lrs = [ph.step(0.5), ph.step(0.5), ph.step(0.5), ph.step(0.6)]
    assert lrs[-2] == 0.1 and lrs[-1] == 0.1


# ---------------------------------------------------------------------------
# checkpointing + elastic
# ---------------------------------------------------------------------------

def _state():
    return {"params": {"w": jnp.arange(12.0).reshape(3, 4),
                       "b": jnp.ones((4,), jnp.bfloat16)},
            "step": jnp.zeros((), jnp.int32)}


def test_checkpoint_roundtrip_bitexact():
    with tempfile.TemporaryDirectory() as d:
        s = _state()
        save_tree(os.path.join(d, "c"), s, step=7, meta={"note": "x"})
        from repro.checkpoint import restore_tree
        r, step, meta = restore_tree(os.path.join(d, "c"), s)
        assert step == 7 and meta["note"] == "x"
        for a, b in zip(jax.tree_util.tree_leaves(s),
                        jax.tree_util.tree_leaves(r)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
            assert a.dtype == b.dtype


def test_manager_rolling_and_async():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2, async_save=True)
        s = _state()
        for step in (1, 2, 3, 4):
            mgr.save(step, jax.tree_util.tree_map(lambda x: x + step, s))
        mgr.wait()
        assert mgr.steps() == [3, 4]
        r, step, _ = mgr.restore(s)
        assert step == 4
        np.testing.assert_allclose(np.asarray(r["params"]["w"]),
                                   np.asarray(s["params"]["w"]) + 4)


def test_elastic_reshard_restore():
    with tempfile.TemporaryDirectory() as d:
        s = _state()
        save_tree(os.path.join(d, "c"), s, step=1)
        r, step, _ = reshard_restore(os.path.join(d, "c"), s, mesh=None)
        np.testing.assert_array_equal(np.asarray(r["params"]["w"]),
                                      np.asarray(s["params"]["w"]))


def test_supervisor_restarts_from_checkpoint():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=3, async_save=False)
        sup = fault.TrainSupervisor(mgr, fault.SupervisorConfig(
            ckpt_every=2, max_restarts=3))
        fail_at = {5}

        def step_fn(s, i):
            return jax.tree_util.tree_map(lambda x: x + 1, s)

        def failure(i):
            if i in fail_at:
                fail_at.discard(i)
                return True
            return False

        res = sup.run(_state(), step_fn, 8, failure_hook=failure)
        assert res.step == 8 and res.restarts == 1
        # deterministic replay: value equals an uninterrupted run
        assert float(res.state["params"]["w"][0, 0]) == 8.0


def test_supervisor_gives_up_after_max_restarts():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=1, async_save=False)
        sup = fault.TrainSupervisor(mgr, fault.SupervisorConfig(
            ckpt_every=100, max_restarts=2))
        with pytest.raises(RuntimeError, match="restarts"):
            sup.run(_state(), lambda s, i: s, 5,
                    failure_hook=lambda i: True)


# ---------------------------------------------------------------------------
# straggler policy
# ---------------------------------------------------------------------------

def test_straggler_escalation_ladder():
    cfg = straggler.StragglerConfig(window=40, slow_factor=1.5,
                                    eject_after=5, min_history=5)
    pol = straggler.MitigationPolicy(straggler.StepTimeTracker(4, cfg))
    actions = []
    for i in range(15):
        times = [1.0, 1.0, 1.0, 2.5]
        actions.append(pol.step(times).action)
    assert "warn" in actions and actions[-1] == "eject"
    # recovered host resets the streak
    pol2 = straggler.MitigationPolicy(straggler.StepTimeTracker(2, cfg))
    for i in range(30):
        t = [1.0, 2.5 if i < 7 else 1.0]
        dec = pol2.step(t)
    assert dec.action == "none"


# ---------------------------------------------------------------------------
# compression
# ---------------------------------------------------------------------------

def test_quantize_error_bound():
    x = jax.random.normal(jax.random.PRNGKey(0), (1024,)) * 3
    q, scale = compression._quantize(x, bits=8)
    err = np.abs(np.asarray(compression._dequantize(q, scale) - x))
    assert err.max() <= float(scale) * 0.5 + 1e-6


def test_ef_compression_error_feedback_carries():
    opt = compression.ef_compress(optim.sgd(1.0))
    p = {"w": jnp.zeros((4,))}
    st = opt.init(p)
    # mixed magnitudes: the small component falls below the per-tensor
    # quantization step (1/127 of the max) and must land in the error buffer
    g = {"w": jnp.array([1.0, 1e-4, 0.0, 0.0])}
    u, st = opt.update(g, st, p)
    assert float(jnp.abs(st.error["w"]).sum()) > 0
    # after enough repeats the error feedback releases the small component
    total = jnp.zeros((4,))
    for _ in range(200):
        u, st = opt.update(g, st, p)
        total = total + u["w"]
    # accumulated update direction reflects the tiny gradient too
    assert float(-total[1]) > 0.0


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------

def test_synthetic_dataset_generalization_gap_exists():
    ds = synthetic.make("usps_like")
    assert ds.x_train.shape == (4096, 256)
    assert ds.num_classes == 10
    # train and test are different draws
    assert not np.allclose(ds.x_train[:10], ds.x_test[:10])
    # deterministic regeneration
    ds2 = synthetic.make("usps_like")
    np.testing.assert_array_equal(ds.x_train, ds2.x_train)


def test_markov_tokens_learnable_structure():
    src = tokens.MarkovTokenSource(64, seed=0)
    b = src.batch(8, 256, seed=1)
    assert b["tokens"].shape == (8, 256)
    # successor entropy is well below uniform (structure exists)
    toks = src.sample(64, 128, seed=2)
    pairs = {}
    for row in toks:
        for a, b_, c in zip(row[:-2], row[1:-1], row[2:]):
            pairs.setdefault((a, b_), []).append(c)
    branching = np.mean([len(set(v)) for v in pairs.values()
                         if len(v) >= 3])
    assert branching < 20        # uniform would approach len(v) distinct


def test_prefetcher_delivers_in_order():
    pf = pipeline.Prefetcher(lambda i: {"x": np.full((2,), i)}, depth=2)
    vals = [int(next(pf)["x"][0]) for _ in range(5)]
    pf.close()
    assert vals == [0, 1, 2, 3, 4]
