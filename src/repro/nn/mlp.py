"""FFN sites: the dispatch point for the paper's technique.

``FFNSpec.kind`` selects dense (vanilla FF), fff (fast feedforward — the
paper), or moe (noisy-top-k — the contender).  One init/forward interface so
transformer blocks are agnostic to the choice; aux losses (hardening entropy,
MoE balancing) flow out through the aux dict.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import FFNSpec
from repro.core import api, ff, fff, moe

Params = dict


def make_fff_config(spec: FFNSpec, d_model: int, *, param_dtype, accum_dtype
                    ) -> fff.FFFConfig:
    return fff.FFFConfig(
        dim_in=d_model, dim_out=d_model, depth=spec.fff_depth,
        leaf_width=spec.fff_leaf_width, node_width=spec.fff_node_width,
        activation=spec.activation, trees=spec.fff_trees,
        hardening_scale=spec.hardening_scale, leaf_bias=False,
        st_training=spec.fff_st, master_leaf=spec.fff_master_leaf,
        master_width=spec.fff_master_width,
        param_dtype=param_dtype, accum_dtype=accum_dtype)


def make_moe_config(spec: FFNSpec, d_model: int, *, param_dtype, accum_dtype
                    ) -> moe.MoEConfig:
    return moe.MoEConfig(
        dim_in=d_model, dim_out=d_model, num_experts=spec.moe_experts,
        expert_width=spec.d_ff, top_k=spec.moe_top_k,
        activation="gelu" if spec.activation == "swiglu" else spec.activation,
        bias=False, param_dtype=param_dtype, accum_dtype=accum_dtype)


def make_ff_config(spec: FFNSpec, d_model: int, *, param_dtype, accum_dtype
                   ) -> ff.FFConfig:
    return ff.FFConfig(
        dim_in=d_model, dim_out=d_model, width=spec.d_ff,
        activation=spec.activation, bias=False,
        param_dtype=param_dtype, accum_dtype=accum_dtype)


def init(key: jax.Array, spec: FFNSpec, d_model: int, *, param_dtype,
         accum_dtype) -> Params:
    kw = dict(param_dtype=param_dtype, accum_dtype=accum_dtype)
    if spec.kind == "none":
        return {}
    if spec.kind == "dense":
        return ff.init(key, make_ff_config(spec, d_model, **kw))
    if spec.kind == "fff":
        return fff.init(key, make_fff_config(spec, d_model, **kw))
    if spec.kind == "moe":
        return moe.init(key, make_moe_config(spec, d_model, **kw))
    raise ValueError(f"unknown ffn kind {spec.kind!r}")


def forward(params: Params, spec: FFNSpec, d_model: int, x: jax.Array, *,
            param_dtype, accum_dtype, train: bool = True,
            rng: Optional[jax.Array] = None,
            valid: Optional[jax.Array] = None) -> tuple[jax.Array, dict]:
    """x (..., D) -> (..., D), aux {'hardening', 'moe_aux', 'balance'}
    (scalars).

    ``valid`` (broadcastable to x's leading shape) marks phantom tokens —
    pad columns of a chunked-prefill slab, free slots of a serving decode
    batch — that capacity-bounded FFF backends must keep out of
    grouped-dispatch capacity and routing telemetry (ExecutionSpec.valid)."""
    kw = dict(param_dtype=param_dtype, accum_dtype=accum_dtype)
    zero = jnp.zeros((), jnp.float32)
    if spec.kind == "none":
        return x, {"hardening": zero, "moe_aux": zero, "balance": zero}
    if spec.kind == "dense":
        return ff.forward(params, make_ff_config(spec, d_model, **kw), x), \
            {"hardening": zero, "moe_aux": zero, "balance": zero}
    if spec.kind == "fff":
        cfg = make_fff_config(spec, d_model, **kw)
        # one entry point; backend="auto" picks the execution strategy per
        # platform/site (and the launch layer can steer it via
        # api.overrides) — see core/api.py
        y, out = api.apply(params, cfg, x, api.ExecutionSpec(
            mode="train" if train else "infer", rng=rng, valid=valid))
        if train:
            harden = spec.hardening_scale * fff.hardening_loss(out.node_probs)
            # load-balancing over soft leaf usage (DESIGN.md §14); the soft
            # node_probs exist in both the FORWARD_T and ST train paths
            balance = (spec.balance_scale
                       * fff.balance_loss(out.node_probs, cfg.depth)
                       if spec.balance_scale else zero)
        aux = {"hardening": harden.astype(jnp.float32) if train else zero,
               "moe_aux": zero,
               "balance": balance.astype(jnp.float32) if train else zero}
        if not train and api.routing_enabled():
            # serving telemetry rides the aux return (DESIGN.md §9): a side
            # list would capture scan-body tracers under scan_layers
            aux["routing"] = api.routing_stats_from(out, cfg)
        return y, aux
    if spec.kind == "moe":
        cfg = make_moe_config(spec, d_model, **kw)
        if train:
            y, aux = moe.forward(params, cfg, x, rng=rng, train=True)
            return y, {"hardening": zero,
                       "moe_aux": aux["aux_loss"].astype(jnp.float32),
                       "balance": zero}
        y, _ = moe.forward_sparse(params, cfg, x)
        return y, {"hardening": zero, "moe_aux": zero, "balance": zero}
    raise ValueError(f"unknown ffn kind {spec.kind!r}")
