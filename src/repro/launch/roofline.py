"""Roofline-term derivation from compiled dry-run artifacts (DESIGN.md §6).

CPU container, TPU v5e target: no wall clocks — the three terms come from the
compiled module itself:

  T_compute    = HLO_FLOPs / (chips * 197e12)          [bf16 MXU peak]
  T_memory     = HLO_bytes / (chips * 819e9)           [HBM]
  T_collective = sum(bytes moved per collective) / (chips * link_bw)
                 ICI 50 GB/s; pod-axis (DCN) hops at 25 GB/s

HLO_FLOPs / bytes come from ``compiled.cost_analysis()``; collective bytes are
parsed from the post-SPMD optimized HLO (``compiled.as_text()``) by summing
result-shape bytes of all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute, with ring-algorithm byte multipliers and a DCN heuristic
(group reaching across the 256-device pod boundary).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

import numpy as np

from repro.configs.base import ModelConfig, ShapeSpec

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link
DCN_BW = 25e9                # bytes/s cross-pod

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0,
}

_COLL_RE = re.compile(
    r"=\s*(?P<shape>\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s*"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=(\{\{[^}]*\}[^}]*\}|\[[0-9,]+\]<=\[[0-9,]+\][T0-9,()]*)")


@dataclasses.dataclass
class CollectiveStats:
    op: str
    bytes_result: int
    group_size: int
    crosses_pod: bool
    count: int = 1

    @property
    def bytes_moved(self) -> float:
        """Ring-algorithm bytes per participant."""
        n = max(self.group_size, 1)
        frac = (n - 1) / n
        if self.op == "all-reduce":
            return 2 * self.bytes_result * frac
        if self.op in ("all-gather", "reduce-scatter", "all-to-all"):
            return self.bytes_result * frac
        return self.bytes_result        # collective-permute


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _parse_groups(line: str, pod_size: int = 256) -> tuple[int, bool]:
    """(group_size, crosses_pod)."""
    m = _GROUPS_RE.search(line)
    if not m:
        return 1, False
    g = m.group(1)
    if g.startswith("{{"):
        first = g[2:].split("}")[0]
        ids = [int(v) for v in first.split(",") if v.strip()]
        size = len(ids)
        crosses = (max(ids) // pod_size) != (min(ids) // pod_size) if ids else False
        return size, crosses
    # iota format: [d0,d1,...]<=[N](T(perm))?
    dims = [int(v) for v in g[1:g.index("]")].split(",")]
    n_total = int(re.search(r"<=\[([0-9,]+)\]", g).group(1).split(",")[0])
    size = dims[-1] if len(dims) > 1 else dims[0]
    transposed = "T(" in g
    if transposed:
        # permuted groups stride across the device space; if the stride
        # reaches past a pod, it is a DCN collective
        stride = n_total // size if size else 1
        crosses = stride >= pod_size and n_total > pod_size
    else:
        crosses = size > pod_size
    return size, crosses


def parse_collectives(hlo_text: str, pod_size: int = 256
                      ) -> list[CollectiveStats]:
    out: dict[tuple, CollectiveStats] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        op = m.group("op")
        nbytes = _shape_bytes(m.group("shape"))
        size, crosses = _parse_groups(line, pod_size)
        key = (op, nbytes, size, crosses)
        if key in out:
            out[key].count += 1
        else:
            out[key] = CollectiveStats(op, nbytes, size, crosses)
    return list(out.values())


@dataclasses.dataclass
class RooflineTerms:
    flops: float
    bytes_hbm: float
    bytes_ici: float
    bytes_dcn: float
    chips: int
    t_compute: float
    t_memory: float
    t_collective: float
    model_flops: float
    useful_ratio: float

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def roofline_fraction(self) -> float:
        """useful model FLOPs / (chips * peak * max-term) — the score."""
        t = max(self.t_compute, self.t_memory, self.t_collective)
        if t <= 0:
            return 0.0
        return self.model_flops / (self.chips * PEAK_FLOPS * t)


def analyze_terms(flops: float, bytes_hbm: float,
                  colls: list[tuple[CollectiveStats, int]], chips: int,
                  model_flops: float) -> RooflineTerms:
    """flops/bytes are per-device; colls carry a repetition multiplier
    (scan trip count) per stat."""
    bytes_ici = sum(c.bytes_moved * c.count * mult
                    for c, mult in colls if not c.crosses_pod)
    bytes_dcn = sum(c.bytes_moved * c.count * mult
                    for c, mult in colls if c.crosses_pod)
    t_compute = flops / PEAK_FLOPS
    t_memory = bytes_hbm / HBM_BW
    t_collective = bytes_ici / ICI_BW + bytes_dcn / DCN_BW
    useful = model_flops / max(flops * chips, 1.0)
    return RooflineTerms(flops, bytes_hbm, bytes_ici, bytes_dcn, chips,
                         t_compute, t_memory, t_collective, model_flops,
                         useful)


def analyze(cost: dict, hlo_text: str, chips: int, model_flops: float
            ) -> RooflineTerms:
    """Single-compile variant (no trip-count correction) — used for
    components that are not inside a scan."""
    flops = float(cost.get("flops", 0.0))
    bytes_hbm = float(cost.get("bytes accessed", 0.0))
    colls = [(c, 1) for c in parse_collectives(hlo_text)]
    return analyze_terms(flops, bytes_hbm, colls, chips, model_flops)


# ---------------------------------------------------------------------------
# MODEL_FLOPS: 6*N*D (train) / 2*N*D (inference), N = *active* params
# ---------------------------------------------------------------------------

def param_counts(cfg: ModelConfig, total_params: int, mode: str = "decode"
                 ) -> tuple[int, int]:
    """(total, effective) parameter counts.  ``total_params`` comes from the
    eval_shape struct (exact); inactive mass is the conditional FFN width the
    pass never touches: MoE non-selected experts, FFF non-selected leaves.

    Mode matters for FFF and mirrors the ``core.api.ExecutionSpec`` backend
    split: faithful FORWARD_T training (the ``train``/``reference`` backend)
    evaluates *all* leaves — they all receive gradient, so that compute is
    useful by the paper's semantics — while ST-trained sites
    (``train``/``grouped``) and every ``infer`` backend touch only the routed
    leaf/forest (DESIGN.md §6)."""
    inactive = 0
    n_periods = cfg.n_layers // len(cfg.period)
    for spec in cfg.period:
        f = spec.ffn
        kk = 3 if f.activation == "swiglu" else 2
        if f.kind == "moe" or (f.kind == "fff"
                               and (mode != "train" or f.fff_st)):
            inactive += (f.training_width - f.active_width) * kk \
                * cfg.d_model * n_periods
    return total_params, total_params - inactive


def attention_model_flops(cfg: ModelConfig, shape: ShapeSpec) -> float:
    """Quadratic attention term of useful model FLOPs (PaLM MFU convention).

    Without it, useful-compute ratios are meaningless for small-param models
    at long context (olmoe@4k measured 250:1 attention:FFN — §Perf iter 1)."""
    n_attn = sum(1 for b in cfg.period if b.mixer == "attn") \
        * (cfg.n_layers // len(cfg.period))
    if cfg.encoder is not None and shape.mode != "decode":
        pass  # encoder attention added below
    H, hd = cfg.n_heads, cfg.resolved_head_dim
    window = min((b.sliding_window or shape.seq_len)
                 for b in cfg.period if b.mixer == "attn") \
        if n_attn else 0
    if shape.mode == "decode":
        ctx = min(shape.seq_len, window or shape.seq_len)
        per_token = 2 * 2 * ctx * H * hd              # qk + pv vs full cache
        tokens = shape.global_batch
        factor = 1.0
    else:
        s_eff = min(shape.seq_len, window or shape.seq_len)
        # causal lower-triangle average context = s_eff/2
        per_token = 2 * 2 * (s_eff / 2) * H * hd
        tokens = shape.global_batch * shape.seq_len
        factor = 3.0 if shape.mode == "train" else 1.0
    total = factor * n_attn * per_token * tokens
    if cfg.encoder is not None and shape.mode != "decode":
        enc_tokens = shape.global_batch * cfg.encoder.seq_len
        total += factor * cfg.encoder.n_layers * 2 * 2 * cfg.encoder.seq_len \
            * H * hd * enc_tokens / 2
    return total


def model_flops(cfg: ModelConfig, shape: ShapeSpec, total_params: int,
                embed_params: int = 0) -> float:
    """6*N*D (train) / 2*N*D (inference) over *effective* params, plus the
    quadratic attention term (PaLM MFU convention)."""
    _, eff = param_counts(cfg, total_params, shape.mode)
    n = eff - embed_params
    tokens = shape.global_batch * (shape.seq_len if shape.mode != "decode"
                                   else 1)
    factor = 6.0 if shape.mode == "train" else 2.0
    return factor * n * tokens + attention_model_flops(cfg, shape)
