"""Small shared utilities used across the repro framework."""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Iterable, Mapping

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


# ---------------------------------------------------------------------------
# pytree helpers
# ---------------------------------------------------------------------------

def tree_size(tree: PyTree) -> int:
    """Total number of scalar parameters in a pytree."""
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(tree))


def tree_bytes(tree: PyTree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree_util.tree_leaves(tree))


def tree_cast(tree: PyTree, dtype) -> PyTree:
    return jax.tree_util.tree_map(lambda x: x.astype(dtype), tree)


def tree_zeros_like(tree: PyTree) -> PyTree:
    return jax.tree_util.tree_map(jnp.zeros_like, tree)


def tree_finite(tree: PyTree) -> jax.Array:
    """True iff every leaf of the tree is finite everywhere."""
    leaves = [jnp.all(jnp.isfinite(x)) for x in jax.tree_util.tree_leaves(tree)]
    return jnp.all(jnp.stack(leaves))


def tree_global_norm(tree: PyTree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


# ---------------------------------------------------------------------------
# PRNG helpers
# ---------------------------------------------------------------------------

def split_like(key: jax.Array, tree: PyTree) -> PyTree:
    """One fresh key per leaf of `tree`, arranged in the same structure."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    keys = jax.random.split(key, len(leaves))
    return jax.tree_util.tree_unflatten(treedef, list(keys))


# ---------------------------------------------------------------------------
# shape / math helpers
# ---------------------------------------------------------------------------

def next_pow2(x: int) -> int:
    return 1 if x <= 1 else 2 ** math.ceil(math.log2(x))


def is_pow2(x: int) -> bool:
    return x > 0 and (x & (x - 1)) == 0


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    return cdiv(a, b) * b


def flatten_leading(x: jax.Array) -> tuple[jax.Array, tuple[int, ...]]:
    """Collapse all leading dims of (..., D) into one batch dim."""
    lead = x.shape[:-1]
    return x.reshape(-1, x.shape[-1]), lead


def unflatten_leading(x: jax.Array, lead: tuple[int, ...]) -> jax.Array:
    return x.reshape(*lead, x.shape[-1])


# ---------------------------------------------------------------------------
# initializers (no flax in this environment)
# ---------------------------------------------------------------------------

def lecun_normal(key, shape, dtype=jnp.float32, fan_in_axis: int = -2) -> jax.Array:
    fan_in = shape[fan_in_axis]
    std = 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape) * std).astype(dtype)


def he_normal(key, shape, dtype=jnp.float32, fan_in_axis: int = -2) -> jax.Array:
    fan_in = shape[fan_in_axis]
    std = math.sqrt(2.0 / fan_in)
    return (jax.random.normal(key, shape) * std).astype(dtype)


def truncated_init(key, shape, std, dtype=jnp.float32) -> jax.Array:
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape) * std).astype(dtype)


def zeros_init(_key, shape, dtype=jnp.float32) -> jax.Array:
    return jnp.zeros(shape, dtype)


# ---------------------------------------------------------------------------
# dataclass config plumbing
# ---------------------------------------------------------------------------

def replace(cfg, **kw):
    return dataclasses.replace(cfg, **kw)


def asdict_shallow(cfg) -> dict:
    return {f.name: getattr(cfg, f.name) for f in dataclasses.fields(cfg)}


ACTIVATIONS: Mapping[str, Callable[[jax.Array], jax.Array]] = {
    "relu": jax.nn.relu,
    "gelu": jax.nn.gelu,
    "silu": jax.nn.silu,
    "tanh": jnp.tanh,
    "identity": lambda x: x,
}


def get_activation(name: str) -> Callable[[jax.Array], jax.Array]:
    if name not in ACTIVATIONS:
        raise ValueError(f"unknown activation {name!r}; have {sorted(ACTIVATIONS)}")
    return ACTIVATIONS[name]


def human_bytes(n: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB", "TiB"):
        if abs(n) < 1024.0:
            return f"{n:.2f}{unit}"
        n /= 1024.0
    return f"{n:.2f}PiB"


def human_flops(n: float) -> str:
    for unit in ("F", "KF", "MF", "GF", "TF"):
        if abs(n) < 1e3:
            return f"{n:.2f}{unit}"
        n /= 1e3
    return f"{n:.2f}PF"
