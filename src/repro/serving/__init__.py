"""Continuous-batching serving engine with FFF leaf-occupancy-aware
scheduling, multi-tenant QoS admission and online per-tenant routing
profiles (DESIGN.md §9)."""
from repro.serving.engine import ContinuousBatchingEngine, EngineConfig, \
    TenantQueues
from repro.serving.metrics import EngineMetrics, LatencySummary, summarize, \
    tenant_breakdown, tokens_per_second
from repro.serving.profiles import RoutingProfileStore, TenantProfile
from repro.serving.request import Request, RequestResult
from repro.serving.scheduler import SCHEDULERS, FCFSScheduler, \
    LeafAwareScheduler, Scheduler, SchedulerView, \
    WeightedLeafAwareScheduler, make_scheduler

__all__ = [
    "ContinuousBatchingEngine", "EngineConfig", "EngineMetrics",
    "LatencySummary", "summarize", "tenant_breakdown", "tokens_per_second",
    "Request", "RequestResult", "RoutingProfileStore", "TenantProfile",
    "TenantQueues",
    "SCHEDULERS", "FCFSScheduler", "LeafAwareScheduler", "Scheduler",
    "SchedulerView", "WeightedLeafAwareScheduler", "make_scheduler",
]
