"""Paper Table 1: FFF vs FF across training widths and leaf sizes.

Grid: widths w in {16, 32, 64, 128}, FFF leaf sizes l in {8, 4, 2, 1} (depth
log2(w/l)), datasets usps_like / mnist_like / fashion_like (synthetic proxies,
see data/synthetic.py).  Reports M_A (memorization: train-set accuracy of an
overfit run), G_A (test accuracy of the best-validation model), and speedup
(FF inference time / FFF hard-inference time at the same training width).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import api
from repro.data import synthetic

WIDTHS = (16, 32, 64, 128)
LEAVES = (8, 4, 2, 1)
DATASETS = ("usps_like", "mnist_like", "fashion_like")


def run(steps: int = 200, quick: bool = False) -> list[dict]:
    rows = []
    widths = WIDTHS[:2] if quick else WIDTHS
    leaves = LEAVES[:2] if quick else LEAVES
    datasets = DATASETS[:1] if quick else DATASETS
    for ds_name in datasets:
        ds = synthetic.make(ds_name)
        xb = jnp.asarray(ds.x_test[:512])
        for w in widths:
            # vanilla FF baseline
            cfg_ff, p_ff, tr_ff, fw_ff = common.build_ff(ds.dim,
                                                         ds.num_classes, w)
            p_ff, _ = common.train_classifier(tr_ff, p_ff, ds, steps=steps)
            ma_ff = common.accuracy(fw_ff, p_ff, ds.x_train[:2048],
                                    ds.y_train[:2048])
            ga_ff = common.accuracy(fw_ff, p_ff, ds.x_test, ds.y_test)
            t_ff, _ = common.time_fn(jax.jit(fw_ff), p_ff, xb)
            rows.append(dict(dataset=ds_name, model="ff", width=w, leaf=0,
                             ma=ma_ff, ga=ga_ff, us=t_ff, speedup=1.0))
            for leaf in leaves:
                if leaf > w:
                    continue
                depth = int(np.log2(w // leaf))
                cfg, p, tr, fw = common.build_fff(ds.dim, ds.num_classes,
                                                  depth, leaf)
                p, _ = common.train_classifier(tr, p, ds, steps=steps)
                ma = common.accuracy(fw, p, ds.x_train[:2048],
                                     ds.y_train[:2048])
                ga = common.accuracy(fw, p, ds.x_test, ds.y_test)
                # pin the exact gather so the speedup column times the
                # paper's FORWARD_I mechanism on every platform (cf. fig34)
                with api.use_backend("reference"):
                    t, _ = common.time_fn(jax.jit(fw), p, xb)
                rows.append(dict(dataset=ds_name, model="fff", width=w,
                                 leaf=leaf, ma=ma, ga=ga, us=t,
                                 speedup=t_ff / t))
    return rows


def main(quick: bool = True):
    rows = run(steps=120 if quick else 400, quick=quick)
    print("name,us_per_call,derived")
    for r in rows:
        name = f"table1/{r['dataset']}/{r['model']}_w{r['width']}_l{r['leaf']}"
        print(f"{name},{r['us']:.1f},"
              f"ma={r['ma']:.3f};ga={r['ga']:.3f};speedup={r['speedup']:.2f}x")
    return rows


if __name__ == "__main__":
    main(quick=False)
