"""Continuous-batching serving engine (DESIGN.md §9, §11).

Owns a request queue, an admission scheduler, a PAGED KV-cache pool with a
host-side page allocator + prefix index (``serving/paging.py``), and
interleaved prefill/decode over FIXED compiled shapes:

* KV state lives in a fixed page pool (``EngineConfig.page_size`` tokens
  per page) indexed through per-slot page tables; admission installs a
  slot's table in one batched dispatch (``lm.cache_admit``) and eviction is
  pure host-side refcount bookkeeping — no device work at all.
* Admissions consult a radix prefix index over token ids: a prompt whose
  leading full pages are already cached maps them read-only (refcounted,
  shared across slots) and prefills ONLY the novel suffix — the
  shared-system-prompt workload prefill drops from O(prompt) to O(suffix).
  A prompt fully covered by shared pages copy-on-writes its last matched
  page so the final token re-prefills privately (first-token logits need
  it, and shared pages are never written).
* The decode batch is always ``(num_slots, 1)`` — free slots decode a dummy
  token whose output is ignored and never written — so the decode step
  compiles exactly once.
* Prompts prefill as chunk slabs right-padded to a small static set of
  *buckets* (powers of two up to ``max_prompt_len``), each bucket compiling
  once; only the admitted row of the ``(num_slots, bucket)`` slab is valid.
* With ``EngineConfig.prefill_chunk > 0`` prefill is CHUNKED instead: every
  in-flight prefill advances together through one fixed
  ``(num_slots, prefill_chunk)`` slab per dispatch (``lm.prefill_chunk`` —
  one more compiled shape, total), at most ``prefill_budget`` dispatches per
  engine step, interleaved with decode — a long prompt's admission never
  stalls in-flight decode latency by more than the budgeted chunk work
  (DESIGN.md §9).  Decode steps mask cache writes for mid-prefill slots.
* Requests enter with prompt + sampling/stop params, decode together until
  EOS/max-tokens, then free their slot for waiting requests (their pages'
  refcounts drop; pages the prefix index still holds stay warm for future
  admissions until ``PrefixIndex.reclaim`` evicts them under pressure).
* With ``EngineConfig.spec_k > 0`` the decode step becomes a SPECULATIVE
  draft/verify round (DESIGN.md §10): ONE fused dispatch rolls out
  ``spec_k`` draft proposals per live slot (default draft: the target's own
  first period — ``serving/spec.py``) and verifies the ``(num_slots,
  spec_k + 1)`` slab with the target, then host-side rejection sampling
  emits 1..spec_k + 1 tokens with the target distribution preserved
  exactly.  Draft KV lives in a second pooled cache tree alongside the
  target's; both trees prefill/evict/truncate in the same dispatches as
  the target's.

Admission policy is pluggable (``serving/scheduler.py``); ``leaf_aware``
consumes the per-step FFF leaf-occupancy telemetry the engine collects via
``core/api.collect_routing`` to compose microbatches that minimize grouped-
dispatch capacity overflow, and ``weighted_leaf_aware`` adds weighted-fair
admission across ``Request.tenant`` classes (the queue keeps per-tenant FIFO
views — ``TenantQueues``).  Finished requests promote their measured leaf
occupancy into an online per-tenant ``RoutingProfileStore``
(``serving/profiles.py``), so hint-less tenants self-calibrate after their
first completions.

The engine is mesh-agnostic: pass ``trace_ctx`` (e.g. the launch layer's
``act.use_mesh`` wrapper) and every jitted call traces under it, so the same
loop serves single-device and expert-parallel (``grouped_ep``) topologies.
Sampling is host-side numpy (deterministic under ``EngineConfig.seed``).
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
import warnings
from collections import deque
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import api
from repro.models import lm
from repro.serving import metrics as metrics_lib
from repro.serving import paging
from repro.serving import spec as spec_lib
from repro.serving.profiles import RoutingProfileStore
from repro.serving.request import Request, RequestResult, SlotState
from repro.serving.scheduler import Scheduler, SchedulerView, make_scheduler


class TenantQueues:
    """The engine's waiting queue: arrival order globally, FIFO per tenant.

    Schedulers receive the arrival-ordered view (``list(queue)``) — FCFS and
    ``leaf_aware`` never notice tenants exist — while QoS policies and the
    per-tenant metrics read the ``per_tenant`` map.  ``remove`` is identity-
    based (Request is eq=False), matching the admission path's contract."""

    def __init__(self):
        self._order: List[Request] = []
        self.per_tenant: Dict[str, deque] = {}

    def append(self, req: Request) -> None:
        self._order.append(req)
        self.per_tenant.setdefault(req.tenant, deque()).append(req)

    def remove(self, req: Request) -> None:
        self._order.remove(req)
        q = self.per_tenant[req.tenant]
        q.remove(req)
        if not q:
            del self.per_tenant[req.tenant]

    def depth(self, tenant: str) -> int:
        return len(self.per_tenant.get(tenant, ()))

    def __len__(self):
        return len(self._order)

    def __bool__(self):
        return bool(self._order)

    def __iter__(self):
        return iter(self._order)


class VirtualClock:
    """Deterministic engine/cluster clock for tests and the in-process
    ``cluster.LocalBus``: reading it never blocks and time only moves when
    the driver says so (``advance``), so heartbeat/timeout/elastic logic
    runs wall-time-free (ISSUE 8).  Inject via
    ``ContinuousBatchingEngine(..., clock=vc)`` — the engine detects the
    ``advance`` method and jumps straight to the next pending arrival
    instead of sleeping when idle."""

    def __init__(self, start: float = 0.0):
        self._t = float(start)

    def __call__(self) -> float:
        return self._t

    def advance(self, dt: float) -> float:
        if dt < 0:
            raise ValueError(f"cannot advance a clock by {dt}")
        self._t += float(dt)
        return self._t


def _pow2_buckets(lo: int, hi: int) -> Tuple[int, ...]:
    out, b = [], lo
    while b < hi:
        out.append(b)
        b *= 2
    out.append(hi)
    return tuple(sorted(set(out)))


@dataclasses.dataclass
class EngineConfig:
    """Engine shape/policy knobs.  ``max_len`` bounds prompt + generation per
    slot (the pooled cache's sequence axis); ``prefill_buckets`` is the
    static set of compiled prompt shapes (default: powers of two from 16 up
    to ``max_prompt_len``).

    ``prefill_chunk``: 0 = monolithic prefill (one bucket-padded dispatch
    per admission, between decode steps); > 0 = chunked prefill — prompts
    advance ``prefill_chunk`` tokens at a time through a shared
    ``(num_slots, prefill_chunk)`` slab, at most ``prefill_budget`` slab
    dispatches per engine step.  Must be a power of two <= max_prompt_len
    (the slab is one fixed compiled shape from the same pow2 family as the
    buckets).  Smaller chunks / budget bound each step's admission work
    tighter (decode p99) at the cost of slower admission (TTFT); the
    scheduler-side ``max_prefilling`` knob caps how many slots prefill
    concurrently (see serving/scheduler.py)."""
    num_slots: int = 8
    max_len: int = 128
    max_prompt_len: int = 64
    prefill_buckets: Tuple[int, ...] = ()
    max_prefills_per_step: int = 2
    prefill_chunk: int = 0
    prefill_budget: int = 1
    scheduler: str = "fcfs"
    scheduler_kw: dict = dataclasses.field(default_factory=dict)
    fff_backend: str = "auto"            # api.use_backend override, "auto" = none
    # fused decode megakernel (DESIGN.md §13): steer the DECODE dispatch
    # (and the draft rollout's seq-len-1 steps under spec_k) to the
    # ("infer", "pallas_decode") backend — routing + selected-leaf MLP +
    # combine in ONE pl.pallas_call instead of three.  Decode-only by
    # design: prefill/verify slabs keep fff_backend's resolution.  The
    # backend's supports predicate still applies (kernel-ineligible sites
    # and EP meshes fall through to the normal auto heuristics), so the
    # flag degrades gracefully rather than crashing a sharded engine.
    pallas_decode: bool = False
    # capacity factor: None = the configured backend's dispatch default.  A
    # value steers the LIVE dispatch (installed as the trace-time capacity
    # override via api.overrides — cf < 1.0 deliberately under-provisions
    # per-leaf capacity) and doubles as the scheduler's overflow proxy.
    capacity_factor: Optional[float] = None
    # overflow policy (DESIGN.md §14): what a capacity-bounded dispatch does
    # with over-capacity tokens — "exact_dense" (dense gather repair),
    # "master_leaf" (approximate: the always-on master term stands in alone;
    # requires FFF sites built with fff_master_leaf), "drop" (zeros).
    # None = the configured backend's default (api.default_overflow_policy).
    overflow_policy: Optional[str] = None
    telemetry: bool = True               # collect FFF routing stats
    occupancy_ewma: float = 0.5
    # online per-tenant routing profiles (serving/profiles.py): finished
    # requests' occupancy EWMA promotes into a per-tenant footprint that
    # seeds hint-less admissions — leaf_hint becomes optional/self-calibrating
    learn_profiles: bool = True
    profile_ewma: float = 0.3            # per-finished-request smoothing
    profile_min_updates: int = 1         # finished requests before serving
    # speculative decoding (DESIGN.md §10): spec_k > 0 replaces the decode
    # step with a draft/verify round — a draft model proposes spec_k tokens
    # per live slot in ONE fused rollout dispatch, the target verifies the
    # (num_slots, spec_k + 1) slab in one chunk dispatch, host-side
    # rejection sampling keeps the target distribution exact.
    # ``draft_config``: "self" / "self:N" = the target's own first N periods
    # (early-exit self-draft, shares params); a registry arch id = an
    # independent reduced draft (random init — correctness testing / a slot
    # for trained drafts); None = "self" (see serving/spec.build_draft).
    spec_k: int = 0
    draft_config: Optional[str] = None
    # paged KV cache (DESIGN.md §11): page_size 0 = one max_len-sized page
    # per slot (the contiguous layout, bit-for-bit) — prefix sharing is
    # structurally off there (no prompt ever fills a max_len page).
    # page_size > 0 carves the pool into fixed pages; num_pages 0 = auto
    # (num_slots * ceil(max_len / page_size), the contiguous footprint).
    # prefix_sharing gates the radix index — admission-time page REUSE —
    # independently of the paged layout itself.
    page_size: int = 0
    num_pages: int = 0
    prefix_sharing: bool = True
    # LRU cap on the per-tenant routing-profile store: an open multi-tenant
    # endpoint sees unbounded distinct tenant ids, and each profile row is
    # O(num_leaves) forever — cap generously and evict least-recently-
    # updated (warn-once on first eviction)
    profile_max_tenants: int = 1024
    seed: int = 0

    def buckets(self) -> Tuple[int, ...]:
        if self.prefill_buckets:
            return tuple(sorted(set(self.prefill_buckets)))
        return _pow2_buckets(min(16, self.max_prompt_len), self.max_prompt_len)



class ContinuousBatchingEngine:
    """Continuous-batching serving loop (module docstring has the design).

    Args:
        params:    the LM parameter tree (``lm.init``), possibly sharded.
        cfg:       the ``ModelConfig`` — decoder-only, attention mixers.
        ecfg:      engine shape/policy knobs (``EngineConfig``).
        scheduler: an admission ``Scheduler`` instance; default builds one
                   from ``ecfg.scheduler`` / ``ecfg.scheduler_kw``.
        trace_ctx: optional zero-arg context-manager factory entered around
                   every jitted call (e.g. ``launch/mesh.serving_context``'s
                   wrapper installing the SPMD mesh).

    Drive it either with ``run(requests)`` (serve a workload to completion,
    returns results + ``EngineMetrics``) or manually: ``submit`` then
    ``step()`` while ``has_work()``, polling ``poll_metrics()`` for live
    queue depth / latency / overflow telemetry."""

    def __init__(self, params, cfg, ecfg: EngineConfig,
                 scheduler: Optional[Scheduler] = None,
                 trace_ctx: Optional[Callable] = None,
                 draft: Optional[Tuple[dict, object]] = None,
                 mesh=None, clock: Optional[Callable[[], float]] = None):
        if cfg.encoder is not None or cfg.frontend != "none":
            raise ValueError("serving engine supports decoder-only token LMs")
        if any(b.mixer != "attn" for b in cfg.period):
            # recurrent mixers fold right-pad garbage into their state; the
            # engine's padded-prefill contract (DESIGN.md §9) needs
            # length-maskable caches
            raise ValueError("serving engine requires attention mixers "
                             "(padded prefill is length-masked, recurrent "
                             "state is not)")
        if ecfg.max_prompt_len >= ecfg.max_len:
            raise ValueError("max_prompt_len must leave room to generate "
                             "(max_prompt_len < max_len)")
        if ecfg.buckets()[-1] != ecfg.max_prompt_len:
            raise ValueError(
                f"prefill_buckets {ecfg.buckets()} must top out at "
                f"max_prompt_len {ecfg.max_prompt_len} — the two knobs "
                f"would otherwise disagree on the servable prompt length")
        if ecfg.prefill_chunk:
            c = ecfg.prefill_chunk
            if c < 1 or (c & (c - 1)):
                raise ValueError(
                    f"prefill_chunk {c} must be a power of two — the chunk "
                    f"slab is one fixed compiled shape from the same pow2 "
                    f"family as the prefill buckets (DESIGN.md §9)")
            if c > ecfg.max_prompt_len:
                raise ValueError(
                    f"prefill_chunk {c} exceeds max_prompt_len "
                    f"{ecfg.max_prompt_len}: every prompt would fit in one "
                    f"chunk — use monolithic prefill (prefill_chunk=0)")
            if ecfg.prefill_budget < 1:
                raise ValueError("prefill_budget must be >= 1 when chunked "
                                 "prefill is on")
        if ecfg.spec_k < 0:
            raise ValueError(f"spec_k {ecfg.spec_k} must be >= 0")
        if ecfg.page_size < 0 or ecfg.page_size > ecfg.max_len:
            raise ValueError(f"page_size {ecfg.page_size} must be in "
                             f"[0, max_len {ecfg.max_len}]")
        _page = ecfg.page_size or ecfg.max_len
        _ppr = -(-ecfg.max_len // _page)          # pages per slot, max
        if ecfg.num_pages and ecfg.num_pages < _ppr:
            raise ValueError(
                f"num_pages {ecfg.num_pages} cannot cover even one "
                f"max-length slot ({_ppr} pages of {_page} tokens)")
        if ecfg.draft_config is not None and not ecfg.spec_k:
            raise ValueError("draft_config is set but spec_k == 0 — "
                             "speculation is off, the draft would be dead "
                             "weight (set spec_k > 0 or drop draft_config)")
        if (ecfg.overflow_policy is not None
                and ecfg.overflow_policy not in api.OVERFLOW_POLICIES):
            raise ValueError(
                f"overflow_policy {ecfg.overflow_policy!r} not in "
                f"{api.OVERFLOW_POLICIES}")
        self.params = params
        self.cfg = cfg
        self.ecfg = ecfg
        self.num_leaves = next(
            (2 ** b.ffn.fff_depth for b in cfg.period if b.ffn.kind == "fff"),
            0)
        fff_spec = next((b.ffn for b in cfg.period if b.ffn.kind == "fff"),
                        None)
        # the first FFF site's layer config, for predicting what the auto
        # resolver will dispatch (the scheduler's capacity proxy)
        from repro.nn import mlp as mlp_lib
        self._site_cfg = None if fff_spec is None else mlp_lib.make_fff_config(
            fff_spec, cfg.d_model, param_dtype=cfg.param_dtype,
            accum_dtype=cfg.accum_dtype)
        if (ecfg.overflow_policy == "master_leaf"
                and self._site_cfg is not None
                and not self._site_cfg.master_leaf):
            # fail at construction, not at first trace: the repair term
            # does not exist in this model (DESIGN.md §14)
            raise ValueError(
                'overflow_policy="master_leaf" needs FFF sites built with '
                "fff_master_leaf=True — this model has no master term to "
                "stand in for dropped tokens")
        self.scheduler = scheduler or make_scheduler(ecfg.scheduler,
                                                     **ecfg.scheduler_kw)
        self._trace_ctx = trace_ctx
        self._topology: Optional[Tuple[int, float]] = None
        self._policy: Optional[str] = None    # set alongside _topology

        S, L = ecfg.num_slots, ecfg.max_len
        # the page pool (DESIGN.md §11): device side is a dumb pool + per-
        # slot tables (prealloc=False — all entries start at the unmapped
        # sentinel); the host-side allocator + prefix index own the mapping
        self._page = _page
        self._ppr = _ppr
        self._num_pages = ecfg.num_pages or S * _ppr
        self.pool = paging.PagePool(self._num_pages, self._page)
        self.prefix = paging.PrefixIndex(self.pool)
        self._slot_pages: List[list] = [[] for _ in range(S)]
        self._alloc_len = np.zeros((S,), np.int32)   # pages * page_size
        self._shared_len = np.zeros((S,), np.int32)  # prefix-hit boundary
        self.n_prefix_hit_tokens = 0
        self.n_cow_copies = 0
        self.n_prefill_tokens = 0
        self.caches = lm.init_caches(cfg, S, L, page_size=self._page,
                                     num_pages=self._num_pages,
                                     prealloc=False)
        # pin the pool's shardings ONCE, at allocation, under the serving
        # mesh (subsumes re-deriving cache placement per dispatch): jitted
        # cache-threading calls then see committed inputs and keep the
        # layout stable across donation round-trips
        self._mesh = mesh
        if mesh is not None:
            self.caches = self._pin_caches(self.caches, mesh)
        # speculative decoding state (spec_k > 0): the draft model's pooled
        # caches live ALONGSIDE the target's, slot-indexed identically, so
        # admission/eviction treat the pair as one unit.  _tlen/_dlen are
        # the host-authoritative cache lengths: verify appends k+1 positions
        # optimistically, host rejection decides how many survive, and the
        # NEXT rollout dispatch rolls both trees back to these (lengths are
        # metadata — the truncate costs no extra dispatch).
        self.spec = ecfg.spec_k > 0
        self.draft_params = self.draft_cfg = None
        self.draft_caches = None
        if self.spec:
            if draft is not None:
                self.draft_params, self.draft_cfg = draft
            else:
                self.draft_params, self.draft_cfg = spec_lib.build_draft(
                    ecfg.draft_config, params, cfg, seed=ecfg.seed)
            if any(b.mixer != "attn" for b in self.draft_cfg.period):
                raise ValueError("draft model requires attention mixers "
                                 "(same pooled-cache contract as the target)")
            if self.draft_cfg.vocab_size != cfg.vocab_size:
                raise ValueError(
                    f"draft vocab {self.draft_cfg.vocab_size} != target "
                    f"vocab {cfg.vocab_size}: rejection sampling compares "
                    f"the two distributions token-for-token")
            # the draft's pool mirrors the target's page geometry (same
            # allocator, same tables): draft K/V for a token is as
            # deterministic as the target's, so shared prompt pages are
            # valid for both trees
            self.draft_caches = lm.init_caches(self.draft_cfg, S, L,
                                               page_size=self._page,
                                               num_pages=self._num_pages,
                                               prealloc=False)
            if mesh is not None:
                self.draft_caches = self._pin_caches(self.draft_caches, mesh)
            self._tlen = np.zeros((S,), np.int32)   # target cache lengths
            self._dlen = np.zeros((S,), np.int32)   # draft cache lengths
        self._spec_rounds = 0
        self.n_draft_tokens = 0
        self.n_accepted_tokens = 0
        self.slots: List[Optional[SlotState]] = [None] * S
        self.queue = TenantQueues()
        self.results: List[RequestResult] = []
        self.occupancy = np.zeros((S, max(self.num_leaves, 1)), np.float64)
        # whether a slot's occupancy row holds MEASURED telemetry (vs a
        # seeded hint/profile prior): only measured rows may promote into
        # the profile store — else telemetry-less serving would EWMA the
        # store's own output (or the client's hint) back into itself and
        # report "learned" profiles built from zero observations
        self._measured = np.zeros((S,), bool)
        # online per-tenant routing profiles, fed by _evict_finished
        self.profiles: Optional[RoutingProfileStore] = (
            RoutingProfileStore(self.num_leaves, ewma=ecfg.profile_ewma,
                                min_updates=ecfg.profile_min_updates,
                                max_tenants=ecfg.profile_max_tenants)
            if ecfg.learn_profiles and self.num_leaves else None)
        self._hint_mismatches = 0            # size-mismatched leaf_hints seen
        self._hint_warned = False            # warn once per engine
        # what a FREE slot decodes: its last occupant's final token (distinct
        # per-slot ids before first use — a constant would concentrate
        # startup phantom load on one leaf).  Free rows' outputs are
        # ignored, but they still
        # route through FFF sites and — under the drop-semantics "grouped"
        # backend — share per-leaf capacity with real tokens; feeding
        # in-distribution, naturally-spread tokens keeps that phantom load
        # from piling onto one leaf (exact backends: reference / pallas /
        # grouped_ep's repair are unaffected by construction)
        self._free_tok = (np.arange(S) % cfg.vocab_size).astype(np.int32)
        self._live_rids: set = set()            # queued or in a slot
        self._arrivals: Dict[int, float] = {}   # id(req) -> engine-clock s

        # donate the pooled caches through every cache-threading jit so XLA
        # updates them in place instead of copying the full KV pool per
        # token (the caller always rebinds self.caches to the result); CPU
        # has no donation support and would warn per compile
        def _don(*i):
            return {} if jax.default_backend() == "cpu" \
                else {"donate_argnums": i}
        self._decode_jit = jax.jit(
            lambda p, t, c, off, wm, lv: lm.decode_step(p, cfg, t, c, off,
                                                        write_mask=wm,
                                                        token_valid=lv,
                                                        with_stats=True),
            **_don(2))
        if self.spec:
            dcfg = self.draft_cfg
            # every spec-mode entry point that touches caches touches BOTH
            # trees in the SAME dispatch — prefill, chunk, admit, round —
            # so speculation adds zero dispatch overhead over plain serving
            # anywhere except the round itself (where it replaces k+1
            # decode dispatches with one).  Monolithic prefill is a chunk
            # slab at bucket width: only the admitted row is valid, and its
            # offset starts at the shared-prefix boundary (DESIGN.md §11).
            self._prefill_jits = {
                b: jax.jit(
                    lambda p, dp, t, v, c, dc, off: spec_lib.chunk_both(
                        p, cfg, dp, dcfg, t, v, c, dc, off), **_don(4, 5))
                for b in ecfg.buckets()}
            self._chunk_jit = None
            if ecfg.prefill_chunk:
                self._chunk_jit = jax.jit(
                    lambda p, dp, t, v, c, dc, off: spec_lib.chunk_both(
                        p, cfg, dp, dcfg, t, v, c, dc, off), **_don(4, 5))
            self._admit_jit = jax.jit(
                lambda c, dc, ad, tb, ln, cs, cd: (
                    lm.cache_admit(c, ad, tb, ln, cs, cd),
                    lm.cache_admit(dc, ad, tb, ln, cs, cd)),
                **_don(0, 1))
            # the whole round — both trees' length rollback, k+1 scanned
            # draft decode steps with on-device sampling, and the target's
            # (num_slots, k+1) verify — in one compiled shape.  The
            # per-round PRNG key derives inside the trace from a traced
            # round counter, so the jit compiles once.
            self._spec_jit = jax.jit(
                lambda p, dp, t0, c, dc, tl, dl, p0, wm, vl, lv, tp, rnd:
                spec_lib.spec_round(
                    p, cfg, dp, dcfg, t0, c, dc, tl, dl, p0, wm, vl, lv, tp,
                    jax.random.fold_in(jax.random.PRNGKey(ecfg.seed), rnd),
                    verify_cf=self._verify_cf(),
                    # the rollout's k+1 scanned draft steps are seq-len-1 —
                    # the megakernel's shape; the verify slab is not and
                    # keeps the normal resolution (DESIGN.md §13)
                    draft_backend=("pallas_decode" if ecfg.pallas_decode
                                   else None)),
                **_don(3, 4))
        else:
            self._prefill_jits = {
                b: jax.jit(
                    lambda p, t, v, c, off: lm.prefill_chunk(p, cfg, t, v,
                                                             c, off),
                    **_don(3))
                for b in ecfg.buckets()}
            self._chunk_jit = None
            if ecfg.prefill_chunk:
                self._chunk_jit = jax.jit(
                    lambda p, t, v, c, off: lm.prefill_chunk(p, cfg, t, v,
                                                             c, off),
                    **_don(3))
            self._admit_jit = jax.jit(
                lambda c, ad, tb, ln, cs, cd: lm.cache_admit(
                    c, ad, tb, ln, cs, cd), **_don(0))
        # per-slot raw leaf counts accumulated across a request's prefill
        # chunks; normalized into self.occupancy when its prefill completes
        self._prefill_counts = np.zeros((S, max(self.num_leaves, 1)),
                                        np.float64)

        # the engine clock is injectable (ISSUE 8): every timestamp —
        # arrivals, TTFT, decode latency, RequestResult times — reads
        # through _clock, so a VirtualClock makes the whole serving loop
        # (and cluster heartbeat/timeout logic above it) wall-time-free
        self._clock: Callable[[], float] = clock or time.monotonic
        self._t0 = self._clock()
        self.n_steps = 0
        self.n_prefills = 0
        self.n_chunks = 0
        self.decode_lat: List[float] = []
        # gaps between consecutive decode dispatches while work was in
        # flight: the stall-free-admission signal (a monolithic long-prompt
        # prefill lands in one of these gaps; chunked prefill bounds them)
        self.decode_interval_s: List[float] = []
        self._last_decode_end: Optional[float] = None
        # slot-weighted overflow accumulators, split by phase: admission
        # composes the *decode* batch, so decode overflow is the scheduler's
        # signal (spec verify dispatches land there too — they ARE the
        # target's decode); "draft" keeps the draft model's own routing out
        # of the target's numbers; prefill overflow is per-request.  Filler
        # rows cost nothing anywhere: the per-row validity mask routes them
        # to the FFF sentinel leaf, outside capacity and telemetry.
        self._overflow = {"prefill": [0.0, 0.0], "decode": [0.0, 0.0],
                          "draft": [0.0, 0.0]}

    # -- cache placement -----------------------------------------------------

    def _pin_caches(self, caches, mesh):
        """Commit the page pool to its serving-mesh placement once, at
        allocation (ROADMAP: pin cache shardings under the EP mesh).  Every
        later jitted call donates the pinned buffers, so the layout derived
        here is the layout for the engine's lifetime."""
        from repro.distributed import sharding as shard_lib
        specs = shard_lib.cache_specs(caches, mesh, self.ecfg.num_slots)
        return jax.tree_util.tree_map(
            lambda x, s: jax.device_put(
                x, jax.sharding.NamedSharding(mesh, s)), caches, specs)

    # -- clock ---------------------------------------------------------------

    def now(self) -> float:
        """Engine-clock seconds since construction (all Request arrival
        offsets and RequestResult timestamps are on this clock).  The
        clock source is injectable (``clock=`` at construction; default
        ``time.monotonic``) — a ``VirtualClock`` runs the loop in
        deterministic virtual time."""
        return self._clock() - self._t0

    # -- submission ----------------------------------------------------------

    def validate(self, req: Request) -> None:
        """Shape/uniqueness checks a request must pass to be servable;
        raises ValueError otherwise (``run`` fail-fasts its whole batch
        through this before serving anything)."""
        buckets = self.ecfg.buckets()
        if len(req.prompt) > buckets[-1]:
            raise ValueError(
                f"request {req.rid}: prompt len {len(req.prompt)} exceeds "
                f"max prefill bucket {buckets[-1]}")
        if len(req.prompt) + req.max_new_tokens > self.ecfg.max_len:
            raise ValueError(
                f"request {req.rid}: prompt + max_new_tokens exceeds "
                f"max_len {self.ecfg.max_len}")
        if req.rid in self._live_rids:
            # rid keys the scheduler's hold map and the sampling RNG stream;
            # two live requests sharing one would alias
            raise ValueError(f"request rid {req.rid} is already queued or "
                             f"active")

    def submit(self, req: Request,
               arrival_time: Optional[float] = None) -> None:
        """Enqueue a request.  Its arrival is recorded on the engine clock —
        submission time by default — in a side table (the caller's
        ``Request.arrival_time`` offset is never mutated, so request lists
        can be replayed on a warm engine)."""
        self.validate(req)
        if req.leaf_hint is not None and self.num_leaves and \
                (req.leaf_hint.size != self.num_leaves
                 or req.leaf_hint.sum() <= 0):
            # advisory, so never reject — but a silently dropped hint looks
            # exactly like a missing one, which hides client-side profile
            # bugs: warn once and count every occurrence (the
            # ``hint_mismatches`` metric).  Unusable = wrong width for this
            # model's leaf count, or zero mass (nothing to normalize) —
            # the same predicate the seeding/footprint paths discard by.
            self._hint_mismatches += 1
            if not self._hint_warned:
                self._hint_warned = True
                why = (f"size {req.leaf_hint.size} != num_leaves "
                       f"{self.num_leaves}"
                       if req.leaf_hint.size != self.num_leaves
                       else "zero mass")
                warnings.warn(
                    f"request {req.rid} (tenant {req.tenant!r}): unusable "
                    f"leaf_hint ({why}); ignoring it (counted in the "
                    f"hint_mismatches metric; further unusable hints warn "
                    f"only via the counter)", stacklevel=2)
        self._live_rids.add(req.rid)
        self._arrivals[id(req)] = (self.now() if arrival_time is None
                                   else arrival_time)
        self.queue.append(req)

    # -- trace contexts ------------------------------------------------------

    def _ctx(self):
        es = contextlib.ExitStack()
        if self._trace_ctx is not None:
            es.enter_context(self._trace_ctx())
        kw = {}
        if self.ecfg.fff_backend != "auto":
            kw.update(backend=self.ecfg.fff_backend, mode="infer")
        if self.ecfg.capacity_factor is not None:
            # the engine's capacity factor steers the LIVE dispatch, not
            # just the scheduler proxy — cf < 1.0 under-provisions on
            # purpose and the overflow policy decides what happens then
            kw["capacity_factor"] = self.ecfg.capacity_factor
        if self.ecfg.overflow_policy is not None:
            kw["overflow_policy"] = self.ecfg.overflow_policy
        if kw:
            es.enter_context(api.overrides(**kw))
        if self.ecfg.telemetry:
            es.enter_context(api.collect_routing())
        return es

    def _decode_backend_ctx(self):
        """The decode-only backend steer: under ``ecfg.pallas_decode`` the
        decode dispatch traces with the fused megakernel backend
        (DESIGN.md §13) while every other dispatch keeps ``fff_backend``'s
        resolution.  Trace-time thread-local, so it costs nothing once the
        decode jit is compiled."""
        if not self.ecfg.pallas_decode:
            return contextlib.nullcontext()
        return api.overrides(backend="pallas_decode", mode="infer")

    def _dispatch_topology(self) -> Tuple[int, Optional[float]]:
        """(token-axis shard count, capacity factor) the live FFF dispatch
        actually runs with — the scheduler's overflow proxy must match it,
        not the engine's nominal config.  ``auto`` resolves through
        ``api.resolve_backend`` (the real resolver, including supports
        predicates), evaluated under the trace contexts because the mesh
        accessors and overrides are trace-time thread-locals; cached — the
        mesh is fixed for the engine's lifetime.  Capacity factor None =
        exact per-token backend, no capacity bound to predict against."""
        if self._topology is None:
            from repro.distributed import act as dist_act
            backend = self.ecfg.fff_backend
            with self._ctx():
                g = dist_act.data_shard_count()
                m = dist_act.model_shard_count()
                if backend == "auto":
                    backend = (api.resolve_backend({}, self._site_cfg)
                               if self._site_cfg is not None else "reference")
            if backend in ("reference", "pallas", "pallas_decode"):
                self._topology = (1, None)     # exact: no capacity bound
                self._policy = None
            else:
                shards = g * m if backend == "grouped_ep" else g
                cf = (self.ecfg.capacity_factor
                      if self.ecfg.capacity_factor is not None
                      else api.default_capacity_factor(backend))
                self._topology = (shards, cf)
                self._policy = (self.ecfg.overflow_policy
                                if self.ecfg.overflow_policy is not None
                                else api.default_overflow_policy(backend))
        return self._topology

    def _overflow_policy(self) -> Optional[str]:
        """The overflow policy the live dispatch runs with (DESIGN.md §14);
        None when no capacity bound exists (exact backends never drop)."""
        self._dispatch_topology()
        return self._policy

    def _repair_counters(self, ovf0: Optional[dict] = None
                         ) -> Tuple[int, float]:
        """Host-side overflow-policy accounting from the routing-stats
        overflow accumulators: (estimated repaired (token, tree) slots,
        fraction of slots served by the master leaf alone).  ``ovf0`` rebases
        onto a per-run snapshot of ``self._overflow``; repairs are 0 under
        policy "drop" (nothing stands in) and the master fraction is nonzero
        only under "master_leaf"."""
        policy = self._overflow_policy()
        if policy in (None, "drop"):
            return 0, 0.0
        w = n = 0.0
        for k, acc in self._overflow.items():
            base = ovf0[k] if ovf0 else (0.0, 0.0)
            w += acc[0] - base[0]
            n += acc[1] - base[1]
        frac = (w / n if n else 0.0) if policy == "master_leaf" else 0.0
        return int(round(w)), frac

    def _verify_cf(self) -> Optional[float]:
        """Capacity factor for the speculative verify dispatch: the decode
        capacity factor scaled by the slab width ``k + 1``.  A verify slab
        is k+1 decode steps fused onto one token axis, so per-leaf capacity
        must scale with that axis — otherwise each verify token would see
        LESS capacity than the same token in plain decode (the per-leaf
        capacity floor is generous to small batches) and speculation would
        change serving numerics instead of just batching them.  None for
        exact backends (no capacity bound)."""
        _, cf = self._dispatch_topology()
        return None if cf is None else float(cf) * (self.ecfg.spec_k + 1)

    # -- telemetry -----------------------------------------------------------

    def _stats_rows(self, stats, phase: str,
                    weight_scale: float = 1.0) -> Optional[np.ndarray]:
        """Merge a per-site routing-stats tuple into per-batch-row leaf
        counts (B, E) for sites matching the engine's telemetry width, and
        fold the slot-weighted overflow into the running per-phase mean.

        ``RoutingStats.slots`` counts VALID tokens only — the per-row
        validity mask routes filler rows to the sentinel leaf, which
        ``bincount`` drops — so slab dispatches self-weight by real-token
        count and ``weight_scale`` stays 1.0 for them (it remains as an
        explicit discount hook for callers with out-of-band knowledge)."""
        if stats is None or self.num_leaves == 0:
            return None
        counts = None
        acc = self._overflow[phase]
        for s in stats:
            if s is None:
                continue
            c = np.asarray(s.leaf_counts, np.float64)
            w = float(s.slots) * weight_scale
            acc[0] += float(s.overflow) * w
            acc[1] += w
            if c.shape[-1] == self.num_leaves:
                counts = c if counts is None else counts + c
        return counts

    def _update_occupancy(self, slot_rows: Sequence[int],
                          counts: Optional[np.ndarray],
                          measured: bool = True) -> None:
        """Fold per-row leaf counts into the occupancy EWMA.  ``measured``
        False (the draft model's histograms — a PRIOR on where the target's
        verify tokens will route, DESIGN.md §10) refines the footprint the
        schedulers read without promoting the row into profile-store
        eligibility: profiles must hold target-measured telemetry only."""
        if counts is None:
            return
        a = self.ecfg.occupancy_ewma
        for r in slot_rows:
            tot = counts[r].sum()
            if tot <= 0:
                continue
            if measured:
                self._measured[r] = True
            frac = counts[r] / tot
            prev = self.occupancy[r]
            self.occupancy[r] = frac if not prev.any() else \
                (1.0 - a) * prev + a * frac

    def overflow_mean(self, phase: Optional[str] = None) -> float:
        """Slot-weighted mean overflow_fraction; ``phase`` in
        {"prefill", "decode", "draft", None = all}."""
        keys = [phase] if phase else list(self._overflow)
        w = sum(self._overflow[k][0] for k in keys)
        n = sum(self._overflow[k][1] for k in keys)
        return w / n if n else 0.0

    # -- sampling (host-side, deterministic under seed) ----------------------

    def _sample(self, st: SlotState, logits_row: np.ndarray) -> int:
        if st.request.temperature <= 0.0:
            return int(logits_row.argmax())
        rng = np.random.default_rng(
            (self.ecfg.seed, st.request.rid, len(st.tokens)))
        z = logits_row / st.request.temperature
        return int((z + rng.gumbel(size=z.shape)).argmax())

    def _record_token(self, st: SlotState, tok: int) -> None:
        st.tokens.append(tok)
        st.total_len += 1
        req = st.request
        if req.eos_id is not None and tok == req.eos_id:
            st.done, st.finish_reason = True, "eos"
        elif len(st.tokens) >= req.max_new_tokens:
            st.done, st.finish_reason = True, "length"
        if st.done:
            st.finish_time = self.now()

    # -- the loop ------------------------------------------------------------

    def _evict_finished(self) -> None:
        for i, st in enumerate(self.slots):
            if st is None or not st.done:
                continue
            self.release_slot(i)

    def release_slot(self, i: int, record_result: bool = True) -> None:
        """Free slot ``i``: pages decref'd, occupancy promoted/reset, rid
        retired.  ``record_result=False`` is the cluster handoff path
        (``cluster/handoff.py``): a prefill worker that just shipped the
        slot's KV pages releases the slot WITHOUT minting a
        ``RequestResult`` — the receiving decode worker owns the request's
        lifecycle from here.  No device dispatch either way — the slot's
        stale table and length rows are harmless because every decode/chunk
        write is masked to live rows, and re-admission overwrites both."""
        st = self.slots[i]
        if st is None:
            return
        # free the slot's pages on the host: refcounts drop, and pages
        # nobody else holds (no other slot, not the prefix index) return
        # to the free list.
        self.pool.decref(self._slot_pages[i])
        self._slot_pages[i] = []
        self._alloc_len[i] = 0
        self._shared_len[i] = 0
        # promote the finished request's measured footprint into its
        # tenant's online routing profile BEFORE the row resets — this
        # is how leaf hints self-calibrate (ROADMAP: learn leaf hints
        # online).  _measured gates out rows that only ever held a
        # seeded prior (telemetry off / no FFF stats landed).
        if self.profiles is not None and self._measured[i] and \
                self.occupancy[i].any():
            self.profiles.update(st.request.tenant, self.occupancy[i])
        self.occupancy[i] = 0.0
        self._measured[i] = False
        self._prefill_counts[i] = 0.0
        if self.spec:
            self._tlen[i] = 0
            self._dlen[i] = 0
        # what this freed slot will decode while idle: the occupant's
        # last NON-EOS token — replaying the EOS id itself would pile
        # every freed slot's phantom routing onto the EOS token's leaf
        spread = [t for t in st.tokens if t != st.request.eos_id]
        self._free_tok[i] = (spread[-1] if spread
                             else int(st.request.prompt[-1]))
        self._live_rids.discard(st.request.rid)
        arrival = self._arrivals.pop(id(st.request), st.admitted_time)
        if record_result:
            self.results.append(RequestResult(
                rid=st.request.rid, prompt=st.request.prompt,
                tokens=np.asarray(st.tokens, np.int32),
                finish_reason=st.finish_reason,
                arrival_time=arrival,
                admitted_time=st.admitted_time,
                first_token_time=st.first_token_time,
                finish_time=st.finish_time,
                tenant=st.request.tenant,
                n_drafted=st.n_drafted,
                n_accepted=st.n_accepted))
        self.slots[i] = None

    def _bucket_for(self, n: int) -> int:
        return next(b for b in self.ecfg.buckets() if b >= n)

    def _seed_hint(self, slot: int, req: Request) -> None:
        """Seed the slot's occupancy row before any telemetry lands: the
        request's own ``leaf_hint`` if usable, else the tenant's learned
        routing profile (mismatched hints were counted at submit)."""
        h = req.leaf_hint
        if h is None or not self.num_leaves or h.size != self.num_leaves \
                or h.sum() <= 0:
            # same usability predicate as the schedulers' _footprint — a
            # zero-mass hint must fall through identically on both sides,
            # or admission and slot seeding would disagree on the footprint
            h = (self.profiles.lookup(req.tenant)
                 if self.profiles is not None else None)
        if h is not None and self.num_leaves and h.size == self.num_leaves \
                and h.sum() > 0:
            self.occupancy[slot] = h / h.sum()

    # -- paged admission (DESIGN.md §11) -------------------------------------

    def _plan_pages(self, req: Request) -> Optional[dict]:
        """Page plan for admitting ``req``: the longest indexed full-page
        prefix maps read-only shared pages; fresh pages cover the rest of
        ``len(prompt) + max_new_tokens``.  A fully-covered prompt
        copy-on-writes its last matched page (the final token must
        re-prefill privately: first-token logits, and shared pages are
        never written).  Returns None — request stays queued — when the
        pool can't cover the fresh pages even after reclaiming LRU index
        entries (OOM-of-pages is scheduler back-pressure, not an error)."""
        page = self._page
        L = len(req.prompt)
        n_total = -(-(L + req.max_new_tokens) // page)
        matched = (self.prefix.match(req.prompt) if self.ecfg.prefix_sharing
                   else [])
        shared = min(len(matched) * page, L - 1)   # >= 1 novel token always
        n_shared = shared // page
        shared_pages = list(matched[:n_shared])
        cow_src = matched[n_shared] if shared % page else None
        n_fresh = n_total - n_shared
        # hold the mapped + COW-source pages through reclaim/alloc — the
        # reclaim below must not free what this very admission depends on
        self.pool.incref(shared_pages)
        if cow_src is not None:
            self.pool.incref([cow_src])
        if self.pool.pages_free < n_fresh:
            self.prefix.reclaim(n_fresh)
        fresh = self.pool.alloc(n_fresh)
        if fresh is None:
            self.pool.decref(shared_pages)
            if cow_src is not None:
                self.pool.decref([cow_src])
            return None
        return {"pages": shared_pages + fresh, "shared_len": shared,
                "cow_src": cow_src,
                "cow_dst": fresh[0] if cow_src is not None else None}

    def _apply_admit(self, slot: int, plan: dict) -> None:
        """Install the plan's page table + shared-prefix length at ``slot``
        in one dispatch (``lm.cache_admit``; spec mode: both trees)."""
        S, sentinel = self.ecfg.num_slots, self._num_pages
        admit = np.zeros((S,), bool)
        admit[slot] = True
        tables = np.full((S, self._ppr), sentinel, np.int32)
        tables[slot, :len(plan["pages"])] = plan["pages"]
        lengths = np.zeros((S,), np.int32)
        lengths[slot] = plan["shared_len"]
        cow_src = np.full((S,), sentinel, np.int32)
        cow_dst = np.full((S,), sentinel, np.int32)
        if plan["cow_src"] is not None:
            cow_src[slot] = plan["cow_src"]
            cow_dst[slot] = plan["cow_dst"]
            self.n_cow_copies += 1
        args = (jnp.asarray(admit), jnp.asarray(tables),
                jnp.asarray(lengths), jnp.asarray(cow_src),
                jnp.asarray(cow_dst))
        with self._ctx():
            if self.spec:
                self.caches, self.draft_caches = self._admit_jit(
                    self.caches, self.draft_caches, *args)
            else:
                self.caches = self._admit_jit(self.caches, *args)
        if plan["cow_src"] is not None:
            # the COW copy is dispatched (device order protects it from any
            # later reuse of the source page) — drop the temporary hold
            self.pool.decref([plan["cow_src"]])
        self._slot_pages[slot] = list(plan["pages"])
        self._alloc_len[slot] = len(plan["pages"]) * self._page
        self._shared_len[slot] = plan["shared_len"]
        self.n_prefix_hit_tokens += plan["shared_len"]

    def _publish_prefix(self, slot: int) -> None:
        """Index the slot's full prompt pages for cross-request sharing —
        only now, at prefill COMPLETION: publishing at admission would let
        a racing request attend to pages whose K/V aren't written yet
        (racing admissions simply miss and prefill themselves)."""
        if not self.ecfg.prefix_sharing:
            return
        prompt = self.slots[slot].request.prompt
        n_full = len(prompt) // self._page
        if n_full:
            self.prefix.insert(prompt, self._slot_pages[slot][:n_full])

    def _admit(self) -> None:
        free = [i for i, s in enumerate(self.slots) if s is None]
        if not free or not self.queue:
            return
        n = min(len(free), self.ecfg.max_prefills_per_step)
        shards, cf = self._dispatch_topology()
        view = SchedulerView(
            occupancy=self.occupancy,
            active=np.asarray([s is not None for s in self.slots]),
            num_leaves=self.num_leaves,
            capacity_factor=cf,
            num_slots=self.ecfg.num_slots,
            dispatch_shards=shards,
            prefilling=np.asarray([s is not None and s.prefilling
                                   for s in self.slots]),
            profiles=self.profiles,
            # spec verify dispatches spec_k + 1 tokens per slot: the
            # scheduler's per-leaf capacity proxy must be normalized by the
            # same factor or it would predict overflow against a bound k+1
            # times too tight (see SchedulerView.leaf_capacity)
            tokens_per_slot=(self.ecfg.spec_k + 1) if self.spec else 1,
            pages_free=self.pool.pages_free)
        if self.ecfg.prefill_chunk:
            # the max_prefilling knob is chunked-only by contract (a
            # monolithic admission never *dwells* in the prefilling state,
            # so capping it would just throttle admission throughput)
            n = min(n, self.scheduler.admission_cap(view))
        if n <= 0:
            return
        chosen = self.scheduler.select(list(self.queue), n, view)
        for req in chosen:
            plan = self._plan_pages(req)
            if plan is None:
                # OOM of pages: the request (and the rest of this step's
                # picks) stays queued — evictions or index reclaim free
                # pages on a later step, and the scheduler sees the
                # pressure via SchedulerView.pages_free
                break
            self.queue.remove(req)
            slot = free.pop(0)
            if self.ecfg.prefill_chunk:
                self._admit_chunked(req, slot, plan)
            else:
                self._admit_monolithic(req, slot, plan)

    def _admit_monolithic(self, req: Request, slot: int, plan: dict) -> None:
        """One bucket-padded chunk slab prefills the prompt's NOVEL suffix
        (everything past the shared-prefix boundary) in a single dispatch.
        Only the admitted row of the (num_slots, bucket) slab is valid;
        the other rows carry in-distribution filler whose writes are
        dropped and whose tokens route to the FFF sentinel leaf."""
        self._apply_admit(slot, plan)
        L = len(req.prompt)
        sh = plan["shared_len"]
        suffix = np.asarray(req.prompt[sh:], np.int32)
        n = len(suffix)                                # >= 1 by plan
        bucket = self._bucket_for(n)
        S = self.ecfg.num_slots
        # right-pad with the LAST real token, not a constant: pad
        # positions' writes are dropped either way, but they do route
        # through FFF sites — repeating in-distribution content keeps the
        # phantom load naturally spread (same rationale as _free_tok)
        toks = np.repeat(self._free_tok[:, None], bucket, axis=1)
        toks[slot, :n] = suffix
        toks[slot, n:] = suffix[-1]
        valid = np.zeros((S,), np.int32)
        valid[slot] = n
        offs = np.zeros((S,), np.int32)
        offs[slot] = sh
        with self._ctx():
            if self.spec:
                # one dispatch prefills the suffix into BOTH cache trees
                logits, self.caches, self.draft_caches, stats, dstats = \
                    self._prefill_jits[bucket](
                        self.params, self.draft_params, jnp.asarray(toks),
                        jnp.asarray(valid), self.caches, self.draft_caches,
                        jnp.asarray(offs))
                self._stats_rows(dstats, "draft")
                self._tlen[slot] = L
                self._dlen[slot] = L
            else:
                logits, self.caches, stats = self._prefill_jits[bucket](
                    self.params, jnp.asarray(toks), jnp.asarray(valid),
                    self.caches, jnp.asarray(offs))
        logits = np.asarray(jax.block_until_ready(logits))
        self.n_prefills += 1
        self.n_prefill_tokens += n
        t = self.now()
        st = SlotState(request=req, admitted_time=t, first_token_time=t,
                       tokens=[], total_len=L, prefill_pos=L)
        self.slots[slot] = st
        # seed the slot's footprint: measured prefill counts (the admitted
        # row of the slab), else the request's hint prior
        counts = self._stats_rows(stats, "prefill")
        if counts is not None and counts[slot].sum() > 0:
            self.occupancy[slot] = counts[slot] / counts[slot].sum()
            self._measured[slot] = True
        else:
            self._measured[slot] = False
            self._seed_hint(slot, req)
        self._record_token(st, self._sample(st, logits[slot]))
        self._publish_prefix(slot)

    def _admit_chunked(self, req: Request, slot: int, plan: dict) -> None:
        """Install the page table only — no model call.  The prompt's novel
        suffix advances through the shared chunk slab in subsequent
        ``_chunk_prefill`` dispatches, starting at the shared-prefix
        boundary (``prefill_pos = shared_len`` — the shared pages' K/V are
        already in the pool)."""
        self._apply_admit(slot, plan)
        sh = plan["shared_len"]
        st = SlotState(request=req, admitted_time=self.now(),
                       first_token_time=0.0, tokens=[], total_len=0,
                       prefill_pos=sh)
        self.slots[slot] = st
        if self.spec:
            self._tlen[slot] = sh
            self._dlen[slot] = sh
        self._prefill_counts[slot] = 0.0
        self._measured[slot] = False
        self._seed_hint(slot, req)     # prior until measured counts land

    def _chunk_prefill(self) -> None:
        """One (num_slots, prefill_chunk) slab dispatch: every mid-prefill
        slot consumes its next chunk of prompt; rows whose prompt completes
        sample their first token from the slab's logits (DESIGN.md §9)."""
        prefilling = [i for i, s in enumerate(self.slots)
                      if s is not None and s.prefilling]
        if not prefilling:
            return
        S, C = self.ecfg.num_slots, self.ecfg.prefill_chunk
        # inactive rows carry in-distribution filler (same rationale as the
        # free-slot decode token); their writes are masked out by valid=0
        toks = np.repeat(self._free_tok[:, None], C, axis=1)
        valid = np.zeros((S,), np.int32)
        offs = np.zeros((S,), np.int32)
        for i in prefilling:
            st = self.slots[i]
            p = st.request.prompt
            n = min(C, len(p) - st.prefill_pos)
            toks[i, :n] = p[st.prefill_pos:st.prefill_pos + n]
            toks[i, n:] = p[st.prefill_pos + n - 1]   # pad: last real token
            valid[i] = n
            offs[i] = st.prefill_pos
        with self._ctx():
            if self.spec:
                # one slab dispatch advances every prefill in BOTH trees
                logits, self.caches, self.draft_caches, stats, dstats = \
                    self._chunk_jit(
                        self.params, self.draft_params, jnp.asarray(toks),
                        jnp.asarray(valid), self.caches, self.draft_caches,
                        jnp.asarray(offs))
                self._stats_rows(dstats, "draft")
            else:
                logits, self.caches, stats = self._chunk_jit(
                    self.params, jnp.asarray(toks), jnp.asarray(valid),
                    self.caches, jnp.asarray(offs))
        logits = np.asarray(jax.block_until_ready(logits))
        self.n_chunks += 1
        # slab overflow self-weights by real-token count now: the chunk-mode
        # validity mask routes filler positions to the sentinel leaf, so
        # RoutingStats.slots already counts only the valid prompt tokens
        counts = self._stats_rows(stats, "prefill")
        for i in prefilling:
            st = self.slots[i]
            st.prefill_pos += int(valid[i])
            self.n_prefill_tokens += int(valid[i])
            if self.spec:
                self._tlen[i] += int(valid[i])
                self._dlen[i] += int(valid[i])
            if counts is not None:
                self._prefill_counts[i] += counts[i]
            if not st.prefilling:          # prompt fully consumed this chunk
                self.n_prefills += 1
                tot = self._prefill_counts[i].sum()
                if tot > 0:
                    self.occupancy[i] = self._prefill_counts[i] / tot
                    self._measured[i] = True
                st.total_len = len(st.request.prompt)
                st.first_token_time = self.now()
                self._record_token(st, self._sample(st, logits[i]))
                self._publish_prefix(i)

    def _decode(self) -> None:
        live = [i for i, s in enumerate(self.slots)
                if s is not None and not s.done and not s.prefilling]
        if not live:
            return
        toks = self._free_tok[:, None].copy()
        offs = np.zeros((self.ecfg.num_slots,), np.int32)
        for i in live:
            st = self.slots[i]
            toks[i, 0] = st.tokens[-1]
            offs[i] = st.total_len - 1      # position of the token being fed
        # ONLY live rows write/advance their caches: mid-prefill slots must
        # not append the dummy decode token, and free/done rows' stale page
        # tables may alias pages the allocator has since handed to OTHER
        # live slots — an unmasked phantom write would corrupt them
        # (DESIGN.md §11).  Live rows' outputs are unaffected: attention is
        # row-independent and the FFF validity mask (lv) already routes
        # phantom rows to the sentinel leaf.
        wm = np.zeros((self.ecfg.num_slots,), bool)
        wm[live] = True
        # free/mid-prefill rows are phantom tokens: the validity mask routes
        # them to the FFF sentinel leaf so they never consume grouped-
        # dispatch capacity or pollute routing telemetry (DESIGN.md §9 —
        # deliberately separate from wm, which guards KV writes)
        lv = np.zeros((self.ecfg.num_slots,), bool)
        lv[live] = True
        t0 = self._clock()
        with self._ctx(), self._decode_backend_ctx():
            logits, self.caches, stats = self._decode_jit(
                self.params, jnp.asarray(toks), self.caches,
                jnp.asarray(offs), jnp.asarray(wm), jnp.asarray(lv))
        logits = np.asarray(jax.block_until_ready(logits))
        t1 = self._clock()
        self.decode_lat.append(t1 - t0)
        if self._last_decode_end is not None:
            self.decode_interval_s.append(t1 - self._last_decode_end)
        self._last_decode_end = t1
        self.n_steps += 1
        self._update_occupancy(live, self._stats_rows(stats, "decode"))
        for i in live:
            self._record_token(self.slots[i], self._sample(self.slots[i],
                                                           logits[i]))

    def _spec_round(self) -> None:
        """One speculative draft/verify round (DESIGN.md §10), replacing
        ``_decode`` when ``spec_k > 0``.  ONE fixed-shape dispatch
        (``_spec_jit``) runs, in order:

        1. rollback — both cache trees to the host-authoritative lengths
           (undoing the previous round's rejected optimistic appends);
        2. draft rollout — ``spec_k + 1`` scanned draft decode steps with
           on-device sampling, yielding proposals + draft logits + per-slot
           draft leaf histograms;
        3. verify — the target scores the ``(num_slots, k + 1)`` slab
           ``[pending, d_1 .. d_k]`` through the chunk machinery, appending
           K/V optimistically (per-row offsets; free rows masked out of
           capacity by the validity mask, writes dropped by valid_len = 0).

        Host-side rejection sampling then emits 1 .. k + 1 tokens per live
        slot — the accepted prefix plus the corrected/bonus token — with the
        target distribution preserved exactly (greedy: the target argmax
        chain, token for token).  Draft histograms fold into the occupancy
        EWMA as an unmeasured prior, so the leaf-aware schedulers compose
        verify batches against predicted — not just trailing — leaf load.
        """
        live = [i for i, s in enumerate(self.slots)
                if s is not None and not s.done and not s.prefilling]
        if not live:
            return
        S, k = self.ecfg.num_slots, self.ecfg.spec_k
        toks = self._free_tok[:, None].copy()
        pos0 = np.zeros((S,), np.int32)
        temps = np.zeros((S,), np.float32)
        lv = np.zeros((S,), bool)
        vlen = np.zeros((S,), np.int32)
        for i in live:
            st = self.slots[i]
            toks[i, 0] = st.tokens[-1]
            n = st.total_len - 1             # position of the pending token
            pos0[i] = n
            temps[i] = max(st.request.temperature, 0.0)
            lv[i] = True
            # the row's writable horizon is its ALLOCATED pages (>= prompt +
            # max_new by the admission plan), not max_len: optimistic
            # appends past the allocation would scatter into other slots'
            # pages through the clamped table lookup
            vlen[i] = min(k + 1, int(self._alloc_len[i]) - n)
        # per-step draft KV-write guards: step j appends at pos0 + j; rows
        # at their allocation edge stop writing (their later drafts go
        # unverified — vlen clips the verify slab identically)
        wm = lv[None, :] & ((pos0[None, :] + np.arange(k + 1)[:, None])
                            < self._alloc_len[None, :])
        t0 = self._clock()
        with self._ctx():
            (drafts, q_logits, p_logits, self.caches, self.draft_caches,
             dstats, vstats) = self._spec_jit(
                self.params, self.draft_params, jnp.asarray(toks),
                self.caches, self.draft_caches, jnp.asarray(self._tlen),
                jnp.asarray(self._dlen), jnp.asarray(pos0), jnp.asarray(wm),
                jnp.asarray(vlen), jnp.asarray(lv), jnp.asarray(temps),
                jnp.int32(self._spec_rounds))
        self._spec_rounds += 1
        p_logits = np.asarray(jax.block_until_ready(p_logits))  # (S,k+1,V)
        drafts = np.asarray(drafts)                             # (k, S)
        q_logits = np.asarray(q_logits)                         # (k+1,S,V)
        # draft leaf histograms: the verify step's occupancy PRIOR.  Width-
        # mismatched drafts contribute overflow telemetry only (_stats_rows
        # drops their counts); self-drafts share the target's leaf space.
        self._update_occupancy(live, self._stats_rows(dstats, "draft"),
                               measured=False)
        t1 = self._clock()
        self.decode_lat.append(t1 - t0)
        if self._last_decode_end is not None:
            self.decode_interval_s.append(t1 - self._last_decode_end)
        self._last_decode_end = t1
        self.n_steps += 1
        # verify IS the target's decode: same phase, measured occupancy
        self._update_occupancy(live, self._stats_rows(vstats, "decode"))

        for i in live:
            st = self.slots[i]
            vl = int(vlen[i])
            m = vl - 1                        # drafts actually verified
            rng = None
            if st.request.temperature > 0.0:
                # 4-tuple stream: disjoint from the non-spec sampler's
                # (seed, rid, len) 3-tuples by construction
                rng = np.random.default_rng(
                    (self.ecfg.seed, st.request.rid, len(st.tokens), 2))
            emitted, n_acc = spec_lib.rejection_sample(
                p_logits[i, :vl], q_logits[:m, i], drafts[:m, i],
                st.request.temperature, rng)
            st.n_drafted += m
            st.n_accepted += n_acc
            self.n_draft_tokens += m
            self.n_accepted_tokens += n_acc
            emitted_n = 0
            for tok in emitted:
                self._record_token(st, int(tok))
                emitted_n += 1
                if st.done:   # EOS/length mid-run: later tokens never exist
                    break
            # both trees sit at pos0 + vl (optimistic appends); the slot's
            # true history is pos0 + emitted_n tokens.  Record the desired
            # lengths — the next rollout's set_cache_lengths applies them.
            self._tlen[i] = int(pos0[i]) + emitted_n
            self._dlen[i] = int(pos0[i]) + emitted_n

    def step(self) -> None:
        """One engine iteration: evict finished slots, admit from the queue,
        advance chunked prefills (up to ``prefill_budget`` slab dispatches),
        then decode every active non-prefilling slot together — one plain
        decode step, or one speculative draft/verify round when spec_k >
        0."""
        self._evict_finished()
        self._admit()
        if self.ecfg.prefill_chunk:
            for _ in range(self.ecfg.prefill_budget):
                self._chunk_prefill()
        if self.spec:
            self._spec_round()
        else:
            self._decode()

    def has_work(self) -> bool:
        """True while anything is queued or occupying a slot (the manual
        ``step()`` loop's condition)."""
        return bool(self.queue) or any(s is not None for s in self.slots)

    def run(self, requests: Sequence[Request]) -> Tuple[List[RequestResult],
                                                        metrics_lib.EngineMetrics]:
        """Serve ``requests`` (arrival_time = offsets from THIS call's start,
        seconds) to completion; returns (results sorted by rid, metrics).
        Re-entrant: each call reports only its own requests/steps and rebases
        arrivals onto its own start — ``Request.arrival_time`` offsets are
        never mutated, so the same list replays (jit caches and slot state
        persist: a later wave is a warm engine, not a fresh one)."""
        for r in requests:            # fail fast, before serving anything
            self.validate(r)
        if len({r.rid for r in requests}) != len(requests):
            raise ValueError("duplicate rids in the request batch")
        pending = deque(sorted(requests,
                               key=lambda r: (r.arrival_time, r.rid)))
        # per-run deltas against the engine-lifetime accumulators
        n_results0, n_steps0 = len(self.results), self.n_steps
        n_prefills0, n_lat0 = self.n_prefills, len(self.decode_lat)
        n_chunks0, n_int0 = self.n_chunks, len(self.decode_interval_s)
        hints0 = self._hint_mismatches
        draft0, acc0 = self.n_draft_tokens, self.n_accepted_tokens
        phit0, cow0 = self.n_prefix_hit_tokens, self.n_cow_copies
        ptoks0 = self.n_prefill_tokens
        ovf0 = {k: list(v) for k, v in self._overflow.items()}
        t_start = self.now()
        self._last_decode_end = None    # decode gaps don't span runs
        while pending or self.has_work():
            while pending and t_start + pending[0].arrival_time <= self.now():
                r = pending.popleft()
                self.submit(r, arrival_time=t_start + r.arrival_time)
            if not self.has_work():
                self._last_decode_end = None    # idle gap, not a stall
                if pending:
                    wait = max(t_start + pending[0].arrival_time - self.now(),
                               0.0)
                    adv = getattr(self._clock, "advance", None)
                    if adv is not None:
                        # virtual time: jump straight to the next arrival —
                        # sleeping would stall forever on a clock that only
                        # moves when told to
                        adv(wait)
                    else:
                        time.sleep(min(wait, 0.05))
                continue
            self.step()
        elapsed = self.now() - t_start
        results = sorted(self.results[n_results0:], key=lambda r: r.rid)
        # drain this run's slice so a long-lived warm engine doesn't grow
        # without bound across waves (earlier entries belong to manual
        # step() users and are left alone)
        del self.results[n_results0:]
        lat = self.decode_lat[n_lat0:]
        del self.decode_lat[n_lat0:]
        intervals = self.decode_interval_s[n_int0:]
        del self.decode_interval_s[n_int0:]

        def ovf_delta(keys):
            w = sum(self._overflow[k][0] - ovf0[k][0] for k in keys)
            n = sum(self._overflow[k][1] - ovf0[k][1] for k in keys)
            return w / n if n else 0.0

        repairs, m_frac = self._repair_counters(ovf0)
        m = metrics_lib.from_results(
            results, elapsed_s=elapsed, n_steps=self.n_steps - n_steps0,
            n_prefills=self.n_prefills - n_prefills0,
            decode_lat_s=lat,
            overflow_mean=ovf_delta(list(self._overflow)),
            overflow_decode_mean=ovf_delta(["decode"]),
            overflow_repairs=repairs,
            master_leaf_fraction=m_frac,
            n_chunks=self.n_chunks - n_chunks0,
            decode_interval_s=intervals,
            hint_mismatches=self._hint_mismatches - hints0,
            draft_tokens=self.n_draft_tokens - draft0,
            accepted_tokens=self.n_accepted_tokens - acc0,
            prefill_tokens=self.n_prefill_tokens - ptoks0,
            prefix_hit_tokens=self.n_prefix_hit_tokens - phit0,
            cow_copies=self.n_cow_copies - cow0,
            pages_in_use=self.pool.pages_in_use,
            pages_free=self.pool.pages_free)
        return results, m

    def poll_metrics(self) -> metrics_lib.EngineMetrics:
        """Live engine-lifetime telemetry snapshot — the autoscaling signal
        (ROADMAP).  Unlike ``run``'s per-run report this reflects everything
        since engine construction (or since ``run`` last drained its slice)
        plus instantaneous state: ``queue_depth`` (waiting requests),
        ``active_slots`` / ``prefilling_slots``, TTFT/latency percentiles
        over finished requests, and the overflow means.  Host-only: no
        device work, safe to call from a monitoring thread between steps.
        ``serve.py --metrics-json`` dumps the same schema (docs/serving.md
        has the field glossary)."""
        repairs, m_frac = self._repair_counters()
        m = metrics_lib.from_results(
            self.results, elapsed_s=self.now(), n_steps=self.n_steps,
            n_prefills=self.n_prefills, decode_lat_s=self.decode_lat,
            overflow_mean=self.overflow_mean(),
            overflow_decode_mean=self.overflow_mean("decode"),
            overflow_repairs=repairs,
            master_leaf_fraction=m_frac,
            n_chunks=self.n_chunks,
            decode_interval_s=self.decode_interval_s,
            hint_mismatches=self._hint_mismatches,
            draft_tokens=self.n_draft_tokens,
            accepted_tokens=self.n_accepted_tokens,
            prefill_tokens=self.n_prefill_tokens,
            prefix_hit_tokens=self.n_prefix_hit_tokens,
            cow_copies=self.n_cow_copies,
            pages_in_use=self.pool.pages_in_use,
            pages_free=self.pool.pages_free)
        m.queue_depth = len(self.queue)
        m.active_slots = sum(s is not None for s in self.slots)
        m.prefilling_slots = sum(s is not None and s.prefilling
                                 for s in self.slots)
        # live per-tenant queue depths on top of the finished-request
        # breakdown (a tenant may be all-queued with nothing finished yet)
        for t, q in self.queue.per_tenant.items():
            m.tenants.setdefault(t, {})["queue_depth"] = len(q)
        if self.profiles is not None:
            for t, snap in self.profiles.as_dict().items():
                m.tenants.setdefault(t, {})["profile"] = snap
        return m

    def occupancy_snapshot(self) -> Optional[np.ndarray]:
        """Mean leaf-occupancy EWMA across active slots — the worker's live
        FFF footprint, consumed by cluster placement (``cluster/placement``)
        to steer tenants whose learned profiles overlap it elsewhere.  None
        when the model has no FFF site; zeros when idle."""
        if not self.num_leaves:
            return None
        act = [i for i, s in enumerate(self.slots) if s is not None]
        if not act:
            return np.zeros((self.num_leaves,), np.float64)
        return self.occupancy[act].mean(axis=0)

    # -- fixed-shape accounting ----------------------------------------------

    def compiled_shapes(self) -> Dict[str, int]:
        """Number of compiled traces per entry point (the fixed-shape
        contract: after warmup, decode == 1, each prefill bucket <= 1, and
        the chunk slab — when chunked prefill is on — exactly 1)."""
        def n(fn):
            try:
                return int(fn._cache_size())
            except AttributeError:           # pragma: no cover - old jax
                return -1
        out = {"decode": n(self._decode_jit), "admit": n(self._admit_jit)}
        for b, fn in self._prefill_jits.items():
            out[f"prefill_{b}"] = n(fn)
        if self._chunk_jit is not None:
            out["prefill_chunk"] = n(self._chunk_jit)
        if self.spec:
            out["spec_round"] = n(self._spec_jit)
        install = getattr(self, "_cluster_install_jit", None)
        if install is not None:
            # the cluster handoff-receive dispatch (cluster/handoff.py):
            # part of a decode worker's compile family, same <= 1 contract
            out["install"] = n(install)
        return out
