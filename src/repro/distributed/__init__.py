"""Distribution: meshes, sharding rules, dispatch plans, compression, fault
tolerance."""
from repro.distributed import (act, compression, dispatch, fault, sharding,
                               straggler)
