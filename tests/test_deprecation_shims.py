"""The legacy ``fff.forward_*`` entry points must (a) warn, (b) delegate to
the exact equivalent ``api.apply()`` call — bit-identical results."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import api, fff


def _make(st=False, act="relu", leaf_bias=True):
    cfg = fff.FFFConfig(dim_in=16, dim_out=10, depth=3, leaf_width=4,
                        activation=act, leaf_bias=leaf_bias, st_training=st)
    params = fff.init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (32, 16))
    return cfg, params, x


def test_forward_train_shim_warns_and_matches_apply():
    cfg, p, x = _make()
    with pytest.warns(DeprecationWarning, match="forward_train"):
        y, aux = fff.forward_train(p, cfg, x)
    want, out = api.apply(p, cfg, x, api.ExecutionSpec(mode="train"))
    np.testing.assert_array_equal(np.asarray(y), np.asarray(want))
    np.testing.assert_array_equal(np.asarray(aux["node_probs"]),
                                  np.asarray(out.node_probs))
    np.testing.assert_array_equal(np.asarray(aux["mixture"]),
                                  np.asarray(out.mixture))
    assert float(aux["entropy"]) == float(out.entropy)


def test_forward_train_shim_honours_st_training():
    cfg, p, x = _make(st=True, act="swiglu", leaf_bias=False)
    with pytest.warns(DeprecationWarning):
        y, aux = fff.forward_train(p, cfg, x)
    # equivalent apply(): auto resolves st_training configs to grouped ST
    want, out = api.apply(p, cfg, x, api.ExecutionSpec(mode="train"))
    np.testing.assert_array_equal(np.asarray(y), np.asarray(want))
    np.testing.assert_array_equal(np.asarray(aux["leaf_idx"]),
                                  np.asarray(out.leaf_idx))


def test_forward_hard_shim_warns_and_matches_apply():
    cfg, p, x = _make()
    with pytest.warns(DeprecationWarning, match="forward_hard"):
        y, aux = fff.forward_hard(p, cfg, x)
    want, out = api.apply(p, cfg, x, api.ExecutionSpec(mode="infer",
                                                       backend="reference"))
    np.testing.assert_array_equal(np.asarray(y), np.asarray(want))
    np.testing.assert_array_equal(np.asarray(aux["leaf_idx"]),
                                  np.asarray(out.leaf_idx))


def test_forward_hard_grouped_shim_warns_and_matches_apply():
    cfg, p, x = _make(act="swiglu", leaf_bias=False)
    with pytest.warns(DeprecationWarning, match="forward_hard_grouped"):
        y, aux = fff.forward_hard_grouped(p, cfg, x, capacity_factor=8.0)
    want, out = api.apply(p, cfg, x, api.ExecutionSpec(
        mode="infer", backend="grouped", capacity_factor=8.0))
    np.testing.assert_array_equal(np.asarray(y), np.asarray(want))
    np.testing.assert_array_equal(np.asarray(aux["leaf_idx"]),
                                  np.asarray(out.leaf_idx))


def test_shims_still_importable_from_package_root():
    from repro.core import forward_hard, forward_train  # noqa: F401
