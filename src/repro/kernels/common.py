"""Shared kernel plumbing: interpret-mode autodetection, tiling helpers,
and the dispatch-count probe the roofline benchmark and the CI compile
gate use to assert kernel fusion (DESIGN.md §13)."""
from __future__ import annotations

import jax

from repro import utils


def default_interpret() -> bool:
    """Pallas kernels target TPU; everywhere else run the interpreter
    (bit-accurate Python execution of the kernel body — how this CPU container
    validates them)."""
    return jax.default_backend() != "tpu"


def pick_tile(n: int, preferred: int, align: int = 8) -> int:
    """Largest tile <= preferred that divides n, preferring MXU-aligned.

    Guarantees: the result always divides ``n`` exactly (callers size Pallas
    grids as ``n // tile``); an ``align``-multiple divisor wins when one
    exists <= preferred; ``n <= preferred`` returns ``n`` itself (one whole
    tile beats splitting).  ``n <= 0`` raises — the old fall-through
    returned 1 for an empty axis, silently building a 0-step grid."""
    if n <= 0:
        raise ValueError(f"pick_tile needs a positive axis size, got n={n}")
    if align <= 0:
        raise ValueError(f"pick_tile needs a positive alignment, got {align}")
    if n <= preferred:
        return n
    preferred = max(1, preferred)
    best = 1
    for t in range(preferred, 0, -1):
        if n % t == 0:
            if t % align == 0:
                return t            # largest aligned divisor <= preferred
            best = max(best, t)
    return best                     # largest divisor <= preferred (unaligned)


def count_pallas_calls(fn, *args, **kwargs) -> int:
    """Number of ``pallas_call`` dispatches in ``fn``'s traced program,
    counted by walking the jaxpr (recursing through pjit / scan / cond
    sub-jaxprs).  Trace-time and cache-independent — unlike a counter inside
    the kernel wrappers, it cannot be fooled by an already-warm inner jit —
    this is the probe that pins the fused decode path at ONE dispatch where
    the router + two gathered matmuls issue three (DESIGN.md §13)."""
    closed = jax.make_jaxpr(fn)(*args, **kwargs)
    return _count_jaxpr(closed.jaxpr)


def _count_jaxpr(jaxpr) -> int:
    try:                              # jax >= 0.4.33 public home; jax.core
        from jax.extend import core as jcore   # deprecates these on newer
    except ImportError:                        # versions of the CI matrix
        import jax.core as jcore
    n = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            n += 1
        for v in eqn.params.values():
            items = v if isinstance(v, (tuple, list)) else (v,)
            for item in items:
                if isinstance(item, jcore.ClosedJaxpr):
                    n += _count_jaxpr(item.jaxpr)
                elif isinstance(item, jcore.Jaxpr):
                    n += _count_jaxpr(item)
    return n
