"""Checkpointing: sharded save/restore, rolling async manager, elastic
re-sharding across device-count changes."""
from repro.checkpoint.ckpt import restore_tree, save_tree
from repro.checkpoint.manager import CheckpointManager
from repro.checkpoint.elastic import reshard_restore
