"""Direct tests for the fault-tolerance scaffolding the cluster tier wires
in (ISSUE 8 satellite): ``distributed/fault.py`` (TrainSupervisor,
RestartBackoff), ``distributed/straggler.py`` escalation, and
``checkpoint/elastic.py`` resharding — all previously dead seed code.
"""
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, reshard_restore
from repro.distributed import (ElasticRemesh, MitigationPolicy,
                               RestartBackoff, StepTimeTracker,
                               StragglerConfig, SupervisorConfig,
                               TrainSupervisor)

# ---------------------------------------------------------------------------
# RestartBackoff
# ---------------------------------------------------------------------------


def test_backoff_exponential_then_exhausted():
    b = RestartBackoff(max_restarts=3, base=0.5, factor=2.0)
    assert b.next_delay() == 0.5
    assert b.next_delay() == 1.0
    assert b.next_delay() == 2.0
    assert b.next_delay() is None          # budget spent
    assert b.next_delay() is None          # stays exhausted
    b.reset()
    assert b.next_delay() == 0.5


def test_backoff_zero_base_disables_sleeps():
    b = RestartBackoff(max_restarts=2, base=0.0)
    assert b.next_delay() == 0.0
    assert b.next_delay() == 0.0
    assert b.next_delay() is None


# ---------------------------------------------------------------------------
# TrainSupervisor: checkpoint/restart semantics
# ---------------------------------------------------------------------------


def _step(state, step):
    # deterministic given (state, step) — the supervisor's replay contract
    return {"x": state["x"] + step + 1}


def _run_plain(num_steps):
    state = {"x": np.zeros(())}
    for s in range(num_steps):
        state = _step(state, s)
    return state


def test_supervisor_clean_run_matches_plain_loop(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    sup = TrainSupervisor(mgr, SupervisorConfig(ckpt_every=4))
    out = sup.run({"x": np.zeros(())}, _step, 10)
    assert out.step == 10 and out.restarts == 0 and out.ejections == 0
    np.testing.assert_array_equal(out.state["x"], _run_plain(10)["x"])


def test_supervisor_recovers_from_injected_failure(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    sleeps = []
    sup = TrainSupervisor(
        mgr, SupervisorConfig(ckpt_every=2, max_restarts=3,
                              backoff_base=0.25, backoff_factor=2.0),
        sleep_fn=sleeps.append)
    tripped = []

    def hook(step):
        if step == 5 and not tripped:
            tripped.append(step)
            return True
        return False

    out = sup.run({"x": np.zeros(())}, _step, 10, failure_hook=hook)
    assert out.restarts == 1
    assert sleeps == [0.25]                # backoff actually slept
    # restore-and-replay converges to the uninterrupted trajectory
    np.testing.assert_array_equal(out.state["x"], _run_plain(10)["x"])


def test_supervisor_restart_budget_exhausts(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    sup = TrainSupervisor(mgr, SupervisorConfig(ckpt_every=2,
                                                max_restarts=2))
    with pytest.raises(RuntimeError, match="exceeded 2 restarts"):
        sup.run({"x": np.zeros(())}, _step, 10,
                failure_hook=lambda step: step == 3)   # fails every retry


def test_supervisor_resumes_from_existing_checkpoint(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    sup = TrainSupervisor(mgr, SupervisorConfig(ckpt_every=4))
    first = sup.run({"x": np.zeros(())}, _step, 8)
    # a fresh supervisor over the same directory resumes, not restarts
    sup2 = TrainSupervisor(CheckpointManager(str(tmp_path),
                                             async_save=False),
                           SupervisorConfig(ckpt_every=4))
    out = sup2.run({"x": np.zeros(())}, _step, 12)
    assert out.step == 12
    np.testing.assert_array_equal(out.state["x"], _run_plain(12)["x"])
    np.testing.assert_array_equal(first.state["x"], _run_plain(8)["x"])


def test_supervisor_straggler_ejection_raises_remesh(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    sup = TrainSupervisor(mgr, SupervisorConfig(ckpt_every=100))

    def straggle(step):
        return [1] if step == 6 else None

    with pytest.raises(ElasticRemesh) as exc:
        sup.run({"x": np.zeros(())}, _step, 10, straggler_hook=straggle)
    assert exc.value.surviving_hosts == [1]
    # the pre-ejection checkpoint is committed, so re-entry resumes there
    assert mgr.latest_step() == 6


# ---------------------------------------------------------------------------
# straggler escalation ladder
# ---------------------------------------------------------------------------


def test_straggler_policy_escalates_to_eject():
    cfg = StragglerConfig(window=16, slow_factor=1.5, eject_after=3,
                          min_history=4)
    policy = MitigationPolicy(StepTimeTracker(3, cfg))
    decisions = []
    for _ in range(10):
        decisions.append(policy.step([1.0, 1.0, 4.0]).action)
    assert decisions[-1] == "eject"
    assert "warn" in decisions             # warned before ejecting
    assert policy.tracker.to_eject() == [2]


def test_straggler_flags_reset_on_recovery():
    cfg = StragglerConfig(window=8, slow_factor=1.5, eject_after=50,
                          min_history=2)
    tracker = StepTimeTracker(2, cfg)
    policy = MitigationPolicy(tracker)
    for _ in range(4):
        policy.step([1.0, 4.0])
    assert tracker.flagged_streak[1] > 0
    for _ in range(8):                     # host recovers; window flushes
        policy.step([1.0, 1.0])
    assert tracker.flagged_streak[1] == 0


# ---------------------------------------------------------------------------
# elastic restore (checkpoint/elastic.py)
# ---------------------------------------------------------------------------


def test_reshard_restore_no_mesh_roundtrip(tmp_path):
    import jax
    from repro.checkpoint import save_tree
    tree = {"w": np.arange(12, dtype=np.float32).reshape(3, 4),
            "b": np.ones((4,), np.float32)}
    save_tree(str(tmp_path), tree, step=7, meta={"tag": "t"})
    like = {"w": np.zeros((3, 4), np.float32), "b": np.zeros((4,),
                                                            np.float32)}
    got, step, meta = reshard_restore(str(tmp_path), like, mesh=None)
    assert step == 7 and meta["tag"] == "t"
    np.testing.assert_array_equal(np.asarray(got["w"]), tree["w"])
    assert isinstance(got["w"], jax.Array)    # re-placed onto devices


def test_reshard_restore_onto_mesh(tmp_path):
    import jax
    from jax.sharding import Mesh, PartitionSpec
    from repro.checkpoint import save_tree
    tree = {"w": np.arange(8, dtype=np.float32)}
    save_tree(str(tmp_path), tree, step=1)
    mesh = Mesh(np.array(jax.devices()[:1]), ("x",))
    got, step, _ = reshard_restore(str(tmp_path), {"w": np.zeros((8,),
                                                                 np.float32)},
                                   mesh, spec_fn=lambda p, l:
                                   PartitionSpec())
    assert step == 1
    np.testing.assert_array_equal(np.asarray(got["w"]), tree["w"])
