"""Token embeddings and the LM head (tied or separate), plus frontend stubs.

``[audio]`` / ``[vlm]`` archs take *precomputed* frame/patch embeddings per
the assignment: the frontend is a learned projection stub, not a full conv /
ViT tower (see DESIGN.md §4).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro import utils

Params = dict


def embed_init(key: jax.Array, vocab: int, d_model: int, *, tie: bool,
               param_dtype) -> Params:
    ks = jax.random.split(key, 2)
    p: Params = {"tok": utils.truncated_init(ks[0], (vocab, d_model),
                                             1.0 / math.sqrt(d_model), param_dtype)}
    if not tie:
        p["head"] = utils.truncated_init(ks[1], (d_model, vocab),
                                         1.0 / math.sqrt(d_model), param_dtype)
    return p


def embed(params: Params, tokens: jax.Array, accum_dtype=jnp.float32) -> jax.Array:
    return jnp.take(params["tok"], tokens, axis=0).astype(accum_dtype)


def logits(params: Params, x: jax.Array, accum_dtype=jnp.float32) -> jax.Array:
    """x (..., D) -> (..., V) in float32 for a stable softmax/loss."""
    if "head" in params:
        return jnp.einsum("...d,dv->...v", x, params["head"],
                          preferred_element_type=jnp.float32)
    return jnp.einsum("...d,vd->...v", x, params["tok"],
                      preferred_element_type=jnp.float32)


def learned_pos_init(key: jax.Array, max_len: int, d_model: int,
                     param_dtype) -> Params:
    return {"pos": utils.truncated_init(key, (max_len, d_model), 0.02, param_dtype)}


def learned_pos(params: Params, x: jax.Array,
                offset: "int | jax.Array" = 0) -> jax.Array:
    """Add learned position rows.  ``offset`` may be a scalar (whole-batch
    prefix length) or a (B,) vector of per-row offsets — the continuous-
    batching decode path, where slots sit at different positions."""
    S = x.shape[1]
    if getattr(offset, "ndim", 0) == 1:
        pos = jnp.take(params["pos"],
                       offset[:, None] + jnp.arange(S)[None, :], axis=0)
        return x + pos.astype(x.dtype)
    return x + jax.lax.dynamic_slice_in_dim(
        params["pos"], offset, S, axis=0).astype(x.dtype)


def frontend_init(key: jax.Array, kind: str, d_model: int, param_dtype) -> Params:
    """Stub frontends: a learned projection over precomputed embeddings."""
    if kind == "none":
        return {}
    ks = jax.random.split(key, 2)
    return {
        "proj": utils.truncated_init(ks[0], (d_model, d_model),
                                     1.0 / math.sqrt(d_model), param_dtype),
        "bias": jnp.zeros((d_model,), param_dtype),
    }


def frontend(params: Params, embeds: jax.Array, accum_dtype=jnp.float32
             ) -> jax.Array:
    """Precomputed frame/patch embeddings (B, S, D) -> (B, S, D)."""
    if not params:
        return embeds.astype(accum_dtype)
    y = jnp.einsum("bsd,de->bse", embeds.astype(accum_dtype),
                   params["proj"], preferred_element_type=accum_dtype)
    return y + params["bias"].astype(accum_dtype)
