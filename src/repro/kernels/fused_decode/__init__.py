from repro.kernels.fused_decode.kernel import fused_forest_decode
from repro.kernels.fused_decode.ops import (collapse_nodes, fused_decode,
                                            fused_decode_ref)
