"""Pallas TPU kernel: ragged grouped GEMM over capacity-padded leaf groups.

The TPU-native replacement for the paper's CUDA "offset in the data load"
(DESIGN.md §3): tokens are sorted by routed leaf and scattered into padded
per-leaf buffers (E, C, D); the kernel is a tiled matmul whose weight block is
selected *by the grid index* (a static scalar-prefetch index map — the
offset-load equivalent), with compute skipped entirely for empty tiles via the
scalar-prefetched ``group_sizes`` (ragged early-out).

Two variants:
  * ``grouped_matmul``      — y[e] = act(x[e] @ w[e]) for MLP leaves
  * ``grouped_matmul_dual`` — y[e] = silu(x[e] @ wg[e]) * (x[e] @ wu[e]) for
    SwiGLU leaves (both ups fused: x tile loaded once, one pass over D)

Grid: (E, C/bc, H/bh, D/bk), k innermost for accumulation in a VMEM f32
scratch tile (bc, bh).  VMEM per step @ defaults (bc=128, bh=512, bk=512,
bf16): x 128 KiB + w 512 KiB + acc 256 KiB (+dual: 2x w/acc) — double-buffered
by the pipeline well inside budget; block sizes are 128-multiples for the MXU.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_ACTS = {
    "none": lambda x: x,
    "relu": jax.nn.relu,
    "gelu": jax.nn.gelu,
    "silu": jax.nn.silu,
}


def _gmm_kernel(gs_ref, x_ref, w_ref, o_ref, acc_ref, *, act: str,
                block_c: int, out_dtype):
    e = pl.program_id(0)
    c = pl.program_id(1)
    k = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    nonempty = gs_ref[e] > c * block_c

    @pl.when(nonempty)
    def _compute():
        acc_ref[...] += jax.lax.dot_general(
            x_ref[0], w_ref[0], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _flush():
        o_ref[0] = _ACTS[act](acc_ref[...]).astype(out_dtype)


def grouped_matmul(x: jax.Array, w: jax.Array, group_sizes: jax.Array, *,
                   act: str = "none", block_c: int = 128, block_h: int = 512,
                   block_k: int = 512, interpret: bool = False,
                   out_dtype=None) -> jax.Array:
    """x (E, C, D) @ w (E, D, H) -> (E, C, H), skipping empty token tiles."""
    E, C, D = x.shape
    H = w.shape[2]
    out_dtype = out_dtype or x.dtype
    bc = min(block_c, C)
    bh = min(block_h, H)
    bk = min(block_k, D)
    while C % bc:
        bc -= 1
    while H % bh:
        bh -= 1
    while D % bk:
        bk -= 1
    grid = (E, C // bc, H // bh, D // bk)
    return pl.pallas_call(
        functools.partial(_gmm_kernel, act=act, block_c=bc, out_dtype=out_dtype),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, bc, bk), lambda e, c, h, k, gs: (e, c, k)),
                pl.BlockSpec((1, bk, bh), lambda e, c, h, k, gs: (e, k, h)),
            ],
            out_specs=pl.BlockSpec((1, bc, bh), lambda e, c, h, k, gs: (e, c, h)),
            scratch_shapes=[pltpu.VMEM((bc, bh), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((E, C, H), out_dtype),
        interpret=interpret,
    )(group_sizes, x, w)


def _gmm_dual_kernel(gs_ref, x_ref, wg_ref, wu_ref, o_ref, accg_ref, accu_ref,
                     *, block_c: int, out_dtype):
    e = pl.program_id(0)
    c = pl.program_id(1)
    k = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(k == 0)
    def _init():
        accg_ref[...] = jnp.zeros_like(accg_ref)
        accu_ref[...] = jnp.zeros_like(accu_ref)

    nonempty = gs_ref[e] > c * block_c

    @pl.when(nonempty)
    def _compute():
        xt = x_ref[0]
        accg_ref[...] += jax.lax.dot_general(
            xt, wg_ref[0], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        accu_ref[...] += jax.lax.dot_general(
            xt, wu_ref[0], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _flush():
        o_ref[0] = (jax.nn.silu(accg_ref[...]) * accu_ref[...]).astype(out_dtype)


def grouped_matmul_dual(x: jax.Array, wg: jax.Array, wu: jax.Array,
                        group_sizes: jax.Array, *, block_c: int = 128,
                        block_h: int = 512, block_k: int = 512,
                        interpret: bool = False, out_dtype=None) -> jax.Array:
    """SwiGLU up: silu(x @ wg) * (x @ wu), grouped per leaf: -> (E, C, H)."""
    E, C, D = x.shape
    H = wg.shape[2]
    out_dtype = out_dtype or x.dtype
    bc = min(block_c, C)
    bh = min(block_h, H)
    bk = min(block_k, D)
    while C % bc:
        bc -= 1
    while H % bh:
        bh -= 1
    while D % bk:
        bk -= 1
    grid = (E, C // bc, H // bh, D // bk)
    return pl.pallas_call(
        functools.partial(_gmm_dual_kernel, block_c=bc, out_dtype=out_dtype),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, bc, bk), lambda e, c, h, k, gs: (e, c, k)),
                pl.BlockSpec((1, bk, bh), lambda e, c, h, k, gs: (e, k, h)),
                pl.BlockSpec((1, bk, bh), lambda e, c, h, k, gs: (e, k, h)),
            ],
            out_specs=pl.BlockSpec((1, bc, bh), lambda e, c, h, k, gs: (e, c, h)),
            scratch_shapes=[pltpu.VMEM((bc, bh), jnp.float32),
                            pltpu.VMEM((bc, bh), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((E, C, H), out_dtype),
        interpret=interpret,
    )(group_sizes, x, wg, wu)
