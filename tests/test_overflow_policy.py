"""Overflow-policy contract tests (DESIGN.md §14): the first-class
``ExecutionSpec.overflow_policy`` axis on capacity-bounded backends, the
composable ``api.overrides()`` trace-time override surface (plus its
deprecated aliases), the approximate master-leaf repair's error bound
against the exact dense fallback, and the EP repair-traffic accounting.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import api, fff
from repro.distributed import dispatch


def _master_case(seed=0, batch=128, din=16):
    """A master-enabled forest plus a batch large enough that
    capacity_factor=0.25 genuinely drops tokens (expected per-leaf load 16
    vs the floor-clamped capacity of 8)."""
    cfg = fff.FFFConfig(dim_in=din, dim_out=din, depth=3, leaf_width=8,
                        activation="gelu", leaf_bias=False, trees=2,
                        master_leaf=True)
    params = fff.init(jax.random.PRNGKey(seed), cfg)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (batch, din))
    return cfg, params, x


def _apply(params, cfg, x, policy, cf=0.25):
    spec = api.ExecutionSpec(mode="infer", backend="grouped",
                             capacity_factor=cf, overflow_policy=policy)
    return api.apply(params, cfg, x, spec)


# ---------------------------------------------------------------------------
# the policy axis on the grouped backend
# ---------------------------------------------------------------------------

def test_exact_dense_matches_reference_under_overflow():
    """"exact_dense" is the lossless policy: even with real overflow the
    repaired output must equal the capacity-unbounded reference."""
    cfg, p, x = _master_case()
    y, out = _apply(p, cfg, x, "exact_dense")
    assert float(out.overflow_fraction) > 0.1   # the regime is real
    y_ref, _ = api.apply(p, cfg, x, api.ExecutionSpec(mode="infer",
                                                      backend="reference"))
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32),
                               rtol=2e-5, atol=2e-5)


def test_master_leaf_repair_error_bounded():
    """The approximate repair: kept tokens are bit-identical to exact_dense
    (same dispatch), dropped tokens lose one tree's leaf term but keep the
    master + the other tree — mean relative delta stays under 1.0."""
    cfg, p, x = _master_case()
    y_exact = np.asarray(_apply(p, cfg, x, "exact_dense")[0], np.float64)
    y_rep, out = _apply(p, cfg, x, "master_leaf")
    y_rep = np.asarray(y_rep, np.float64)
    rel = (np.linalg.norm(y_rep - y_exact, axis=-1)
           / (np.linalg.norm(y_exact, axis=-1) + 1e-9))
    dropped = rel > 1e-6
    assert dropped.any(), "cf=0.25 produced no dropped tokens"
    assert not dropped.all(), "every token dropped — dispatch is broken"
    assert float(rel[dropped].mean()) < 1.0
    assert float(rel[dropped].max()) < 2.0


def test_master_leaf_equals_drop_numerics_on_grouped():
    """On the single-host grouped backend the master term is added centrally
    for EVERY token, so "master_leaf" and "drop" produce identical arrays —
    the policies differ in validation and serving-metrics accounting, not in
    this layer's math."""
    cfg, p, x = _master_case()
    y_m, _ = _apply(p, cfg, x, "master_leaf")
    y_d, _ = _apply(p, cfg, x, "drop")
    np.testing.assert_array_equal(np.asarray(y_m), np.asarray(y_d))


def test_master_leaf_policy_requires_master_leaf_config():
    cfg = fff.FFFConfig(dim_in=8, dim_out=8, depth=2, leaf_width=4,
                        activation="gelu", leaf_bias=False)
    p = fff.init(jax.random.PRNGKey(0), cfg)
    x = jnp.zeros((16, 8))
    with pytest.raises(ValueError, match="master_leaf"):
        _apply(p, cfg, x, "master_leaf")


def test_spec_rejects_unknown_policy():
    with pytest.raises(ValueError, match="overflow_policy"):
        api.ExecutionSpec(mode="infer", overflow_policy="densely").validate()


def test_default_policies_are_the_historical_behaviours():
    assert api.default_overflow_policy("grouped_ep") == "exact_dense"
    assert api.default_overflow_policy("grouped") == "drop"
    assert api.default_overflow_policy("pallas") == "drop"


# ---------------------------------------------------------------------------
# api.overrides(): composition, nesting, eager validation, aliases
# ---------------------------------------------------------------------------

def test_overrides_sets_any_subset_at_once():
    st = api._thread_state
    with api.overrides(backend="grouped", mode="infer", capacity_factor=4.0,
                       overflow_policy="drop"):
        assert st.override == ("grouped", "infer")
        assert st.capacity_override == 4.0
        assert st.overflow_override == "drop"
    assert getattr(st, "override", None) is None
    assert getattr(st, "capacity_override", None) is None
    assert getattr(st, "overflow_override", None) is None


def test_overrides_nesting_inner_wins_per_field():
    """Each context saves/restores exactly the fields it sets, so unrelated
    fields compose and an inner same-field context wins then restores."""
    st = api._thread_state
    with api.overrides(capacity_factor=2.0):
        with api.overrides(backend="reference"):       # unrelated field
            assert st.capacity_override == 2.0
            assert st.override == ("reference", None)
        with api.overrides(capacity_factor=0.5):       # same field: inner wins
            assert st.capacity_override == 0.5
        assert st.capacity_override == 2.0             # ...and restores
        assert getattr(st, "override", None) is None
    assert getattr(st, "capacity_override", None) is None


def test_overrides_fills_unset_spec_fields_only():
    """The override fills in specs that leave capacity/policy unset; explicit
    per-spec values win (the speculative-verify contract, DESIGN.md §10)."""
    seen = {}
    orig = fff._forward_hard_grouped

    def spy(*a, **kw):
        seen["cf"] = kw["capacity_factor"]
        seen["policy"] = kw["overflow_policy"]
        return orig(*a, **kw)

    cfg, p, x = _master_case(batch=32)
    fff._forward_hard_grouped = spy
    try:
        with api.overrides(capacity_factor=4.0, overflow_policy="master_leaf"):
            api.apply(p, cfg, x, api.ExecutionSpec(mode="infer",
                                                   backend="grouped"))
            assert seen == {"cf": 4.0, "policy": "master_leaf"}
            api.apply(p, cfg, x, api.ExecutionSpec(
                mode="infer", backend="grouped", capacity_factor=1.0,
                overflow_policy="drop"))
            assert seen == {"cf": 1.0, "policy": "drop"}
    finally:
        fff._forward_hard_grouped = orig


def test_overrides_validation_is_eager():
    """Bad arguments raise AT THE CALL, before the with-body runs."""
    with pytest.raises(KeyError, match="any mode"):
        api.overrides(backend="palas")
    with pytest.raises(ValueError, match="mode"):
        api.overrides(backend="grouped", mode="decode")
    with pytest.raises(ValueError, match="backend"):
        api.overrides(mode="infer")                    # mode needs backend
    with pytest.raises(ValueError, match="positive"):
        api.overrides(capacity_factor=0.0)
    with pytest.raises(ValueError, match="overflow_policy"):
        api.overrides(overflow_policy="dense")


def test_deprecated_aliases_warn_and_still_work():
    st = api._thread_state
    for alias, kwargs, attr, want in [
            (api.use_backend, ("reference",), "override", ("reference", None)),
            (api.use_capacity_factor, (3.0,), "capacity_override", 3.0),
            (api.use_overflow_policy, ("drop",), "overflow_override", "drop")]:
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            cm = alias(*kwargs)
        assert any(issubclass(x.category, DeprecationWarning) for x in w), \
            alias.__name__
        assert any("overrides(" in str(x.message) for x in w), alias.__name__
        with cm:
            assert getattr(st, attr) == want
        assert getattr(st, attr, None) is None


# ---------------------------------------------------------------------------
# EP repair-traffic accounting (dispatch.ep_bytes_moved)
# ---------------------------------------------------------------------------

def test_ep_bytes_moved_policy_accounting():
    base = dispatch.ep_bytes_moved(32, 4, 128, 128, 8)
    assert base > 0
    # master_leaf / drop: the repair round is statically absent -> a2a only
    for policy in ("master_leaf", "drop"):
        assert dispatch.ep_bytes_moved(
            32, 4, 128, 128, 8, overflow_policy=policy,
            tokens_per_shard=256) == base
    # exact_dense pays the all_gather + psum repair round on top
    exact = dispatch.ep_bytes_moved(32, 4, 128, 128, 8,
                                    overflow_policy="exact_dense",
                                    tokens_per_shard=256)
    assert exact > base
    # single shard: nothing crosses, any policy
    assert dispatch.ep_bytes_moved(32, 1, 128, 128, 8,
                                   overflow_policy="exact_dense",
                                   tokens_per_shard=256) == 0
