"""Expert-parallel ``grouped_ep`` backend tests (DESIGN.md §5).

Three tiers:
* no-device tests — the dispatch-plan math, the capacity-neutral padding fix
  in ``grouped_leaf_apply`` (B % G != 0 must NOT collapse to G=1) and the
  unsharded degradation of ``grouped_ep`` (always run);
* subprocess tests — the real shard_map + all_to_all path on 8 fake host
  devices, kept out of this process like tests/test_sharding.py (always run);
* direct tests — the same sharded parity in-process, active when the
  interpreter already has >= 8 devices (the CI multi-device job sets
  ``XLA_FLAGS=--xla_force_host_platform_device_count=8``).
"""
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import api, fff, routing
from repro.distributed import act as dist_act
from repro.distributed import dispatch as dispatch_lib

from test_sharding import run_with_fake_devices

multidevice = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs 8 devices (CI multi-device job forces them via XLA_FLAGS)")


def _case(depth=3, trees=2, batch=61, din=16, dout=12, seed=0):
    cfg = fff.FFFConfig(dim_in=din, dim_out=dout, depth=depth, leaf_width=8,
                        activation="gelu", trees=trees, leaf_bias=False)
    params = fff.init(jax.random.PRNGKey(seed), cfg)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (batch, din))
    return cfg, params, x


# ---------------------------------------------------------------------------
# no-device tier
# ---------------------------------------------------------------------------

def test_ep_plan_roundtrip_local():
    """Plan scatter/gather inverts for kept tokens, zeros dropped ones."""
    E, M, C, B, D = 8, 4, 2, 37, 5
    idx = jax.random.randint(jax.random.PRNGKey(0), (B,), 0, E)
    slot = routing.group_slots(idx, E)
    plan = dispatch_lib.make_ep_plan(idx, slot, jnp.ones((B,), bool), E, M, C)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, D))
    send = dispatch_lib.ep_scatter(x, plan)
    assert send.shape == (M, E // M, C, D)
    back = dispatch_lib.ep_gather(send.reshape(E * C, D), plan)
    kept = np.asarray(plan.kept)
    np.testing.assert_allclose(np.asarray(back)[kept], np.asarray(x)[kept])
    assert float(jnp.abs(back[~kept]).max()) == 0.0
    # kept tokens occupy unique slots, within their own leaf's block
    flat = np.asarray(plan.flat_idx)[kept]
    assert len(set(flat.tolist())) == kept.sum()
    np.testing.assert_array_equal(flat // C, np.asarray(idx)[kept])


def test_ep_plan_rejects_indivisible_groups():
    with pytest.raises(ValueError, match="divide"):
        dispatch_lib.make_ep_plan(jnp.zeros((4,), jnp.int32),
                                  jnp.zeros((4,), jnp.int32),
                                  jnp.ones((4,), bool), 6, 4, 2)


def test_grouped_leaf_apply_pads_nondivisible_batch(monkeypatch):
    """B % G != 0 must keep shard-local dispatch via capacity-neutral padding
    (the seed silently collapsed to G=1); padded results match G=1 exactly
    when capacity does not bite."""
    cfg, params, x = _case(trees=1, batch=53)
    leaf_idx = fff.route_hard(params, cfg, x)[:, 0]
    tree = {k: v[0] for k, v in params.items() if k.startswith("leaf_")}
    want = routing.grouped_leaf_apply(x, leaf_idx, tree, "gelu",
                                      capacity_factor=8.0)
    monkeypatch.setattr(dist_act, "data_shard_count", lambda: 4)
    got, kept = routing.grouped_leaf_apply(x, leaf_idx, tree, "gelu",
                                           capacity_factor=8.0,
                                           return_kept=True)
    assert got.shape == want.shape and kept.shape == (53,)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    assert bool(kept.all())  # pad tokens must not surface as overflow


def test_grouped_leaf_apply_padding_is_capacity_neutral(monkeypatch):
    """Pad tokens must not consume real leaves' capacity slots: with all
    real tokens on one leaf and capacity exactly matching their count, none
    may be dropped even though padding shares the shard."""
    E, B, D, H = 4, 13, 8, 4
    key = jax.random.PRNGKey(0)
    params = {"leaf_w1": jax.random.normal(key, (E, D, H)),
              "leaf_w2": jax.random.normal(jax.random.fold_in(key, 1),
                                           (E, H, D))}
    x = jax.random.normal(jax.random.fold_in(key, 2), (B, D))
    leaf_idx = jnp.zeros((B,), jnp.int32)
    monkeypatch.setattr(dist_act, "data_shard_count", lambda: 2)
    # Bg = 7 after padding to 14; capacity floor 8 >= 7 covers every shard
    _, kept = routing.grouped_leaf_apply(x, leaf_idx, params, "gelu",
                                         capacity_factor=8.0,
                                         return_kept=True)
    assert bool(kept.all())


def test_grouped_ep_unsharded_degradation_exact():
    """With no mesh installed grouped_ep degrades to local dispatch + dense
    repair — still exact under capacity pressure, real overflow reported."""
    cfg, params, x = _case()
    want, wout = api.apply(params, cfg, x, api.ExecutionSpec(
        mode="infer", backend="reference"))
    got, out = api.apply(params, cfg, x, api.ExecutionSpec(
        mode="infer", backend="grouped_ep", capacity_factor=0.25))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(out.leaf_idx),
                                  np.asarray(wout.leaf_idx))
    assert float(out.overflow_fraction) > 0.0  # the bound actually bit


def test_grouped_ep_capacity_default():
    """spec.capacity_factor=None must hand grouped_ep its own (Switch-style)
    default, not the generic serving default."""
    seen = {}
    orig = fff._forward_hard_ep

    def spy(*a, **kw):
        seen["cf"] = kw["capacity_factor"]
        return orig(*a, **kw)

    cfg, params, x = _case(batch=16)
    fff._forward_hard_ep = spy
    try:
        api.apply(params, cfg, x, api.ExecutionSpec(mode="infer",
                                                    backend="grouped_ep"))
    finally:
        fff._forward_hard_ep = orig
    assert seen == {"cf": api.DEFAULT_CAPACITY_EP}


# ---------------------------------------------------------------------------
# subprocess tier: real shard_map + all_to_all on 8 fake host devices
# ---------------------------------------------------------------------------

def test_ep_sharded_parity_and_auto_resolution():
    """On a (2 data, 4 model) mesh: auto resolves to grouped_ep, outputs
    match the reference to fp32 tolerance with B % (G*M) != 0, and skewed
    routing stays exact through the overflow-to-dense repair."""
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import api, fff
        from repro.distributed import act, sharding
        from repro.launch import mesh as mesh_lib

        cfg = fff.FFFConfig(dim_in=16, dim_out=12, depth=3, leaf_width=8,
                            activation="gelu", trees=2, leaf_bias=False)
        params = fff.init(jax.random.PRNGKey(0), cfg)
        mesh = mesh_lib.make_mesh((2, 4), ("data", "model"))
        rules = sharding.activation_rules(mesh)

        for tag, shift, batch in (("uniform", 0.0, 61), ("skewed", 50.0, 509)):
            p = dict(params)
            p["node_b2"] = params["node_b2"] + shift
            x = jax.random.normal(jax.random.PRNGKey(1), (batch, 16))
            want, wout = api.apply(p, cfg, x, api.ExecutionSpec(
                mode="infer", backend="reference"))
            p_sh = sharding.shard_params(p, mesh, fsdp=False)
            with act.use_mesh(mesh, rules):
                assert api._resolve_auto(p, cfg, "infer") == "grouped_ep"
                got, out = jax.jit(lambda pp, xx: api.apply(
                    pp, cfg, xx,
                    api.ExecutionSpec(mode="infer", backend="grouped_ep")))(
                        p_sh, x)
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       rtol=1e-5, atol=1e-5)
            np.testing.assert_array_equal(np.asarray(out.leaf_idx),
                                          np.asarray(wout.leaf_idx))
            print(tag, "overflow", float(out.overflow_fraction))
        print("PARITY_OK")
    """)
    out = run_with_fake_devices(code)
    assert "PARITY_OK" in out
    skew_overflow = float(out.split("skewed overflow")[1].split()[0])
    assert skew_overflow > 0.3  # the repair path actually ran


def test_ep_sharded_batch_not_divisible_by_data_shards():
    """Parity for B not divisible by the data-shard count (and by G*M) —
    both the grouped and grouped_ep backends must pad, not collapse."""
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import api, fff
        from repro.distributed import act, sharding
        from repro.launch import mesh as mesh_lib

        cfg = fff.FFFConfig(dim_in=16, dim_out=12, depth=3, leaf_width=8,
                            activation="gelu", trees=1, leaf_bias=False)
        params = fff.init(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (37, 16))  # 37 % 8 != 0
        want, _ = api.apply(params, cfg, x, api.ExecutionSpec(
            mode="infer", backend="reference"))
        mesh = mesh_lib.make_mesh((4, 2), ("data", "model"))
        rules = sharding.activation_rules(mesh)
        p_sh = sharding.shard_params(params, mesh, fsdp=False)
        with act.use_mesh(mesh, rules):
            for backend in ("grouped_ep", "grouped"):
                got, _ = jax.jit(lambda pp, xx: api.apply(
                    pp, cfg, xx, api.ExecutionSpec(
                        mode="infer", backend=backend,
                        capacity_factor=8.0)))(p_sh, x)
                np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                           rtol=1e-5, atol=1e-5)
        print("OK")
    """)
    assert "OK" in run_with_fake_devices(code)


def test_ep_serve_driver_end_to_end():
    """launch/serve.py --model-parallel 4 --fff-backend grouped_ep runs the
    whole stack (prefill + decode) over the EP mesh."""
    code = textwrap.dedent("""
        import sys
        sys.argv = ["serve", "--arch", "internlm2-20b", "--reduced",
                    "--batch", "4", "--prompt-len", "16", "--gen", "3",
                    "--fff-backend", "grouped_ep", "--model-parallel", "4"]
        from repro.launch import serve
        serve.main()
    """)
    out = run_with_fake_devices(code)
    assert "decode" in out and "expert-parallel serving" in out


# ---------------------------------------------------------------------------
# direct tier: runs when the process already owns >= 8 devices (CI job)
# ---------------------------------------------------------------------------

@multidevice
@pytest.mark.parametrize("batch", [64, 61])
def test_ep_sharded_parity_direct(batch):
    from repro.distributed import sharding
    from repro.launch import mesh as mesh_lib

    cfg, params, x = _case(batch=batch)
    want, wout = api.apply(params, cfg, x, api.ExecutionSpec(
        mode="infer", backend="reference"))
    mesh = mesh_lib.make_mesh((2, 4), ("data", "model"))
    rules = sharding.activation_rules(mesh)
    p_sh = sharding.shard_params(params, mesh, fsdp=False)
    with dist_act.use_mesh(mesh, rules):
        got, out = jax.jit(lambda p, xx: api.apply(
            p, cfg, xx, api.ExecutionSpec(mode="infer",
                                          backend="grouped_ep")))(p_sh, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(out.leaf_idx),
                                  np.asarray(wout.leaf_idx))


@multidevice
def test_ep_sharded_skew_exact_direct():
    from repro.distributed import sharding
    from repro.launch import mesh as mesh_lib

    cfg, params, x = _case(batch=509)
    params = dict(params)
    params["node_b2"] = params["node_b2"] + 50.0   # all-right routing skew
    want, _ = api.apply(params, cfg, x, api.ExecutionSpec(
        mode="infer", backend="reference"))
    mesh = mesh_lib.make_mesh((2, 4), ("data", "model"))
    rules = sharding.activation_rules(mesh)
    p_sh = sharding.shard_params(params, mesh, fsdp=False)
    with dist_act.use_mesh(mesh, rules):
        got, out = jax.jit(lambda p, xx: api.apply(
            p, cfg, xx, api.ExecutionSpec(mode="infer",
                                          backend="grouped_ep")))(p_sh, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    assert float(out.overflow_fraction) > 0.3
