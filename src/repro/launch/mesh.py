"""Production meshes.

``make_production_mesh`` builds the assignment's target topology as a
FUNCTION (importing this module never touches jax device state):
  single-pod:  (16, 16)    axes (data, model)        = 256 chips (one v5e pod)
  multi-pod:   (2, 16, 16) axes (pod, data, model)   = 512 chips

The ``pod`` axis composes with ``data`` everywhere batch/FSDP sharding is
expressed — model code never names a pod, so scaling to N pods is a mesh-shape
change only.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh

try:  # jax >= 0.5 makes axis types explicit; older jax is Auto-only anyway
    from jax.sharding import AxisType

    def _axis_types(n: int) -> dict:
        return {"axis_types": (AxisType.Auto,) * n}
except ImportError:  # pragma: no cover - depends on installed jax
    def _axis_types(n: int) -> dict:
        return {}


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_types(len(axes)))


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]) -> Mesh:
    """Arbitrary mesh (tests / elastic re-mesh)."""
    return jax.make_mesh(shape, axes, **_axis_types(len(axes)))


def make_host_mesh() -> Mesh:
    """Whatever devices exist, as a 1-D data mesh (CPU tests, examples)."""
    n = jax.device_count()
    return jax.make_mesh((n,), ("data",), **_axis_types(1))


def make_serving_mesh(model_parallel: int = 1) -> Mesh:
    """Whatever devices exist, as a (data, model) mesh for expert-parallel
    serving (the grouped_ep backend's all_to_all runs over the model axis;
    DESIGN.md §5).  ``model_parallel`` must divide the device count."""
    n = jax.device_count()
    if model_parallel < 1 or n % model_parallel:
        raise ValueError(
            f"model_parallel={model_parallel} must divide {n} devices")
    return jax.make_mesh((n // model_parallel, model_parallel),
                         ("data", "model"), **_axis_types(2))


def serving_context(model_parallel: int = 1):
    """The serving topology as an installable pair: ``(mesh, trace_ctx)``.

    ``trace_ctx()`` is a zero-arg context-manager factory entering
    ``act.use_mesh(mesh, rules)`` — the shape both ``launch/serve.py`` paths
    and the continuous-batching engine (``serving.engine``) wrap every traced
    call in.  With ``model_parallel <= 1`` returns ``(None, nullcontext)`` so
    callers need no branching."""
    import contextlib

    if model_parallel <= 1:
        return None, contextlib.nullcontext
    from repro.distributed import act, sharding
    mesh = make_serving_mesh(model_parallel)
    rules = sharding.activation_rules(mesh)

    def trace_ctx():
        return act.use_mesh(mesh, rules)

    return mesh, trace_ctx


def data_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def num_chips(mesh: Mesh) -> int:
    return mesh.devices.size
