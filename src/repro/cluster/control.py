"""Cluster monitor: liveness, restart budget, elastic autoscaling
(DESIGN.md §12).

``ClusterMonitor`` is a pure decision loop: the router feeds it heartbeats
and the current worker views, and ``tick()`` returns *actions* for the
router to execute — the monitor never touches the bus, so every policy
(timeout, backoff, watermark) unit-tests against a ``VirtualClock`` with
zero sleeps.

Three sub-policies:

* **Liveness** — a worker whose last heartbeat is older than
  ``heartbeat_timeout`` is declared dead (``MarkDead``).  Respawns go
  through a per-role ``RestartBackoff`` (distributed/fault.py): each death
  spends one restart from the budget and schedules a ``Respawn`` after the
  exponential delay; an exhausted budget stops respawning that role and
  the router surfaces the stall in metrics instead of flapping.
* **Straggler escalation** — heartbeat *intervals* feed the training
  stack's ``MitigationPolicy`` (distributed/straggler.py): a worker that
  heartbeats persistently slower than the fleet p50 is demoted to
  draining (``DrainWorker``) before it becomes a timeout — the serving
  analogue of ejecting a slow host from the training mesh.
* **Elastic watermarks** — queue depth and decode-fleet pages_free are
  EWMA-smoothed; sustained pressure (queue above ``scale_up_watermark``,
  or free-page fraction under ``pages_free_low_frac``) emits
  ``SpawnDecode``, and a slack fleet (queue under ``scale_down_watermark``
  with all decode workers near-idle) drains the highest-wid decode worker.
  A cooldown and ``min_decode``/``max_decode`` bounds stop oscillation.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

from repro.cluster.placement import WorkerView
from repro.distributed.fault import RestartBackoff
from repro.distributed.straggler import (MitigationPolicy, StepTimeTracker,
                                         StragglerConfig)


# -- actions the router executes ------------------------------------------

@dataclasses.dataclass
class MarkDead:
    """Heartbeat timeout: drop the worker, replay its in-flight work."""
    wid: str


@dataclasses.dataclass
class Respawn:
    """Start a replacement worker for ``role`` (backoff delay elapsed)."""
    role: str


@dataclasses.dataclass
class SpawnDecode:
    """Elastic scale-up: add a decode worker."""


@dataclasses.dataclass
class DrainWorker:
    """Elastic scale-down / straggler demotion: drain ``wid`` gracefully."""
    wid: str
    reason: str = "scale_down"


@dataclasses.dataclass
class ControlConfig:
    heartbeat_timeout: float = 1.0
    max_restarts: int = 3
    backoff_base: float = 0.0       # 0: respawn on the next tick (tests)
    backoff_factor: float = 2.0
    straggler: Optional[StragglerConfig] = None   # None disables escalation
    # elastic watermarks (smoothed): queue depth in requests, pages as a
    # fraction of the decode fleet's total
    scale_up_watermark: float = 4.0
    scale_down_watermark: float = 0.5
    pages_free_low_frac: float = 0.1
    watermark_ewma: float = 0.3
    scale_cooldown: float = 2.0
    min_decode: int = 1
    max_decode: int = 8


class ClusterMonitor:
    def __init__(self, cfg: ControlConfig, clock: Callable[[], float]):
        self.cfg = cfg
        self.clock = clock
        self._backoff: Dict[str, RestartBackoff] = {}   # per role
        self._pending_respawn: List[tuple] = []          # (due_t, role)
        self._last_beat: Dict[str, float] = {}
        self._beat_hist: Dict[str, List[float]] = {}     # recent intervals
        self._queue_ewma: Optional[float] = None
        self._pages_ewma: Optional[float] = None
        self._last_scale_t: Optional[float] = None
        self._dead: set = set()
        self._straggler_wids: tuple = ()
        self._straggler_policy: Optional[MitigationPolicy] = None
        self.scale_events: List[dict] = []

    def _role_backoff(self, role: str) -> RestartBackoff:
        if role not in self._backoff:
            self._backoff[role] = RestartBackoff(
                self.cfg.max_restarts, self.cfg.backoff_base,
                self.cfg.backoff_factor)
        return self._backoff[role]

    def observe_heartbeat(self, wid: str, t: float) -> None:
        prev = self._last_beat.get(wid)
        if prev is not None and t > prev:
            hist = self._beat_hist.setdefault(wid, [])
            hist.append(t - prev)
            if len(hist) > 64:
                del hist[:-64]
        self._last_beat[wid] = t

    def forget(self, wid: str) -> None:
        """Worker left (death or drain-complete): drop its liveness state."""
        self._last_beat.pop(wid, None)
        self._beat_hist.pop(wid, None)
        self._dead.discard(wid)

    # -- policy ticks ------------------------------------------------------

    def _liveness(self, views: Dict[str, WorkerView], now: float) -> list:
        actions = []
        for wid in sorted(views):
            if wid in self._dead:
                continue
            seen = self._last_beat.get(wid, views[wid].last_seen)
            if now - seen > self.cfg.heartbeat_timeout:
                self._dead.add(wid)
                actions.append(MarkDead(wid))
                delay = self._role_backoff(views[wid].role).next_delay()
                if delay is not None:
                    self._pending_respawn.append((now + delay,
                                                  views[wid].role))
        due = [r for t, r in self._pending_respawn if t <= now]
        self._pending_respawn = [(t, r) for t, r in self._pending_respawn
                                 if t > now]
        actions.extend(Respawn(r) for r in due)
        return actions

    def _stragglers(self, views: Dict[str, WorkerView]) -> list:
        cfg = self.cfg.straggler
        if cfg is None:
            return []
        wids = tuple(w for w in sorted(views)
                     if w not in self._dead and not views[w].draining)
        if len(wids) < 2:
            return []
        if wids != self._straggler_wids:
            # membership changed: fresh tracker (streaks restart — a new
            # fleet shape resets what "slow relative to the fleet" means)
            self._straggler_wids = wids
            self._straggler_policy = MitigationPolicy(
                StepTimeTracker(len(wids), cfg))
        sample = []
        for w in wids:
            hist = self._beat_hist.get(w)
            if not hist:
                return []          # wait until every member has an interval
            sample.append(hist[-1])
        decision = self._straggler_policy.step(sample)
        if decision.action != "eject":
            return []
        return [DrainWorker(wids[h], reason="straggler")
                for h in decision.hosts if views[wids[h]].role == "decode"]

    def _elastic(self, views: Dict[str, WorkerView], queue_depth: int,
                 now: float) -> list:
        a = self.cfg.watermark_ewma
        decode = [v for v in views.values()
                  if v.role == "decode" and v.wid not in self._dead
                  and not v.draining]
        if not decode:
            return []
        total = sum(v.pages_total for v in decode)
        free_frac = (sum(v.pages_free for v in decode) / total) if total \
            else 1.0
        q = float(queue_depth + sum(v.queue_depth for v in decode))
        self._queue_ewma = q if self._queue_ewma is None else \
            (1 - a) * self._queue_ewma + a * q
        self._pages_ewma = free_frac if self._pages_ewma is None else \
            (1 - a) * self._pages_ewma + a * free_frac
        if self._last_scale_t is not None and \
                now - self._last_scale_t < self.cfg.scale_cooldown:
            return []
        if (self._queue_ewma > self.cfg.scale_up_watermark
                or self._pages_ewma < self.cfg.pages_free_low_frac) \
                and len(decode) < self.cfg.max_decode:
            self._last_scale_t = now
            self.scale_events.append(
                {"t": now, "action": "scale_up",
                 "queue_ewma": self._queue_ewma,
                 "pages_free_ewma": self._pages_ewma})
            return [SpawnDecode()]
        idle = all(v.active_slots == 0 and v.queue_depth == 0
                   for v in decode)
        if self._queue_ewma < self.cfg.scale_down_watermark and idle \
                and len(decode) > self.cfg.min_decode:
            victim = max(v.wid for v in decode)
            self._last_scale_t = now
            self.scale_events.append(
                {"t": now, "action": "scale_down", "wid": victim,
                 "queue_ewma": self._queue_ewma,
                 "pages_free_ewma": self._pages_ewma})
            return [DrainWorker(victim, reason="scale_down")]
        return []

    def tick(self, views: Dict[str, WorkerView], queue_depth: int) -> list:
        """One monitor pass → ordered action list for the router."""
        now = self.clock()
        actions = self._liveness(views, now)
        actions.extend(self._stragglers(views))
        actions.extend(self._elastic(views, queue_depth, now))
        return actions
