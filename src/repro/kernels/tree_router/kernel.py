"""Pallas TPU kernel: fused FFF tree descent (FORWARD_I routing).

TPU adaptation of the paper's per-token offset loads (DESIGN.md §3): the node
weight matrix of the whole tree lives in VMEM; ONE MXU matmul computes every
node logit for the token tile, and the d-level descent then runs entirely on
registers/VMEM with ``take_along_axis`` (a sublane dynamic gather) — no HBM
traffic per level.

For node counts where the full matrix no longer pays off (deep trees), ops.py
caps the dense phase at ``dense_levels`` and finishes the descent with the
pure-JAX gather path; the crossover arithmetic is worked out in DESIGN.md §8
and measured in EXPERIMENTS.md §Perf.

Grid: (B // block_b,).  VMEM per step: block_b*D (x tile) + N*D (node weights)
+ block_b*N (logits); with the default block_b=256, d=6, D=7168, bf16 that is
3.5 MiB + 0.9 MiB + 32 KiB — comfortably inside the ~16 MiB VMEM budget.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _router_kernel(x_ref, nw_ref, nb_ref, idx_ref, *, depth: int):
    x = x_ref[...]                                           # (bB, D)
    nw = nw_ref[...]                                         # (N, D)
    logits = jax.lax.dot_general(
        x, nw, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)                  # (bB, N)
    logits = logits + nb_ref[...][None, :].astype(jnp.float32)
    bB = x.shape[0]
    idx = jnp.zeros((bB, 1), jnp.int32)
    off = 0
    for m in range(depth):
        level = logits[:, off:off + 2 ** m]                  # (bB, 2^m) static
        cur = jnp.take_along_axis(level, idx, axis=1)        # (bB, 1)
        idx = 2 * idx + (cur >= 0.0).astype(jnp.int32)
        off += 2 ** m
    idx_ref[...] = idx[:, 0]


def tree_router(x: jax.Array, node_w: jax.Array, node_b: jax.Array, *,
                depth: int, block_b: int = 256,
                interpret: bool = False) -> jax.Array:
    """x (B, D), node_w (N, D), node_b (N,) with N = 2^depth - 1 -> (B,) int32
    leaf indices.  B must be a multiple of block_b (ops.py pads)."""
    B, D = x.shape
    N = node_w.shape[0]
    assert N == 2 ** depth - 1, (N, depth)
    assert B % block_b == 0, (B, block_b)
    grid = (B // block_b,)
    return pl.pallas_call(
        functools.partial(_router_kernel, depth=depth),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, D), lambda i: (i, 0)),
            pl.BlockSpec((N, D), lambda i: (0, 0)),
            pl.BlockSpec((N,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((B,), jnp.int32),
        interpret=interpret,
    )(x, node_w, node_b)
