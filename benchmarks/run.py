"""Benchmark harness: one module per paper table/figure, each printing
``name,us_per_call,derived`` CSV rows.

  table1 -> paper Table 1 (FFF vs FF across widths/leaf sizes, M_A/G_A/speedup)
  fig2   -> paper Figure 2 (equal inference size comparison)
  table2 -> paper Table 2 (FFF vs MoE vs FF + epochs-to-train)
  fig34  -> paper Figures 3-4 (mechanism latency scaling, BERT dims)
  table3 -> paper Table 3 (ViT with FFF layers)
  roofline -> formats the dry-run roofline artifact AND measures the fused
             decode megakernel vs the 3-dispatch kernel path at decode
             shape, asserting the one-pallas_call dispatch contract
             (DESIGN.md §13; writes BENCH_roofline.json)
  ep_dispatch -> grouped_ep dispatch-locality curve: tokens/s, per-shard
                 capacity and bytes moved vs model-shard count, plus the
                 overflow-policy traffic gate (master_leaf repair bytes == 0,
                 exact_dense pays a real round) (DESIGN.md §5, §14)
  serving -> continuous-batching engine under Poisson load, fcfs vs
             leaf_aware admission: throughput / TTFT / per-token latency /
             overflow_fraction; plus the capacity<1.0 overflow-policy
             sections — master_leaf-vs-exact_dense decode tok/s gate,
             balanced-vs-unbalanced training overflow gate, approximate-
             repair error bound (DESIGN.md §9, §14; writes
             BENCH_serving_load.json)
  serving_chunked -> chunked vs monolithic prefill under long-prompt
             arrivals: decode-interval p99 / throughput / TTFT
             (DESIGN.md §9; writes BENCH_serving_chunked.json)
  serving_qos -> multi-tenant weighted-fair admission + online routing
             profiles on a skewed two-tenant workload: fairness vs
             weights, profile convergence, overflow vs no-hint fcfs
             (DESIGN.md §9; writes BENCH_serving_qos.json)
  serving_spec -> speculative decoding vs plain decode on a drafter-
             consistent deep target: tokens/s speedup gate, acceptance,
             verify-slab overflow vs baseline
             (DESIGN.md §10; writes BENCH_serving_spec.json)
  serving_paged -> paged KV cache + cross-request prefix sharing vs the
             contiguous cache on a shared-system-prompt workload:
             prefill-token ratio gate, TTFT, exact parity, compile contract
             (DESIGN.md §11; writes BENCH_serving_paged.json)
  serving_cluster -> disaggregated prefill/decode cluster vs one colocated
             engine at equal total slots: throughput gate, worker-kill
             replay with exact parity, elastic scale-up/down, per-role
             compile contract (DESIGN.md §12; writes
             BENCH_serving_cluster.json)

``python -m benchmarks.run`` runs the quick profile (CPU-sized, ~minutes);
``python -m benchmarks.run --full`` runs the paper-scale grids.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale grids (slow)")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: table1,fig2,table2,fig34,"
                         "table3,roofline,ep_dispatch,serving,"
                         "serving_chunked,serving_qos,serving_spec,"
                         "serving_paged,serving_cluster")
    args = ap.parse_args()

    from benchmarks import (ep_dispatch, fig2, fig34, roofline_bench,
                            serving_chunked, serving_cluster, serving_load,
                            serving_paged, serving_qos, serving_spec,
                            table1, table2, table3)
    suites = {
        "table1": table1.main,
        "fig2": fig2.main,
        "table2": table2.main,
        "fig34": fig34.main,
        "table3": table3.main,
        "roofline": roofline_bench.main,
        "ep_dispatch": ep_dispatch.main,
        "serving": serving_load.main,
        "serving_chunked": serving_chunked.main,
        "serving_qos": serving_qos.main,
        "serving_spec": serving_spec.main,
        "serving_paged": serving_paged.main,
        "serving_cluster": serving_cluster.main,
    }
    selected = (args.only.split(",") if args.only else list(suites))
    failures = []
    for name in selected:
        t0 = time.time()
        print(f"# === {name} ===", flush=True)
        try:
            suites[name](quick=not args.full)
        except Exception as e:                       # noqa: BLE001
            traceback.print_exc()
            failures.append((name, e))
        print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
    if failures:
        print(f"# FAILURES: {[n for n, _ in failures]}")
        sys.exit(1)


if __name__ == "__main__":
    main()
