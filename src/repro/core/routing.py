"""Token -> leaf dispatch machinery for FFF serving on TPU.

The paper's CUDA implementation exploits per-token offset loads.  On TPU the
equivalent-cost primitive is *sorted dispatch*: sort tokens by their routed
leaf id, run a ragged grouped GEMM over contiguous per-leaf token runs, and
scatter results back (DESIGN.md §3).  This module provides the host-side
dispatch plan; the GEMM itself lives in ``repro.kernels.leaf_gemm``.

Also provides Switch-style capacity-bounded dispatch (with an optional
overflow-to-dense fallback) used when serving under adversarial routing skew.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro import utils
from repro.distributed import act as dist_act
from repro.distributed import dispatch as dispatch_lib


class SortedDispatch(NamedTuple):
    """A plan for grouped execution of tokens sorted by leaf id.

    sort_idx:    (B,) permutation; x_sorted = x[sort_idx]
    unsort_idx:  (B,) inverse permutation
    group_sizes: (E,) tokens routed to each leaf (sums to B)
    group_offsets: (E+1,) exclusive prefix sums of group_sizes
    leaf_ids_sorted: (B,) leaf id per sorted slot
    """
    sort_idx: jax.Array
    unsort_idx: jax.Array
    group_sizes: jax.Array
    group_offsets: jax.Array
    leaf_ids_sorted: jax.Array


def make_sorted_dispatch(leaf_idx: jax.Array, num_leaves: int) -> SortedDispatch:
    """Build the sorted-dispatch plan from per-token leaf ids (B,)."""
    B = leaf_idx.shape[0]
    sort_idx = jnp.argsort(leaf_idx, stable=True)
    leaf_sorted = jnp.take(leaf_idx, sort_idx)
    unsort_idx = jnp.argsort(sort_idx)
    group_sizes = jnp.bincount(leaf_idx, length=num_leaves)
    group_offsets = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(group_sizes).astype(jnp.int32)])
    return SortedDispatch(sort_idx.astype(jnp.int32), unsort_idx.astype(jnp.int32),
                          group_sizes.astype(jnp.int32), group_offsets,
                          leaf_sorted.astype(jnp.int32))


def apply_sorted(x: jax.Array, plan: SortedDispatch) -> jax.Array:
    return jnp.take(x, plan.sort_idx, axis=0)


def unapply_sorted(y_sorted: jax.Array, plan: SortedDispatch) -> jax.Array:
    return jnp.take(y_sorted, plan.unsort_idx, axis=0)


# ---------------------------------------------------------------------------
# capacity-bounded dispatch (Switch-transformer style; beyond-paper hardening
# of FFF serving against routing skew)
# ---------------------------------------------------------------------------

class CapacityDispatch(NamedTuple):
    """Scatter/gather dispatch plan bounded by per-leaf capacity C.

    Slots come from ``group_slots`` sort ranks and the plan stores flat
    buffer positions, not a dense (B, E, C) one-hot: the seed implementation
    built exactly the ``cumsum(one_hot)`` + dense-dispatch-tensor pattern
    DESIGN.md §5 bans (O(B^2) reduce-window cumsum, O(B*E*C*D) dispatch
    einsums — the FLOP regression guard in tests/test_fff_core.py pins the
    fix).

    flat_idx: (B,) int32 position ``leaf*C + slot`` in the flattened (E*C,)
              buffer; dropped tokens carry the out-of-bounds sentinel E*C
    kept:     (B,) bool; False = token overflowed its leaf's capacity
    """
    flat_idx: jax.Array
    kept: jax.Array
    capacity: int
    num_leaves: int


def _as_ep_plan(plan: CapacityDispatch) -> dispatch_lib.EPPlan:
    """A CapacityDispatch IS the single-shard special case of the EP
    exchange plan; delegate the scatter/gather to one implementation."""
    return dispatch_lib.EPPlan(plan.flat_idx, plan.kept, plan.capacity,
                               plan.num_leaves, 1)


def make_capacity_dispatch(leaf_idx: jax.Array, num_leaves: int,
                           capacity_factor: float = 1.25) -> CapacityDispatch:
    B = leaf_idx.shape[0]
    capacity = max(1, int(capacity_factor * utils.cdiv(B, num_leaves)))
    slot = group_slots(leaf_idx, num_leaves)
    p = dispatch_lib.make_ep_plan(leaf_idx, slot,
                                  jnp.ones((B,), bool), num_leaves,
                                  num_shards=1, capacity=capacity)
    return CapacityDispatch(p.flat_idx, p.kept, capacity, num_leaves)


def capacity_gather(x: jax.Array, plan: CapacityDispatch) -> jax.Array:
    """x (B, D) -> per-leaf buffers (E, C, D); O(B) scatter, no dispatch
    einsum."""
    return dispatch_lib.ep_scatter(x, _as_ep_plan(plan))[0]


def capacity_scatter(y: jax.Array, plan: CapacityDispatch) -> jax.Array:
    """(E, C, O) -> (B, O); dropped tokens receive zeros (caller may fall back
    to a dense path for them — overflow-to-dense, DESIGN.md §8)."""
    E, C, O = y.shape
    return dispatch_lib.ep_gather(y.reshape(E * C, O), _as_ep_plan(plan))


# ---------------------------------------------------------------------------
# grouped leaf execution over a sorted plan (pure-jnp reference; the Pallas
# ragged GEMM in kernels/leaf_gemm implements the same contract)
# ---------------------------------------------------------------------------

def grouped_leaf_matmul_ref(x_sorted: jax.Array, leaf_ids_sorted: jax.Array,
                            w: jax.Array) -> jax.Array:
    """Reference grouped GEMM: y[i] = x_sorted[i] @ w[leaf_ids_sorted[i]].

    x_sorted (B, D), w (E, D, H) -> (B, H).  O(B*D*H) with a per-token gather
    of the weight block — the oracle for kernels/leaf_gemm.
    """
    w_g = jnp.take(w, leaf_ids_sorted, axis=0)          # (B, D, H)
    return jnp.einsum("bd,bdh->bh", x_sorted, w_g,
                      preferred_element_type=jnp.float32)


def group_slots(leaf_idx: jax.Array, num_groups: int) -> jax.Array:
    """Per-token slot index within its routed group, O(B log B).

    slot[i] = |{j : leaf[j] == leaf[i], j < i in sorted order}| — computed
    from sort ranks: rank_in_sorted(i) - group_offset(leaf[i])."""
    B = leaf_idx.shape[0]
    sort_idx = jnp.argsort(leaf_idx, stable=True)
    rank = jnp.zeros((B,), jnp.int32).at[sort_idx].set(
        jnp.arange(B, dtype=jnp.int32))
    sizes = jnp.bincount(leaf_idx, length=num_groups)
    offsets = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                               jnp.cumsum(sizes)[:-1].astype(jnp.int32)])
    return rank - jnp.take(offsets, leaf_idx)


def _leaf_mlp_on_buffers(xbuf: jax.Array, params: dict, activation: str,
                         accum_dtype) -> jax.Array:
    """Per-leaf MLP on capacity-padded buffers: (..., E, C, D) -> (..., E, C,
    O).  Shared by the data-local and expert-parallel dispatchers; ``params``
    holds single-tree leaf weights keyed on the SAME leading E axis as
    ``xbuf`` (the EP caller passes the model-axis shard of both)."""
    ad = accum_dtype
    if "leaf_wg" in params:
        g = jnp.einsum("...ecd,edh->...ech", xbuf, params["leaf_wg"],
                       preferred_element_type=ad)
        u = jnp.einsum("...ecd,edh->...ech", xbuf, params["leaf_wu"],
                       preferred_element_type=ad)
        return jnp.einsum("...ech,eho->...eco", jax.nn.silu(g) * u,
                          params["leaf_wd"], preferred_element_type=ad)
    h = jnp.einsum("...ecd,edh->...ech", xbuf, params["leaf_w1"],
                   preferred_element_type=ad)
    if "leaf_b1" in params:
        h = h + params["leaf_b1"][:, None].astype(ad)
    h = utils.get_activation(activation)(h)
    y = jnp.einsum("...ech,eho->...eco", h, params["leaf_w2"],
                   preferred_element_type=ad)
    if "leaf_b2" in params:
        y = y + params["leaf_b2"][:, None].astype(ad)
    return y


def _pad_tokens(x: jax.Array, leaf_idx: jax.Array, multiple: int,
                num_leaves: int) -> tuple[jax.Array, jax.Array]:
    """Pad the token axis up to ``multiple`` with capacity-neutral tokens.

    Pad tokens carry the out-of-range leaf id E: they sort into a virtual
    group past every real leaf (``group_slots(..., E + 1)``), so they never
    occupy a real leaf's capacity slot, scatter out of bounds, and gather
    zeros.  Callers slice results back to the true token count.  Padding is
    a zeros/full-buffer update, not a concatenate — see
    ``fff._pad_for_dispatch`` on the SPMD mis-lowering of token-axis
    concatenates."""
    B = x.shape[0]
    Bp = utils.round_up(max(B, 1), multiple)
    if Bp == B:
        return x, leaf_idx
    xb = jnp.zeros((Bp,) + x.shape[1:], x.dtype).at[:B].set(x)
    ib = jnp.full((Bp,), num_leaves, leaf_idx.dtype).at[:B].set(leaf_idx)
    return xb, ib


def grouped_leaf_apply(x: jax.Array, leaf_idx: jax.Array, params: dict,
                       activation: str, capacity_factor: float = 1.5,
                       accum_dtype=jnp.float32, serving: bool = False,
                       return_kept: bool = False):
    """Differentiable capacity-bounded grouped leaf execution (pure jnp).

    The scale path for both ST training and batched serving of MoE-sized FFF
    layers.  LOCAL dispatch semantics (DESIGN.md §5, §Perf iter 1): the token
    axis is blocked by the data-shard count G so every scatter/gather stays
    shard-local under SPMD — capacity is per (shard, leaf), exactly like a
    production MoE.  When B is not a multiple of G the token axis is padded
    with capacity-neutral tokens (the seed silently collapsed to G=1, i.e.
    fully non-local dispatch, for every such batch).  Per-leaf GEMMs are
    batched over (G-data, E-model); the only cross-shard traffic is what the
    leaf-weight sharding itself implies.

    Tokens over their shard's capacity contribute zeros (standard MoE-style
    drop; exactness, when needed, comes from the overflow-to-dense fallback —
    kernels/leaf_gemm for the Pallas path, grouped_leaf_apply_ep for EP).

    x (B, D); params: single-tree leaf weights {leaf_w1/leaf_w2} or
    {leaf_wg/leaf_wu/leaf_wd}; returns (B, dim_out), or with
    ``return_kept=True`` a ``(y, kept)`` pair where ``kept`` (B,) bool marks
    tokens that fit under capacity (False = dropped to zeros).
    """
    B, D = x.shape
    swiglu = "leaf_wg" in params
    E = (params["leaf_wg"] if swiglu else params["leaf_w1"]).shape[0]
    G = dist_act.data_shard_count()
    x, leaf_idx = _pad_tokens(x, leaf_idx, G, E)
    Bg = x.shape[0] // G
    capacity = max(8, utils.round_up(int(capacity_factor * utils.cdiv(Bg, E)), 8))

    xg_ = x.reshape(G, Bg, D)
    idx_g = leaf_idx.reshape(G, Bg)
    # slot-within-(shard, leaf) via sort ranks, NOT cumsum(one_hot): XLA
    # lowers a (B, E) token-axis cumsum to an O(B^2) reduce-window
    # (measured 260x FLOP inflation at 64 experts — §Perf iter 1).  E + 1
    # groups: pad tokens (leaf id E) slot into a virtual group of their own.
    slot = jax.vmap(lambda i: group_slots(i, E + 1))(idx_g)       # (G, Bg)
    kept = (slot < capacity) & (idx_g < E)
    # dropped tokens scatter OUT OF BOUNDS (mode="drop"): clamping them onto
    # slot capacity-1 would collide with the kept token legitimately there,
    # and duplicate-index scatter-set resolution is nondeterministic
    flat_idx = jnp.where(kept, idx_g * capacity + slot, E * capacity)

    def scatter_one(xg, fi):
        buf = jnp.zeros((E * capacity, D), x.dtype)
        return buf.at[fi].set(xg, mode="drop")

    xbuf = jax.vmap(scatter_one)(xg_, flat_idx)                   # (G, E*C, D)
    xbuf = xbuf.reshape(G, E, capacity, D)
    dispatch_kind = dist_act.DISPATCH_SERVE if serving else dist_act.DISPATCH_ECD
    xbuf = dist_act.shard(xbuf, dispatch_kind)
    yg = _leaf_mlp_on_buffers(xbuf, params, activation, accum_dtype)
    yg = dist_act.shard(yg, dispatch_kind)
    O = yg.shape[-1]

    def gather_one(yb, fi, kp):
        out = jnp.take(yb.reshape(E * capacity, O), fi, axis=0)
        return jnp.where(kp[:, None], out, 0.0)

    y = jax.vmap(gather_one)(yg, flat_idx, kept)                  # (G, Bg, O)
    y = y.reshape(-1, O)[:B]
    if return_kept:
        return y, kept.reshape(-1)[:B]
    return y


# ---------------------------------------------------------------------------
# expert-parallel grouped leaf execution: shard_map + all_to_all against the
# model axis (the "grouped_ep" serving backend; DESIGN.md §5)
# ---------------------------------------------------------------------------

def _dense_leaf_gather(x: jax.Array, leaf_idx: jax.Array, params: dict,
                       activation: str, accum_dtype) -> jax.Array:
    """Exact per-token leaf eval via weight gathers: x (B, D), leaf_idx (B,)
    indexing the LOCAL leaf axis of ``params`` -> (B, O).  The overflow-to-
    dense repair path (DESIGN.md §8); O(B*D*l) gathered weight bytes, paid
    only for tokens that overflowed capacity."""
    ad = accum_dtype

    def tk(name):
        return jnp.take(params[name], leaf_idx, axis=0)

    if "leaf_wg" in params:
        g = jnp.einsum("bd,bdh->bh", x, tk("leaf_wg"), preferred_element_type=ad)
        u = jnp.einsum("bd,bdh->bh", x, tk("leaf_wu"), preferred_element_type=ad)
        return jnp.einsum("bh,bho->bo", jax.nn.silu(g) * u, tk("leaf_wd"),
                          preferred_element_type=ad)
    h = jnp.einsum("bd,bdh->bh", x, tk("leaf_w1"), preferred_element_type=ad)
    if "leaf_b1" in params:
        h = h + tk("leaf_b1").astype(ad)
    h = utils.get_activation(activation)(h)
    y = jnp.einsum("bh,bho->bo", h, tk("leaf_w2"), preferred_element_type=ad)
    if "leaf_b2" in params:
        y = y + tk("leaf_b2").astype(ad)
    return y


def grouped_leaf_apply_ep(x: jax.Array, leaf_idx: jax.Array, params: dict,
                          activation: str, capacity_factor: float = 1.25,
                          accum_dtype=jnp.float32, return_kept: bool = False,
                          overflow_policy: str = "exact_dense"):
    """Expert-parallel grouped leaf execution (DESIGN.md §5), exact by default.

    A ``shard_map`` over the installed mesh: the token axis is split over
    (data x model), leaf weights over the model axis.  Each source shard
    slots its Bl local tokens per leaf from ``group_slots`` sort ranks into
    an (M, E/M, C, D) send buffer, one ``all_to_all`` over the model axis
    delivers per-leaf token runs to the owning shard, local grouped GEMMs run
    at (E/M, M*C) occupancy, and the inverse ``all_to_all`` returns results
    to token order.  Capacity is per (source shard, leaf); over-capacity
    tokens are repaired by an overflow-to-dense round (all_gather of the
    dropped tokens over the model axis + masked dense eval + psum), entered
    through a ``lax.cond`` on the globally summed drop count so the steady
    state pays exactly the two all_to_alls.

    With no mesh (or no model axis) installed this degrades to the local
    grouped dispatch plus the same dense repair — still exact, so parity
    tests exercise the identical contract unsharded.

    ``overflow_policy`` selects what happens to over-capacity tokens
    (DESIGN.md §14): "exact_dense" (default) runs the repair round above;
    "master_leaf" and "drop" statically omit it — dropped tokens keep their
    zeros (the caller's central master-leaf term, when enabled, is what
    turns those zeros into the approximate master output), and the
    all_gather/psum traffic of the repair disappears from the program
    entirely (``dispatch.ep_bytes_moved`` models the same distinction).

    Returns (B, O), or with ``return_kept=True`` a ``(y, kept)`` pair;
    ``kept`` False marks tokens that overflowed capacity and took the
    policy's overflow path (exact repair, master fallback, or zeros) — the
    honest ``overflow_fraction`` the aux reports.
    """
    B, D = x.shape
    swiglu = "leaf_wg" in params
    E = (params["leaf_wg"] if swiglu else params["leaf_w1"]).shape[0]
    mesh = dist_act.current_mesh()
    M = dist_act.model_shard_count()

    if mesh is None or M <= 1 or E % M:
        # unsharded (or degenerate model axis) degradation: local dispatch +
        # dense repair, same contract
        y, kept = grouped_leaf_apply(
            x, leaf_idx, params, activation, capacity_factor=capacity_factor,
            accum_dtype=accum_dtype, serving=True, return_kept=True)
        if overflow_policy == "exact_dense":
            # repair only REAL overflow: callers may pass sentinel-padded
            # tokens (leaf id E, kept=False by construction) which need no
            # repair — a kept.all() predicate would fire the dense pass on
            # every padded call
            dropped = ~kept & (leaf_idx < E)
            y = jax.lax.cond(
                dropped.any(),
                lambda y: jnp.where(
                    dropped[:, None],
                    _dense_leaf_gather(x, leaf_idx, params, activation,
                                       accum_dtype), y),
                lambda y: y,
                y)
        return (y, kept) if return_kept else y

    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    G = dist_act.data_shard_count()
    S = G * M
    E_local = E // M
    # pad BEFORE the layout constraint: constraining a non-divisible token
    # axis forces padded-sharding lowerings (and see _pad_for_dispatch on
    # why that is never allowed to feed the dispatch)
    x, leaf_idx = _pad_tokens(x, leaf_idx, S, E)
    x = dist_act.shard(x, dist_act.TOKENS_EP)
    Bl = x.shape[0] // S
    C = dispatch_lib.ep_capacity(Bl, E, capacity_factor)
    all_axes = tuple(mesh.axis_names)

    def body(x_l, idx_l, leaves_l):
        valid = idx_l < E
        slot = group_slots(idx_l, E + 1)   # pads slot into a virtual group
        plan = dispatch_lib.make_ep_plan(idx_l, slot, valid, E, M, C)
        send = dispatch_lib.ep_scatter(x_l, plan)
        xr = dispatch_lib.ep_exchange(send, "model", plan)   # (E/M, M*C, D)
        yr = _leaf_mlp_on_buffers(xr, leaves_l, activation, accum_dtype)
        y_flat = dispatch_lib.ep_combine(yr, "model", plan)  # (E*C, O)
        y_l = dispatch_lib.ep_gather(y_flat, plan)

        if overflow_policy != "exact_dense":
            # master_leaf / drop: over-capacity tokens keep zeros; no
            # all_gather round exists in the lowered program at all
            return y_l, plan.kept

        dropped = valid & ~plan.kept
        n_drop = jax.lax.psum(dropped.sum(), all_axes)

        def repair(y_l):
            # every model-axis peer sees every dropped token of its data row,
            # evaluates the leaves it owns, and a psum assembles exact outputs
            xm = jnp.where(dropped[:, None], x_l, 0.0)
            im = jnp.where(dropped, idx_l, 0)
            xg = jax.lax.all_gather(xm, "model", axis=0, tiled=True)
            ig = jax.lax.all_gather(im, "model", axis=0, tiled=True)
            dg = jax.lax.all_gather(dropped, "model", axis=0, tiled=True)
            rank = jax.lax.axis_index("model")
            off = rank * E_local
            own = dg & (ig >= off) & (ig < off + E_local)
            rel = jnp.clip(ig - off, 0, E_local - 1)
            yd = _dense_leaf_gather(xg, rel, leaves_l, activation, accum_dtype)
            yd = jax.lax.psum(jnp.where(own[:, None], yd, 0.0), "model")
            mine = jax.lax.dynamic_slice_in_dim(yd, rank * x_l.shape[0],
                                                x_l.shape[0], axis=0)
            return jnp.where(dropped[:, None], mine, y_l)

        y_l = jax.lax.cond(n_drop > 0, repair, lambda y: y, y_l)
        return y_l, plan.kept

    tok_axes = batch_axes + ("model",)
    leaf_specs = {k: P(*(("model",) + (None,) * (v.ndim - 1)))
                  for k, v in params.items()}
    y, kept = shard_map(
        body, mesh=mesh,
        in_specs=(P(tok_axes, None), P(tok_axes), leaf_specs),
        out_specs=(P(tok_axes, None), P(tok_axes)),
        check_rep=False)(x, leaf_idx, params)
    y, kept = y[:B], kept[:B]
    return (y, kept) if return_kept else y


def leaf_histogram(leaf_idx: jax.Array, num_leaves: int) -> jax.Array:
    """Load histogram over leaves.  Skew here is what capacity-bounded
    dispatch pays for; ``fff.balance_loss`` (DESIGN.md §14) trains it flat
    so serving can drop the capacity factor below 1.0."""
    return jnp.bincount(leaf_idx.reshape(-1), length=num_leaves)


def routing_skew(leaf_idx: jax.Array, num_leaves: int) -> jax.Array:
    """max-load / mean-load; 1.0 = perfectly balanced."""
    h = leaf_histogram(leaf_idx, num_leaves).astype(jnp.float32)
    return h.max() / jnp.maximum(h.mean(), 1e-9)
