"""Pallas kernel correctness: shape/dtype sweeps vs the pure-jnp oracles,
all in interpret mode (CPU container; TPU is the lowering target).

Tolerances come from the shared dtype-keyed policy in conftest.py
(``assert_close``) — the differential harness in test_kernel_diff.py uses
the same one, so both suites move together."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import api, fff
from repro.kernels.fused_fff import (fff_decode, gathered_matmul,
                                     gathered_matmul_dual,
                                     gathered_matmul_dual_ref,
                                     gathered_matmul_ref)
from repro.kernels.leaf_gemm import (fff_infer, grouped_matmul,
                                     grouped_matmul_dual,
                                     grouped_matmul_dual_ref,
                                     grouped_matmul_ref)
from repro.kernels.tree_router import route, tree_router_ref

from conftest import assert_close


# ---------------------------------------------------------------------------
# tree_router
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("depth", [1, 2, 4, 6, 8])
@pytest.mark.parametrize("dim", [32, 96])
def test_router_matches_ref(depth, dim):
    B, N = 128, 2 ** depth - 1
    x = jax.random.normal(jax.random.PRNGKey(depth), (B, dim))
    nw = jax.random.normal(jax.random.PRNGKey(depth + 1), (N, dim)) / np.sqrt(dim)
    nb = jax.random.normal(jax.random.PRNGKey(depth + 2), (N,)) * 0.1
    got = route(x, nw, nb, depth=depth, interpret=True)
    want = tree_router_ref(x, nw, nb, depth=depth)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_router_dtypes(dtype):
    depth, dim, B = 5, 64, 64
    N = 2 ** depth - 1
    x = jax.random.normal(jax.random.PRNGKey(0), (B, dim)).astype(dtype)
    nw = (jax.random.normal(jax.random.PRNGKey(1), (N, dim)) / 8).astype(dtype)
    nb = jnp.zeros((N,), dtype)
    got = route(x, nw, nb, depth=depth, interpret=True)
    want = tree_router_ref(x, nw, nb, depth=depth)
    # bf16 logits can flip near-zero decisions; require 99% agreement
    agree = float((got == want).mean())
    assert agree > 0.99


def test_router_deep_tree_split():
    depth, dim, B = 11, 32, 64
    N = 2 ** depth - 1
    x = jax.random.normal(jax.random.PRNGKey(3), (B, dim))
    nw = jax.random.normal(jax.random.PRNGKey(4), (N, dim)) / np.sqrt(dim)
    nb = jnp.zeros((N,))
    got = route(x, nw, nb, depth=depth, dense_levels=6, interpret=True)
    want = tree_router_ref(x, nw, nb, depth=depth)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_router_unpadded_batch():
    depth, dim = 3, 32
    N = 2 ** depth - 1
    x = jax.random.normal(jax.random.PRNGKey(5), (37, dim))   # odd batch
    nw = jax.random.normal(jax.random.PRNGKey(6), (N, dim))
    nb = jnp.zeros((N,))
    got = route(x, nw, nb, depth=depth, interpret=True)
    want = tree_router_ref(x, nw, nb, depth=depth)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# leaf_gemm (grouped / ragged)
# ---------------------------------------------------------------------------

def _ragged_inputs(E, C, D, H, seed, dtype=jnp.float32):
    k = jax.random.PRNGKey(seed)
    gs = jax.random.randint(jax.random.fold_in(k, 0), (E,), 0, C + 1)
    mask = (jnp.arange(C)[None, :] < gs[:, None])
    x = jax.random.normal(jax.random.fold_in(k, 1), (E, C, D)) \
        * mask[..., None]
    w = jax.random.normal(jax.random.fold_in(k, 2), (E, D, H)) / np.sqrt(D)
    return x.astype(dtype), w.astype(dtype), gs.astype(jnp.int32)


@pytest.mark.parametrize("act", ["none", "gelu", "relu", "silu"])
@pytest.mark.parametrize("shape", [(2, 16, 32, 24), (5, 24, 16, 16)])
def test_grouped_matmul_sweep(act, shape):
    E, C, D, H = shape
    x, w, gs = _ragged_inputs(E, C, D, H, seed=hash((act, shape)) % 1000)
    got = grouped_matmul(x, w, gs, act=act, block_c=8, block_h=8, block_k=8,
                         interpret=True)
    want = grouped_matmul_ref(x, w, gs, act=act)
    assert_close(got, want)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_grouped_matmul_dtypes(dtype):
    x, w, gs = _ragged_inputs(3, 16, 32, 16, seed=7, dtype=dtype)
    got = grouped_matmul(x, w, gs, act="gelu", block_c=8, block_h=8,
                         block_k=16, interpret=True)
    want = grouped_matmul_ref(x, w, gs, act="gelu")
    assert_close(got, want, dtype=dtype)


def test_grouped_matmul_dual_swiglu():
    E, C, D, H = 4, 16, 24, 16
    x, wg, gs = _ragged_inputs(E, C, D, H, seed=11)
    wu = jax.random.normal(jax.random.PRNGKey(99), (E, D, H)) / np.sqrt(D)
    got = grouped_matmul_dual(x, wg, wu, gs, block_c=8, block_h=8, block_k=8,
                              interpret=True)
    want = grouped_matmul_dual_ref(x, wg, wu, gs)
    assert_close(got, want)


def test_grouped_empty_groups_produce_zeros():
    E, C, D, H = 4, 8, 16, 8
    x = jax.random.normal(jax.random.PRNGKey(0), (E, C, D))
    w = jax.random.normal(jax.random.PRNGKey(1), (E, D, H))
    gs = jnp.array([0, 8, 0, 4], jnp.int32)
    mask = (jnp.arange(C)[None, :] < gs[:, None])
    got = grouped_matmul(x * mask[..., None], w, gs, act="none",
                         block_c=4, block_h=8, block_k=8, interpret=True)
    assert float(jnp.abs(got[0]).max()) == 0.0
    assert float(jnp.abs(got[2]).max()) == 0.0


# ---------------------------------------------------------------------------
# fused_fff (gathered, per-token)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("act", ["none", "gelu"])
@pytest.mark.parametrize("E,B,D,H", [(4, 8, 32, 16), (16, 13, 16, 24)])
def test_gathered_matmul_sweep(act, E, B, D, H):
    x = jax.random.normal(jax.random.PRNGKey(0), (B, D))
    w = jax.random.normal(jax.random.PRNGKey(1), (E, D, H)) / np.sqrt(D)
    idx = jax.random.randint(jax.random.PRNGKey(2), (B,), 0, E)
    got = gathered_matmul(x, w, idx, act=act, block_h=8, block_k=8,
                          interpret=True)
    want = gathered_matmul_ref(x, w, idx, act=act)
    assert_close(got, want)


def test_gathered_dual():
    E, B, D, H = 8, 16, 24, 16
    x = jax.random.normal(jax.random.PRNGKey(3), (B, D))
    wg = jax.random.normal(jax.random.PRNGKey(4), (E, D, H)) / np.sqrt(D)
    wu = jax.random.normal(jax.random.PRNGKey(5), (E, D, H)) / np.sqrt(D)
    idx = jax.random.randint(jax.random.PRNGKey(6), (B,), 0, E)
    got = gathered_matmul_dual(x, wg, wu, idx, block_h=8, block_k=8,
                               interpret=True)
    want = gathered_matmul_dual_ref(x, wg, wu, idx)
    assert_close(got, want)


# ---------------------------------------------------------------------------
# end-to-end FFF inference paths vs the core oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("act,trees", [("gelu", 1), ("relu", 2),
                                       ("swiglu", 1), ("swiglu", 2)])
def test_fff_infer_matches_forward_hard(act, trees):
    cfg = fff.FFFConfig(dim_in=32, dim_out=32, depth=3, leaf_width=8,
                        activation=act, trees=trees, leaf_bias=False)
    p = fff.init(jax.random.PRNGKey(7), cfg)
    x = jax.random.normal(jax.random.PRNGKey(8), (64, 32))
    want, _ = api.apply(p, cfg, x,
                        api.ExecutionSpec(mode="infer", backend="reference"))
    got_grouped = fff_infer(x, p, cfg, capacity_factor=8.0, interpret=True)
    got_decode = fff_decode(x, p, cfg, interpret=True)
    assert_close(got_grouped, want, kind="e2e")
    assert_close(got_decode, want, kind="e2e")


def test_fff_infer_overflow_fallback_exact():
    cfg = fff.FFFConfig(dim_in=32, dim_out=16, depth=2, leaf_width=8,
                        activation="gelu", leaf_bias=False)
    p = fff.init(jax.random.PRNGKey(9), cfg)
    x = jax.random.normal(jax.random.PRNGKey(10), (256, 32))
    want, _ = api.apply(p, cfg, x,
                        api.ExecutionSpec(mode="infer", backend="reference"))
    got = fff_infer(x, p, cfg, capacity_factor=0.2, interpret=True)
    assert_close(got, want, kind="e2e")


def test_fff_leaf_mlp_skewed_overflow_exact():
    """Real token dropping (one leaf far past the block_c=128 capacity
    floor): every token — kept AND overflowed-to-dense — must match the
    exact gather; a bad dropped-token scatter sentinel corrupts a
    neighbouring leaf's kept token."""
    from repro.kernels.leaf_gemm import fff_leaf_mlp
    E, B, D, H = 2, 160, 16, 8
    key = jax.random.PRNGKey(11)
    x = jax.random.normal(key, (B, D))
    params = {
        "leaf_w1": jax.random.normal(jax.random.fold_in(key, 1), (E, D, H))
        / np.sqrt(D),
        "leaf_w2": jax.random.normal(jax.random.fold_in(key, 2), (E, H, D))
        / np.sqrt(H),
    }
    # token 0 -> leaf 1, everyone else -> leaf 0: leaf 0 overflows capacity
    leaf_idx = jnp.zeros((B,), jnp.int32).at[0].set(1)
    got = fff_leaf_mlp(x, leaf_idx, params, activation="gelu",
                       capacity_factor=0.5, block_c=128, interpret=True)
    w1 = jnp.take(params["leaf_w1"], leaf_idx, axis=0)
    w2 = jnp.take(params["leaf_w2"], leaf_idx, axis=0)
    h = jax.nn.gelu(jnp.einsum("bd,bdh->bh", x, w1,
                               preferred_element_type=jnp.float32))
    want = jnp.einsum("bh,bho->bo", h, w2,
                      preferred_element_type=jnp.float32)
    assert_close(got, want)
