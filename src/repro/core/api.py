"""The single FFF entry point: ``apply()`` + a pluggable execution-backend
registry (DESIGN.md §2).

The paper's layer has one contract and many execution strategies: FORWARD_T's
soft mixture for training, FORWARD_I's log-time hard descent for inference,
and — per strategy — a pure-gather reference, a capacity-bounded grouped
dispatch (SPMD-shardable), an expert-parallel shard_map/all_to_all path
(``grouped_ep``, DESIGN.md §5) and the Pallas TPU kernels.  Every consumer
goes through::

    y, out = api.apply(params, cfg, x, api.ExecutionSpec(mode="infer"))

``ExecutionSpec.backend`` names the implementation; ``"auto"`` (the default)
picks one from the platform, token count, tree depth and config.  All
backends return the same ``(y, FFFOutput)`` pair, so swapping execution
strategies (new kernels, sharded backends, batching policies) never touches
call sites.

Adding a backend::

    def my_backend(params, cfg, x, spec):
        ...
        return y, api.FFFOutput(leaf_idx=idx)

    api.register_backend("infer", "mine", my_backend)
    y, out = api.apply(params, cfg, x,
                       api.ExecutionSpec(mode="infer", backend="mine"))

The launch layer can steer ``backend="auto"`` call sites wholesale with
``with api.overrides(backend="grouped"): ...`` (same thread-local pattern
as ``repro.distributed.act.use_mesh`` — read at trace time).  The same
context manager composes every trace-time override — backend, capacity
factor and overflow policy — and nests (inner wins per field); the old
single-purpose ``use_backend`` / ``use_capacity_factor`` /
``use_overflow_policy`` names survive as thin deprecated aliases.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
import warnings
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro import utils
from repro.core import fff as fff_lib
from repro.distributed import act as dist_act

MODES = ("train", "infer")

#: pre-registry capacity defaults, preserved per backend (ExecutionSpec's
#: capacity_factor=None means "use the backend's own default")
DEFAULT_CAPACITY_TRAIN_ST = 1.5
DEFAULT_CAPACITY_INFER = 2.0
#: grouped_ep runs Switch-style tight capacity: every slot crosses the wire
#: twice (all_to_all there and back), and exactness comes from the
#: overflow-to-dense repair, not headroom (DESIGN.md §5/§8)
DEFAULT_CAPACITY_EP = 1.25

#: token count at or below which the pallas backend prefers the per-token
#: gathered decode kernel over the sorted-dispatch grouped GEMM (DESIGN.md §3)
PALLAS_DECODE_MAX_TOKENS = 32

#: what happens to tokens a capacity-bounded backend drops (DESIGN.md §14):
#: "exact_dense" repairs them with the per-token dense fallback (exact,
#: all_gather traffic under EP), "master_leaf" lets the always-on master-leaf
#: term stand in (approximate, zero repair traffic, needs cfg.master_leaf),
#: "drop" leaves them at zero output (historical grouped behaviour)
OVERFLOW_POLICIES = ("exact_dense", "master_leaf", "drop")


def default_capacity_factor(backend: str, mode: str = "infer") -> float:
    """The capacity factor a capacity-bounded backend runs with when
    ``ExecutionSpec.capacity_factor`` is None — the single source of truth
    for consumers that must PREDICT dispatch behavior (e.g. the serving
    scheduler's overflow proxy, DESIGN.md §9)."""
    if mode == "train":
        return DEFAULT_CAPACITY_TRAIN_ST
    return DEFAULT_CAPACITY_EP if backend == "grouped_ep" \
        else DEFAULT_CAPACITY_INFER


def default_overflow_policy(backend: str) -> str:
    """The overflow policy a capacity-bounded backend runs with when
    ``ExecutionSpec.overflow_policy`` is None — the historical per-backend
    behaviour the first-class policy replaced (DESIGN.md §14): grouped_ep
    repaired exactly, grouped dropped.  Exact backends have no overflow, so
    the answer only matters for capacity-bounded ones; consumers that must
    predict repair behaviour (serving metrics, ``dispatch.ep_bytes_moved``)
    read it from here."""
    return "exact_dense" if backend == "grouped_ep" else "drop"

#: per-tree training width at which "auto" inference switches from the exact
#: per-token gather to capacity-bounded grouped dispatch (DESIGN.md §3)
AUTO_GROUPED_MIN_WIDTH = 4096


@dataclasses.dataclass(frozen=True)
class ExecutionSpec:
    """How to execute one FFF layer application.

    mode:            "train" (FORWARD_T semantics) | "infer" (FORWARD_I)
    backend:         registered backend name, or "auto" to resolve from the
                     platform, token count, depth and config
    capacity_factor: per-leaf capacity multiplier for capacity-bounded
                     backends (grouped dispatch, pallas leaf GEMM); None =
                     each backend's own default (1.5 for ST training, 2.0
                     for serving — the pre-registry values)
    overflow_policy: what a capacity-bounded backend does with tokens it
                     drops — one of ``OVERFLOW_POLICIES`` ("exact_dense" |
                     "master_leaf" | "drop", DESIGN.md §14); None = the
                     backend's historical default
                     (``default_overflow_policy``: "exact_dense" for
                     grouped_ep, "drop" for grouped).  "master_leaf"
                     requires ``cfg.master_leaf`` — the always-on master
                     term is what stands in for the dropped leaf output.
                     Exact (capacity-unbounded) backends ignore it.
    dense_levels:    tree levels routed by one dense logit matmul before
                     falling back to per-token gathers (DESIGN.md §3)
    rng:             PRNG key for stochastic training features (child
                     transposition); unused by inference backends
    interpret:       Pallas interpret-mode override (None = autodetect:
                     interpret everywhere but TPU)
    valid:           optional boolean per-token validity mask, broadcastable
                     to x's leading (batch, ...) shape.  Capacity-bounded
                     backends route invalid tokens to the capacity-neutral
                     sentinel leaf so phantom rows (e.g. a serving engine's
                     free slots) never consume grouped-dispatch capacity or
                     appear in routing telemetry, and exclude them from
                     overflow accounting.  Exact backends (reference,
                     pallas, and grouped_ep's overflow repair) ignore it —
                     their outputs are per-token exact regardless.
    """
    mode: str = "infer"
    backend: str = "auto"
    capacity_factor: Optional[float] = None
    overflow_policy: Optional[str] = None
    dense_levels: int = 8
    rng: Optional[jax.Array] = None
    interpret: Optional[bool] = None
    valid: Optional[jax.Array] = None

    def validate(self) -> "ExecutionSpec":
        if self.mode not in MODES:
            raise ValueError(f"mode must be one of {MODES}, got {self.mode!r}")
        if (self.overflow_policy is not None
                and self.overflow_policy not in OVERFLOW_POLICIES):
            raise ValueError(
                f"overflow_policy must be one of {OVERFLOW_POLICIES} or None, "
                f"got {self.overflow_policy!r}")
        return self


@dataclasses.dataclass(frozen=True)
class FFFOutput:
    """Structured aux returned by every backend.  Fields a backend cannot
    produce are None — e.g. hard inference has no node probabilities, and
    exact (capacity-unbounded) paths report no overflow.

    leaf_idx:          (..., trees) int32 — routed leaf per (token, tree)
    node_probs:        (B, trees, num_nodes) — sigmoid node outputs
    mixture:           (B, trees, num_leaves) — FORWARD_T leaf weights
    entropy:           scalar — mean Bernoulli entropy of node decisions
    overflow_fraction: scalar — fraction of (token, tree) slots dropped by a
                       capacity bound (0 for exact paths)
    """
    leaf_idx: Optional[jax.Array] = None
    node_probs: Optional[jax.Array] = None
    mixture: Optional[jax.Array] = None
    entropy: Optional[jax.Array] = None
    overflow_fraction: Optional[jax.Array] = None

    def as_dict(self) -> dict:
        """Legacy aux-dict view (the pre-registry forward_* return type).
        References the field arrays, no copies (asdict would deep-copy)."""
        return {f.name: getattr(self, f.name) for f in dataclasses.fields(self)
                if getattr(self, f.name) is not None}


jax.tree_util.register_dataclass(
    FFFOutput,
    data_fields=["leaf_idx", "node_probs", "mixture", "entropy",
                 "overflow_fraction"],
    meta_fields=[])


@dataclasses.dataclass(frozen=True)
class RoutingStats:
    """Per-call routing telemetry for serving observability (DESIGN.md §9).

    Built by ``routing_stats_from`` out of the ``FFFOutput`` every backend
    already returns, when a ``collect_routing()`` tap is active.  The serving
    engine's scheduler consumes these to compose microbatches that balance
    leaf load (the paper's grouped dispatch is composition-sensitive:
    capacity overflow depends on which tokens share a batch).

    leaf_counts: (B, E) float32 — routed (token, tree) slots per leading
                 batch row per leaf, summed over every other leading axis
                 (sequence) and trees.  Row b is batch element b's *leaf
                 footprint* at this site.
    overflow:    scalar — the call's overflow_fraction (0 for exact paths)
    slots:       scalar — total routed (token, tree) slots (weight for
                 averaging overflow across sites)
    """
    leaf_counts: jax.Array
    overflow: jax.Array
    slots: jax.Array


jax.tree_util.register_dataclass(
    RoutingStats, data_fields=["leaf_counts", "overflow", "slots"],
    meta_fields=[])


@contextlib.contextmanager
def collect_routing(enable: bool = True):
    """Ask FFF call sites to surface ``RoutingStats`` for the dynamic extent
    of a trace.  Read at trace time (same thread-local pattern as
    ``use_backend``): model code checks ``routing_enabled()`` and, when true,
    attaches ``routing_stats_from(out, cfg)`` to its aux outputs so the
    telemetry rides the normal function returns — it must, because inside a
    ``lax.scan`` over layers a side-channel list would capture scan-body
    tracers that cannot escape the loop."""
    prev = getattr(_thread_state, "routing", False)
    _thread_state.routing = bool(enable)
    try:
        yield
    finally:
        _thread_state.routing = prev


def routing_enabled() -> bool:
    """Whether a ``collect_routing()`` tap is active for the current trace."""
    return bool(getattr(_thread_state, "routing", False))


def routing_stats_from(out: FFFOutput, cfg: "fff_lib.FFFConfig"
                       ) -> Optional[RoutingStats]:
    """Compact per-call telemetry from a backend's ``FFFOutput``.

    Reduces ``leaf_idx`` (B, ..., trees) to a per-batch-row leaf histogram
    (B, E); returns None when the backend reported no leaf indices (e.g.
    FORWARD_T training, depth-0 sites)."""
    if out.leaf_idx is None:
        return None
    idx = out.leaf_idx
    if idx.ndim == 1:                      # (B,) single-tree flat call
        idx = idx[:, None]
    flat = idx.reshape(idx.shape[0], -1)   # (B, S*...*trees)
    counts = jax.vmap(
        lambda i: jnp.bincount(i, length=cfg.num_leaves))(flat)
    counts = counts.astype(jnp.float32)
    ovf = (out.overflow_fraction if out.overflow_fraction is not None
           else jnp.zeros((), jnp.float32))
    return RoutingStats(leaf_counts=counts, overflow=ovf,
                        slots=counts.sum())

BackendFn = Callable[[dict, "fff_lib.FFFConfig", jax.Array, ExecutionSpec],
                     tuple[jax.Array, FFFOutput]]
SupportsFn = Callable[[dict, "fff_lib.FFFConfig"], bool]

_REGISTRY: dict[tuple[str, str], BackendFn] = {}
_SUPPORTS: dict[tuple[str, str], SupportsFn] = {}
_thread_state = threading.local()


def register_backend(mode: str, name: str, fn: BackendFn,
                     supports: Optional[SupportsFn] = None) -> None:
    """Register ``fn`` as execution backend ``name`` for ``mode``.

    ``fn(params, cfg, x, spec) -> (y, FFFOutput)`` with ``x`` (..., dim_in)
    and ``y`` (..., dim_out).  ``supports(params, cfg) -> bool`` (optional)
    is the eligibility predicate the *auto* resolver honours — both when
    picking the backend itself and when a ``use_backend`` override names it;
    ineligible configs fall through to the heuristics instead of crashing
    inside the backend.  Explicit ``ExecutionSpec(backend=name)`` bypasses
    it: explicit means explicit, and the backend's own errors apply.
    Re-registering a name overwrites it (so tests and downstream packages
    can shadow the built-ins)."""
    if mode not in MODES:
        raise ValueError(f"mode must be one of {MODES}, got {mode!r}")
    if name == "auto":
        raise ValueError('"auto" is the resolver, not a registrable backend')
    _REGISTRY[(mode, name)] = fn
    if supports is not None:
        _SUPPORTS[(mode, name)] = supports
    else:
        _SUPPORTS.pop((mode, name), None)


def _backend_supported(mode: str, name: str, params: dict,
                       cfg: fff_lib.FFFConfig) -> bool:
    pred = _SUPPORTS.get((mode, name))
    return pred is None or pred(params, cfg)


def get_backend(mode: str, name: str) -> BackendFn:
    try:
        return _REGISTRY[(mode, name)]
    except KeyError:
        raise KeyError(
            f"no backend {name!r} registered for mode {mode!r}; available: "
            f"{list_backends(mode)}") from None


def list_backends(mode: Optional[str] = None) -> list[str]:
    """Registered backend names, optionally restricted to one mode."""
    if mode is None:
        return sorted({n for _, n in _REGISTRY})
    return sorted(n for m, n in _REGISTRY if m == mode)


def overrides(*, backend: Optional[str] = None, mode: Optional[str] = None,
              capacity_factor: Optional[float] = None,
              overflow_policy: Optional[str] = None):
    """One composable trace-time override context for ``apply()`` (DESIGN.md
    §2/§14): steer ``backend="auto"`` resolution, fill in unset
    ``capacity_factor``s, and fill in unset ``overflow_policy``s — any
    subset at once, for the dynamic extent of a trace in this thread.

    ``backend`` steers every ``backend="auto"`` apply() to the named
    implementation; explicit non-auto specs are unaffected.  ``mode``
    restricts the backend override to one mode — pass ``mode="infer"`` when
    a name exists for both modes with different math (``"grouped"`` is exact
    dispatch for inference but the ST top-1 *estimator* for training; an
    unrestricted override would silently change training semantics).
    Backends missing for an applicable mode — or failing their registered
    ``supports`` predicate for a given (params, cfg) — fall through to the
    normal auto heuristics, so e.g. ``overrides(backend="pallas")`` serves
    kernel-eligible inference sites with the kernels while biased-leaf sites
    and training keep their normal paths.  A name registered for no mode at
    all raises up front — otherwise a typo would silently run auto.

    ``capacity_factor`` fills in every spec that leaves its own unset;
    explicit per-spec values win.  The motivating consumer is the serving
    engine's speculative verify dispatch (DESIGN.md §10): a verify slab is
    k+1 decode steps fused onto one token axis, so its per-leaf capacity
    must scale with that axis — otherwise speculation would *change serving
    numerics* instead of just batching them.  Capacity-free exact backends
    ignore capacity factors entirely, so the override is harmless there.

    ``overflow_policy`` (one of ``OVERFLOW_POLICIES``) likewise fills in
    specs that leave theirs unset — how the serving engine selects
    master-leaf overflow repair for a whole trace without touching call
    sites.

    Contexts nest: each ``overrides()`` saves and restores exactly the
    fields it sets, so an inner context wins per field and unrelated fields
    compose (``overrides(backend=...)`` inside
    ``overrides(capacity_factor=...)`` leaves the capacity override
    active).  Validation is eager — bad arguments raise at the call, before
    the ``with`` body runs."""
    if mode is not None and mode not in MODES:
        raise ValueError(f"mode must be one of {MODES}, got {mode!r}")
    if mode is not None and backend is None:
        raise ValueError("mode= only restricts a backend override; pass "
                         "backend= as well")
    if backend is not None and not any(n == backend for _, n in _REGISTRY):
        raise KeyError(f"no backend {backend!r} registered for any mode; "
                       f"available: {list_backends()}")
    if capacity_factor is not None:
        capacity_factor = float(capacity_factor)
        if capacity_factor <= 0:
            raise ValueError(
                f"capacity factor must be positive, got {capacity_factor}")
    if overflow_policy is not None and overflow_policy not in OVERFLOW_POLICIES:
        raise ValueError(f"overflow_policy must be one of {OVERFLOW_POLICIES},"
                         f" got {overflow_policy!r}")

    sets = []
    if backend is not None:
        sets.append(("override", (backend, mode)))
    if capacity_factor is not None:
        sets.append(("capacity_override", capacity_factor))
    if overflow_policy is not None:
        sets.append(("overflow_override", overflow_policy))

    @contextlib.contextmanager
    def _installed():
        prev = [(a, getattr(_thread_state, a, None)) for a, _ in sets]
        for a, v in sets:
            setattr(_thread_state, a, v)
        try:
            yield
        finally:
            for a, v in prev:
                setattr(_thread_state, a, v)

    return _installed()


def _deprecated_alias(old: str, new: str) -> None:
    warnings.warn(f"api.{old} is deprecated; use api.{new}",
                  DeprecationWarning, stacklevel=3)


def use_backend(name: str, mode: Optional[str] = None):
    """Deprecated alias for ``overrides(backend=name, mode=mode)``."""
    _deprecated_alias("use_backend(name)", "overrides(backend=name)")
    return overrides(backend=name, mode=mode)


def use_capacity_factor(cf: float):
    """Deprecated alias for ``overrides(capacity_factor=cf)``."""
    _deprecated_alias("use_capacity_factor(cf)", "overrides(capacity_factor=cf)")
    return overrides(capacity_factor=cf)


def use_overflow_policy(policy: str):
    """Deprecated alias for ``overrides(overflow_policy=policy)``."""
    _deprecated_alias("use_overflow_policy(policy)",
                      "overrides(overflow_policy=policy)")
    return overrides(overflow_policy=policy)


def _pallas_supported(params: dict, cfg: fff_lib.FFFConfig) -> bool:
    """The kernel path collapses the node net to one hyperplane and needs the
    zero-row padding invariant of bias-free leaves (kernels/leaf_gemm)."""
    return (cfg.node_width == 1 and "leaf_b1" not in params
            and "leaf_b2" not in params)


def _kernels_native() -> bool:
    """Whether Pallas kernels compile natively here (TPU).  The interpret
    fallback keeps them *correct* everywhere, but auto never picks an
    interpreted kernel over a compiled XLA path — tests monkeypatch this to
    exercise the kernel branches of the resolver on CPU."""
    return jax.default_backend() == "tpu"


def _resolve_auto(params: dict, cfg: fff_lib.FFFConfig, mode: str,
                  x_shape: Optional[tuple] = None) -> str:
    """Backend choice for ``backend="auto"`` (DESIGN.md §3 regime map):

    train: the ST-grouped estimator when the config asks for it (MoE-scale
           sites) and there is a tree to descend; otherwise faithful
           FORWARD_T.
    infer: expert-parallel a2a dispatch (grouped_ep) whenever a mesh with a
           model axis >1 is installed and the leaf count divides over it —
           sharded serving's whole point is that tokens travel to the leaf
           shards (§5); else, on TPU with a kernel-eligible config: the
           fused decode MEGAKERNEL (``pallas_decode``, §13) for seq-len-1
           shapes — serving decode's forever-shape — and the three-kernel
           ``pallas`` path otherwise; grouped dispatch for wide sites —
           always, regardless of token count, because wide sites are the
           EP-sharded ones and the per-token gather would allgather their
           sharded leaf weights; the exact gather reference otherwise
           (small sites, depth 0).  ``x_shape`` is the call's input shape
           when known (apply() passes it); shape-blind resolution simply
           never picks the decode-shaped fast path."""
    override = getattr(_thread_state, "override", None)
    if override is not None:
        o_name, o_mode = override
        if ((o_mode in (None, mode)) and (mode, o_name) in _REGISTRY
                and _backend_supported(mode, o_name, params, cfg)):
            return o_name
    if mode == "train":
        return "grouped" if (cfg.st_training and cfg.depth > 0) else "reference"
    if cfg.depth == 0:
        return "reference"
    if (dist_act.model_shard_count() > 1
            and _backend_supported("infer", "grouped_ep", params, cfg)):
        return "grouped_ep"
    if (x_shape is not None and len(x_shape) >= 3 and x_shape[-2] == 1
            and _kernels_native()
            and _backend_supported("infer", "pallas_decode", params, cfg)):
        return "pallas_decode"
    if (_kernels_native()
            and _backend_supported("infer", "pallas", params, cfg)):
        return "pallas"
    if cfg.num_leaves * cfg.leaf_width >= AUTO_GROUPED_MIN_WIDTH:
        return "grouped"
    return "reference"


def resolve_backend(params: dict, cfg: "fff_lib.FFFConfig",
                    mode: str = "infer",
                    x_shape: Optional[tuple] = None) -> str:
    """The backend ``apply(backend="auto")`` would run under the CURRENT
    trace-time context (installed mesh, ``use_backend`` override, supports
    predicates) — for consumers that must predict dispatch behavior without
    running it, e.g. the serving scheduler's capacity proxy (DESIGN.md §9).
    Pass the site's params when available; ``{}`` is an acceptable proxy for
    bias-free configs (the predicates only probe bias keys).  ``x_shape``
    (the ``(..., seq, dim)`` input shape) enables the shape-dependent picks
    — without it the decode-shaped fast path is never predicted."""
    return _resolve_auto(params, cfg, mode, x_shape=x_shape)


def apply(params: dict, cfg: fff_lib.FFFConfig, x: jax.Array,
          spec: ExecutionSpec = ExecutionSpec()
          ) -> tuple[jax.Array, FFFOutput]:
    """Apply one FFF layer: x (..., dim_in) -> (..., dim_out), FFFOutput.

    The only supported invocation of the layer outside ``repro.core``; the
    backend registry does the rest (module docstring has the map).

    When ``cfg.master_leaf`` is set the always-on master-leaf term
    (``fff.master_apply``, DESIGN.md §14) is added HERE, after backend
    dispatch, so every backend — reference, grouped, grouped_ep, pallas —
    gets identical master semantics without per-backend code and without an
    extra pallas_call (the addition is plain jnp and fuses into the
    surrounding XLA program).  The one exception is the fused decode
    megakernel, which folds the master MLP into its single kernel."""
    cf = getattr(_thread_state, "capacity_override", None)
    if cf is not None and spec.capacity_factor is None:
        spec = dataclasses.replace(spec, capacity_factor=cf)
    op = getattr(_thread_state, "overflow_override", None)
    if op is not None and spec.overflow_policy is None:
        spec = dataclasses.replace(spec, overflow_policy=op)
    spec.validate()
    if spec.overflow_policy == "master_leaf" and not cfg.master_leaf:
        raise ValueError(
            'overflow_policy="master_leaf" requires cfg.master_leaf=True — '
            "without the always-on master term, dropped tokens would "
            'silently degrade to zeros (use "drop" to ask for that)')
    name = spec.backend
    if name == "auto":
        name = _resolve_auto(params, cfg, spec.mode, x_shape=x.shape)
    y, out = get_backend(spec.mode, name)(params, cfg, x, spec)
    if cfg.master_leaf and not (name == "pallas_decode" and cfg.depth > 0):
        y = y + fff_lib.master_apply(params, cfg, x).astype(y.dtype)
    return y, out


# ---------------------------------------------------------------------------
# built-in backends
# ---------------------------------------------------------------------------

def _train_reference(params, cfg, x, spec):
    """FORWARD_T: dense soft mixture over all leaves (paper Algorithm 1)."""
    y, aux = fff_lib._forward_soft_mixture(params, cfg, x, rng=spec.rng)
    return y, FFFOutput(node_probs=aux["node_probs"], mixture=aux["mixture"],
                        entropy=aux["entropy"])


def _train_grouped(params, cfg, x, spec):
    """Straight-through top-1 training via capacity-bounded grouped dispatch
    (O(l) leaf cost per token; DESIGN.md §8)."""
    cf = (spec.capacity_factor if spec.capacity_factor is not None
          else DEFAULT_CAPACITY_TRAIN_ST)
    y, aux = fff_lib._forward_st_grouped(
        params, cfg, x, rng=spec.rng, capacity_factor=cf)
    return y, FFFOutput(leaf_idx=aux["leaf_idx"],
                        node_probs=aux["node_probs"], mixture=aux["mixture"],
                        entropy=aux["entropy"],
                        overflow_fraction=aux["overflow_fraction"])


def _infer_reference(params, cfg, x, spec):
    """FORWARD_I: hard descent + exact per-token leaf gather."""
    y, aux = fff_lib._forward_hard_gather(params, cfg, x,
                                          dense_levels=spec.dense_levels)
    return y, FFFOutput(leaf_idx=aux["leaf_idx"],
                        overflow_fraction=jnp.zeros((), jnp.float32))


def _infer_grouped(params, cfg, x, spec):
    """FORWARD_I via capacity-bounded grouped dispatch (EP-shardable).
    ``spec.overflow_policy`` governs dropped tokens (default "drop",
    the historical behaviour; DESIGN.md §14)."""
    cf = (spec.capacity_factor if spec.capacity_factor is not None
          else DEFAULT_CAPACITY_INFER)
    policy = (spec.overflow_policy if spec.overflow_policy is not None
              else default_overflow_policy("grouped"))
    y, aux = fff_lib._forward_hard_grouped(
        params, cfg, x, capacity_factor=cf, dense_levels=spec.dense_levels,
        valid=spec.valid, overflow_policy=policy)
    return y, FFFOutput(leaf_idx=aux["leaf_idx"],
                        overflow_fraction=aux["overflow_fraction"])


def _infer_grouped_ep(params, cfg, x, spec):
    """FORWARD_I via expert-parallel shard_map + all_to_all dispatch
    (DESIGN.md §5).  Leaf weights stay sharded on the model axis; tokens
    travel to their routed leaf's shard and back.  Exact under the default
    ``overflow_policy="exact_dense"``: over-capacity tokens take the
    overflow-to-dense repair, and overflow_fraction reports the true repair
    rate.  "master_leaf"/"drop" (§14) omit the repair round — and its
    all_gather traffic — entirely.  Degrades to local grouped dispatch +
    the same policy when no mesh is installed (so the contract is testable
    unsharded)."""
    cf = (spec.capacity_factor if spec.capacity_factor is not None
          else DEFAULT_CAPACITY_EP)
    policy = (spec.overflow_policy if spec.overflow_policy is not None
              else default_overflow_policy("grouped_ep"))
    y, aux = fff_lib._forward_hard_ep(
        params, cfg, x, capacity_factor=cf, dense_levels=spec.dense_levels,
        valid=spec.valid, overflow_policy=policy)
    return y, FFFOutput(leaf_idx=aux["leaf_idx"],
                        overflow_fraction=aux["overflow_fraction"])


def _infer_pallas(params, cfg, x, spec):
    """FORWARD_I on the Pallas TPU kernels: fused tree-router descent, then
    sorted-dispatch grouped GEMMs (batch) or per-token gathered matmuls
    (decode-sized batches).  Exact: grouped overflow falls back to the dense
    gather (DESIGN.md §8), so overflow_fraction is 0 by construction."""
    # imported here, not at module scope: repro.kernels sits above repro.core
    # in the layering and itself imports this package
    from repro.kernels.fused_fff import ops as fused_ops
    from repro.kernels.leaf_gemm import ops as gemm_ops
    xf, lead = utils.flatten_leading(x)
    if xf.shape[0] <= PALLAS_DECODE_MAX_TOKENS:
        y, leaf_idx = fused_ops.fff_decode(
            xf, params, cfg, interpret=spec.interpret,
            dense_levels=spec.dense_levels, return_leaf_idx=True)
    else:
        cf = (spec.capacity_factor if spec.capacity_factor is not None
              else DEFAULT_CAPACITY_INFER)
        y, leaf_idx = gemm_ops.fff_infer(
            xf, params, cfg, capacity_factor=cf,
            interpret=spec.interpret, dense_levels=spec.dense_levels,
            return_leaf_idx=True)
    return (utils.unflatten_leading(y, lead),
            FFFOutput(leaf_idx=utils.unflatten_leading(leaf_idx, lead),
                      overflow_fraction=jnp.zeros((), jnp.float32)))


def _infer_pallas_decode(params, cfg, x, spec):
    """FORWARD_I on the fused decode MEGAKERNEL (DESIGN.md §13): tree
    routing, the selected leaf's MLP and the forest combine in ONE
    ``pl.pallas_call`` — built for the serving engine's ``(num_slots, 1)``
    decode shape, where the three-dispatch pallas path pays two extra
    kernel launches and an HBM round trip of the hidden activation per
    token.  Exact for any batch (per-token, no capacity bound), so
    ``spec.valid`` does not change outputs; it only masks the reported
    ``leaf_idx`` to the sentinel leaf so phantom rows (a serving engine's
    free slots) stay out of routing telemetry — ``routing_stats_from``'s
    bincount drops the sentinel id, same contract as the capacity-bounded
    backends (DESIGN.md §9)."""
    if cfg.depth == 0:
        # a depth-0 FFF is one dense leaf: no tree to descend, nothing to
        # fuse.  The supports predicate keeps auto away from this case;
        # an explicit request stays correct via the reference path.
        return _infer_reference(params, cfg, x, spec)
    # imported here, not at module scope: repro.kernels sits above repro.core
    # in the layering and itself imports this package
    from repro.kernels.fused_decode import ops as fd_ops
    xf, lead = utils.flatten_leading(x)
    y, leaf_idx = fd_ops.fused_decode(xf, params, cfg,
                                      interpret=spec.interpret,
                                      return_leaf_idx=True)
    if spec.valid is not None:
        vf = jnp.broadcast_to(spec.valid, x.shape[:-1]).reshape(-1)
        leaf_idx = jnp.where(vf[:, None], leaf_idx, cfg.num_leaves)
    return (utils.unflatten_leading(y, lead),
            FFFOutput(leaf_idx=utils.unflatten_leading(leaf_idx, lead),
                      overflow_fraction=jnp.zeros((), jnp.float32)))


register_backend("train", "reference", _train_reference)
register_backend("train", "grouped", _train_grouped)
register_backend("infer", "reference", _infer_reference)
register_backend("infer", "grouped", _infer_grouped)
register_backend(
    "infer", "grouped_ep", _infer_grouped_ep,
    # auto/override eligibility: a model axis to exchange over and a leaf
    # count that divides across it (explicit specs still run — the backend
    # degrades gracefully unsharded)
    supports=lambda params, cfg: (
        cfg.depth > 0 and dist_act.model_shard_count() > 1
        and cfg.num_leaves % dist_act.model_shard_count() == 0))
register_backend(
    "infer", "pallas", _infer_pallas,
    # single-device kernels: ineligible under an SPMD mesh (sharded serving
    # wants the partitionable grouped dispatch, DESIGN.md §5)
    supports=lambda params, cfg: (_pallas_supported(params, cfg)
                                  and not dist_act.mesh_installed()))
register_backend(
    "infer", "pallas_decode", _infer_pallas_decode,
    # same single-device + kernel-eligibility constraints as "pallas", plus
    # a tree to descend (the megakernel's routing phase is the fusion's
    # whole point; depth-0 sites are a plain dense MLP)
    supports=lambda params, cfg: (cfg.depth > 0
                                  and _pallas_supported(params, cfg)
                                  and not dist_act.mesh_installed()))
