"""Fast Feedforward (FFF) layer — Belcak & Wattenhofer, 2023.

A differentiable balanced binary tree of depth ``d`` with ``2^d - 1`` node
networks (``<dim_in, n, 1>`` feedforward nets with a sigmoid head) and ``2^d``
leaf networks (``<dim_in, l, dim_out>`` feedforward nets).

Two execution semantics, exactly as in the paper's Algorithm 1:

* FORWARD_T (``mode="train"``): every node emits a Bernoulli probability;
  each leaf's mixture weight is the product of branch probabilities along its
  root-to-leaf path; *all* leaves are evaluated and mixed.
* FORWARD_I (``mode="infer"``): each node decision is rounded; a single
  root-to-leaf path is followed and exactly one leaf is evaluated.

The single entry point for both is :func:`repro.core.api.apply`::

    from repro.core import api, fff

    cfg = fff.FFFConfig(dim_in=64, dim_out=64, depth=4, leaf_width=8)
    params = fff.init(key, cfg)
    y, out = api.apply(params, cfg, x, api.ExecutionSpec(mode="infer"))

``ExecutionSpec.backend`` selects the implementation through a registry
(``reference`` | ``grouped`` | ``grouped_ep`` | ``pallas`` | ``auto``); see
``core/api.py``
for the registry contract and DESIGN.md §2 for the layering.  This module
holds the layer math itself — config, init, node/leaf forward primitives —
plus the pure-jnp reference/grouped implementations the registry wraps.

Node/leaf numbering follows the paper: the children of node ``N[m, k]`` are
``N[m+1, 2k]`` (left, taken with weight ``1 - c``) and ``N[m+1, 2k+1]``
(right, weight ``c``).  Nodes are stored level-major: global index of
``N[m, k]`` is ``2^m - 1 + k``.

Beyond-paper extensions (all default-off; the defaults reproduce the paper):

* ``trees > 1``      — a *forest* of independent trees whose outputs are
  summed; matches MoE top-k active width while keeping O(k*d) routing.
* ``st_training``    — straight-through top-1 training (O(l) instead of
  O(2^d * l) per token); DESIGN.md §8.
* SwiGLU leaves      — LLM-style gated leaves for transformer FFN sites.
* ``master_leaf``    — an always-on small MLP added to every token's output
  in both modes (arxiv 2405.16836); doubles as the cheap approximate
  overflow repair under ``overflow_policy="master_leaf"`` (DESIGN.md §14).
* ``balance_loss``   — load-balancing auxiliary loss over the soft leaf
  usage (same source, surfaced through ``FFNSpec.balance_scale``).
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro import utils
from repro.core import routing as routing_lib
from repro.distributed import act as dist_act

Params = dict


@dataclasses.dataclass(frozen=True)
class FFFConfig:
    dim_in: int
    dim_out: int
    depth: int                      # d >= 0; 2^d leaves
    leaf_width: int                 # l
    node_width: int = 1             # n (paper: n = 1 suffices)
    activation: str = "gelu"        # leaf hidden activation: relu|gelu|silu|swiglu
    trees: int = 1                  # forest size; 1 == paper
    hardening_scale: float = 0.0    # h; 0 disables the hardening loss term
    transposition_prob: float = 0.0  # randomized child transposition (paper §Overfragmentation)
    freeze_tree: bool = False       # paper's h = inf: boundaries not trainable
    leaf_bias: bool = True          # LLM FFNs conventionally drop biases
    st_training: bool = False       # straight-through top-1 training (beyond paper)
    master_leaf: bool = False       # always-on master MLP added to every token
    master_width: int = 0           # master hidden width; 0 = leaf_width
    param_dtype: Any = jnp.float32
    accum_dtype: Any = jnp.float32

    @property
    def num_leaves(self) -> int:
        return 2 ** self.depth

    @property
    def num_nodes(self) -> int:
        return 2 ** self.depth - 1

    @property
    def training_width(self) -> int:
        return self.trees * self.num_leaves * self.leaf_width

    @property
    def inference_width(self) -> int:
        return self.trees * self.leaf_width

    @property
    def training_size(self) -> int:
        return self.trees * (self.num_nodes * self.node_width
                             + self.num_leaves * self.leaf_width)

    @property
    def inference_size(self) -> int:
        return self.trees * (self.depth * self.node_width + self.leaf_width)

    @property
    def master_hidden(self) -> int:
        """Hidden width of the master leaf (0 defaults to leaf_width)."""
        return self.master_width or self.leaf_width

    def validate(self) -> "FFFConfig":
        if self.depth < 0:
            raise ValueError("depth must be >= 0")
        if self.leaf_width < 1 or self.node_width < 1 or self.trees < 1:
            raise ValueError("leaf_width, node_width, trees must be >= 1")
        if self.master_width < 0:
            raise ValueError("master_width must be >= 0 (0 = leaf_width)")
        if self.activation != "swiglu":
            utils.get_activation(self.activation)
        return self


def for_ffn(dim: int, d_ff: int, leaf_width: int, *, trees: int = 1,
            activation: str = "swiglu", **kw) -> FFFConfig:
    """Paper 'user manual' Case 1: replace a width-``d_ff`` FFN keeping the
    training width: ``2^d * l * trees == next_pow2(d_ff)``."""
    per_tree = utils.cdiv(d_ff, trees)
    depth = max(0, math.ceil(math.log2(max(1, utils.cdiv(per_tree, leaf_width)))))
    return FFFConfig(dim_in=dim, dim_out=dim, depth=depth, leaf_width=leaf_width,
                     trees=trees, activation=activation, leaf_bias=False, **kw)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init(key: jax.Array, cfg: FFFConfig) -> Params:
    """Parameters, stacked over a leading ``trees`` axis.

    node_w1: (T, N, dim_in, n)   node hidden weights
    node_b1: (T, N, n)
    node_w2: (T, N, n)           head -> scalar logit (sigmoid applied in fwd)
    node_b2: (T, N)
    leaves:
      gelu/relu: leaf_w1 (T, L, dim_in, l), leaf_b1 (T, L, l),
                 leaf_w2 (T, L, l, dim_out), leaf_b2 (T, L, dim_out)
      swiglu:    leaf_wg, leaf_wu (T, L, dim_in, l), leaf_wd (T, L, l, dim_out)
    master leaf (cfg.master_leaf, bias-free, shared across the forest):
      gelu/relu: master_w1 (dim_in, mw), master_w2 (mw, dim_out)
      swiglu:    master_wg, master_wu (dim_in, mw), master_wd (mw, dim_out)
    """
    cfg.validate()
    T, N, L = cfg.trees, cfg.num_nodes, cfg.num_leaves
    D, O, l, n = cfg.dim_in, cfg.dim_out, cfg.leaf_width, cfg.node_width
    pd = cfg.param_dtype
    ks = jax.random.split(key, 8)
    # Node nets start near p = 0.5 everywhere (balanced tree) with hyperplane
    # normals of modest norm so boundaries start soft (paper Fig. 1 bottom).
    params: Params = {
        "node_w1": utils.truncated_init(ks[0], (T, max(N, 1), D, n), 1.0 / math.sqrt(D), pd),
        "node_b1": jnp.zeros((T, max(N, 1), n), pd),
        "node_w2": utils.truncated_init(ks[1], (T, max(N, 1), n), 1.0 / math.sqrt(n), pd),
        "node_b2": jnp.zeros((T, max(N, 1)), pd),
    }
    if cfg.activation == "swiglu":
        params.update({
            "leaf_wg": utils.truncated_init(ks[2], (T, L, D, l), 1.0 / math.sqrt(D), pd),
            "leaf_wu": utils.truncated_init(ks[3], (T, L, D, l), 1.0 / math.sqrt(D), pd),
            "leaf_wd": utils.truncated_init(ks[4], (T, L, l, O), 1.0 / math.sqrt(l), pd),
        })
    else:
        params.update({
            "leaf_w1": utils.he_normal(ks[2], (T, L, D, l), pd, fan_in_axis=-2),
            "leaf_w2": utils.lecun_normal(ks[3], (T, L, l, O), pd, fan_in_axis=-2),
        })
        if cfg.leaf_bias:
            params["leaf_b1"] = jnp.zeros((T, L, l), pd)
            params["leaf_b2"] = jnp.zeros((T, L, O), pd)
    if cfg.master_leaf:
        # ks[5..7] were always split off but unused, so adding the master
        # leaf never perturbs the node/leaf init of existing checkpoints
        mw = cfg.master_hidden
        if cfg.activation == "swiglu":
            params.update({
                "master_wg": utils.truncated_init(ks[5], (D, mw), 1.0 / math.sqrt(D), pd),
                "master_wu": utils.truncated_init(ks[6], (D, mw), 1.0 / math.sqrt(D), pd),
                "master_wd": utils.truncated_init(ks[7], (mw, O), 1.0 / math.sqrt(mw), pd),
            })
        else:
            params.update({
                "master_w1": utils.he_normal(ks[5], (D, mw), pd, fan_in_axis=-2),
                "master_w2": utils.lecun_normal(ks[6], (mw, O), pd, fan_in_axis=-2),
            })
    return params


# ---------------------------------------------------------------------------
# node math
# ---------------------------------------------------------------------------

def _node_logits_all(params: Params, cfg: FFFConfig, x: jax.Array) -> jax.Array:
    """Logits of every node for every token: (B, T, N).

    The node net is <dim_in, n, 1>; for n == 1 the hidden activation is the
    identity so the boundary is exactly the hyperplane of the single neuron
    (paper §Regions of responsibility)."""
    h = jnp.einsum("bd,tndk->btnk", x, params["node_w1"],
                   preferred_element_type=cfg.accum_dtype)
    h = h + params["node_b1"][None].astype(cfg.accum_dtype)
    if cfg.node_width > 1:
        h = jax.nn.gelu(h)
    logit = jnp.einsum("btnk,tnk->btn", h, params["node_w2"].astype(cfg.accum_dtype))
    logit = logit + params["node_b2"][None].astype(cfg.accum_dtype)
    # pin to data-parallel: node weights are replicated and tiny, but left
    # unconstrained XLA "helpfully" model-partitions this einsum, adding an
    # unneeded (tokens, D) psum in its transpose (§Perf iter 3)
    return dist_act.shard(logit, dist_act.NODE_BTN)


def _node_logit_at(params: Params, cfg: FFFConfig, x: jax.Array,
                   gidx: jax.Array) -> jax.Array:
    """Logit of one (per-token, per-tree) node: x (B, D), gidx (B, T) -> (B, T).

    params['node_w1']: (T, N, D, n); we need per (b, t) the row gidx[b, t] of
    tree t.  vmap over the tree axis keeps the gather 1-D per tree."""
    def per_tree(w1_t, b1_t, w2_t, b2_t, idx_t):       # idx_t: (B,)
        w1_g = jnp.take(w1_t, idx_t, axis=0)           # (B, D, n)
        b1_g = jnp.take(b1_t, idx_t, axis=0)           # (B, n)
        w2_g = jnp.take(w2_t, idx_t, axis=0)           # (B, n)
        b2_g = jnp.take(b2_t, idx_t, axis=0)           # (B,)
        h = jnp.einsum("bd,bdn->bn", x, w1_g,
                       preferred_element_type=cfg.accum_dtype)
        h = h + b1_g.astype(cfg.accum_dtype)
        if cfg.node_width > 1:
            h = jax.nn.gelu(h)
        return jnp.einsum("bn,bn->b", h, w2_g.astype(cfg.accum_dtype)) \
            + b2_g.astype(cfg.accum_dtype)

    return jax.vmap(per_tree, in_axes=(0, 0, 0, 0, 1), out_axes=1)(
        params["node_w1"], params["node_b1"], params["node_w2"],
        params["node_b2"], gidx)


def mixture_weights(node_probs: jax.Array, depth: int) -> jax.Array:
    """Leaf mixture weights from level-major node probabilities.

    node_probs: (..., 2^d - 1) with node (m, k) at index 2^m - 1 + k.
    Returns (..., 2^d): w[leaf] = prod over path of p (right) / 1-p (left).
    Weights form a distribution over leaves (sum to 1) by construction.
    """
    lead = node_probs.shape[:-1]
    w = jnp.ones(lead + (1,), node_probs.dtype)
    off = 0
    for m in range(depth):
        p = node_probs[..., off:off + 2 ** m]
        w = jnp.stack([w * (1.0 - p), w * p], axis=-1).reshape(lead + (2 ** (m + 1),))
        off += 2 ** m
    return w


# ---------------------------------------------------------------------------
# leaf math
# ---------------------------------------------------------------------------

def _leaf_forward_all(params: Params, cfg: FFFConfig, x: jax.Array) -> jax.Array:
    """Evaluate every leaf of every tree: x (B, D) -> (B, T, L, dim_out)."""
    ad = cfg.accum_dtype
    if cfg.activation == "swiglu":
        g = jnp.einsum("bd,tldh->btlh", x, params["leaf_wg"], preferred_element_type=ad)
        u = jnp.einsum("bd,tldh->btlh", x, params["leaf_wu"], preferred_element_type=ad)
        h = jax.nn.silu(g) * u
        return jnp.einsum("btlh,tlho->btlo", h, params["leaf_wd"],
                          preferred_element_type=ad)
    act = utils.get_activation(cfg.activation)
    h = jnp.einsum("bd,tldh->btlh", x, params["leaf_w1"], preferred_element_type=ad)
    if "leaf_b1" in params:
        h = h + params["leaf_b1"][None].astype(ad)
    h = act(h)
    y = jnp.einsum("btlh,tlho->btlo", h, params["leaf_w2"], preferred_element_type=ad)
    if "leaf_b2" in params:
        y = y + params["leaf_b2"][None].astype(ad)
    return y


def _leaf_forward_gather(params: Params, cfg: FFFConfig, x: jax.Array,
                         leaf_idx: jax.Array) -> jax.Array:
    """Evaluate only the selected leaf per (token, tree).

    x (B, D), leaf_idx (B, T) -> (B, T, dim_out).  This is the reference
    gather path; the production serving path uses the sorted-dispatch ragged
    GEMM in ``repro.kernels.leaf_gemm`` (see core/routing.py).
    """
    ad = cfg.accum_dtype

    def per_tree(tree_params, idx_t):  # idx_t: (B,)
        def tk(name):
            return jnp.take(tree_params[name], idx_t, axis=0)
        if cfg.activation == "swiglu":
            g = jnp.einsum("bd,bdh->bh", x, tk("leaf_wg"), preferred_element_type=ad)
            u = jnp.einsum("bd,bdh->bh", x, tk("leaf_wu"), preferred_element_type=ad)
            h = jax.nn.silu(g) * u
            return jnp.einsum("bh,bho->bo", h, tk("leaf_wd"), preferred_element_type=ad)
        act = utils.get_activation(cfg.activation)
        h = jnp.einsum("bd,bdh->bh", x, tk("leaf_w1"), preferred_element_type=ad)
        if "leaf_b1" in tree_params:
            h = h + tk("leaf_b1").astype(ad)
        h = act(h)
        y = jnp.einsum("bh,bho->bo", h, tk("leaf_w2"), preferred_element_type=ad)
        if "leaf_b2" in tree_params:
            y = y + tk("leaf_b2").astype(ad)
        return y

    leaf_names = [k for k in params if k.startswith("leaf_")]
    tree_params = {k: params[k] for k in leaf_names}
    return jax.vmap(per_tree, in_axes=(0, 1), out_axes=1)(tree_params, leaf_idx)


def master_apply(params: Params, cfg: FFFConfig, x: jax.Array) -> jax.Array:
    """The master leaf (arxiv 2405.16836): one small always-on MLP shared by
    every token, x (..., dim_in) -> (..., dim_out).

    Added to the routed output in BOTH modes by ``api.apply`` (so train and
    infer see the same function), and the whole output for tokens dropped
    under ``overflow_policy="master_leaf"`` — the cheap approximate overflow
    repair (DESIGN.md §14).  Dense math, no routing, no dispatch: a plain
    (D, mw) + (mw, O) matmul pair riding whatever program already runs."""
    ad = cfg.accum_dtype
    xf = x.astype(ad)
    if cfg.activation == "swiglu":
        g = jnp.einsum("...d,dh->...h", xf, params["master_wg"],
                       preferred_element_type=ad)
        u = jnp.einsum("...d,dh->...h", xf, params["master_wu"],
                       preferred_element_type=ad)
        h = jax.nn.silu(g) * u
        return jnp.einsum("...h,ho->...o", h, params["master_wd"],
                          preferred_element_type=ad)
    act = utils.get_activation(cfg.activation)
    h = act(jnp.einsum("...d,dh->...h", xf, params["master_w1"],
                       preferred_element_type=ad))
    return jnp.einsum("...h,ho->...o", h, params["master_w2"],
                      preferred_element_type=ad)


# ---------------------------------------------------------------------------
# execution implementations (paper Algorithm 1); the public entry point is
# repro.core.api.apply() — these are the "reference" and "grouped" backends
# ---------------------------------------------------------------------------

def _soft_stats(params: Params, cfg: FFFConfig, xf: jax.Array,
                rng: Optional[jax.Array]) -> tuple[jax.Array, jax.Array,
                                                   jax.Array]:
    """Per-token soft routing statistics on flattened tokens ``xf`` (B, D):
    node probabilities (B, T, N), leaf mixture (B, T, L), mean entropy."""
    B = xf.shape[0]
    if cfg.depth == 0:
        return (jnp.zeros((B, cfg.trees, 0), cfg.accum_dtype),
                jnp.ones((B, cfg.trees, 1), cfg.accum_dtype),
                jnp.zeros((), cfg.accum_dtype))
    logits = _node_logits_all(params, cfg, xf)            # (B, T, N)
    if cfg.freeze_tree:                                    # paper's h = inf
        logits = jax.lax.stop_gradient(logits)
    probs = jax.nn.sigmoid(logits)
    if cfg.transposition_prob > 0.0 and rng is not None:
        # randomized child transposition: swap <1-p, p> -> <p, 1-p> with low
        # probability, exposing children to neighbouring regions' data.
        flip = jax.random.bernoulli(rng, cfg.transposition_prob, probs.shape)
        probs = jnp.where(flip, 1.0 - probs, probs)
    mix = mixture_weights(probs, cfg.depth)               # (B, T, L)
    ent = bernoulli_entropy(probs).mean()
    return probs, mix, ent


def _forward_soft_mixture(params: Params, cfg: FFFConfig, x: jax.Array,
                          rng: Optional[jax.Array] = None
                          ) -> tuple[jax.Array, dict]:
    """FORWARD_T: soft mixture over all leaves (the training reference).

    x: (..., dim_in) -> (..., dim_out), plus aux dict with
    ``node_probs`` (B, T, N), ``mixture`` (B, T, L), ``entropy`` scalar.
    """
    xf, lead = utils.flatten_leading(x)
    xf = xf.astype(cfg.accum_dtype)
    probs, mix, ent = _soft_stats(params, cfg, xf, rng)
    leaf_out = _leaf_forward_all(params, cfg, xf)         # (B, T, L, O)
    y = jnp.einsum("btl,btlo->bo", mix, leaf_out)
    aux = {"node_probs": probs, "mixture": mix, "entropy": ent}
    return utils.unflatten_leading(y, lead), aux


def _st_descend(cfg: FFFConfig, probs: jax.Array
                ) -> tuple[jax.Array, jax.Array]:
    """Hard top-1 descent with a straight-through path-probability scale.

    probs (B, T, N) -> (leaf_idx (B, T) int32, scale (B, T)) where the scale's
    forward value is exactly 1 while its gradient flows into the path
    probabilities (DESIGN.md §8)."""
    B, T = probs.shape[0], probs.shape[1]
    idx = jnp.zeros((B, T), jnp.int32)
    path_prob = jnp.ones((B, T), cfg.accum_dtype)
    off = 0
    for m in range(cfg.depth):
        p_level = probs[:, :, off:off + 2 ** m]                       # (B, T, 2^m)
        p_here = jnp.take_along_axis(p_level, idx[..., None], axis=2)[..., 0]
        bit = jax.lax.stop_gradient((p_here >= 0.5).astype(jnp.int32))
        path_prob = path_prob * jnp.where(bit == 1, p_here, 1.0 - p_here)
        idx = 2 * idx + bit
        off += 2 ** m
    scale = path_prob + jax.lax.stop_gradient(1.0 - path_prob)        # (B, T)
    return idx, scale


def _pad_for_dispatch(xf: jax.Array, multiple: int
                      ) -> tuple[jax.Array, int]:
    """Pad flat tokens up to ``multiple`` BEFORE routing so every sharded
    intermediate (node logits under NODE_BTN, dispatch buffers) has a
    shard-divisible token axis.  Constraining a non-divisible axis forces
    XLA into padded-sharding lowerings of the downstream scatter — slower,
    and observed to miscompile when the dispatch constraints compose
    (DESIGN.md §5).  Returns (padded tokens, true token count); callers
    route the pads to the capacity-neutral sentinel leaf and slice outputs
    back to the true count.

    The pad is a zeros-buffer update, NOT ``jnp.concatenate``: the SPMD
    partitioner on this jax mis-lowers a token-axis concatenate feeding the
    NODE_BTN + dispatch constraint chain (every output wrong on a (4,2)
    mesh at B=37 while the same program is exact unsharded); the
    dynamic-update-slice form partitions correctly."""
    B = xf.shape[0]
    Bp = utils.round_up(max(B, 1), multiple)
    if Bp == B:
        return xf, B
    buf = jnp.zeros((Bp,) + xf.shape[1:], xf.dtype)
    return buf.at[:B].set(xf), B


def _sentinel_pads(leaf_idx: jax.Array, true_count: int, num_leaves: int
                   ) -> jax.Array:
    """Route the pad rows of a ``_pad_for_dispatch``-padded batch to the
    capacity-neutral sentinel leaf E: leaf_idx (Bp, T) -> (Bp, T) with rows
    >= true_count replaced by ``num_leaves`` (core/routing treats that id as
    a virtual group that never occupies real capacity)."""
    return jnp.where(jnp.arange(leaf_idx.shape[0])[:, None] < true_count,
                     leaf_idx, num_leaves)


def _forward_st_grouped(params: Params, cfg: FFFConfig, x: jax.Array,
                        rng: Optional[jax.Array] = None,
                        capacity_factor: float = 1.5
                        ) -> tuple[jax.Array, dict]:
    """Beyond-paper: top-1 training at O(l) leaf cost with an ST estimator.

    The hard path is followed (stop-gradient); the selected leaf output is
    scaled by ``path_prob + sg(1 - path_prob)`` so the forward value equals
    the leaf output while gradients flow into the path probabilities.  Leaf
    execution is the differentiable capacity-bounded grouped dispatch
    (core/routing.py) — O(B * l * D) compute and memory, EP-shardable; this
    is what makes trillion-scale FFF-for-MoE training feasible (DESIGN.md §8).
    """
    xf, lead = utils.flatten_leading(x)
    xf = xf.astype(cfg.accum_dtype)
    xf, B = _pad_for_dispatch(xf, dist_act.data_shard_count())
    probs, mix, ent = _soft_stats(params, cfg, xf, rng)
    if xf.shape[0] != B:  # keep the entropy monitor over real tokens only
        ent = bernoulli_entropy(probs[:B]).mean()
    idx, scale = _st_descend(cfg, probs)
    idx = _sentinel_pads(idx, B, cfg.num_leaves)
    out = None
    kept_all = []
    for t in range(cfg.trees):
        tree_leaves = {k: v[t] for k, v in params.items()
                       if k.startswith("leaf_")}
        y, kept = routing_lib.grouped_leaf_apply(
            xf, idx[:, t], tree_leaves, cfg.activation,
            capacity_factor=capacity_factor, accum_dtype=cfg.accum_dtype,
            return_kept=True)
        y = y * scale[:, t:t + 1]
        out = y if out is None else out + y
        kept_all.append(kept[:B])
    overflow = 1.0 - jnp.stack(kept_all).astype(cfg.accum_dtype).mean()
    aux = {"node_probs": probs[:B], "mixture": mix[:B], "entropy": ent,
           "leaf_idx": idx[:B].reshape(*lead, cfg.trees),
           "overflow_fraction": overflow}
    return utils.unflatten_leading(out[:B], lead), aux


def _sentinel_invalid(leaf_idx: jax.Array, valid: Optional[jax.Array],
                      lead: tuple, B: int, num_leaves: int
                      ) -> tuple[jax.Array, Optional[jax.Array]]:
    """Route caller-declared invalid tokens to the capacity-neutral sentinel
    leaf, same mechanism as ``_sentinel_pads`` (DESIGN.md §9: a serving
    engine's free-slot phantom rows must not consume grouped-dispatch
    capacity or pollute routing telemetry).  ``valid`` is broadcastable to
    the leading (batch, ...) shape; returns the masked (Bp, T) leaf_idx and
    the flat (Bp,) validity (pads invalid) for overflow accounting, or
    (leaf_idx, None) when no mask was given."""
    if valid is None:
        return leaf_idx, None
    vf = jnp.broadcast_to(valid, lead).reshape(-1)
    # zeros-buffer pad, not concatenate, for the same SPMD-lowering reason
    # as _pad_for_dispatch
    vfp = jnp.zeros((leaf_idx.shape[0],), bool).at[:B].set(vf)
    return jnp.where(vfp[:, None], leaf_idx, num_leaves), vfp


def _overflow_from_kept(kept_all: list, vfp: Optional[jax.Array], B: int,
                        accum_dtype) -> jax.Array:
    """Dropped fraction over REAL routed slots: invalid/sentinel rows are
    never ``kept`` by construction, so they must be excluded from the
    denominator or phantom rows would read as overflow."""
    kept = jnp.stack(kept_all).astype(accum_dtype)        # (T, B)
    if vfp is None:
        return 1.0 - kept.mean()
    w = vfp[:B].astype(accum_dtype)
    denom = jnp.maximum(w.sum() * kept.shape[0], 1.0)
    return 1.0 - (kept * w[None, :]).sum() / denom


def _forward_hard_grouped(params: Params, cfg: FFFConfig, x: jax.Array,
                          capacity_factor: float = 2.0,
                          dense_levels: int = 8,
                          valid: Optional[jax.Array] = None,
                          overflow_policy: str = "drop"
                          ) -> tuple[jax.Array, dict]:
    """FORWARD_I via capacity-bounded grouped dispatch (pure jnp, EP-shardable).

    The lowering-friendly twin of kernels/leaf_gemm.fff_infer: same dispatch
    structure, expressed in einsums so pjit/SPMD can partition it.  Used by
    the serving path for MoE-scale FFF sites (DESIGN.md §3).  ``valid``
    (broadcastable to x's leading shape) routes phantom tokens to the
    sentinel leaf: zero capacity use, zero output, excluded from overflow.

    ``overflow_policy`` (DESIGN.md §14): "drop" (historical behaviour;
    over-capacity tokens contribute zeros), "exact_dense" (a lax.cond-gated
    per-token dense repair of dropped tokens, same mechanism as the EP
    backend's overflow-to-dense round), or "master_leaf" (identical to
    "drop" at this layer — the always-on master-leaf term api.apply adds
    centrally IS the approximate repair, so dropped tokens degrade to the
    master output instead of zero).  ``overflow_fraction`` always reports
    the true over-capacity rate regardless of policy."""
    xf, lead = utils.flatten_leading(x)
    xf = xf.astype(cfg.accum_dtype)
    xf, B = _pad_for_dispatch(xf, dist_act.data_shard_count())
    leaf_idx = route_hard(params, cfg, xf,
                          dense_levels=dense_levels).reshape(xf.shape[0],
                                                             cfg.trees)
    leaf_idx = _sentinel_pads(leaf_idx, B, cfg.num_leaves)
    leaf_idx, vfp = _sentinel_invalid(leaf_idx, valid, lead, B,
                                      cfg.num_leaves)
    out = None
    kept_all = []
    for t in range(cfg.trees):
        tree_leaves = {k: v[t] for k, v in params.items()
                       if k.startswith("leaf_")}
        y, kept = routing_lib.grouped_leaf_apply(
            xf, leaf_idx[:, t], tree_leaves, cfg.activation,
            capacity_factor=capacity_factor, accum_dtype=cfg.accum_dtype,
            serving=True, return_kept=True)
        if overflow_policy == "exact_dense":
            # repair only REAL overflow (sentinel pads/invalids need none);
            # the cond keeps the steady state free of gather traffic
            dropped = ~kept & (leaf_idx[:, t] < cfg.num_leaves)

            def repair(y, d=dropped, it=leaf_idx[:, t], tl=tree_leaves):
                return jnp.where(
                    d[:, None],
                    routing_lib._dense_leaf_gather(
                        xf, it, tl, cfg.activation, cfg.accum_dtype), y)

            y = jax.lax.cond(dropped.any(), repair, lambda y: y, y)
        out = y if out is None else out + y
        kept_all.append(kept[:B])
    overflow = _overflow_from_kept(kept_all, vfp, B, cfg.accum_dtype)
    aux = {"leaf_idx": leaf_idx[:B].reshape(*lead, cfg.trees),
           "overflow_fraction": overflow}
    return utils.unflatten_leading(out[:B], lead), aux


def _forward_hard_ep(params: Params, cfg: FFFConfig, x: jax.Array,
                     capacity_factor: float = 1.25,
                     dense_levels: int = 8,
                     valid: Optional[jax.Array] = None,
                     overflow_policy: str = "exact_dense"
                     ) -> tuple[jax.Array, dict]:
    """FORWARD_I via expert-parallel all_to_all dispatch.

    Routing runs data-parallel (node nets are replicated); leaf execution
    crosses shards deliberately: tokens travel over the model axis to the
    shard owning their routed leaf (``routing.grouped_leaf_apply_ep``,
    DESIGN.md §5).  Under the default ``overflow_policy="exact_dense"``
    over-capacity tokens are repaired by the overflow-to-dense round, so
    outputs match the reference backend exactly; "master_leaf" and "drop"
    (DESIGN.md §14) skip the all_gather repair round entirely — dropped
    tokens fall back to the central master-leaf term or to zeros — and
    ``overflow_fraction`` reports the true over-capacity rate either way."""
    xf, lead = utils.flatten_leading(x)
    xf = xf.astype(cfg.accum_dtype)
    xf, B = _pad_for_dispatch(
        xf, dist_act.data_shard_count() * dist_act.model_shard_count())
    leaf_idx = route_hard(params, cfg, xf,
                          dense_levels=dense_levels).reshape(xf.shape[0],
                                                             cfg.trees)
    leaf_idx = _sentinel_pads(leaf_idx, B, cfg.num_leaves)
    leaf_idx, vfp = _sentinel_invalid(leaf_idx, valid, lead, B,
                                      cfg.num_leaves)
    out = None
    kept_all = []
    for t in range(cfg.trees):
        tree_leaves = {k: v[t] for k, v in params.items()
                       if k.startswith("leaf_")}
        y, kept = routing_lib.grouped_leaf_apply_ep(
            xf, leaf_idx[:, t], tree_leaves, cfg.activation,
            capacity_factor=capacity_factor, accum_dtype=cfg.accum_dtype,
            overflow_policy=overflow_policy, return_kept=True)
        out = y if out is None else out + y
        kept_all.append(kept[:B])
    overflow = _overflow_from_kept(kept_all, vfp, B, cfg.accum_dtype)
    aux = {"leaf_idx": leaf_idx[:B].reshape(*lead, cfg.trees),
           "overflow_fraction": overflow}
    return utils.unflatten_leading(out[:B], lead), aux


def route_hard(params: Params, cfg: FFFConfig, x: jax.Array,
               dense_levels: int = 8) -> jax.Array:
    """FORWARD_I descent only: x (..., dim_in) -> leaf indices (..., trees).

    Two regimes (DESIGN.md §3): for shallow levels one dense MXU matmul
    computes every node logit and the descent is a register-local
    take_along_axis; deep levels fall back to per-token gathers.  The node
    FLOPs are O(2^min(d,dense) * n) per token — negligible next to the leaf
    cost for the depths the paper uses (and d <= 8 covers every config here).
    """
    xf, lead = utils.flatten_leading(x)
    xf = xf.astype(cfg.accum_dtype)
    B = xf.shape[0]
    idx = jnp.zeros((B, cfg.trees), jnp.int32)
    nd = min(dense_levels, cfg.depth)
    if nd > 0:
        n_dense = 2 ** nd - 1
        p_dense = {k: (v[:, :n_dense] if k.startswith("node_") else v)
                   for k, v in params.items()}
        logits = _node_logits_all(p_dense, cfg, xf)       # (B, T, n_dense)
        off = 0
        for m in range(nd):
            level = logits[:, :, off:off + 2 ** m]        # (B, T, 2^m)
            cur = jnp.take_along_axis(level, idx[..., None], axis=2)[..., 0]
            idx = 2 * idx + (cur >= 0).astype(jnp.int32)
            off += 2 ** m
    for m in range(nd, cfg.depth):
        gidx = (2 ** m - 1) + idx
        logit = _node_logit_at(params, cfg, xf, gidx)     # (B, T)
        idx = 2 * idx + (logit >= 0).astype(jnp.int32)
    return idx.reshape(*lead, cfg.trees)


def _forward_hard_gather(params: Params, cfg: FFFConfig, x: jax.Array,
                         dense_levels: int = 8) -> tuple[jax.Array, dict]:
    """FORWARD_I: hard descent + single-leaf evaluation per tree (the exact
    inference reference — no capacity bound, per-token weight gathers)."""
    xf, lead = utils.flatten_leading(x)
    xf = xf.astype(cfg.accum_dtype)
    leaf_idx = route_hard(params, cfg, xf,
                          dense_levels=dense_levels).reshape(xf.shape[0],
                                                             cfg.trees)
    y = _leaf_forward_gather(params, cfg, xf, leaf_idx).sum(axis=1)
    return utils.unflatten_leading(y, lead), {"leaf_idx":
                                              leaf_idx.reshape(*lead, cfg.trees)}


# ---------------------------------------------------------------------------
# hardening (paper §Hardening)
# ---------------------------------------------------------------------------

def bernoulli_entropy(p: jax.Array, eps: float = 1e-7) -> jax.Array:
    """H(Bernoulli(p)) in nats, elementwise, numerically safe at p in {0,1}."""
    p = jnp.clip(p, eps, 1.0 - eps)
    return -(p * jnp.log(p) + (1.0 - p) * jnp.log1p(-p))


def hardening_loss(node_probs: jax.Array, reduction: str = "mean") -> jax.Array:
    """L_harden = sum over batch and nodes of H(N(iota)).

    The paper sums; ``mean`` (default) is scale-invariant across depths and is
    what we use in training loops (the scale is folded into ``h``)."""
    ent = bernoulli_entropy(node_probs)
    if reduction == "sum":
        return ent.sum()
    return ent.mean()


def balance_loss(node_probs: jax.Array, depth: int) -> jax.Array:
    """Load-balancing auxiliary loss over soft leaf usage (arxiv 2405.16836).

    node_probs (B, T, N) -> scalar ``E * sum_e mean_batch(P)_e^2 - 1``, mean
    over trees, where P is each token's soft leaf mixture
    (``mixture_weights``).  By Cauchy-Schwarz the sum-of-squares term is
    >= 1/E with equality exactly at uniform mean usage, so the loss is 0 at
    balance and grows with skew — pushing the node hyperplanes to split
    traffic evenly, which is what lets serving run capacity factors < 1
    without overflow (DESIGN.md §14).  Differentiable through the same soft
    probabilities the hardening loss uses, so it works for both the soft
    FORWARD_T reference and the ST grouped estimator."""
    if depth == 0:
        return jnp.zeros((), node_probs.dtype)
    mix = mixture_weights(node_probs, depth)           # (B, T, E)
    usage = mix.mean(axis=0)                           # (T, E) mean leaf prob
    E = mix.shape[-1]
    return (E * jnp.square(usage).sum(axis=-1) - 1.0).mean()


def leaf_usage(node_probs: jax.Array, depth: int) -> jax.Array:
    """Mean soft leaf usage per tree: (B, T, N) -> (T, 2^depth) distribution
    (the quantity ``balance_loss`` penalizes the skew of)."""
    return mixture_weights(node_probs, depth).mean(axis=0)


def decision_entropy_per_node(node_probs: jax.Array) -> jax.Array:
    """Batch-mean Bernoulli entropy per node: (B, T, N) -> (T, N).

    The paper's hardening monitor: below ~0.10 rounding is nearly lossless."""
    return bernoulli_entropy(node_probs).mean(axis=0)


def decisive_fraction(node_probs: jax.Array, threshold: float = 0.10) -> jax.Array:
    """Fraction of (token, node) decisions whose entropy is below threshold."""
    return (bernoulli_entropy(node_probs) < threshold).mean()


# ---------------------------------------------------------------------------
# equivalence helper (paper §Size and width)
# ---------------------------------------------------------------------------

def as_dense_ff_params(params: Params, cfg: FFFConfig) -> Params:
    """FFF with all node weights zero == vanilla FF with 2^d*l neurons, up to a
    uniform output rescale of 2^-d (every leaf mixed with weight 2^-d).

    Returns the equivalent dense-FF parameter set (single tree only)."""
    if cfg.trees != 1 or cfg.activation == "swiglu":
        raise ValueError("dense equivalence defined for single-tree MLP leaves")
    L = cfg.num_leaves
    w1 = params["leaf_w1"][0].transpose(1, 0, 2).reshape(cfg.dim_in, L * cfg.leaf_width)
    w2 = (params["leaf_w2"][0] * (1.0 / L)).reshape(L * cfg.leaf_width, cfg.dim_out)
    out: Params = {"w1": w1, "w2": w2}
    if "leaf_b1" in params:
        out["b1"] = params["leaf_b1"][0].reshape(L * cfg.leaf_width)
        out["b2"] = params["leaf_b2"][0].sum(axis=0) * (1.0 / L)
    return out
