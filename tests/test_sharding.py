"""Distribution-layer tests that need multiple devices run in a SUBPROCESS
with 8 fake host devices (the main test process keeps the real single CPU
device, per the assignment's constraint on XLA_FLAGS placement)."""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_with_fake_devices(code: str, n: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", code], env=env, cwd=REPO,
                         capture_output=True, text=True, timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_pjit_train_step_matches_single_device():
    """The sharded train step computes the same loss/grad-update as the
    unsharded one (data=4 x model=2 fake mesh)."""
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np, dataclasses
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import registry
        from repro.models import lm
        from repro.distributed import act, sharding
        from repro.launch import mesh as mesh_lib
        from repro import optim

        cfg = registry.get_config("internlm2-20b", ffn="fff").reduced()
        cfg = dataclasses.replace(cfg, n_layers=2, d_model=64, n_heads=4,
                                  n_kv_heads=2)
        params = lm.init(jax.random.PRNGKey(0), cfg)
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 16),
                                              0, cfg.vocab_size),
                 "labels": jax.random.randint(jax.random.PRNGKey(2), (8, 16),
                                              0, cfg.vocab_size)}
        l_ref, _ = lm.loss_fn(params, cfg, batch)

        mesh = mesh_lib.make_mesh((4, 2), ("data", "model"))
        rules = sharding.activation_rules(mesh)
        p_sh = sharding.shard_params(params, mesh)
        with act.use_mesh(mesh, rules):
            l_sharded, _ = jax.jit(lambda p, b: lm.loss_fn(p, cfg, b))(p_sh, batch)
        print("DIFF", abs(float(l_ref) - float(l_sharded)))
    """)
    out = run_with_fake_devices(code)
    diff = float(out.strip().split("DIFF")[-1])
    assert diff < 1e-3, out


def test_param_specs_divisibility_everywhere():
    """Every param sharding divides its dimension on the production mesh
    (validated on a small 4x4 mesh with the same axis names)."""
    code = textwrap.dedent("""
        import jax, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.configs import registry
        from repro.models import lm
        from repro.distributed import sharding
        from repro.launch import mesh as mesh_lib
        from functools import partial

        mesh = mesh_lib.make_mesh((2, 4), ("data", "model"))
        for arch in registry.ARCH_IDS:
            cfg = registry.get_config(arch, ffn="fff").reduced(d_model=128,
                                                               n_heads=8)
            struct = jax.eval_shape(partial(lm.init, cfg=cfg),
                                    jax.random.PRNGKey(0))
            specs = sharding.param_specs(struct, mesh)
            flat_s, _ = jax.tree_util.tree_flatten_with_path(
                specs, is_leaf=lambda x: isinstance(x, P))
            flat_l, _ = jax.tree_util.tree_flatten_with_path(struct)
            for (kp, spec), (_, leaf) in zip(flat_s, flat_l):
                for dim, ax in zip(leaf.shape, tuple(spec)):
                    if ax is None:
                        continue
                    axes = ax if isinstance(ax, tuple) else (ax,)
                    size = int(np.prod([mesh.shape[a] for a in axes]))
                    assert dim % size == 0, (arch, kp, leaf.shape, spec)
        print("OK")
    """)
    assert "OK" in run_with_fake_devices(code)


def test_compressed_psum_shard_map():
    """int8 error-feedback all-reduce under shard_map reduces correctly."""
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from functools import partial
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.distributed import compression
        from repro.launch import mesh as mesh_lib

        mesh = mesh_lib.make_mesh((8,), ("pod",))
        x = jax.random.normal(jax.random.PRNGKey(0), (8, 64))

        f = shard_map(lambda a: compression.compressed_psum(a, "pod"),
                      mesh=mesh, in_specs=P("pod", None),
                      out_specs=P("pod", None))
        got = f(x)[0]
        want = x.sum(0)
        rel = float(jnp.abs(got - want).max() / (jnp.abs(want).max() + 1e-9))
        print("REL", rel)
    """)
    rel = float(run_with_fake_devices(code).strip().split("REL")[-1])
    assert rel < 0.05   # int8 quantization tolerance


def test_elastic_reshard_across_device_counts():
    """Save on an 8-device mesh, restore onto 4 devices (elastic re-mesh)."""
    code = textwrap.dedent("""
        import os, tempfile, jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.checkpoint import save_tree, reshard_restore
        from repro.distributed import sharding
        from repro.launch import mesh as mesh_lib

        mesh8 = mesh_lib.make_mesh((4, 2), ("data", "model"))
        tree = {"ffn": {"w1": jnp.arange(64.0).reshape(8, 8)}}
        placed = sharding.shard_params(tree, mesh8)
        d = tempfile.mkdtemp()
        save_tree(os.path.join(d, "c"), placed, step=3)

        mesh4 = mesh_lib.make_mesh((2, 2), ("data", "model"))
        def spec_fn(path, leaf):
            return sharding.spec_for_path(
                sharding.path_of(path), leaf.ndim, mesh4, leaf.shape)
        restored, step, _ = reshard_restore(os.path.join(d, "c"), tree,
                                            mesh4, spec_fn)
        ok = np.allclose(np.asarray(restored["ffn"]["w1"]),
                         np.arange(64.0).reshape(8, 8))
        print("OK" if ok and step == 3 else "FAIL")
    """)
    assert "OK" in run_with_fake_devices(code)


def test_dryrun_entry_point_small():
    """launch/dryrun.py lowers+compiles a cell end-to-end in a subprocess
    (its own 512-device XLA_FLAGS line is what this exercises)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "whisper-small", "--shape", "train_4k", "--multi-pod", "multi"],
        env=env, cwd=REPO, capture_output=True, text=True, timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "ok" in out.stdout
