"""Serving request/result types (DESIGN.md §9).

A ``Request`` is what enters the engine queue: prompt tokens plus sampling
and stop parameters.  ``tenant`` names the traffic class the request bills
to — the engine keeps per-tenant queues, the ``weighted_leaf_aware``
scheduler does weighted-fair admission across tenants, and the online
routing-profile store (``serving/profiles.py``) learns each tenant's leaf
footprint from its finished requests.  ``leaf_hint`` is an optional prior
over the model's FFF leaves for this request's tokens (e.g. a per-tenant
routing profile measured offline) — the leaf-aware schedulers use it to
predict how a candidate would load the grouped dispatch before the request
has ever been prefilled; without one they fall back to the tenant's learned
profile, then uniform.  Once admitted, live telemetry replaces both.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


# eq=False (identity equality): the engine's queue.remove and the
# scheduler's hold map must never field-compare numpy prompts (ambiguous
# truth value), and duplicate rids must not alias distinct requests
@dataclasses.dataclass(eq=False)
class Request:
    rid: int
    prompt: np.ndarray                      # (L,) int32 token ids
    max_new_tokens: int = 16
    temperature: float = 0.0                # 0 = greedy
    eos_id: Optional[int] = None            # None = run the full budget
    arrival_time: float = 0.0               # engine-clock seconds
    leaf_hint: Optional[np.ndarray] = None  # (E,) nonnegative, any scale
    tenant: str = "default"                 # traffic class (QoS accounting)

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        if self.prompt.size == 0:
            raise ValueError(f"request {self.rid}: empty prompt")
        if not self.tenant or not isinstance(self.tenant, str):
            raise ValueError(f"request {self.rid}: tenant must be a "
                             f"non-empty string")
        if self.max_new_tokens < 1:
            raise ValueError(f"request {self.rid}: max_new_tokens < 1")
        if self.leaf_hint is not None:
            self.leaf_hint = np.asarray(self.leaf_hint, np.float64).reshape(-1)
            if (self.leaf_hint < 0).any():
                # the scheduler normalizes by sum: a mixed-sign hint would
                # yield negative footprints that *lower* predicted load and
                # queue-jump every honest request
                raise ValueError(f"request {self.rid}: leaf_hint must be "
                                 f"nonnegative")
            if not np.isfinite(self.leaf_hint).all():
                # NaN defeats every downstream usability predicate
                # (sum() <= 0 is False for NaN) and would poison the
                # scheduler's accumulated load for the whole admission round
                raise ValueError(f"request {self.rid}: leaf_hint must be "
                                 f"finite")


@dataclasses.dataclass(eq=False)
class RequestResult:
    """Completed request: generated tokens + lifecycle timestamps (engine
    clock, seconds).  ``finish_reason`` is "eos" | "length"."""
    rid: int
    prompt: np.ndarray
    tokens: np.ndarray                      # (n_generated,) int32
    finish_reason: str
    arrival_time: float
    admitted_time: float
    first_token_time: float
    finish_time: float
    tenant: str = "default"
    # speculative decoding (DESIGN.md §10): draft tokens proposed/accepted
    # for this request — 0/0 when the engine runs without speculation
    n_drafted: int = 0
    n_accepted: int = 0

    @property
    def n_generated(self) -> int:
        return int(self.tokens.size)

    @property
    def ttft(self) -> float:
        return self.first_token_time - self.arrival_time

    @property
    def e2e_latency(self) -> float:
        return self.finish_time - self.arrival_time

    def per_token_latency(self) -> float:
        """Mean decode seconds per generated token after the first."""
        n = max(self.n_generated - 1, 1)
        return (self.finish_time - self.first_token_time) / n


@dataclasses.dataclass
class SlotState:
    """Host-side record of one cache slot's occupant.

    ``prefill_pos`` tracks chunked-prefill progress: how many prompt tokens
    are already consumed into the slot's cache.  Monolithic admission sets
    it to the full prompt length up front; under chunked prefill it advances
    chunk by chunk and the slot decodes only once ``prefilling`` is False.
    ``first_token_time`` is 0.0 until the first token is actually sampled
    (at admission for monolithic prefill, at prefill completion for
    chunked)."""
    request: Request
    admitted_time: float
    first_token_time: float
    tokens: list                            # generated token ids (host ints)
    total_len: int                          # prompt + generated, in cache
    prefill_pos: int = 0                    # prompt tokens consumed so far
    done: bool = False
    finish_reason: str = ""
    finish_time: float = 0.0
    n_drafted: int = 0                      # spec decoding: proposed drafts
    n_accepted: int = 0                     # spec decoding: accepted drafts

    @property
    def prefilling(self) -> bool:
        """True while the occupant still has prompt tokens to consume."""
        return self.prefill_pos < self.request.prompt.size
