"""The paper's own Table-3 setting: a 4-layer vision transformer, patch size
4, hidden dimension 128, with FFN sites of training width 128 that are
replaced by FFF layers of leaf size l in {1,2,4,8,16,32} and depth
log2(128/l).  Used by benchmarks/table3.py and examples/vit_cifar_fff.py."""
import dataclasses

import jax.numpy as jnp

from repro.configs.base import BlockSpec, FFNSpec, ModelConfig


def vit_config(ffn_kind: str = "dense", leaf_width: int = 32,
               hardening_scale: float = 10.0) -> ModelConfig:
    if ffn_kind == "dense":
        ffn = FFNSpec(kind="dense", d_ff=128, activation="gelu")
    else:
        ffn = FFNSpec(kind="dense", d_ff=128,
                      activation="gelu").as_fff(leaf_width=leaf_width, trees=1)
        ffn = dataclasses.replace(ffn, hardening_scale=hardening_scale)
    return ModelConfig(
        arch_id=f"paper-vit-{ffn_kind}-l{leaf_width}",
        family="vlm",
        d_model=128,
        n_layers=4,
        n_heads=4,
        n_kv_heads=4,
        vocab_size=10,            # CIFAR-10 classes (head reuses vocab)
        max_seq_len=65,           # 8x8 patches + CLS
        pos_emb="learned",
        norm="layernorm",
        frontend="vision_stub",
        period=(BlockSpec(mixer="attn", ffn=ffn),),
        param_dtype=jnp.float32,
        accum_dtype=jnp.float32,
        scan_layers=False,
        attn_chunk=64,
    )


CONFIG = vit_config("dense")
FFF_CONFIG = vit_config("fff", leaf_width=32)
