"""Differential kernel-testing harness (DESIGN.md §13).

Every kernel package under ``repro.kernels`` ships a pure-jnp ``ref.py``
oracle next to its Pallas ``kernel.py``; this suite drives each pair through
one shared parameter matrix — dtypes (fp32/bf16), tree depths, leaf widths,
non-power-of-two batch sizes, skewed and degenerate routings (all tokens in
one leaf, sentinel-masked phantom rows) — instead of the per-kernel ad-hoc
shapes in tests/test_kernels.py.  Tolerances come from the shared
dtype-keyed policy in conftest.py.

Also the home of:
* the unit tests for ``kernels/common.py`` (``pick_tile`` divisibility
  guarantees, ``default_interpret``, the jaxpr-walking dispatch counter);
* the dispatch-count gate the CI serving job runs by name
  (``test_fused_decode_dispatch_count``): the legacy decode path issues
  THREE ``pallas_call``s, the fused megakernel exactly ONE;
* property tests (hypothesis where available — the container may not have
  it, so they are import-guarded like tests/test_serving_paged.py, with
  seeded sweeps that always run covering the same invariants).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.core import api, fff
from repro.kernels import common
from repro.kernels.fused_decode import ops as fd_ops
from repro.kernels.fused_decode import fused_forest_decode
from repro.kernels.fused_decode.ref import fused_decode_ref as fd_kernel_ref
from repro.kernels.fused_fff import (fff_decode, gathered_matmul,
                                     gathered_matmul_ref)
from repro.kernels.leaf_gemm import grouped_matmul, grouped_matmul_ref
from repro.kernels.tree_router import route, tree_router_ref
from repro.models import lm
from repro.serving import ContinuousBatchingEngine, EngineConfig, Request

from conftest import assert_close

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # container has no
    HAVE_HYPOTHESIS = False                           # hypothesis; the
                                                      # seeded sweeps below
                                                      # cover the properties

# the shared differential matrix: every kernel-vs-oracle test draws its
# axes from here so adding a case exercises the whole kernel surface
DTYPES = [jnp.float32, jnp.bfloat16]
DEPTHS = [1, 2, 4]
LEAF_WIDTHS = [4, 8]
ODD_BATCHES = [1, 7, 37]            # non-power-of-two: no tile evenly fits


def _fff_cfg(depth=3, act="gelu", trees=1, dim=16, leaf=8, master=False):
    return fff.FFFConfig(dim_in=dim, dim_out=dim, depth=depth,
                         leaf_width=leaf, activation=act, trees=trees,
                         leaf_bias=False, master_leaf=master)


def _fff(seed, **kw):
    cfg = _fff_cfg(**kw)
    return fff.init(jax.random.PRNGKey(seed), cfg), cfg


def _cast(tree, dtype):
    return jax.tree_util.tree_map(lambda a: a.astype(dtype), tree)


# ---------------------------------------------------------------------------
# kernels/common.py units
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [1, 7, 8, 20, 37, 96, 100, 128, 1000])
@pytest.mark.parametrize("preferred", [1, 8, 12, 64, 128])
def test_pick_tile_always_divides(n, preferred):
    t = common.pick_tile(n, preferred)
    assert 1 <= t <= max(n, preferred)
    assert n % t == 0, (n, preferred, t)       # grids are sized n // tile


def test_pick_tile_small_n_is_whole():
    # n <= preferred: one whole tile, never split (the edge the old
    # fall-through mishandled for n below the alignment)
    for n in (1, 2, 3, 5, 7):
        assert common.pick_tile(n, 8) == n
        assert common.pick_tile(n, 128, align=8) == n


def test_pick_tile_prefers_aligned_divisor():
    assert common.pick_tile(128, 64) == 64             # aligned, divides
    assert common.pick_tile(96, 64) == 48              # largest aligned
    assert common.pick_tile(20, 12, align=2) == 10     # largest 2-aligned
    assert common.pick_tile(20, 12, align=8) == 10     # none 8-aligned:
    assert common.pick_tile(13, 8) == 1                # largest divisor wins


def test_pick_tile_rejects_degenerate_axes():
    with pytest.raises(ValueError):
        common.pick_tile(0, 8)
    with pytest.raises(ValueError):
        common.pick_tile(-4, 8)
    with pytest.raises(ValueError):
        common.pick_tile(16, 8, align=0)


def test_default_interpret_tracks_backend():
    assert common.default_interpret() == (jax.default_backend() != "tpu")
    assert common.default_interpret() is True          # this container: CPU


def test_count_pallas_calls_sees_through_jit():
    p, cfg = _fff(0, depth=2)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, cfg.dim_in))
    fn = jax.jit(lambda x: fd_ops.fused_decode(x, p, cfg, interpret=True))
    assert common.count_pallas_calls(fn, x) == 1       # recurses pjit


# ---------------------------------------------------------------------------
# the dispatch-count gate (CI runs this by name): 3 -> 1
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("act,trees", [("gelu", 1), ("swiglu", 2)])
def test_fused_decode_dispatch_count(act, trees):
    """The whole point of the megakernel: the legacy decode path costs a
    router dispatch plus two gathered-matmul dispatches per tree; the fused
    path is ONE ``pallas_call`` for the entire forest."""
    p, cfg = _fff(0, depth=3, act=act, trees=trees)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, cfg.dim_in))
    legacy = lambda x: fff_decode(x, p, cfg, interpret=True)
    fused = lambda x: fd_ops.fused_decode(x, p, cfg, interpret=True)
    # legacy: router + up-projection (dual for swiglu) + down, PER TREE
    assert common.count_pallas_calls(legacy, x) == 3 * trees
    assert common.count_pallas_calls(fused, x) == 1


def test_pallas_decode_backend_dispatch_count():
    """Same gate one level up, through the execution registry — what the
    serving engine's decode step actually traces."""
    p, cfg = _fff(0, depth=3)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 1, cfg.dim_in))
    spec = api.ExecutionSpec(mode="infer", backend="pallas_decode",
                             interpret=True)
    assert common.count_pallas_calls(
        lambda x: api.apply(p, cfg, x, spec)[0], x) == 1


# ---------------------------------------------------------------------------
# differential matrix: tree_router
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B", ODD_BATCHES)
@pytest.mark.parametrize("depth", DEPTHS)
def test_diff_router(depth, B):
    N, dim = 2 ** depth - 1, 32
    x = jax.random.normal(jax.random.PRNGKey(depth), (B, dim))
    nw = jax.random.normal(jax.random.PRNGKey(B), (N, dim)) / np.sqrt(dim)
    nb = jax.random.normal(jax.random.PRNGKey(B + 1), (N,)) * 0.1
    got = route(x, nw, nb, depth=depth, interpret=True)
    want = tree_router_ref(x, nw, nb, depth=depth)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("dtype", DTYPES)
def test_diff_router_dtypes(dtype):
    depth, dim, B = 4, 32, 64
    N = 2 ** depth - 1
    x = jax.random.normal(jax.random.PRNGKey(0), (B, dim)).astype(dtype)
    nw = (jax.random.normal(jax.random.PRNGKey(1), (N, dim)) / 8).astype(dtype)
    nb = jnp.zeros((N,), dtype)
    got = route(x, nw, nb, depth=depth, interpret=True)
    want = tree_router_ref(x, nw, nb, depth=depth)
    if dtype == jnp.float32:
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    else:          # bf16 can flip near-zero boundary decisions
        assert float((got == want).mean()) > 0.99


def test_diff_router_degenerate_all_one_leaf():
    """Hyperplanes rigged so every token descends to the same leaf —
    the skew that breaks anything assuming balanced occupancy."""
    depth, dim, B = 3, 16, 37
    N, E = 2 ** depth - 1, 2 ** depth
    x = jax.random.normal(jax.random.PRNGKey(2), (B, dim))
    nw = jnp.zeros((N, dim))
    for target, bias in [(0, -1.0), (E - 1, 1.0)]:     # all-left / all-right
        nb = jnp.full((N,), bias)
        got = route(x, nw, nb, depth=depth, interpret=True)
        want = tree_router_ref(x, nw, nb, depth=depth)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        assert int(got[0]) == target and bool((got == got[0]).all())


# ---------------------------------------------------------------------------
# differential matrix: leaf_gemm (grouped) and fused_fff (gathered)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("H", LEAF_WIDTHS)
def test_diff_grouped_matmul(dtype, H):
    E, C, D = 3, 16, 32
    k = jax.random.PRNGKey(H)
    gs = jax.random.randint(jax.random.fold_in(k, 0), (E,), 0, C + 1)
    mask = (jnp.arange(C)[None, :] < gs[:, None])
    x = (jax.random.normal(jax.random.fold_in(k, 1), (E, C, D))
         * mask[..., None]).astype(dtype)
    w = (jax.random.normal(jax.random.fold_in(k, 2), (E, D, H))
         / np.sqrt(D)).astype(dtype)
    got = grouped_matmul(x, w, gs.astype(jnp.int32), act="gelu", block_c=8,
                         block_h=4, block_k=8, interpret=True)
    want = grouped_matmul_ref(x, w, gs.astype(jnp.int32), act="gelu")
    assert_close(got, want, dtype=dtype)


def test_diff_grouped_matmul_skew_one_group():
    # degenerate grouping: every token in group 0, the rest empty
    E, C, D, H = 4, 16, 16, 8
    k = jax.random.PRNGKey(9)
    gs = jnp.array([C, 0, 0, 0], jnp.int32)
    mask = (jnp.arange(C)[None, :] < gs[:, None])
    x = jax.random.normal(jax.random.fold_in(k, 1), (E, C, D)) \
        * mask[..., None]
    w = jax.random.normal(jax.random.fold_in(k, 2), (E, D, H)) / np.sqrt(D)
    got = grouped_matmul(x, w, gs, act="relu", block_c=8, block_h=8,
                         block_k=8, interpret=True)
    assert_close(got, grouped_matmul_ref(x, w, gs, act="relu"))
    assert float(jnp.abs(got[1:]).max()) == 0.0


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("B", ODD_BATCHES)
def test_diff_gathered_matmul(dtype, B):
    E, D, H = 8, 16, 8
    x = jax.random.normal(jax.random.PRNGKey(B), (B, D)).astype(dtype)
    w = (jax.random.normal(jax.random.PRNGKey(B + 1), (E, D, H))
         / np.sqrt(D)).astype(dtype)
    idx = jax.random.randint(jax.random.PRNGKey(B + 2), (B,), 0, E)
    got = gathered_matmul(x, w, idx, act="gelu", block_h=8, block_k=8,
                          interpret=True)
    assert_close(got, gathered_matmul_ref(x, w, idx, act="gelu"), dtype=dtype)


# ---------------------------------------------------------------------------
# differential matrix: fused_decode (the megakernel)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B", ODD_BATCHES)
@pytest.mark.parametrize("leaf", LEAF_WIDTHS)
@pytest.mark.parametrize("depth", DEPTHS)
@pytest.mark.parametrize("act,trees", [("gelu", 1), ("relu", 2),
                                       ("swiglu", 1), ("swiglu", 2)])
def test_diff_fused_decode(act, trees, depth, leaf, B):
    p, cfg = _fff(depth * 10 + B, depth=depth, act=act, trees=trees,
                  leaf=leaf)
    x = jax.random.normal(jax.random.PRNGKey(B), (B, cfg.dim_in))
    y, idx = fd_ops.fused_decode(x, p, cfg, interpret=True,
                                 return_leaf_idx=True)
    y_ref, idx_ref = fd_ops.fused_decode_ref(x, p, cfg, return_leaf_idx=True)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(idx_ref))
    assert_close(y, y_ref)
    assert idx.shape == (B, trees) and y.shape == (B, cfg.dim_out)


@pytest.mark.parametrize("dtype", DTYPES)
def test_diff_fused_decode_dtypes(dtype):
    p, cfg = _fff(3, depth=3, act="gelu", trees=1)
    p = _cast(p, dtype)
    x = jax.random.normal(jax.random.PRNGKey(4), (32, cfg.dim_in)) \
        .astype(dtype)
    y, idx = fd_ops.fused_decode(x, p, cfg, interpret=True,
                                 return_leaf_idx=True)
    y_ref, idx_ref = fd_ops.fused_decode_ref(x, p, cfg, return_leaf_idx=True)
    assert y.dtype == dtype
    if dtype == jnp.float32:
        np.testing.assert_array_equal(np.asarray(idx), np.asarray(idx_ref))
        assert_close(y, y_ref)
    else:
        # bf16 routing can flip near-zero boundary logits between the
        # kernel's and the oracle's accumulation orders: require near-total
        # path agreement and value parity on the agreeing rows
        agree = np.asarray((idx == idx_ref).all(axis=1))
        assert float(agree.mean()) >= 0.9
        assert_close(jnp.asarray(y)[agree], jnp.asarray(y_ref)[agree],
                     dtype=dtype)


@pytest.mark.parametrize("act,trees", [("gelu", 1), ("relu", 2),
                                       ("swiglu", 2)])
def test_diff_fused_decode_master_leaf(act, trees):
    """Master-leaf rows of the fused-decode differential matrix: the kernel
    folds the always-on master MLP into the same dispatch, so kernel parity
    vs the fp32 oracle must hold with the master term included — and the
    output must differ from the master-free forest by exactly
    ``fff.master_apply``."""
    import dataclasses
    p, cfg = _fff(6, depth=3, act=act, trees=trees, master=True)
    x = jax.random.normal(jax.random.PRNGKey(7), (9, cfg.dim_in))
    y, idx = fd_ops.fused_decode(x, p, cfg, interpret=True,
                                 return_leaf_idx=True)
    y_ref, idx_ref = fd_ops.fused_decode_ref(x, p, cfg, return_leaf_idx=True)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(idx_ref))
    assert_close(y, y_ref)
    cfg0 = dataclasses.replace(cfg, master_leaf=False)
    p0 = {k: v for k, v in p.items() if not k.startswith("master_")}
    y0, _ = fd_ops.fused_decode(x, p0, cfg0, interpret=True,
                                return_leaf_idx=True)
    assert_close(jnp.asarray(y) - jnp.asarray(y0),
                 fff.master_apply(p, cfg, x), kind="e2e")


def test_fused_decode_master_leaf_dispatch_count_unchanged():
    """The §14 no-extra-dispatch gate: enabling the master leaf (and with it
    the master_leaf overflow repair, which reuses the already-computed term)
    must keep the megakernel at ONE pallas_call — through the raw op and
    through the pallas_decode registry backend alike."""
    p, cfg = _fff(0, depth=3, act="swiglu", trees=2, master=True)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, cfg.dim_in))
    fused = lambda x: fd_ops.fused_decode(x, p, cfg, interpret=True)
    assert common.count_pallas_calls(fused, x) == 1
    spec = api.ExecutionSpec(mode="infer", backend="pallas_decode",
                             interpret=True)
    assert common.count_pallas_calls(
        lambda x: api.apply(p, cfg, x[:, None, :], spec)[0], x) == 1


def test_diff_fused_decode_degenerate_routing():
    """All-one-leaf forest: zero hyperplanes with a uniform bias sign push
    every token down one side; the fused output must equal that single
    leaf's MLP applied to every token."""
    depth, dim, leaf, B = 3, 16, 8, 21
    N, E = 2 ** depth - 1, 2 ** depth
    k = jax.random.PRNGKey(5)
    x = jax.random.normal(k, (B, dim))
    nw = jnp.zeros((1, N, dim))
    w1 = jax.random.normal(jax.random.fold_in(k, 1), (1, E, dim, leaf)) \
        / np.sqrt(dim)
    w2 = jax.random.normal(jax.random.fold_in(k, 2), (1, E, leaf, dim)) \
        / np.sqrt(leaf)
    for target, bias in [(0, -1.0), (E - 1, 1.0)]:
        nb = jnp.full((1, N), bias)
        y, idx = fused_forest_decode(x, nw, nb, (w1, w2), depth=depth,
                                     act="gelu", interpret=True)
        assert bool((idx == target).all())
        h = jax.nn.gelu(x.astype(jnp.float32) @ w1[0, target])
        assert_close(y, h @ w2[0, target])


def test_diff_fused_decode_rejects_unsupported():
    cfg = _fff_cfg(depth=2)
    p = fff.init(jax.random.PRNGKey(0), cfg)
    x = jnp.zeros((2, cfg.dim_in))
    bad = fff.FFFConfig(dim_in=16, dim_out=16, depth=2, leaf_width=8,
                        node_width=2, leaf_bias=False)
    with pytest.raises(ValueError, match="node_width"):
        fd_ops.fused_decode(x, fff.init(jax.random.PRNGKey(0), bad), bad)
    biased = fff.FFFConfig(dim_in=16, dim_out=16, depth=2, leaf_width=8,
                           leaf_bias=True)
    with pytest.raises(ValueError, match="bias-free"):
        fd_ops.fused_decode(x, fff.init(jax.random.PRNGKey(0), biased),
                            biased)
    with pytest.raises(ValueError, match="depth"):
        zero = fff.FFFConfig(dim_in=16, dim_out=16, depth=0, leaf_width=8,
                             leaf_bias=False)
        fd_ops.fused_decode(x, fff.init(jax.random.PRNGKey(0), zero), zero)


# ---------------------------------------------------------------------------
# registry integration: resolution, sentinel masking, telemetry
# ---------------------------------------------------------------------------

def test_resolver_routes_decode_shape_to_fused(monkeypatch):
    """On kernel-native platforms the auto resolver sends seq-len-1 infer
    to the megakernel and wider shapes to the grouped pallas path; on this
    CPU container everything stays on reference."""
    p, cfg = _fff(0, depth=3)
    assert api.resolve_backend(p, cfg, "infer",
                               x_shape=(4, 1, cfg.dim_in)) == "reference"
    monkeypatch.setattr(api, "_kernels_native", lambda: True)
    assert api.resolve_backend(p, cfg, "infer",
                               x_shape=(4, 1, cfg.dim_in)) == "pallas_decode"
    assert api.resolve_backend(p, cfg, "infer",
                               x_shape=(4, 16, cfg.dim_in)) == "pallas"
    assert api.resolve_backend(p, cfg, "infer",
                               x_shape=(4, cfg.dim_in)) == "pallas"


def test_pallas_decode_backend_matches_reference():
    for act, trees in [("gelu", 1), ("swiglu", 2)]:
        p, cfg = _fff(1, depth=3, act=act, trees=trees)
        x = jax.random.normal(jax.random.PRNGKey(2), (5, 1, cfg.dim_in))
        y, out = api.apply(p, cfg, x, api.ExecutionSpec(
            mode="infer", backend="pallas_decode", interpret=True))
        y_ref, out_ref = api.apply(p, cfg, x, api.ExecutionSpec(
            mode="infer", backend="reference"))
        np.testing.assert_array_equal(np.asarray(out.leaf_idx),
                                      np.asarray(out_ref.leaf_idx))
        assert_close(y, y_ref, kind="e2e")


def test_pallas_decode_sentinel_masking_and_telemetry():
    """``ExecutionSpec.valid`` must mask leaf telemetry to the sentinel id
    (num_leaves) for phantom rows — the engine's free slots — while outputs
    stay per-token exact; routing_stats drops the sentinel column."""
    p, cfg = _fff(2, depth=2, trees=1)
    B = 4
    x = jax.random.normal(jax.random.PRNGKey(3), (B, 1, cfg.dim_in))
    valid = jnp.array([True, False, True, False])[:, None]
    spec = api.ExecutionSpec(mode="infer", backend="pallas_decode",
                             interpret=True, valid=valid)
    y, out = api.apply(p, cfg, x, spec)
    y_all, out_all = api.apply(p, cfg, x, api.ExecutionSpec(
        mode="infer", backend="pallas_decode", interpret=True))
    # outputs exact for every row (exact backend ignores valid for y)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(y_all))
    idx = np.asarray(out.leaf_idx)[:, 0, :]
    assert (idx[1] == cfg.num_leaves).all()
    assert (idx[3] == cfg.num_leaves).all()
    np.testing.assert_array_equal(idx[0], np.asarray(out_all.leaf_idx)[0, 0])
    stats = api.routing_stats_from(out, cfg)
    assert stats.leaf_counts.shape[-1] == cfg.num_leaves  # sentinel dropped
    assert float(stats.slots) == 2.0 * cfg.trees          # only valid rows
    np.testing.assert_array_equal(
        np.asarray(stats.leaf_counts).sum(axis=-1),
        np.array([1.0, 0.0, 1.0, 0.0]) * cfg.trees)


# ---------------------------------------------------------------------------
# engine parity under the flag
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def model():
    cfg = registry.get_config("internlm2-20b", ffn="fff").reduced()
    params = lm.init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_engine_pallas_decode_matches_lm_generate(model):
    """Greedy engine output with the fused-decode flag on must equal the
    synchronous lm.generate path — the acceptance gate for wiring the
    megakernel into serving (DESIGN.md §13)."""
    cfg, params = model
    rng = np.random.default_rng(6)
    reqs = [Request(rid=i, prompt=rng.integers(1, 256, int(rng.integers(3, 9))),
                    max_new_tokens=5) for i in range(3)]
    eng = ContinuousBatchingEngine(params, cfg, EngineConfig(
        num_slots=2, max_len=32, max_prompt_len=8, seed=0,
        pallas_decode=True))
    results, _ = eng.run(reqs)
    assert sorted(r.rid for r in results) == [0, 1, 2]
    for r in results:
        want = lm.generate(params, cfg, jnp.asarray(r.prompt[None]),
                           steps=r.n_generated, max_len=32)
        np.testing.assert_array_equal(
            np.asarray(want)[0], np.concatenate([r.prompt, r.tokens]),
            err_msg=f"rid {r.rid}")


# ---------------------------------------------------------------------------
# property tests: descent bit-path and telemetry bit-mask invariants
# ---------------------------------------------------------------------------

def _heap_descent(logits, depth):
    """Independent formulation of FORWARD_I: walk the heap-ordered tree
    (level-major; node g at level m sits at offset 2^m - 1 + its in-level
    index), taking the right child on a nonnegative logit."""
    idx = 0
    for m in range(depth):
        idx = 2 * idx + (1 if logits[2 ** m - 1 + idx] >= 0.0 else 0)
    return idx


def _check_descent_bits(logits, depth):
    nw = jnp.zeros((1, 2 ** depth - 1, 4))
    nb = jnp.asarray(logits, jnp.float32)[None, :]
    E, leaf = 2 ** depth, 2
    w1 = jnp.ones((1, E, 4, leaf))
    w2 = jnp.ones((1, E, leaf, 4))
    _, idx = fused_forest_decode(jnp.zeros((1, 4)), nw, nb, (w1, w2),
                                 depth=depth, act="none", interpret=True)
    want = _heap_descent(list(logits), depth)
    assert int(idx[0, 0]) == want, (list(logits), depth, int(idx[0, 0]), want)
    # bit m of the leaf index == sign bit of the level-m logit on the path
    path, node = [], 0
    for m in range(depth):
        bit = (want >> (depth - 1 - m)) & 1
        assert bit == (1 if logits[2 ** m - 1 + node] >= 0.0 else 0)
        node = 2 * node + bit


def test_descent_bit_path_seeded_sweep():
    rng = np.random.default_rng(0)
    for depth in (1, 2, 3, 5):
        for _ in range(10):
            logits = rng.normal(size=2 ** depth - 1) * rng.choice([1e-3, 1.0])
            _check_descent_bits(logits, depth)
    _check_descent_bits(np.zeros(7), 3)        # ties: >= 0 goes right


if HAVE_HYPOTHESIS:
    @settings(max_examples=50, deadline=None)
    @given(depth=st.integers(1, 5), data=st.data())
    def test_descent_bit_path_property(depth, data):
        logits = data.draw(st.lists(
            st.floats(-4.0, 4.0, allow_nan=False, width=32),
            min_size=2 ** depth - 1, max_size=2 ** depth - 1))
        _check_descent_bits(np.asarray(logits), depth)


def _check_mask_invariant(ids, E):
    """routing_stats must drop exactly the sentinel column: per-row counts
    sum to the row's non-sentinel entries and the histogram is a bincount."""
    ids = np.asarray(ids, np.int32).reshape(-1, 1)
    cfg = _fff_cfg(depth=int(np.log2(E)))
    out = api.FFFOutput(leaf_idx=jnp.asarray(ids),
                        overflow_fraction=jnp.zeros((), jnp.float32))
    stats = api.routing_stats_from(out, cfg)
    counts = np.asarray(stats.leaf_counts)
    assert counts.shape == (ids.shape[0], E)
    want = np.zeros((ids.shape[0], E))
    for b, row in enumerate(ids):
        for v in row:
            if v < E:                           # sentinel id E is dropped
                want[b, v] += 1
    np.testing.assert_array_equal(counts, want)
    assert float(stats.slots) == float((ids < E).sum())


def test_routing_mask_invariant_seeded_sweep():
    rng = np.random.default_rng(1)
    for E in (2, 4, 8):
        for _ in range(10):
            n = int(rng.integers(1, 12))
            ids = rng.integers(0, E + 1, n)     # includes the sentinel id E
            _check_mask_invariant(ids, E)
    _check_mask_invariant(np.full(5, 4), 4)     # all-sentinel (no valid rows)
    _check_mask_invariant(np.zeros(6), 4)       # all-one-leaf skew


if HAVE_HYPOTHESIS:
    @settings(max_examples=50, deadline=None)
    @given(e_pow=st.integers(1, 3), data=st.data())
    def test_routing_mask_invariant_property(e_pow, data):
        E = 2 ** e_pow
        ids = data.draw(st.lists(st.integers(0, E), min_size=1, max_size=16))
        _check_mask_invariant(ids, E)
