"""Vanilla feedforward baseline — the ``FF`` peer the paper compares against.

One hidden layer in the paper's single-set-of-neurons terminology: each of the
``width`` neurons has ``dim_in`` input weights and ``dim_out`` output weights.
Also provides the SwiGLU variant used at transformer FFN sites.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro import utils

Params = dict


@dataclasses.dataclass(frozen=True)
class FFConfig:
    dim_in: int
    dim_out: int
    width: int
    activation: str = "gelu"       # relu|gelu|silu|swiglu
    bias: bool = True
    param_dtype: Any = jnp.float32
    accum_dtype: Any = jnp.float32

    @property
    def training_width(self) -> int:  # symmetry with FFFConfig
        return self.width

    @property
    def inference_width(self) -> int:
        return self.width


def init(key: jax.Array, cfg: FFConfig) -> Params:
    D, H, O = cfg.dim_in, cfg.width, cfg.dim_out
    ks = jax.random.split(key, 3)
    pd = cfg.param_dtype
    if cfg.activation == "swiglu":
        return {
            "wg": utils.truncated_init(ks[0], (D, H), 1.0 / math.sqrt(D), pd),
            "wu": utils.truncated_init(ks[1], (D, H), 1.0 / math.sqrt(D), pd),
            "wd": utils.truncated_init(ks[2], (H, O), 1.0 / math.sqrt(H), pd),
        }
    p: Params = {
        "w1": utils.he_normal(ks[0], (D, H), pd),
        "w2": utils.lecun_normal(ks[1], (H, O), pd),
    }
    if cfg.bias:
        p["b1"] = jnp.zeros((H,), pd)
        p["b2"] = jnp.zeros((O,), pd)
    return p


def forward(params: Params, cfg: FFConfig, x: jax.Array) -> jax.Array:
    ad = cfg.accum_dtype
    xf, lead = utils.flatten_leading(x)
    xf = xf.astype(ad)
    if cfg.activation == "swiglu":
        g = jnp.einsum("bd,dh->bh", xf, params["wg"], preferred_element_type=ad)
        u = jnp.einsum("bd,dh->bh", xf, params["wu"], preferred_element_type=ad)
        y = jnp.einsum("bh,ho->bo", jax.nn.silu(g) * u, params["wd"],
                       preferred_element_type=ad)
        return utils.unflatten_leading(y, lead)
    act = utils.get_activation(cfg.activation)
    h = jnp.einsum("bd,dh->bh", xf, params["w1"], preferred_element_type=ad)
    if "b1" in params:
        h = h + params["b1"].astype(ad)
    h = act(h)
    y = jnp.einsum("bh,ho->bo", h, params["w2"], preferred_element_type=ad)
    if "b2" in params:
        y = y + params["b2"].astype(ad)
    return utils.unflatten_leading(y, lead)
