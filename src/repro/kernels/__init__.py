"""Pallas TPU kernels for the FFF hot spots (DESIGN.md §3):

  tree_router  — fused multi-level tree descent (routing)
  leaf_gemm    — ragged grouped GEMM over sorted tokens (batch serving)
  fused_fff    — per-token gathered leaf matmul (decode; the paper's
                 offset-load, expressed as a scalar-prefetch index map)

Each kernel ships ops.py (jit wrapper) and ref.py (pure-jnp oracle); tests
sweep shapes x dtypes in interpret mode against the oracle.

Consumers do not call these directly: the package is wired into the
execution-backend registry as the ``"pallas"`` backend of
``repro.core.api.apply()`` (selected automatically on TPU for kernel-eligible
configs, or explicitly via ``ExecutionSpec(backend="pallas")``).  The raw
``fff_infer`` / ``fff_decode`` wrappers remain exported for kernel-level
tests and benchmarking.
"""
from repro.kernels import fused_fff, leaf_gemm, tree_router
