"""End-to-end behaviour: FFF networks learn, harden, and serve — the paper's
workflow on synthetic data, at CPU-test scale."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.core import api, ff, fff
from repro.data import synthetic
from repro.models import lm
from repro.configs import registry


def _train_fff_classifier(ds, depth=3, leaf=16, steps=400, h=0.5, lr=0.3,
                          batch=256, seed=0):
    cfg = fff.FFFConfig(dim_in=ds.dim, dim_out=ds.num_classes, depth=depth,
                        leaf_width=leaf, activation="relu",
                        hardening_scale=h)
    params = fff.init(jax.random.PRNGKey(seed), cfg)
    opt = optim.sgd(lr)
    state = opt.init(params)

    def loss_fn(p, x, y):
        logits, out = api.apply(p, cfg, x, api.ExecutionSpec(mode="train"))
        ce = -jnp.mean(jnp.take_along_axis(
            jax.nn.log_softmax(logits), y[:, None], 1))
        return ce + h * fff.hardening_loss(out.node_probs), out.entropy

    @jax.jit
    def step(p, s, x, y):
        (l, ent), g = jax.value_and_grad(loss_fn, has_aux=True)(p, x, y)
        u, s = opt.update(g, s, p)
        return optim.apply_updates(p, u), s, l, ent

    rng = np.random.default_rng(seed)
    ents = []
    for i in range(steps):
        sel = rng.integers(0, len(ds.x_train), batch)
        params, state, l, ent = step(params, state,
                                     jnp.asarray(ds.x_train[sel]),
                                     jnp.asarray(ds.y_train[sel]))
        ents.append(float(ent))
    return cfg, params, ents


def _hard_accuracy(cfg, params, x, y):
    logits, _ = api.apply(params, cfg, jnp.asarray(x),
                          api.ExecutionSpec(mode="infer"))
    return float((np.asarray(logits.argmax(-1)) == y).mean())


def test_fff_learns_and_hardens_on_synthetic_images():
    ds = synthetic.make("usps_like")
    cfg, params, ents = _train_fff_classifier(ds)
    acc_train = _hard_accuracy(cfg, params, ds.x_train[:1024], ds.y_train[:1024])
    acc_test = _hard_accuracy(cfg, params, ds.x_test, ds.y_test)
    assert acc_train > 0.8, acc_train       # learns (10 classes, chance=0.1)
    assert acc_test > 0.7, acc_test         # generalizes
    assert ents[-1] < 0.5 * ents[0], "hardening entropy must decrease"


def test_hard_inference_close_to_soft_after_hardening():
    ds = synthetic.make("usps_like")
    cfg, params, _ = _train_fff_classifier(ds, h=2.0)
    x = jnp.asarray(ds.x_test[:512])
    y_soft, _ = api.apply(params, cfg, x, api.ExecutionSpec(mode="train"))
    y_hard, _ = api.apply(params, cfg, x, api.ExecutionSpec(mode="infer"))
    agree = float((y_soft.argmax(-1) == y_hard.argmax(-1)).mean())
    assert agree > 0.9, agree               # paper: hardened -> lossless rounding


def test_lm_training_decreases_loss():
    import dataclasses
    from repro.data import tokens as tokens_lib
    cfg = registry.get_config("internlm2-20b", ffn="fff").reduced()
    cfg = dataclasses.replace(cfg, n_layers=2)
    params = lm.init(jax.random.PRNGKey(0), cfg)
    opt = optim.adamw(3e-3)
    state = opt.init(params)
    src = tokens_lib.MarkovTokenSource(cfg.vocab_size, seed=0)

    @jax.jit
    def step(p, s, batch):
        (l, m), g = jax.value_and_grad(
            lambda p: lm.loss_fn(p, cfg, batch), has_aux=True)(p)
        u, s = opt.update(g, s, p)
        return optim.apply_updates(p, u), s, m["ce"]

    ces = []
    for i in range(30):
        batch = src.batch(8, 64, seed=i)
        params, state, ce = step(params, state, batch)
        ces.append(float(ce))
    assert np.mean(ces[-5:]) < np.mean(ces[:5]) - 0.2, ces


def test_generation_is_deterministic_greedy():
    cfg = registry.get_config("olmoe-1b-7b", ffn="fff").reduced()
    params = lm.init(jax.random.PRNGKey(0), cfg)
    prompt = jnp.asarray([[1, 2, 3, 4]], jnp.int32)
    out1 = lm.generate(params, cfg, prompt, steps=6, max_len=16)
    out2 = lm.generate(params, cfg, prompt, steps=6, max_len=16)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    assert out1.shape == (1, 4 + 6)
