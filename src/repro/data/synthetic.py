"""Synthetic image-classification datasets (offline stand-ins for
USPS/MNIST/FashionMNIST/CIFAR in the paper's protocol).

Each class is a mixture of prototype templates plus per-sample deformation and
noise, giving a real train/test generalization gap: memorization accuracy
(train-set accuracy of an overfitted model) and generalization accuracy
(test-set accuracy) behave like the paper's M_A / G_A.

Difficulty knobs mirror the paper's dataset ladder:
  usps_like    16x16, 10 classes, 2 prototypes/class, low noise
  mnist_like   28x28, 10 classes, 3 prototypes/class, low noise
  fashion_like 28x28, 10 classes, 4 prototypes/class, medium noise
  cifar_like   32x32x3 flattened, 10/100 classes, 6 prototypes, high noise
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import numpy as np


class Dataset(NamedTuple):
    x_train: np.ndarray   # (N, D) float32 in [0, 1]
    y_train: np.ndarray   # (N,) int32
    x_val: np.ndarray
    y_val: np.ndarray
    x_test: np.ndarray
    y_test: np.ndarray
    num_classes: int

    @property
    def dim(self) -> int:
        return self.x_train.shape[1]


@dataclasses.dataclass(frozen=True)
class SyntheticSpec:
    side: int = 16
    channels: int = 1
    num_classes: int = 10
    prototypes_per_class: int = 2
    noise: float = 0.15
    warp: float = 0.3             # prototype-mixing deformation strength
    n_train: int = 4096
    n_val: int = 512
    n_test: int = 1024
    seed: int = 0


PRESETS = {
    "usps_like": SyntheticSpec(side=16, prototypes_per_class=2, noise=0.12,
                               n_train=4096),
    "mnist_like": SyntheticSpec(side=28, prototypes_per_class=3, noise=0.12,
                                n_train=8192),
    "fashion_like": SyntheticSpec(side=28, prototypes_per_class=4, noise=0.20,
                                  warp=0.45, n_train=8192),
    "svhn_like": SyntheticSpec(side=32, channels=3, prototypes_per_class=5,
                               noise=0.25, warp=0.5, n_train=8192),
    "cifar10_like": SyntheticSpec(side=32, channels=3, prototypes_per_class=6,
                                  noise=0.30, warp=0.6, n_train=8192),
    "cifar100_like": SyntheticSpec(side=32, channels=3, num_classes=100,
                                   prototypes_per_class=4, noise=0.30,
                                   warp=0.6, n_train=8192),
}


def _smooth(img: np.ndarray, side: int, channels: int) -> np.ndarray:
    """Cheap separable blur so prototypes have spatial structure."""
    im = img.reshape(side, side, channels)
    k = np.array([0.25, 0.5, 0.25])
    for axis in (0, 1):
        im = (np.apply_along_axis(lambda v: np.convolve(v, k, mode="same"),
                                  axis, im))
    return im.reshape(-1)


def make(spec_or_name: SyntheticSpec | str) -> Dataset:
    spec = PRESETS[spec_or_name] if isinstance(spec_or_name, str) else spec_or_name
    rng = np.random.default_rng(spec.seed)
    D = spec.side * spec.side * spec.channels
    C, P = spec.num_classes, spec.prototypes_per_class

    protos = rng.uniform(0, 1, size=(C, P, D)).astype(np.float32)
    protos = np.stack([[_smooth(p, spec.side, spec.channels) for p in row]
                       for row in protos])
    # normalize prototypes to [0, 1]
    protos -= protos.min(axis=-1, keepdims=True)
    protos /= np.maximum(protos.max(axis=-1, keepdims=True), 1e-6)

    def sample(n: int, seed: int) -> tuple[np.ndarray, np.ndarray]:
        r = np.random.default_rng(seed)
        y = r.integers(0, C, size=n).astype(np.int32)
        pid = r.integers(0, P, size=n)
        base = protos[y, pid]
        # deformation: mix with a second prototype of the same class
        pid2 = r.integers(0, P, size=n)
        alpha = r.uniform(0, spec.warp, size=(n, 1)).astype(np.float32)
        base = (1 - alpha) * base + alpha * protos[y, pid2]
        x = base + r.normal(0, spec.noise, size=(n, D)).astype(np.float32)
        return np.clip(x, 0, 1).astype(np.float32), y

    x_tr, y_tr = sample(spec.n_train, spec.seed + 1)
    x_va, y_va = sample(spec.n_val, spec.seed + 2)
    x_te, y_te = sample(spec.n_test, spec.seed + 3)
    return Dataset(x_tr, y_tr, x_va, y_va, x_te, y_te, C)


def patches(x: np.ndarray, side: int, channels: int, patch: int) -> np.ndarray:
    """Flattened images -> (N, n_patches, patch*patch*channels) for ViT."""
    n = x.shape[0]
    im = x.reshape(n, side, side, channels)
    g = side // patch
    im = im.reshape(n, g, patch, g, patch, channels)
    return im.transpose(0, 1, 3, 2, 4, 5).reshape(n, g * g, patch * patch * channels)
