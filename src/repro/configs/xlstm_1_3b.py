"""xlstm-1.3b [ssm] — 48L d_model=2048 4H (GQA kv=4) d_ff=0 vocab=50304 —
sLSTM + mLSTM blocks (xLSTM[7:1]: seven mLSTM per sLSTM).
[arXiv:2405.04517; unverified]

ARCH-APPLICABILITY (DESIGN.md §4): d_ff = 0 — these blocks have NO FFN site;
the up/down projections inside the mLSTM block are integral to the recurrence
(pre-up-projection design), not a replaceable feedforward layer.  The paper's
FFF technique therefore does not apply; the arch runs FFF-free rather than
forcing a degenerate port.  Constant-state recurrence => runs long_500k."""
import jax.numpy as jnp

from repro.configs.base import BlockSpec, FFNSpec, ModelConfig

_NONE = FFNSpec(kind="none")

CONFIG = ModelConfig(
    arch_id="xlstm-1.3b",
    family="ssm",
    d_model=2048,
    n_layers=48,
    n_heads=4,
    n_kv_heads=4,
    lstm_heads=4,
    vocab_size=50304,
    max_seq_len=524288,
    pos_emb="none",
    subquadratic=True,
    period=(
        BlockSpec(mixer="mlstm", ffn=_NONE),
        BlockSpec(mixer="mlstm", ffn=_NONE),
        BlockSpec(mixer="mlstm", ffn=_NONE),
        BlockSpec(mixer="mlstm", ffn=_NONE),
        BlockSpec(mixer="mlstm", ffn=_NONE),
        BlockSpec(mixer="mlstm", ffn=_NONE),
        BlockSpec(mixer="mlstm", ffn=_NONE),
        BlockSpec(mixer="slstm", ffn=_NONE),
    ),
    param_dtype=jnp.bfloat16,
    accum_dtype=jnp.bfloat16,
    remat="full",
    grad_accum=16,
)

# FFF inapplicable (no FFN sites) — FFF_CONFIG is identical to CONFIG.
FFF_CONFIG = CONFIG
