from repro.kernels.fused_fff.kernel import gathered_matmul, gathered_matmul_dual
from repro.kernels.fused_fff.ops import fff_decode, gathered_leaf_mlp
from repro.kernels.fused_fff.ref import (gathered_matmul_dual_ref,
                                         gathered_matmul_ref)
