"""Jitted decode path: route + per-token gathered leaf MLP (no sort/scatter).

``fff_decode`` is exact (no capacity bound — every token fetches its own
leaf).  Preferred over the grouped path when B is small (decode); crossover
vs. the sorted-dispatch path measured in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro import utils
from repro.core import fff as fff_lib
from repro.kernels import common
from repro.kernels.fused_fff import kernel as K
from repro.kernels.tree_router import ops as router_ops


@partial(jax.jit, static_argnames=("activation", "interpret", "block_h",
                                   "block_k"))
def gathered_leaf_mlp(x: jax.Array, leaf_idx: jax.Array, params: dict, *,
                      activation: str = "gelu",
                      interpret: Optional[bool] = None,
                      block_h: int = 512, block_k: int = 512) -> jax.Array:
    if interpret is None:
        interpret = common.default_interpret()
    if "leaf_b1" in params or "leaf_b2" in params:
        raise ValueError("kernel path requires bias-free leaves")
    kw = dict(block_h=block_h, block_k=block_k, interpret=interpret)
    if "leaf_wg" in params:
        h = K.gathered_matmul_dual(x, params["leaf_wg"], params["leaf_wu"],
                                   leaf_idx, **kw)
        return K.gathered_matmul(h, params["leaf_wd"], leaf_idx,
                                 act="none", **kw)
    h = K.gathered_matmul(x, params["leaf_w1"], leaf_idx, act=activation, **kw)
    return K.gathered_matmul(h, params["leaf_w2"], leaf_idx, act="none", **kw)


def fff_decode(x: jax.Array, params: dict, cfg: fff_lib.FFFConfig, *,
               interpret: Optional[bool] = None,
               dense_levels: Optional[int] = None,
               return_leaf_idx: bool = False):
    """Exact FORWARD_I via router kernel + gathered leaf kernels.

    x (B, D) -> (B, dim_out); sums over forest trees.  With
    ``return_leaf_idx=True`` returns ``(y, leaf_idx (B, trees))``."""
    if cfg.node_width != 1:
        raise ValueError("kernel path supports node_width == 1 (paper default)")
    out = None
    idxs = []
    for t in range(cfg.trees):
        nw = params["node_w1"][t, :, :, 0] * params["node_w2"][t, :, 0:1]
        nb = params["node_b1"][t, :, 0] * params["node_w2"][t, :, 0] \
            + params["node_b2"][t]
        leaf_idx = router_ops.route(x, nw, nb, depth=cfg.depth,
                                    dense_levels=dense_levels,
                                    interpret=interpret)
        tree_leaves = {k: v[t] for k, v in params.items()
                       if k.startswith("leaf_")}
        y = gathered_leaf_mlp(
            x, leaf_idx, tree_leaves,
            activation=cfg.activation if cfg.activation != "swiglu" else "swiglu",
            interpret=interpret)
        out = y if out is None else out + y
        idxs.append(leaf_idx)
    if return_leaf_idx:
        return out, jnp.stack(idxs, axis=1)
    return out
