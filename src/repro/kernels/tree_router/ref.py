"""Pure-jnp oracle for the tree_router kernel (paper Algorithm 1 FORWARD_I,
descent only, single tree, node width 1)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_router_ref(x: jax.Array, node_w: jax.Array, node_b: jax.Array, *,
                    depth: int) -> jax.Array:
    """x (B, D), node_w (N, D), node_b (N,) -> (B,) int32 leaf indices."""
    B = x.shape[0]
    idx = jnp.zeros((B,), jnp.int32)
    for m in range(depth):
        g = (2 ** m - 1) + idx                       # global node ids (B,)
        w = jnp.take(node_w, g, axis=0)              # (B, D)
        b = jnp.take(node_b, g, axis=0)              # (B,)
        logit = jnp.einsum("bd,bd->b", x.astype(jnp.float32),
                           w.astype(jnp.float32)) + b.astype(jnp.float32)
        idx = 2 * idx + (logit >= 0.0).astype(jnp.int32)
    return idx
