"""Mamba (selective SSM) block — the Jamba hybrid's sequence mixer.

Chunked selective scan: sequential ``lax.scan`` over sequence chunks with a
parallel ``associative_scan`` inside each chunk, so peak memory is
O(chunk * d_inner * d_state) instead of O(S * d_inner * d_state).
Constant-size state makes this the sub-quadratic path for ``long_500k``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro import utils

Params = dict


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_model: int
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0               # 0 -> ceil(d_model / 16)
    chunk: int = 256
    param_dtype: Any = jnp.float32
    accum_dtype: Any = jnp.float32

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def resolved_dt_rank(self) -> int:
        return self.dt_rank or utils.cdiv(self.d_model, 16)


class MambaState(NamedTuple):
    conv: jax.Array    # (B, d_conv - 1, d_inner) ring of recent inputs
    ssm: jax.Array     # (B, d_inner, d_state)


def init(key: jax.Array, cfg: MambaConfig) -> Params:
    D, DI, N, R = cfg.d_model, cfg.d_inner, cfg.d_state, cfg.resolved_dt_rank
    ks = jax.random.split(key, 6)
    pd = cfg.param_dtype
    # dt bias: softplus^-1 of dt ~ U[1e-3, 1e-1] (Mamba init)
    dt = jnp.exp(jax.random.uniform(ks[0], (DI,))
                 * (math.log(0.1) - math.log(1e-3)) + math.log(1e-3))
    dt_bias = dt + jnp.log1p(-jnp.exp(-dt))
    return {
        "in_proj": utils.truncated_init(ks[1], (D, 2 * DI), 1.0 / math.sqrt(D), pd),
        "conv_w": utils.truncated_init(ks[2], (cfg.d_conv, DI), 1.0 / math.sqrt(cfg.d_conv), pd),
        "conv_b": jnp.zeros((DI,), pd),
        "x_proj": utils.truncated_init(ks[3], (DI, R + 2 * N), 1.0 / math.sqrt(DI), pd),
        "dt_proj": utils.truncated_init(ks[4], (R, DI), 1.0 / math.sqrt(R), pd),
        "dt_bias": dt_bias.astype(pd),
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, N + 1, dtype=jnp.float32), (DI, N))).astype(pd),
        "D_skip": jnp.ones((DI,), pd),
        "out_proj": utils.truncated_init(ks[5], (DI, D), 1.0 / math.sqrt(DI), pd),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 history: jax.Array | None = None) -> jax.Array:
    """Depthwise causal conv1d.  x (B, S, DI), w (k, DI).

    history (B, k-1, DI) holds the trailing inputs of the previous segment
    (zeros at sequence start)."""
    k = w.shape[0]
    if history is None:
        history = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([history, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(k))
    return out + b


def _selective_scan_chunk(h0: jax.Array, da: jax.Array, dbx: jax.Array
                          ) -> tuple[jax.Array, jax.Array]:
    """Linear recurrence h_t = da_t * h_{t-1} + dbx_t within one chunk.

    h0 (B, DI, N); da, dbx (B, C, DI, N).  Returns (h_all (B, C, DI, N), h_C).
    """
    def combine(left, right):
        a1, b1 = left
        a2, b2 = right
        return a1 * a2, a2 * b1 + b2

    a_all, b_all = jax.lax.associative_scan(combine, (da, dbx), axis=1)
    h_all = a_all * h0[:, None] + b_all
    return h_all, h_all[:, -1]


def scan_sequence(params: Params, cfg: MambaConfig, xz: jax.Array,
                  state: MambaState) -> tuple[jax.Array, MambaState]:
    """Core SSM over (B, S, DI) pre-activation input; returns (B, S, DI)."""
    ad = cfg.accum_dtype
    B, S, DI = xz.shape
    N, R = cfg.d_state, cfg.resolved_dt_rank
    chunk = min(cfg.chunk, S)
    if S % chunk:
        chunk = math.gcd(S, chunk)
    n_chunks = S // chunk
    A = -jnp.exp(params["A_log"].astype(ad))                      # (DI, N)

    xz_c = xz.reshape(B, n_chunks, chunk, DI).transpose(1, 0, 2, 3)
    conv_hist0 = state.conv

    def body(carry, x_chunk):                                     # (B, C, DI)
        h, conv_hist = carry
        xc = _causal_conv(x_chunk, params["conv_w"], params["conv_b"], conv_hist)
        xc = jax.nn.silu(xc)
        proj = jnp.einsum("bcd,dr->bcr", xc, params["x_proj"],
                          preferred_element_type=ad)
        dt_r, Bmat, Cmat = jnp.split(proj, [R, R + N], axis=-1)
        dt = jax.nn.softplus(
            jnp.einsum("bcr,rd->bcd", dt_r, params["dt_proj"],
                       preferred_element_type=ad)
            + params["dt_bias"].astype(ad))                       # (B, C, DI)
        da = jnp.exp(dt[..., None] * A)                           # (B, C, DI, N)
        dbx = dt[..., None] * Bmat[:, :, None, :] * xc[..., None]  # (B,C,DI,N)
        h_all, h_new = _selective_scan_chunk(h, da, dbx)
        y = jnp.einsum("bcdn,bcn->bcd", h_all, Cmat)
        y = y + xc * params["D_skip"].astype(ad)
        new_hist = jnp.concatenate([conv_hist, x_chunk],
                                   axis=1)[:, -(cfg.d_conv - 1):]
        return (h_new, new_hist), y

    (h_fin, hist_fin), ys = jax.lax.scan(body, (state.ssm, conv_hist0), xz_c)
    y = ys.transpose(1, 0, 2, 3).reshape(B, S, DI)
    return y, MambaState(hist_fin, h_fin)


def init_state(batch: int, cfg: MambaConfig, dtype=None) -> MambaState:
    dtype = dtype or cfg.accum_dtype
    return MambaState(
        jnp.zeros((batch, cfg.d_conv - 1, cfg.d_inner), dtype),
        jnp.zeros((batch, cfg.d_inner, cfg.d_state), dtype))


def forward(params: Params, cfg: MambaConfig, x: jax.Array,
            state: MambaState | None = None
            ) -> tuple[jax.Array, MambaState]:
    """Full Mamba block: x (B, S, D) -> (B, S, D) + final state."""
    ad = cfg.accum_dtype
    B, S, _ = x.shape
    if state is None:
        state = init_state(B, cfg)
    xz = jnp.einsum("bsd,de->bse", x, params["in_proj"], preferred_element_type=ad)
    xs, z = jnp.split(xz, 2, axis=-1)
    y, new_state = scan_sequence(params, cfg, xs, state)
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"], preferred_element_type=ad)
    return out, new_state


def forward_step(params: Params, cfg: MambaConfig, x1: jax.Array,
                 state: MambaState) -> tuple[jax.Array, MambaState]:
    """Single-token decode: x1 (B, 1, D) -> (B, 1, D).  O(1) in context len."""
    y, new_state = forward(params, cfg, x1, state)
    return y, new_state
