"""Parameter and activation sharding rules (DESIGN.md §5).

Rules are (regex over the parameter path) -> axis assignment, evaluated in
order; the first match wins.  Axis placeholders:

  MODEL  -> the "model" mesh axis (TP / EP)
  FSDP   -> the compound batch axes ("pod","data") — ZeRO-3 style parameter
            sharding, gathered per-layer by SPMD inside the stack scan
  None   -> replicated

Conventions in this codebase (see the respective modules):
  stack params carry a leading n_periods scan axis   -> never sharded
  fff leaf weights (P, T, L, D, l)                    -> L on MODEL (EP), D FSDP
  moe expert weights (P, E, D, H)                     -> E on MODEL (EP), D FSDP
  attention wq/wk/wv (P, D, H, hd)                    -> H on MODEL (TP), D FSDP
  mamba/mlstm in/up projections (P, D, E)             -> E on MODEL (column)
  mamba/mlstm out/down projections (P, E, D)          -> E on MODEL (row)
  embeddings (V, D)                                   -> V on MODEL
"""
from __future__ import annotations

import re
from typing import Any, Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any

MODEL = "__model__"
FSDP = "__fsdp__"

# (path regex, LIST of candidate per-dimension assignments aligned to the
# LAST len(spec) dims; the first candidate whose sharded dims all divide is
# used).  The leading scan axis (and any unmatched leading dims) is
# replicated.  Expert/leaf weights fall back to tensor parallelism over the
# hidden width when the expert count doesn't divide the model axis (e.g.
# olmoe's 8 leaves/tree on a 16-way axis left the axis idle — §Perf iter 1).
PARAM_RULES: list[tuple[str, tuple]] = [
    # --- FFF ---
    (r".*leaf_w[gu1]$", ((MODEL, FSDP, None),          # (T,L,D,l): L on model
                         (None, FSDP, MODEL))),        # fallback: l column-TP
    (r".*leaf_w[d2]$", ((MODEL, None, FSDP),           # (T,L,l,O): L on model
                        (None, MODEL, FSDP))),         # fallback: l row-TP
    (r".*leaf_b[12]$", ((MODEL, None),)),              # (T,L,l)
    (r".*node_w1$", ((None, FSDP, None),)),            # (T,N,D,n)
    (r".*node_(b1|w2|b2)$", ((None, None),)),
    # master leaf (DESIGN.md §14): one small always-on MLP, no tree/leaf
    # axis — every token needs it, so keep it off the model axis (FSDP only)
    (r".*master_w[gu1]$", ((FSDP, None),)),            # (D,mw)
    (r".*master_w[d2]$", ((None, FSDP),)),             # (mw,O)
    # --- MoE ---
    (r".*expert_w1$", ((MODEL, FSDP, None),            # (E,D,H)
                       (None, FSDP, MODEL))),
    (r".*expert_w2$", ((MODEL, None, FSDP),            # (E,H,O)
                       (None, MODEL, FSDP))),
    (r".*expert_b[12]$", ((MODEL, None),)),
    (r".*(gate_w|noise_w)$", ((FSDP, None),)),
    # --- dense FF (megatron column/row) ---
    (r".*ffn/w(g|u|1)$", ((FSDP, MODEL),)),            # (D,H)
    (r".*ffn/w(d|2)$", ((MODEL, FSDP),)),              # (H,D)
    (r".*ffn/b1$", ((MODEL,),)),
    (r".*ffn/b2$", ((None,),)),
    # --- attention ---
    (r".*(mixer|cross)/w[qkv]$", ((FSDP, MODEL, None),  # (D,H,hd) heads model
                                  (FSDP, None, MODEL))),  # fallback: hd TP
    (r".*(mixer|cross)/wo$", ((MODEL, None, FSDP),      # (H,hd,D)
                              (None, MODEL, FSDP))),
    (r".*(mixer|cross)/b[qkv]$", ((MODEL, None),)),
    (r".*(mixer|cross)/bo$", ((None,),)),
    # --- mamba ---
    (r".*mixer/in_proj$", ((FSDP, MODEL),)),
    (r".*mixer/out_proj$", ((MODEL, FSDP),)),
    (r".*mixer/(conv_w|conv_b|dt_bias|A_log|D_skip)$", ((None, MODEL),)),
    (r".*mixer/x_proj$", ((MODEL, None),)),
    (r".*mixer/dt_proj$", ((None, MODEL),)),
    # --- xlstm ---
    (r".*mixer/up_proj$", ((FSDP, MODEL),)),
    (r".*mixer/down_proj$", ((MODEL, FSDP),)),
    (r".*mixer/w[qkv]$", ((MODEL, None, None),)),       # (DI,H,hd)->DI model
    (r".*mixer/w_if$", ((MODEL, None),)),
    (r".*mixer/w_h$", ((None, None, None),)),
    (r".*mixer/(b_if|b|gn_scale)$", ((None,),)),
    (r".*mixer/w_x$", ((FSDP, MODEL),)),
    # --- embeddings / head / frontends ---
    (r".*embed/tok$", ((MODEL, FSDP),)),                # (V,D) vocab-sharded
    (r".*embed/head$", ((FSDP, MODEL),)),
    (r".*pos/pos$", ((None, None),)),
    (r".*frontend/proj$", ((None, MODEL),)),
    (r".*frontend/bias$", ((MODEL,),)),
    # --- norms & fallback ---
    (r".*(norm|scale|bias).*", ()),
    (r".*", ()),
]

# activation rules consumed by distributed/act.py
def activation_rules(mesh: Mesh) -> dict:
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    model = "model" if "model" in mesh.axis_names else None
    from repro.distributed import act
    return {
        act.TOKENS_BS: P(batch_axes),
        act.ACT_BSD: P(batch_axes, None, None),
        act.LOGITS_BSV: P(batch_axes, None, model),
        act.KV_CACHE: P(batch_axes, None, None, None),
        act.NODE_BTN: P(batch_axes, None, None),
        act.DISPATCH_ECD: P(batch_axes, None, None, None),  # (G, E, C, D)
        act.DISPATCH_SERVE: P(None, model, None, None),     # (G, E, C, D)
        # (B, D) flat tokens split over every axis — grouped_ep entry layout
        act.TOKENS_EP: P(batch_axes + ((model,) if model else ()), None),
    }


def _try_resolve(assign: tuple, ndim: int, mesh: Mesh, shape: tuple
                 ) -> tuple[P, bool]:
    """Align the rule to the trailing dims; replicate leading (scan) dims.
    Returns (spec, complete) — complete=False if any requested sharding had
    to be dropped for divisibility (a fallback candidate should be tried)."""
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    fsdp_size = int(np.prod([mesh.shape[a] for a in batch_axes])) \
        if batch_axes else 1
    model_size = mesh.shape.get("model", 1)
    out: list = [None] * ndim
    complete = True
    k = ndim - len(assign)
    for i, a in enumerate(assign):
        if k + i < 0:
            continue
        dim = shape[k + i]
        if a == MODEL:
            if "model" in mesh.axis_names and dim % model_size == 0 \
                    and dim >= model_size:
                out[k + i] = "model"
            elif "model" in mesh.axis_names:
                complete = False
        elif a == FSDP:
            if batch_axes and dim % fsdp_size == 0 and dim >= fsdp_size:
                out[k + i] = batch_axes if len(batch_axes) > 1 \
                    else batch_axes[0]
            elif batch_axes:
                complete = False
    return P(*out), complete


def spec_for_path(path: str, ndim: int, mesh: Mesh, shape: tuple,
                  fsdp: bool = True) -> P:
    for pattern, candidates in PARAM_RULES:
        if re.match(pattern, path):
            if not candidates:
                return P()
            best = P()
            for assign in candidates:
                if not fsdp:
                    assign = tuple(None if a == FSDP else a for a in assign)
                spec, complete = _try_resolve(assign, ndim, mesh, shape)
                if complete:
                    return spec
                if tuple(best) == () or tuple(best).count(None) == len(best):
                    best = spec
            return best
    return P()


def path_of(key_path) -> str:
    parts = []
    for p in key_path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_specs(params: PyTree, mesh: Mesh, fsdp: bool = True) -> PyTree:
    """PartitionSpec pytree matching ``params``.

    fsdp=True  -> ZeRO-3 layout (params sharded over the batch axes too)
    fsdp=False -> ZeRO-1 layout (params model-sharded, data-replicated);
                  optimizer moments always use fsdp=True so the update and
                  param all-gather happen once per step, not per layer."""
    def spec(kp, leaf):
        return spec_for_path(path_of(kp), np.ndim(leaf), mesh,
                             tuple(np.shape(leaf)), fsdp=fsdp)
    return jax.tree_util.tree_map_with_path(spec, params)


def param_shardings(params: PyTree, mesh: Mesh, fsdp: bool = True) -> PyTree:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), param_specs(params, mesh, fsdp),
        is_leaf=lambda x: isinstance(x, P))


def shard_params(params: PyTree, mesh: Mesh, fsdp: bool = True) -> PyTree:
    """Place an existing (host/single-device) param tree onto the mesh."""
    sh = param_shardings(params, mesh, fsdp)
    return jax.tree_util.tree_map(jax.device_put, params, sh)


# ---------------------------------------------------------------------------
# cache/state shardings for serving
# ---------------------------------------------------------------------------

def cache_specs(caches: PyTree, mesh: Mesh, batch: int, *,
                seq_shard_below_batch: bool = True) -> PyTree:
    """KV caches: batch on data axes when divisible; for tiny batches
    (long-context decode) shard the *sequence* dim instead (DESIGN.md §5)."""
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    fsdp_size = int(np.prod([mesh.shape[a] for a in batch_axes])) \
        if batch_axes else 1
    dp = batch_axes if len(batch_axes) > 1 else (batch_axes[0] if batch_axes
                                                 else None)

    model_size = mesh.shape.get("model", 1)

    def spec(kp, leaf):
        shape = tuple(np.shape(leaf))
        nd = len(shape)
        path = path_of(kp)
        is_kv = ("kv/" in path) or path.endswith(("/k", "/v")) \
            or ("cross_" in path)
        if is_kv and nd == 5:            # (n_periods, num_pages, page, K, hd)
            # the paged pool's page axis plays the role the batch axis used
            # to: pages are independent, so the data axes shard dim 1 when
            # the page count divides them (the degenerate page_size=max_len
            # pool is exactly the old per-slot layout, num_pages == B).  The
            # model axis carries KV heads when they divide it (olmoe's MHA),
            # otherwise the within-page sequence dim (context parallelism):
            # decode softmax over a sharded S lowers to tiny (B,K,G) stat
            # psums and the cache never replicates across the model axis —
            # replication both OOMs and wastes cache bandwidth (§Perf iter 2).
            m_k = m_s = None
            if "model" in mesh.axis_names:
                if shape[3] % model_size == 0 and shape[3] >= model_size:
                    m_k = "model"
                elif shape[2] % model_size == 0 and shape[2] >= model_size:
                    m_s = "model"
            if shape[1] % fsdp_size == 0 and shape[1] >= fsdp_size:
                return P(None, dp, m_s, m_k, None)
            if seq_shard_below_batch and shape[2] % fsdp_size == 0 \
                    and shape[2] >= fsdp_size:
                dp_s = (tuple([a for a in (dp if isinstance(dp, tuple)
                                           else (dp,))]) + ((m_s,) if m_s
                                                            else ()))
                return P(None, None, dp_s, m_k, None)
            return P(None, None, m_s, m_k, None)
        # recurrent states / lengths: (n_periods, B, ...) batch-shard if divisible
        if nd >= 2 and shape[1] == batch and batch % fsdp_size == 0 \
                and batch >= fsdp_size:
            return P(*([None, dp] + [None] * (nd - 2)))
        return P()

    return jax.tree_util.tree_map_with_path(spec, caches)
