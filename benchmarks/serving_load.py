"""Serving-load benchmark: continuous-batching engine under synthetic
Poisson arrivals, per scheduler (ISSUE 3; first entry in the serving perf
trajectory).

Workload: a *skewed-routing* request mix — requests come in per-class bursts
where each class's prompt routes (near-)entirely to one FFF leaf (classes are
discovered by a calibration probe against the model's own routing, and each
request carries its class footprint as ``leaf_hint`` — the per-tenant
routing-profile story from DESIGN.md §9).  Under the capacity-bounded
``grouped`` backend the decode batch composition then decides
overflow_fraction: FCFS admits bursts wholesale (one hot leaf), while the
``leaf_aware`` scheduler interleaves classes to balance leaf load.

On top of the scheduler comparison, three capacity-under-provisioned
(``capacity_factor < 1.0``) sections measure the DESIGN.md §14 contract:

* ``policy_compare`` — master-leaf overflow repair vs the exact dense
  fallback at equal slots: decode-phase tokens/s ratio (gate >= 1.2x);
* ``balance_compare`` — a briefly load-balance-trained checkpoint vs the
  same steps without the balance aux, served on a leaf-colliding workload:
  decode overflow must drop;
* ``repair_error`` — per-token output delta of the approximate master-leaf
  repair vs the exact output on dropped tokens (bounded and reported).

Emits CSV rows
``serving,<sched>,<rate>,<tok_s>,<ttft_p50_ms>,<per_tok_p50_ms>,<ovf>,<ovf_decode>``
and writes ``experiments/BENCH_serving_load.json``.
"""
from __future__ import annotations

import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

ARTIFACT = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "experiments", "BENCH_serving_load.json")

PROMPT_LEN = 16
GEN = 12
N_CLASSES = 4

# the capacity-under-provisioned sections (DESIGN.md §14)
POLICY_CF = 0.5             # per-leaf capacity deliberately halved
POLICY_GEN = 24             # decode-heavy: the phase the policy governs
TOK_S_RATIO_GATE = 1.2      # master_leaf decode tok/s vs exact_dense
REPAIR_ERROR_BOUND = 1.0    # mean per-token relative delta on dropped tokens


def _model(seed: int = 0):
    from repro.configs import registry
    from repro.models import lm
    cfg = registry.get_config("internlm2-20b", ffn="fff").reduced()
    params = lm.init(jax.random.PRNGKey(seed), cfg)
    return cfg, params


def _policy_model(seed: int = 0, balance: float = 0.0):
    """The §14 sections' model: the reduced config with a fatter FFF site
    (deeper tree, wider leaves, two trees) so the FFF dispatch — the thing
    the overflow policy governs — actually dominates the decode step, plus
    the always-on master leaf the ``master_leaf`` policy repairs with."""
    from repro.configs import registry
    from repro.models import lm
    cfg = registry.get_config("internlm2-20b", ffn="fff").reduced()
    period = tuple(
        dataclasses.replace(b, ffn=dataclasses.replace(
            b.ffn, fff_master_leaf=True, fff_depth=4, fff_leaf_width=128,
            fff_trees=2, balance_scale=balance))
        if b.ffn.kind == "fff" else b for b in cfg.period)
    cfg = dataclasses.replace(cfg, period=period)
    params = lm.init(jax.random.PRNGKey(seed), cfg)
    return cfg, params


def calibrate_classes(params, cfg, n_classes: int, max_probe: int = 64):
    """Find ``n_classes`` prompt tokens whose repeated-token prompts route
    dominantly to *distinct* leaves; returns [(token, footprint (E,))].

    This is the offline per-tenant routing-profile measurement: one padded
    prefill per candidate under an ``api.collect_routing`` tap."""
    from repro.core import api
    from repro.models import lm

    probe = jax.jit(lambda p, t, c: lm.prefill_padded(
        p, cfg, {"tokens": t}, c, jnp.full((1,), PROMPT_LEN, jnp.int32)))

    def footprint(tok: int) -> np.ndarray:
        caches = lm.init_caches(cfg, 1, PROMPT_LEN + 1)
        with api.collect_routing(), \
                api.overrides(backend="grouped", mode="infer"):
            _, _, stats = probe(params,
                                jnp.full((1, PROMPT_LEN), tok, jnp.int32),
                                caches)
        c = np.asarray(next(s.leaf_counts[0] for s in stats if s is not None),
                       np.float64)
        return c / max(c.sum(), 1e-9)

    classes, seen = [], set()
    for tok in range(1, max_probe):
        f = footprint(tok)
        lead = int(f.argmax())
        if f[lead] > 0.5 and lead not in seen:
            seen.add(lead)
            classes.append((tok, f))
        if len(classes) == n_classes:
            break
    if len(classes) < n_classes:
        raise RuntimeError(f"calibration found only {len(classes)} distinct "
                           f"leaf classes in {max_probe} probe tokens")
    return classes


def calibrate_collisions(params, cfg, n_classes: int, max_probe: int = 64):
    """The inverse calibration: ``n_classes`` prompt tokens whose prompts all
    route dominantly to the SAME leaf — the workload a load-balancing aux
    loss exists to fix (DESIGN.md §14).  Returns [(token, footprint)] with a
    shared leading leaf."""
    from repro.core import api
    from repro.models import lm

    probe = jax.jit(lambda p, t, c: lm.prefill_padded(
        p, cfg, {"tokens": t}, c, jnp.full((1,), PROMPT_LEN, jnp.int32)))
    by_leaf: dict = {}
    for tok in range(1, max_probe):
        caches = lm.init_caches(cfg, 1, PROMPT_LEN + 1)
        with api.collect_routing(), \
                api.overrides(backend="grouped", mode="infer"):
            _, _, stats = probe(params,
                                jnp.full((1, PROMPT_LEN), tok, jnp.int32),
                                caches)
        c = np.asarray(next(s.leaf_counts[0] for s in stats if s is not None),
                       np.float64)
        f = c / max(c.sum(), 1e-9)
        by_leaf.setdefault(int(f.argmax()), []).append((tok, f))
        if max(len(v) for v in by_leaf.values()) >= n_classes:
            break
    leaf, group = max(by_leaf.items(), key=lambda kv: len(kv[1]))
    if len(group) < n_classes:
        raise RuntimeError(f"collision calibration found only {len(group)} "
                           f"tokens sharing leaf {leaf} in {max_probe} probes")
    return group[:n_classes]


def make_workload(classes, *, n_requests: int, burst: int, rate: float,
                  seed: int, gen: int = GEN, prompt_len: int = PROMPT_LEN):
    """Per-class bursts of ``burst`` requests with Poisson arrivals at
    ``rate`` req/s (rate <= 0: everything arrives at t=0)."""
    from repro.serving import Request
    rng = np.random.default_rng(seed)
    gaps = (np.zeros(n_requests) if rate <= 0
            else rng.exponential(1.0 / rate, n_requests))
    arrivals = np.cumsum(gaps)
    reqs = []
    for rid in range(n_requests):
        tok, fp = classes[(rid // burst) % len(classes)]
        reqs.append(Request(
            rid=rid, prompt=np.full((prompt_len,), tok, np.int32),
            max_new_tokens=gen, arrival_time=float(arrivals[rid]),
            leaf_hint=fp.copy()))
    return reqs


def run_one(params, cfg, *, scheduler: str, slots: int, reqs, seed: int,
            gen: int = GEN, capacity_factor=None, overflow_policy=None,
            warm: bool = False):
    from repro.serving import ContinuousBatchingEngine, EngineConfig
    kw = {"window": 4 * slots} if scheduler == "leaf_aware" else {}
    ecfg = EngineConfig(
        num_slots=slots, max_len=PROMPT_LEN + gen + 1,
        max_prompt_len=PROMPT_LEN, scheduler=scheduler, scheduler_kw=kw,
        fff_backend="grouped",          # capacity-bounded dispatch: the
        max_prefills_per_step=slots,    # regime where composition matters
        capacity_factor=capacity_factor,
        overflow_policy=overflow_policy,
        seed=seed)
    engine = ContinuousBatchingEngine(params, cfg, ecfg)
    if warm:
        engine.run(reqs)                # compile outside the measured run
    _, m = engine.run(reqs)
    return m


def decode_tok_s(m) -> float:
    """Decode-phase tokens/s: every generated token comes out of a decode
    dispatch, so this is the equal-slots throughput the overflow policy
    governs (whole-run tok/s also counts prefill + host scheduling)."""
    dec_s = m.decode_step.mean_ms / 1e3 * m.decode_step.n
    return m.n_tokens / max(dec_s, 1e-9)


def train_checkpoint(params0, class_tokens, *, balance: float, steps: int,
                     seed: int = 0, batch: int = 32, seq: int = 24,
                     lr: float = 3e-3):
    """Fine-tune the policy model on repeated-class-token rows for ``steps``
    adamw steps; ``balance`` weights the FFF load-balancing aux (0 = the
    unbalanced baseline trained identically otherwise).  Returns (params,
    per-step metric dicts)."""
    from repro import optim
    from repro.models import lm
    cfg, _ = _policy_model(balance=balance)
    params = jax.tree.map(lambda a: a, params0)
    opt = optim.chain_clip(optim.adamw(
        optim.cosine_warmup(lr, steps // 10 + 1, steps)), 1.0)
    ostate = opt.init(params)
    rng = np.random.default_rng(seed)

    def step(params, ostate, batch_d, key):
        def loss(p):
            return lm.loss_fn(p, cfg, batch_d, key)
        (_, m), g = jax.value_and_grad(loss, has_aux=True)(params)
        up, ostate = opt.update(g, ostate, params)
        return optim.apply_updates(params, up), ostate, m

    step_jit = jax.jit(step)
    history = []
    for i in range(steps):
        rows = np.stack([np.full((seq,), class_tokens[
            rng.integers(len(class_tokens))], np.int32)
            for _ in range(batch)])
        params, ostate, m = step_jit(params, ostate,
                                     {"tokens": rows, "labels": rows},
                                     jax.random.PRNGKey(seed * 10_000 + i))
        history.append({k: float(v) for k, v in m.items()})
    return params, history


def policy_compare_section(runs: list, quick: bool, seed: int) -> dict:
    """Master-leaf overflow repair vs the exact dense fallback at equal
    slots, capacity_factor < 1.0, on the skewed class-burst workload: the
    repair trades the dense gather round for the already-paid master term,
    so decode-phase tokens/s must win by >= TOK_S_RATIO_GATE while the
    output degrades only on dropped tokens (repair_error_section bounds
    that)."""
    slots = 128
    n_requests = 2 * slots
    cfg, params = _policy_model(seed)
    classes = calibrate_classes(params, cfg, N_CLASSES)
    reqs = make_workload(classes, n_requests=n_requests, burst=slots,
                         rate=0.0, seed=seed + 1, gen=POLICY_GEN)
    out = {"slots": slots, "n_requests": n_requests,
           "capacity_factor": POLICY_CF, "gen": POLICY_GEN,
           "gate_tok_s_ratio": TOK_S_RATIO_GATE}
    for policy in ("exact_dense", "master_leaf"):
        m = run_one(params, cfg, scheduler="fcfs", slots=slots, reqs=reqs,
                    seed=seed, gen=POLICY_GEN, capacity_factor=POLICY_CF,
                    overflow_policy=policy, warm=True)
        d = decode_tok_s(m)
        out[policy] = {"tok_s": m.throughput_tok_s, "decode_tok_s": d,
                       "overflow_decode_mean": m.overflow_decode_mean,
                       "overflow_repairs": m.overflow_repairs,
                       "master_leaf_fraction": m.master_leaf_fraction}
        runs.append({"section": "policy_compare", "overflow_policy": policy,
                     "scheduler": "fcfs", "rate_req_s": 0.0, "slots": slots,
                     "n_requests": n_requests, **m.as_dict()})
        print(f"serving_policy,{policy},{m.throughput_tok_s:.1f},{d:.0f},"
              f"{m.overflow_decode_mean:.4f},{m.overflow_repairs}",
              flush=True)
    ratio = (out["master_leaf"]["decode_tok_s"]
             / max(out["exact_dense"]["decode_tok_s"], 1e-9))
    out["decode_tok_s_ratio"] = ratio
    out["ok"] = bool(ratio >= TOK_S_RATIO_GATE)
    print(f"# master_leaf decode tok/s {out['master_leaf']['decode_tok_s']:.0f}"
          f" vs exact_dense {out['exact_dense']['decode_tok_s']:.0f} at "
          f"cf={POLICY_CF} -> {ratio:.2f}x "
          f"(gate {TOK_S_RATIO_GATE}x: {'OK' if out['ok'] else 'FAIL'})")
    return out


def balance_compare_section(runs: list, quick: bool, seed: int) -> dict:
    """Load-balanced training vs the identical loop without the balance aux:
    fine-tune the policy model on a leaf-COLLIDING class set (all classes
    route to one leaf at init), then serve the mixed-class workload at
    capacity_factor < 1.0 from each checkpoint — the balanced one must
    spread the classes across leaves and cut decode overflow."""
    slots = 64
    steps = 80 if quick else 120
    n_collide = 8
    cfg, params0 = _policy_model(seed)
    collide = calibrate_collisions(params0, cfg, n_collide)
    toks = [t for t, _ in collide]
    print(f"# collision classes (shared leaf "
          f"{int(collide[0][1].argmax())}): {toks}")
    reqs = make_workload(collide, n_requests=2 * slots, burst=1, rate=0.0,
                         seed=seed + 1, gen=POLICY_GEN)
    out = {"slots": slots, "capacity_factor": POLICY_CF, "steps": steps,
           "balance_weight": 1.0, "collision_tokens": toks,
           "collision_leaf": int(collide[0][1].argmax())}
    for label, balance in (("balanced", 1.0), ("unbalanced", 0.0)):
        params, hist = train_checkpoint(params0, toks, balance=balance,
                                        steps=steps, seed=seed)
        m = run_one(params, cfg, scheduler="fcfs", slots=slots, reqs=reqs,
                    seed=seed, gen=POLICY_GEN, capacity_factor=POLICY_CF,
                    overflow_policy="master_leaf")
        out[label] = {
            "loss_first": hist[0]["loss"], "loss_last": hist[-1]["loss"],
            "balance_first": hist[0]["balance"],
            "balance_last": hist[-1]["balance"],
            "overflow_decode_mean": m.overflow_decode_mean,
            "tok_s": m.throughput_tok_s}
        runs.append({"section": "balance_compare", "checkpoint": label,
                     "scheduler": "fcfs", "rate_req_s": 0.0, "slots": slots,
                     "n_requests": 2 * slots, **m.as_dict()})
        print(f"serving_balance,{label},{m.throughput_tok_s:.1f},"
              f"{m.overflow_decode_mean:.4f},"
              f"{hist[-1]['loss']:.3f}", flush=True)
    out["ok"] = bool(out["balanced"]["overflow_decode_mean"]
                     < out["unbalanced"]["overflow_decode_mean"])
    print(f"# balanced decode overflow "
          f"{out['balanced']['overflow_decode_mean']:.4f} vs unbalanced "
          f"{out['unbalanced']['overflow_decode_mean']:.4f} after {steps} "
          f"steps -> {'LOWER (OK)' if out['ok'] else 'NOT LOWER (FAIL)'}")
    return out


def repair_error_section(seed: int) -> dict:
    """Per-token output delta of the approximate master-leaf repair vs the
    exact dense fallback, on a standalone FFF site at capacity_factor < 1.0:
    kept tokens are bit-identical (same dispatch), dropped tokens lose one
    tree's leaf term and keep the master + remaining trees — the relative
    delta must stay under REPAIR_ERROR_BOUND."""
    from repro.core import api, fff
    cfg = fff.FFFConfig(dim_in=64, dim_out=64, depth=4, leaf_width=64,
                        trees=2, activation="gelu", leaf_bias=False,
                        master_leaf=True)
    params = fff.init(jax.random.PRNGKey(seed), cfg)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (256, 64))

    def y_for(policy):
        spec = api.ExecutionSpec(mode="infer", backend="grouped",
                                 capacity_factor=0.25,
                                 overflow_policy=policy)
        return np.asarray(api.apply(params, cfg, x, spec)[0], np.float64)

    y_exact = y_for("exact_dense")
    y_master = y_for("master_leaf")
    delta = np.linalg.norm(y_master - y_exact, axis=-1)
    rel = delta / (np.linalg.norm(y_exact, axis=-1) + 1e-9)
    dropped = rel > 1e-7            # kept tokens ride the identical path
    out = {"batch": int(x.shape[0]), "capacity_factor": 0.25,
           "dropped_fraction": float(dropped.mean()),
           "rel_delta_mean": float(rel[dropped].mean()) if dropped.any()
           else 0.0,
           "rel_delta_max": float(rel[dropped].max()) if dropped.any()
           else 0.0,
           "bound": REPAIR_ERROR_BOUND}
    out["ok"] = bool(dropped.any()
                     and out["rel_delta_mean"] <= REPAIR_ERROR_BOUND)
    print(f"# repair error: {out['dropped_fraction']:.2f} of tokens dropped, "
          f"rel delta mean {out['rel_delta_mean']:.3f} / max "
          f"{out['rel_delta_max']:.3f} (bound {REPAIR_ERROR_BOUND}: "
          f"{'OK' if out['ok'] else 'FAIL'})")
    return out


def main(quick: bool = True) -> None:
    seed = 0
    slots = 16 if quick else 32
    n_requests = (8 if quick else 16) * slots // 2
    rates = [16.0, 64.0, 0.0] if quick else [8.0, 16.0, 32.0, 64.0, 0.0]

    cfg, params = _model(seed)
    classes = calibrate_classes(params, cfg, N_CLASSES)
    print(f"# classes (token -> leaf): "
          f"{[(t, int(f.argmax())) for t, f in classes]}")
    print("# name,sched,rate_req_s,tok_s,ttft_p50_ms,per_token_p50_ms,"
          "overflow_mean,overflow_decode_mean")

    runs = []
    for rate in rates:
        for sched in ("fcfs", "leaf_aware"):
            reqs = make_workload(classes, n_requests=n_requests, burst=slots,
                                 rate=rate, seed=seed + 1)
            m = run_one(params, cfg, scheduler=sched, slots=slots,
                        reqs=reqs, seed=seed)
            rate_label = rate if rate > 0 else float("inf")
            print(f"serving,{sched},{rate_label},{m.throughput_tok_s:.1f},"
                  f"{m.ttft.p50_ms:.2f},{m.per_token.p50_ms:.2f},"
                  f"{m.overflow_fraction_mean:.4f},"
                  f"{m.overflow_decode_mean:.4f}", flush=True)
            runs.append({"scheduler": sched, "rate_req_s": rate,
                         "slots": slots, "n_requests": n_requests,
                         **m.as_dict()})

    # the acceptance comparison: at saturating load (every arrival pattern
    # shares the same token budget, so throughput is decode-bound and equal),
    # leaf-aware admission must cut capacity overflow on this skewed mix
    sat = [r for r in runs if r["rate_req_s"] == 0.0]
    fcfs = next(r for r in sat if r["scheduler"] == "fcfs")
    aware = next(r for r in sat if r["scheduler"] == "leaf_aware")
    verdict = aware["overflow_decode_mean"] < fcfs["overflow_decode_mean"]
    print(f"# leaf_aware decode overflow {aware['overflow_decode_mean']:.4f} "
          f"vs fcfs {fcfs['overflow_decode_mean']:.4f} at "
          f"{aware['throughput_tok_s']:.0f}/{fcfs['throughput_tok_s']:.0f} "
          f"tok/s -> {'LOWER' if verdict else 'NOT LOWER'}")

    # DESIGN.md §14: the capacity-under-provisioned sections
    policy_compare = policy_compare_section(runs, quick, seed)
    balance_compare = balance_compare_section(runs, quick, seed)
    repair_error = repair_error_section(seed)

    with open(ARTIFACT, "w") as f:
        json.dump({"bench": "serving_load", "quick": quick, "slots": slots,
                   "prompt_len": PROMPT_LEN, "gen": GEN,
                   "classes": [(int(t), int(fp.argmax()))
                               for t, fp in classes],
                   "policy_compare": policy_compare,
                   "balance_compare": balance_compare,
                   "repair_error": repair_error,
                   "runs": runs}, f, indent=1)
    print(f"# wrote {ARTIFACT}")


if __name__ == "__main__":
    main()
