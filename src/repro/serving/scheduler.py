"""Admission schedulers for the continuous-batching engine (DESIGN.md §9).

``select(waiting, n_free, view)`` picks which waiting requests to admit into
free cache slots this step.  The engine passes a ``SchedulerView`` of its
live FFF telemetry; schedulers are pure host-side policy (numpy only) so new
ones need no jax knowledge.

Built-ins:

* ``fcfs`` — strict arrival order.
* ``leaf_aware`` — FFF-composition-aware: grouped/grouped_ep serving drops
  (or dense-repairs) tokens past per-leaf capacity, and which tokens share a
  microbatch decides that overflow (Fast Feedforward Networks, 2023; skewed
  leaf load is the failure mode the load-balancing follow-up targets).  The
  scheduler greedily admits, from a bounded look-ahead window, the candidate
  whose predicted leaf footprint (its ``leaf_hint`` prior, the tenant's
  learned routing profile, or live EWMA occupancy once measured) minimizes
  predicted capacity overflow of the composed batch.  A hold counter bounds
  how often the queue head can be bypassed, so no request starves.
* ``weighted_leaf_aware`` — multi-tenant QoS on top of the same objective:
  stride accounting (deterministic weighted round-robin — each admission
  advances its tenant's virtual pass by 1/weight, the tenant with the
  smallest pass admits next) apportions admission slots across tenants in
  proportion to configured weights, and *within* the winning tenant the
  leaf-aware pick composes the batch.  Weighted fairness holds under
  overload by construction; starvation is impossible for any tenant with
  positive weight.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.serving.request import Request


@dataclasses.dataclass
class SchedulerView:
    """What the engine exposes to admission policy each step.

    occupancy: (num_slots, E) float64 — per-slot EWMA leaf-footprint
               fractions (rows of active slots sum to ~1; free rows are 0)
    active:    (num_slots,) bool
    num_leaves: E of the telemetry (0 = no FFF telemetry; leaf_aware then
               degrades to FCFS)
    capacity_factor: the serving capacity factor the dispatch runs with
    num_slots: total cache slots (the decode dispatch batch is always this
               size — free slots decode a dummy token)
    dispatch_shards: how many ways the dispatch splits the token axis —
               the data-shard count G for local grouped dispatch, G·M for
               grouped_ep (capacity is per *source shard* there, DESIGN.md
               §5); 1 unmeshed
    prefilling: (num_slots,) bool — slots admitted but still mid-chunked-
               prefill (all False under monolithic prefill); the
               ``max_prefilling`` admission cap counts these
    profiles:  the engine's online ``RoutingProfileStore`` (or None) —
               leaf-aware schedulers fall back to ``profiles.lookup(
               req.tenant)`` for candidates without a usable ``leaf_hint``
    tokens_per_slot: tokens each active slot contributes to one decode-side
               dispatch — 1 for plain decode, ``spec_k + 1`` for a
               speculative verify slab (DESIGN.md §10): the capacity the
               overflow proxy predicts against scales with the slab width,
               and the occupancy fractions are per-token so the load side
               scales identically
    pages_free: free pages in the engine's KV page pool (None when the
               engine predates paging or a custom driver doesn't track it).
               A free slot no longer guarantees admission — the page
               allocator can refuse a long prompt even with slots open —
               so page-aware policies can skip candidates that obviously
               can't be funded this step
    """
    occupancy: np.ndarray
    active: np.ndarray
    num_leaves: int
    capacity_factor: Optional[float]     # None = exact backend, no bound
    num_slots: int
    dispatch_shards: int = 1
    prefilling: Optional[np.ndarray] = None
    profiles: Optional[object] = None    # serving.profiles.RoutingProfileStore
    tokens_per_slot: int = 1
    pages_free: Optional[int] = None

    def leaf_capacity(self) -> float:
        """Whole-batch per-leaf capacity of one decode-side dispatch, in
        units of slot-footprints (occupancy rows summing to ~1 per slot):
        the dispatch layer's own per-(shard, leaf) law
        (``dispatch.ep_capacity``, shared by ``grouped_leaf_apply``) on the
        per-shard token count, times the shard count — with tokens split
        roughly evenly, the per-shard floor multiplies.  The dispatch
        carries ``num_slots * tokens_per_slot`` tokens (a speculative
        verify slab is ``(num_slots, spec_k + 1)``); dividing back by
        ``tokens_per_slot`` converts token capacity into the per-slot
        footprint units the leaf_aware load side uses.  Infinite for exact
        (capacity-unbounded) backends: the leaf_aware objective then
        reduces to its max-load balancing term."""
        if self.num_leaves <= 0 or self.capacity_factor is None:
            return float("inf")
        from repro.distributed import dispatch as dispatch_lib
        shards = max(self.dispatch_shards, 1)
        tps = max(self.tokens_per_slot, 1)
        per_shard = -(-self.num_slots * tps // shards)       # ceil
        return float(dispatch_lib.ep_capacity(
            per_shard, self.num_leaves, self.capacity_factor) * shards) / tps


class Scheduler:
    """Admission-policy base class.

    Subclasses implement ``select``; registering the class in ``SCHEDULERS``
    (or shadowing a built-in name) makes it reachable from
    ``EngineConfig.scheduler`` and ``serve.py --scheduler``.

    ``max_prefilling`` is the TTFT-vs-decode-p99 knob for chunked prefill
    (DESIGN.md §9): it caps how many slots may sit in the prefilling state
    at once.  Admitting more concurrent prefills fills the shared
    ``(num_slots, chunk_len)`` slab — better amortization and TTFT — but
    every in-flight prefill keeps the per-step chunk work at its budgeted
    maximum for longer, which is what decode p99 pays.  0 = uncapped.  The
    knob is inert under monolithic prefill (admission and prefill complete
    in the same step, so nothing is ever *in* the prefilling state)."""
    name = "base"

    def __init__(self, max_prefilling: int = 0):
        self.max_prefilling = max_prefilling

    def admission_cap(self, view: SchedulerView) -> int:
        """How many NEW requests may be admitted this step, given how many
        slots are already mid-prefill.  The engine intersects this with its
        free-slot count and ``max_prefills_per_step``."""
        if self.max_prefilling <= 0:
            return view.num_slots
        busy = (int(view.prefilling.sum()) if view.prefilling is not None
                else 0)
        return max(self.max_prefilling - busy, 0)

    def select(self, waiting: Sequence[Request], n_free: int,
               view: SchedulerView) -> List[Request]:
        """Pick <= n_free requests from ``waiting`` to admit this step.

        ``waiting`` is in arrival order; the returned list's order is the
        admission order (earlier = lower slot index).  Must not mutate
        ``waiting`` or the requests.  Called once per engine step while any
        slot is free and the queue is non-empty."""
        raise NotImplementedError


class FCFSScheduler(Scheduler):
    """First-come-first-served: admit in arrival order."""
    name = "fcfs"

    def select(self, waiting, n_free, view):
        return list(waiting[:n_free])


class LeafAwareScheduler(Scheduler):
    """Greedy leaf-load-balancing admission (module docstring).

    window:   how deep into the queue the policy may look (bounds both
              unfairness and per-step host cost)
    max_hold: after this many bypasses the queue head is force-admitted
              (the no-starvation bound: head waits at most ``max_hold``
              admission rounds beyond FCFS)
    """
    name = "leaf_aware"

    def __init__(self, window: int = 16, max_hold: int = 8,
                 max_prefilling: int = 0):
        super().__init__(max_prefilling=max_prefilling)
        self.window = window
        self.max_hold = max_hold
        self._holds: Dict[int, int] = {}

    def _footprint(self, req: Request, E: int,
                   view: Optional[SchedulerView] = None) -> np.ndarray:
        h = req.leaf_hint
        if (h is None or h.size != E or h.sum() <= 0) and view is not None \
                and view.profiles is not None:
            h = view.profiles.lookup(req.tenant)   # learned tenant profile
        if h is None or h.size != E or h.sum() <= 0:
            return np.full((E,), 1.0 / E)
        return h / h.sum()

    @staticmethod
    def _overflow(load: np.ndarray, cap: float) -> float:
        return float(np.maximum(load - cap, 0.0).sum())

    def _pick(self, pool: List[Request], load: np.ndarray, E: int,
              cap: float, view: SchedulerView) -> int:
        """Hold-guarded leaf-aware pick: index into ``pool`` (a FIFO window)
        minimizing the lexicographic cost (predicted overflow, then
        max-leaf load — balance below the capacity threshold too, headroom
        — then arrival order, stable/deterministic).  The queue head is
        force-picked once its hold count reaches ``max_hold`` (the
        starvation guard).  Shared by ``leaf_aware`` and the within-tenant
        pick of ``weighted_leaf_aware`` — one objective, two policies."""
        if E <= 0 or len(pool) == 1:
            return 0
        if self._holds.get(pool[0].rid, 0) >= self.max_hold:
            return 0
        costs = []
        for i, r in enumerate(pool):
            nl = load + self._footprint(r, E, view)
            costs.append((self._overflow(nl, cap), float(nl.max()), i))
        return min(costs)[2]

    def select(self, waiting, n_free, view):
        if view.num_leaves <= 0 or not waiting:
            return list(waiting[:n_free])
        E = view.num_leaves
        cap = view.leaf_capacity()
        # current per-leaf load of the composed decode batch, in routed
        # slots per step (each active slot ≈ its footprint row)
        load = view.occupancy[view.active].sum(axis=0) if view.active.any() \
            else np.zeros((E,))
        pool = list(waiting[: max(self.window, n_free)])
        chosen: List[Request] = []
        for _ in range(min(n_free, len(waiting))):
            if not pool:
                break
            pick = self._pick(pool, load, E, cap, view)
            req = pool.pop(pick)
            load = load + self._footprint(req, E, view)
            chosen.append(req)
        chosen_ids = {r.rid for r in chosen}
        # bump hold counters for bypassed waiters ahead of any chosen one
        for r in waiting:
            if r.rid in chosen_ids:
                break
            self._holds[r.rid] = self._holds.get(r.rid, 0) + (1 if chosen
                                                              else 0)
        for r in chosen:
            self._holds.pop(r.rid, None)
        return chosen


class WeightedLeafAwareScheduler(LeafAwareScheduler):
    """Multi-tenant weighted-fair admission with leaf-aware composition
    (module docstring).

    Tenant selection is STRIDE SCHEDULING: each tenant carries a virtual
    ``pass``; every admission it wins advances its pass by ``1 / weight``,
    and the waiting tenant with the smallest pass wins the next free slot.
    Over any saturated interval each tenant's admission share converges to
    ``weight_t / sum(weights of backlogged tenants)`` with bounded lag — the
    deficit-round-robin guarantee, deterministically (name-ordered
    tie-break, no RNG).  A tenant that rejoins after idling resumes at the
    current virtual time, not its stale pass, so it cannot burst-catch-up
    and monopolize the slots its peers were promised.

    Within the winning tenant, the pick over its first ``window`` waiters is
    the parent class's leaf-aware objective (predicted overflow, max-leaf
    load, arrival order) against the composed batch, with the same
    ``max_hold`` guard on the tenant's queue head — so QoS weights decide
    *who* gets capacity while FFF telemetry still decides *which* of their
    requests mix well.

    weights:        tenant -> positive weight (admission-rate share; for
                    similar request shapes this is also the slot-time and
                    tokens/s share).  Tenants not listed get
                    ``default_weight``.
    default_weight: weight for unlisted tenants (> 0).
    """
    name = "weighted_leaf_aware"

    def __init__(self, weights: Optional[Dict[str, float]] = None,
                 default_weight: float = 1.0, window: int = 16,
                 max_hold: int = 8, max_prefilling: int = 0):
        super().__init__(window=window, max_hold=max_hold,
                         max_prefilling=max_prefilling)
        weights = dict(weights or {})
        for t, w in weights.items():
            # finite required: an inf weight makes the stride 0, freezing
            # the tenant's pass at the virtual time — it would win every
            # admission and starve all peers
            if not (w > 0 and np.isfinite(w)):
                raise ValueError(f"tenant {t!r}: weight must be positive "
                                 f"and finite, got {w}")
        if not (default_weight > 0 and np.isfinite(default_weight)):
            raise ValueError(f"default_weight must be positive and finite, "
                             f"got {default_weight}")
        self.weights = weights
        self.default_weight = default_weight
        self._pass: Dict[str, float] = {}
        self._vtime = 0.0                   # pass of the last admission

    def weight(self, tenant: str) -> float:
        return self.weights.get(tenant, self.default_weight)

    def select(self, waiting, n_free, view):
        if not waiting:
            return []
        E = view.num_leaves
        cap = view.leaf_capacity()
        load = (view.occupancy[view.active].sum(axis=0)
                if E > 0 and view.active.any() else np.zeros((max(E, 1),)))
        groups: Dict[str, List[Request]] = {}
        for r in waiting:                    # insertion order = first arrival
            groups.setdefault(r.tenant, []).append(r)
        for t in groups:                     # rejoin at current virtual time
            self._pass[t] = max(self._pass.get(t, self._vtime), self._vtime)
        chosen: List[Request] = []
        for _ in range(min(n_free, len(waiting))):
            live = [t for t, g in groups.items() if g]
            if not live:
                break
            t = min(live, key=lambda name: (self._pass[name], name))
            pool = groups[t][: max(self.window, 1)]
            pick = self._pick(pool, load, E, cap, view)
            req = pool[pick]
            groups[t].remove(req)
            if pick > 0:                     # bypassed this tenant's head
                head = pool[0]
                self._holds[head.rid] = self._holds.get(head.rid, 0) + 1
            self._holds.pop(req.rid, None)
            if E > 0:
                load = load + self._footprint(req, E, view)
            chosen.append(req)
            self._vtime = self._pass[t]
            self._pass[t] += 1.0 / self.weight(t)
        # bounded state under churning tenant names: drop pass entries for
        # absent tenants ONLY once the virtual time has caught up to them —
        # an absent tenant still ahead of vtime carries stride debt it just
        # consumed, and deleting that would let a drip-feed tenant (queue
        # drains every time it wins) rejoin debt-free each round and take
        # ~every other slot regardless of weight.  Entries expire naturally:
        # a pass exceeds vtime by at most one stride, and vtime advances
        # every admission.
        for t in [t for t in self._pass
                  if t not in groups and self._pass[t] <= self._vtime]:
            del self._pass[t]
        return chosen


SCHEDULERS = {
    "fcfs": FCFSScheduler,
    "leaf_aware": LeafAwareScheduler,
    "weighted_leaf_aware": WeightedLeafAwareScheduler,
}


def make_scheduler(name: str, **kw) -> Scheduler:
    """Instantiate a registered admission scheduler by name.

    ``kw`` is forwarded to the scheduler's constructor (``EngineConfig.
    scheduler_kw`` arrives here): ``fcfs`` takes ``max_prefilling``;
    ``leaf_aware`` additionally takes ``window`` and ``max_hold``;
    ``weighted_leaf_aware`` additionally takes ``weights`` (tenant -> weight
    dict) and ``default_weight``.  Unknown names raise KeyError listing the
    registry."""
    try:
        cls = SCHEDULERS[name]
    except KeyError:
        raise KeyError(f"unknown scheduler {name!r}; have "
                       f"{sorted(SCHEDULERS)}") from None
    return cls(**kw)
