"""Sharding-aware host data loader with background prefetch.

On a real multi-host pod each process feeds its local shard
(``jax.make_array_from_process_local_data``); in this single-process container
the same code path degenerates to a device_put with the global sharding.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def shard_batch(batch: dict, mesh: Optional[Mesh] = None,
                batch_axes: tuple = ("pod", "data")) -> dict:
    """Place a host batch onto the mesh, batch dim sharded over data axes."""
    if mesh is None:
        return jax.tree_util.tree_map(jax.numpy.asarray, batch)
    axes = tuple(a for a in batch_axes if a in mesh.axis_names)

    def put(x):
        spec = P(axes, *([None] * (x.ndim - 1)))
        return jax.make_array_from_process_local_data(
            NamedSharding(mesh, spec), np.asarray(x))

    return jax.tree_util.tree_map(put, batch)


class Prefetcher:
    """Background-thread prefetch of host batches (overlap data/compute)."""

    def __init__(self, make_batch: Callable[[int], dict], depth: int = 2,
                 mesh: Optional[Mesh] = None):
        self.make_batch = make_batch
        self.mesh = mesh
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = 0
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        step = 0
        while not self._stop.is_set():
            try:
                batch = self.make_batch(step)
            except Exception as e:              # surface errors to the consumer
                self.q.put(e)
                return
            self.q.put(shard_batch(batch, self.mesh))
            step += 1

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        item = self.q.get()
        if isinstance(item, Exception):
            raise item
        return item

    def close(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass


def epoch_batches(x: np.ndarray, y: np.ndarray, batch_size: int, seed: int
                  ) -> Iterator[dict]:
    """Shuffled epoch iterator over an in-memory dataset."""
    rng = np.random.default_rng(seed)
    idx = rng.permutation(len(x))
    for i in range(0, len(x) - batch_size + 1, batch_size):
        sel = idx[i:i + batch_size]
        yield {"x": x[sel], "y": y[sel]}
