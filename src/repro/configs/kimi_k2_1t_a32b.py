"""kimi-k2-1t-a32b [moe] — 61L d_model=7168 64H (GQA kv=8) d_ff=2048 (per
expert) vocab=163840, MoE 384 experts top-8 — trillion-param MoE.
[arXiv:2501.kimi2; unverified]

FFF-for-MoE at the trillion scale: forest of 8 trees (top-8 active width),
each depth 6 (64 leaves) with leaf width 2048: training width 8*64*2048 =
1,048,576 neurons vs the MoE's 384*2048 = 786,432 — the paper's user manual
explicitly allows the training width to grow when matching an inference
budget.  Routing drops from an O(384) gate to 8 * 6 node dot-products."""
import jax.numpy as jnp

from repro.configs.base import BlockSpec, FFNSpec, ModelConfig

CONFIG = ModelConfig(
    arch_id="kimi-k2-1t-a32b",
    family="moe",
    d_model=7168,
    n_layers=61,
    n_heads=64,
    n_kv_heads=8,
    head_dim=112,
    vocab_size=163840,
    max_seq_len=32768,
    period=(BlockSpec(mixer="attn",
                      ffn=FFNSpec(kind="moe", d_ff=2048, activation="swiglu",
                                  moe_experts=384, moe_top_k=8)),),
    param_dtype=jnp.bfloat16,
    accum_dtype=jnp.bfloat16,
    remat="full",
    grad_accum=16,
    zero_stage=3,
)

FFF_CONFIG = CONFIG.with_ffn_kind("fff", leaf_width=2048, trees=8)
