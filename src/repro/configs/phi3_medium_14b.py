"""phi3-medium-14b [dense] — 40L d_model=5120 40H (GQA kv=10) d_ff=17920
vocab=100352, RoPE SwiGLU GQA.  [arXiv:2404.14219; unverified]"""
import jax.numpy as jnp

from repro.configs.base import BlockSpec, FFNSpec, ModelConfig

CONFIG = ModelConfig(
    arch_id="phi3-medium-14b",
    family="dense",
    d_model=5120,
    n_layers=40,
    n_heads=40,
    n_kv_heads=10,
    vocab_size=100352,
    max_seq_len=32768,
    period=(BlockSpec(mixer="attn",
                      ffn=FFNSpec(kind="dense", d_ff=17920,
                                  activation="swiglu")),),
    param_dtype=jnp.bfloat16,
    accum_dtype=jnp.bfloat16,
    remat="full",
    grad_accum=16,
)

# 16 leaves x 1120 = 17920 (exact width match; 1120 = 35*32 stays VPU-aligned)
FFF_CONFIG = CONFIG.with_ffn_kind("fff", leaf_width=1120)
