"""Disaggregated prefill/decode cluster tests (DESIGN.md §12).

Tiers:
* host-only — VirtualClock, placement scoring, GlobalPrefixMap, and the
  ClusterMonitor's liveness/backoff/straggler/watermark policies run
  against synthetic views in pure virtual time (zero sleeps, zero jax);
* handoff tier — extract/install round-trips KV pages between two real
  engines and the receiver decodes token-for-token what a single engine
  would have produced;
* cluster tier — a LocalBus fleet (router + prefill + decode workers)
  serves mixed workloads with exact single-engine parity, survives a
  decode-worker kill mid-stream with zero lost or duplicated tokens
  (request replay from the prompt + Done dedup), honors drain semantics,
  autoscales on queue pressure, and keeps the per-worker compile contract
  at single-engine counts (decode workers never compile admit; prefill
  workers never compile decode).
"""
import numpy as np
import pytest

import jax

from repro.cluster import (ClusterConfig, ClusterWorker, GlobalPrefixMap,
                           LocalBus, Router, WorkerView, choose_decode,
                           choose_prefill)
from repro.cluster import handoff as handoff_lib
from repro.cluster.control import (ClusterMonitor, ControlConfig,
                                   DrainWorker, MarkDead, Respawn,
                                   SpawnDecode)
from repro.cluster.placement import overlap
from repro.configs import registry
from repro.distributed import StragglerConfig
from repro.models import lm
from repro.serving import ContinuousBatchingEngine, EngineConfig, Request
from repro.serving.engine import VirtualClock

# ---------------------------------------------------------------------------
# host-only tier
# ---------------------------------------------------------------------------


def test_virtual_clock():
    vc = VirtualClock(start=2.0)
    assert vc() == 2.0
    assert vc.advance(0.5) == 2.5
    assert vc() == 2.5
    with pytest.raises(ValueError):
        vc.advance(-0.1)


def test_engine_accepts_injected_clock():
    """now() runs entirely on the injected clock — no wall time."""
    cfg = registry.get_config("internlm2-20b", ffn="fff").reduced()
    params = lm.init(jax.random.PRNGKey(0), cfg)
    vc = VirtualClock(start=100.0)
    eng = ContinuousBatchingEngine(
        params, cfg, EngineConfig(num_slots=2, max_len=32,
                                  max_prompt_len=16, seed=0), clock=vc)
    assert eng.now() == 0.0
    vc.advance(3.0)
    assert eng.now() == 3.0


def test_overlap_and_decode_scoring():
    assert overlap(None, np.ones(4)) == 0.0
    assert overlap(np.ones(4), np.zeros(4)) == 0.0
    assert overlap(np.array([1.0, 0]), np.array([1.0, 0])) == \
        pytest.approx(1.0)
    base = dict(pages_total=64, queue_depth=0, active_slots=0, num_slots=4)
    views = {
        "d0": WorkerView(wid="d0", role="decode", pages_free=64,
                         occupancy=np.array([1.0, 0.0]), **base),
        "d1": WorkerView(wid="d1", role="decode", pages_free=64,
                         occupancy=np.array([0.0, 1.0]), **base),
    }
    # leaf-overlap steers AWAY from the worker already loaded on our leaves
    assert choose_decode(views, np.array([1.0, 0.0])) == "d1"
    assert choose_decode(views, np.array([0.0, 1.0])) == "d0"
    # page headroom dominates when footprints are flat
    views["d0"].pages_free = 4
    assert choose_decode(views, None) == "d1"
    # draining / full workers are never placed on
    views["d1"].draining = True
    views["d0"].draining = True
    assert choose_decode(views, None) is None


def test_choose_prefill_affinity_and_fallback():
    mk = lambda wid, q: WorkerView(wid=wid, role="prefill", num_slots=2,
                                   queue_depth=q)
    views = {"p0": mk("p0", 4), "p1": mk("p1", 0)}
    assert choose_prefill(views, None) == "p1"          # least loaded
    assert choose_prefill(views, "p0") == "p0"          # affinity wins
    views["p0"].draining = True
    assert choose_prefill(views, "p0") == "p1"          # unless draining


def test_global_prefix_map():
    m = GlobalPrefixMap(page_size=4)
    sys_prefix = list(range(100, 108))                  # two chunks
    m.insert(sys_prefix, "p0")
    assert m.lookup(sys_prefix + [1, 2, 3, 4]) == "p0"
    assert m.lookup([9, 9, 9, 9]) is None
    assert m.lookup([1, 2]) is None                     # sub-chunk: no key
    m.insert([9, 9, 9, 9], "p1")
    assert m.lookup([9, 9, 9, 9, 5]) == "p1"
    m.drop_worker("p0")
    assert m.lookup(sys_prefix) is None
    assert m.lookup([9, 9, 9, 9]) == "p1"


def _mk_views(**extra):
    views = {
        "p0": WorkerView(wid="p0", role="prefill", num_slots=2),
        "d0": WorkerView(wid="d0", role="decode", num_slots=4,
                         pages_free=64, pages_total=64),
        "d1": WorkerView(wid="d1", role="decode", num_slots=4,
                         pages_free=64, pages_total=64),
    }
    for wid, kw in extra.items():
        for k, v in kw.items():
            setattr(views[wid], k, v)
    return views


def test_monitor_heartbeat_timeout_and_backoff_respawn():
    vc = VirtualClock()
    mon = ClusterMonitor(ControlConfig(heartbeat_timeout=1.0,
                                       max_restarts=2, backoff_base=0.5,
                                       scale_up_watermark=1e9,
                                       scale_down_watermark=-1.0), vc)
    views = _mk_views()
    for wid in views:
        mon.observe_heartbeat(wid, vc())
    assert mon.tick(views, 0) == []                     # everyone fresh
    vc.advance(0.5)
    for wid in ("p0", "d1"):
        mon.observe_heartbeat(wid, vc())
    vc.advance(0.7)                                     # d0 now stale (1.2s)
    acts = mon.tick(views, 0)
    assert acts == [MarkDead("d0")]                     # death detected once
    assert mon.tick(views, 0) == []                     # not re-reported
    for wid in ("p0", "d1"):                            # survivors stay fresh
        mon.observe_heartbeat(wid, vc())
    vc.advance(0.5)                                     # backoff elapses
    acts = mon.tick(views, 0)
    assert acts == [Respawn("decode")]


def test_monitor_restart_budget_stops_respawns():
    vc = VirtualClock()
    mon = ClusterMonitor(ControlConfig(heartbeat_timeout=0.1,
                                       max_restarts=1, backoff_base=0.0,
                                       scale_up_watermark=1e9,
                                       scale_down_watermark=-1.0), vc)
    views = _mk_views()
    vc.advance(1.0)
    acts = mon.tick(views, 0)                           # all 3 time out
    # one respawn per role from the budget; the second decode death gets
    # nothing (budget 1), so the fleet stops flapping
    assert sum(isinstance(a, MarkDead) for a in acts) == 3
    assert sum(isinstance(a, Respawn) for a in acts) == 2


def test_monitor_elastic_watermarks():
    vc = VirtualClock()
    mon = ClusterMonitor(ControlConfig(heartbeat_timeout=1e9,
                                       scale_up_watermark=3.0,
                                       scale_down_watermark=0.5,
                                       watermark_ewma=1.0,
                                       scale_cooldown=1.0, min_decode=1,
                                       max_decode=4), vc)
    views = _mk_views()
    acts = mon.tick(views, 10)                          # heavy queue
    assert acts == [SpawnDecode()]
    assert mon.tick(views, 10) == []                    # cooldown holds
    vc.advance(1.5)
    assert mon.tick(views, 10) == [SpawnDecode()]
    vc.advance(1.5)
    acts = mon.tick(views, 0)                           # idle fleet drains
    assert acts == [DrainWorker("d1", reason="scale_down")]
    assert len(mon.scale_events) == 3


def test_monitor_straggler_drains_slow_decode_worker():
    vc = VirtualClock()
    mon = ClusterMonitor(
        ControlConfig(heartbeat_timeout=1e9, scale_up_watermark=1e9,
                      scale_down_watermark=-1.0,
                      straggler=StragglerConfig(window=16, slow_factor=1.5,
                                                eject_after=3,
                                                min_history=4)), vc)
    views = _mk_views()
    t = {"p0": 0.0, "d0": 0.0, "d1": 0.0}
    acts = []
    for _ in range(10):
        for wid, dt in (("p0", 0.1), ("d0", 0.1), ("d1", 0.5)):
            t[wid] += dt                                # d1 beats 5x slower
            mon.observe_heartbeat(wid, t[wid])
        acts = mon.tick(views, 0)
        if acts:
            break
    assert acts == [DrainWorker("d1", reason="straggler")]


# ---------------------------------------------------------------------------
# engine + cluster tiers (one module-scoped model)
# ---------------------------------------------------------------------------

PAGE = 8


@pytest.fixture(scope="module")
def model():
    cfg = registry.get_config("internlm2-20b", ffn="fff").reduced()
    params = lm.init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _ecfg(role, **kw):
    defaults = dict(num_slots=2 if role == "prefill" else 4, max_len=48,
                    max_prompt_len=16, page_size=PAGE, seed=0)
    defaults.update(kw)
    return EngineConfig(**defaults)


def _requests(n, seed=7, max_new=6, lo=4, hi=17):
    rng = np.random.default_rng(seed)
    return [Request(rid=i, prompt=rng.integers(1, 256,
                                               int(rng.integers(lo, hi))),
                    max_new_tokens=max_new) for i in range(n)]


def _cluster(cfg, params, *, n_prefill=1, n_decode=2, control=None,
             failure_hooks=None, engine_kw=None):
    vc = VirtualClock()
    engines = {}

    def factory(wid, role):
        eng = ContinuousBatchingEngine(params, cfg,
                                       _ecfg(role, **(engine_kw or {})),
                                       clock=vc)
        engines[wid] = eng
        hook = (failure_hooks or {}).get(wid)
        return ClusterWorker(wid, role, eng, failure_hook=hook)

    bus = LocalBus(factory, clock=vc)
    ctrl = control or ControlConfig(heartbeat_timeout=0.05, max_restarts=3,
                                    scale_up_watermark=1e9,
                                    scale_down_watermark=-1.0)
    router = Router(bus, ClusterConfig(n_prefill=n_prefill,
                                       n_decode=n_decode, page_size=PAGE,
                                       control=ctrl), clock=vc)
    router.start()
    return router, engines, vc


def test_handoff_roundtrip_matches_local_decode(model):
    """extract → install between two engines: the receiver finishes the
    request token-for-token as the engine that keeps the slot."""
    cfg, params = model
    reqs = _requests(2, seed=3)
    mirror = [Request(rid=r.rid, prompt=r.prompt,
                      max_new_tokens=r.max_new_tokens) for r in reqs]

    src = ContinuousBatchingEngine(params, cfg, _ecfg("prefill"))
    dst = ContinuousBatchingEngine(params, cfg, _ecfg("decode"))
    ref = ContinuousBatchingEngine(params, cfg, _ecfg("decode"))
    want, _ = ref.run(mirror)

    for r in reqs:
        src.submit(r)
    src._evict_finished()
    src._admit()                           # monolithic: prefill happens here
    handoffs = []
    for i, st in enumerate(src.slots):
        if st is not None and st.tokens and not st.done:
            h = handoff_lib.extract(src, i)
            assert h.n_pages == -(-len(st.request.prompt) // PAGE)
            assert h.nbytes > 0
            handoffs.append(h)
            src.release_slot(i, record_result=False)
    assert len(handoffs) == 2
    assert all(s is None for s in src.slots)            # fully released
    # only the prefix index still pins pages (published-prefix retention)
    src.prefix.reclaim(src.pool.num_pages)
    assert src.pool.pages_free == src.pool.num_pages

    for h in handoffs:
        assert handoff_lib.install(dst, h) is not None
    while dst.has_work():
        dst.step()
    got = sorted(dst.results, key=lambda r: r.rid)
    assert [list(g.tokens) for g in got] == [list(w.tokens) for w in want]
    assert dst.compiled_shapes()["install"] == 1        # one jit, reused


def test_cluster_parity_and_compile_contract(model):
    """LocalBus fleet output is byte-identical to one engine serving the
    same batch; each worker's compile ledger stays at single-engine
    counts for its role only."""
    cfg, params = model
    reqs = _requests(8, seed=11)
    router, engines, _ = _cluster(cfg, params)
    res = router.run(reqs, max_ticks=4000)

    ref = ContinuousBatchingEngine(params, cfg, _ecfg("decode"))
    want, _ = ref.run([Request(rid=r.rid, prompt=r.prompt,
                               max_new_tokens=r.max_new_tokens)
                       for r in reqs])
    assert [(r.rid, list(r.tokens), r.finish_reason) for r in res] == \
        [(w.rid, list(w.tokens), w.finish_reason) for w in want]

    cm = router.cluster_metrics()
    assert cm["worker_restarts"] == 0
    assert cm["replayed_requests"] == 0
    assert cm["handoff_bytes"] > 0
    for wid, eng in engines.items():
        shapes = eng.compiled_shapes()
        if wid.startswith("p"):
            assert shapes["admit"] == 1 and shapes["decode"] == 0
        else:
            assert shapes["decode"] == 1 and shapes["admit"] == 0
            assert shapes.get("install", 0) == 1
    m = router.metrics()
    assert m.n_requests == 8 and m.ttft.mean_ms > 0


def test_cluster_kill_decode_worker_exact_replay(model):
    """SIGKILL-equivalent mid-stream: every request still completes with
    output exactly equal to lm.generate — zero lost or duplicated
    tokens — and exactly one respawn happens."""
    cfg, params = model
    reqs = _requests(8, seed=7, max_new=8)
    router, engines, _ = _cluster(
        cfg, params, failure_hooks={"d0": lambda n: n == 6},
        engine_kw=dict(prefill_chunk=8, prefill_budget=2))
    res = router.run(reqs, max_ticks=6000)
    cm = router.cluster_metrics()
    assert len(res) == len(reqs)                        # zero lost
    assert cm["worker_restarts"] == 1
    assert cm["replayed_requests"] >= 1
    assert cm["duplicate_results"] == 0                 # zero duplicated
    for r in res:
        prompt = np.asarray(r.prompt)[None, :]
        want = lm.generate(params, cfg, prompt, steps=len(r.tokens),
                           max_len=48)[0, prompt.shape[1]:]
        assert list(r.tokens) == list(np.asarray(want))
    # the killed worker is gone; its replacement carries a fresh wid
    assert "d0" not in router.views and "d2" in router.views
    # chunked prefill keeps the slab ledger at 1 on the prefill worker
    assert engines["p0"].compiled_shapes()["prefill_chunk"] == 1


def test_cluster_drain_blocks_new_admissions(model):
    """Drain: in-flight work completes, queued work is never admitted."""
    cfg, params = model
    reqs = _requests(6, seed=5)
    router, engines, _ = _cluster(cfg, params)
    for r in reqs[:2]:
        router.submit(r)
    for _ in range(3):                                  # get them in flight
        router.step()
    assert sum(1 for s in router.states.values()
               if s.phase != "queued") == 2
    router.drain_all()
    for r in reqs[2:]:
        router.submit(r)
    for _ in range(200):
        router.step()
        if all(router.states[r.rid].phase == "done" for r in reqs[:2]):
            break
    assert all(router.states[r.rid].phase == "done" for r in reqs[:2])
    assert all(router.states[r.rid].phase == "queued" for r in reqs[2:])
    # drained workers have left the fleet after their goodbye handshake
    for _ in range(20):
        router.step()
    assert not router.views


def test_cluster_elastic_scale_up_then_down(model):
    """Queue pressure spawns a decode worker; the drained idle fleet
    scales back down."""
    cfg, params = model
    ctrl = ControlConfig(heartbeat_timeout=1e9, scale_up_watermark=3.0,
                         scale_down_watermark=0.5, watermark_ewma=1.0,
                         scale_cooldown=0.02, min_decode=1, max_decode=2)
    router, engines, _ = _cluster(cfg, params, n_decode=1, control=ctrl)
    res = router.run(_requests(10, seed=9), max_ticks=6000)
    assert len(res) == 10
    actions = [e["action"] for e in router.cluster_metrics()["scale_events"]]
    assert "scale_up" in actions
    assert len([w for w in engines if w.startswith("d")]) == 2
    # after the work drains, the idle fleet sheds the extra worker
    for _ in range(400):
        router.step()
        if "scale_down" in [e["action"] for e in
                            router.cluster_metrics()["scale_events"]]:
            break
    assert "scale_down" in [e["action"] for e in
                            router.cluster_metrics()["scale_events"]]


def test_cluster_prefix_affinity_routes_to_publisher(model):
    """Prompts sharing a system prefix land on the prefill worker that
    published it, where admission allocates shared pages."""
    cfg, params = model
    rng = np.random.default_rng(13)
    system = rng.integers(1, 256, PAGE)                 # one full page
    reqs = [Request(rid=i,
                    prompt=np.concatenate([system,
                                           rng.integers(1, 256, 4)]),
                    max_new_tokens=4) for i in range(6)]
    router, engines, _ = _cluster(cfg, params, n_prefill=2)
    res = router.run(reqs, max_ticks=4000)
    assert len(res) == 6
    assert len(router.prefix_map) > 0
    hits = sum(e.n_prefix_hit_tokens for w, e in engines.items()
               if w.startswith("p"))
    assert hits > 0                                     # pages were shared
