"""Admission schedulers for the continuous-batching engine (DESIGN.md §9).

``select(waiting, n_free, view)`` picks which waiting requests to admit into
free cache slots this step.  The engine passes a ``SchedulerView`` of its
live FFF telemetry; schedulers are pure host-side policy (numpy only) so new
ones need no jax knowledge.

Built-ins:

* ``fcfs`` — strict arrival order.
* ``leaf_aware`` — FFF-composition-aware: grouped/grouped_ep serving drops
  (or dense-repairs) tokens past per-leaf capacity, and which tokens share a
  microbatch decides that overflow (Fast Feedforward Networks, 2023; skewed
  leaf load is the failure mode the load-balancing follow-up targets).  The
  scheduler greedily admits, from a bounded look-ahead window, the candidate
  whose predicted leaf footprint (its ``leaf_hint`` prior, or live EWMA
  occupancy once measured) minimizes predicted capacity overflow of the
  composed batch.  A hold counter bounds how often the queue head can be
  bypassed, so no request starves.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.serving.request import Request


@dataclasses.dataclass
class SchedulerView:
    """What the engine exposes to admission policy each step.

    occupancy: (num_slots, E) float64 — per-slot EWMA leaf-footprint
               fractions (rows of active slots sum to ~1; free rows are 0)
    active:    (num_slots,) bool
    num_leaves: E of the telemetry (0 = no FFF telemetry; leaf_aware then
               degrades to FCFS)
    capacity_factor: the serving capacity factor the dispatch runs with
    num_slots: total cache slots (the decode dispatch batch is always this
               size — free slots decode a dummy token)
    dispatch_shards: how many ways the dispatch splits the token axis —
               the data-shard count G for local grouped dispatch, G·M for
               grouped_ep (capacity is per *source shard* there, DESIGN.md
               §5); 1 unmeshed
    prefilling: (num_slots,) bool — slots admitted but still mid-chunked-
               prefill (all False under monolithic prefill); the
               ``max_prefilling`` admission cap counts these
    """
    occupancy: np.ndarray
    active: np.ndarray
    num_leaves: int
    capacity_factor: Optional[float]     # None = exact backend, no bound
    num_slots: int
    dispatch_shards: int = 1
    prefilling: Optional[np.ndarray] = None

    def leaf_capacity(self) -> float:
        """Whole-batch per-leaf slot capacity of one decode dispatch: the
        dispatch layer's own per-(shard, leaf) law (``dispatch.ep_capacity``,
        shared by ``grouped_leaf_apply``) times the shard count — with
        tokens split roughly evenly, the per-shard floor multiplies.
        Infinite for exact (capacity-unbounded) backends: the leaf_aware
        objective then reduces to its max-load balancing term."""
        if self.num_leaves <= 0 or self.capacity_factor is None:
            return float("inf")
        from repro.distributed import dispatch as dispatch_lib
        shards = max(self.dispatch_shards, 1)
        per_shard = -(-self.num_slots // shards)             # ceil
        return float(dispatch_lib.ep_capacity(
            per_shard, self.num_leaves, self.capacity_factor) * shards)


class Scheduler:
    """Admission-policy base class.

    Subclasses implement ``select``; registering the class in ``SCHEDULERS``
    (or shadowing a built-in name) makes it reachable from
    ``EngineConfig.scheduler`` and ``serve.py --scheduler``.

    ``max_prefilling`` is the TTFT-vs-decode-p99 knob for chunked prefill
    (DESIGN.md §9): it caps how many slots may sit in the prefilling state
    at once.  Admitting more concurrent prefills fills the shared
    ``(num_slots, chunk_len)`` slab — better amortization and TTFT — but
    every in-flight prefill keeps the per-step chunk work at its budgeted
    maximum for longer, which is what decode p99 pays.  0 = uncapped.  The
    knob is inert under monolithic prefill (admission and prefill complete
    in the same step, so nothing is ever *in* the prefilling state)."""
    name = "base"

    def __init__(self, max_prefilling: int = 0):
        self.max_prefilling = max_prefilling

    def admission_cap(self, view: SchedulerView) -> int:
        """How many NEW requests may be admitted this step, given how many
        slots are already mid-prefill.  The engine intersects this with its
        free-slot count and ``max_prefills_per_step``."""
        if self.max_prefilling <= 0:
            return view.num_slots
        busy = (int(view.prefilling.sum()) if view.prefilling is not None
                else 0)
        return max(self.max_prefilling - busy, 0)

    def select(self, waiting: Sequence[Request], n_free: int,
               view: SchedulerView) -> List[Request]:
        """Pick <= n_free requests from ``waiting`` to admit this step.

        ``waiting`` is in arrival order; the returned list's order is the
        admission order (earlier = lower slot index).  Must not mutate
        ``waiting`` or the requests.  Called once per engine step while any
        slot is free and the queue is non-empty."""
        raise NotImplementedError


class FCFSScheduler(Scheduler):
    """First-come-first-served: admit in arrival order."""
    name = "fcfs"

    def select(self, waiting, n_free, view):
        return list(waiting[:n_free])


class LeafAwareScheduler(Scheduler):
    """Greedy leaf-load-balancing admission (module docstring).

    window:   how deep into the queue the policy may look (bounds both
              unfairness and per-step host cost)
    max_hold: after this many bypasses the queue head is force-admitted
              (the no-starvation bound: head waits at most ``max_hold``
              admission rounds beyond FCFS)
    """
    name = "leaf_aware"

    def __init__(self, window: int = 16, max_hold: int = 8,
                 max_prefilling: int = 0):
        super().__init__(max_prefilling=max_prefilling)
        self.window = window
        self.max_hold = max_hold
        self._holds: Dict[int, int] = {}

    def _footprint(self, req: Request, E: int) -> np.ndarray:
        h = req.leaf_hint
        if h is None or h.size != E or h.sum() <= 0:
            return np.full((E,), 1.0 / E)
        return h / h.sum()

    @staticmethod
    def _overflow(load: np.ndarray, cap: float) -> float:
        return float(np.maximum(load - cap, 0.0).sum())

    def select(self, waiting, n_free, view):
        if view.num_leaves <= 0 or not waiting:
            return list(waiting[:n_free])
        E = view.num_leaves
        cap = view.leaf_capacity()
        # current per-leaf load of the composed decode batch, in routed
        # slots per step (each active slot ≈ its footprint row)
        load = view.occupancy[view.active].sum(axis=0) if view.active.any() \
            else np.zeros((E,))
        pool = list(waiting[: max(self.window, n_free)])
        chosen: List[Request] = []
        for _ in range(min(n_free, len(waiting))):
            if not pool:
                break
            head = pool[0]
            if self._holds.get(head.rid, 0) >= self.max_hold:
                pick = 0                                  # starvation guard
            else:
                # lexicographic: predicted overflow, then max-leaf load
                # (balance below the capacity threshold too — headroom),
                # then arrival order (stable/deterministic)
                costs = []
                for i, r in enumerate(pool):
                    nl = load + self._footprint(r, E)
                    costs.append((self._overflow(nl, cap), float(nl.max()), i))
                pick = min(costs)[2]
            req = pool.pop(pick)
            load = load + self._footprint(req, E)
            chosen.append(req)
        chosen_ids = {r.rid for r in chosen}
        # bump hold counters for bypassed waiters ahead of any chosen one
        for r in waiting:
            if r.rid in chosen_ids:
                break
            self._holds[r.rid] = self._holds.get(r.rid, 0) + (1 if chosen
                                                              else 0)
        for r in chosen:
            self._holds.pop(r.rid, None)
        return chosen


SCHEDULERS = {
    "fcfs": FCFSScheduler,
    "leaf_aware": LeafAwareScheduler,
}


def make_scheduler(name: str, **kw) -> Scheduler:
    """Instantiate a registered admission scheduler by name.

    ``kw`` is forwarded to the scheduler's constructor (``EngineConfig.
    scheduler_kw`` arrives here): ``fcfs`` takes ``max_prefilling``;
    ``leaf_aware`` additionally takes ``window`` and ``max_hold``.  Unknown
    names raise KeyError listing the registry."""
    try:
        cls = SCHEDULERS[name]
    except KeyError:
        raise KeyError(f"unknown scheduler {name!r}; have "
                       f"{sorted(SCHEDULERS)}") from None
    return cls(**kw)
