"""Jitted wrappers: sorted-dispatch scatter/gather plumbing and the complete
TPU-native FFF inference path (route -> sort -> grouped GEMMs -> unsort).

This is the production serving path for FFF layers (DESIGN.md §3).  The
capacity-padded layout turns the ragged problem into a statically-shaped one;
tokens overflowing a leaf's capacity fall back to the exact gather path
(overflow-to-dense, DESIGN.md §8) so results are always exact.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro import utils
from repro.core import fff as fff_lib
from repro.core import routing as routing_lib
from repro.kernels import common
from repro.kernels.leaf_gemm import kernel as K
from repro.kernels.tree_router import ops as router_ops


class GroupedLayout(NamedTuple):
    x_grouped: jax.Array      # (E, C, D) capacity-padded sorted tokens
    leaf_idx: jax.Array       # (B,) routed leaf per original token
    slot: jax.Array           # (B,) slot within the leaf's buffer;
                              # == capacity marks a dropped token — always
                              # mask reads with `kept`, never index raw
    kept: jax.Array           # (B,) bool: token fit under capacity
    group_sizes: jax.Array    # (E,) clipped to capacity


def scatter_to_groups(x: jax.Array, leaf_idx: jax.Array, num_leaves: int,
                      capacity: int) -> GroupedLayout:
    """x (B, D) -> capacity-padded per-leaf buffers.  O(B log B) sort +
    O(B) scatter (no (B, E) cumsum — see core/routing.group_slots)."""
    B, D = x.shape
    slot = routing_lib.group_slots(leaf_idx, num_leaves)
    kept = slot < capacity
    # dropped tokens get the uniform out-of-bounds flat index E*C so
    # mode="drop" discards their write — a per-leaf sentinel like
    # leaf*C + capacity would land in the NEXT leaf's slot 0, and clamping
    # to capacity-1 would nondeterministically clobber the kept token there
    slot_c = jnp.where(kept, slot, capacity)
    flat_idx = jnp.where(kept, leaf_idx * capacity + slot,
                         num_leaves * capacity)
    xg = jnp.zeros((num_leaves * capacity, D), x.dtype)
    xg = xg.at[flat_idx].set(x, mode="drop")
    sizes = jnp.minimum(jnp.bincount(leaf_idx, length=num_leaves), capacity)
    return GroupedLayout(xg.reshape(num_leaves, capacity, D), leaf_idx,
                         slot_c, kept, sizes.astype(jnp.int32))


def gather_from_groups(y_grouped: jax.Array, layout: GroupedLayout
                       ) -> jax.Array:
    """(E, C, O) -> per-token outputs (B, O); overflowed tokens get zeros."""
    E, C, O = y_grouped.shape
    flat = y_grouped.reshape(E * C, O)
    # same uniform out-of-bounds sentinel as the scatter: dropped tokens read
    # the clipped last row, then the kept mask zeroes them — never a
    # neighbouring leaf's slot
    idx = jnp.where(layout.kept, layout.leaf_idx * C + layout.slot, E * C)
    y = jnp.take(flat, idx, axis=0)
    return jnp.where(layout.kept[:, None], y, 0.0)


@partial(jax.jit, static_argnames=("activation", "capacity_factor",
                                   "interpret", "block_c", "block_h",
                                   "block_k"))
def fff_leaf_mlp(x: jax.Array, leaf_idx: jax.Array, params: dict, *,
                 activation: str = "gelu", capacity_factor: float = 2.0,
                 interpret: Optional[bool] = None, block_c: int = 128,
                 block_h: int = 512, block_k: int = 512) -> jax.Array:
    """Evaluate each token's routed leaf MLP via the grouped kernels.

    params: single-tree leaf weights — MLP: {leaf_w1 (E,D,l), leaf_w2 (E,l,O)}
    or SwiGLU: {leaf_wg, leaf_wu, leaf_wd}.  Returns (B, O).
    """
    if interpret is None:
        interpret = common.default_interpret()
    if "leaf_b1" in params or "leaf_b2" in params:
        # biases break the zero-row padding invariant; transformer FFF sites
        # are bias-free (LLM convention).  Small biased MLPs use the core path.
        raise ValueError("kernel path requires bias-free leaves")
    B, D = x.shape
    swiglu = "leaf_wg" in params
    E = (params["leaf_wg"] if swiglu else params["leaf_w1"]).shape[0]
    capacity = max(block_c,
                   utils.round_up(int(capacity_factor * utils.cdiv(B, E)),
                                  block_c))
    layout = scatter_to_groups(x, leaf_idx, E, capacity)
    kw = dict(block_c=block_c, block_h=block_h, block_k=block_k,
              interpret=interpret)
    if swiglu:
        h = K.grouped_matmul_dual(layout.x_grouped, params["leaf_wg"],
                                  params["leaf_wu"], layout.group_sizes, **kw)
        yg = K.grouped_matmul(h, params["leaf_wd"], layout.group_sizes,
                              act="none", **kw)
    else:
        act = "gelu" if activation == "gelu" else activation
        h = K.grouped_matmul(layout.x_grouped, params["leaf_w1"],
                             layout.group_sizes, act=act, **kw)
        yg = K.grouped_matmul(h, params["leaf_w2"], layout.group_sizes,
                              act="none", **kw)
    y = gather_from_groups(yg, layout)

    # overflow-to-dense fallback: exact gather path for dropped tokens
    any_dropped = jnp.logical_not(layout.kept.all())

    def fallback(y):
        dense = _exact_gather_leaf(x, leaf_idx, params, swiglu, activation)
        return jnp.where(layout.kept[:, None], y, dense)

    return jax.lax.cond(any_dropped, fallback, lambda y: y, y)


def _exact_gather_leaf(x, leaf_idx, params, swiglu, activation):
    if swiglu:
        wg = jnp.take(params["leaf_wg"], leaf_idx, axis=0)
        wu = jnp.take(params["leaf_wu"], leaf_idx, axis=0)
        wd = jnp.take(params["leaf_wd"], leaf_idx, axis=0)
        g = jnp.einsum("bd,bdh->bh", x, wg, preferred_element_type=jnp.float32)
        u = jnp.einsum("bd,bdh->bh", x, wu, preferred_element_type=jnp.float32)
        h = jax.nn.silu(g) * u
        return jnp.einsum("bh,bho->bo", h, wd,
                          preferred_element_type=jnp.float32).astype(x.dtype)
    w1 = jnp.take(params["leaf_w1"], leaf_idx, axis=0)
    w2 = jnp.take(params["leaf_w2"], leaf_idx, axis=0)
    h = jnp.einsum("bd,bdh->bh", x, w1, preferred_element_type=jnp.float32)
    h = utils.get_activation(activation)(h)
    return jnp.einsum("bh,bho->bo", h, w2,
                      preferred_element_type=jnp.float32).astype(x.dtype)


def fff_infer(x: jax.Array, params: dict, cfg: fff_lib.FFFConfig, *,
              capacity_factor: float = 2.0,
              interpret: Optional[bool] = None,
              dense_levels: Optional[int] = None,
              return_leaf_idx: bool = False):
    """Full TPU-native FORWARD_I for a (possibly multi-tree) FFF layer:
    kernel-routed descent + grouped leaf GEMMs.  x (B, D) -> (B, dim_out),
    or ``(y, leaf_idx (B, trees))`` with ``return_leaf_idx=True``."""
    if cfg.node_width != 1:
        raise ValueError("kernel path supports node_width == 1 (paper default)")
    out = None
    idxs = []
    for t in range(cfg.trees):
        # collapse the <D, 1, 1> node net to a hyperplane (w2 * w1, w2*b1+b2)
        nw = params["node_w1"][t, :, :, 0] * params["node_w2"][t, :, 0:1]
        nb = params["node_b1"][t, :, 0] * params["node_w2"][t, :, 0] \
            + params["node_b2"][t]
        leaf_idx = router_ops.route(x, nw, nb, depth=cfg.depth,
                                    dense_levels=dense_levels,
                                    interpret=interpret)
        tree_leaves = {k: v[t] for k, v in params.items()
                       if k.startswith("leaf_")}
        y = fff_leaf_mlp(x, leaf_idx, tree_leaves,
                         activation=cfg.activation if cfg.activation != "swiglu"
                         else "swiglu",
                         capacity_factor=capacity_factor, interpret=interpret)
        out = y if out is None else out + y
        idxs.append(leaf_idx)
    if return_leaf_idx:
        return out, jnp.stack(idxs, axis=1)
    return out
