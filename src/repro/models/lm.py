"""Causal LM wrapper: init / train loss / prefill / decode for every assigned
architecture, including encoder-decoder (whisper) and stub-frontend (vlm,
audio) variants.

The three entry points lowered by the dry-run:
  * ``train_step``  — loss + grads + optimizer update (shape: train_4k)
  * ``prefill``     — build KV/state caches over a prefix (prefill_32k)
  * ``decode_step`` — one new token against the caches (decode_32k, long_500k)
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed import act
from repro.nn import embeddings, norms, rope as rope_lib, transformer

Params = dict


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init(key: jax.Array, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 6)
    p: Params = {
        "embed": embeddings.embed_init(ks[0], cfg.vocab_size, cfg.d_model,
                                       tie=cfg.tie_embeddings,
                                       param_dtype=cfg.param_dtype),
        "stack": transformer.stack_init(ks[1], cfg, causal=True),
        "final_norm": norms.norm_init(cfg.norm, cfg.d_model, cfg.param_dtype),
    }
    if cfg.pos_emb == "learned":
        p["pos"] = embeddings.learned_pos_init(ks[2], cfg.max_seq_len,
                                               cfg.d_model, cfg.param_dtype)
    if cfg.frontend != "none" and cfg.encoder is None:
        p["frontend"] = embeddings.frontend_init(ks[3], cfg.frontend,
                                                 cfg.d_model, cfg.param_dtype)
    if cfg.encoder is not None:
        p["enc_frontend"] = embeddings.frontend_init(ks[3], cfg.frontend,
                                                     cfg.d_model, cfg.param_dtype)
        p["enc_stack"] = transformer.stack_init(
            ks[4], cfg, causal=False, period=cfg.encoder.period,
            n_layers=cfg.encoder.n_layers)
        p["enc_norm"] = norms.norm_init(cfg.norm, cfg.d_model, cfg.param_dtype)
    return p


# ---------------------------------------------------------------------------
# pieces
# ---------------------------------------------------------------------------

def encode(params: Params, cfg: ModelConfig, enc_embeds: jax.Array
           ) -> jax.Array:
    """Encoder over precomputed frame/patch embeddings (B, S_enc, D)."""
    x = embeddings.frontend(params["enc_frontend"], enc_embeds, cfg.accum_dtype)
    x = x + rope_lib.sinusoidal_embedding(x.shape[1], cfg.d_model).astype(x.dtype)
    x = act.shard(x, act.ACT_BSD)
    x, _, _ = transformer.stack_forward(
        params["enc_stack"], cfg, x, mode="train", causal=False,
        period=cfg.encoder.period)
    return norms.norm_apply(cfg.norm, params["enc_norm"], x)


def _embed_inputs(params: Params, cfg: ModelConfig, batch: dict,
                  pos_offset: int | jax.Array = 0) -> jax.Array:
    if cfg.frontend != "none" and cfg.encoder is None and "embeds" in batch:
        x = embeddings.frontend(params["frontend"], batch["embeds"],
                                cfg.accum_dtype)
    else:
        x = embeddings.embed(params["embed"], batch["tokens"], cfg.accum_dtype)
    if cfg.pos_emb == "learned":
        x = embeddings.learned_pos(params["pos"], x, pos_offset)
    elif cfg.pos_emb == "sinusoidal":
        x = x + rope_lib.sinusoidal_embedding(
            x.shape[1] + 0, cfg.d_model).astype(x.dtype)
    return act.shard(x, act.ACT_BSD)


def _head(params: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    x = norms.norm_apply(cfg.norm, params["final_norm"], x)
    lg = embeddings.logits(params["embed"], x)
    return act.shard(lg, act.LOGITS_BSV)


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  ignore_index: int = -1) -> tuple[jax.Array, jax.Array]:
    """Mean CE over valid positions; returns (loss, accuracy)."""
    valid = labels != ignore_index
    safe = jnp.where(valid, labels, 0)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
    denom = jnp.maximum(valid.sum(), 1)
    loss = -(ll * valid).sum() / denom
    acc = ((logits.argmax(-1) == labels) & valid).sum() / denom
    return loss, acc


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------

def loss_fn(params: Params, cfg: ModelConfig, batch: dict,
            rng: Optional[jax.Array] = None) -> tuple[jax.Array, dict]:
    """Training loss: CE + hardening (FFF) + load-balancing (FFF leaf usage,
    DESIGN.md §14) + balancing (MoE) aux terms."""
    enc_out = None
    if cfg.encoder is not None:
        enc_out = encode(params, cfg, batch["enc_embeds"])
    x = _embed_inputs(params, cfg, batch)
    x, _, aux = transformer.stack_forward(params["stack"], cfg, x,
                                          mode="train", rng=rng,
                                          enc_out=enc_out)
    logits = _head(params, cfg, x)
    ce, acc = cross_entropy(logits, batch["labels"])
    loss = ce + aux["hardening"] + aux["moe_aux"] + aux["balance"]
    metrics = {"loss": loss, "ce": ce, "accuracy": acc,
               "hardening": aux["hardening"], "moe_aux": aux["moe_aux"],
               "balance": aux["balance"]}
    return loss, metrics


def init_caches(cfg: ModelConfig, batch: int, max_len: int,
                dtype=None, *, page_size: int = 0, num_pages: int = 0,
                prealloc: bool = True) -> list[dict]:
    enc_len = cfg.encoder.seq_len if cfg.encoder is not None else 0
    return transformer.init_caches(cfg, batch, max_len, enc_len=enc_len,
                                   dtype=dtype, page_size=page_size,
                                   num_pages=num_pages, prealloc=prealloc)


def prefill(params: Params, cfg: ModelConfig, batch: dict,
            caches: list[dict]) -> tuple[jax.Array, list[dict]]:
    """Run the prefix, fill caches, return last-position logits (B, V)."""
    enc_out = None
    if cfg.encoder is not None:
        enc_out = encode(params, cfg, batch["enc_embeds"])
    x = _embed_inputs(params, cfg, batch)
    x, caches, _ = transformer.stack_forward(params["stack"], cfg, x,
                                             mode="prefill", caches=caches,
                                             enc_out=enc_out)
    logits = _head(params, cfg, x[:, -1:, :])
    return logits[:, 0], caches


def prefill_padded(params: Params, cfg: ModelConfig, batch: dict,
                   caches: list[dict], true_len: jax.Array
                   ) -> tuple[jax.Array, list[dict], Any]:
    """Prefill with RIGHT-PADDED prompts (the serving engine's fixed-shape
    contract, DESIGN.md §9).

    ``batch["tokens"]`` is (B, S_pad); ``true_len`` (B,) int32 gives each
    row's real prompt length.  Causal attention makes positions < true_len
    independent of the pad garbage to their right; the garbage K/V rows land
    in the cache but are masked out by setting each row's cache length to
    ``true_len`` (and are progressively overwritten by decode appends).
    Returns (logits at each row's last real token (B, V), caches, routing
    stats — None unless an ``api.collect_routing`` tap is active).

    Only valid for attention-mixer stacks: recurrent mixers (mamba/xlstm)
    fold pad tokens into their state.  Callers enforce that
    (``serving.engine`` checks the period at construction).
    """
    x = _embed_inputs(params, cfg, batch)
    x, caches, aux = transformer.stack_forward(params["stack"], cfg, x,
                                               mode="prefill", caches=caches)
    last = jnp.take_along_axis(
        x, (true_len - 1)[:, None, None].astype(jnp.int32), axis=1)  # (B,1,D)
    logits = _head(params, cfg, last)
    caches = set_cache_lengths(caches, true_len)
    return logits[:, 0], caches, aux.get("routing")


def decode_step(params: Params, cfg: ModelConfig, token: jax.Array,
                caches: list[dict], pos_offset: jax.Array | int = 0,
                *, write_mask: Optional[jax.Array] = None,
                token_valid: Optional[jax.Array] = None,
                with_stats: bool = False):
    """One serve step: token (B, 1) int32 -> logits (B, V), updated caches.

    ``pos_offset`` may be per-row (B,) for continuous batching (slots sit at
    different positions; only learned positional embeddings consume it — RoPE
    reads per-row positions off the KV cache lengths).  ``write_mask`` (B,)
    bool, optional: rows where it is False compute logits but neither write
    K/V nor advance their cache length — the engine decodes its full slot
    batch while some slots are mid-chunked-prefill (DESIGN.md §9).
    ``token_valid`` (B,) bool, optional: rows where it is False are phantom
    (free slots) — capacity-bounded FFF backends route them to the sentinel
    leaf so they never consume grouped-dispatch capacity or appear in
    routing telemetry; deliberately separate from ``write_mask`` so the
    fixed-shape KV-write contract is unaffected.  With ``with_stats=True``
    also returns the per-site routing-stats tuple from the
    ``api.collect_routing`` tap (None when no tap is active)."""
    x = _embed_inputs(params, cfg, {"tokens": token}, pos_offset=pos_offset)
    tv = token_valid[:, None] if token_valid is not None else None
    x, caches, aux = transformer.stack_forward(params["stack"], cfg, x,
                                               mode="decode", caches=caches,
                                               decode_mask=write_mask,
                                               token_valid=tv)
    logits = _head(params, cfg, x)
    if with_stats:
        return logits[:, 0], caches, aux.get("routing")
    return logits[:, 0], caches


# ---------------------------------------------------------------------------
# paged-cache surgery (continuous-batching serving, DESIGN.md §9/§11)
# ---------------------------------------------------------------------------

def set_cache_lengths(caches: list[dict], lengths: jax.Array) -> list[dict]:
    """Overwrite every attention cache's per-row filled length with
    ``lengths`` (B,) — the padded-prefill epilogue."""
    out = []
    for c in caches:
        c = dict(c)
        if "kv" in c:
            kv = c["kv"]
            c["kv"] = kv._replace(length=jnp.broadcast_to(
                lengths.astype(kv.length.dtype)[None], kv.length.shape))
        out.append(c)
    return out


def cache_admit(caches: list[dict], admit: jax.Array, tables: jax.Array,
                lengths: jax.Array, cow_src: jax.Array, cow_dst: jax.Array
                ) -> list[dict]:
    """Install admitted rows' page tables in ONE batched dispatch
    (DESIGN.md §11).

    ``admit`` (B,) bool marks rows being (re)admitted this step; their page
    tables are overwritten with ``tables`` (B, ppr) and their cache lengths
    with ``lengths`` (B,) — the shared-prefix boundary, so prefill resumes
    at the first novel token.  ``cow_src``/``cow_dst`` (B,) are page ids
    for the copy-on-write case (a prompt fully covered by shared pages must
    recompute its last token for first-token logits): the source page's K/V
    are copied into the row's private ``cow_dst`` page before the table
    swap.  Rows without a copy pass the ``num_pages`` sentinel as
    ``cow_dst`` (the scatter drops it).

    Eviction needs no dispatch at all: freeing pages is host-side refcount
    bookkeeping, and a freed row's stale device table is harmless because
    every decode/chunk write is masked to live rows."""
    out = []
    for c in caches:
        c = dict(c)
        kv = c["kv"]                       # leaves stacked (n_periods, ...)
        num_pages = kv.k.shape[1]
        src = jnp.minimum(cow_src, num_pages - 1)
        new_k = kv.k.at[:, cow_dst].set(kv.k[:, src], mode="drop")
        new_v = kv.v.at[:, cow_dst].set(kv.v[:, src], mode="drop")
        new_table = jnp.where(admit[None, :, None],
                              tables[None].astype(kv.table.dtype), kv.table)
        new_len = jnp.where(admit[None, :],
                            lengths[None].astype(kv.length.dtype), kv.length)
        c["kv"] = kv._replace(k=new_k, v=new_v, table=new_table,
                              length=new_len)
        out.append(c)
    return out


def cache_install(caches: list[dict], admit: jax.Array, tables: jax.Array,
                  lengths: jax.Array, pages: jax.Array,
                  k_rows: list[jax.Array], v_rows: list[jax.Array]
                  ) -> list[dict]:
    """Install a handed-off row — page CONTENTS plus table — in ONE batched
    dispatch (the cluster cache-handoff receive path, DESIGN.md §12).

    Same ``admit`` (B,) / ``tables`` (B, ppr) / ``lengths`` (B,) contract
    as ``cache_admit``, but the page K/V arrive over the wire instead of
    being computed here: ``pages`` (ppr,) int32 names the destination page
    ids in THIS pool (``num_pages`` sentinel for unused tail entries —
    their writes drop), and ``k_rows``/``v_rows`` align with ``caches``,
    each entry a ``(n_periods, ppr, page_size, K, hd)`` slab gathered from
    the SENDING worker's pool (``cluster/handoff.extract``; zero-padded
    past the shipped pages — fresh generation-room pages tolerate the
    overwrite, nothing reads past the installed length).

    One fixed compiled shape per engine config: the decode-worker analogue
    of the prefill side's ``admit`` dispatch."""
    out = []
    for c, kr, vr in zip(caches, k_rows, v_rows):
        c = dict(c)
        kv = c["kv"]                       # leaves stacked (n_periods, ...)
        new_k = kv.k.at[:, pages].set(kr.astype(kv.k.dtype), mode="drop")
        new_v = kv.v.at[:, pages].set(vr.astype(kv.v.dtype), mode="drop")
        new_table = jnp.where(admit[None, :, None],
                              tables[None].astype(kv.table.dtype), kv.table)
        new_len = jnp.where(admit[None, :],
                            lengths[None].astype(kv.length.dtype), kv.length)
        c["kv"] = kv._replace(k=new_k, v=new_v, table=new_table,
                              length=new_len)
        out.append(c)
    return out


def prefill_chunk(params: Params, cfg: ModelConfig, tokens: jax.Array,
                  valid_len: jax.Array, caches: list[dict],
                  pos_offset: jax.Array) -> tuple[jax.Array, list[dict], Any]:
    """Consume one chunk of prefill for every row of a pooled cache at once
    (chunked prefill, DESIGN.md §9).

    ``tokens`` is a fixed-shape (B, C) slab — B = num_slots, C = the engine's
    ``prefill_chunk`` — so ALL in-flight prefills advance in ONE dispatch
    that compiles exactly once.  ``valid_len`` (B,) int32 in [0, C] is each
    row's real token count this chunk (0 = the slot has no prefill work;
    its slab row is in-distribution filler).  ``pos_offset`` (B,) is each
    row's absolute start position — the number of prompt tokens already
    consumed — and must equal the row's current attention-cache length
    (the caller tracks both; they advance in lockstep).

    Each row's valid tokens are appended to its cache at
    ``pos_offset[b]..`` and attend causally to the row's full history;
    pad positions and inactive rows write nothing (``chunk_into_cache``
    drops their scatter indices) and their outputs are garbage the caller
    ignores.  Returns (logits (B, V) at each row's LAST VALID chunk
    position — the next-token logits for rows whose prompt completes this
    chunk — updated caches, routing stats).  Attention mixers only, like
    ``prefill_padded``."""
    x = _embed_inputs(params, cfg, {"tokens": tokens}, pos_offset=pos_offset)
    x, caches, aux = transformer.stack_forward(
        params["stack"], cfg, x, mode="chunk", caches=caches,
        chunk_valid=valid_len)
    last_idx = jnp.clip(valid_len - 1, 0)[:, None, None].astype(jnp.int32)
    last = jnp.take_along_axis(x, last_idx, axis=1)               # (B, 1, D)
    logits = _head(params, cfg, last)
    return logits[:, 0], caches, aux.get("routing")


def verify_chunk(params: Params, cfg: ModelConfig, tokens: jax.Array,
                 valid_len: jax.Array, caches: list[dict],
                 pos_offset: jax.Array) -> tuple[jax.Array, list[dict], Any]:
    """Speculative-decoding verify step (DESIGN.md §10): the same fixed-shape
    (B, C) slab dispatch as ``prefill_chunk``, but returning the target
    model's logits at EVERY slab position — ``logits[b, j]`` is the target's
    next-token distribution after consuming ``tokens[b, :j+1]``, exactly
    what host-side rejection sampling needs to accept/reject a draft run
    ``tokens[b] = [pending, d_1 .. d_k]``.

    K/V for all C positions (the pending token plus every draft token) are
    appended optimistically; the caller rolls rejected suffixes back with
    ``set_cache_lengths`` — stale rows beyond the new length are masked by
    length and overwritten by later appends, the same mechanism as
    ``prefill_padded``.  Rows with ``valid_len == 0`` (free slots) write
    nothing, and the chunk-mode validity mask keeps their phantom tokens out
    of FFF grouped-dispatch capacity.  Attention mixers only."""
    x = _embed_inputs(params, cfg, {"tokens": tokens}, pos_offset=pos_offset)
    x, caches, aux = transformer.stack_forward(
        params["stack"], cfg, x, mode="chunk", caches=caches,
        chunk_valid=valid_len)
    logits = _head(params, cfg, x)                              # (B, C, V)
    return logits, caches, aux.get("routing")


def generate(params: Params, cfg: ModelConfig, prompt: jax.Array,
             steps: int, max_len: int, rng: Optional[jax.Array] = None,
             temperature: float = 0.0,
             eos_id: Optional[int] = None, caches=None) -> jax.Array:
    """Greedy/temperature sampling loop (host-driven example path).

    With ``eos_id`` set, rows that emit it stop: their subsequent tokens are
    pinned to ``eos_id`` (pad), and the loop exits once every row has
    finished — so the result may have fewer than ``steps`` generated columns.
    ``caches`` substitutes a caller-built cache set (e.g. a preallocated
    *paged* one from ``init_caches(..., page_size=N)``) for the default
    contiguous allocation; it must be fresh (zero lengths) and sized
    ``(B, max_len)``.
    """
    B = prompt.shape[0]
    if caches is None:
        caches = init_caches(cfg, B, max_len)
    logits, caches = prefill(params, cfg, {"tokens": prompt}, caches)
    out = [prompt]
    tok = logits.argmax(-1)[:, None].astype(jnp.int32)
    done = jnp.zeros((B,), bool)
    for i in range(steps):
        out.append(tok)
        if eos_id is not None:
            done = done | (tok[:, 0] == eos_id)
            if bool(done.all()):
                break
        logits, caches = decode_step(params, cfg, tok, caches,
                                     pos_offset=prompt.shape[1] + i)
        if temperature > 0.0 and rng is not None:
            rng, sub = jax.random.split(rng)
            tok = jax.random.categorical(sub, logits / temperature)[:, None]
            tok = tok.astype(jnp.int32)
        else:
            tok = logits.argmax(-1)[:, None].astype(jnp.int32)
        if eos_id is not None:
            tok = jnp.where(done[:, None], jnp.int32(eos_id), tok)
    return jnp.concatenate(out, axis=1)
