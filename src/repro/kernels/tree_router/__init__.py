from repro.kernels.tree_router.ops import route, route_forest
from repro.kernels.tree_router.ref import tree_router_ref
