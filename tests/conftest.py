"""Test fixtures.  NOTE: no XLA_FLAGS here on purpose — smoke tests and
benchmarks must see the real single CPU device; only launch/dryrun.py forces
512 placeholder devices (and tests exercise it via a subprocess).

Also home of the shared dtype-keyed comparison-tolerance policy: every
kernel-vs-oracle assertion (tests/test_kernels.py, tests/test_kernel_diff.py)
routes through ``dtype_tol`` / ``assert_close`` so a tolerance change is one
edit, not an audit of scattered ad-hoc atol literals.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# (rtol, atol) by dtype and comparison kind:
#   kernel — one kernel vs its pure-jnp oracle, same op order modulo tiling
#   e2e    — a whole FFF forward vs the reference backend (router + two
#            matmul layers of fp32 accumulation drift compound)
# bf16 carries ~8 mantissa bits, so anything through a matmul is only good
# to ~0.4%; 5e-2 absorbs that plus accumulation-order noise.
_TOLS = {
    "kernel": {"float32": (1e-4, 1e-4), "bfloat16": (5e-2, 5e-2)},
    "e2e": {"float32": (1e-3, 1e-3), "bfloat16": (5e-2, 5e-2)},
}


def dtype_tol(dtype, kind: str = "kernel") -> tuple:
    """(rtol, atol) for comparing arrays of ``dtype`` under policy ``kind``."""
    name = jnp.dtype(dtype).name
    try:
        return _TOLS[kind][name]
    except KeyError:
        raise KeyError(f"no tolerance policy for kind={kind!r} "
                       f"dtype={name!r} (have {sorted(_TOLS)} x "
                       f"{sorted(_TOLS['kernel'])})") from None


def assert_close(got, want, dtype=None, kind: str = "kernel",
                 err_msg: str = ""):
    """allclose with the shared policy; compares in fp32 so bf16 inputs
    don't lose further precision inside numpy's subtraction."""
    got = jnp.asarray(got)
    rtol, atol = dtype_tol(got.dtype if dtype is None else dtype, kind)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(jnp.asarray(want), np.float32),
                               rtol=rtol, atol=atol, err_msg=err_msg)


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
