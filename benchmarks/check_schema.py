"""Schema gate for ``experiments/BENCH_*.json`` benchmark artifacts (the CI
``bench-smoke`` job; start of the perf trajectory ISSUE 5 names).

Each artifact self-identifies via its ``bench`` key; this checker asserts
the per-bench required top-level keys and — for benches that embed engine
runs — the ``EngineMetrics.as_dict()`` core fields inside every run record,
so a refactor that silently drops a dashboarded field fails CI instead of
producing hollow artifacts.

Usage:
  PYTHONPATH=src python -m benchmarks.check_schema experiments/BENCH_*.json
"""
from __future__ import annotations

import json
import sys

# the EngineMetrics.as_dict() core every embedded run must carry
# (docs/serving.md documents the schema field-by-field)
METRICS_KEYS = {
    "n_requests", "n_tokens", "elapsed_s", "n_steps", "throughput_tok_s",
    "ttft_ms", "per_token_ms", "e2e_ms", "decode_step_ms",
    "decode_interval_ms", "overflow_fraction_mean", "overflow_decode_mean",
    "hint_mismatches", "tenants",
    # paged KV cache / prefix sharing (DESIGN.md §11)
    "prefill_tokens", "prefix_hit_tokens", "cow_copies", "pages_in_use",
    "pages_free",
}
SUMMARY_KEYS = {"n", "mean_ms", "p50_ms", "p90_ms", "p99_ms", "max_ms"}

# bench name -> (required top-level keys, key holding the run list/map)
SCHEMAS = {
    "serving_load": ({"bench", "quick", "slots", "classes", "runs"}, "runs"),
    "serving_chunked": ({"bench", "quick", "slots", "chunk",
                         "decode_interval_p99_drop", "stall_bound_tokens",
                         "runs"}, "runs"),
    "serving_qos": ({"bench", "quick", "slots", "classes", "fairness",
                     "profile_convergence", "overflow_decode", "runs"},
                    "runs"),
    "serving_spec": ({"bench", "quick", "slots", "depth", "gen", "spec_k",
                      "classes", "speedup", "speedup_gate", "speedup_ok",
                      "overflow_ok", "runs"}, "runs"),
    "serving_paged": ({"bench", "quick", "slots", "page_size", "shared_len",
                       "gen", "prefill_ratio", "prefill_gate", "prefill_ok",
                       "ttft_ok", "parity_checked", "compile_ok",
                       "compiled_shapes", "runs"}, "runs"),
    "serving_cluster": ({"bench", "quick", "topology", "page_size", "gen",
                         "speedup", "speedup_gate", "speedup_ok", "kill_ok",
                         "lost_requests", "parity_checked", "worker_restarts",
                         "replayed_requests", "duplicate_results", "scale_ok",
                         "scale_events", "compile_ok", "compiled_shapes",
                         "runs"}, "runs"),
}


def check_artifact(path: str) -> list:
    """Return a list of problem strings (empty = artifact passes)."""
    problems = []
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"{path}: unreadable ({e})"]
    bench = doc.get("bench")
    if bench not in SCHEMAS:
        return [f"{path}: unknown/missing bench id {bench!r} "
                f"(known: {sorted(SCHEMAS)})"]
    required, runs_key = SCHEMAS[bench]
    missing = required - set(doc)
    if missing:
        problems.append(f"{path}: missing top-level keys {sorted(missing)}")
    runs = doc.get(runs_key, [])
    records = list(runs.values()) if isinstance(runs, dict) else list(runs)
    if not records:
        problems.append(f"{path}: empty {runs_key!r}")
    for i, rec in enumerate(records):
        gone = METRICS_KEYS - set(rec)
        if gone:
            problems.append(f"{path}: run[{i}] missing metric keys "
                            f"{sorted(gone)}")
            continue
        for k in ("ttft_ms", "decode_step_ms"):
            if set(rec[k]) != SUMMARY_KEYS:
                problems.append(f"{path}: run[{i}].{k} is not a latency "
                                f"summary (has {sorted(rec[k])})")
    return problems


def main(argv=None) -> int:
    paths = list(sys.argv[1:] if argv is None else argv)
    if not paths:
        print("usage: python -m benchmarks.check_schema BENCH_*.json",
              file=sys.stderr)
        return 2
    problems = []
    for p in paths:
        problems += check_artifact(p)
    for msg in problems:
        print(f"SCHEMA: {msg}", file=sys.stderr)
    if not problems:
        print(f"schema ok: {len(paths)} artifact(s) "
              f"({', '.join(paths)})")
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main())
