"""Paged KV cache + cross-request prefix sharing tests (DESIGN.md §11).

Four tiers:
* host-only allocator/index properties — ``serving/paging.py`` is pure
  Python, so the page-conservation invariants are checked over randomized
  admit/evict/publish/reclaim interleavings (property-style via hypothesis
  when installed, a seeded deterministic sweep otherwise);
* cache-level parity — a preallocated paged cache is bit-for-bit the
  contiguous layout (the degenerate-paging claim the engine's
  ``page_size=0`` mode rests on);
* engine tier — paged serving matches ``lm.generate`` exactly while
  actually sharing pages (``prefix_hit_tokens > 0``), refuses admission
  gracefully when the pool is exhausted, and keeps the compile contract;
* the RoutingProfileStore LRU cap (ISSUE 7 satellite).
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import lm
from repro.serving import ContinuousBatchingEngine, EngineConfig, Request
from repro.serving.paging import PagePool, PrefixIndex
from repro.serving.profiles import RoutingProfileStore

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # container has no
    HAVE_HYPOTHESIS = False                           # hypothesis; the
                                                      # seeded sweep below
                                                      # covers the property

# ---------------------------------------------------------------------------
# host-only tier: PagePool / PrefixIndex invariants
# ---------------------------------------------------------------------------


def _run_ops(ops, num_pages=16, page_size=4):
    """Interpret an op sequence against a PagePool + PrefixIndex while
    checking the conservation invariants after every step.

    Each op is ``(kind, a, b)`` with kind in 0..3:
      0 = admit: alloc ``1 + a % 4`` pages for slot ``b % 4`` (skipped if
          the slot is live), mapping the longest indexed prefix first
      1 = evict: decref slot ``b % 4``'s pages
      2 = publish: insert slot ``b % 4``'s prompt chunks into the index
      3 = reclaim: evict index entries until ``a % num_pages`` pages free
    """
    pool = PagePool(num_pages, page_size)
    index = PrefixIndex(pool)
    slots = {}                 # slot -> [tokens, pages, n_shared, published]
    next_tok = [0]

    def check():
        # conservation: every page is either free or referenced; refcounts
        # reconcile exactly with (live slot maps) + (index entries)
        refs = np.zeros(num_pages, np.int64)
        for _, pages, _, _ in slots.values():
            for p in pages:
                refs[p] += 1
        stack = [index._root]
        while stack:
            n = stack.pop()
            for c in n.children.values():
                stack.append(c)
                if c.page is not None:
                    refs[c.page] += 1
        for p in range(num_pages):
            assert pool.refcount(p) == refs[p], (p, pool.refcount(p), refs[p])
        assert pool.pages_free == int((refs == 0).sum())
        # write exclusivity: a page mapped by two live slots is never
        # writable by either — each holder either got it from match() (its
        # shared prefix, read-only by construction) or already published it
        # (prefill complete, the page is frozen)
        owners = {}
        for s, (_, pages, n_shared, published) in slots.items():
            for i, p in enumerate(pages):
                owners.setdefault(p, []).append(i < n_shared or published)
        for p, holders in owners.items():
            if len(holders) > 1:
                assert all(holders), f"page {p} multiply mapped yet writable"

    for kind, a, b in ops:
        kind, slot = kind % 4, b % 4
        if kind == 0 and slot not in slots:
            n = 1 + a % 4
            # half the admissions reuse an existing prompt prefix (sharing),
            # half are fresh
            if slots and a % 2 == 0:
                donor = sorted(slots.values())[0][0]
                tokens = list(donor[:n * page_size])
            else:
                tokens = [next_tok[0] + i for i in range(n * page_size)]
                next_tok[0] += n * page_size
            shared = index.match(tokens)[:max(n - 1, 0)]
            pool.incref(shared)
            fresh = pool.alloc(n - len(shared))
            if fresh is None:
                pool.decref(shared)          # admission refused: roll back
            else:
                slots[slot] = [tuple(tokens), list(shared) + fresh,
                               len(shared), False]
        elif kind == 1 and slot in slots:
            _, pages, _, _ = slots.pop(slot)
            pool.decref(pages)
        elif kind == 2 and slot in slots:
            tokens, pages, _, _ = slots[slot]
            index.insert(tokens, pages)
            slots[slot][3] = True
        elif kind == 3:
            index.reclaim(a % num_pages)
        check()
    # teardown: evicting everything must return the pool to fully free
    for _, pages, _, _ in slots.values():
        pool.decref(pages)
    index.reclaim(num_pages)
    assert pool.pages_free == num_pages


def test_pool_conservation_seeded_sweep():
    """Deterministic stand-in for the hypothesis property: 200 seeded random
    interleavings of admit/evict/publish/reclaim."""
    rng = np.random.default_rng(0)
    for _ in range(200):
        n_ops = int(rng.integers(1, 40))
        ops = rng.integers(0, 64, (n_ops, 3)).tolist()
        _run_ops(ops)


if HAVE_HYPOTHESIS:
    @settings(max_examples=200, deadline=None)
    @given(st.lists(st.tuples(st.integers(0, 63), st.integers(0, 63),
                              st.integers(0, 63)), max_size=40))
    def test_pool_conservation_property(ops):
        _run_ops(ops)


def test_pool_alloc_all_or_nothing():
    pool = PagePool(4, 8)
    assert pool.alloc(5) is None and pool.pages_free == 4
    got = pool.alloc(4)
    assert sorted(got) == [0, 1, 2, 3] and pool.pages_free == 0
    assert pool.alloc(1) is None
    assert pool.decref(got) == got
    assert pool.pages_free == 4


def test_pool_guards_double_free_and_free_incref():
    pool = PagePool(2, 8)
    (p,) = pool.alloc(1)
    pool.decref([p])
    with pytest.raises(RuntimeError, match="double free"):
        pool.decref([p])
    with pytest.raises(RuntimeError, match="incref of free"):
        pool.incref([p])


def test_prefix_index_match_insert_reclaim():
    pool = PagePool(8, 4)
    index = PrefixIndex(pool)
    toks = list(range(10))                      # 2 full pages + remainder
    pages = pool.alloc(3)
    assert index.match(toks) == []
    assert index.insert(toks, pages) == 2       # only full pages indexed
    assert index.match(toks) == pages[:2]
    assert index.match(toks[:7]) == pages[:1]   # partial second page: 1 hit
    assert index.match([99] + toks[1:]) == []
    # slot evicts; index refs keep both published pages alive
    freed = pool.decref(pages)
    assert freed == [pages[2]]
    assert index.reclaim(pool.num_pages) == 2
    assert pool.pages_free == pool.num_pages


def test_prefix_index_reclaim_is_lru():
    pool = PagePool(8, 2)
    index = PrefixIndex(pool)
    a, b = pool.alloc(1), pool.alloc(1)
    index.insert([1, 2], a)
    index.insert([3, 4], b)
    pool.decref(a + b)
    index.match([1, 2])                         # touch a: b is now LRU
    index.reclaim(7)                            # needs one eviction
    assert index.match([1, 2]) == a
    assert index.match([3, 4]) == []


# ---------------------------------------------------------------------------
# cache tier: preallocated paging is bit-for-bit the contiguous layout
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def model():
    cfg = registry.get_config("internlm2-20b", ffn="fff").reduced()
    params = lm.init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_prealloc_paged_generate_matches_contiguous(model):
    """``lm.generate`` through an identity-table paged cache must reproduce
    the contiguous cache token-for-token: gathering a preallocated table is
    exactly the old per-slot layout."""
    cfg, params = model
    prompt = jnp.asarray(np.random.default_rng(1).integers(1, 256, (2, 12)))
    want = lm.generate(params, cfg, prompt, steps=6, max_len=32)
    caches = lm.init_caches(cfg, 2, 32, page_size=8, prealloc=True)
    got = lm.generate(params, cfg, prompt, steps=6, max_len=32,
                      caches=caches)
    np.testing.assert_array_equal(np.asarray(want), np.asarray(got))


# ---------------------------------------------------------------------------
# engine tier
# ---------------------------------------------------------------------------

def _paged_engine(cfg, params, **kw):
    defaults = dict(num_slots=4, max_len=48, max_prompt_len=16, page_size=8,
                    seed=0)
    defaults.update(kw)
    return ContinuousBatchingEngine(params, cfg, EngineConfig(**defaults))


def _shared_prefix_requests(n, rng, shared=8, max_new=6):
    system = rng.integers(1, 256, shared)
    reqs = []
    for i in range(n):
        suffix = rng.integers(1, 256, int(rng.integers(1, 9)))
        reqs.append(Request(rid=i, prompt=np.concatenate([system, suffix]),
                            max_new_tokens=max_new))
    return reqs


def test_paged_engine_matches_lm_generate_and_shares(model):
    """The headline: paged serving with prefix sharing is exact (every
    request token-identical to ``lm.generate``) while genuinely sharing
    pages across requests."""
    cfg, params = model
    eng = _paged_engine(cfg, params)
    reqs = _shared_prefix_requests(8, np.random.default_rng(2))
    results, m = eng.run(reqs)
    assert sorted(r.rid for r in results) == list(range(8))
    for r in results:
        want = lm.generate(params, cfg, jnp.asarray(r.prompt[None]),
                           steps=r.n_generated, max_len=48)
        np.testing.assert_array_equal(
            np.asarray(want)[0], np.concatenate([r.prompt, r.tokens]),
            err_msg=f"rid {r.rid}")
    assert m.prefix_hit_tokens > 0, "no pages were shared"
    assert m.prefill_tokens < sum(len(r.prompt) for r in reqs)
    # run() drains everything: all pages back to the index or free
    assert all(s is None for s in eng.slots)


def test_paged_engine_mixed_requests_exact(model):
    """No shared prefixes at all: paging must still be exact (the PR 2
    parity test's workload through the paged path)."""
    cfg, params = model
    eng = _paged_engine(cfg, params)
    rng = np.random.default_rng(3)
    reqs = [Request(rid=i, prompt=rng.integers(1, 256, int(rng.integers(3, 17))),
                    max_new_tokens=6) for i in range(6)]
    results, _ = eng.run(reqs)
    for r in results:
        want = lm.generate(params, cfg, jnp.asarray(r.prompt[None]),
                           steps=r.n_generated, max_len=48)
        np.testing.assert_array_equal(
            np.asarray(want)[0], np.concatenate([r.prompt, r.tokens]),
            err_msg=f"rid {r.rid}")


def test_paged_engine_chunked_and_spec_modes(model):
    """Paging composes with chunked prefill and speculative decoding: both
    alternate engine modes stay exact on a shared-prefix workload."""
    cfg, params = model
    rng = np.random.default_rng(4)
    reqs = _shared_prefix_requests(6, rng)
    for kw in ({"prefill_chunk": 8}, {"spec_k": 3}):
        eng = _paged_engine(cfg, params, **kw)
        results, m = eng.run([Request(rid=r.rid, prompt=r.prompt,
                                      max_new_tokens=r.max_new_tokens)
                              for r in reqs])
        for r in results:
            want = lm.generate(params, cfg, jnp.asarray(r.prompt[None]),
                               steps=r.n_generated, max_len=48)
            np.testing.assert_array_equal(
                np.asarray(want)[0], np.concatenate([r.prompt, r.tokens]),
                err_msg=f"{kw} rid {r.rid}")
        assert m.prefix_hit_tokens > 0, kw


def test_paged_engine_pool_exhaustion_backpressure(model):
    """A pool too small for two long concurrent requests must serialize
    them (queue the second) rather than fail or corrupt."""
    cfg, params = model
    # 6 pages of 8 = 48 tokens of pool; each request needs 16+6+1 -> 3 pages
    eng = _paged_engine(cfg, params, num_pages=6)
    rng = np.random.default_rng(5)
    reqs = [Request(rid=i, prompt=rng.integers(1, 256, 16), max_new_tokens=6)
            for i in range(4)]
    results, _ = eng.run(reqs)
    assert sorted(r.rid for r in results) == list(range(4))
    for r in results:
        want = lm.generate(params, cfg, jnp.asarray(r.prompt[None]),
                           steps=r.n_generated, max_len=48)
        np.testing.assert_array_equal(
            np.asarray(want)[0], np.concatenate([r.prompt, r.tokens]),
            err_msg=f"rid {r.rid}")


def test_paged_engine_compile_contract(model):
    """Paging keeps the fixed-compiled-shape contract: decode 1 / admit 1 /
    <= 1 per prefill bucket across two waves."""
    cfg, params = model
    eng = _paged_engine(cfg, params, prefill_buckets=(8, 16))
    rng = np.random.default_rng(6)
    eng.run(_shared_prefix_requests(5, rng))
    warm = eng.compiled_shapes()
    eng.run(_shared_prefix_requests(7, rng))
    after = eng.compiled_shapes()
    assert after == warm, "recompilation after warmup"
    assert after["decode"] == 1
    assert after["admit"] == 1
    assert all(v <= 1 for k, v in after.items() if k.startswith("prefill_"))


def test_engine_metrics_expose_pool_state(model):
    cfg, params = model
    eng = _paged_engine(cfg, params)
    _, m = eng.run(_shared_prefix_requests(4, np.random.default_rng(7)))
    d = m.as_dict()
    for k in ("prefill_tokens", "prefix_hit_tokens", "cow_copies",
              "pages_in_use", "pages_free"):
        assert k in d, k
    assert d["pages_in_use"] + d["pages_free"] == eng.pool.num_pages


# ---------------------------------------------------------------------------
# RoutingProfileStore LRU cap (ISSUE 7 satellite)
# ---------------------------------------------------------------------------

def test_profile_store_lru_cap_warns_once():
    store = RoutingProfileStore(4, max_tenants=2)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        for t in ("a", "b", "c", "d"):
            store.update(t, np.ones(4))
    assert store.n_evicted == 2
    assert store.tenants() == ["c", "d"]
    evict_warns = [x for x in w if "evicted tenant" in str(x.message)]
    assert len(evict_warns) == 1, "eviction must warn exactly once"
    # lookup refreshes recency: 'c' survives the next eviction
    store.lookup("c")
    store.update("e", np.ones(4))
    assert store.tenants() == ["c", "e"]
    # update refreshes too, and existing-tenant updates never evict
    store.update("c", np.ones(4))
    assert store.n_evicted == 3
    assert store.tenants() == ["c", "e"]


def test_profile_store_uncapped_by_zero():
    store = RoutingProfileStore(4, max_tenants=0)
    for i in range(64):
        store.update(f"t{i}", np.ones(4))
    assert store.n_evicted == 0 and len(store.tenants()) == 64
