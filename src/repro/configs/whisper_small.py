"""whisper-small [audio] — 12L d_model=768 12H (GQA kv=12) d_ff=3072
vocab=51865 — encoder-decoder; conv frontend is a STUB per assignment
(input_specs provides precomputed frame embeddings).  [arXiv:2212.04356]

12 encoder + 12 decoder layers, GELU FFNs, LayerNorm with biases, learned
positions (decoder) / sinusoidal (encoder), cross-attention in every decoder
block.  Note (DESIGN.md §4): the assigned 32k decode shapes exceed whisper's
448-token trained context; we lower/compile them as assigned."""
import jax.numpy as jnp

from repro.configs.base import BlockSpec, EncoderSpec, FFNSpec, ModelConfig

_FFN = FFNSpec(kind="dense", d_ff=3072, activation="gelu")

CONFIG = ModelConfig(
    arch_id="whisper-small",
    family="audio",
    d_model=768,
    n_layers=12,
    n_heads=12,
    n_kv_heads=12,
    vocab_size=51865,
    max_seq_len=32768,
    pos_emb="learned",
    norm="layernorm",
    attn_bias=True,
    frontend="audio_stub",
    encoder=EncoderSpec(
        n_layers=12,
        period=(BlockSpec(mixer="attn", ffn=_FFN),),
        seq_len=1500,
    ),
    period=(BlockSpec(mixer="attn", ffn=_FFN, cross_attention=True),),
    param_dtype=jnp.bfloat16,
    accum_dtype=jnp.bfloat16,
    remat="full",
    grad_accum=16,
)

# 8 leaves x 384 = 3072 (exact width; 384 = 3*128, MXU-aligned)
FFF_CONFIG = CONFIG.with_ffn_kind("fff", leaf_width=384)
