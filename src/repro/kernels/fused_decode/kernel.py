"""Pallas TPU megakernel: the WHOLE FFF decode forward in one dispatch.

The serving engine's decode step is ``(num_slots, 1)`` forever (DESIGN.md
§9), and the existing kernel path covers it with THREE dispatches —
``tree_router`` then two gathered leaf matmuls — with the ``(B, l)`` hidden
activation making an HBM round trip between them and three kernel-launch
overheads per emitted token.  This kernel fuses tree routing, the selected
leaf's MLP (plain or SwiGLU) and the forest combine into ONE
``pl.pallas_call`` (DESIGN.md §13): the token's hidden activation never
leaves VMEM, and the descent's leaf choice feeds the leaf-weight loads
*inside the same kernel* — the paper's "conditionality is just an offset in
the data load" claim, taken to its limit on TPU.

Grid: ``(B,)`` — one token per step, matching decode's tiny batch (the
grouped/sorted paths win at prefill widths; ``core/api`` only routes
seq-len-1 inference here).  Per step:

1. node logits: ONE ``(1, D) @ (D, N)`` MXU matmul against the collapsed
   node hyperplanes (node_width == 1 folds the two node layers into one);
2. descent: ``depth`` register-level dynamic picks from the logit row —
   bit m of the leaf index is the sign of the chosen level-m logit;
3. leaf MLP: the computed ``idx`` drives ``pl.load(w_ref, (t, dslice(idx,
   1), ...))`` — only the routed leaf's weights are touched — with f32
   accumulation and the activation applied in-register;
4. combine: tree outputs accumulate in an f32 register tile; one store of
   ``y`` and the per-tree leaf indices (the telemetry contract: consumers
   get the same ``(B, trees)`` leaf_idx every other backend returns).

Memory layout note: the leaf-weight operands are declared whole (index_map
pinned to block 0) so the in-kernel dynamic index can select among them;
on real TPU the production variant keeps them HBM-resident
(``pltpu.ANY`` + an async copy of the selected leaf issued after the
descent) because 2^d leaves do not fit VMEM at paper scale — the interpret
path used on this CPU container executes the identical selection semantics
either way, which is what the differential harness pins down.  HBM traffic
per token is O(N·D + l·(D + O)) — the routed leaf only — vs the dense
layer's O(2^d·l·D), and vs the 3-dispatch path it additionally saves the
``(B, l)`` activation round trip plus two kernel launches.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_ACTS = {
    "none": lambda x: x,
    "relu": jax.nn.relu,
    "gelu": jax.nn.gelu,
    "silu": jax.nn.silu,
}


def _descend(logits_row, depth: int):
    """Register-level hard descent over one token's node-logit row
    (N = 2^depth - 1, level-major layout): bit m of the returned leaf index
    is the sign of the level-m logit chosen by the prefix path."""
    idx = jnp.zeros((), jnp.int32)
    off = 0
    for m in range(depth):
        cur = jax.lax.dynamic_index_in_dim(logits_row, off + idx,
                                           keepdims=False)
        idx = 2 * idx + (cur >= 0.0).astype(jnp.int32)
        off += 2 ** m
    return idx


def _leaf_slab(w_ref, t: int, idx):
    """Load exactly one leaf's weight slab: (T, E, A, B) ref -> (A, B).
    ``idx`` is the in-kernel descent result — the offset-load."""
    return pl.load(w_ref, (pl.dslice(t, 1), pl.dslice(idx, 1),
                           slice(None), slice(None)))[0, 0]


def _fused_decode_kernel(x_ref, nw_ref, nb_ref, *refs, depth: int, trees: int,
                         act: str, out_dtype, master: bool = False):
    m_refs = ()
    if master:
        n_m = 3 if act == "swiglu" else 2
        m_refs, refs = refs[-2 - n_m:-2], refs[:-2 - n_m] + refs[-2:]
    if act == "swiglu":
        wg_ref, wu_ref, wd_ref, y_ref, idx_ref = refs
    else:
        w1_ref, w2_ref, y_ref, idx_ref = refs
    x = x_ref[...]                                            # (1, D)
    acc = jnp.zeros((1, y_ref.shape[-1]), jnp.float32)
    idxs = []
    for t in range(trees):
        logits = jax.lax.dot_general(
            x, nw_ref[t], (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)               # (1, N)
        logits = logits + nb_ref[t][None, :].astype(jnp.float32)
        idx = _descend(logits[0], depth)
        if act == "swiglu":
            g = jax.lax.dot_general(
                x, _leaf_slab(wg_ref, t, idx), (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
            u = jax.lax.dot_general(
                x, _leaf_slab(wu_ref, t, idx), (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
            h = jax.nn.silu(g) * u                            # (1, l) f32
            w_down = _leaf_slab(wd_ref, t, idx)
        else:
            h = _ACTS[act](jax.lax.dot_general(
                x, _leaf_slab(w1_ref, t, idx), (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32))          # (1, l) f32
            w_down = _leaf_slab(w2_ref, t, idx)
        acc += jax.lax.dot_general(
            h.astype(x.dtype), w_down, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        idxs.append(idx)
    if master:
        # always-on master leaf (DESIGN.md §14): one more MLP on the same
        # in-VMEM token — fused here so the megakernel keeps its single
        # dispatch (the other backends add the master term centrally in
        # api.apply)
        if act == "swiglu":
            mg_ref, mu_ref, md_ref = m_refs
            g = jax.lax.dot_general(
                x, mg_ref[...], (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
            u = jax.lax.dot_general(
                x, mu_ref[...], (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
            h = jax.nn.silu(g) * u
            m_down = md_ref[...]
        else:
            m1_ref, m2_ref = m_refs
            h = _ACTS[act](jax.lax.dot_general(
                x, m1_ref[...], (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32))
            m_down = m2_ref[...]
        acc += jax.lax.dot_general(
            h.astype(x.dtype), m_down, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
    y_ref[...] = acc.astype(out_dtype)
    idx_ref[...] = jnp.stack(idxs).astype(jnp.int32)[None, :]


def fused_forest_decode(x: jax.Array, nw: jax.Array, nb: jax.Array,
                        leaf_w: tuple, *, depth: int, act: str = "gelu",
                        master_w: tuple | None = None,
                        interpret: bool = False,
                        out_dtype=None) -> tuple[jax.Array, jax.Array]:
    """One fused dispatch: route + selected-leaf MLP + forest combine.

    Args:
        x:      (B, D) decode tokens.
        nw:     (T, N, D) collapsed node hyperplanes, N = 2^depth - 1.
        nb:     (T, N) collapsed node biases.
        leaf_w: ``(w1 (T, E, D, l), w2 (T, E, l, O))`` for plain leaves, or
                ``(wg, wu (T, E, D, l), wd (T, E, l, O))`` for SwiGLU
                (then ``act`` must be ``"swiglu"``).
        master_w: optional always-on master-leaf MLP (DESIGN.md §14), fused
                into the same dispatch: ``(m1 (D, mw), m2 (mw, O))`` for
                plain leaves or ``(mg, mu (D, mw), md (mw, O))`` for SwiGLU;
                None (default) preserves the master-free contract.

    Returns ``(y (B, O), leaf_idx (B, T) int32)``.
    """
    B, D = x.shape
    T, N, _ = nw.shape
    assert B >= 1, "fused decode needs at least one token"
    assert depth >= 1 and N == 2 ** depth - 1, (N, depth)
    assert (len(leaf_w) == 3) == (act == "swiglu"), (len(leaf_w), act)
    if master_w is not None:
        assert len(master_w) == len(leaf_w), (len(master_w), len(leaf_w))
    E = leaf_w[0].shape[1]
    O = leaf_w[-1].shape[-1]
    out_dtype = out_dtype or x.dtype
    whole = lambda a: pl.BlockSpec(a.shape, lambda i: (0,) * a.ndim)
    m_ops = tuple(master_w) if master_w is not None else ()
    return pl.pallas_call(
        functools.partial(_fused_decode_kernel, depth=depth, trees=T,
                          act=act, out_dtype=out_dtype,
                          master=master_w is not None),
        grid=(B,),
        in_specs=[pl.BlockSpec((1, D), lambda i: (i, 0)),
                  whole(nw), whole(nb)] + [whole(w) for w in leaf_w]
                 + [whole(w) for w in m_ops],
        out_specs=[pl.BlockSpec((1, O), lambda i: (i, 0)),
                   pl.BlockSpec((1, T), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((B, O), out_dtype),
                   jax.ShapeDtypeStruct((B, T), jnp.int32)],
        interpret=interpret,
    )(x, nw, nb, *leaf_w, *m_ops)
