"""Speculative decoding for the continuous-batching engine (DESIGN.md §10).

The paper's FORWARD_I makes per-token FLOPs nearly free (log-depth leaf
path), so serving throughput is bounded by the one-token-per-step decode
loop — dispatch overhead plus one full weight pass per emitted token.
Speculative decoding (Leviathan et al., 2023) breaks that bound: a cheap
DRAFT model proposes ``k`` tokens autoregressively, the TARGET model scores
all ``k + 1`` positions in ONE slab dispatch, and host-side rejection
sampling keeps the longest prefix the target agrees with — the output
distribution is exactly the target's, for any draft.

Engine integration (``serving/engine.py``) keeps the fixed-shape contract:

* ``draft_rollout`` — the whole draft phase as one traced computation: a
  ``lax.scan`` of ``k + 1`` draft decode steps over the pooled draft caches
  (the extra step appends the last draft token's KV so an all-accepted
  round leaves the draft cache aligned).  It also applies both cache
  trees' length rollback from the PREVIOUS round's rejection — lengths are
  metadata, so the truncate rides along for free instead of costing its
  own dispatch.
* ``lm.verify_chunk`` — the chunk-slab machinery scores
  ``(num_slots, k + 1)`` at every position, writing draft KV
  optimistically.
* ``spec_round`` — rollout + verify fused into ONE dispatch per round
  (verify reads the drafts on device; only rejection needs the host), so a
  round costs a single dispatch overhead however many tokens it emits.
* ``rejection_sample`` — host-side accept/reject per row, exact.

The FFF-specific edge: the draft's leaf routing path is a free PRIOR on the
verify step's leaf occupancy.  The rollout aggregates per-slot draft leaf
histograms (``api.RoutingStats``) and the engine folds them into the
occupancy EWMA the ``leaf_aware`` / ``weighted_leaf_aware`` schedulers
read, so verify slabs are composed to minimize predicted grouped-dispatch
overflow before the target ever routes a token.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import api
from repro.models import lm

Params = dict


# ---------------------------------------------------------------------------
# draft-model construction
# ---------------------------------------------------------------------------

def self_draft_config(cfg, n_periods: int = 1):
    """The ``self:N`` draft config: the target architecture truncated to its
    first ``n_periods`` period repetitions (an early-exit draft).  Shares
    vocabulary, d_model and period structure with the target by
    construction, so sliced target params fit it directly."""
    if not (1 <= n_periods <= cfg.n_periods):
        raise ValueError(f"self-draft n_periods {n_periods} out of range "
                         f"[1, {cfg.n_periods}]")
    return dataclasses.replace(cfg, n_layers=n_periods * len(cfg.period))


def slice_draft_params(params: Params, cfg, n_periods: int = 1) -> Params:
    """Self-speculative draft parameters: the first ``n_periods`` entries of
    every stacked period axis, SHARING embed / positional / final-norm
    leaves with the target (no copies — the draft is a view of the target's
    own early layers).  An early-exit draft needs no training to correlate
    with the target, which is what makes acceptance non-trivial out of the
    box; a well-calibrated target makes it high."""
    if not (1 <= n_periods <= cfg.n_periods):
        raise ValueError(f"self-draft n_periods {n_periods} out of range "
                         f"[1, {cfg.n_periods}]")
    out = dict(params)
    out["stack"] = [jax.tree_util.tree_map(lambda a: a[:n_periods], p)
                    for p in params["stack"]]
    return out


def build_draft(spec: Optional[str], params: Params, cfg,
                seed: int = 0) -> Tuple[Params, object]:
    """Resolve a draft-model spec string into ``(draft_params, draft_cfg)``.

    * ``None`` / ``"self"`` / ``"self:N"`` — self-speculative: the target's
      own first N periods (default 1), params shared (see
      ``slice_draft_params``).
    * a registry arch id (``configs.registry.ARCH_IDS``) — an independent
      randomly-initialized draft in the *reduced* shape.  Near-zero
      acceptance untrained (correctness testing / a slot for real trained
      drafts), and its KV pool is still slot-indexed alongside the
      target's.  Must share the target's vocabulary.
    """
    spec = spec or "self"
    if spec == "self" or spec.startswith("self:"):
        n = int(spec.split(":", 1)[1]) if ":" in spec else 1
        return slice_draft_params(params, cfg, n), self_draft_config(cfg, n)
    from repro.configs.registry import get_config
    dcfg = get_config(spec, ffn="fff").reduced(
        d_model=cfg.d_model, n_heads=cfg.n_heads, vocab=cfg.vocab_size,
        seq=cfg.max_seq_len)
    if dcfg.vocab_size != cfg.vocab_size:   # pragma: no cover - reduced() sets it
        raise ValueError(f"draft {spec!r}: vocab {dcfg.vocab_size} != "
                         f"target vocab {cfg.vocab_size}")
    if any(b.mixer != "attn" for b in dcfg.period):
        raise ValueError(f"draft {spec!r}: the engine's pooled-cache "
                         f"contract needs attention mixers in the draft too")
    return lm.init(jax.random.PRNGKey(seed), dcfg), dcfg


# ---------------------------------------------------------------------------
# the fused draft phase (one dispatch per spec round)
# ---------------------------------------------------------------------------

def _agg_stats(stats):
    """Collapse scan-stacked per-site RoutingStats (leading k+1 step axis)
    into one per-site aggregate: summed leaf counts / slots, slot-weighted
    overflow."""
    if stats is None:
        return None
    out = []
    for s in stats:
        if s is None:
            out.append(None)
            continue
        slots = s.slots.sum()
        out.append(api.RoutingStats(
            leaf_counts=s.leaf_counts.sum(0),
            overflow=(s.overflow * s.slots).sum() / jnp.maximum(slots, 1.0),
            slots=slots))
    return tuple(out)


def draft_rollout(draft_params: Params, dcfg, tok0: jax.Array,
                  target_caches: list, draft_caches: list,
                  target_len: jax.Array, draft_len: jax.Array,
                  pos0: jax.Array, write_masks: jax.Array,
                  live: jax.Array, temps: jax.Array, key: jax.Array,
                  draft_backend: Optional[str] = None):
    """The whole draft phase in one traced computation (jitted by the
    engine; fixed shapes — compiles once).

    Steps, in order:
    1. Roll BOTH cache trees back to the host-tracked lengths
       (``set_cache_lengths`` — the previous verify appended ``k + 1``
       positions optimistically; rejected suffixes die here, one round
       late, without a dedicated truncate dispatch).
    2. ``lax.scan`` ``k + 1`` draft decode steps: step ``j`` feeds the
       current token at per-row position ``pos0 + j``, appends its KV to
       the draft cache (per-step ``write_masks[j]`` guards the ``max_len``
       edge), and samples the next draft token — on-device gumbel-argmax
       for ``temps > 0`` rows, argmax otherwise, so the proposal
       distribution is exactly ``softmax(q_logits / temp)`` and host-side
       rejection can use the returned logits verbatim.

    Args:
        tok0:        (S, 1) int32 — each live row's pending token.
        target_len:  (S,) int32 — authoritative target cache lengths.
        draft_len:   (S,) int32 — authoritative draft cache lengths.
        pos0:        (S,) int32 — absolute position of ``tok0``.
        write_masks: (k+1, S) bool — per-step KV-append guards.
        live:        (S,) bool — FFF validity mask (free slots are routed
                     to the sentinel leaf, DESIGN.md §9).
        temps:       (S,) float32 — per-row sampling temperature.
        key:         PRNG key for on-device draft sampling.
        draft_backend: optional FFF backend name steered (``use_backend``)
                     around the scanned draft steps only — the engine
                     passes ``"pallas_decode"`` so the rollout's seq-len-1
                     decode steps trace onto the fused megakernel
                     (DESIGN.md §13) while the verify slab keeps its own
                     resolution.  None = no steer.

    Returns ``(drafts (k, S), q_logits (k+1, S, V), target_caches,
    draft_caches, stats)`` — ``drafts[j]`` was sampled from
    ``softmax(q_logits[j] / temps)``; ``stats`` is the step-aggregated
    per-site RoutingStats tuple (the scheduler's verify-occupancy prior).
    """
    target_caches = lm.set_cache_lengths(target_caches, target_len)
    draft_caches = lm.set_cache_lengths(draft_caches, draft_len)
    k_plus_1 = write_masks.shape[0]
    t_safe = jnp.maximum(temps, 1e-6)[:, None]

    def step(carry, xs):
        tok, caches = carry
        j, wm, sub = xs
        logits, caches, stats = lm.decode_step(
            draft_params, dcfg, tok, caches, pos_offset=pos0 + j,
            write_mask=wm, token_valid=live, with_stats=True)
        greedy = logits.argmax(-1)
        g = jax.random.gumbel(sub, logits.shape, dtype=jnp.float32)
        sampled = (logits.astype(jnp.float32) / t_safe + g).argmax(-1)
        nxt = jnp.where(temps > 0.0, sampled, greedy).astype(jnp.int32)
        return (nxt[:, None], caches), (nxt, logits, stats)

    xs = (jnp.arange(k_plus_1), write_masks,
          jax.random.split(key, k_plus_1))
    steer = (api.overrides(backend=draft_backend, mode="infer")
             if draft_backend is not None else contextlib.nullcontext())
    with steer:      # trace-time: applies to the scanned step body only
        (_, draft_caches), (sampled, q_logits, stats) = jax.lax.scan(
            step, (tok0, draft_caches), xs)
    # the last step exists only to append d_k's KV; its sample is unused
    return (sampled[:-1], q_logits, target_caches, draft_caches,
            _agg_stats(stats))


def spec_round(params: Params, cfg, draft_params: Params, dcfg,
               tok0: jax.Array, caches: list, draft_caches: list,
               target_len: jax.Array, draft_len: jax.Array,
               pos0: jax.Array, write_masks: jax.Array, verify_len: jax.Array,
               live: jax.Array, temps: jax.Array, key: jax.Array,
               verify_cf: Optional[float] = None,
               draft_backend: Optional[str] = None):
    """One whole speculative round in a single traced computation: the draft
    rollout followed immediately by the target's batched verify over the
    ``(num_slots, k + 1)`` slab ``[pending, d_1 .. d_k]``.

    The verify consumes the drafts ON DEVICE (host rejection only needs the
    resulting logits), so fusing it into the rollout's jit costs nothing and
    halves the per-round dispatch overhead — the term that decides whether
    speculation wins at all in the small-model regime the paper's log-depth
    FORWARD_I creates (see benchmarks/serving_spec.py).

    ``verify_len`` (S,) int32 in [0, k + 1]: tokens of the slab the target
    actually scores/appends per row (0 = free slot; rows near the cache edge
    clip, mirroring ``write_masks``).  ``verify_cf``: capacity factor for
    the verify dispatch only (``api.overrides(capacity_factor=...)``, which
    nests inside and wins over the engine's own override) — the engine
    passes the decode capacity factor scaled by ``k + 1`` so each verify
    token sees the per-leaf capacity it would have seen in plain decode
    (None = backend default, for capacity-free backends).  Returns
    ``(drafts (k, S), q_logits (k+1, S, V), p_logits (S, k+1, V), caches,
    draft_caches, draft_stats, verify_stats)``.
    """
    ctx = (api.overrides(capacity_factor=verify_cf) if verify_cf is not None
           else contextlib.nullcontext())
    with ctx:
        # the rollout runs at the scaled capacity too: draft dispatch
        # capacity only shapes the PROPOSAL distribution (rejection keeps
        # exactness for any draft), so capacity drops there are pure
        # acceptance loss — one early drop rejects the whole suffix
        drafts, q_logits, caches, draft_caches, dstats = draft_rollout(
            draft_params, dcfg, tok0, caches, draft_caches, target_len,
            draft_len, pos0, write_masks, live, temps, key,
            draft_backend=draft_backend)
        vtoks = jnp.concatenate([tok0, drafts.T], axis=1)  # (S, k+1)
        p_logits, caches, vstats = lm.verify_chunk(
            params, cfg, vtoks, verify_len, caches, pos0)
    return drafts, q_logits, p_logits, caches, draft_caches, dstats, vstats


def chunk_both(params: Params, cfg, draft_params: Params, dcfg,
               tokens: jax.Array, valid_len: jax.Array, caches: list,
               draft_caches: list, pos_offset: jax.Array):
    """Chunked prefill with speculation on: one slab dispatch advances every
    in-flight prefill through BOTH cache trees."""
    logits, caches, stats = lm.prefill_chunk(
        params, cfg, tokens, valid_len, caches, pos_offset)
    _, draft_caches, dstats = lm.prefill_chunk(
        draft_params, dcfg, tokens, valid_len, draft_caches, pos_offset)
    return logits, caches, draft_caches, stats, dstats


# ---------------------------------------------------------------------------
# host-side rejection sampling (exact target distribution)
# ---------------------------------------------------------------------------

def _softmax64(z: np.ndarray) -> np.ndarray:
    z = np.asarray(z, np.float64)
    z = z - z.max()
    e = np.exp(z)
    return e / e.sum()


def rejection_sample(p_logits: np.ndarray, q_logits: np.ndarray,
                     drafts: np.ndarray, temperature: float,
                     rng: Optional[np.random.Generator] = None
                     ) -> Tuple[List[int], int]:
    """Speculative rejection sampling for one row (Leviathan et al., 2023).

    Args:
        p_logits: (m+1, V) target logits — row ``j`` is the target's
                  next-token distribution after consuming the pending token
                  plus drafts ``d_1 .. d_j``.
        q_logits: (m, V) draft logits — row ``j`` is the distribution
                  ``d_{j+1}`` was sampled from.
        drafts:   (m,) proposed tokens ``d_1 .. d_m``.
        temperature: the request's sampling temperature (<= 0 = greedy).
        rng:      host RNG for the stochastic path (unused when greedy).

    Returns ``(emitted, n_accepted)``: ``emitted`` is the accepted prefix
    plus exactly one more token — the corrected sample from
    ``norm(max(p - q, 0))`` on first rejection, or the bonus token from the
    target's ``m+1``-th distribution when every draft is accepted.  The
    sequence of emitted tokens is distributed EXACTLY as if each had been
    sampled from the target one at a time; under greedy both reduce to the
    target argmax chain, token for token.
    """
    m = len(drafts)
    emitted: List[int] = []
    if temperature <= 0.0:
        for j in range(m):
            t = int(p_logits[j].argmax())
            if t != int(drafts[j]):
                return emitted + [t], j
            emitted.append(t)
        return emitted + [int(p_logits[m].argmax())], m
    for j in range(m):
        p = _softmax64(p_logits[j] / temperature)
        q = _softmax64(q_logits[j] / temperature)
        d = int(drafts[j])
        if rng.random() < min(1.0, p[d] / max(q[d], 1e-300)):
            emitted.append(d)
            continue
        r = np.maximum(p - q, 0.0)
        s = r.sum()
        if s <= 0.0:          # numerically p <= q everywhere: p itself
            r, s = p, p.sum()
        return emitted + [int(rng.choice(r.size, p=r / s))], j
    p = _softmax64(p_logits[m] / temperature)
    return emitted + [int(rng.choice(p.size, p=p / p.sum()))], m
