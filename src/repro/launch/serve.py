"""Serving driver: continuous-batching engine (default) or the legacy
fixed-batch loop (``--engine off``).

``--engine continuous`` (default) drives ``repro.serving``: a request queue
with per-tenant views, pluggable admission scheduling (``--scheduler
fcfs|leaf_aware|weighted_leaf_aware``, the latter taking QoS shares from
``--tenant-weights``), a slot-pooled KV-cache and interleaved
prefill/decode over fixed compiled shapes — requests of mixed lengths
arrive, finish and free their slots independently (DESIGN.md §9).  ``--prefill-chunk N`` switches admission to
chunked prefill: long prompts advance N tokens per step instead of running
one monolithic prefill between decode steps (stall-free admission; tune with
``--prefill-budget`` / ``--max-prefilling``).  ``--spec-k K`` turns on
speculative decoding: a draft model (``--draft-config``, default the
target's own first period) proposes K tokens per slot per round and the
target verifies them in one slab dispatch, multiplying decode throughput by
the acceptance-weighted emission rate (DESIGN.md §10).  ``--page-size N``
switches the KV cache from contiguous per-slot rows to a paged pool with
cross-request prefix sharing — admissions whose prompts share a leading
prefix map the same pages and only prefill their novel suffix (DESIGN.md
§11); ``--shared-prefix M`` synthesizes the matching shared-system-prompt
workload.  ``--engine off`` keeps the original synchronous batched
prefill + decode demo loop.  Operator guide: docs/serving.md.

Both paths report p50/p90/p99 latency and tokens/s through
``repro.serving.metrics`` and steer every FFF site's execution strategy with
``--fff-backend`` / ``--capacity-factor`` / ``--overflow-policy`` via
``api.overrides`` (core/api.py, DESIGN.md §2 + §14).

``--model-parallel M`` installs an (all-devices/M, M) (data, model) mesh and
shards the params onto it — the expert-parallel serving topology the
``grouped_ep`` backend exchanges tokens over (DESIGN.md §5).  On a CPU host,
combine with ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` to
exercise the collective path.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch internlm2-20b --reduced \
      --engine continuous --batch 4 --prompt-len 32 --gen 16 \
      [--scheduler leaf_aware] [--fff-backend grouped_ep] [--model-parallel 4]
"""
from __future__ import annotations

import argparse
import contextlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import utils
from repro.configs import registry
from repro.core import api
from repro.data import tokens as tokens_lib
from repro.models import lm
from repro.serving import metrics as metrics_lib
from repro.serving.engine import ContinuousBatchingEngine, EngineConfig
from repro.serving.request import Request
from repro.serving.scheduler import SCHEDULERS


def build_parser() -> argparse.ArgumentParser:
    """The serving CLI (docs/serving.md documents every flag; the docs CI
    job cross-checks that list against this parser)."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-20b",
                    choices=list(registry.ARCH_IDS))
    ap.add_argument("--ffn", default="fff", choices=["fff", "native", "dense"])
    ap.add_argument("--fff-backend", default="auto",
                    choices=["auto"] + api.list_backends("infer"),
                    help="execution backend for every FFF site (auto = "
                         "per-site resolution; see core/api.py)")
    ap.add_argument("--capacity-factor", type=float, default=None,
                    help="capacity factor for capacity-bounded FFF backends "
                         "(grouped / grouped_ep): per-(shard, leaf) slots "
                         "scale with cf * tokens / leaves.  < 1.0 "
                         "deliberately under-provisions — pair with "
                         "--overflow-policy (DESIGN.md §14; default: the "
                         "configured backend's dispatch default)")
    ap.add_argument("--overflow-policy", default=None,
                    choices=list(api.OVERFLOW_POLICIES),
                    help="what over-capacity tokens get under a capacity-"
                         "bounded backend: exact_dense = dense gather "
                         "repair (exact, pays collective traffic), "
                         "master_leaf = the always-on master term stands in "
                         "alone (approximate, zero repair traffic; needs a "
                         "model built with fff_master_leaf), drop = zeros "
                         "(DESIGN.md §14; default: backend default)")
    ap.add_argument("--pallas-decode", action="store_true",
                    help="engine: steer one-token decode (and speculative "
                         "draft rollout) through the fused megakernel "
                         "backend — routing + selected-leaf MLP + forest "
                         "combine in ONE dispatch (DESIGN.md §13); prefill "
                         "and verify slabs keep normal backend resolution")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--engine", default="continuous",
                    choices=["continuous", "off"],
                    help="continuous = the batching engine (repro.serving); "
                         "off = the legacy synchronous fixed-batch loop")
    ap.add_argument("--scheduler", default="fcfs",
                    choices=sorted(SCHEDULERS),
                    help="admission policy for --engine continuous")
    ap.add_argument("--tenant-weights", default="",
                    help="comma list of tenant=weight pairs (e.g. "
                         "gold=3,free=1): synthetic requests are assigned "
                         "round-robin across the named tenants, and the "
                         "weights parameterize --scheduler "
                         "weighted_leaf_aware's weighted-fair admission; "
                         "empty = single 'default' tenant")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="engine: >0 = chunked prefill — prompts advance "
                         "this many tokens per (num_slots, chunk) slab "
                         "dispatch, interleaved with decode so long-prompt "
                         "admission never stalls in-flight decode (power of "
                         "two <= --prompt-len; 0 = monolithic per-bucket "
                         "prefill)")
    ap.add_argument("--prefill-budget", type=int, default=1,
                    help="engine: max chunk-slab dispatches per step when "
                         "--prefill-chunk > 0 (higher = faster admission / "
                         "TTFT, longer decode intervals / p99)")
    ap.add_argument("--max-prefilling", type=int, default=0,
                    help="scheduler: cap on slots concurrently mid-chunked-"
                         "prefill (0 = uncapped); the admission-side "
                         "TTFT-vs-p99 knob")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="engine: >0 = speculative decoding — a draft model "
                         "proposes this many tokens per live slot per round; "
                         "the target verifies the (slots, k+1) slab in one "
                         "dispatch and host-side rejection sampling keeps "
                         "the target distribution exact (DESIGN.md §10; "
                         "0 = plain one-token decode)")
    ap.add_argument("--draft-config", default="",
                    help="engine: draft model for --spec-k — 'self' / "
                         "'self:N' = the target's own first N periods "
                         "(early-exit self-draft, shares weights; default "
                         "'self'), or a registry arch id for an independent "
                         "reduced draft (random init)")
    ap.add_argument("--page-size", type=int, default=0,
                    help="engine: >0 = paged KV cache — the cache becomes a "
                         "page pool of this many tokens per page with per-"
                         "slot page tables, and admissions sharing a prompt "
                         "prefix map the same pages instead of re-prefilling "
                         "them (DESIGN.md §11; 0 = contiguous per-slot "
                         "cache, the degenerate one-page-per-slot layout)")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="workload: >0 = every synthetic request starts with "
                         "the same this-many-token system prompt (the cross-"
                         "request prefix-sharing workload; 0 = fully "
                         "independent prompts)")
    ap.add_argument("--metrics-json", default="",
                    help="engine: write the run's EngineMetrics (+ compiled-"
                         "shape counts) as JSON to this path — the "
                         "autoscaling-signal schema (docs/serving.md)")
    ap.add_argument("--batch", type=int, default=4,
                    help="fixed batch (legacy) / cache slots (engine)")
    ap.add_argument("--requests", type=int, default=0,
                    help="engine: number of requests (0 = 2x slots)")
    ap.add_argument("--prompt-len", type=int, default=32,
                    help="max prompt length (engine serves a mixed-length "
                         "set up to this)")
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--eos-id", type=int, default=-1,
                    help=">= 0: stop each sequence at this token id")
    ap.add_argument("--model-parallel", type=int, default=1,
                    help="model-axis size of the serving mesh; >1 installs "
                         "a (data, model) mesh over all devices so FFF "
                         "sites serve expert-parallel (grouped_ep)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--cluster", nargs=2, type=int, default=None,
                    metavar=("N_PREFILL", "N_DECODE"),
                    help="disaggregated serving: run this many prefill and "
                         "decode workers behind the cluster router "
                         "(repro.cluster, DESIGN.md §12) instead of one "
                         "engine; each worker gets --batch slots of its "
                         "role; implies a paged KV cache (--page-size, "
                         "default 16 when unset)")
    ap.add_argument("--cluster-bus", default="proc",
                    choices=["local", "proc"],
                    help="cluster transport: proc = one OS process per "
                         "worker (multiprocessing, the real topology); "
                         "local = in-process deterministic bus (debugging)")
    ap.add_argument("--cluster-kill", type=int, default=0,
                    help="cluster fault injection: after this many requests "
                         "complete, SIGKILL one decode worker mid-stream — "
                         "the router replays its in-flight work and "
                         "respawns the role (0 = no kill)")
    ap.add_argument("--cluster-verify", action="store_true",
                    help="cluster: after serving, replay the same workload "
                         "on a single in-process engine and report exact "
                         "token parity in the summary / --metrics-json "
                         "(the zero-lost-tokens check)")
    ap.add_argument("--scale-up-watermark", type=float, default=0.0,
                    help="cluster: smoothed queue depth above which the "
                         "monitor spawns an extra decode worker "
                         "(0 = elastic scaling off)")
    ap.add_argument("--scale-down-watermark", type=float, default=0.0,
                    help="cluster: smoothed queue depth below which an "
                         "idle surplus decode worker is drained "
                         "(0 = never scale down)")
    ap.add_argument("--heartbeat-timeout", type=float, default=600.0,
                    help="cluster: seconds without a heartbeat before a "
                         "worker is declared dead and its work replayed "
                         "(default is deliberately huge — jit compiles "
                         "stall heartbeats; lower it only on warm fleets)")
    ap.add_argument("--drain", action="store_true",
                    help="cluster: after serving, drain the fleet "
                         "gracefully (finish in-flight, refuse new work, "
                         "stop each worker on its Drained handshake) "
                         "instead of stopping it immediately")
    return ap


def parse_args(argv=None) -> argparse.Namespace:
    return build_parser().parse_args(argv)


def _setup(args):
    cfg = registry.get_config(args.arch, ffn=args.ffn)
    if args.reduced:
        cfg = cfg.reduced(seq=max(64, args.prompt_len + args.gen + 1))
    params = lm.init(jax.random.PRNGKey(args.seed), cfg)
    print(f"{cfg.arch_id}: {utils.tree_size(params)/1e6:.1f}M params")

    from repro.launch import mesh as mesh_lib
    mesh, mesh_ctx = mesh_lib.serving_context(args.model_parallel)
    if mesh is not None:
        from repro.distributed import sharding
        params = sharding.shard_params(params, mesh, fsdp=False)
        print(f"mesh: {dict(mesh.shape)} (expert-parallel serving)")
    return cfg, params, mesh, mesh_ctx


def parse_tenant_weights(spec: str) -> dict:
    """'gold=3,free=1' -> {'gold': 3.0, 'free': 1.0} (docs/serving.md)."""
    out = {}
    for part in filter(None, (p.strip() for p in spec.split(","))):
        name, eq, w = part.partition("=")
        if not eq or not name:
            raise ValueError(f"--tenant-weights entry {part!r} is not "
                             f"tenant=weight")
        try:
            weight = float(w)
        except ValueError:
            raise ValueError(f"--tenant-weights entry {part!r}: weight "
                             f"{w!r} is not a number") from None
        if not (weight > 0 and np.isfinite(weight)):
            # fail at the CLI boundary, where the operator can see which
            # flag was wrong, not later inside the scheduler constructor
            raise ValueError(f"--tenant-weights entry {part!r}: weight must "
                             f"be positive and finite")
        if name in out:
            # a silent overwrite would turn an intended 3:1 split into
            # whatever the last duplicate said
            raise ValueError(f"--tenant-weights names tenant {name!r} twice")
        out[name] = weight
    return out


def build_requests(args, cfg, *, n=None) -> list:
    """The synthetic mixed-length workload every serving mode shares (the
    engine, the cluster, and --cluster-verify's replay must serve the SAME
    request set for parity to mean anything)."""
    eos = args.eos_id if args.eos_id >= 0 else None
    weights = parse_tenant_weights(args.tenant_weights)
    n = n if n is not None else (args.requests or 2 * args.batch)
    src = tokens_lib.MarkovTokenSource(cfg.vocab_size, seed=args.seed)
    rng = np.random.default_rng(args.seed)
    tenants = sorted(weights) or ["default"]
    if args.shared_prefix >= args.prompt_len:
        raise ValueError(f"--shared-prefix ({args.shared_prefix}) must be "
                         f"< --prompt-len ({args.prompt_len}): every request "
                         f"needs at least one token of its own")
    sp = max(args.shared_prefix, 0)
    system = src.sample(1, sp, seed=args.seed)[0, :sp] if sp else None
    reqs = []
    for i in range(n):
        # mixed lengths: the engine's reason to exist
        lo = min(max(sp + 1, 4, args.prompt_len // 4), args.prompt_len)
        L = int(rng.integers(lo, args.prompt_len + 1))
        prompt = src.sample(1, L, seed=args.seed + 1 + i)[0, :L]
        if system is not None:
            # shared-system-prompt workload: identical leading tokens, so a
            # paged engine prefills the prefix once and shares the pages
            prompt = np.concatenate([system, prompt[sp:]])
        reqs.append(Request(rid=i, prompt=prompt, max_new_tokens=args.gen,
                            eos_id=eos, tenant=tenants[i % len(tenants)]))
    return reqs


def run_engine(args) -> None:
    cfg, params, mesh, mesh_ctx = _setup(args)
    weights = parse_tenant_weights(args.tenant_weights)
    sched_kw = ({"max_prefilling": args.max_prefilling}
                if args.max_prefilling > 0 else {})
    if weights and args.scheduler == "weighted_leaf_aware":
        sched_kw["weights"] = weights
    elif weights:
        # labels without enforcement is a misconfiguration trap: metrics
        # split per tenant but admission ignores the weights entirely
        print(f"WARNING: --tenant-weights given but --scheduler is "
              f"{args.scheduler!r}: requests get tenant labels and "
              f"per-tenant metrics, but only weighted_leaf_aware enforces "
              f"the weights at admission")
    ecfg = EngineConfig(
        num_slots=args.batch,
        max_len=args.prompt_len + args.gen + 1,
        max_prompt_len=args.prompt_len,
        scheduler=args.scheduler,
        scheduler_kw=sched_kw,
        prefill_chunk=args.prefill_chunk,
        prefill_budget=args.prefill_budget,
        fff_backend=args.fff_backend,
        pallas_decode=args.pallas_decode,
        capacity_factor=args.capacity_factor,
        overflow_policy=args.overflow_policy,
        spec_k=args.spec_k,
        draft_config=args.draft_config or None,
        page_size=args.page_size,
        seed=args.seed)
    engine = ContinuousBatchingEngine(params, cfg, ecfg, trace_ctx=mesh_ctx,
                                      mesh=mesh)

    reqs = build_requests(args, cfg)
    n, sp = len(reqs), max(args.shared_prefix, 0)
    mode = (f"chunked prefill (chunk={args.prefill_chunk}, "
            f"budget={args.prefill_budget})" if args.prefill_chunk
            else "monolithic prefill")
    qos = (f", tenants={{{args.tenant_weights}}}" if weights else "")
    spec = (f", speculative (k={args.spec_k}, "
            f"draft={args.draft_config or 'self'})" if args.spec_k else "")
    paged = (f", paged kv (page={args.page_size})" if args.page_size else "")
    shared = f", shared prefix {sp} tokens" if sp else ""
    print(f"engine: {args.batch} slots, {n} requests, prompt lens "
          f"{min(len(r.prompt) for r in reqs)}-"
          f"{max(len(r.prompt) for r in reqs)}, scheduler={args.scheduler}"
          f"{qos}, {mode}{spec}{paged}{shared}, "
          f"fff backend={args.fff_backend} requested")
    _, m = engine.run(reqs)
    print(m.report())
    print(f"compiled shapes: {engine.compiled_shapes()}")
    if args.metrics_json:
        import json
        payload = m.as_dict()
        payload["compiled_shapes"] = engine.compiled_shapes()
        if engine.profiles is not None:
            # learned per-tenant routing profiles (docs/serving.md): lets
            # operators watch online calibration converge across dumps
            payload["routing_profiles"] = engine.profiles.as_dict()
        with open(args.metrics_json, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"wrote metrics to {args.metrics_json}")


def run_cluster(args) -> None:
    """Disaggregated serving (``--cluster N_PREFILL N_DECODE``): a router
    control plane over role-restricted worker engines, prefill→decode KV
    handoff, heartbeat liveness + replay, and optional elastic scaling
    (repro.cluster, DESIGN.md §12, docs/serving.md "Cluster mode")."""
    import json

    from repro.cluster import (ClusterConfig, ClusterWorker, LocalBus,
                               ProcBus, Router)
    from repro.cluster.control import ControlConfig
    from repro.cluster.worker import WorkerSpec, build_engine

    n_prefill, n_decode = args.cluster
    if n_prefill < 1 or n_decode < 1:
        raise ValueError("--cluster needs >= 1 prefill and >= 1 decode "
                         "worker")
    if args.model_parallel > 1:
        raise ValueError("--cluster and --model-parallel are exclusive: "
                         "cluster workers are single-process engines")
    cfg = registry.get_config(args.arch, ffn=args.ffn)
    if args.reduced:
        cfg = cfg.reduced(seq=max(64, args.prompt_len + args.gen + 1))
    page = args.page_size or 16          # handoff moves pages: paging is on
    weights = parse_tenant_weights(args.tenant_weights)
    sched_kw = {"weights": weights} if weights and \
        args.scheduler == "weighted_leaf_aware" else {}

    def ecfg_for(role):
        return EngineConfig(
            num_slots=args.batch,
            max_len=args.prompt_len + args.gen + 1,
            max_prompt_len=args.prompt_len,
            prefill_chunk=args.prefill_chunk,
            prefill_budget=args.prefill_budget,
            fff_backend=args.fff_backend,
            pallas_decode=args.pallas_decode,
            capacity_factor=args.capacity_factor,
            overflow_policy=args.overflow_policy,
            spec_k=args.spec_k,
            draft_config=args.draft_config or None,
            page_size=page, seed=args.seed)

    ctrl = ControlConfig(
        heartbeat_timeout=args.heartbeat_timeout,
        scale_up_watermark=args.scale_up_watermark or 1e9,
        scale_down_watermark=args.scale_down_watermark or -1.0,
        max_decode=max(n_decode + 2, n_decode * 2))
    if args.cluster_bus == "local":
        params = lm.init(jax.random.PRNGKey(args.seed), cfg)
        print(f"{cfg.arch_id}: {utils.tree_size(params)/1e6:.1f}M params "
              f"(shared across in-process workers)")
        bus = LocalBus(lambda wid, role: ClusterWorker(
            wid, role, ContinuousBatchingEngine(params, cfg,
                                                ecfg_for(role))))
    else:
        bus = ProcBus(lambda wid, role: WorkerSpec(
            wid=wid, role=role, cfg=cfg, ecfg=ecfg_for(role),
            seed=args.seed, heartbeat_every=1))
    router = Router(bus, ClusterConfig(
        n_prefill=n_prefill, n_decode=n_decode, scheduler=args.scheduler,
        scheduler_kw=sched_kw, control=ctrl, page_size=page),
        clock=time.monotonic)
    router.start()

    reqs = build_requests(args, cfg)
    print(f"cluster: {n_prefill} prefill + {n_decode} decode workers "
          f"({args.cluster_bus} bus), {args.batch} slots each, "
          f"{len(reqs)} requests, prompt lens "
          f"{min(len(r.prompt) for r in reqs)}-"
          f"{max(len(r.prompt) for r in reqs)}, page={page}, "
          f"scheduler={args.scheduler}")

    killed = []

    def on_tick(r):
        if args.cluster_kill and not killed and \
                len(r.results) >= args.cluster_kill:
            victim = next((w for w, v in sorted(r.views.items())
                           if v.role == "decode"), None)
            if victim is not None:
                print(f"FAULT INJECTION: killing decode worker {victim} "
                      f"after {len(r.results)} results")
                killed.append(victim)
                r.kill_worker(victim)

    t0 = time.monotonic()
    results = router.run(reqs, on_tick=on_tick)
    elapsed = time.monotonic() - t0
    m = router.metrics(elapsed_s=elapsed)
    cm = router.cluster_metrics()
    print(m.report())
    print(f"cluster: replayed={cm['replayed_requests']} "
          f"restarts={cm['worker_restarts']} "
          f"handoff={cm['handoff_bytes']/1e6:.2f}MB "
          f"scale_events={len(cm['scale_events'])}")

    parity_ok = None
    if args.cluster_verify:
        # the zero-lost-tokens check: one in-process engine, same seed,
        # same requests — cluster output must be byte-identical
        params = lm.init(jax.random.PRNGKey(args.seed), cfg)
        ref = ContinuousBatchingEngine(params, cfg, ecfg_for("decode"))
        want, _ = ref.run([Request(rid=r.rid, prompt=r.prompt,
                                   max_new_tokens=r.max_new_tokens,
                                   eos_id=r.eos_id, tenant=r.tenant)
                           for r in reqs])
        parity_ok = (
            len(results) == len(want)
            and all(a.rid == b.rid and list(a.tokens) == list(b.tokens)
                    and a.finish_reason == b.finish_reason
                    for a, b in zip(results, want)))
        print(f"parity vs single engine: "
              f"{'EXACT' if parity_ok else 'MISMATCH'}")

    if args.drain:
        router.drain_all()
        deadline = time.monotonic() + 120
        while router.views and time.monotonic() < deadline:
            router.step()
        print(f"drained: {'clean' if not router.views else 'TIMED OUT'} "
              f"({len(router.byes)} goodbyes)")
    router.shutdown()

    if args.metrics_json:
        payload = m.as_dict()
        payload["cluster"] = cm
        payload["topology"] = {"n_prefill": n_prefill, "n_decode": n_decode,
                               "bus": args.cluster_bus,
                               "slots_per_worker": args.batch}
        if parity_ok is not None:
            payload["parity_ok"] = bool(parity_ok)
        with open(args.metrics_json, "w") as f:
            json.dump(payload, f, indent=1, default=_json_default)
        print(f"wrote metrics to {args.metrics_json}")


def _json_default(o):
    import numpy as _np
    if isinstance(o, _np.ndarray):
        return o.tolist()
    if isinstance(o, (_np.integer, _np.floating)):
        return o.item()
    raise TypeError(f"not JSON serializable: {type(o)}")


def run_legacy(args) -> None:
    cfg, params, _mesh, mesh_ctx = _setup(args)
    src = tokens_lib.MarkovTokenSource(cfg.vocab_size, seed=args.seed)
    prompt = jnp.asarray(src.sample(args.batch, args.prompt_len, seed=1)
                         [:, :args.prompt_len])
    max_len = args.prompt_len + args.gen + 1

    batch = {"tokens": prompt}
    if cfg.encoder is not None:
        batch["enc_embeds"] = jnp.asarray(np.random.default_rng(0).normal(
            0, 1, (args.batch, cfg.encoder.seq_len, cfg.d_model)),
            jnp.float32)
    if cfg.frontend != "none" and cfg.encoder is None:
        batch = {"embeds": jnp.asarray(np.random.default_rng(0).normal(
            0, 1, (args.batch, args.prompt_len, cfg.d_model)), jnp.float32)}

    prefill_jit = jax.jit(lambda p, b, c: lm.prefill(p, cfg, b, c))
    decode_jit = jax.jit(lambda p, t, c, off: lm.decode_step(p, cfg, t, c, off))

    # the backend override is read at trace time; wrap every call since any
    # shape change retraces
    def backend_ctx():
        # mode="infer": never let a serving override redirect train-mode math
        kw = {}
        if args.fff_backend != "auto":
            kw.update(backend=args.fff_backend, mode="infer")
        if args.capacity_factor is not None:
            kw["capacity_factor"] = args.capacity_factor
        if args.overflow_policy is not None:
            kw["overflow_policy"] = args.overflow_policy
        return api.overrides(**kw) if kw else contextlib.nullcontext()

    caches = lm.init_caches(cfg, args.batch, max_len)
    t0 = time.time()
    with mesh_ctx(), backend_ctx():
        logits, caches = prefill_jit(params, batch, caches)
    logits.block_until_ready()
    t_prefill = time.time() - t0
    # "requested": ineligible sites fall through to auto heuristics
    # (core/api.py supports predicates), so the label is the override, not
    # a per-site guarantee
    print(f"prefill: {args.batch}x{args.prompt_len} in {t_prefill*1e3:.1f}ms "
          f"(incl. compile, fff backend={args.fff_backend} requested)")

    eos = args.eos_id if args.eos_id >= 0 else None
    tok = logits.argmax(-1)[:, None].astype(jnp.int32)
    out = [tok]
    lat = []
    step_tokens = []                      # real (non-pad) tokens per step
    done = np.zeros((args.batch,), bool)
    for i in range(args.gen):
        if eos is not None:
            done |= np.asarray(tok[:, 0]) == eos
            if done.all():
                break
        t0 = time.time()
        with mesh_ctx(), backend_ctx():
            logits, caches = decode_jit(params, tok, caches,
                                        jnp.int32(args.prompt_len + i))
        logits.block_until_ready()
        lat.append(time.time() - t0)
        step_tokens.append(int(args.batch - done.sum()))  # finished rows: pad
        tok = logits.argmax(-1)[:, None].astype(jnp.int32)
        if eos is not None:
            tok = jnp.where(jnp.asarray(done)[:, None], jnp.int32(eos), tok)
        out.append(tok)
    gen = jnp.concatenate(out, axis=1)
    if lat:
        # steady state excludes the first (compile-laden) step when possible;
        # tokens and time cover the same steps so tok/s is decode-only
        steady = slice(1, None) if len(lat) > 1 else slice(None)
        summary = metrics_lib.summarize(lat[steady])
        tok_s = metrics_lib.tokens_per_second(sum(step_tokens[steady]),
                                              max(sum(lat[steady]), 1e-9))
        print(f"decode: {len(lat)} steps; first {lat[0]*1e3:.1f}ms (compile); "
              + summary.line("steady"))
        print(f"throughput: {tok_s:.1f} tok/s steady decode "
              f"({sum(step_tokens)} decode tokens total)")
    else:
        print("decode: 0 steps (every sequence hit --eos-id at prefill)")
    print("sample continuation:", np.asarray(gen[0])[:12].tolist())


def main(argv=None) -> None:
    args = parse_args(argv)
    if args.cluster is not None:
        run_cluster(args)
    elif args.engine == "continuous":
        run_engine(args)
    else:
        run_legacy(args)


if __name__ == "__main__":
    main()
