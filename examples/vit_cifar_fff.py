"""Paper Table 3 scenario: a vision transformer whose feedforward layers are
fast-feedforward layers, down to single-neuron inference width.

Trains the 4-layer/d128 ViT of the paper on synthetic CIFAR-like data with
l in {32, 8, 1} and prints G_A + the relative drop vs the dense baseline
(paper: 5.8% at l=1).

Run:  PYTHONPATH=src python examples/vit_cifar_fff.py [--steps 150]
"""
import argparse

from benchmarks import table3


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    args = ap.parse_args()
    rows = table3.run(steps=args.steps, leaves=(32, 8, 1))
    base = rows[0]["ga"]
    print(f"\n{'model':12s} {'leaf':>4s} {'G_A':>7s} {'drop':>7s} "
          f"{'ffn speedup':>12s} {'inf width':>9s}")
    for r in rows:
        drop = (base - r["ga"]) / max(base, 1e-9) * 100
        print(f"{r['model']:12s} {r['leaf']:4d} {r['ga']:7.3f} "
              f"{drop:6.1f}% {r['speedup']:11.2f}x {r['inf_width']:9d}")


if __name__ == "__main__":
    main()
