"""Shared kernel plumbing: interpret-mode autodetection, tiling helpers."""
from __future__ import annotations

import jax

from repro import utils


def default_interpret() -> bool:
    """Pallas kernels target TPU; everywhere else run the interpreter
    (bit-accurate Python execution of the kernel body — how this CPU container
    validates them)."""
    return jax.default_backend() != "tpu"


def pick_tile(n: int, preferred: int, align: int = 8) -> int:
    """Largest tile <= preferred that divides n, preferring MXU-aligned."""
    preferred = min(preferred, n)
    for t in range(preferred, 0, -1):
        if n % t == 0 and (t % align == 0 or t == n or t < align):
            return t
    return 1
