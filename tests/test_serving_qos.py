"""Multi-tenant QoS properties (ISSUE 5; DESIGN.md §9).

Host-only tier (pure numpy, no model): weighted-fair admission under
saturation, no-starvation under extreme/arbitrary weights (hypothesis),
stride determinism, routing-profile-store convergence determinism.

Engine tier (reduced config): tenant isolation (a burst cannot evict
another tenant's active slots), the hint-mismatch warn-once + counter fix,
online profile learning end-to-end, and the per-tenant metrics schema.
"""
import numpy as np
import pytest

try:        # the property test is extra assurance where hypothesis exists
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                  # pragma: no cover - env-dependent
    HAVE_HYPOTHESIS = False

from repro.configs import registry
from repro.models import lm
from repro.serving import (ContinuousBatchingEngine, EngineConfig, Request,
                           RoutingProfileStore, make_scheduler)
from repro.serving.scheduler import SchedulerView

import jax


# ---------------------------------------------------------------------------
# host-only tier: weighted scheduler properties
# ---------------------------------------------------------------------------

def _view(num_slots=8, E=4, occupancy=None, active=None, cf=2.0,
          profiles=None):
    return SchedulerView(
        occupancy=(occupancy if occupancy is not None
                   else np.zeros((num_slots, E))),
        active=(active if active is not None
                else np.zeros((num_slots,), bool)),
        num_leaves=E, capacity_factor=cf, num_slots=num_slots,
        profiles=profiles)


def _req(rid, tenant="default", hint=None, L=4):
    return Request(rid=rid, prompt=np.ones((L,), np.int32),
                   max_new_tokens=4, leaf_hint=hint, tenant=tenant)


def _drain(sched, waiting, n, view):
    """n single-slot admission rounds; returns the admitted tenants."""
    out = []
    for _ in range(n):
        got = sched.select(waiting, 1, view)
        assert len(got) == 1, "scheduler must admit when a slot is free"
        waiting.remove(got[0])
        out.append(got[0].tenant)
    return out

def test_weighted_fairness_under_saturation():
    """With both tenants backlogged throughout, admissions split in weight
    proportion (stride scheduling is exact up to rounding per cycle)."""
    s = make_scheduler("weighted_leaf_aware", weights={"a": 3.0, "b": 1.0})
    waiting = [_req(i, tenant=("a" if i % 2 else "b")) for i in range(80)]
    admitted = _drain(s, waiting, 40, _view())
    assert admitted.count("a") == 30
    assert admitted.count("b") == 10


def test_weighted_share_tracks_weights_three_tenants():
    w = {"a": 4.0, "b": 2.0, "c": 1.0}
    s = make_scheduler("weighted_leaf_aware", weights=w)
    waiting = [_req(i, tenant="abc"[i % 3]) for i in range(210)]
    admitted = _drain(s, waiting, 70, _view())
    assert admitted.count("a") == 40
    assert admitted.count("b") == 20
    assert admitted.count("c") == 10


def test_weighted_fifo_within_tenant_without_telemetry():
    """No telemetry (E=0): within each tenant, admissions stay FIFO."""
    s = make_scheduler("weighted_leaf_aware", weights={"a": 2.0, "b": 1.0})
    waiting = [_req(i, tenant=("a" if i < 5 else "b")) for i in range(10)]
    order = {"a": [], "b": []}
    for _ in range(10):
        got = s.select(waiting, 1, _view(E=0))
        waiting.remove(got[0])
        order[got[0].tenant].append(got[0].rid)
    assert order["a"] == sorted(order["a"])
    assert order["b"] == sorted(order["b"])


def test_weighted_unlisted_tenant_gets_default_weight():
    s = make_scheduler("weighted_leaf_aware", weights={"vip": 3.0},
                       default_weight=1.0)
    waiting = [_req(i, tenant=("vip" if i % 2 else "anon"))
               for i in range(40)]
    admitted = _drain(s, waiting, 20, _view())
    assert admitted.count("vip") == 15
    assert admitted.count("anon") == 5


def test_weighted_drip_feed_tenant_cannot_dodge_stride_debt():
    """A tenant whose queue drains every time it wins (drip-feed, one
    request in flight at a time) must still be held to its weight: the
    stride debt it consumed survives the moments it has nothing waiting."""
    s = make_scheduler("weighted_leaf_aware", weights={"gold": 3.0,
                                                       "free": 1.0})
    gold = [_req(i, tenant="gold") for i in range(60)]
    admitted = []
    next_free_rid = 1000
    drip = [_req(next_free_rid, tenant="free")]
    for _ in range(40):
        waiting = gold + drip            # free offers at most one request
        got = s.select(waiting, 1, _view())
        assert len(got) == 1
        admitted.append(got[0].tenant)
        if got[0].tenant == "free":
            next_free_rid += 1
            drip = [_req(next_free_rid, tenant="free")]   # fresh drip
        else:
            gold.remove(got[0])
    assert admitted.count("gold") == 30
    assert admitted.count("free") == 10


def test_weighted_idle_tenant_rejoins_without_burst_catchup():
    """A tenant absent for many rounds must NOT monopolize admission on
    return: it rejoins at the current virtual time, not its stale pass."""
    s = make_scheduler("weighted_leaf_aware", weights={"a": 1.0, "b": 1.0})
    waiting = [_req(i, tenant="a") for i in range(20)]
    _drain(s, waiting, 10, _view())               # b idle for 10 rounds
    waiting += [_req(100 + i, tenant="b") for i in range(20)]
    admitted = _drain(s, waiting, 10, _view())
    # equal weights -> the comeback tenant gets ~half, not everything
    assert 4 <= admitted.count("b") <= 6


def test_weighted_rejects_bad_weights():
    with pytest.raises(ValueError, match="positive"):
        make_scheduler("weighted_leaf_aware", weights={"a": 0.0})
    with pytest.raises(ValueError, match="positive"):
        make_scheduler("weighted_leaf_aware", default_weight=-1.0)
    # inf would zero the stride: that tenant's pass never advances and it
    # wins every admission — exactly the starvation the class forbids
    with pytest.raises(ValueError, match="finite"):
        make_scheduler("weighted_leaf_aware", weights={"a": float("inf")})
    with pytest.raises(ValueError, match="finite"):
        make_scheduler("weighted_leaf_aware", default_weight=float("nan"))


def test_weighted_deterministic():
    rng = np.random.default_rng(0)
    ws = [_req(i, tenant="ab"[i % 2], hint=rng.dirichlet(np.ones(4)))
          for i in range(12)]
    picks = []
    for _ in range(2):
        s = make_scheduler("weighted_leaf_aware", weights={"a": 2.0})
        picks.append([r.rid for r in s.select(list(ws), 6, _view(E=4))])
    assert picks[0] == picks[1]


def test_weighted_leaf_aware_composes_within_tenant():
    """The winning tenant's pick is leaf-aware: with load on leaf 0 and the
    tenant offering a hot and a cold candidate, the cold one admits first."""
    E = 4
    occ = np.zeros((8, E))
    occ[0] = occ[1] = [1.0, 0, 0, 0]
    active = np.zeros((8,), bool)
    active[:2] = True
    hot = np.array([1.0, 0, 0, 0])
    cold = np.array([0, 1.0, 0, 0])
    s = make_scheduler("weighted_leaf_aware", weights={"a": 1.0})
    ws = [_req(0, "a", hot), _req(1, "a", hot), _req(2, "a", cold)]
    view = _view(num_slots=8, E=E, occupancy=occ, active=active, cf=0.01)
    assert [r.rid for r in s.select(ws, 1, view)] == [2]


def test_weighted_footprint_falls_back_to_profile():
    """Hint-less candidates draw their footprint from the tenant's learned
    routing profile, steering composition exactly like a hint would."""
    E = 4
    occ = np.zeros((8, E))
    occ[0] = occ[1] = [1.0, 0, 0, 0]
    active = np.zeros((8,), bool)
    active[:2] = True
    profiles = RoutingProfileStore(E)
    profiles.update("hot", np.array([1.0, 0, 0, 0]))
    profiles.update("cold", np.array([0, 1.0, 0, 0]))
    s = make_scheduler("weighted_leaf_aware")
    ws = [_req(0, "hot"), _req(1, "hot"), _req(2, "cold")]   # no hints
    view = _view(num_slots=8, E=E, occupancy=occ, active=active, cf=0.01,
                 profiles=profiles)
    assert [r.rid for r in s.select(ws, 1, view)] == [2]


def _assert_no_starvation(w_a, w_b, order):
    """Progress + eventual admission for any positive weights and arrival
    pattern: extreme weight ratios skew shares, never liveness."""
    s = make_scheduler("weighted_leaf_aware", weights={"a": w_a, "b": w_b})
    waiting = [_req(i, tenant=t) for i, t in enumerate(order)]
    view = _view()
    seen = set()
    for _ in range(len(order)):
        got = s.select(waiting, 1, view)
        assert len(got) == 1
        seen.add(got[0].rid)
        waiting.remove(got[0])
    assert seen == set(range(len(order)))


def test_weighted_no_starvation_extreme_weights_deterministic():
    _assert_no_starvation(1000.0, 0.001, ["a", "b"] * 15)
    _assert_no_starvation(0.001, 1000.0, ["a"] * 10 + ["b"] * 10)


if HAVE_HYPOTHESIS:
    @settings(max_examples=30, deadline=None)
    @given(w_a=st.floats(0.001, 1000.0), w_b=st.floats(0.001, 1000.0),
           order=st.lists(st.sampled_from(["a", "b"]), min_size=1,
                          max_size=30))
    def test_weighted_no_starvation_extreme_weights(w_a, w_b, order):
        _assert_no_starvation(w_a, w_b, order)


# ---------------------------------------------------------------------------
# host-only tier: routing-profile store
# ---------------------------------------------------------------------------

def test_profile_store_convergence_determinism():
    """Two stores fed the same update sequence are bit-identical, and a
    stationary input converges to itself."""
    rng = np.random.default_rng(0)
    rows = [rng.dirichlet(np.ones(8)) for _ in range(50)]
    stores = [RoutingProfileStore(8, ewma=0.3) for _ in range(2)]
    for st_ in stores:
        for r in rows:
            st_.update("t", r)
    np.testing.assert_array_equal(stores[0].lookup("t"),
                                  stores[1].lookup("t"))
    fixed = np.array([0.0, 0.25, 0.75, 0.0])
    store = RoutingProfileStore(4, ewma=0.5)
    for _ in range(30):
        store.update("t", fixed * 10.0)         # any scale: normalized
    np.testing.assert_allclose(store.lookup("t"), fixed, atol=1e-6)
    assert store.n_updates("t") == 30


def test_profile_store_gates_and_filters():
    store = RoutingProfileStore(4, min_updates=2)
    assert store.lookup("t") is None
    store.update("t", np.zeros(4))              # zero mass: no signal
    store.update("t", np.ones(8))               # wrong width: rejected
    assert store.n_updates("t") == 0
    store.update("t", np.array([1.0, 0, 0, 0]))
    assert store.lookup("t") is None            # below min_updates
    store.update("t", np.array([1.0, 0, 0, 0]))
    np.testing.assert_allclose(store.lookup("t"), [1, 0, 0, 0])
    assert store.tenants() == ["t"]
    assert store.as_dict()["t"]["dominant_leaf"] == 0


def test_profile_store_lookup_returns_copy():
    store = RoutingProfileStore(2)
    store.update("t", np.array([1.0, 1.0]))
    got = store.lookup("t")
    got[:] = 0.0
    np.testing.assert_allclose(store.lookup("t"), [0.5, 0.5])


def test_profile_store_validates_args():
    with pytest.raises(ValueError, match="num_leaves"):
        RoutingProfileStore(0)
    with pytest.raises(ValueError, match="ewma"):
        RoutingProfileStore(4, ewma=0.0)
    with pytest.raises(ValueError, match="min_updates"):
        RoutingProfileStore(4, min_updates=0)


def test_request_validates_tenant():
    with pytest.raises(ValueError, match="tenant"):
        Request(rid=0, prompt=np.ones(4, np.int32), tenant="")


def test_request_rejects_nonfinite_hint():
    # NaN slips every sum()<=0 usability predicate and would poison the
    # scheduler's accumulated load — reject at construction
    for bad in (np.array([np.nan, 1.0]), np.array([np.inf, 0.0])):
        with pytest.raises(ValueError, match="finite"):
            Request(rid=0, prompt=np.ones(4, np.int32), leaf_hint=bad)


def test_parse_tenant_weights_cli_boundary():
    from repro.launch.serve import parse_tenant_weights
    assert parse_tenant_weights("gold=3,free=1") == {"gold": 3.0, "free": 1.0}
    assert parse_tenant_weights("") == {}
    with pytest.raises(ValueError, match="not tenant=weight"):
        parse_tenant_weights("gold")
    with pytest.raises(ValueError, match="not a number"):
        parse_tenant_weights("gold=abc")
    with pytest.raises(ValueError, match="positive and finite"):
        parse_tenant_weights("gold=0")
    with pytest.raises(ValueError, match="positive and finite"):
        parse_tenant_weights("gold=inf")
    with pytest.raises(ValueError, match="twice"):
        parse_tenant_weights("gold=3,free=1,gold=1")


# ---------------------------------------------------------------------------
# engine tier (reduced config)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def model():
    cfg = registry.get_config("internlm2-20b", ffn="fff").reduced()
    params = lm.init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _engine(cfg, params, **kw):
    defaults = dict(num_slots=4, max_len=48, max_prompt_len=16, seed=0)
    defaults.update(kw)
    return ContinuousBatchingEngine(params, cfg, EngineConfig(**defaults))


def test_tenant_isolation_burst_cannot_evict_active(model):
    """One tenant's burst must not displace another tenant's ACTIVE slots:
    the victim's in-flight requests keep their slot objects until they
    finish on their own terms, and complete their full token budget."""
    cfg, params = model
    eng = _engine(cfg, params, num_slots=2,
                  scheduler="weighted_leaf_aware",
                  scheduler_kw={"weights": {"burst": 100.0, "victim": 1.0}})
    rng = np.random.default_rng(0)
    victims = [Request(rid=i, prompt=rng.integers(1, 256, 6),
                       max_new_tokens=8, tenant="victim") for i in range(2)]
    for r in victims:
        eng.submit(r)
    eng.step()                      # both victims admitted and decoding
    active = [s for s in eng.slots if s is not None]
    assert len(active) == 2
    for j in range(10):             # the adversarial burst, huge weight
        eng.submit(Request(rid=100 + j, prompt=rng.integers(1, 256, 6),
                           max_new_tokens=1, tenant="burst"))
    while not all(s.done for s in active):
        # the victim slot objects stay installed until they finish
        assert [s for s in eng.slots if s is not None
                and s.request.tenant == "victim"] == active
        eng.step()
    while eng.has_work():
        eng.step()
    vres = [r for r in eng.results if r.tenant == "victim"]
    assert len(vres) == 2
    assert all(r.n_generated == 8 and r.finish_reason == "length"
               for r in vres)


def test_hint_mismatch_warns_once_and_counts(model):
    """The ISSUE 5 fix for silent hint drops: first mismatched leaf_hint
    warns, later ones only count; the counter lands in the metrics."""
    cfg, params = model
    eng = _engine(cfg, params)
    E = eng.num_leaves
    assert E > 0
    bad = np.ones(E + 3)
    with pytest.warns(UserWarning, match="leaf_hint"):
        eng.submit(Request(rid=0, prompt=np.ones(4, np.int32),
                           max_new_tokens=1, leaf_hint=bad))
    import warnings as warnings_mod
    with warnings_mod.catch_warnings(record=True) as record:
        warnings_mod.simplefilter("always")
        eng.submit(Request(rid=1, prompt=np.ones(4, np.int32),
                           max_new_tokens=1, leaf_hint=bad.copy()))
    assert not [w for w in record if issubclass(w.category, UserWarning)], \
        "second mismatch must not warn again"
    while eng.has_work():
        eng.step()
    assert eng.poll_metrics().hint_mismatches == 2
    # zero-mass hints are just as unusable as wrong-sized ones — silently
    # equivalent to "no hint" unless counted
    eng.submit(Request(rid=10, prompt=np.ones(4, np.int32),
                       max_new_tokens=1, leaf_hint=np.zeros(E)))
    while eng.has_work():
        eng.step()
    assert eng.poll_metrics().hint_mismatches == 3
    # a correctly sized hint does not count
    good = np.ones(E)
    eng.submit(Request(rid=2, prompt=np.ones(4, np.int32),
                       max_new_tokens=1, leaf_hint=good))
    while eng.has_work():
        eng.step()
    assert eng.poll_metrics().hint_mismatches == 3


def test_profiles_learned_from_finished_requests(model):
    """Hint-less requests teach the store: after serving, the tenant has a
    normalized footprint with one update per finished request."""
    cfg, params = model
    eng = _engine(cfg, params)
    rng = np.random.default_rng(1)
    reqs = [Request(rid=i, prompt=rng.integers(1, 256, 8),
                    max_new_tokens=4, tenant="t0") for i in range(3)]
    eng.run(reqs)
    assert eng.profiles is not None
    assert eng.profiles.n_updates("t0") == 3
    fp = eng.profiles.lookup("t0")
    assert fp is not None and fp.shape == (eng.num_leaves,)
    assert fp.min() >= 0 and fp.sum() == pytest.approx(1.0)


def test_learn_profiles_off(model):
    cfg, params = model
    eng = _engine(cfg, params, learn_profiles=False)
    eng.run([Request(rid=0, prompt=np.ones(4, np.int32), max_new_tokens=1)])
    assert eng.profiles is None


def test_profiles_not_fed_by_seeded_priors(model):
    """With telemetry off, occupancy rows only ever hold seeded priors —
    the store must not EWMA hints (or its own output) back into itself."""
    cfg, params = model
    eng = _engine(cfg, params, telemetry=False)
    E = eng.num_leaves
    hint = np.zeros(E)
    hint[0] = 1.0
    eng.run([Request(rid=0, prompt=np.ones(4, np.int32), max_new_tokens=2,
                     tenant="t0", leaf_hint=hint)])
    assert eng.profiles is not None
    assert eng.profiles.n_updates("t0") == 0, \
        "seeded prior was promoted as if it were a measurement"


def test_per_tenant_metrics_and_queue_depths(model):
    """run() metrics carry the per-tenant breakdown; poll_metrics adds live
    per-tenant queue depth for still-waiting tenants."""
    cfg, params = model
    eng = _engine(cfg, params)
    rng = np.random.default_rng(2)
    reqs = [Request(rid=i, prompt=rng.integers(1, 256, 5), max_new_tokens=2,
                    tenant=("gold" if i % 2 else "free")) for i in range(6)]
    _, m = eng.run(reqs)
    assert set(m.tenants) == {"gold", "free"}
    assert m.tenants["gold"]["n_requests"] == 3
    assert m.tenants["free"]["n_tokens"] == 6
    d = m.as_dict()
    assert "tenants" in d and "hint_mismatches" in d
    assert d["tenants"]["gold"]["ttft_ms"]["n"] == 3
    # live depths: submit without stepping, then poll
    for i in range(3):
        eng.submit(Request(rid=100 + i, prompt=np.ones(4, np.int32),
                           max_new_tokens=1, tenant="queued"))
    live = eng.poll_metrics()
    assert live.tenants["queued"]["queue_depth"] == 3
    while eng.has_work():
        eng.step()


def test_weighted_engine_serves_all_and_matches_generate(model):
    """The weighted scheduler only reorders admission: greedy outputs still
    match the synchronous lm.generate path per request."""
    import jax.numpy as jnp
    cfg, params = model
    eng = _engine(cfg, params, scheduler="weighted_leaf_aware",
                  scheduler_kw={"weights": {"a": 2.0, "b": 1.0}})
    rng = np.random.default_rng(3)
    reqs = [Request(rid=i, prompt=rng.integers(1, 256, int(rng.integers(3, 17))),
                    max_new_tokens=5, tenant="ab"[i % 2]) for i in range(6)]
    results, m = eng.run(reqs)
    assert sorted(r.rid for r in results) == list(range(6))
    for r in results:
        want = lm.generate(params, cfg, jnp.asarray(r.prompt[None]),
                           steps=r.n_generated, max_len=48)
        np.testing.assert_array_equal(
            np.asarray(want)[0], np.concatenate([r.prompt, r.tokens]),
            err_msg=f"rid {r.rid}")
