"""Gradient accumulation: microbatch the global batch through a lax.scan so
arbitrarily large global batches fit device memory (shrinking-batch-problem
mitigation from the paper, and the standard LLM trick)."""
from __future__ import annotations

from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

PyTree = Any


def gradient_accumulation(loss_fn: Callable, num_micro: int) -> Callable:
    """loss_fn(params, batch, rng) -> (loss, metrics).

    Returns grad_fn(params, batch, rng) -> (grads, (loss, metrics)) where the
    batch's leading dim is split into ``num_micro`` microbatches processed
    sequentially with donated accumulators."""
    vg = jax.value_and_grad(loss_fn, has_aux=True)

    def grad_fn(params: PyTree, batch: PyTree, rng: Optional[jax.Array] = None):
        if num_micro <= 1:
            (loss, metrics), grads = vg(params, batch, rng)
            return grads, (loss, metrics)

        def split(x):
            b = x.shape[0]
            return x.reshape(num_micro, b // num_micro, *x.shape[1:])

        micro = jax.tree_util.tree_map(split, batch)
        rngs = (jax.random.split(rng, num_micro) if rng is not None
                else jnp.zeros((num_micro,), jnp.uint32))

        def body(carry, xs):
            g_acc, l_acc = carry
            mb, r = xs
            (loss, _), grads = vg(params, mb, r if rng is not None else None)
            g_acc = jax.tree_util.tree_map(
                lambda a, g: a + g.astype(jnp.float32), g_acc, grads)
            return (g_acc, l_acc + loss), None

        g0 = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (g_sum, l_sum), _ = jax.lax.scan(body, (g0, jnp.zeros(())), (micro, rngs))
        scale = 1.0 / num_micro
        grads = jax.tree_util.tree_map(lambda g: g * scale, g_sum)
        loss = l_sum * scale
        return grads, (loss, {"loss": loss})

    return grad_fn
