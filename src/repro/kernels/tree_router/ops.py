"""Jitted wrapper for the tree_router kernel: padding, the dense/gather level
split for deep trees, and multi-tree (forest) batching."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro import utils
from repro.kernels import common
from repro.kernels.tree_router import kernel as K
from repro.kernels.tree_router import ref as R


@partial(jax.jit, static_argnames=("depth", "dense_levels", "block_b",
                                   "interpret"))
def route(x: jax.Array, node_w: jax.Array, node_b: jax.Array, *, depth: int,
          dense_levels: int | None = None, block_b: int = 256,
          interpret: bool | None = None) -> jax.Array:
    """Leaf index per token.  x (B, D); node_w (N, D); node_b (N,).

    ``dense_levels`` caps how many levels the fused dense-logit kernel
    handles; the remainder descends with per-token gathers (cheaper once
    2^m >> d — crossover analysis in DESIGN.md §8).  Default: all levels up
    to 8 are dense."""
    if interpret is None:
        interpret = common.default_interpret()
    if dense_levels is None:
        dense_levels = min(depth, 8)
    dense_levels = min(dense_levels, depth)
    B, D = x.shape

    if dense_levels == 0:
        return R.tree_router_ref(x, node_w, node_b, depth=depth)

    block_b = common.pick_tile(B, block_b)
    pad = (-B) % block_b
    xp = jnp.pad(x, ((0, pad), (0, 0))) if pad else x
    n_dense = 2 ** dense_levels - 1
    idx = K.tree_router(xp, node_w[:n_dense], node_b[:n_dense],
                        depth=dense_levels, block_b=block_b,
                        interpret=interpret)
    idx = idx[:B]

    # finish deep levels with the gather path
    for m in range(dense_levels, depth):
        g = (2 ** m - 1) + idx
        w = jnp.take(node_w, g, axis=0)
        b = jnp.take(node_b, g, axis=0)
        logit = jnp.einsum("bd,bd->b", x.astype(jnp.float32),
                           w.astype(jnp.float32)) + b.astype(jnp.float32)
        idx = 2 * idx + (logit >= 0.0).astype(jnp.int32)
    return idx


def route_forest(x: jax.Array, node_w: jax.Array, node_b: jax.Array, *,
                 depth: int, **kw) -> jax.Array:
    """Forest variant: node_w (T, N, D), node_b (T, N) -> (B, T)."""
    f = jax.vmap(lambda w, b: route(x, w, b, depth=depth, **kw))
    return f(node_w, node_b).T
