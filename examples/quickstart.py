"""Quickstart: the FFF layer as a drop-in feedforward replacement.

Trains a small fast-feedforward network on a synthetic image task, watches
the hardening process, then serves it with hard (FORWARD_I) routing — the
whole paper in ~60 lines of user code.

Everything goes through the one entry point::

    y, out = api.apply(params, cfg, x, api.ExecutionSpec(mode=..., backend=...))

``mode`` picks the paper's semantics (FORWARD_T soft mixture for training,
FORWARD_I single-leaf descent for inference); ``backend`` picks the
implementation from a registry — ``"auto"`` (default) resolves per platform
and shape, and step 5 below registers a custom backend to show the seam.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.core import api, fff
from repro.data import synthetic

# --- 1. data ---------------------------------------------------------------
ds = synthetic.make("mnist_like")
print(f"dataset: {ds.x_train.shape[0]} train / {ds.x_test.shape[0]} test, "
      f"dim={ds.dim}, classes={ds.num_classes}")

# --- 2. an FFF layer: depth 4, leaf width 8 => training width 128,
#        inference width 8 (the paper's headline trade) -----------------------
cfg = fff.FFFConfig(dim_in=ds.dim, dim_out=ds.num_classes, depth=4,
                    leaf_width=8, activation="relu", hardening_scale=3.0)
params = fff.init(jax.random.PRNGKey(0), cfg)
print(f"FFF: training width {cfg.training_width}, inference width "
      f"{cfg.inference_width}, {cfg.num_leaves} leaves; execution backends "
      f"registered for inference: {api.list_backends('infer')}")

# --- 3. train with the hardening loss (paper: L_total = L_pred + h*L_harden)
opt = optim.sgd(0.2)
state = opt.init(params)
TRAIN = api.ExecutionSpec(mode="train")                    # FORWARD_T


def loss_fn(p, x, y):
    logits, out = api.apply(p, cfg, x, TRAIN)
    ce = -jnp.mean(jnp.take_along_axis(jax.nn.log_softmax(logits),
                                       y[:, None], 1))
    return ce + cfg.hardening_scale * fff.hardening_loss(out.node_probs), \
        out.entropy


@jax.jit
def step(p, s, x, y):
    (l, ent), g = jax.value_and_grad(loss_fn, has_aux=True)(p, x, y)
    u, s = opt.update(g, s, p)
    return optim.apply_updates(p, u), s, l, ent


rng = np.random.default_rng(0)
for i in range(300):
    sel = rng.integers(0, len(ds.x_train), 256)
    params, state, l, ent = step(params, state, jnp.asarray(ds.x_train[sel]),
                                 jnp.asarray(ds.y_train[sel]))
    if i % 50 == 0:
        print(f"step {i:3d}  loss {float(l):.3f}  "
              f"mean node entropy {float(ent):.3f}  (hardening toward 0)")

# --- 4. serve with hard routing (FORWARD_I): one leaf per input -------------
INFER = api.ExecutionSpec(mode="infer")                    # backend="auto"
logits_hard, out = api.apply(params, cfg, jnp.asarray(ds.x_test), INFER)
acc = float((np.asarray(logits_hard.argmax(-1)) == ds.y_test).mean())
logits_soft, _ = api.apply(params, cfg, jnp.asarray(ds.x_test), TRAIN)
agree = float((logits_soft.argmax(-1) == logits_hard.argmax(-1)).mean())
print(f"\nhard-inference accuracy: {acc:.3f}  "
      f"(soft/hard agreement {agree:.3f} — hardening carried over)")

# --- 5. the registry seam: plug in a custom execution backend ---------------
# A backend is any fn(params, cfg, x, spec) -> (y, FFFOutput).  This toy one
# wraps the reference path and rounds outputs to bf16 — a stand-in for
# quantized serving, remote execution, new kernels, ...
def bf16_backend(p, c, x, spec):
    y, out = api.get_backend("infer", "reference")(p, c, x, spec)
    return y.astype(jnp.bfloat16).astype(jnp.float32), out


api.register_backend("infer", "bf16-demo", bf16_backend)
logits_q, _ = api.apply(params, cfg, jnp.asarray(ds.x_test),
                        api.ExecutionSpec(mode="infer", backend="bf16-demo"))
agree_q = float((logits_q.argmax(-1) == logits_hard.argmax(-1)).mean())
print(f"custom 'bf16-demo' backend agreement with exact serving: {agree_q:.3f}")

# --- 6. the learned partition of the input space (paper §Regionalization) ---
hist = np.bincount(np.asarray(out.leaf_idx[:, 0]),
                   minlength=cfg.num_leaves)
print(f"leaf load histogram over test set: {hist.tolist()}")
