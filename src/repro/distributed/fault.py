"""Fault tolerance: the training supervisor (checkpoint/restart loop).

``TrainSupervisor`` wraps a step function with:
  * periodic checkpointing through CheckpointManager (async, atomic)
  * crash recovery: on any step exception, restore the latest committed
    checkpoint and resume (bounded retries, exponential backoff budget)
  * straggler escalation hooks (distributed/straggler.py): on "eject", the
    supervisor raises ElasticRemesh so the launcher rebuilds the mesh with the
    surviving hosts and re-enters with reshard_restore

Failure injection for tests: pass ``failure_hook(step) -> bool``.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Any, Callable, Optional

from repro.checkpoint.manager import CheckpointManager

log = logging.getLogger("repro.fault")

PyTree = Any


class ElasticRemesh(Exception):
    """Raised to request a re-mesh onto ``surviving_hosts``."""

    def __init__(self, surviving_hosts: list[int]):
        super().__init__(f"elastic re-mesh onto {len(surviving_hosts)} hosts")
        self.surviving_hosts = surviving_hosts


@dataclasses.dataclass
class SupervisorConfig:
    ckpt_every: int = 100
    max_restarts: int = 5
    keep: int = 3
    backoff_base: float = 0.0     # first retry delay (s); 0 disables sleeps
    backoff_factor: float = 2.0


class RestartBackoff:
    """Exponential-backoff restart budget, shared by the training
    supervisor and the cluster monitor (cluster/control.py).

    ``next_delay()`` spends one restart from the budget and returns the
    delay before the retry (``base * factor**n``), or None once the budget
    is exhausted — the caller escalates (raise / mark the worker
    permanently dead).  ``reset()`` refunds the budget after sustained
    health."""

    def __init__(self, max_restarts: int = 5, base: float = 0.0,
                 factor: float = 2.0):
        self.max_restarts = max_restarts
        self.base = base
        self.factor = factor
        self.restarts = 0

    def next_delay(self) -> Optional[float]:
        if self.restarts >= self.max_restarts:
            return None
        delay = self.base * (self.factor ** self.restarts)
        self.restarts += 1
        return delay

    def reset(self) -> None:
        self.restarts = 0


@dataclasses.dataclass
class RunResult:
    state: PyTree
    step: int
    restarts: int
    ejections: int


class TrainSupervisor:
    def __init__(self, manager: CheckpointManager,
                 cfg: SupervisorConfig = SupervisorConfig(),
                 sleep_fn: Callable[[float], None] = time.sleep):
        self.manager = manager
        self.cfg = cfg
        self.sleep_fn = sleep_fn

    def run(self, state: PyTree, step_fn: Callable[[PyTree, int], PyTree],
            num_steps: int, *,
            failure_hook: Optional[Callable[[int], bool]] = None,
            straggler_hook: Optional[Callable[[int], Optional[list[int]]]] = None
            ) -> RunResult:
        """Run ``num_steps`` of ``step_fn`` with checkpoint/restart semantics.

        step_fn(state, step) -> state.  Deterministic given (state, step), so
        replay after restore is consistent.
        """
        start = 0
        ejections = 0
        backoff = RestartBackoff(self.cfg.max_restarts,
                                 self.cfg.backoff_base,
                                 self.cfg.backoff_factor)
        if self.manager.latest_step() is not None:
            state, start, _ = self.manager.restore(state)
            log.info("resuming from step %d", start)

        step = start
        while step < num_steps:
            try:
                if failure_hook is not None and failure_hook(step):
                    raise RuntimeError(f"injected failure at step {step}")
                state = step_fn(state, step)
                step += 1
                if step % self.cfg.ckpt_every == 0 or step == num_steps:
                    self.manager.save(step, state)
                if straggler_hook is not None:
                    eject = straggler_hook(step)
                    if eject:
                        ejections += 1
                        self.manager.save(step, state, block=True)
                        raise ElasticRemesh(eject)
            except ElasticRemesh:
                raise
            except Exception as e:                        # noqa: BLE001
                delay = backoff.next_delay()
                if delay is None:
                    raise RuntimeError(
                        f"exceeded {self.cfg.max_restarts} restarts") from e
                log.warning("step %d failed (%s); restoring", step, e)
                if delay > 0:
                    self.sleep_fn(delay)
                self.manager.wait()
                if self.manager.latest_step() is not None:
                    state, step, _ = self.manager.restore(state)
                else:
                    step = 0
        self.manager.wait()
        return RunResult(state, step, backoff.restarts, ejections)
