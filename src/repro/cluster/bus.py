"""Cluster transport: message types + the two pluggable buses (DESIGN.md
§12).

Topology is a star: the router owns one control-plane mailbox; every worker
has an inbox the router posts to (``send``) and all worker→router traffic
funnels back through ``poll``.  Two implementations share that contract:

* ``LocalBus`` — in-process, deterministic.  Workers are plain objects
  stepped round-robin in wid order by ``pump()``; with a ``VirtualClock``
  the whole cluster (heartbeats, timeouts, elastic watermarks) runs in
  virtual time with zero sleeps.  Failure injection: a worker whose
  ``failure_hook`` fires raises ``WorkerKilled`` and the bus drops it cold
  — undelivered inbox and all — exactly like a crashed process.
* ``ProcBus`` — ``multiprocessing`` (spawn context: jax is not fork-safe),
  one process per worker, ``Queue`` mailboxes.  Workers rebuild params
  from ``(cfg, seed)`` inside their process (determinism makes the rebuild
  exact; pickling a sharded param tree would not survive the trip).
  ``kill()`` SIGKILLs — the fault-injection path ``serve.py
  --cluster-kill`` and the CI worker-kill e2e use.

Both buses surface liveness (``alive``) but neither *interprets* it: dead-
worker detection is the monitor's heartbeat-timeout logic (control.py), so
tests can exercise replay without a real process dying.
"""
from __future__ import annotations

import dataclasses
import queue as queue_lib
from collections import deque
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.cluster.handoff import KVHandoff
from repro.serving.request import Request, RequestResult


# -- router -> worker ------------------------------------------------------

@dataclasses.dataclass
class Submit:
    """Admit this request on a prefill worker."""
    req: Request


@dataclasses.dataclass
class Install:
    """Take ownership of a completed prefill (decode worker)."""
    handoff: KVHandoff


@dataclasses.dataclass
class Drain:
    """Finish in-flight work, accept nothing new, report ``Drained``."""


@dataclasses.dataclass
class Stop:
    """Exit after the current step (final stats ride the ``Bye``)."""


# -- worker -> router ------------------------------------------------------

@dataclasses.dataclass
class PrefillDone:
    """A prompt's KV pages are ready to travel (router places the decode)."""
    wid: str
    handoff: KVHandoff


@dataclasses.dataclass
class Done:
    """A request finished on this worker."""
    wid: str
    result: RequestResult


@dataclasses.dataclass
class Heartbeat:
    """Per-step liveness + the placement signals (``poll_metrics`` slice)."""
    wid: str
    role: str
    t: float                        # sender's cluster clock
    n_ticks: int
    pages_free: int = 0
    pages_total: int = 0
    queue_depth: int = 0            # engine queue + pending installs
    active_slots: int = 0
    num_slots: int = 0
    occupancy: Optional[np.ndarray] = None   # live leaf footprint (mean)
    profiles: Optional[dict] = None          # learned per-tenant footprints
    compiled_shapes: Optional[dict] = None
    handoff_bytes: int = 0
    draining: bool = False


@dataclasses.dataclass
class Drained:
    wid: str


@dataclasses.dataclass
class Bye:
    """Final stats on clean shutdown (``Stop``)."""
    wid: str
    compiled_shapes: dict
    metrics: dict


class WorkerKilled(Exception):
    """Raised inside a LocalBus worker tick to simulate a crash."""

    def __init__(self, wid: str):
        super().__init__(f"worker {wid} killed")
        self.wid = wid


class LocalBus:
    """Deterministic in-process transport (module docstring).

    ``factory(wid, role)`` builds a ``cluster.worker.ClusterWorker``; the
    bus steps live workers in sorted-wid order each ``pump()`` and
    advances ``clock`` by ``tick_dt`` when the clock supports it (a
    ``VirtualClock``) so heartbeat timestamps move without wall time."""

    def __init__(self, factory: Callable[[str, str], object],
                 clock: Optional[Callable[[], float]] = None,
                 tick_dt: float = 0.01):
        self._factory = factory
        self._workers: Dict[str, object] = {}
        self._out: deque = deque()
        self._clock = clock
        self._tick_dt = tick_dt
        self.dead: set = set()

    def spawn(self, wid: str, role: str) -> None:
        if wid in self._workers:
            raise ValueError(f"worker {wid} already exists")
        self._workers[wid] = self._factory(wid, role)

    def send(self, wid: str, msg) -> bool:
        w = self._workers.get(wid)
        if w is None:
            return False
        w.inbox.append(msg)
        return True

    def pump(self) -> None:
        for wid in sorted(self._workers):
            w = self._workers[wid]
            try:
                self._out.extend(w.tick())
            except WorkerKilled:
                # a crash loses everything in the process: slot state,
                # queued installs, the undelivered inbox — replay is the
                # router's job once the heartbeat times out
                del self._workers[wid]
                self.dead.add(wid)
                continue
            if w.stopped:
                del self._workers[wid]
        adv = getattr(self._clock, "advance", None)
        if adv is not None and self._tick_dt > 0:
            adv(self._tick_dt)

    def poll(self) -> List[object]:
        msgs = list(self._out)
        self._out.clear()
        return msgs

    def alive(self, wid: str) -> bool:
        return wid in self._workers

    def workers(self) -> List[str]:
        return sorted(self._workers)

    def kill(self, wid: str) -> None:
        self._workers.pop(wid, None)
        self.dead.add(wid)

    def close(self) -> None:
        self._workers.clear()
        self._out.clear()


class ProcBus:
    """``multiprocessing`` transport (module docstring).  ``make_spec(wid,
    role)`` returns a picklable ``cluster.worker.WorkerSpec``; each spawn
    starts a daemon process running ``cluster.worker.worker_main``."""

    def __init__(self, make_spec: Callable[[str, str], object]):
        import multiprocessing as mp
        self._ctx = mp.get_context("spawn")   # jax is not fork-safe
        self._make_spec = make_spec
        self._procs: Dict[str, Tuple[object, object]] = {}
        self._out_q = self._ctx.Queue()
        self.dead: set = set()

    def spawn(self, wid: str, role: str) -> None:
        if wid in self._procs:
            raise ValueError(f"worker {wid} already exists")
        from repro.cluster.worker import worker_main
        spec = self._make_spec(wid, role)
        inbox = self._ctx.Queue()
        p = self._ctx.Process(target=worker_main,
                              args=(spec, inbox, self._out_q), daemon=True)
        p.start()
        self._procs[wid] = (p, inbox)

    def send(self, wid: str, msg) -> bool:
        entry = self._procs.get(wid)
        if entry is None:
            return False
        entry[1].put(msg)
        return True

    def pump(self) -> None:
        pass                                  # workers run their own loops

    def poll(self) -> List[object]:
        # first get blocks briefly so an idle router doesn't busy-spin its
        # tick budget away while workers are still starting up / compiling
        try:
            msgs = [self._out_q.get(timeout=0.01)]
        except queue_lib.Empty:
            return []
        while True:
            try:
                msgs.append(self._out_q.get_nowait())
            except queue_lib.Empty:
                break
        return msgs

    def alive(self, wid: str) -> bool:
        entry = self._procs.get(wid)
        return entry is not None and entry[0].is_alive()

    def workers(self) -> List[str]:
        return sorted(self._procs)

    def kill(self, wid: str) -> None:
        """SIGKILL — the fault-injection path (no cleanup, no goodbye)."""
        entry = self._procs.pop(wid, None)
        if entry is not None:
            entry[0].kill()
            entry[0].join(timeout=5)
        self.dead.add(wid)

    def close(self) -> None:
        for wid in list(self._procs):
            p, inbox = self._procs.pop(wid)
            inbox.put(Stop())
            p.join(timeout=10)
            if p.is_alive():
                p.kill()
                p.join(timeout=5)
