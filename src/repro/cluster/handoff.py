"""KV-cache handoff between cluster workers (DESIGN.md §12).

The disaggregated serving tier splits prefill from decode: a prefill
worker's engine computes a prompt's KV pages, then the pages themselves
travel to whichever decode worker placement picked.  This module is both
ends of that wire:

* ``extract(engine, slot)`` — gather the slot's allocated pool pages to the
  host in ONE fixed-shape device gather per cache tree (the page-id vector
  is padded to ``ppr`` so the gather compiles once), truncate to the pages
  that actually hold prompt K/V, and pack them with the request, the first
  sampled token, and the slot's measured leaf-occupancy row into a
  picklable ``KVHandoff``.  The caller then releases the slot WITHOUT
  minting a result (``engine.release_slot(slot, record_result=False)``) —
  ownership of the request moves with the handoff.
* ``install(engine, handoff)`` — on the decode worker: fund pages for the
  full ``prompt + max_new`` horizon from the local pool (all-or-nothing —
  a short pool returns None and the worker re-queues the handoff, which is
  the cluster's backpressure signal), then scatter the shipped rows and
  install table + length in ONE jitted dispatch (``lm.cache_install`` —
  the decode-side analogue of the prefill ``admit`` dispatch, one compiled
  shape for the engine's lifetime), and rebuild the host-side
  ``SlotState`` so the engine decodes the request as if it had prefilled
  it locally.

Determinism makes this exact: sampling is keyed by ``(seed, rid,
len(tokens))`` on whichever engine holds the slot, so a request decoded
after handoff emits byte-identical tokens to one served end-to-end by a
single engine — the property the fault-injection parity tests pin down.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lm
from repro.serving.request import Request, SlotState


@dataclasses.dataclass
class KVHandoff:
    """A completed prefill, packed for the wire (picklable: numpy + request).

    ``k_rows[c]`` / ``v_rows[c]`` align with the engine's cache list; each
    is ``(n_periods, n_pages, page_size, K, hd)`` — only the pages holding
    prompt K/V ship (``n_pages = ceil(prompt_len / page_size)``); the
    receiver zero-pads to its table width.  ``draft_*`` carry the draft
    tree when the cluster runs speculative decoding (same page geometry)."""
    request: Request
    tokens: List[int]                 # sampled at prefill completion (>= 1)
    prompt_len: int
    page_size: int
    n_pages: int
    k_rows: List[np.ndarray]
    v_rows: List[np.ndarray]
    draft_k_rows: Optional[List[np.ndarray]] = None
    draft_v_rows: Optional[List[np.ndarray]] = None
    occupancy: Optional[np.ndarray] = None   # slot's leaf footprint row
    measured: bool = False

    @property
    def nbytes(self) -> int:
        rows = (self.k_rows + self.v_rows
                + (self.draft_k_rows or []) + (self.draft_v_rows or []))
        return int(sum(a.nbytes for a in rows))


def _gather_rows(caches, idx: jax.Array, n_keep: int) -> tuple:
    """Host-side copies of the pool pages named by ``idx`` (fixed (ppr,)
    shape — ONE gather shape per cache config), truncated to ``n_keep``."""
    k_rows, v_rows = [], []
    for c in caches:
        kv = c["kv"]
        k_rows.append(np.asarray(kv.k[:, idx])[:, :n_keep])
        v_rows.append(np.asarray(kv.v[:, idx])[:, :n_keep])
    return k_rows, v_rows


def extract(engine, slot: int) -> KVHandoff:
    """Package slot ``slot``'s completed prefill for shipment (module
    docstring).  The slot must hold a non-done occupant whose prefill has
    completed (>= 1 sampled token); the caller releases the slot after."""
    st = engine.slots[slot]
    if st is None or st.prefilling or not st.tokens:
        raise ValueError(f"slot {slot} has no completed prefill to extract")
    pages = engine._slot_pages[slot]
    page = engine._page
    L = len(st.request.prompt)
    n_keep = -(-L // page)              # pages that actually hold prompt K/V
    idx = np.full((engine._ppr,), pages[0], np.int32)
    idx[:len(pages)] = pages            # pad with a real page: dup gather
    idx_j = jnp.asarray(idx)            # rows past n_keep are dropped below
    k_rows, v_rows = _gather_rows(engine.caches, idx_j, n_keep)
    dk = dv = None
    if engine.spec:
        dk, dv = _gather_rows(engine.draft_caches, idx_j, n_keep)
    occ = engine.occupancy[slot].copy() if engine.num_leaves else None
    return KVHandoff(
        request=st.request, tokens=list(st.tokens), prompt_len=L,
        page_size=page, n_pages=n_keep, k_rows=k_rows, v_rows=v_rows,
        draft_k_rows=dk, draft_v_rows=dv, occupancy=occ,
        measured=bool(engine._measured[slot]))


def _install_jit_for(engine):
    """The receive dispatch, built lazily per engine (donated caches; the
    compile count surfaces in ``engine.compiled_shapes()['install']``)."""
    jit = getattr(engine, "_cluster_install_jit", None)
    if jit is not None:
        return jit
    don = ((lambda *i: {}) if jax.default_backend() == "cpu"
           else (lambda *i: {"donate_argnums": i}))
    if engine.spec:
        jit = jax.jit(
            lambda c, dc, ad, tb, ln, pg, kr, vr, dkr, dvr: (
                lm.cache_install(c, ad, tb, ln, pg, kr, vr),
                lm.cache_install(dc, ad, tb, ln, pg, dkr, dvr)),
            **don(0, 1))
    else:
        jit = jax.jit(lm.cache_install, **don(0))
    engine._cluster_install_jit = jit
    return jit


def install(engine, h: KVHandoff) -> Optional[int]:
    """Install ``h`` into a free slot of ``engine`` (module docstring).

    Returns the slot index, or None when the worker can't take it yet (no
    free slot, or the pool can't fund the full generation horizon even
    after index reclaim) — the caller keeps the handoff queued."""
    if h.page_size != engine._page:
        raise ValueError(f"handoff page size {h.page_size} != receiving "
                         f"engine page size {engine._page}")
    if engine.spec and h.draft_k_rows is None:
        raise ValueError("speculative engine requires the draft cache tree "
                         "in the handoff")
    free = [i for i, s in enumerate(engine.slots) if s is None]
    if not free:
        return None
    req = h.request
    L = h.prompt_len
    n_total = -(-(L + req.max_new_tokens) // engine._page)
    if engine.pool.pages_free < n_total:
        engine.prefix.reclaim(n_total)
    pages = engine.pool.alloc(n_total)
    if pages is None:
        return None
    slot = free[0]
    S, ppr, sentinel = engine.ecfg.num_slots, engine._ppr, engine._num_pages
    admit = np.zeros((S,), bool)
    admit[slot] = True
    tables = np.full((S, ppr), sentinel, np.int32)
    tables[slot, :n_total] = pages
    lengths = np.zeros((S,), np.int32)
    lengths[slot] = L
    # destination pages for the shipped rows: generation-room pages past
    # n_pages receive the zero padding (fresh pages tolerate it — nothing
    # reads past the installed length), sentinel tail entries drop
    dst = np.full((ppr,), sentinel, np.int32)
    dst[:n_total] = pages

    def pad(rows):
        out = []
        for r in rows:
            buf = np.zeros(r.shape[:1] + (ppr,) + r.shape[2:], r.dtype)
            buf[:, :r.shape[1]] = r
            out.append(jnp.asarray(buf))
        return out

    args = (jnp.asarray(admit), jnp.asarray(tables), jnp.asarray(lengths),
            jnp.asarray(dst))
    jit = _install_jit_for(engine)
    with engine._ctx():
        if engine.spec:
            engine.caches, engine.draft_caches = jit(
                engine.caches, engine.draft_caches, *args,
                pad(h.k_rows), pad(h.v_rows),
                pad(h.draft_k_rows), pad(h.draft_v_rows))
        else:
            engine.caches = jit(engine.caches, *args,
                                pad(h.k_rows), pad(h.v_rows))
    engine._slot_pages[slot] = list(pages)
    engine._alloc_len[slot] = n_total * engine._page
    engine._shared_len[slot] = 0
    t = engine.now()
    st = SlotState(request=req, admitted_time=t, first_token_time=t,
                   tokens=list(h.tokens), total_len=L + len(h.tokens),
                   prefill_pos=L)
    engine.slots[slot] = st
    engine._live_rids.add(req.rid)
    engine._arrivals[id(req)] = t
    if engine.spec:
        engine._tlen[slot] = L
        engine._dlen[slot] = L
    if h.occupancy is not None and engine.num_leaves and \
            h.occupancy.size == engine.num_leaves and h.occupancy.any():
        engine.occupancy[slot] = h.occupancy
        engine._measured[slot] = h.measured
    # replay the stop checks on the shipped tokens (an EOS/length finish
    # at prefill normally never ships, but a custom driver might)
    for j, tok in enumerate(st.tokens):
        if req.eos_id is not None and tok == req.eos_id:
            st.done, st.finish_reason = True, "eos"
        elif j + 1 >= req.max_new_tokens:
            st.done, st.finish_reason = True, "length"
        if st.done:
            st.finish_time = t
            break
    return slot
