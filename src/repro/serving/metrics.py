"""Latency/throughput summaries shared by the serving engine and the legacy
``launch/serve.py`` loop (ISSUE 3 satellite: serve reported mean-only).

All inputs are seconds; summaries render in milliseconds.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class LatencySummary:
    n: int
    mean_ms: float
    p50_ms: float
    p90_ms: float
    p99_ms: float
    max_ms: float

    def line(self, label: str) -> str:
        return (f"{label}: p50 {self.p50_ms:.2f}ms p90 {self.p90_ms:.2f}ms "
                f"p99 {self.p99_ms:.2f}ms mean {self.mean_ms:.2f}ms "
                f"max {self.max_ms:.2f}ms (n={self.n})")

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def summarize(samples_s: Sequence[float]) -> LatencySummary:
    """Percentile summary of latency samples (seconds in, ms out)."""
    a = np.asarray(list(samples_s), np.float64)
    if a.size == 0:
        return LatencySummary(0, 0.0, 0.0, 0.0, 0.0, 0.0)
    ms = a * 1e3
    return LatencySummary(
        n=int(a.size), mean_ms=float(ms.mean()),
        p50_ms=float(np.percentile(ms, 50)),
        p90_ms=float(np.percentile(ms, 90)),
        p99_ms=float(np.percentile(ms, 99)),
        max_ms=float(ms.max()))


def tokens_per_second(n_tokens: int, elapsed_s: float) -> float:
    """Throughput with a zero-division guard (0 tokens in 0s -> 0.0)."""
    return n_tokens / max(elapsed_s, 1e-9)


@dataclasses.dataclass
class EngineMetrics:
    """Aggregate engine telemetry, filled by ``engine.run`` /
    per-``RequestResult`` bookkeeping, and snapshotted live by
    ``engine.poll_metrics()`` (the autoscaling signal; the JSON schema —
    ``as_dict()`` — is documented in docs/serving.md).

    Latency summaries are over finished requests (``ttft``, ``per_token``,
    ``e2e``), decode dispatches (``decode_step``), and gaps between
    consecutive decode dispatches while work was in flight
    (``decode_interval`` — the stall-free-admission signal: monolithic
    prefill of a long prompt lands between two decode steps and shows up
    here, chunked prefill bounds it).  ``queue_depth`` / ``active_slots`` /
    ``prefilling_slots`` are instantaneous (0 in a finished ``run`` report,
    meaningful from ``poll_metrics``)."""
    n_requests: int = 0
    n_tokens: int = 0
    elapsed_s: float = 0.0
    n_steps: int = 0
    n_prefills: int = 0
    n_chunks: int = 0                    # chunked-prefill dispatches
    ttft: LatencySummary = dataclasses.field(
        default_factory=lambda: summarize(()))
    per_token: LatencySummary = dataclasses.field(
        default_factory=lambda: summarize(()))
    e2e: LatencySummary = dataclasses.field(
        default_factory=lambda: summarize(()))
    decode_step: LatencySummary = dataclasses.field(
        default_factory=lambda: summarize(()))
    decode_interval: LatencySummary = dataclasses.field(
        default_factory=lambda: summarize(()))
    overflow_fraction_mean: float = 0.0
    overflow_decode_mean: float = 0.0    # decode-phase only: the scheduler's
                                         # microbatch-composition signal
    # overflow-policy accounting (DESIGN.md §14): estimated (token, tree)
    # slots that took the configured overflow path instead of dropping to
    # zeros, and the fraction of slots served by the master leaf alone
    # (nonzero only under overflow_policy="master_leaf")
    overflow_repairs: int = 0
    master_leaf_fraction: float = 0.0
    hint_mismatches: int = 0             # leaf_hints dropped for size mismatch
    # speculative decoding (DESIGN.md §10): draft tokens proposed, accepted,
    # and wasted (= drafted - accepted, the verify compute thrown away);
    # spec_acceptance = accepted / drafted (0 when speculation is off)
    draft_tokens: int = 0
    accepted_tokens: int = 0
    # paged KV cache + prefix sharing (DESIGN.md §11): prefill_tokens counts
    # tokens actually prefilled on device; prefix_hit_tokens counts prompt
    # tokens served from shared pages instead (the prefill work avoided);
    # cow_copies counts copy-on-write page duplications; pages_in_use /
    # pages_free snapshot the page pool (instantaneous)
    prefill_tokens: int = 0
    prefix_hit_tokens: int = 0
    cow_copies: int = 0
    pages_in_use: int = 0
    pages_free: int = 0
    queue_depth: int = 0                 # waiting requests (instantaneous)
    active_slots: int = 0                # occupied slots (instantaneous)
    prefilling_slots: int = 0            # slots mid-chunked-prefill
    # per-tenant QoS breakdown over finished requests (tenant -> counters +
    # latency summaries; poll_metrics adds live queue_depth / profile keys)
    tenants: Dict[str, dict] = dataclasses.field(default_factory=dict)

    @property
    def throughput_tok_s(self) -> float:
        return tokens_per_second(self.n_tokens, self.elapsed_s)

    @property
    def spec_acceptance(self) -> float:
        return self.accepted_tokens / max(self.draft_tokens, 1)

    @property
    def wasted_tokens(self) -> int:
        return self.draft_tokens - self.accepted_tokens

    def report(self) -> str:
        lines = [
            f"served {self.n_requests} requests, {self.n_tokens} tokens in "
            f"{self.elapsed_s:.2f}s ({self.throughput_tok_s:.1f} tok/s, "
            f"{self.n_steps} decode steps, {self.n_prefills} prefills"
            + (f", {self.n_chunks} prefill chunks" if self.n_chunks else "")
            + ")",
            self.ttft.line("ttft"),
            self.per_token.line("per-token"),
            self.e2e.line("e2e"),
            self.decode_step.line("decode step"),
            self.decode_interval.line("decode interval"),
            f"fff overflow_fraction mean {self.overflow_fraction_mean:.4f} "
            f"(decode-only {self.overflow_decode_mean:.4f})",
        ]
        if self.overflow_repairs:
            lines.append(
                f"overflow policy: ~{self.overflow_repairs} slots repaired "
                f"(master-leaf fraction {self.master_leaf_fraction:.4f})")
        if self.draft_tokens:
            lines.append(
                f"speculative: {self.draft_tokens} drafted, "
                f"{self.accepted_tokens} accepted "
                f"(acceptance {self.spec_acceptance:.3f}, "
                f"{self.wasted_tokens} wasted)")
        if self.hint_mismatches:
            lines.append(f"leaf_hint size mismatches dropped: "
                         f"{self.hint_mismatches}")
        if self.prefix_hit_tokens or self.cow_copies:
            lines.append(
                f"paged kv: {self.prefill_tokens} tokens prefilled, "
                f"{self.prefix_hit_tokens} served from shared prefix pages "
                f"({self.cow_copies} cow copies)")
        if set(self.tenants) - {"default"}:
            for t, d in sorted(self.tenants.items()):
                if "n_requests" not in d:
                    continue
                lines.append(
                    f"tenant {t}: {d['n_requests']} requests, "
                    f"{d['n_tokens']} tokens ({d['throughput_tok_s']:.1f} "
                    f"tok/s), ttft p50 {d['ttft_ms']['p50_ms']:.2f}ms")
        return "\n".join(lines)

    def as_dict(self) -> dict:
        """The metrics JSON schema (``serve.py --metrics-json``; documented
        field-by-field in docs/serving.md)."""
        return {
            "n_requests": self.n_requests, "n_tokens": self.n_tokens,
            "elapsed_s": self.elapsed_s, "n_steps": self.n_steps,
            "n_prefills": self.n_prefills, "n_chunks": self.n_chunks,
            "throughput_tok_s": self.throughput_tok_s,
            "ttft_ms": self.ttft.as_dict(),
            "per_token_ms": self.per_token.as_dict(),
            "e2e_ms": self.e2e.as_dict(),
            "decode_step_ms": self.decode_step.as_dict(),
            "decode_interval_ms": self.decode_interval.as_dict(),
            "overflow_fraction_mean": self.overflow_fraction_mean,
            "overflow_decode_mean": self.overflow_decode_mean,
            "overflow_repairs": self.overflow_repairs,
            "master_leaf_fraction": self.master_leaf_fraction,
            "hint_mismatches": self.hint_mismatches,
            "spec_acceptance": self.spec_acceptance,
            "draft_tokens": self.draft_tokens,
            "accepted_tokens": self.accepted_tokens,
            "wasted_tokens": self.wasted_tokens,
            "prefill_tokens": self.prefill_tokens,
            "prefix_hit_tokens": self.prefix_hit_tokens,
            "cow_copies": self.cow_copies,
            "pages_in_use": self.pages_in_use,
            "pages_free": self.pages_free,
            "queue_depth": self.queue_depth,
            "active_slots": self.active_slots,
            "prefilling_slots": self.prefilling_slots,
            "tenants": self.tenants,
        }


def tenant_breakdown(results: Iterable, elapsed_s: float) -> Dict[str, dict]:
    """Per-tenant QoS slice of finished requests: request/token counts,
    tokens/s over the shared wall clock (under saturation the ratios track
    the configured admission weights — the fairness acceptance signal), and
    TTFT/e2e summaries."""
    rs = list(results)
    out: Dict[str, dict] = {}
    for t in sorted({r.tenant for r in rs}):
        trs = [r for r in rs if r.tenant == t]
        n_tok = sum(r.n_generated for r in trs)
        drafted = sum(r.n_drafted for r in trs)
        accepted = sum(r.n_accepted for r in trs)
        out[t] = {
            "n_requests": len(trs),
            "n_tokens": n_tok,
            "throughput_tok_s": tokens_per_second(n_tok, elapsed_s),
            "ttft_ms": summarize([r.ttft for r in trs]).as_dict(),
            "e2e_ms": summarize([r.e2e_latency for r in trs]).as_dict(),
            "draft_tokens": drafted,
            "accepted_tokens": accepted,
            "spec_acceptance": accepted / max(drafted, 1),
        }
    return out


def from_results(results: Iterable, *, elapsed_s: float, n_steps: int,
                 n_prefills: int, decode_lat_s: Sequence[float],
                 overflow_mean: float,
                 overflow_decode_mean: float = 0.0,
                 overflow_repairs: int = 0,
                 master_leaf_fraction: float = 0.0,
                 n_chunks: int = 0,
                 decode_interval_s: Sequence[float] = (),
                 hint_mismatches: int = 0,
                 draft_tokens: int = 0,
                 accepted_tokens: int = 0,
                 prefill_tokens: int = 0,
                 prefix_hit_tokens: int = 0,
                 cow_copies: int = 0,
                 pages_in_use: int = 0,
                 pages_free: int = 0) -> EngineMetrics:
    """Build an ``EngineMetrics`` from finished ``RequestResult`` records."""
    rs = list(results)
    return EngineMetrics(
        n_requests=len(rs),
        n_tokens=sum(r.n_generated for r in rs),
        elapsed_s=elapsed_s, n_steps=n_steps, n_prefills=n_prefills,
        n_chunks=n_chunks,
        ttft=summarize([r.ttft for r in rs]),
        per_token=summarize([r.per_token_latency() for r in rs]),
        e2e=summarize([r.e2e_latency for r in rs]),
        decode_step=summarize(decode_lat_s),
        decode_interval=summarize(decode_interval_s),
        overflow_fraction_mean=overflow_mean,
        overflow_decode_mean=overflow_decode_mean,
        overflow_repairs=overflow_repairs,
        master_leaf_fraction=master_leaf_fraction,
        hint_mismatches=hint_mismatches,
        draft_tokens=draft_tokens,
        accepted_tokens=accepted_tokens,
        prefill_tokens=prefill_tokens,
        prefix_hit_tokens=prefix_hit_tokens,
        cow_copies=cow_copies,
        pages_in_use=pages_in_use,
        pages_free=pages_free,
        tenants=tenant_breakdown(rs, elapsed_s))
