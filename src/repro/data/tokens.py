"""Synthetic LM token stream: an order-2 Markov source with a power-law
unigram prior.  Learnable structure (bigram/trigram statistics) so LM training
loss decreases meaningfully; fully deterministic given a seed."""
from __future__ import annotations

import numpy as np


class MarkovTokenSource:
    def __init__(self, vocab_size: int, seed: int = 0, branch: int = 8):
        self.vocab = vocab_size
        self.branch = branch
        rng = np.random.default_rng(seed)
        # power-law unigram prior
        ranks = np.arange(1, vocab_size + 1, dtype=np.float64)
        self.prior = (1.0 / ranks ** 1.1)
        self.prior /= self.prior.sum()
        # each context hashes to `branch` plausible successors
        self._a = int(rng.integers(1, 2**31 - 1)) | 1
        self._b = int(rng.integers(1, 2**31 - 1))
        self._succ = rng.choice(vocab_size, size=(4096, branch), p=self.prior)

    def _ctx_hash(self, t1: np.ndarray, t2: np.ndarray) -> np.ndarray:
        return ((t1 * self._a + t2 * 31 + self._b) % 4096).astype(np.int64)

    def sample(self, batch: int, seq_len: int, seed: int) -> np.ndarray:
        rng = np.random.default_rng(seed)
        out = np.empty((batch, seq_len + 1), dtype=np.int32)
        out[:, 0] = rng.choice(self.vocab, size=batch, p=self.prior)
        out[:, 1] = rng.choice(self.vocab, size=batch, p=self.prior)
        for t in range(2, seq_len + 1):
            h = self._ctx_hash(out[:, t - 2], out[:, t - 1])
            pick = rng.integers(0, self.branch, size=batch)
            nxt = self._succ[h, pick]
            # 10% noise from the prior keeps entropy > 0
            noise = rng.random(batch) < 0.1
            nxt = np.where(noise, rng.choice(self.vocab, size=batch, p=self.prior),
                           nxt)
            out[:, t] = nxt
        return out

    def batch(self, batch: int, seq_len: int, seed: int) -> dict:
        toks = self.sample(batch, seq_len, seed)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
