"""nn substrate: attention (flash vs full, cache), mamba, xlstm, norms."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.nn import attention as attn
from repro.nn import mamba, norms, rope, xlstm


def test_flash_matches_full_causal():
    B, S, H, K, hd = 2, 128, 8, 4, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, K, hd))
    v = jax.random.normal(ks[2], (B, S, K, hd))
    o1 = attn.full_attention(q, k, v, causal=True)
    o2 = attn.flash_attention(q, k, v, causal=True, chunk=32)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=1e-5, atol=1e-5)


def test_flash_sliding_window():
    B, S, H, K, hd = 1, 128, 4, 4, 16
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, K, hd))
    v = jax.random.normal(ks[2], (B, S, K, hd))
    band = (jnp.arange(S)[:, None] - jnp.arange(S)[None, :]) < 48
    o1 = attn.full_attention(q, k, v, causal=True, bias_mask=band)
    o2 = attn.flash_attention(q, k, v, causal=True, chunk=16, sliding_window=48)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=1e-5, atol=1e-5)


def test_chunk_pairs_skip_upper_triangle():
    qi, kj, mk = attn._chunk_pairs(4, 4, causal=True, window_chunks=0)
    assert len(qi) == 10                     # 4*5/2 lower-triangle pairs
    assert all(int(b) <= int(a) for a, b in zip(qi, kj))
    qi2, kj2, _ = attn._chunk_pairs(8, 8, causal=True, window_chunks=2)
    assert all(int(a) - int(b) <= 2 for a, b in zip(qi2, kj2))


def test_decode_matches_full_attention():
    cfg = attn.AttnConfig(d_model=32, n_heads=4, n_kv_heads=2, head_dim=8)
    p = attn.init(jax.random.PRNGKey(2), cfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 12, 32))
    y_ref = attn.forward(p, cfg, x)
    cache = attn.init_cache(2, 16, cfg)
    y_pre, cache = attn.forward_prefill(p, cfg, x[:, :8], cache)
    np.testing.assert_allclose(np.asarray(y_ref[:, :8]), np.asarray(y_pre),
                               rtol=1e-5, atol=1e-5)
    for t in range(8, 12):
        y_t, cache = attn.forward_decode(p, cfg, x[:, t:t + 1], cache)
        np.testing.assert_allclose(np.asarray(y_ref[:, t:t + 1]),
                                   np.asarray(y_t), rtol=1e-4, atol=1e-4)


def test_mamba_chunk_invariance_and_decode():
    cfg = mamba.MambaConfig(d_model=24, d_state=8, chunk=8)
    p = mamba.init(jax.random.PRNGKey(4), cfg)
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 32, 24))
    y1, _ = mamba.forward(p, cfg, x)
    cfg2 = mamba.MambaConfig(d_model=24, d_state=8, chunk=32)
    y2, _ = mamba.forward(p, cfg2, x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)
    # incremental decode
    y_pre, st = mamba.forward(p, cfg, x[:, :24])
    outs = []
    for t in range(24, 32):
        o, st = mamba.forward_step(p, cfg, x[:, t:t + 1], st)
        outs.append(o)
    y_inc = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y1[:, 24:]), np.asarray(y_inc),
                               rtol=1e-4, atol=1e-4)


def test_mlstm_chunkwise_matches_sequential():
    cfg = xlstm.XLSTMConfig(d_model=16, n_heads=2, chunk=8)
    p = xlstm.mlstm_init(jax.random.PRNGKey(6), cfg)
    x = jax.random.normal(jax.random.PRNGKey(7), (2, 48, 16))
    ys, _ = xlstm.mlstm_block(p, cfg, x, sequential=True)
    yc, _ = xlstm.mlstm_block(p, cfg, x, sequential=False)
    np.testing.assert_allclose(np.asarray(ys), np.asarray(yc),
                               rtol=1e-4, atol=1e-4)


def test_mlstm_state_carries_across_segments():
    cfg = xlstm.XLSTMConfig(d_model=16, n_heads=2, chunk=8)
    p = xlstm.mlstm_init(jax.random.PRNGKey(8), cfg)
    x = jax.random.normal(jax.random.PRNGKey(9), (1, 32, 16))
    y_full, _ = xlstm.mlstm_block(p, cfg, x)
    y_a, st = xlstm.mlstm_block(p, cfg, x[:, :16])
    y_b, _ = xlstm.mlstm_block(p, cfg, x[:, 16:], st)
    np.testing.assert_allclose(np.asarray(y_full),
                               np.asarray(jnp.concatenate([y_a, y_b], 1)),
                               rtol=1e-4, atol=1e-4)


def test_slstm_finite_and_stateful():
    cfg = xlstm.XLSTMConfig(d_model=16, n_heads=4)
    p = xlstm.slstm_init(jax.random.PRNGKey(10), cfg)
    x = jax.random.normal(jax.random.PRNGKey(11), (2, 24, 16))
    y, st = xlstm.slstm_block(p, cfg, x)
    assert jnp.isfinite(y).all()
    y2, _ = xlstm.slstm_block(p, cfg, x, st)
    assert not np.allclose(np.asarray(y), np.asarray(y2))


def test_norms():
    x = jax.random.normal(jax.random.PRNGKey(12), (4, 8, 16)) * 5 + 2
    pr = norms.rmsnorm_init(16)
    y = norms.rmsnorm(pr, x)
    rms = np.sqrt(np.mean(np.asarray(y) ** 2, -1))
    np.testing.assert_allclose(rms, 1.0, atol=1e-3)
    pl = norms.layernorm_init(16)
    y2 = norms.layernorm(pl, x)
    np.testing.assert_allclose(np.asarray(y2).mean(-1), 0.0, atol=1e-4)


def test_rope_rotation_preserves_norm_and_relativity():
    x = jax.random.normal(jax.random.PRNGKey(13), (1, 8, 2, 16))
    pos = jnp.arange(8)[None, :]
    y = rope.apply_rope(x, pos)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(x), axis=-1),
                               np.linalg.norm(np.asarray(y), axis=-1),
                               rtol=1e-5)
    # relative property: <R(p)q, R(p+k)v> depends only on k
    q = jax.random.normal(jax.random.PRNGKey(14), (1, 1, 1, 16))
    v = jax.random.normal(jax.random.PRNGKey(15), (1, 1, 1, 16))
    def dot_at(p):
        rq = rope.apply_rope(q, jnp.array([[p]]))
        rv = rope.apply_rope(v, jnp.array([[p + 3]]))
        return float(jnp.sum(rq * rv))
    assert dot_at(0) == pytest.approx(dot_at(7), rel=1e-4)
