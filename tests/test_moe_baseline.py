"""MoE baseline (Shazeer noisy top-k): gating semantics and aux losses."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import moe


def make(E=8, k=2, din=16, dout=8, width=4, seed=0):
    cfg = moe.MoEConfig(dim_in=din, dim_out=dout, num_experts=E,
                        expert_width=width, top_k=k)
    return cfg, moe.init(jax.random.PRNGKey(seed), cfg)


def test_gates_sum_to_one_over_topk():
    cfg, p = make()
    x = jax.random.normal(jax.random.PRNGKey(1), (32, 16))
    _, aux = moe.forward(p, cfg, x, rng=jax.random.PRNGKey(2), train=True)
    gates = np.asarray(aux["gates"])
    np.testing.assert_allclose(gates.sum(-1), 1.0, atol=1e-5)
    assert ((gates > 0).sum(-1) <= cfg.top_k).all()


def test_aux_loss_penalizes_imbalance():
    cfg, p = make()
    # bias the gate toward expert 0 hard
    p = dict(p)
    p["gate_w"] = p["gate_w"].at[:, 0].set(5.0)
    x = jax.random.normal(jax.random.PRNGKey(3), (64, 16))
    _, aux_biased = moe.forward(p, cfg, x, rng=jax.random.PRNGKey(4))
    cfg2, p2 = make(seed=7)
    _, aux_fair = moe.forward(p2, cfg2, x, rng=jax.random.PRNGKey(4))
    assert float(aux_biased["aux_loss"]) > float(aux_fair["aux_loss"])


def test_sparse_inference_matches_dense_eval_topk():
    """forward_sparse (gathered top-k) == dense combine with clean gates."""
    cfg, p = make()
    x = jax.random.normal(jax.random.PRNGKey(5), (16, 16))
    y_dense, aux = moe.forward(p, cfg, x, rng=None, train=False)
    y_sparse, _ = moe.forward_sparse(p, cfg, x)
    np.testing.assert_allclose(np.asarray(y_dense), np.asarray(y_sparse),
                               rtol=1e-4, atol=1e-4)


def test_load_estimate_differentiable():
    cfg, p = make()
    x = jax.random.normal(jax.random.PRNGKey(6), (32, 16))

    def loss(p):
        _, aux = moe.forward(p, cfg, x, rng=jax.random.PRNGKey(7), train=True)
        return aux["aux_loss"]

    g = jax.grad(loss)(p)
    assert float(jnp.abs(g["gate_w"]).sum()) > 0
    assert float(jnp.abs(g["noise_w"]).sum()) > 0
