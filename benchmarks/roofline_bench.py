"""Roofline summary, two sections:

1. Renders the dry-run artifact (experiments/dryrun_full.json) into the
   per-(arch x shape x mesh) three-term table used by EXPERIMENTS.md
   §Roofline.  Run ``python -m repro.launch.dryrun --all --out
   experiments/dryrun_full.json`` first (hours of compiles); this section
   only formats and sanity-checks the stored records.

2. Measures the fused decode megakernel (``kernels/fused_decode``) against
   the legacy 3-dispatch kernel path (router + two gathered matmuls) at the
   serving engine's decode shape, asserts the dispatch contract — exactly
   ONE ``pallas_call`` in the fused trace vs three — via the jaxpr-walking
   probe in ``kernels/common.py``, and records the analytic per-token HBM
   traffic terms behind the fusion claim (DESIGN.md §13).  Writes
   ``experiments/BENCH_roofline.json`` for the bench-smoke schema gate.

Timing caveat: on this CPU container the kernels execute in Pallas
interpret mode, so absolute ``us_per_call`` is not TPU-representative —
but the *relative* win is structurally honest at decode shape, where
per-dispatch overhead (three launches + the (B, l) activation round trip)
dominates the arithmetic.  The attained-vs-roofline HBM columns are
analytic byte counts, not measurements.
"""
from __future__ import annotations

import json
import os
import time

ARTIFACT = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "experiments", "dryrun_full.json")
OUT = os.path.join(os.path.dirname(ARTIFACT), "BENCH_roofline.json")


def load(path: str = ARTIFACT) -> list[dict]:
    if not os.path.exists(path):
        return []
    with open(path) as f:
        return json.load(f)


def _dryrun_section(recs: list[dict]) -> None:
    if not recs:
        print("roofline/missing,0.0,run_dryrun_first=1")
        return
    ok = [r for r in recs if r.get("status") == "ok"]
    for r in ok:
        name = f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}"
        t_max = max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"])
        print(f"{name},{t_max*1e6:.0f},"
              f"tc={r['t_compute_s']:.3f};tm={r['t_memory_s']:.3f};"
              f"tx={r['t_collective_s']:.3f};dom={r['dominant']};"
              f"rf={r['roofline_fraction']:.4f};"
              f"useful={r['useful_ratio']:.3f};"
              f"fits={int(r.get('fits_v5e_16g', False))}")
    n_skip = sum(r.get("status") == "skipped" for r in recs)
    n_err = sum(r.get("status") == "error" for r in recs)
    print(f"roofline/summary,0.0,ok={len(ok)};skipped={n_skip};errors={n_err}")


def _time_us(fn, x, iters: int) -> float:
    fn(x).block_until_ready()                           # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        r = fn(x)
    r.block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6


def _fused_decode_section(quick: bool) -> dict:
    import jax

    from repro.core import fff
    from repro.kernels import common
    from repro.kernels.fused_decode import ops as fd_ops
    from repro.kernels.fused_fff import fff_decode

    slots, dim, depth, leaf = (8, 64, 4, 16) if quick else (32, 256, 6, 32)
    iters = 20 if quick else 50
    cfg = fff.FFFConfig(dim_in=dim, dim_out=dim, depth=depth,
                        leaf_width=leaf, activation="gelu", trees=1,
                        leaf_bias=False)
    params = fff.init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (slots, dim))

    fused = jax.jit(lambda x: fd_ops.fused_decode(x, params, cfg,
                                                  interpret=True))
    legacy = jax.jit(lambda x: fff_decode(x, params, cfg, interpret=True))
    d_fused = common.count_pallas_calls(
        lambda x: fd_ops.fused_decode(x, params, cfg, interpret=True), x)
    d_legacy = common.count_pallas_calls(
        lambda x: fff_decode(x, params, cfg, interpret=True), x)
    # the contract the CI compile gate also pins (tests/test_kernel_diff.py)
    assert d_fused == 1, f"fused decode must be ONE dispatch, got {d_fused}"
    assert d_legacy == 3 * cfg.trees, d_legacy

    fused_us = _time_us(fused, x, iters)
    legacy_us = _time_us(legacy, x, iters)
    fused_tok_s = slots / (fused_us * 1e-6)
    legacy_tok_s = slots / (legacy_us * 1e-6)
    speedup = legacy_us / fused_us

    # analytic per-token HBM traffic (fp32 bytes): the routed leaf only vs
    # the dense-layer equivalent, plus the 3-dispatch path's extra (B, l)
    # activation round trip and leaf_idx handoff between kernels
    N, E = cfg.num_nodes, cfg.num_leaves
    weights = 4 * (N * dim + leaf * dim + leaf * dim)   # nodes + w1 + w2
    io = 4 * 2 * dim                                    # x in, y out
    roundtrip = 4 * (2 * leaf + 2)                      # h store+load, idx
    hbm = {
        "fused": weights + io,
        "baseline": weights + io + roundtrip,
        "dense_equivalent": 4 * (E * leaf * 2 * dim) + io,
    }

    rows = [
        {"name": f"roofline/fused_decode/b{slots}d{dim}x{depth}",
         "us_per_call": fused_us, "dispatches": d_fused,
         "tok_s": fused_tok_s},
        {"name": f"roofline/fff_decode_3pass/b{slots}d{dim}x{depth}",
         "us_per_call": legacy_us, "dispatches": d_legacy,
         "tok_s": legacy_tok_s},
    ]
    for r in rows:
        print(f"{r['name']},{r['us_per_call']:.1f},"
              f"dispatches={r['dispatches']};tok_s={r['tok_s']:.0f}")
    print(f"roofline/fused_speedup,0.0,speedup={speedup:.2f};"
          f"dispatch_ok={int(d_fused == 1)};"
          f"hbm_fused={hbm['fused']};hbm_dense={hbm['dense_equivalent']}")
    return {
        "shape": {"slots": slots, "dim": dim, "depth": depth,
                  "leaf_width": leaf, "trees": cfg.trees},
        "dispatches_fused": d_fused,
        "dispatches_baseline": d_legacy,
        "dispatch_ok": d_fused == 1,
        "fused_us": fused_us, "baseline_us": legacy_us,
        "fused_tok_s": fused_tok_s, "baseline_tok_s": legacy_tok_s,
        "speedup": speedup,
        "speedup_ok": speedup >= 1.0,
        "hbm_bytes_per_token": hbm,
        "rows": rows,
    }


def main(quick: bool = True):
    recs = load()
    print("name,us_per_call,derived")
    _dryrun_section(recs)
    doc = {"bench": "roofline", "quick": quick, "dryrun_records": len(recs)}
    doc.update(_fused_decode_section(quick))
    with open(OUT, "w") as f:
        json.dump(doc, f, indent=1)
    print(f"# wrote {OUT}")
    return recs


if __name__ == "__main__":
    main()
