"""Paper Table 3: vision transformer with FFF layers replacing FFs.

4-layer ViT, hidden 128, patch-embedded synthetic CIFAR-like images; FFF
training width 128 with leaf sizes l in {32, 8, 1} (quick subset; full run
sweeps {32, 16, 8, 4, 2, 1}).  Reports G_A and the FFN-site speedup (timed on
the FFN layers alone, matching the paper's "speedup at the feedforward
layers"), plus training/inference size accounting of Table 3's columns.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro import optim
from repro.configs.paper_vit import vit_config
from repro.core import api
from repro.core import ff as ff_lib
from repro.core import fff as fff_lib
from repro.data import synthetic
from repro.models import lm
from repro.nn import mlp


def _vit_batchify(ds, patch=4, side=32, channels=3):
    xtr = synthetic.patches(ds.x_train, side, channels, patch)
    xte = synthetic.patches(ds.x_test, side, channels, patch)
    return xtr, xte


def _train_vit(cfg, ds_patches, labels, test_patches, test_labels,
               steps, seed=0):
    """ViT = patch-projection frontend + lm stack; classify via mean-pooled
    final hidden -> vocab head (vocab = n_classes)."""
    key = jax.random.PRNGKey(seed)
    params = lm.init(key, cfg)
    # project raw patches to d_model with a fixed random matrix (frontend stub
    # owns the learned projection)
    dpatch = ds_patches.shape[-1]
    proj = jax.random.normal(jax.random.fold_in(key, 1),
                             (dpatch, cfg.d_model)) / np.sqrt(dpatch)

    def fwd(p, x_patches, mode):
        emb = jnp.einsum("bsp,pd->bsd", x_patches, proj)
        from repro.nn import transformer
        x = lm._embed_inputs(p, cfg, {"embeds": emb})
        x, _, aux = transformer.stack_forward(p["stack"], cfg, x, mode=mode,
                                              causal=False)
        x = x.mean(axis=1)
        logits = lm._head(p, cfg, x[:, None, :])[:, 0]
        return logits, aux

    def fwd_train(p, x, rng=None):
        logits, aux = fwd(p, x, "train")
        return logits, aux["hardening"]

    def fwd_infer(p, x):
        # FORWARD_I at every FFF site (mode="eval": hard tree routing)
        return fwd(p, x, "eval")[0]

    class DS:
        x_train, y_train = ds_patches, labels
        x_test, y_test = test_patches, test_labels

    p, _ = common.train_classifier(fwd_train, params, DS, steps=steps,
                                   batch=128, opt=optim.adamw(4e-4))
    ga = common.accuracy(jax.jit(fwd_infer), p, test_patches, test_labels,
                         batch=256)
    return p, ga


def _ffn_site_speedup(leaf: int, d_model: int = 128, d_ff: int = 128,
                      batch: int = 2048) -> float:
    """Timed FFN-site comparison: dense FF(128) vs hard FFF(depth, leaf)."""
    x = jax.random.normal(jax.random.PRNGKey(0), (batch, d_model))
    fcfg = ff_lib.FFConfig(dim_in=d_model, dim_out=d_model, width=d_ff,
                           activation="gelu")
    fp = ff_lib.init(jax.random.PRNGKey(1), fcfg)
    t_ff, _ = common.time_fn(jax.jit(
        lambda p, x: ff_lib.forward(p, fcfg, x)), fp, x, iters=15)
    depth = int(np.log2(d_ff // leaf))
    xcfg = fff_lib.FFFConfig(dim_in=d_model, dim_out=d_model, depth=depth,
                             leaf_width=leaf, activation="gelu",
                             leaf_bias=False)
    xp = fff_lib.init(jax.random.PRNGKey(2), xcfg)
    # pin the exact per-token gather so the Table 3 speedup column measures
    # the paper's mechanism on every platform (auto would swap in the
    # kernels on TPU — a backend choice, not the paper's FORWARD_I cost)
    with api.use_backend("reference"):
        t_fff, _ = common.time_fn(jax.jit(
            lambda p, x: api.apply(p, xcfg, x,
                                   api.ExecutionSpec(mode="infer"))[0]),
            xp, x, iters=15)
    return t_ff / t_fff


def run(steps: int = 200, leaves=(32, 8, 1), quick: bool = False):
    ds = synthetic.make("cifar10_like")
    xtr, xte = _vit_batchify(ds)
    rows = []
    # dense baseline
    cfg0 = vit_config("dense")
    _, ga0 = _train_vit(cfg0, xtr, ds.y_train, xte, ds.y_test, steps)
    rows.append(dict(model="ff", leaf=0, depth=0, ga=ga0, speedup=1.0,
                     train_size=128, inf_width=128))
    for leaf in (leaves[:2] if quick else leaves):
        cfg = vit_config("fff", leaf_width=leaf)
        depth = int(np.log2(128 // leaf))
        _, ga = _train_vit(cfg, xtr, ds.y_train, xte, ds.y_test, steps)
        spd = _ffn_site_speedup(leaf)
        rows.append(dict(model="fff", leaf=leaf, depth=depth, ga=ga,
                         speedup=spd,
                         train_size=(2 ** depth - 1) + 128,
                         inf_width=leaf))
    return rows


def main(quick: bool = True):
    rows = run(steps=80 if quick else 300, quick=quick)
    print("name,us_per_call,derived")
    base_ga = rows[0]["ga"]
    for r in rows:
        rel = (base_ga - r["ga"]) / max(base_ga, 1e-9) * 100
        print(f"table3/{r['model']}_l{r['leaf']},0.0,"
              f"ga={r['ga']:.3f};rel_drop={rel:.1f}%;"
              f"ffn_speedup={r['speedup']:.2f}x;inf_width={r['inf_width']}")
    return rows


if __name__ == "__main__":
    main(quick=False)
