"""Serving with the TPU kernel path: routes a batch through the Pallas
tree-router + grouped leaf GEMM (interpret mode on CPU) and cross-checks
against the pure-JAX oracle — the production inference dataflow end to end.

Run:  PYTHONPATH=src python examples/serve_fff_kernels.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fff, routing
from repro.kernels.fused_fff import fff_decode
from repro.kernels.leaf_gemm import fff_infer

# a transformer-FFN-sized FFF layer: d_model 512, 16 leaves x 256 = 4096 width
cfg = fff.FFFConfig(dim_in=512, dim_out=512, depth=4, leaf_width=256,
                    activation="swiglu", leaf_bias=False)
params = fff.init(jax.random.PRNGKey(0), cfg)
x = jax.random.normal(jax.random.PRNGKey(1), (256, 512))

print(f"FFF layer: {cfg.num_leaves} leaves x {cfg.leaf_width} wide "
      f"(training width {cfg.training_width}, inference width "
      f"{cfg.inference_width})")

# --- oracle ------------------------------------------------------------
t0 = time.time()
y_ref, aux = fff.forward_hard(params, cfg, x)
print(f"oracle  forward_hard        {1e3*(time.time()-t0):7.1f}ms")

# --- batch path: router kernel + sorted-dispatch ragged GEMM ------------
t0 = time.time()
y_grouped = fff_infer(x, params, cfg, interpret=True)
err = float(jnp.abs(y_grouped - y_ref).max())
print(f"kernels fff_infer (grouped) {1e3*(time.time()-t0):7.1f}ms   "
      f"max|err| vs oracle = {err:.2e}")

# --- decode path: per-token gathered weights (the offset-load) ----------
xd = x[:8]
y_dec = fff_decode(xd, params, cfg, interpret=True)
y_dec_ref, _ = fff.forward_hard(params, cfg, xd)
print(f"kernels fff_decode (gather)           max|err| vs oracle = "
      f"{float(jnp.abs(y_dec - y_dec_ref).max()):.2e}")

# --- routing statistics --------------------------------------------------
leaf_idx = aux["leaf_idx"][:, 0]
hist = np.asarray(routing.leaf_histogram(leaf_idx, cfg.num_leaves))
skew = float(routing.routing_skew(leaf_idx, cfg.num_leaves))
print(f"\nrouting: leaf loads {hist.tolist()}  skew={skew:.2f} "
      f"(1.0 = perfectly balanced; capacity dispatch bounds the worst case)")
print("note: interpret=True executes the Pallas kernel bodies on CPU; on a "
      "TPU the same calls lower to MXU code (see DESIGN.md §3).")
