"""Continuous-batching serving engine with FFF leaf-occupancy-aware
scheduling, multi-tenant QoS admission, online per-tenant routing profiles
and speculative decoding (DESIGN.md §9, §10)."""
from repro.serving.engine import ContinuousBatchingEngine, EngineConfig, \
    TenantQueues
from repro.serving.metrics import EngineMetrics, LatencySummary, summarize, \
    tenant_breakdown, tokens_per_second
from repro.serving.profiles import RoutingProfileStore, TenantProfile
from repro.serving.request import Request, RequestResult
from repro.serving.scheduler import SCHEDULERS, FCFSScheduler, \
    LeafAwareScheduler, Scheduler, SchedulerView, \
    WeightedLeafAwareScheduler, make_scheduler
from repro.serving.spec import build_draft, rejection_sample, \
    self_draft_config, slice_draft_params

__all__ = [
    "ContinuousBatchingEngine", "EngineConfig", "EngineMetrics",
    "LatencySummary", "summarize", "tenant_breakdown", "tokens_per_second",
    "Request", "RequestResult", "RoutingProfileStore", "TenantProfile",
    "TenantQueues",
    "SCHEDULERS", "FCFSScheduler", "LeafAwareScheduler", "Scheduler",
    "SchedulerView", "WeightedLeafAwareScheduler", "make_scheduler",
    "build_draft", "rejection_sample", "self_draft_config",
    "slice_draft_params",
]
