"""internvl2-26b [vlm] — 48L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=92553 — InternViT + InternLM2 backbone; the vision frontend is a STUB
per assignment (input_specs provides precomputed patch embeddings).
[arXiv:2404.16821; hf]"""
import jax.numpy as jnp

from repro.configs.base import BlockSpec, FFNSpec, ModelConfig

CONFIG = ModelConfig(
    arch_id="internvl2-26b",
    family="vlm",
    d_model=6144,
    n_layers=48,
    n_heads=48,
    n_kv_heads=8,
    vocab_size=92553,
    max_seq_len=32768,
    rope_theta=1_000_000.0,
    frontend="vision_stub",
    period=(BlockSpec(mixer="attn",
                      ffn=FFNSpec(kind="dense", d_ff=16384,
                                  activation="swiglu")),),
    param_dtype=jnp.bfloat16,
    accum_dtype=jnp.bfloat16,
    remat="full",
    grad_accum=16,
)

# 16 leaves x 1024 = 16384 (exact width match)
FFF_CONFIG = CONFIG.with_ffn_kind("fff", leaf_width=1024)
