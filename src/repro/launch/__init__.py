"""Launch layer: meshes, multi-pod dry-run, roofline analysis, drivers."""
from repro.launch import mesh, roofline, specs
