"""Distribution: meshes, sharding rules, dispatch plans, compression, fault
tolerance."""
from repro.distributed import (act, compression, dispatch, fault, sharding,
                               straggler)
from repro.distributed.fault import (ElasticRemesh, RestartBackoff, RunResult,
                                     SupervisorConfig, TrainSupervisor)
from repro.distributed.straggler import (MitigationDecision, MitigationPolicy,
                                         StepTimeTracker, StragglerConfig)
