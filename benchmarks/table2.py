"""Paper Table 2: FFF vs MoE vs FF at matched training width, with ETT
("epochs to train" — here, steps to reach the best metric).

Protocol (scaled to CPU): widths w in {64, 128, 256}, leaf width 32,
expert width 16 with top-k 2, Adam lr 1e-3, cifar10_like.  Claims reproduced:
FFFs beat MoEs of equal training width on M_A/G_A and reach them in ~10x
fewer steps (the paper attributes the gap to noisy gating).
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks import common
from repro import optim
from repro.data import synthetic

WIDTHS = (64, 128, 256)


def _eval_maker(fw, ds):
    def ev(params):
        return (common.accuracy(fw, params, ds.x_train[:2048],
                                ds.y_train[:2048]),
                common.accuracy(fw, params, ds.x_val, ds.y_val))
    return ev


def _best(history):
    """(best_ma, ett_ma, best_ga, ett_ga) from [(step, (ma, va))]."""
    best_ma, ett_ma, best_va, ett_va = 0.0, 0, 0.0, 0
    for step, (ma, va) in history:
        if ma > best_ma:
            best_ma, ett_ma = ma, step
        if va > best_va:
            best_va, ett_va = va, step
    return best_ma, ett_ma, best_va, ett_va


def run(steps: int = 300, quick: bool = False) -> list[dict]:
    ds = synthetic.make("cifar10_like")
    rows = []
    widths = WIDTHS[:2] if quick else WIDTHS
    opt = lambda: optim.adamw(1e-3)
    for w in widths:
        builders = {
            "ff": common.build_ff(ds.dim, ds.num_classes, w),
            "moe": common.build_moe(ds.dim, ds.num_classes, w // 16, 16, k=2),
            "fff": common.build_fff(ds.dim, ds.num_classes,
                                    int(np.log2(w // 32)), 32),
        }
        for name, (cfg, p, tr, fw) in builders.items():
            ev = _eval_maker(fw, ds)
            p, hist = common.train_classifier(tr, p, ds, steps=steps,
                                              batch=512, opt=opt(),
                                              eval_every=max(steps // 20, 1),
                                              eval_fn=ev)
            ma, ett_ma, va, ett_va = _best(hist)
            ga = common.accuracy(fw, p, ds.x_test, ds.y_test)
            rows.append(dict(model=name, width=w, ma=ma, ett_ma=ett_ma,
                             ga=ga, ett_ga=ett_va))
    return rows


def main(quick: bool = True):
    rows = run(steps=200 if quick else 600, quick=quick)
    print("name,us_per_call,derived")
    for r in rows:
        print(f"table2/{r['model']}_w{r['width']},0.0,"
              f"ma={r['ma']:.3f};ett_ma={r['ett_ma']};"
              f"ga={r['ga']:.3f};ett_ga={r['ett_ga']}")
    return rows


if __name__ == "__main__":
    main(quick=False)
