"""Gradient compression for cross-pod (DCN) all-reduce.

Int8 error-feedback quantization: gradients are quantized per-leaf before the
pod-axis reduction, and the quantization error is carried into the next step
(error feedback keeps SGD convergence — Karimireddy et al., 2019).  The
intra-pod (ICI) reduction stays full precision; only the slow cross-pod hop is
compressed, a 4x byte reduction on the DCN bottleneck.

Two entry points:
  * ``ef_compress(opt)``     — optimizer wrapper; simulates the quantization
    on any topology (used in tests, exact error-feedback algebra).
  * ``compressed_psum``      — shard_map building block doing the real
    quantize -> psum(axis) -> dequantize dance on a named axis.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.optim.common import Optimizer

PyTree = Any


def _quantize(x: jax.Array, bits: int = 8) -> tuple[jax.Array, jax.Array]:
    qmax = 2.0 ** (bits - 1) - 1
    scale = jnp.max(jnp.abs(x)) / qmax
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax).astype(jnp.int8)
    return q, scale


def _dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


class EFState(NamedTuple):
    inner: Any
    error: PyTree


def ef_compress(opt: Optimizer, bits: int = 8) -> Optimizer:
    """Error-feedback int8 compression applied to the gradient stream."""

    def init(params: PyTree) -> EFState:
        err = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return EFState(opt.init(params), err)

    def update(grads: PyTree, state: EFState, params: PyTree = None):
        def comp(g, e):
            corrected = g.astype(jnp.float32) + e
            q, scale = _quantize(corrected, bits)
            deq = _dequantize(q, scale)
            return deq, corrected - deq

        pairs = jax.tree_util.tree_map(comp, grads, state.error)
        comp_grads = jax.tree_util.tree_map(
            lambda t: t[0], pairs, is_leaf=lambda x: isinstance(x, tuple))
        new_err = jax.tree_util.tree_map(
            lambda t: t[1], pairs, is_leaf=lambda x: isinstance(x, tuple))
        updates, inner = opt.update(comp_grads, state.inner, params)
        return updates, EFState(inner, new_err)

    return Optimizer(init, update)


def compressed_psum(x: jax.Array, axis_name: str, bits: int = 8) -> jax.Array:
    """Quantize -> all-reduce over ``axis_name`` -> dequantize.

    For use inside shard_map over the pod axis.  The int8 payload is what
    crosses DCN; the scale is agreed FIRST (a scalar pmax — negligible bytes)
    so every participant quantizes on the same grid and the integer sum
    dequantizes exactly.  psum of int8 can overflow at >127 pods; we
    accumulate in int32.
    """
    qmax = 2.0 ** (bits - 1) - 1
    local_scale = jnp.max(jnp.abs(x)) / qmax
    scale = jnp.maximum(jax.lax.pmax(local_scale, axis_name), 1e-12)
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax).astype(jnp.int8)
    q32 = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return q32.astype(jnp.float32) * scale


def compression_ratio(bits: int = 8, dtype_bits: int = 32) -> float:
    return dtype_bits / bits
