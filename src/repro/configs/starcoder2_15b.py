"""starcoder2-15b [dense] — 40L d_model=6144 48H (GQA kv=4) d_ff=24576
vocab=49152, GQA, RoPE, sliding-window 4096, GELU, LayerNorm, biases.
[arXiv:2402.19173; hf]"""
import jax.numpy as jnp

from repro.configs.base import BlockSpec, FFNSpec, ModelConfig

CONFIG = ModelConfig(
    arch_id="starcoder2-15b",
    family="dense",
    d_model=6144,
    n_layers=40,
    n_heads=48,
    n_kv_heads=4,
    vocab_size=49152,
    max_seq_len=32768,
    norm="layernorm",
    attn_bias=True,
    period=(BlockSpec(mixer="attn", sliding_window=4096,
                      ffn=FFNSpec(kind="dense", d_ff=24576,
                                  activation="gelu")),),
    param_dtype=jnp.bfloat16,
    accum_dtype=jnp.bfloat16,
    remat="full",
    grad_accum=16,
)

# 16 leaves x 1536 = 24576 (exact width match; 1536 = 12*128, MXU-aligned)
FFF_CONFIG = CONFIG.with_ffn_kind("fff", leaf_width=1536)
