"""Schema gate for ``experiments/BENCH_*.json`` benchmark artifacts (the CI
``bench-smoke`` job; start of the perf trajectory ISSUE 5 names).

Each artifact self-identifies via its ``bench`` key; this checker asserts
the per-bench required top-level keys and — for benches that embed engine
runs — the ``EngineMetrics.as_dict()`` core fields inside every run record,
so a refactor that silently drops a dashboarded field fails CI instead of
producing hollow artifacts.

Usage:
  PYTHONPATH=src python -m benchmarks.check_schema experiments/BENCH_*.json
"""
from __future__ import annotations

import json
import sys

# the EngineMetrics.as_dict() core every embedded run must carry
# (docs/serving.md documents the schema field-by-field)
METRICS_KEYS = {
    "n_requests", "n_tokens", "elapsed_s", "n_steps", "throughput_tok_s",
    "ttft_ms", "per_token_ms", "e2e_ms", "decode_step_ms",
    "decode_interval_ms", "overflow_fraction_mean", "overflow_decode_mean",
    "hint_mismatches", "tenants",
    # paged KV cache / prefix sharing (DESIGN.md §11)
    "prefill_tokens", "prefix_hit_tokens", "cow_copies", "pages_in_use",
    "pages_free",
}
SUMMARY_KEYS = {"n", "mean_ms", "p50_ms", "p90_ms", "p99_ms", "max_ms"}

# per-kernel-row benches carry these instead of engine metrics
ROW_KEYS = {"name", "us_per_call"}

# bench name -> (required top-level keys, key holding the run list/map,
#                record kind: "engine" = EngineMetrics.as_dict() runs,
#                "rows" = kernel-benchmark CSV rows)
SCHEMAS = {
    # serving_load additionally carries the capacity<1.0 overflow-policy
    # sections (DESIGN.md §14): policy throughput gate, balanced-training
    # overflow gate, and the approximate-repair error bound
    "serving_load": ({"bench", "quick", "slots", "classes", "policy_compare",
                      "balance_compare", "repair_error", "runs"}, "runs",
                     "engine"),
    "serving_chunked": ({"bench", "quick", "slots", "chunk",
                         "decode_interval_p99_drop", "stall_bound_tokens",
                         "runs"}, "runs", "engine"),
    "serving_qos": ({"bench", "quick", "slots", "classes", "fairness",
                     "profile_convergence", "overflow_decode", "runs"},
                    "runs", "engine"),
    "serving_spec": ({"bench", "quick", "slots", "depth", "gen", "spec_k",
                      "classes", "speedup", "speedup_gate", "speedup_ok",
                      "overflow_ok", "runs"}, "runs", "engine"),
    "serving_paged": ({"bench", "quick", "slots", "page_size", "shared_len",
                       "gen", "prefill_ratio", "prefill_gate", "prefill_ok",
                       "ttft_ok", "parity_checked", "compile_ok",
                       "compiled_shapes", "runs"}, "runs", "engine"),
    "serving_cluster": ({"bench", "quick", "topology", "page_size", "gen",
                         "speedup", "speedup_gate", "speedup_ok", "kill_ok",
                         "lost_requests", "parity_checked", "worker_restarts",
                         "replayed_requests", "duplicate_results", "scale_ok",
                         "scale_events", "compile_ok", "compiled_shapes",
                         "runs"}, "runs", "engine"),
    # fused decode megakernel vs the 3-dispatch path (DESIGN.md §13):
    # kernel timing rows, not engine runs — plus the dispatch contract
    "roofline": ({"bench", "quick", "dryrun_records", "shape",
                  "dispatches_fused", "dispatches_baseline", "dispatch_ok",
                  "speedup", "speedup_ok", "hbm_bytes_per_token", "rows"},
                 "rows", "rows"),
}


def check_artifact(path: str) -> list:
    """Return a list of problem strings (empty = artifact passes)."""
    problems = []
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"{path}: unreadable ({e})"]
    bench = doc.get("bench")
    if bench not in SCHEMAS:
        return [f"{path}: unknown/missing bench id {bench!r} "
                f"(known: {sorted(SCHEMAS)})"]
    required, runs_key, kind = SCHEMAS[bench]
    missing = required - set(doc)
    if missing:
        problems.append(f"{path}: missing top-level keys {sorted(missing)}")
    runs = doc.get(runs_key, [])
    records = list(runs.values()) if isinstance(runs, dict) else list(runs)
    if not records:
        problems.append(f"{path}: empty {runs_key!r}")
    per_record = METRICS_KEYS if kind == "engine" else ROW_KEYS
    for i, rec in enumerate(records):
        gone = per_record - set(rec)
        if gone:
            problems.append(f"{path}: run[{i}] missing "
                            f"{'metric' if kind == 'engine' else 'row'} "
                            f"keys {sorted(gone)}")
            continue
        if kind != "engine":
            continue
        for k in ("ttft_ms", "decode_step_ms"):
            if set(rec[k]) != SUMMARY_KEYS:
                problems.append(f"{path}: run[{i}].{k} is not a latency "
                                f"summary (has {sorted(rec[k])})")
    return problems


def main(argv=None) -> int:
    paths = list(sys.argv[1:] if argv is None else argv)
    if not paths:
        print("usage: python -m benchmarks.check_schema BENCH_*.json",
              file=sys.stderr)
        return 2
    problems = []
    for p in paths:
        problems += check_artifact(p)
    for msg in problems:
        print(f"SCHEMA: {msg}", file=sys.stderr)
    if not problems:
        print(f"schema ok: {len(paths)} artifact(s) "
              f"({', '.join(paths)})")
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main())
