"""Cluster control plane: admission, placement, replay, elastic actuation
(DESIGN.md §12).

The ``Router`` owns everything above ``EngineConfig``:

* **Admission + tenant QoS** — one global waiting queue fed by
  ``submit``; each step the serving scheduler (``make_scheduler`` — the
  same stride-fair ``weighted_leaf_aware`` policy the single engine runs)
  picks which waiting requests get prefill credits.  The cluster view has
  no slot-level telemetry, so the scheduler sees a synthetic
  ``num_leaves=0`` view and degrades to its weighted-FIFO core; leaf
  balance is placement's job (placement.py scores the decode side).
* **Prefix affinity** — ``GlobalPrefixMap`` is a router-side radix over
  page-sized token chunks mapping longest-known-prefix → prefill worker,
  so prompts sharing a system prefix land where the local ``PrefixIndex``
  already holds those pages (admission there allocates shared pages
  instead of recomputing).  Entries die with their worker.
* **Handoff routing** — completed prefills (``PrefillDone``) carry their
  KV pages and measured leaf footprint; ``choose_decode`` places them on
  the decode fleet and the router optimistically debits the target's view
  so a burst doesn't pile onto one worker between heartbeats.
* **Fault tolerance** — the ``ClusterMonitor`` times out heartbeats; a
  dead worker's in-flight requests (prefilling on it, or decoding on it —
  the pages died with the process) go back to ``queued`` and re-run from
  the prompt.  Determinism makes replay exact: the regenerated tokens are
  byte-identical, and Done dedup (first result per rid wins) makes a
  kill-after-finish race harmless.  Respawns come back under a fresh
  worker id through the monitor's restart budget.
* **Elastic actuation** — monitor watermark decisions become
  ``bus.spawn`` (scale-up) or a ``Drain`` handshake (scale-down: worker
  finishes in-flight work, reports ``Drained``, gets ``Stop``).

Results are re-stamped on the ROUTER clock (submit→admit→first
token→finish as observed here), so cluster latency metrics include queue,
wire, and handoff time — not just the engine-local slice.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.cluster import bus as bus_lib
from repro.cluster.control import (ClusterMonitor, ControlConfig,
                                   DrainWorker, MarkDead, Respawn,
                                   SpawnDecode)
from repro.cluster.placement import (WorkerView, choose_decode,
                                     choose_prefill)
from repro.serving import metrics as metrics_lib
from repro.serving.request import Request, RequestResult
from repro.serving.scheduler import SchedulerView, make_scheduler


@dataclasses.dataclass
class ClusterConfig:
    """Cluster policy knobs (everything above EngineConfig)."""
    n_prefill: int = 1
    n_decode: int = 2
    scheduler: str = "weighted_leaf_aware"
    scheduler_kw: dict = dataclasses.field(default_factory=dict)
    control: ControlConfig = dataclasses.field(default_factory=ControlConfig)
    page_size: int = 16             # must match every worker engine


class GlobalPrefixMap:
    """Longest-known-prefix → prefill worker, over page-sized chunks.

    Mirrors the per-engine radix ``PrefixIndex`` one level up: the router
    can't see pool pages, but it knows WHICH worker published a prefix, and
    that is all affinity needs."""

    def __init__(self, page_size: int):
        self.page = page_size
        self._map: Dict[bytes, str] = {}

    def insert(self, prompt, wid: str) -> None:
        p = np.asarray(prompt, np.int32)
        for n in range(self.page, len(p) + 1, self.page):
            self._map[p[:n].tobytes()] = wid

    def lookup(self, prompt) -> Optional[str]:
        p = np.asarray(prompt, np.int32)
        best = None
        for n in range(self.page, len(p) + 1, self.page):
            wid = self._map.get(p[:n].tobytes())
            if wid is None:
                break
            best = wid
        return best

    def drop_worker(self, wid: str) -> None:
        self._map = {k: w for k, w in self._map.items() if w != wid}

    def __len__(self) -> int:
        return len(self._map)


@dataclasses.dataclass
class _ReqState:
    req: Request
    phase: str = "queued"   # queued|prefilling|pending_handoff|decoding|done
    wid: Optional[str] = None
    submit_t: float = 0.0
    dispatch_t: float = 0.0
    first_token_t: float = 0.0


class Router:
    def __init__(self, bus, ccfg: ClusterConfig,
                 clock: Callable[[], float],
                 spawn_decode_fn: Optional[Callable[[], None]] = None):
        self.bus = bus
        self.ccfg = ccfg
        self.clock = clock
        self.scheduler = make_scheduler(ccfg.scheduler, **ccfg.scheduler_kw)
        self.monitor = ClusterMonitor(ccfg.control, clock)
        self.prefix_map = GlobalPrefixMap(ccfg.page_size)
        self.views: Dict[str, WorkerView] = {}
        self.waiting: deque = deque()
        self.pending_handoffs: deque = deque()
        self.states: Dict[int, _ReqState] = {}
        self.results: List[RequestResult] = []
        self.byes: Dict[str, bus_lib.Bye] = {}
        self._wid_seq: Dict[str, int] = {"prefill": 0, "decode": 0}
        self._spawn_decode_fn = spawn_decode_fn
        self.replayed_requests = 0
        self.worker_restarts = 0
        self.duplicate_results = 0
        self.ticks = 0

    # -- topology ----------------------------------------------------------

    def _new_wid(self, role: str) -> str:
        n = self._wid_seq[role]
        self._wid_seq[role] = n + 1
        return f"{role[0]}{n}"

    def spawn_worker(self, role: str) -> str:
        wid = self._new_wid(role)
        self.bus.spawn(wid, role)
        self.views[wid] = WorkerView(wid=wid, role=role,
                                     last_seen=self.clock())
        return wid

    def start(self) -> None:
        for _ in range(self.ccfg.n_prefill):
            self.spawn_worker("prefill")
        for _ in range(self.ccfg.n_decode):
            self.spawn_worker("decode")

    # -- submission --------------------------------------------------------

    def submit(self, req: Request) -> None:
        if req.rid in self.states:
            raise ValueError(f"request rid {req.rid} already submitted")
        self.states[req.rid] = _ReqState(req=req, submit_t=self.clock())
        self.waiting.append(req)

    @property
    def queue_depth(self) -> int:
        return len(self.waiting) + len(self.pending_handoffs)

    def outstanding(self) -> int:
        return sum(1 for s in self.states.values() if s.phase != "done")

    # -- message handling --------------------------------------------------

    def _handle(self, msg) -> None:
        now = self.clock()
        if isinstance(msg, bus_lib.Heartbeat):
            v = self.views.get(msg.wid)
            if v is None:        # late beat from a worker we already buried
                return
            v.pages_free = msg.pages_free
            v.pages_total = msg.pages_total
            v.queue_depth = msg.queue_depth
            v.active_slots = msg.active_slots
            v.num_slots = msg.num_slots
            v.handoff_bytes = msg.handoff_bytes
            v.n_ticks = msg.n_ticks
            v.last_seen = now
            v.update_occupancy(msg.occupancy)
            if msg.profiles:
                v.profiles = msg.profiles
            # liveness runs on receipt time: worker clocks aren't ours
            self.monitor.observe_heartbeat(msg.wid, now)
        elif isinstance(msg, bus_lib.PrefillDone):
            st = self.states.get(msg.handoff.request.rid)
            if st is None or st.phase != "prefilling" or st.wid != msg.wid:
                return           # late: the request was replayed elsewhere
            self._credit(msg.wid, -1)
            self.prefix_map.insert(msg.handoff.request.prompt, msg.wid)
            st.phase, st.wid = "pending_handoff", None
            self.pending_handoffs.append(msg.handoff)
        elif isinstance(msg, bus_lib.Done):
            st = self.states.get(msg.result.rid)
            if st is None:
                return
            if st.phase == "done":
                self.duplicate_results += 1     # kill-after-finish race
                return
            if st.wid != msg.wid:
                # stale: the sender was buried and the request replayed —
                # only the currently-assigned worker's result counts (a
                # ProcBus SIGKILL can leave the victim's last sends in the
                # shared outbox queue)
                self.duplicate_results += 1
                return
            self._credit(msg.wid, -1)
            st.phase = "done"
            self.results.append(dataclasses.replace(
                msg.result, arrival_time=st.submit_t,
                admitted_time=st.dispatch_t,
                first_token_time=st.first_token_t or now, finish_time=now))
        elif isinstance(msg, bus_lib.Drained):
            if msg.wid in self.views:
                self.bus.send(msg.wid, bus_lib.Stop())
        elif isinstance(msg, bus_lib.Bye):
            self.byes[msg.wid] = msg
            self.views.pop(msg.wid, None)
            self.monitor.forget(msg.wid)
            self.prefix_map.drop_worker(msg.wid)

    def _credit(self, wid: str, delta: int) -> None:
        v = self.views.get(wid)
        if v is not None:
            v.outstanding = max(0, v.outstanding + delta)

    # -- dispatch ----------------------------------------------------------

    def _scheduler_view(self) -> SchedulerView:
        """Synthetic slot-less view: the scheduler's leaf logic needs
        engine telemetry the router doesn't have, so num_leaves=0 degrades
        it to its fair-queueing core over the prefill fleet's credit."""
        free = sum(v.free_slots for v in self.views.values()
                   if v.role == "prefill" and not v.draining)
        n = max(1, free)
        return SchedulerView(
            occupancy=np.zeros((n, 1)), active=np.zeros((n,), bool),
            num_leaves=0, capacity_factor=1.0, num_slots=n,
            prefilling=np.zeros((n,), bool),
            pages_free=sum(v.pages_free for v in self.views.values()))

    def _dispatch_prefill(self) -> None:
        free = sum(v.free_slots for v in self.views.values()
                   if v.role == "prefill" and not v.draining)
        if free <= 0 or not self.waiting:
            return
        chosen = self.scheduler.select(list(self.waiting), free,
                                       self._scheduler_view())
        for req in chosen:
            if self.states[req.rid].phase != "queued":
                self.waiting.remove(req)        # completed while waiting
                continue
            hint = self.prefix_map.lookup(req.prompt)
            wid = choose_prefill(self.views, hint)
            if wid is None:
                break
            if not self.bus.send(wid, bus_lib.Submit(req)):
                continue         # raced a death; retry next tick
            self.waiting.remove(req)
            st = self.states[req.rid]
            st.phase, st.wid = "prefilling", wid
            st.dispatch_t = self.clock()
            self._credit(wid, +1)

    def _route_handoffs(self) -> None:
        held = len(self.pending_handoffs)
        for _ in range(held):
            h = self.pending_handoffs.popleft()
            st = self.states.get(h.request.rid)
            if st is None or st.phase != "pending_handoff":
                continue         # replayed or completed meanwhile
            wid = choose_decode(self.views, h.occupancy)
            if wid is None or not self.bus.send(wid, bus_lib.Install(h)):
                self.pending_handoffs.append(h)   # backpressure: hold it
                continue
            st.phase, st.wid = "decoding", wid
            if not st.first_token_t:
                st.first_token_t = self.clock()
            v = self.views[wid]
            self._credit(wid, +1)
            # optimistic debit until the next heartbeat refreshes truth
            need = -(-(h.prompt_len + h.request.max_new_tokens)
                     // max(1, h.page_size))
            v.pages_free = max(0, v.pages_free - need)

    # -- fault handling ----------------------------------------------------

    def _bury(self, wid: str) -> None:
        """Worker is dead: fence it, forget it, replay its in-flight
        work from the prompt (its pages died with it)."""
        self.bus.kill(wid)
        self.views.pop(wid, None)
        self.monitor.forget(wid)
        self.prefix_map.drop_worker(wid)
        for st in self.states.values():
            if st.wid == wid and st.phase in ("prefilling", "decoding"):
                st.phase, st.wid = "queued", None
                self.waiting.append(st.req)
                self.replayed_requests += 1

    def _execute(self, actions) -> None:
        for a in actions:
            if isinstance(a, MarkDead):
                self._bury(a.wid)
            elif isinstance(a, Respawn):
                self.spawn_worker(a.role)
                self.worker_restarts += 1
            elif isinstance(a, SpawnDecode):
                if self._spawn_decode_fn is not None:
                    self._spawn_decode_fn()
                else:
                    self.spawn_worker("decode")
            elif isinstance(a, DrainWorker):
                v = self.views.get(a.wid)
                if v is not None and not v.draining:
                    v.draining = True
                    self.bus.send(a.wid, bus_lib.Drain())

    # -- the loop ----------------------------------------------------------

    def step(self) -> None:
        self.ticks += 1
        self.bus.pump()
        for msg in self.bus.poll():
            self._handle(msg)
        self._dispatch_prefill()
        self._route_handoffs()
        self._execute(self.monitor.tick(self.views, len(self.waiting)))

    def run(self, requests: List[Request], max_ticks: int = 100_000,
            on_tick: Optional[Callable[["Router"], None]] = None
            ) -> List[RequestResult]:
        """Serve ``requests`` to completion; returns results sorted by rid.
        ``max_ticks`` bounds a wedged cluster (dead fleet + exhausted
        restart budget) instead of spinning forever.  ``on_tick`` runs
        after every step — fault-injection drivers (serve.py
        ``--cluster-kill``, the benchmark's kill run) hook it."""
        for r in requests:
            self.submit(r)
        t0 = self.ticks
        while any(s.phase != "done" for s in self.states.values()):
            if self.ticks - t0 >= max_ticks:
                stuck = sorted(r for r, s in self.states.items()
                               if s.phase != "done")
                raise RuntimeError(
                    f"cluster wedged after {max_ticks} ticks; "
                    f"unfinished rids: {stuck[:10]}")
            self.step()
            if on_tick is not None:
                on_tick(self)
        return sorted(self.results, key=lambda r: r.rid)

    def kill_worker(self, wid: str) -> None:
        """Driver-initiated fault injection: SIGKILL/drop ``wid`` NOW, bury
        it (replaying its in-flight work) and respawn its role — the
        deterministic e2e kill path that doesn't wait out the heartbeat
        timeout (the monitor path is what the LocalBus tests exercise)."""
        role = self.views[wid].role
        self._bury(wid)
        self.spawn_worker(role)
        self.worker_restarts += 1

    def drain_all(self) -> None:
        for wid in list(self.views):
            self._execute([DrainWorker(wid)])

    def shutdown(self, max_ticks: int = 10_000) -> None:
        """Stop every worker and collect final Byes (LocalBus; ProcBus
        workers answer over the queue within the tick budget)."""
        for wid in list(self.views):
            self.bus.send(wid, bus_lib.Stop())
        for _ in range(max_ticks):
            if not self.views:
                break
            self.bus.pump()
            for msg in self.bus.poll():
                self._handle(msg)
        self.bus.close()

    # -- reporting ---------------------------------------------------------

    def cluster_metrics(self) -> dict:
        per_worker = {}
        for wid, v in self.views.items():
            per_worker[wid] = {"role": v.role, "pages_free": v.pages_free,
                               "queue_depth": v.queue_depth,
                               "handoff_bytes": v.handoff_bytes,
                               "n_ticks": v.n_ticks}
        for wid, bye in self.byes.items():
            per_worker.setdefault(wid, {})["compiled_shapes"] = \
                bye.compiled_shapes
        return {
            "per_worker": per_worker,
            "handoff_bytes": sum(v.handoff_bytes
                                 for v in self.views.values())
                             + sum(b.metrics.get("handoff_bytes", 0)
                                   for b in self.byes.values()),
            "replayed_requests": self.replayed_requests,
            "worker_restarts": self.worker_restarts,
            "duplicate_results": self.duplicate_results,
            "scale_events": list(self.monitor.scale_events),
            "router_ticks": self.ticks,
        }

    def metrics(self, elapsed_s: Optional[float] = None
                ) -> metrics_lib.EngineMetrics:
        n_ticks = sum(v.n_ticks for v in self.views.values()) + \
            sum(b.metrics.get("n_ticks", 0) for b in self.byes.values())
        return metrics_lib.from_results(
            self.results,
            elapsed_s=self.clock() if elapsed_s is None else elapsed_s,
            n_steps=n_ticks, n_prefills=len(self.results),
            decode_lat_s=[], overflow_mean=0.0,
            pages_free=sum(v.pages_free for v in self.views.values()),
            pages_in_use=sum(v.pages_total - v.pages_free
                             for v in self.views.values()))
