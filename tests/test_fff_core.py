"""Unit tests for the core FFF layer: paper Algorithm 1 semantics, exercised
through the single ``api.apply()`` entry point."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import api, ff, fff, routing

TRAIN = api.ExecutionSpec(mode="train")
INFER = api.ExecutionSpec(mode="infer", backend="reference")


def make(depth=3, leaf=4, din=16, dout=10, act="relu", trees=1, seed=0, **kw):
    cfg = fff.FFFConfig(dim_in=din, dim_out=dout, depth=depth, leaf_width=leaf,
                        activation=act, trees=trees, **kw)
    return cfg, fff.init(jax.random.PRNGKey(seed), cfg)


def test_shapes_train_and_hard():
    cfg, p = make()
    x = jax.random.normal(jax.random.PRNGKey(1), (32, 16))
    y_t, out = api.apply(p, cfg, x, TRAIN)
    y_i, out_i = api.apply(p, cfg, x, INFER)
    assert y_t.shape == (32, 10) and y_i.shape == (32, 10)
    assert out.node_probs.shape == (32, 1, cfg.num_nodes)
    assert out.mixture.shape == (32, 1, cfg.num_leaves)
    assert out_i.leaf_idx.shape == (32, 1)
    assert jnp.isfinite(y_t).all() and jnp.isfinite(y_i).all()


def test_mixture_weights_form_distribution():
    cfg, p = make(depth=5, leaf=2)
    x = jax.random.normal(jax.random.PRNGKey(2), (64, 16))
    _, out = api.apply(p, cfg, x, TRAIN)
    s = out.mixture.sum(-1)
    np.testing.assert_allclose(np.asarray(s), 1.0, atol=1e-5)
    assert (out.mixture >= 0).all()


def test_leading_dims_flattened():
    cfg, p = make()
    x = jax.random.normal(jax.random.PRNGKey(3), (4, 8, 16))
    y, _ = api.apply(p, cfg, x, TRAIN)
    assert y.shape == (4, 8, 10)
    y2, out2 = api.apply(p, cfg, x, INFER)
    assert y2.shape == (4, 8, 10)
    assert out2.leaf_idx.shape == (4, 8, 1)


def test_hard_equals_train_when_hardened():
    """FORWARD_I == FORWARD_T in the hardened limit (paper §Hardening),
    on tokens with a decision margin at every node."""
    cfg, p = make(depth=3, leaf=4)
    scale = 50000.0
    p_hard = dict(p)
    p_hard["node_w1"] = p["node_w1"] * scale
    p_hard["node_b1"] = p["node_b1"] * scale
    x = jax.random.normal(jax.random.PRNGKey(4), (128, 16))
    # keep only tokens where every node decision has margin
    logits = fff._node_logits_all(p, cfg, x.astype(jnp.float32))
    margin = jnp.abs(logits).min(axis=(1, 2))
    keep = np.asarray(margin) > 1e-3
    x = x[keep]
    y_t, _ = api.apply(p_hard, cfg, x, TRAIN)
    y_i, _ = api.apply(p_hard, cfg, x, INFER)
    np.testing.assert_allclose(np.asarray(y_t), np.asarray(y_i),
                               rtol=1e-4, atol=1e-4)


def test_zero_nodes_equals_scaled_dense_ff():
    """Paper §Size and width: FFF with zeroed node nets == vanilla FF of the
    training width, up to the uniform 2^-d output rescale."""
    cfg, p = make(depth=2, leaf=4)
    for k in ("node_w1", "node_b1", "node_w2", "node_b2"):
        p[k] = jnp.zeros_like(p[k])
    x = jax.random.normal(jax.random.PRNGKey(5), (16, 16))
    y, _ = api.apply(p, cfg, x, TRAIN)
    dense = fff.as_dense_ff_params(p, cfg)
    fcfg = ff.FFConfig(dim_in=16, dim_out=10, width=16, activation="relu")
    y_ff = ff.forward(dense, fcfg, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ff), atol=1e-5)


def test_route_hard_matches_per_level_gather():
    cfg, p = make(depth=6, leaf=2, din=24)
    x = jax.random.normal(jax.random.PRNGKey(6), (64, 24))
    dense = fff.route_hard(p, cfg, x, dense_levels=8)
    gather = fff.route_hard(p, cfg, x, dense_levels=0)
    assert (dense == gather).all()


def test_forest_sums_trees():
    cfg, p = make(depth=2, leaf=4, trees=3)
    x = jax.random.normal(jax.random.PRNGKey(7), (8, 16))
    y, _ = api.apply(p, cfg, x, INFER)
    # evaluate each tree separately and sum
    total = jnp.zeros_like(y)
    for t in range(3):
        p_t = {k: v[t:t + 1] for k, v in p.items()}
        cfg_t = fff.FFFConfig(dim_in=16, dim_out=10, depth=2, leaf_width=4,
                              activation="relu", trees=1)
        y_t, _ = api.apply(p_t, cfg_t, x, INFER)
        total = total + y_t
    np.testing.assert_allclose(np.asarray(y), np.asarray(total), atol=1e-5)


def test_grouped_hard_matches_gather_hard():
    cfg, p = make(depth=4, leaf=8, act="swiglu", leaf_bias=False)
    x = jax.random.normal(jax.random.PRNGKey(8), (64, 16))
    y1, o1 = api.apply(p, cfg, x, INFER)
    y2, o2 = api.apply(p, cfg, x, api.ExecutionSpec(
        mode="infer", backend="grouped", capacity_factor=8.0))
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)
    assert (o1.leaf_idx == o2.leaf_idx).all()
    assert float(o2.overflow_fraction) == 0.0


def test_grouped_overflow_never_corrupts_kept_tokens():
    """Over-capacity tokens must be dropped cleanly: kept tokens' outputs
    match the exact gather bit-for-bit (a clamped scatter used to collide a
    dropped token's zero row with the last kept slot nondeterministically)."""
    E, B, D, H = 4, 64, 8, 4
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (B, D))
    params = {"leaf_w1": jax.random.normal(jax.random.fold_in(key, 1),
                                           (E, D, H)),
              "leaf_w2": jax.random.normal(jax.random.fold_in(key, 2),
                                           (E, H, D))}
    leaf_idx = jnp.zeros((B,), jnp.int32)          # everyone routes to leaf 0
    y, kept = routing.grouped_leaf_apply(x, leaf_idx, params, "gelu",
                                         capacity_factor=0.25,
                                         return_kept=True)
    assert 0 < int(kept.sum()) < B                 # the bound actually bites
    h = jax.nn.gelu(jnp.einsum("bd,dh->bh", x, params["leaf_w1"][0],
                               preferred_element_type=jnp.float32))
    want = jnp.einsum("bh,ho->bo", h, params["leaf_w2"][0],
                      preferred_element_type=jnp.float32)
    np.testing.assert_allclose(np.asarray(y[np.asarray(kept)]),
                               np.asarray(want[np.asarray(kept)]),
                               rtol=1e-5, atol=1e-5)
    assert float(jnp.abs(y[~np.asarray(kept)]).max()) == 0.0


def test_capacity_dispatch_flop_regression_guard():
    """DESIGN.md §5: slot assignment must come from sort ranks, never
    cumsum(one_hot) + dense (B, E, C) dispatch einsums.  The seed's
    make_capacity_dispatch built exactly that (measured 260x FLOP inflation
    at 64 experts); pin the compiled FLOP count orders of magnitude below the
    dense-dispatch cost so it cannot come back."""
    B, E, D = 512, 64, 128
    x = jnp.zeros((B, D))
    leaf_idx = jnp.zeros((B,), jnp.int32)

    def gather(xx, ii):
        return routing.capacity_gather(
            xx, routing.make_capacity_dispatch(ii, E))

    compiled = jax.jit(gather).lower(x, leaf_idx).compile()
    ca = compiled.cost_analysis()
    ca = ca[0] if isinstance(ca, (list, tuple)) else ca
    flops = float(ca.get("flops", 0.0))
    # dense dispatch costs 2*B*E*C*D (~84 MFLOP here); sort-rank scatter is
    # O(B log B) comparisons and O(B*D) moves — essentially FLOP-free
    assert flops < B * E, f"capacity dispatch regressed to dense: {flops}"


def test_hardening_loss_properties():
    p_half = jnp.full((8, 1, 7), 0.5)
    p_hard = jnp.concatenate([jnp.full((8, 1, 4), 1e-6),
                              jnp.full((8, 1, 3), 1 - 1e-6)], axis=-1)
    assert float(fff.hardening_loss(p_half)) == pytest.approx(np.log(2), rel=1e-3)
    assert float(fff.hardening_loss(p_hard)) < 1e-4
    assert float(fff.decisive_fraction(p_hard)) == 1.0
    assert float(fff.decisive_fraction(p_half)) == 0.0


def test_st_training_grads_flow_everywhere():
    cfg, p = make(depth=3, leaf=4, act="swiglu", leaf_bias=False,
                  st_training=True)
    x = jax.random.normal(jax.random.PRNGKey(9), (64, 16))

    def loss(p):
        # backend="auto" resolves st_training configs to the grouped ST path
        y, out = api.apply(p, cfg, x, TRAIN)
        return (y ** 2).mean() + 0.1 * out.entropy

    g = jax.grad(loss)(p)
    for k, v in g.items():
        assert jnp.isfinite(v).all(), k
        assert float(jnp.abs(v).sum()) > 0, f"zero grad for {k}"


def test_dense_training_grads_flow_everywhere():
    cfg, p = make(depth=3, leaf=4)
    x = jax.random.normal(jax.random.PRNGKey(10), (32, 16))

    def loss(p):
        y, out = api.apply(p, cfg, x, TRAIN)
        return (y ** 2).mean() + 0.1 * out.entropy

    g = jax.grad(loss)(p)
    for k, v in g.items():
        assert float(jnp.abs(v).sum()) > 0, f"zero grad for {k}"


def test_balance_loss_properties():
    """0 at uniform soft usage, E-1 at fully collapsed usage, 0 at depth 0."""
    depth, E = 3, 8
    p_half = jnp.full((16, 2, E - 1), 0.5)         # uniform mixture
    assert float(fff.balance_loss(p_half, depth)) == pytest.approx(0.0,
                                                                   abs=1e-6)
    p_hard = jnp.full((16, 2, E - 1), 1.0 - 1e-7)  # everyone to one leaf
    assert float(fff.balance_loss(p_hard, depth)) == pytest.approx(E - 1,
                                                                   rel=1e-3)
    assert float(fff.balance_loss(p_half, 0)) == 0.0
    u = fff.leaf_usage(p_half, depth)
    assert u.shape == (2, E)
    np.testing.assert_allclose(np.asarray(u), 1.0 / E, atol=1e-6)


def test_balance_training_balances_skewed_usage():
    """The toy skewed task: a tight input cluster routes (softly) to few
    leaves at init; descending only the balance aux must spread mean soft
    usage to near-uniform (entropy gate) without touching leaf params."""
    cfg, p = make(depth=3, leaf=4, act="gelu", seed=3, leaf_bias=False)
    # sharpen the node boundaries so the cluster's soft routing is decisively
    # skewed at t=0 (untouched init sits near sigmoid(0): already uniform)
    for k in ("node_w1", "node_b1"):
        p[k] = p[k] * 3.0
    base = jax.random.normal(jax.random.PRNGKey(30), (1, 16))
    x = base + 0.05 * jax.random.normal(jax.random.PRNGKey(31), (256, 16))

    def bal(p):
        _, out = api.apply(p, cfg, x, TRAIN)
        return fff.balance_loss(out.node_probs, cfg.depth)

    def usage_entropy(p):
        _, out = api.apply(p, cfg, x, TRAIN)
        u = np.asarray(fff.leaf_usage(out.node_probs, cfg.depth),
                       np.float64)[0]
        u = u / u.sum()
        return float(-(u * np.log(u + 1e-12)).sum())

    l0, h0 = float(bal(p)), usage_entropy(p)
    assert l0 > 0.5, "cluster not skewed enough to exercise the loss"
    g = jax.jit(jax.grad(bal))
    for _ in range(150):
        grads = g(p)
        p = {k: (v - 0.5 * grads[k] if k.startswith("node_") else v)
             for k, v in p.items()}
    l1, h1 = float(bal(p)), usage_entropy(p)
    assert l1 < 0.1 * l0
    assert h1 > h0
    assert h1 > 0.9 * np.log(cfg.num_leaves)       # near-uniform usage


def test_master_leaf_term_is_additive_and_grads_flow():
    """cfg.master_leaf adds exactly master_apply(x) to every token in BOTH
    modes (api.apply adds it centrally), and training gradients reach the
    master weights alongside everything else."""
    import dataclasses
    for act, keys in [("gelu", ("master_w1", "master_w2")),
                      ("swiglu", ("master_wg", "master_wu", "master_wd"))]:
        cfg, p = make(depth=3, leaf=4, act=act, leaf_bias=False, seed=7,
                      master_leaf=True)
        assert all(k in p for k in keys)
        x = jax.random.normal(jax.random.PRNGKey(13), (32, 16))
        cfg0 = dataclasses.replace(cfg, master_leaf=False)
        p0 = {k: v for k, v in p.items() if not k.startswith("master_")}
        m = fff.master_apply(p, cfg, x)
        for spec in (TRAIN, INFER):
            y1, _ = api.apply(p, cfg, x, spec)
            y0, _ = api.apply(p0, cfg0, x, spec)
            np.testing.assert_allclose(np.asarray(y1 - y0), np.asarray(m),
                                       rtol=2e-5, atol=2e-5)

        def loss(p):
            y, _ = api.apply(p, cfg, x, TRAIN)
            return (y ** 2).mean()

        g = jax.grad(loss)(p)
        for k in keys:
            assert float(jnp.abs(g[k]).sum()) > 0, f"zero grad for {k}"


def test_child_transposition_changes_mixture():
    cfg, p = make(depth=3, leaf=4, transposition_prob=0.5)
    x = jax.random.normal(jax.random.PRNGKey(11), (32, 16))
    _, o1 = api.apply(p, cfg, x, api.ExecutionSpec(
        mode="train", rng=jax.random.PRNGKey(1)))
    _, o2 = api.apply(p, cfg, x, api.ExecutionSpec(
        mode="train", rng=jax.random.PRNGKey(2)))
    assert not np.allclose(np.asarray(o1.mixture), np.asarray(o2.mixture))


def test_freeze_tree_stops_node_grads():
    cfg, p = make(depth=3, leaf=4, freeze_tree=True)
    x = jax.random.normal(jax.random.PRNGKey(12), (32, 16))

    def loss(p):
        y, _ = api.apply(p, cfg, x, TRAIN)
        return (y ** 2).mean()

    g = jax.grad(loss)(p)
    assert float(jnp.abs(g["node_w1"]).sum()) == 0.0
    assert float(jnp.abs(g["leaf_w1"]).sum()) > 0.0


def test_size_width_accounting():
    """Paper §Size and width formulas."""
    cfg = fff.FFFConfig(dim_in=8, dim_out=8, depth=4, leaf_width=8,
                        node_width=1)
    assert cfg.training_width == 2 ** 4 * 8
    assert cfg.inference_width == 8
    assert cfg.training_size == (2 ** 4 - 1) * 1 + 2 ** 4 * 8
    assert cfg.inference_size == 4 * 1 + 8
