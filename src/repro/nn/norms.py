"""Normalization layers (RMSNorm / LayerNorm).

Numerics note (§Perf iter 3): reductions (mean/var) accumulate in float32,
but the normalize multiply stays in the input dtype.  Materializing a full
f32 copy of the residual stream made XLA hoist the upcast through the
residual add into the tensor-parallel all-reduces, doubling the dominant
collective bytes of every training step (f32 ARs of (tokens, D)).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Params = dict


def rmsnorm_init(dim: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((dim,), dtype)}


def rmsnorm(params: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * inv * params["scale"].astype(x.dtype)


def layernorm_init(dim: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}


def layernorm(params: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mean = xf.mean(axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    y = (x - mean.astype(x.dtype)) * inv
    return y * params["scale"].astype(x.dtype) + params["bias"].astype(x.dtype)


def norm_init(kind: str, dim: int, dtype=jnp.float32) -> Params:
    return rmsnorm_init(dim, dtype) if kind == "rmsnorm" else layernorm_init(dim, dtype)


def norm_apply(kind: str, params: Params, x: jax.Array) -> jax.Array:
    return rmsnorm(params, x) if kind == "rmsnorm" else layernorm(params, x)
