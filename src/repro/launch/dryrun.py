import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: pjit partitions
every entry point over the production meshes ((16,16) single-pod, (2,16,16)
multi-pod), ``compiled.memory_analysis()`` reports the per-device footprint,
``compiled.cost_analysis()`` + the optimized HLO feed §Roofline.

NOTE the XLA_FLAGS line above MUST run before any other import (jax locks the
device count on first init) — this file is the only place the 512 placeholder
devices exist; smoke tests and benchmarks see the real single CPU device.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch internlm2-20b \
      --shape train_4k --multi-pod both
  PYTHONPATH=src python -m repro.launch.dryrun --all --out dryrun.json
"""

import argparse
import dataclasses
import json
import time
import traceback
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import optim, utils
from repro.configs import SHAPES, registry, shape_applicable
from repro.configs.base import ModelConfig, ShapeSpec
from repro.distributed import act, sharding
from repro.launch import mesh as mesh_lib
from repro.launch import roofline, specs
from repro.models import lm


def _dp_axes(mesh, batch: int):
    """Batch-dim sharding axes, or None when the batch is too small to
    shard (long-context decode: B=1 -> replicate batch, shard sequence)."""
    daxes = mesh_lib.data_axes(mesh)
    import numpy as _np
    dsize = int(_np.prod([mesh.shape[a] for a in daxes]))
    if batch % dsize or batch < dsize:
        return None
    return daxes if len(daxes) > 1 else daxes[0]


def _batch_shardings(batch_struct: dict, mesh) -> dict:
    def spec(x):
        dp = _dp_axes(mesh, x.shape[0])
        return NamedSharding(mesh, P(dp, *([None] * (len(x.shape) - 1))))

    return jax.tree_util.tree_map(spec, batch_struct)


def _replicated(mesh):
    return NamedSharding(mesh, P())


def _cost_of(fn, structs, in_shardings, mesh, rules):
    """Lower+compile one component and return (flops, bytes, collectives)."""
    with act.use_mesh(mesh, rules):
        lowered = jax.jit(fn, in_shardings=in_shardings).lower(*structs)
        compiled = lowered.compile()
    cost = compiled.cost_analysis()
    cost = cost[0] if isinstance(cost, (list, tuple)) else (cost or {})
    colls = roofline.parse_collectives(compiled.as_text())
    return (float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0)), colls)


def cost_model(cfg: ModelConfig, shape: ShapeSpec, mesh, rules) -> dict:
    """Per-device roofline inputs with correct loop-trip accounting.

    ``compiled.cost_analysis()`` counts a scanned layer body ONCE (XLA does
    not multiply while-loop bodies by their trip count), so the full-model
    numbers undercount by ~n_periods.  We therefore lower one *period* of the
    stack separately (unrolled, exact) and aggregate:

        total = period_cost * n_periods + embed/head cost

    Both artifacts are compiled dry-run products; the full-model compile
    still provides memory_analysis + the end-to-end partitioning proof.
    """
    import dataclasses as _dc
    from repro.nn import transformer

    daxes = mesh_lib.data_axes(mesh)
    dp = daxes if len(daxes) > 1 else daxes[0]
    # train lowers one *microbatch* through one period and scales by
    # grad_accum * n_periods — FSDP param re-gathers per micro-step are real
    # traffic and must multiply (remat recompute is inside the grad already).
    accum = cfg.grad_accum if shape.mode == "train" else 1
    B = shape.global_batch // accum
    S = shape.seq_len if shape.mode != "decode" else 1
    D = cfg.d_model
    cfg1 = _dc.replace(cfg, n_layers=len(cfg.period), scan_layers=False,
                       remat="none")
    dp_b = _dp_axes(mesh, B)
    x_struct = jax.ShapeDtypeStruct((B, S, D), cfg.accum_dtype)
    x_sh = NamedSharding(mesh, P(dp_b, None, None))
    fsdp_params = cfg.zero_stage >= 3
    stack1_struct = jax.eval_shape(
        lambda k: transformer.stack_init(k, cfg1), jax.random.PRNGKey(0))
    s_shardings = jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        sharding.param_specs(stack1_struct, mesh, fsdp=fsdp_params),
        is_leaf=lambda x: isinstance(x, P))

    enc_struct = None
    enc_sh = None
    if cfg.encoder is not None:
        enc_struct = jax.ShapeDtypeStruct((B, cfg.encoder.seq_len, D),
                                          cfg.accum_dtype)
        enc_sh = NamedSharding(mesh, P(dp_b, None, None))

    # ---- one period of the stack -------------------------------------
    if shape.mode == "train":
        if cfg.encoder is not None:
            def body(p1, x, enc):
                y, _, aux = transformer.stack_forward(
                    p1, cfg1, x, mode="train", enc_out=enc)
                return (y.astype(jnp.float32).sum()
                        + aux["hardening"] + aux["moe_aux"] + aux["balance"])
            fn = jax.grad(body, argnums=(0, 1))
            fl, by, co = _cost_of(fn, (stack1_struct, x_struct, enc_struct),
                                  (s_shardings, x_sh, enc_sh), mesh, rules)
        else:
            def body(p1, x):
                y, _, aux = transformer.stack_forward(p1, cfg1, x, mode="train")
                return (y.astype(jnp.float32).sum()
                        + aux["hardening"] + aux["moe_aux"] + aux["balance"])
            fn = jax.grad(body, argnums=(0, 1))
            fl, by, co = _cost_of(fn, (stack1_struct, x_struct),
                                  (s_shardings, x_sh), mesh, rules)
    else:
        mode = "prefill" if shape.mode == "prefill" else "decode"
        cache_len = shape.seq_len
        caches1 = jax.eval_shape(
            lambda: transformer.init_caches(
                cfg1, B, cache_len,
                enc_len=cfg.encoder.seq_len if cfg.encoder else 0,
                dtype=cfg.param_dtype))
        c_shardings = jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, s),
            sharding.cache_specs(caches1, mesh, B),
            is_leaf=lambda x: isinstance(x, P))
        if cfg.encoder is not None and mode == "prefill":
            def body(p1, x, caches, enc):
                y, cs, _ = transformer.stack_forward(
                    p1, cfg1, x, mode=mode, caches=caches, enc_out=enc)
                return y, cs
            fl, by, co = _cost_of(
                body, (stack1_struct, x_struct, caches1, enc_struct),
                (s_shardings, x_sh, c_shardings, enc_sh), mesh, rules)
        else:
            def body(p1, x, caches):
                y, cs, _ = transformer.stack_forward(
                    p1, cfg1, x, mode=mode, caches=caches)
                return y, cs
            fl, by, co = _cost_of(body, (stack1_struct, x_struct, caches1),
                                  (s_shardings, x_sh, c_shardings), mesh, rules)

    n_periods = (cfg.n_layers // len(cfg.period)) * accum
    flops = fl * n_periods
    bytes_ = by * n_periods
    colls = [(c, n_periods) for c in co]

    # ---- encoder stack (whisper) ---------------------------------------
    if cfg.encoder is not None and shape.mode != "decode":
        cfg_e = _dc.replace(cfg1, period=cfg.encoder.period,
                            n_layers=len(cfg.encoder.period))
        enc_stack_struct = jax.eval_shape(
            lambda k: transformer.stack_init(k, cfg_e, causal=False),
            jax.random.PRNGKey(0))
        e_shardings = jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, s),
            sharding.param_specs(enc_stack_struct, mesh, fsdp=fsdp_params),
            is_leaf=lambda x: isinstance(x, P))
        if shape.mode == "train":
            def ebody(p1, x):
                y, _, _ = transformer.stack_forward(
                    p1, cfg_e, x, mode="train", causal=False,
                    period=cfg.encoder.period)
                return y.astype(jnp.float32).sum()
            efn = jax.grad(ebody, argnums=(0, 1))
        else:
            def efn(p1, x):
                return transformer.stack_forward(
                    p1, cfg_e, x, mode="train", causal=False,
                    period=cfg.encoder.period)[0]
        efl, eby, eco = _cost_of(efn, (enc_stack_struct, enc_struct),
                                 (e_shardings, enc_sh), mesh, rules)
        n_enc = (cfg.encoder.n_layers // len(cfg.encoder.period)) * accum
        flops += efl * n_enc
        bytes_ += eby * n_enc
        colls += [(c, n_enc) for c in eco]

    # ---- embed + head (+ loss) ------------------------------------------
    ends_struct = {k: v for k, v in jax.eval_shape(
        partial(lm.init, cfg=_dc.replace(cfg, n_layers=len(cfg.period))),
        jax.random.PRNGKey(0)).items() if k not in ("stack", "enc_stack")}
    ends_shardings = jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        sharding.param_specs(ends_struct, mesh, fsdp=fsdp_params),
        is_leaf=lambda x: isinstance(x, P))
    batch_struct = specs._token_batch(
        cfg, B, S, shape.mode == "train") if shape.mode != "decode" else {
            "tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32)}
    b_shardings = _batch_shardings(batch_struct, mesh)
    if shape.mode == "train":
        def ends(hp, y, batch):
            x0 = lm._embed_inputs(hp, cfg, batch)
            lg = lm._head(hp, cfg, x0 * 0.5 + y)
            return lm.cross_entropy(lg, batch["labels"])[0]
        efn2 = jax.grad(ends, argnums=(0, 1))
    else:
        def efn2(hp, y, batch):
            x0 = lm._embed_inputs(hp, cfg, batch)
            return lm._head(hp, cfg, x0 * 0.5 + y[:, -1:, :])
    hfl, hby, hco = _cost_of(efn2, (ends_struct, x_struct, batch_struct),
                             (ends_shardings, x_sh, b_shardings), mesh, rules)
    flops += hfl * accum
    bytes_ += hby * accum
    colls += [(c, accum) for c in hco]
    return {"flops": flops, "bytes": bytes_, "colls": colls}


def make_train_fn(cfg: ModelConfig):
    opt = optim.chain_clip(optim.adamw(1e-4, weight_decay=0.1), 1.0)
    grad_fn = optim.gradient_accumulation(
        lambda p, b, r: lm.loss_fn(p, cfg, b, r), cfg.grad_accum)

    def train_step(params, opt_state, batch, rng):
        grads, (loss, metrics) = grad_fn(params, batch, rng)
        updates, opt_state2 = opt.update(grads, opt_state, params)
        params2 = optim.apply_updates(params, updates)
        return params2, opt_state2, metrics

    return train_step, opt


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               ffn: str = "fff", compile_: bool = True) -> dict:
    """Lower+compile one cell; returns the §Dry-run/§Roofline record."""
    cfg = registry.get_config(arch, ffn=ffn)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    rec: dict[str, Any] = {
        "arch": arch, "shape": shape_name, "ffn": ffn,
        "mesh": "2x16x16" if multi_pod else "16x16",
    }
    if not ok:
        rec["status"] = "skipped"
        rec["reason"] = why
        return rec

    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    chips = mesh_lib.num_chips(mesh)
    t0 = time.time()

    params_struct = jax.eval_shape(partial(lm.init, cfg=cfg),
                                   jax.random.PRNGKey(0))
    fsdp_params = cfg.zero_stage >= 3
    p_shardings = jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        sharding.param_specs(params_struct, mesh, fsdp=fsdp_params),
        is_leaf=lambda x: isinstance(x, P))
    total_params = utils.tree_size(params_struct)
    embed_params = utils.tree_size(params_struct["embed"])
    rules = sharding.activation_rules(mesh)

    with act.use_mesh(mesh, rules):
        if shape.mode == "train":
            train_step, opt = make_train_fn(cfg)
            opt_struct = jax.eval_shape(opt.init, params_struct)
            # moments are always fully sharded (ZeRO-1/3); scalars replicated
            m_shardings = jax.tree_util.tree_map(
                lambda s: NamedSharding(mesh, s),
                sharding.param_specs(params_struct, mesh, fsdp=True),
                is_leaf=lambda x: isinstance(x, P))
            o_shardings = type(opt_struct)(
                step=_replicated(mesh), mu=m_shardings, nu=m_shardings)
            batch_struct = specs.input_specs(cfg, shape)
            b_shardings = _batch_shardings(batch_struct, mesh)
            fn = jax.jit(train_step,
                         in_shardings=(p_shardings, o_shardings, b_shardings,
                                       _replicated(mesh)),
                         out_shardings=(p_shardings, o_shardings, None),
                         donate_argnums=(0, 1))
            rng_s = jax.ShapeDtypeStruct((2,), jnp.uint32)
            lowered = fn.lower(params_struct, opt_struct, batch_struct, rng_s)
        elif shape.mode == "prefill":
            batch_struct = specs.input_specs(cfg, shape)
            b_shardings = _batch_shardings(batch_struct, mesh)

            def prefill_step(params, batch):
                caches = lm.init_caches(cfg, shape.global_batch, shape.seq_len,
                                        dtype=cfg.param_dtype)
                return lm.prefill(params, cfg, batch, caches)

            fn = jax.jit(prefill_step, in_shardings=(p_shardings, b_shardings))
            lowered = fn.lower(params_struct, batch_struct)
        else:  # decode
            token_s, caches_s, pos_s = specs.decode_specs(cfg, shape)
            c_shardings = jax.tree_util.tree_map(
                lambda s: NamedSharding(mesh, s),
                sharding.cache_specs(caches_s, mesh, shape.global_batch),
                is_leaf=lambda x: isinstance(x, P))
            tok_sh = NamedSharding(
                mesh, P(_dp_axes(mesh, shape.global_batch), None))

            def decode_step(params, token, caches, pos):
                return lm.decode_step(params, cfg, token, caches, pos)

            fn = jax.jit(decode_step,
                         in_shardings=(p_shardings, tok_sh, c_shardings,
                                       _replicated(mesh)),
                         donate_argnums=(2,))
            lowered = fn.lower(params_struct, token_s, caches_s, pos_s)

        rec["lower_s"] = round(time.time() - t0, 1)
        if not compile_:
            rec["status"] = "lowered"
            return rec
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)

    # ---- artifacts -----------------------------------------------------
    mem = compiled.memory_analysis()
    if mem is not None:
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "peak_memory_in_bytes"):
            v = getattr(mem, attr, None)
            if v is not None:
                rec[attr] = int(v)
        args_b = rec.get("argument_size_in_bytes", 0)
        peak_b = rec.get("peak_memory_in_bytes", 0) \
            or rec.get("temp_size_in_bytes", 0)
        rec["bytes_per_device"] = args_b + peak_b
        rec["fits_v5e_16g"] = bool(rec["bytes_per_device"] < 16 * 1024 ** 3)
    mf = roofline.model_flops(cfg, shape, total_params, embed_params)
    # trip-count-correct per-device roofline terms (see cost_model docstring)
    cm = cost_model(cfg, shape, mesh, rules)
    terms = roofline.analyze_terms(cm["flops"], cm["bytes"], cm["colls"],
                                   chips, mf)
    rec.update({
        "status": "ok",
        "total_params": total_params,
        "active_params": roofline.param_counts(cfg, total_params)[1],
        "hlo_flops_per_device": terms.flops,
        "hlo_bytes_per_device": terms.bytes_hbm,
        "ici_bytes_per_device": terms.bytes_ici,
        "dcn_bytes_per_device": terms.bytes_dcn,
        "t_compute_s": terms.t_compute,
        "t_memory_s": terms.t_memory,
        "t_collective_s": terms.t_collective,
        "dominant": terms.dominant,
        "model_flops": mf,
        "useful_ratio": terms.useful_ratio,
        "roofline_fraction": terms.roofline_fraction,
        "n_collectives": sum(c.count * m for c, m in cm["colls"]),
    })
    return rec


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, choices=list(registry.ARCH_IDS))
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--ffn", default="fff", choices=["fff", "native", "dense"])
    ap.add_argument("--multi-pod", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    archs = list(registry.ARCH_IDS) if (args.all or args.arch is None) \
        else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[
        args.multi_pod]

    records = []
    for arch in archs:
        for shape_name in shapes:
            for mp in meshes:
                t0 = time.time()
                try:
                    rec = lower_cell(arch, shape_name, multi_pod=mp,
                                     ffn=args.ffn)
                except Exception as e:            # noqa: BLE001
                    rec = {"arch": arch, "shape": shape_name,
                           "mesh": "2x16x16" if mp else "16x16",
                           "status": "error",
                           "error": f"{type(e).__name__}: {e}"}
                    traceback.print_exc()
                rec["wall_s"] = round(time.time() - t0, 1)
                records.append(rec)
                status = rec.get("status")
                extra = ""
                if status == "ok":
                    extra = (f" bytes/dev={utils.human_bytes(rec['bytes_per_device'])}"
                             f" dominant={rec['dominant']}"
                             f" roofline={rec['roofline_fraction']:.3f}")
                elif status == "skipped":
                    extra = f" ({rec['reason']})"
                print(f"[{rec['mesh']:8s}] {arch:24s} {shape_name:12s} "
                      f"{status}{extra}", flush=True)
                if args.out:
                    with open(args.out, "w") as f:
                        json.dump(records, f, indent=1)
    n_ok = sum(r.get("status") == "ok" for r in records)
    n_skip = sum(r.get("status") == "skipped" for r in records)
    n_err = len(records) - n_ok - n_skip
    print(f"\ndry-run: {n_ok} ok, {n_skip} skipped, {n_err} errors "
          f"/ {len(records)} cells")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
