"""Shared benchmark plumbing: timing, training loops for the paper's
experimental protocol (M_A / G_A / ETT / speedup), CSV emission.

Timing caveat (stated in EXPERIMENTS.md): this container is a single-CPU
host, so wall-clock numbers are *relative* CPU costs of the same XLA
programs, not TPU/A100 latencies; the paper's speedup TRENDS (FFF log-depth
vs MoE linear-expert scaling) are what these benchmarks reproduce.  Roofline
numbers for the TPU target come from the dry-run (launch/roofline.py).
"""
from __future__ import annotations

import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.core import api, ff, fff, moe


def time_fn(fn, *args, iters: int = 30, warmup: int = 3) -> tuple[float, float]:
    """(mean_us, std_us) per call of a jitted fn."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.mean(ts)), float(np.std(ts))


def train_classifier(train_fwd: Callable, params, ds, *, steps: int,
                     batch: int = 256, lr: float = 0.2, seed: int = 0,
                     opt=None, eval_every: int = 0,
                     eval_fn: Optional[Callable] = None):
    """Generic classifier training loop (paper protocol: pure SGD, lr=0.2).

    train_fwd(params, x, rng) -> (logits, aux_loss_scalar).
    Returns (params, history) where history records (step, eval_fn(params)).
    """
    opt = opt or optim.sgd(lr)
    state = opt.init(params)
    base_key = jax.random.PRNGKey(seed + 12345)

    def loss_fn(p, x, y, r):
        logits, aux = train_fwd(p, x, r)
        ce = -jnp.mean(jnp.take_along_axis(
            jax.nn.log_softmax(logits), y[:, None], 1))
        return ce + aux

    @jax.jit
    def step(p, s, x, y, r):
        g = jax.grad(loss_fn)(p, x, y, r)
        u, s = opt.update(g, s, p)
        return optim.apply_updates(p, u), s

    rng = np.random.default_rng(seed)
    history = []
    for i in range(steps):
        sel = rng.integers(0, len(ds.x_train), batch)
        params, state = step(params, state,
                             jnp.asarray(ds.x_train[sel]),
                             jnp.asarray(ds.y_train[sel]),
                             jax.random.fold_in(base_key, i))
        if eval_every and eval_fn and (i + 1) % eval_every == 0:
            history.append((i + 1, eval_fn(params)))
    return params, history


def accuracy(predict: Callable, params, x, y, batch: int = 1024) -> float:
    correct = 0
    for i in range(0, len(x), batch):
        logits = predict(params, jnp.asarray(x[i:i + batch]))
        correct += int((np.asarray(logits.argmax(-1)) == y[i:i + batch]).sum())
    return correct / len(x)


# --- model builders used across tables -------------------------------------

def build_fff(dim, classes, depth, leaf, h=3.0, seed=0, act="relu"):
    cfg = fff.FFFConfig(dim_in=dim, dim_out=classes, depth=depth,
                        leaf_width=leaf, activation=act, hardening_scale=h)
    params = fff.init(jax.random.PRNGKey(seed), cfg)

    def fwd_train(p, x, rng=None):
        logits, out = api.apply(p, cfg, x,
                                api.ExecutionSpec(mode="train", rng=rng))
        return logits, h * fff.hardening_loss(out.node_probs)

    def fwd_hard(p, x):
        return api.apply(p, cfg, x, api.ExecutionSpec(mode="infer"))[0]

    return cfg, params, fwd_train, fwd_hard


def build_ff(dim, classes, width, seed=0, act="relu"):
    cfg = ff.FFConfig(dim_in=dim, dim_out=classes, width=width,
                      activation=act)
    params = ff.init(jax.random.PRNGKey(seed), cfg)

    def fwd_train(p, x, rng=None):
        return ff.forward(p, cfg, x), jnp.zeros(())

    def fwd(p, x):
        return ff.forward(p, cfg, x)

    return cfg, params, fwd_train, fwd


def build_moe(dim, classes, experts, expert_width, k=2, seed=0):
    cfg = moe.MoEConfig(dim_in=dim, dim_out=classes, num_experts=experts,
                        expert_width=expert_width, top_k=k)
    params = moe.init(jax.random.PRNGKey(seed), cfg)

    def fwd_train(p, x, rng=None):
        y, aux = moe.forward(p, cfg, x, rng=rng, train=True)
        return y, aux["aux_loss"]

    def fwd_infer(p, x):
        return moe.forward_sparse(p, cfg, x)[0]

    return cfg, params, fwd_train, fwd_infer
