"""Wrapper for the fused decode megakernel: node-parameter collapse,
eligibility checks, and the ``(y, leaf_idx)`` contract the execution
registry's ``("infer", "pallas_decode")`` backend exposes (DESIGN.md §13).

Unlike ``fused_fff.fff_decode`` (router kernel + two gathered-matmul
kernels, one set PER TREE), this path is ONE ``pl.pallas_call`` for the
whole forest — the dispatch count the roofline benchmark and the CI
compile gate pin at 1.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import fff as fff_lib
from repro.kernels import common
from repro.kernels.fused_decode import kernel as K
from repro.kernels.fused_decode import ref as R


def collapse_nodes(params: dict, cfg: fff_lib.FFFConfig
                   ) -> tuple[jax.Array, jax.Array]:
    """Fold the node_width-1 two-layer node net into one hyperplane per
    node: w = w1[..., 0] * w2[..., 0], b = b1[..., 0] * w2[..., 0] + b2
    (same collapse as ``fused_fff.fff_decode``, all trees at once).
    Returns ``(nw (T, N, D), nb (T, N))``."""
    nw = params["node_w1"][:, :, :, 0] * params["node_w2"][:, :, 0:1]
    nb = params["node_b1"][:, :, 0] * params["node_w2"][:, :, 0] \
        + params["node_b2"]
    return nw, nb


def _leaf_weights(params: dict, cfg: fff_lib.FFFConfig) -> tuple[tuple, str]:
    if "leaf_b1" in params or "leaf_b2" in params:
        raise ValueError("fused decode kernel requires bias-free leaves")
    if cfg.activation == "swiglu":
        return ((params["leaf_wg"], params["leaf_wu"], params["leaf_wd"]),
                "swiglu")
    return (params["leaf_w1"], params["leaf_w2"]), cfg.activation


def _master_weights(params: dict, cfg: fff_lib.FFFConfig):
    """The always-on master-leaf MLP weights (DESIGN.md §14), fused into the
    same single dispatch, or None for master-free configs."""
    if not cfg.master_leaf:
        return None
    if cfg.activation == "swiglu":
        return (params["master_wg"], params["master_wu"],
                params["master_wd"])
    return (params["master_w1"], params["master_w2"])


def fused_decode(x: jax.Array, params: dict, cfg: fff_lib.FFFConfig, *,
                 interpret: Optional[bool] = None,
                 return_leaf_idx: bool = False):
    """Exact FORWARD_I for decode-shaped batches in ONE kernel dispatch.

    x (B, D) -> (B, dim_out), summed over forest trees; with
    ``return_leaf_idx=True`` returns ``(y, leaf_idx (B, trees))``.  Exact
    for ANY batch size (per-token, no capacity bound) — the single-dispatch
    fusion is simply tuned for decode's ``(num_slots, 1)`` shape."""
    if cfg.node_width != 1:
        raise ValueError("kernel path supports node_width == 1 (paper default)")
    if cfg.depth < 1:
        raise ValueError("fused decode needs a tree to descend (depth >= 1)")
    if interpret is None:
        interpret = common.default_interpret()
    nw, nb = collapse_nodes(params, cfg)
    leaf_w, act = _leaf_weights(params, cfg)
    y, leaf_idx = K.fused_forest_decode(x, nw, nb, leaf_w, depth=cfg.depth,
                                        act=act,
                                        master_w=_master_weights(params, cfg),
                                        interpret=interpret)
    if return_leaf_idx:
        return y, leaf_idx
    return y


def fused_decode_ref(x: jax.Array, params: dict, cfg: fff_lib.FFFConfig, *,
                     return_leaf_idx: bool = False):
    """The oracle at the same params/cfg contract as ``fused_decode``."""
    if cfg.node_width != 1:
        raise ValueError("kernel path supports node_width == 1 (paper default)")
    nw, nb = collapse_nodes(params, cfg)
    leaf_w, act = _leaf_weights(params, cfg)
    y, leaf_idx = R.fused_decode_ref(x, nw, nb, leaf_w, depth=cfg.depth,
                                     act=act,
                                     master_w=_master_weights(params, cfg))
    if return_leaf_idx:
        return y, leaf_idx
    return y
