"""Chunked-prefill invariants (ISSUE 4 tentpole; DESIGN.md §9).

* logits parity: a prompt prefetched chunk-by-chunk (``lm.prefill_chunk``)
  must produce the same next-token logits and cache state as one monolithic
  padded prefill (``lm.prefill_padded``);
* telemetry accumulation: per-slot FFF leaf counts summed across a
  request's chunks equal the monolithic prefill's counts;
* no decode starvation: short requests keep producing tokens while a
  continuous stream of long prompts is admitted;
* the fixed-compiled-shape bound: chunked serving compiles exactly one
  decode shape and one chunk-slab shape, whatever the workload mix;
* engine-level token parity with ``lm.generate``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.core import api
from repro.models import lm
from repro.serving import (ContinuousBatchingEngine, EngineConfig, Request,
                           make_scheduler)
from repro.serving.scheduler import SchedulerView


@pytest.fixture(scope="module")
def model():
    cfg = registry.get_config("internlm2-20b", ffn="fff").reduced()
    params = lm.init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _chunked_prefill(params, cfg, prompt, caches, slot, num_slots, chunk,
                     free_fill=1):
    """Drive lm.prefill_chunk over one prompt at ``slot``; other rows idle.
    Returns (final logits row, caches, accumulated (E,) leaf counts)."""
    E = 2 ** next(b.ffn.fff_depth for b in cfg.period if b.ffn.kind == "fff")
    counts = np.zeros((E,))
    pos, logits = 0, None
    while pos < len(prompt):
        n = min(chunk, len(prompt) - pos)
        slab = np.full((num_slots, chunk), free_fill, np.int32)
        slab[slot, :n] = prompt[pos:pos + n]
        slab[slot, n:] = prompt[pos + n - 1]
        valid = np.zeros((num_slots,), np.int32)
        valid[slot] = n
        offs = np.zeros((num_slots,), np.int32)
        offs[slot] = pos
        with api.collect_routing():
            lg, caches, stats = jax.jit(
                lambda p, t, v, c, o: lm.prefill_chunk(p, cfg, t, v, c, o)
            )(params, jnp.asarray(slab), jnp.asarray(valid), caches,
              jnp.asarray(offs))
        for s in (stats or ()):
            if s is not None and s.leaf_counts.shape[-1] == E:
                counts += np.asarray(s.leaf_counts)[slot]
        pos += n
        logits = np.asarray(lg)[slot]
    return logits, caches, counts


def _monolithic_prefill(params, cfg, prompt, caches, num_slots):
    """Padded prefill of ``prompt`` in row 1 of a (num_slots, L) batch,
    with accumulated (E,) leaf counts for that row."""
    E = 2 ** next(b.ffn.fff_depth for b in cfg.period if b.ffn.kind == "fff")
    L = len(prompt)
    toks = np.ones((num_slots, L), np.int32)
    toks[1] = prompt
    true_len = np.ones((num_slots,), np.int32)
    true_len[1] = L
    with api.collect_routing():
        logits, caches, stats = jax.jit(
            lambda p, t, c, n: lm.prefill_padded(p, cfg, {"tokens": t}, c, n)
        )(params, jnp.asarray(toks), caches, jnp.asarray(true_len))
    counts = np.zeros((E,))
    for s in (stats or ()):
        if s is not None and s.leaf_counts.shape[-1] == E:
            counts += np.asarray(s.leaf_counts)[1]
    return np.asarray(logits)[1], caches, counts


# ---------------------------------------------------------------------------
# model-level parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("L,chunk", [(16, 8), (16, 4), (12, 8)])
def test_chunked_matches_monolithic_logits(model, L, chunk):
    """Same prompt, same final logits and same decode continuation whether
    prefilled in one padded dispatch or chunk-by-chunk (incl. a final
    partial chunk for L=12, chunk=8)."""
    cfg, params = model
    B, max_len = 4, 32
    prompt = np.random.default_rng(0).integers(1, 256, L).astype(np.int32)

    lg_m, caches_m, _ = _monolithic_prefill(
        params, cfg, prompt, lm.init_caches(cfg, B, max_len), B)
    lg_c, caches_c, _ = _chunked_prefill(
        params, cfg, prompt, lm.init_caches(cfg, B, max_len), 1, B, chunk)
    np.testing.assert_allclose(lg_c, lg_m, rtol=2e-4, atol=2e-4)

    # the caches must be interchangeable: decode the argmax token on both
    tok = np.zeros((B, 1), np.int32)
    tok[1, 0] = lg_m.argmax()
    lm_m, _ = lm.decode_step(params, cfg, jnp.asarray(tok), caches_m, 0)
    lm_c, _ = lm.decode_step(params, cfg, jnp.asarray(tok), caches_c, 0)
    np.testing.assert_allclose(np.asarray(lm_c)[1], np.asarray(lm_m)[1],
                               rtol=2e-4, atol=2e-4)
    # and agree on the cache's filled length for the active row
    np.testing.assert_array_equal(
        np.asarray(caches_m[0]["kv"].length)[:, 1],
        np.asarray(caches_c[0]["kv"].length)[:, 1])


def test_chunked_telemetry_accumulates_to_monolithic(model):
    """Summing a request's per-chunk leaf counts reproduces the monolithic
    prefill's counts (no pad anywhere: L divides into whole chunks and
    equals the bucket)."""
    cfg, params = model
    B, L, chunk, max_len = 4, 16, 8, 32
    prompt = np.random.default_rng(1).integers(1, 256, L).astype(np.int32)
    _, _, c_mono = _monolithic_prefill(
        params, cfg, prompt, lm.init_caches(cfg, B, max_len), B)
    _, _, c_chunk = _chunked_prefill(
        params, cfg, prompt, lm.init_caches(cfg, B, max_len), 1, B, chunk)
    # counts are integers (routed slots); fp noise in hidden states may
    # flip a borderline token's leaf, so allow a one-slot wobble per leaf
    np.testing.assert_allclose(c_chunk, c_mono, atol=1)
    assert c_chunk.sum() == c_mono.sum()          # every slot accounted for


def test_inactive_rows_untouched(model):
    """A chunk dispatch must not perturb rows with valid_len == 0: a decode
    on an unrelated slot yields identical logits before and after."""
    cfg, params = model
    B, max_len = 4, 32
    prompt = np.random.default_rng(2).integers(1, 256, 16).astype(np.int32)
    caches = lm.init_caches(cfg, B, max_len)
    # occupy row 0 with a short monolithic prefill
    toks = np.tile(prompt[:8][None], (B, 1))
    tl = np.ones((B,), np.int32)
    tl[0] = 8
    _, caches, _ = lm.prefill_padded(params, cfg,
                                     {"tokens": jnp.asarray(toks)}, caches,
                                     jnp.asarray(tl))
    tok = np.full((B, 1), 7, np.int32)
    probe = lambda c: np.asarray(lm.decode_step(
        params, cfg, jnp.asarray(tok), c, 0)[0])[0]
    before = probe(caches)
    # now chunk-prefill row 2; row 0 must be bit-identical afterwards
    _, caches, _ = _chunked_prefill(params, cfg, prompt, caches, 2, B, 8)
    np.testing.assert_array_equal(probe(caches), before)


# ---------------------------------------------------------------------------
# engine-level invariants
# ---------------------------------------------------------------------------

def _engine(cfg, params, **kw):
    defaults = dict(num_slots=4, max_len=80, max_prompt_len=64,
                    prefill_chunk=16, prefill_budget=1, seed=0)
    defaults.update(kw)
    return ContinuousBatchingEngine(params, cfg, EngineConfig(**defaults))


def _mixed_requests(n, rng, max_new=6):
    return [Request(rid=i,
                    prompt=rng.integers(1, 256, int(rng.integers(3, 50))),
                    max_new_tokens=max_new + int(rng.integers(0, 3)))
            for i in range(n)]


def test_chunked_engine_matches_lm_generate(model):
    """Greedy chunked-engine output equals the synchronous lm.generate path
    for every request (the monolithic-engine parity test, chunked)."""
    cfg, params = model
    eng = _engine(cfg, params)
    results, m = eng.run(_mixed_requests(7, np.random.default_rng(3)))
    assert m.n_chunks > 0
    for r in results:
        want = lm.generate(params, cfg, jnp.asarray(r.prompt[None]),
                           steps=r.n_generated, max_len=80)
        np.testing.assert_array_equal(
            np.asarray(want)[0], np.concatenate([r.prompt, r.tokens]),
            err_msg=f"rid {r.rid}")


def test_chunked_fixed_compiled_shapes(model):
    """Chunked serving compiles ONE decode shape, ONE chunk-slab shape and
    ZERO prefill buckets, whatever the prompt-length mix — tighter than the
    monolithic per-bucket bound."""
    cfg, params = model
    eng = _engine(cfg, params)
    eng.run(_mixed_requests(6, np.random.default_rng(4)))
    warm = eng.compiled_shapes()
    eng.run(_mixed_requests(8, np.random.default_rng(5)))
    after = eng.compiled_shapes()
    assert after == warm, "recompilation after warmup"
    assert after["decode"] == 1
    assert after["prefill_chunk"] == 1
    assert all(v == 0 for k, v in after.items() if k.startswith("prefill_")
               and k != "prefill_chunk")


def test_no_decode_starvation_under_long_prompt_stream(model):
    """While a continuous stream of max-length prompts is admitted, an
    in-flight short request must keep producing tokens: with chunk c over
    prompt L the admission spans ~L/c steps and the short request gets a
    decode in each — under monolithic prefill it would finish no earlier
    than the long prompt's first token."""
    cfg, params = model
    eng = _engine(cfg, params, num_slots=2, prefill_chunk=8)
    rng = np.random.default_rng(6)
    short = Request(rid=0, prompt=rng.integers(1, 256, 4),
                    max_new_tokens=6)
    eng.submit(short)
    eng.step()                                    # short admitted + decoding
    for j in range(3):                            # long-prompt stream
        eng.submit(Request(rid=1 + j, prompt=rng.integers(1, 256, 64),
                           max_new_tokens=1))
    first_long_done = None
    steps = 0
    while eng.has_work() and steps < 200:
        eng.step()
        steps += 1
        if first_long_done is None and any(
                r.rid == 1 for r in eng.results):
            first_long_done = steps
    # 64-token prompts over 8-token chunks: >= 8 steps of admission per
    # long request; the short request (6 tokens) must have finished while
    # the FIRST long prompt was still prefilling
    short_res = next(r for r in eng.results if r.rid == 0)
    long_res = next(r for r in eng.results if r.rid == 1)
    assert short_res.finish_time < long_res.first_token_time, \
        "short request was starved by long-prompt admission"
    assert short_res.n_generated == 6


def test_scheduler_max_prefilling_caps_admission(model):
    """The scheduler-side TTFT-vs-p99 knob: with max_prefilling=1 the
    engine never holds two slots mid-prefill at once."""
    cfg, params = model
    eng = _engine(cfg, params, num_slots=4, prefill_chunk=16,
                  scheduler_kw={"max_prefilling": 1})
    rng = np.random.default_rng(7)
    for i in range(4):
        eng.submit(Request(rid=i, prompt=rng.integers(1, 256, 64),
                           max_new_tokens=1))
    max_seen = 0
    steps = 0
    while eng.has_work() and steps < 300:
        eng.step()
        steps += 1
        max_seen = max(max_seen, sum(
            s is not None and s.prefilling for s in eng.slots))
    assert len(eng.results) == 4
    assert max_seen <= 1, f"{max_seen} slots mid-prefill despite cap"


def test_scheduler_admission_cap_math():
    view = SchedulerView(occupancy=np.zeros((4, 2)),
                         active=np.zeros((4,), bool), num_leaves=2,
                         capacity_factor=2.0, num_slots=4,
                         prefilling=np.asarray([True, True, False, False]))
    assert make_scheduler("fcfs").admission_cap(view) == 4     # uncapped
    assert make_scheduler("fcfs", max_prefilling=3).admission_cap(view) == 1
    assert make_scheduler("leaf_aware",
                          max_prefilling=2).admission_cap(view) == 0


def test_compile_bound(model):
    """The documented compile contract, standalone: chunked serving runs on
    EXACTLY decode 1 + chunk slab 1 + admit 1 compiled traces with zero
    bucket prefills (docs/serving.md).  The CI serving job runs this single
    node id as a dedicated gate step, so a contract regression fails loudly
    on its own instead of somewhere inside the full suite."""
    cfg, params = model
    eng = _engine(cfg, params)
    eng.run(_mixed_requests(5, np.random.default_rng(9)))
    shapes = eng.compiled_shapes()
    assert shapes["decode"] == 1, shapes
    assert shapes["prefill_chunk"] == 1, shapes
    assert shapes["admit"] == 1, shapes
    assert all(v == 0 for k, v in shapes.items()
               if k.startswith("prefill_") and k != "prefill_chunk"), shapes


def test_chunk_config_validation(model):
    cfg, params = model
    with pytest.raises(ValueError, match="power of two"):
        _engine(cfg, params, prefill_chunk=12)
    with pytest.raises(ValueError, match="max_prompt_len"):
        _engine(cfg, params, prefill_chunk=128, max_prompt_len=64)
    with pytest.raises(ValueError, match="prefill_budget"):
        _engine(cfg, params, prefill_budget=0)


def test_poll_metrics_snapshot(model):
    """poll_metrics reports live queue/slot state mid-run and zeroes out
    once drained."""
    cfg, params = model
    eng = _engine(cfg, params, num_slots=2, prefill_chunk=8)
    rng = np.random.default_rng(8)
    for i in range(4):
        eng.submit(Request(rid=i, prompt=rng.integers(1, 256, 32),
                           max_new_tokens=2))
    eng.step()
    m = eng.poll_metrics()
    assert m.active_slots == 2 and m.prefilling_slots >= 1
    assert m.queue_depth == 4 - m.active_slots
    assert m.n_chunks >= 1
    while eng.has_work():
        eng.step()
    m = eng.poll_metrics()
    assert m.queue_depth == 0 and m.active_slots == 0
    assert m.n_requests == 4
    assert {"queue_depth", "decode_interval_ms", "n_chunks"} <= set(
        m.as_dict())
