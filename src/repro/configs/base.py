"""Config schema: model architecture descriptions.

Every assigned architecture is expressed as a ``ModelConfig`` whose layer
stack is a repeated ``period`` of ``BlockSpec``s (homogeneous periods let the
runtime scan over stacked parameters — small HLO, fast compiles, remat-able).

``FFNSpec.kind`` selects the paper's technique per FFN site:
  dense -> vanilla FF (baseline)
  fff   -> fast feedforward tree/forest (the paper)
  moe   -> noisy-top-k mixture of experts (the paper's contender)
  none  -> block has no FFN site (e.g. xLSTM)
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional, Tuple

import jax.numpy as jnp

from repro import utils


@dataclasses.dataclass(frozen=True)
class FFNSpec:
    kind: str = "dense"            # dense|fff|moe|none
    d_ff: int = 0                  # dense: hidden width; moe/fff: per-expert/base width
    activation: str = "swiglu"
    # --- fff ---
    fff_leaf_width: int = 0
    fff_depth: int = 0
    fff_trees: int = 1
    fff_node_width: int = 1
    fff_st: bool = False           # straight-through top-1 training (MoE-scale
                                   # sites; DESIGN.md §8) vs faithful FORWARD_T
    fff_master_leaf: bool = False  # always-on master leaf (arxiv 2405.16836,
                                   # DESIGN.md §14); doubles as the approximate
                                   # overflow repair under capacity bounds
    fff_master_width: int = 0      # master hidden width; 0 = leaf width
    hardening_scale: float = 1.0
    balance_scale: float = 0.0     # load-balancing aux weight over soft leaf
                                   # usage (0 = off; DESIGN.md §14)
    # --- moe ---
    moe_experts: int = 0
    moe_top_k: int = 2

    @property
    def training_width(self) -> int:
        if self.kind == "dense":
            return self.d_ff
        if self.kind == "moe":
            return self.moe_experts * self.d_ff
        if self.kind == "fff":
            return self.fff_trees * (2 ** self.fff_depth) * self.fff_leaf_width
        return 0

    @property
    def active_width(self) -> int:
        if self.kind == "dense":
            return self.d_ff
        if self.kind == "moe":
            return self.moe_top_k * self.d_ff
        if self.kind == "fff":
            return self.fff_trees * self.fff_leaf_width
        return 0

    def as_fff(self, leaf_width: int = 0, trees: int = 0) -> "FFNSpec":
        """Convert a dense/moe FFN site into the FFF replacement that preserves
        the *training width* (paper user-manual Case 1 / FFF-for-MoE)."""
        if self.kind == "none":
            return self
        total = self.training_width
        trees = trees or (self.moe_top_k if self.kind == "moe" else 1)
        # defaults: dense FFNs fragment into 16 leaves (paper Case 1 with a
        # 16x inference saving); MoE FFNs keep expert-sized leaves.
        leaf_width = leaf_width or max(1, self.d_ff // (16 if self.kind == "dense" else 1))
        per_tree = utils.cdiv(total, trees)
        depth = max(0, math.ceil(math.log2(max(1, utils.cdiv(per_tree, leaf_width)))))
        # MoE-derived sites train straight-through (dense FORWARD_T over
        # hundreds of expert-sized leaves would cost the full training width
        # per token — exactly what MoE-scale models cannot afford).
        return dataclasses.replace(
            self, kind="fff", fff_leaf_width=leaf_width, fff_depth=depth,
            fff_trees=trees, fff_st=(self.kind == "moe"))


@dataclasses.dataclass(frozen=True)
class BlockSpec:
    mixer: str = "attn"            # attn|mamba|mlstm|slstm|none
    ffn: FFNSpec = FFNSpec()
    cross_attention: bool = False  # decoder blocks of enc-dec models
    sliding_window: int = 0        # 0 = full attention


@dataclasses.dataclass(frozen=True)
class EncoderSpec:
    n_layers: int = 0
    period: Tuple[BlockSpec, ...] = ()
    seq_len: int = 0               # fixed encoder length (e.g. whisper frames)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                    # dense|moe|hybrid|ssm|vlm|audio
    d_model: int
    n_layers: int
    n_heads: int
    n_kv_heads: int
    vocab_size: int
    period: Tuple[BlockSpec, ...]
    head_dim: int = 0              # 0 -> d_model // n_heads
    max_seq_len: int = 8192
    pos_emb: str = "rope"          # rope|learned|sinusoidal|none
    rope_theta: float = 10000.0
    norm: str = "rmsnorm"          # rmsnorm|layernorm
    attn_bias: bool = False
    attn_logit_softcap: float = 0.0
    tie_embeddings: bool = False
    encoder: Optional[EncoderSpec] = None
    frontend: str = "none"         # none|audio_stub|vision_stub
    # mamba hyper-params (hybrid archs)
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2
    # xlstm hyper-params
    lstm_heads: int = 4
    # numerics
    param_dtype: Any = jnp.float32
    accum_dtype: Any = jnp.float32
    # runtime
    scan_layers: bool = True
    remat: str = "none"            # none|dots|full
    grad_accum: int = 1            # microbatches per train step
    zero_stage: int = 1            # 1: params data-replicated, moments FSDP
                                   #    (one param gather/step);
                                   # 3: params FSDP too (re-gathered per
                                   #    micro-step; for models whose model-
                                   #    sharded params exceed HBM)
    attn_chunk: int = 1024         # flash-attention chunk size
    # full-attention archs cannot run the 500k-decode cell (DESIGN.md §4)
    subquadratic: bool = False

    def __post_init__(self):
        if self.n_layers % max(1, len(self.period)) != 0:
            raise ValueError(
                f"{self.arch_id}: n_layers={self.n_layers} not divisible by "
                f"period length {len(self.period)}")
        if self.n_heads % max(1, self.n_kv_heads) != 0:
            raise ValueError(f"{self.arch_id}: n_heads % n_kv_heads != 0")

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def n_periods(self) -> int:
        return self.n_layers // len(self.period)

    def with_ffn_kind(self, kind: str, **fff_kw) -> "ModelConfig":
        """Swap every FFN site to dense/fff/moe — the --ffn flag."""
        def convert(b: BlockSpec) -> BlockSpec:
            if b.ffn.kind == "none":
                return b
            if kind == "fff":
                return dataclasses.replace(b, ffn=b.ffn.as_fff(**fff_kw))
            if kind == "dense":
                total = b.ffn.training_width
                return dataclasses.replace(b, ffn=dataclasses.replace(
                    b.ffn, kind="dense", d_ff=total))
            return b
        new_period = tuple(convert(b) for b in self.period)
        enc = self.encoder
        if enc is not None and enc.period:
            enc = dataclasses.replace(
                enc, period=tuple(convert(b) for b in enc.period))
        return dataclasses.replace(self, period=new_period, encoder=enc)

    def reduced(self, n_layers: int = 0, d_model: int = 64, n_heads: int = 4,
                n_kv_heads: int = 0, vocab: int = 256, seq: int = 64
                ) -> "ModelConfig":
        """A tiny same-family config for CPU smoke tests."""
        n_layers = utils.round_up(n_layers or len(self.period), len(self.period))
        scale = d_model / self.d_model

        def shrink_ffn(f: FFNSpec) -> FFNSpec:
            if f.kind == "none":
                return f
            d_ff = max(8, int(f.d_ff * scale)) if f.d_ff else 0
            return dataclasses.replace(
                f, d_ff=min(d_ff, 4 * d_model) or 2 * d_model,
                moe_experts=min(f.moe_experts, 4) if f.moe_experts else 0,
                moe_top_k=min(f.moe_top_k, 2),
                fff_depth=min(f.fff_depth, 3),
                fff_leaf_width=min(f.fff_leaf_width, 16) or 0,
                fff_trees=min(f.fff_trees, 2))

        new_period = tuple(dataclasses.replace(b, ffn=shrink_ffn(b.ffn))
                           for b in self.period)
        nkv = n_kv_heads or max(1, min(self.n_kv_heads, n_heads))
        while n_heads % nkv:
            nkv -= 1
        enc = self.encoder
        if enc is not None:
            enc = dataclasses.replace(
                enc, n_layers=len(enc.period) if enc.period else 0,
                period=tuple(dataclasses.replace(b, ffn=shrink_ffn(b.ffn))
                             for b in enc.period),
                seq_len=min(enc.seq_len, 32) or 32)
        return dataclasses.replace(
            self, n_layers=n_layers,
            d_model=d_model, n_heads=n_heads, n_kv_heads=nkv, head_dim=0,
            vocab_size=vocab, max_seq_len=seq, period=new_period, encoder=enc,
            scan_layers=False, attn_chunk=32, remat="none",
            param_dtype=jnp.float32, accum_dtype=jnp.float32)


# ---------------------------------------------------------------------------
# input shapes assigned to the LM family (the 4 shape cells per arch)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    mode: str                      # train|prefill|decode

SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """Whether an (arch, shape) cell runs; 500k decode needs sub-quadratic
    attention (constant-state SSM or hybrid) — see DESIGN.md §4."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "long_500k skipped: pure full-attention arch (quadratic)"
    return True, ""
