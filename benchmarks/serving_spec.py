"""Speculative-decoding benchmark: spec-k draft/verify rounds vs plain
one-token decode on the continuous-batching engine (DESIGN.md §10).

Model: a *drafter-consistent* deep target.  The serving model is the
``serving`` bench's reduced config deepened to ``DEPTH`` periods, with the
residual-writing output projections (attention ``wo``/``bo``, FFF leaf
down-projections) of every period past the first zeroed.  Each tail block
then contributes exactly 0 to the residual stream — the first-period
self-slice (``--draft-config self:1``) reproduces the target distribution
*bit-for-bit* — while the target still pays full ``DEPTH``-deep compute per
decode/verify token and the tail routers still see real hidden states (so
capacity/overflow telemetry stays live at every FFF site).  With untrained
weights no shallow draft can otherwise agree with the target, so this
construction is what lets the bench measure the serving mechanism at a
*known* acceptance of ~1: k+1 sequential shallow draft steps plus ONE
full-depth verify dispatch, against k+1 full-depth decode dispatches.

Workload: the same calibrated *skewed-routing* per-class-burst mix as the
``serving`` bench (classes probed against the period-0 slice — the only
period that writes the residual) at saturating load, decode-bound
(``GEN_SPEC`` generated tokens per request), under the capacity-bounded
``grouped`` backend with ``leaf_aware`` admission.

Rows:
  * baseline  — plain decode, leaf_aware (the PR 3/5 serving configuration)
  * spec      — ``SPEC_K`` draft tokens/slot/round from the exact ``self:1``
    shallow slice (the headline: amortization *and* cheap drafting)
  * full_self — same ``SPEC_K`` but a full-depth self-draft; acceptance is
    also ~1 yet drafting costs as much as decoding, isolating how much of
    the win needs the draft to actually be shallow

Gates (printed + recorded in the artifact):
  * spec tokens/s > 1.8x baseline tokens/s
  * spec verify-step decode overflow <= baseline decode overflow (the
    leaf-hint co-scheduling must absorb the (k+1)-token verify slabs)

Emits CSV rows
``serving_spec,<name>,<spec_k>,<tok_s>,<acceptance>,<ovf_decode>,<wasted>``
and writes ``experiments/BENCH_serving_spec.json``.
"""
from __future__ import annotations

import dataclasses
import json
import os

ARTIFACT = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "experiments", "BENCH_serving_spec.json")

DEPTH = 6       # periods in the deep target (draft = 1 of these)
SPEC_K = 11     # draft tokens per slot per round
GEN_SPEC = 60   # decode-bound: 5 full (k+1)-token rounds per request
SPEEDUP_GATE = 1.8

# residual-writing output projections: zeroing these for a period makes the
# whole block contribute exactly +0 to the residual stream (pre-norm blocks
# only touch x via `x = x + proj(...)`)
_OUT_PROJ_KEYS = frozenset(
    {"wo", "bo", "leaf_w2", "leaf_b2", "leaf_wd", "w2", "b2"})


def drafter_consistent_model(seed: int, depth: int = DEPTH):
    """Deep reduced model whose tail periods write exactly 0 to the residual
    stream (see module docstring); returns ``(cfg, params)``."""
    import jax
    import jax.tree_util as jtu

    from repro.configs import registry
    from repro.models import lm

    cfg = registry.get_config("internlm2-20b", ffn="fff").reduced()
    cfg = dataclasses.replace(cfg, n_layers=depth * len(cfg.period))
    params = lm.init(jax.random.PRNGKey(seed), cfg)

    def zero_tail(path, a):
        name = path[-1].key if hasattr(path[-1], "key") else None
        return a.at[1:].set(0) if name in _OUT_PROJ_KEYS else a

    params = dict(params)
    params["stack"] = [jtu.tree_map_with_path(zero_tail, p)
                       for p in params["stack"]]
    return cfg, params


def run_one(params, cfg, *, slots: int, reqs, seed: int, spec_k: int = 0,
            draft_config=None, warmup_reqs=None):
    from benchmarks.serving_load import PROMPT_LEN
    from repro.serving import ContinuousBatchingEngine, EngineConfig
    ecfg = EngineConfig(
        num_slots=slots, max_len=PROMPT_LEN + GEN_SPEC + 1,
        max_prompt_len=PROMPT_LEN, scheduler="leaf_aware",
        scheduler_kw={"window": 4 * slots},
        fff_backend="grouped",          # capacity-bounded dispatch: the
        max_prefills_per_step=slots,    # regime where composition matters
        spec_k=spec_k, draft_config=draft_config, seed=seed)
    engine = ContinuousBatchingEngine(params, cfg, ecfg)
    if warmup_reqs:
        # burn every compile (decode or rollout/verify) outside the timed
        # run — the two engine variants compile different trace sets, and
        # the ratio below must compare steady-state serving, not XLA
        engine.run(warmup_reqs)
    _, m = engine.run(reqs)
    return m


def main(quick: bool = True) -> None:
    from benchmarks.serving_load import (N_CLASSES, calibrate_classes,
                                         make_workload)
    from repro.serving import self_draft_config, slice_draft_params
    seed = 0
    slots = 16 if quick else 32
    # keep all N_CLASSES in flight at once: leaf-balanced composition needs
    # the scheduler's window to actually contain every class
    n_requests = (8 if quick else 16) * slots // 2

    cfg, params = drafter_consistent_model(seed)
    # probe routing on the period-0 slice: the only period that writes the
    # residual, hence the site the leaf_hint story is about
    classes = calibrate_classes(slice_draft_params(params, cfg),
                                self_draft_config(cfg), N_CLASSES)
    print(f"# classes (token -> leaf): "
          f"{[(t, int(f.argmax())) for t, f in classes]}")
    print("# name,spec_k,tok_s,spec_acceptance,overflow_decode_mean,"
          "wasted_tokens")

    # saturating arrivals + long generations: throughput is decode/verify
    # bound, the regime the speedup claim is about
    def workload():
        return make_workload(classes, n_requests=n_requests, burst=slots,
                             rate=0.0, seed=seed + 1, gen=GEN_SPEC)

    warm = make_workload(classes, n_requests=slots, burst=slots,
                         rate=0.0, seed=seed + 2, gen=GEN_SPEC)

    variants = [
        ("baseline", 0, None),
        ("spec", SPEC_K, "self:1"),
        ("full_self", SPEC_K, f"self:{cfg.n_periods}"),
    ]
    runs = {}
    for name, k, draft in variants:
        m = run_one(params, cfg, slots=slots, reqs=workload(), seed=seed,
                    spec_k=k, draft_config=draft, warmup_reqs=warm)
        print(f"serving_spec,{name},{k},{m.throughput_tok_s:.1f},"
              f"{m.spec_acceptance:.3f},{m.overflow_decode_mean:.4f},"
              f"{m.wasted_tokens}", flush=True)
        runs[name] = {"spec_k": k, "draft_config": draft, "slots": slots,
                      "n_requests": n_requests, **m.as_dict()}

    base, spec = runs["baseline"], runs["spec"]
    speedup = spec["throughput_tok_s"] / max(base["throughput_tok_s"], 1e-9)
    speedup_ok = speedup > SPEEDUP_GATE
    overflow_ok = (spec["overflow_decode_mean"]
                   <= base["overflow_decode_mean"] + 1e-9)
    print(f"# spec {spec['throughput_tok_s']:.1f} tok/s vs baseline "
          f"{base['throughput_tok_s']:.1f} -> {speedup:.2f}x "
          f"({'PASS' if speedup_ok else 'FAIL'} vs {SPEEDUP_GATE}x gate)")
    print(f"# verify decode overflow {spec['overflow_decode_mean']:.4f} vs "
          f"baseline {base['overflow_decode_mean']:.4f} -> "
          f"{'PASS' if overflow_ok else 'FAIL'} (must not exceed)")

    with open(ARTIFACT, "w") as f:
        json.dump({"bench": "serving_spec", "quick": quick, "slots": slots,
                   "depth": DEPTH, "gen": GEN_SPEC,
                   "spec_k": SPEC_K, "classes": [(int(t), int(fp.argmax()))
                                                 for t, fp in classes],
                   "speedup": speedup, "speedup_gate": SPEEDUP_GATE,
                   "speedup_ok": speedup_ok, "overflow_ok": overflow_ok,
                   "runs": runs}, f, indent=1)
    print(f"# wrote {ARTIFACT}")


if __name__ == "__main__":
    main()
