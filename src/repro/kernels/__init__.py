"""Pallas TPU kernels for the FFF hot spots (DESIGN.md §3):

  tree_router  — fused multi-level tree descent (routing)
  leaf_gemm    — ragged grouped GEMM over sorted tokens (batch serving)
  fused_fff    — per-token gathered leaf matmul (decode; the paper's
                 offset-load, expressed as a scalar-prefetch index map)

Each kernel ships ops.py (jit wrapper) and ref.py (pure-jnp oracle); tests
sweep shapes x dtypes in interpret mode against the oracle.
"""
from repro.kernels import fused_fff, leaf_gemm, tree_router
