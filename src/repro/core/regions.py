"""Input-space regionalization utilities (paper §Regions of responsibility).

Each FFF leaf owns one region of the learned tree partition.  For node width
n = 1 the boundary at each node is the activation hyperplane of its single
neuron, so every leaf region is an intersection of half-spaces — algebraically
identifiable, which the paper highlights for interpretability, surgical model
editing and replay-budget reduction.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fff


class Halfspace(NamedTuple):
    normal: np.ndarray   # (dim_in,)
    offset: float        # region satisfies sign * (normal . x + offset) >= 0
    sign: int            # +1 if the path took the right child here


def leaf_region(params: fff.Params, cfg: fff.FFFConfig, leaf: int,
                tree: int = 0) -> list[Halfspace]:
    """The half-space constraints defining ``leaf``'s region of responsibility."""
    if cfg.node_width != 1:
        raise ValueError("closed-form regions require node_width == 1")
    constraints = []
    idx = 0
    w1 = np.asarray(params["node_w1"][tree, :, :, 0])
    b1 = np.asarray(params["node_b1"][tree, :, 0])
    w2 = np.asarray(params["node_w2"][tree, :, 0])
    b2 = np.asarray(params["node_b2"][tree])
    for m in range(cfg.depth):
        bit = (leaf >> (cfg.depth - 1 - m)) & 1
        g = 2 ** m - 1 + idx
        # logit(x) = w2 * (w1 . x + b1) + b2; right child iff logit >= 0
        normal = w2[g] * w1[g]
        offset = w2[g] * b1[g] + b2[g]
        constraints.append(Halfspace(normal, float(offset), +1 if bit else -1))
        idx = 2 * idx + bit
    return constraints


def region_membership(constraints: list[Halfspace], x: np.ndarray) -> np.ndarray:
    """Vectorized membership test for a batch of points (B, D) -> (B,) bool."""
    ok = np.ones(x.shape[0], bool)
    for c in constraints:
        val = x @ c.normal + c.offset
        ok &= (val >= 0) if c.sign > 0 else (val < 0)
    return ok


def partition_histogram(params: fff.Params, cfg: fff.FFFConfig,
                        x: jax.Array) -> jax.Array:
    """How many of the given samples fall into each leaf region: (T, 2^d)."""
    leaf_idx = fff.route_hard(params, cfg, x)        # (B, T)
    counts = jax.vmap(lambda col: jnp.bincount(col, length=cfg.num_leaves),
                      in_axes=1)(leaf_idx.reshape(-1, cfg.trees))
    return counts


def is_partition(params: fff.Params, cfg: fff.FFFConfig, x: jax.Array) -> bool:
    """Every sample belongs to exactly one closed-form region, and it is the
    region of the leaf FORWARD_I selects — the partition invariant."""
    xf = np.asarray(x.reshape(-1, cfg.dim_in))
    routed = np.asarray(fff.route_hard(params, cfg, x)).reshape(-1, cfg.trees)
    for t in range(cfg.trees):
        membership = np.zeros(xf.shape[0], dtype=int)
        agree = np.zeros(xf.shape[0], dtype=bool)
        for leaf in range(cfg.num_leaves):
            cons = leaf_region(params, cfg, leaf, tree=t)
            inside = region_membership(cons, xf)
            membership += inside.astype(int)
            agree |= inside & (routed[:, t] == leaf)
        if not (membership == 1).all() or not agree.all():
            return False
    return True
