"""xLSTM blocks: mLSTM (matrix memory, chunkwise-parallel) and sLSTM (scalar
memory, strictly sequential) — Beck et al., 2024 (arXiv:2405.04517).

mLSTM state per head is a (hd x hd) matrix updated with exponential gating:
    C_t = f_t C_{t-1} + i_t v_t k_t^T,   n_t = f_t n_{t-1} + i_t k_t
    h_t = C_t q_t / max(|n_t . q_t|, 1)
We run it chunkwise (sequential over chunks, parallel inside) in log-space for
stability; the sequential formulation is kept as the oracle (tests compare).
Constant-size state => sub-quadratic: this is the long_500k-capable arch.

Simplifications vs. the reference implementation are documented in DESIGN.md:
block wiring follows the paper's pre-up-projection (mLSTM) and
post-up-projection (sLSTM) shapes, with GroupNorm over heads.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro import utils

Params = dict


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    d_model: int
    n_heads: int = 4
    expand: int = 2                # mLSTM up-projection factor
    chunk: int = 256
    param_dtype: Any = jnp.float32
    accum_dtype: Any = jnp.float32

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def head_dim(self) -> int:
        return self.d_inner // self.n_heads


class MLSTMState(NamedTuple):
    C: jax.Array   # (B, H, hd, hd)
    n: jax.Array   # (B, H, hd)
    m: jax.Array   # (B, H)  running log-scale


class SLSTMState(NamedTuple):
    h: jax.Array   # (B, d_model)
    c: jax.Array   # (B, d_model)
    n: jax.Array   # (B, d_model)
    m: jax.Array   # (B, d_model)


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_init(key: jax.Array, cfg: XLSTMConfig) -> Params:
    D, DI, H, hd = cfg.d_model, cfg.d_inner, cfg.n_heads, cfg.head_dim
    ks = jax.random.split(key, 7)
    pd = cfg.param_dtype
    s = 1.0 / math.sqrt(D)
    si = 1.0 / math.sqrt(DI)
    return {
        "up_proj": utils.truncated_init(ks[0], (D, 2 * DI), s, pd),
        "wq": utils.truncated_init(ks[1], (DI, H, hd), si, pd),
        "wk": utils.truncated_init(ks[2], (DI, H, hd), si, pd),
        "wv": utils.truncated_init(ks[3], (DI, H, hd), si, pd),
        "w_if": utils.truncated_init(ks[4], (DI, 2 * H), si, pd),
        # forget-gate bias >> 0 so early training approximates cumulative sum
        "b_if": jnp.concatenate([jnp.zeros((H,)), 3.0 * jnp.ones((H,))]).astype(pd),
        "gn_scale": jnp.ones((H, hd), pd),
        "down_proj": utils.truncated_init(ks[5], (DI, D), si, pd),
    }


def _mlstm_gates(params: Params, cfg: XLSTMConfig, xi: jax.Array):
    """q, k, v (B, S, H, hd); log-i, log-f (B, S, H)."""
    ad = cfg.accum_dtype
    q = jnp.einsum("bsd,dhk->bshk", xi, params["wq"], preferred_element_type=ad)
    k = jnp.einsum("bsd,dhk->bshk", xi, params["wk"], preferred_element_type=ad) \
        / math.sqrt(cfg.head_dim)
    v = jnp.einsum("bsd,dhk->bshk", xi, params["wv"], preferred_element_type=ad)
    g = jnp.einsum("bsd,dh->bsh", xi, params["w_if"], preferred_element_type=ad) \
        + params["b_if"].astype(ad)
    log_i, f_pre = jnp.split(g, 2, axis=-1)
    log_f = jax.nn.log_sigmoid(f_pre)        # exp-gating via sigmoid-forget
    return q, k, v, log_i, log_f


def mlstm_sequential(q, k, v, log_i, log_f, state: MLSTMState
                     ) -> tuple[jax.Array, MLSTMState]:
    """Oracle: stabilized per-step recurrence. Shapes as in _mlstm_gates."""
    def step(s, t):
        C, n, m = s
        qt, kt, vt, lit, lft = t
        m_new = jnp.maximum(lft + m, lit)                       # (B, H)
        i_p = jnp.exp(lit - m_new)
        f_p = jnp.exp(lft + m - m_new)
        C = f_p[..., None, None] * C + i_p[..., None, None] \
            * (vt[..., :, None] * kt[..., None, :])             # (B,H,hd,hd)
        n = f_p[..., None] * n + i_p[..., None] * kt
        num = jnp.einsum("bhvk,bhk->bhv", C, qt)
        den = jnp.abs(jnp.einsum("bhk,bhk->bh", n, qt))
        h = num / jnp.maximum(den, jnp.exp(-m_new))[..., None]
        return (C, n, m_new), h

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (q, k, v, log_i, log_f))
    (C, n, m), hs = jax.lax.scan(step, (state.C, state.n, state.m), xs)
    return jnp.moveaxis(hs, 0, 1), MLSTMState(C, n, m)


def mlstm_chunkwise(q, k, v, log_i, log_f, state: MLSTMState, chunk: int
                    ) -> tuple[jax.Array, MLSTMState]:
    """Chunkwise-parallel stabilized mLSTM (production path).

    Sequential scan over S/chunk chunks; inside a chunk, intra-chunk causal
    contributions and the inter-chunk carry are dense einsums (MXU-friendly).
    """
    B, S, H, hd = q.shape
    chunk = min(chunk, S)
    if S % chunk:
        chunk = math.gcd(S, chunk)
    n_ch = S // chunk

    def to_chunks(t):
        return jnp.moveaxis(t.reshape(B, n_ch, chunk, *t.shape[2:]), 1, 0)

    qs, ks_, vs, lis, lfs = map(to_chunks, (q, k, v, log_i, log_f))

    def body(carry, t):
        C, n, m = carry                         # C/exp(m) convention: C,n are
        qt, kt, vt, lit, lft = t                # already scaled by exp(-m)
        F = jnp.cumsum(lft, axis=1)             # (B, C, H) cumulative log-f
        # log weight of source step s seen at the chunk end: F_L - F_s + li_s
        F_last = F[:, -1:, :]
        src = F_last - F + lit                  # (B, C, H)
        # stabilizer for this chunk
        m_new = jnp.maximum(F_last[:, 0] + m, src.max(axis=1))   # (B, H)
        # --- intra-chunk: score(t, s) = q_t.k_s * exp(F_t - F_s + li_s) ---
        # stabilized per-row by b_t = max(F_t + m, max_s<=t (F_t - F_s + li_s))
        dmat = F[:, :, None, :] - F[:, None, :, :] + lit[:, None, :, :]
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        dmat = jnp.where(tri[None, :, :, None], dmat, -jnp.inf)  # (B,Ct,Cs,H)
        inter_log = F + m[:, None, :]                            # (B, C, H)
        b = jnp.maximum(dmat.max(axis=2), inter_log)             # (B, C, H)
        w_intra = jnp.exp(dmat - b[:, :, None, :])               # (B,Ct,Cs,H)
        scores = jnp.einsum("bthk,bshk->btsh", qt, kt) * w_intra
        num = jnp.einsum("btsh,bshv->bthv", scores, vt)
        den = scores.sum(axis=2)                                 # (B, C, H)
        # --- inter-chunk: carry C (already exp(-m)-scaled) ---
        w_inter = jnp.exp(inter_log - b)                         # (B, C, H)
        num = num + jnp.einsum("bthk,bhvk->bthv", qt, C) * w_inter[..., None]
        den = den + jnp.einsum("bthk,bhk->bth", qt, n) * w_inter
        h = num / jnp.maximum(jnp.abs(den), jnp.exp(-b))[..., None]
        # --- state update (rescale to the new stabilizer m_new) ---
        w_src = jnp.exp(src - m_new[:, None, :])                 # (B, C, H)
        w_old = jnp.exp(F_last[:, 0] + m - m_new)                # (B, H)
        C_new = w_old[..., None, None] * C + jnp.einsum(
            "bshv,bshk,bsh->bhvk", vt, kt, w_src)
        n_new = w_old[..., None] * n + jnp.einsum("bshk,bsh->bhk", kt, w_src)
        return (C_new, n_new, m_new), h

    (C, n, m), hs = jax.lax.scan(body, (state.C, state.n, state.m),
                                 (qs, ks_, vs, lis, lfs))
    return jnp.moveaxis(hs, 0, 1).reshape(B, S, H, hd), MLSTMState(C, n, m)


def _group_norm(x: jax.Array, scale: jax.Array) -> jax.Array:
    """Per-head LayerNorm (GroupNorm with groups = heads): x (B, S, H, hd)."""
    xf = x.astype(jnp.float32)
    mean = xf.mean(axis=-1, keepdims=True)
    var = xf.var(axis=-1, keepdims=True)
    return ((xf - mean) * jax.lax.rsqrt(var + 1e-6)
            * scale.astype(jnp.float32)).astype(x.dtype)


def mlstm_block(params: Params, cfg: XLSTMConfig, x: jax.Array,
                state: MLSTMState | None = None, *, sequential: bool = False
                ) -> tuple[jax.Array, MLSTMState]:
    """Full mLSTM block: (B, S, D) -> (B, S, D) + state."""
    ad = cfg.accum_dtype
    B, S, _ = x.shape
    if state is None:
        state = mlstm_init_state(B, cfg)
    up = jnp.einsum("bsd,de->bse", x, params["up_proj"], preferred_element_type=ad)
    xi, z = jnp.split(up, 2, axis=-1)
    q, k, v, log_i, log_f = _mlstm_gates(params, cfg, xi)
    if sequential:
        h, new_state = mlstm_sequential(q, k, v, log_i, log_f, state)
    else:
        h, new_state = mlstm_chunkwise(q, k, v, log_i, log_f, state, cfg.chunk)
    h = _group_norm(h, params["gn_scale"]).reshape(B, S, cfg.d_inner)
    h = h * jax.nn.silu(z)
    return jnp.einsum("bse,ed->bsd", h, params["down_proj"],
                      preferred_element_type=ad), new_state


def mlstm_init_state(batch: int, cfg: XLSTMConfig, dtype=jnp.float32) -> MLSTMState:
    H, hd = cfg.n_heads, cfg.head_dim
    return MLSTMState(jnp.zeros((batch, H, hd, hd), dtype),
                      jnp.zeros((batch, H, hd), dtype),
                      jnp.full((batch, H), -1e30, dtype))


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_init(key: jax.Array, cfg: XLSTMConfig) -> Params:
    D, H = cfg.d_model, cfg.n_heads
    dh = D // H
    ks = jax.random.split(key, 3)
    pd = cfg.param_dtype
    s = 1.0 / math.sqrt(D)
    return {
        # 4 gates (i, f, z, o) from input; recurrent weights block-diagonal
        "w_x": utils.truncated_init(ks[0], (D, 4 * D), s, pd),
        "w_h": utils.truncated_init(ks[1], (H, dh, 4 * dh), 1.0 / math.sqrt(dh), pd),
        "b": jnp.concatenate([jnp.zeros((D,)), 3.0 * jnp.ones((D,)),
                              jnp.zeros((2 * D,))]).astype(pd),
        "gn_scale": jnp.ones((D,), pd),
        "out_proj": utils.truncated_init(ks[2], (D, D), s, pd),
    }


def slstm_block(params: Params, cfg: XLSTMConfig, x: jax.Array,
                state: SLSTMState | None = None
                ) -> tuple[jax.Array, SLSTMState]:
    """Strictly sequential sLSTM: (B, S, D) -> (B, S, D) + state."""
    ad = cfg.accum_dtype
    B, S, D = x.shape
    H = cfg.n_heads
    dh = D // H
    if state is None:
        state = slstm_init_state(B, D, dtype=ad)
    gx = jnp.einsum("bsd,de->bse", x, params["w_x"], preferred_element_type=ad) \
        + params["b"].astype(ad)                                 # (B, S, 4D)

    def step(s_, gx_t):
        h, c, n, m = s_
        hh = h.reshape(B, H, dh)
        gr = jnp.einsum("bhk,hke->bhe", hh, params["w_h"].astype(ad))
        g = gx_t + gr.reshape(B, 4 * D)
        gi, gf, gz, go = jnp.split(g, 4, axis=-1)
        m_new = jnp.maximum(jax.nn.log_sigmoid(gf) + m, gi)
        i_p = jnp.exp(gi - m_new)
        f_p = jnp.exp(jax.nn.log_sigmoid(gf) + m - m_new)
        c_new = f_p * c + i_p * jnp.tanh(gz)
        n_new = f_p * n + i_p
        h_new = jax.nn.sigmoid(go) * c_new / jnp.maximum(n_new, 1e-6)
        return (h_new, c_new, n_new, m_new), h_new

    (h, c, n, m), hs = jax.lax.scan(step, tuple(state), jnp.moveaxis(gx, 1, 0))
    y = jnp.moveaxis(hs, 0, 1)                                   # (B, S, D)
    # per-head group norm
    yh = y.reshape(B, S, H, dh)
    yh = _group_norm(yh, params["gn_scale"].reshape(H, dh)).reshape(B, S, D)
    out = jnp.einsum("bsd,de->bse", yh, params["out_proj"],
                     preferred_element_type=ad)
    return out, SLSTMState(h, c, n, m)


def slstm_init_state(batch: int, d_model: int, dtype=jnp.float32) -> SLSTMState:
    z = jnp.zeros((batch, d_model), dtype)
    return SLSTMState(z, z, z, jnp.full((batch, d_model), -1e30, dtype))
