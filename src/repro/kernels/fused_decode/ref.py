"""Pure-jnp oracle for the fused decode megakernel: hard descent + the
selected leaf's MLP + forest combine, all in fp32 (paper Algorithm 1
FORWARD_I, node_width 1, bias-free leaves)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import utils


def fused_decode_ref(x: jax.Array, nw: jax.Array, nb: jax.Array,
                     leaf_w: tuple, *, depth: int, act: str = "gelu",
                     master_w: tuple | None = None
                     ) -> tuple[jax.Array, jax.Array]:
    """Same contract as ``kernel.fused_forest_decode``: x (B, D), collapsed
    nodes nw (T, N, D) / nb (T, N), ``leaf_w`` = (w1, w2) or (wg, wu, wd)
    with leading (T, E) axes -> ``(y (B, O), leaf_idx (B, T) int32)``.
    ``master_w`` (optional, same layout as one leaf minus the (T, E) axes)
    adds the always-on master-leaf MLP to every token (DESIGN.md §14)."""
    B = x.shape[0]
    T = nw.shape[0]
    xf = x.astype(jnp.float32)
    y = None
    idxs = []
    for t in range(T):
        idx = jnp.zeros((B,), jnp.int32)
        for m in range(depth):
            g = (2 ** m - 1) + idx
            w = jnp.take(nw[t], g, axis=0).astype(jnp.float32)   # (B, D)
            b = jnp.take(nb[t], g, axis=0).astype(jnp.float32)   # (B,)
            logit = jnp.einsum("bd,bd->b", xf, w) + b
            idx = 2 * idx + (logit >= 0.0).astype(jnp.int32)
        if act == "swiglu":
            wg, wu, wd = (jnp.take(w[t], idx, axis=0).astype(jnp.float32)
                          for w in leaf_w)
            h = jax.nn.silu(jnp.einsum("bd,bdh->bh", xf, wg)) \
                * jnp.einsum("bd,bdh->bh", xf, wu)
            yt = jnp.einsum("bh,bho->bo", h, wd)
        else:
            w1, w2 = (jnp.take(w[t], idx, axis=0).astype(jnp.float32)
                      for w in leaf_w)
            h = utils.get_activation(act)(jnp.einsum("bd,bdh->bh", xf, w1))
            yt = jnp.einsum("bh,bho->bo", h, w2)
        y = yt if y is None else y + yt
        idxs.append(idx)
    if master_w is not None:
        if act == "swiglu":
            mg, mu, md = (w.astype(jnp.float32) for w in master_w)
            h = jax.nn.silu(xf @ mg) * (xf @ mu)
            y = y + h @ md
        else:
            m1, m2 = (w.astype(jnp.float32) for w in master_w)
            y = y + utils.get_activation(act)(xf @ m1) @ m2
    return y.astype(x.dtype), jnp.stack(idxs, axis=1)
