"""Architecture configs: one module per assigned arch + the paper's own."""
from repro.configs.base import (BlockSpec, EncoderSpec, FFNSpec, ModelConfig,
                                SHAPES, ShapeSpec, shape_applicable)
from repro.configs.registry import ARCH_IDS, get_config
