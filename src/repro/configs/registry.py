"""Arch registry: ``--arch <id>`` lookup for every assigned architecture.

``get_config(arch_id, ffn="fff")`` returns the FFF variant (the paper's
technique as a first-class feature); ``ffn="native"`` returns the published
baseline (dense or MoE as the source model ships)."""
from __future__ import annotations

import importlib

from repro.configs.base import ModelConfig

_MODULES = {
    "whisper-small": "repro.configs.whisper_small",
    "kimi-k2-1t-a32b": "repro.configs.kimi_k2_1t_a32b",
    "olmoe-1b-7b": "repro.configs.olmoe_1b_7b",
    "jamba-1.5-large-398b": "repro.configs.jamba_1_5_large_398b",
    "internlm2-20b": "repro.configs.internlm2_20b",
    "phi3-medium-14b": "repro.configs.phi3_medium_14b",
    "starcoder2-15b": "repro.configs.starcoder2_15b",
    "command-r-35b": "repro.configs.command_r_35b",
    "internvl2-26b": "repro.configs.internvl2_26b",
    "xlstm-1.3b": "repro.configs.xlstm_1_3b",
    "paper-vit": "repro.configs.paper_vit",
}

ARCH_IDS = tuple(k for k in _MODULES if k != "paper-vit")


def get_config(arch_id: str, ffn: str = "fff") -> ModelConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; have {sorted(_MODULES)}")
    mod = importlib.import_module(_MODULES[arch_id])
    if ffn == "fff":
        return mod.FFF_CONFIG
    if ffn == "native":
        return mod.CONFIG
    return mod.CONFIG.with_ffn_kind(ffn)
