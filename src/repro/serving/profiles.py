"""Online per-tenant routing profiles (DESIGN.md §9).

The FFF paper's conditionality is *noiseless*: a prompt's leaf footprint is
a stable property of its content, so a tenant's traffic has a measurable,
slowly-drifting routing signature.  ``RoutingProfileStore`` learns that
signature online — every finished request's accumulated EWMA leaf occupancy
(the engine's per-slot telemetry) folds into its tenant's profile — and
serves it back as the admission prior for the tenant's *next* requests.
``Request.leaf_hint`` thereby becomes optional and self-calibrating: the
offline probe (``benchmarks/serving_load.py::calibrate_classes``) is still
the ground-truth reference, but no longer a deployment prerequisite.

Profiles are advisory exactly like hints: a stale or wrong profile costs
scheduling quality, never correctness.  Pure host-side numpy, deterministic
for a given update sequence (no wall-clock, no RNG).
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Dict, Optional

import numpy as np


@dataclasses.dataclass
class TenantProfile:
    """One tenant's learned leaf footprint: a normalized (E,) EWMA over the
    occupancy rows of its finished requests, plus the update count that
    gates serving it (``min_updates``)."""
    footprint: np.ndarray
    n_updates: int = 0


class RoutingProfileStore:
    """Per-tenant EWMA leaf-footprint store.

    Args:
        num_leaves:  E — telemetry width; rows of any other size are
                     rejected by ``update`` (they came from a different
                     model/site and would poison the profile).
        ewma:        per-*request* smoothing weight of the newest finished
                     request's footprint (the engine already EWMA-smooths
                     per step within a request; this level tracks tenant
                     drift across requests).
        min_updates: how many finished requests a tenant needs before
                     ``lookup`` serves its profile — below it the scheduler
                     falls back to the request's own hint or the uniform
                     prior (one request is already a usable signal; raise
                     this for bursty tenants whose first request may be
                     unrepresentative).
        max_tenants: LRU cap on tracked tenants — the store is otherwise
                     unbounded host memory under tenant-id churn (every
                     distinct id allocates an (E,) row forever).  When a new
                     tenant would exceed the cap, the least-recently-touched
                     (update or lookup) profile is dropped; the first
                     eviction warns once so operators notice the working set
                     outgrew the cap.  0 = unbounded.
    """

    def __init__(self, num_leaves: int, ewma: float = 0.3,
                 min_updates: int = 1, max_tenants: int = 1024):
        if num_leaves <= 0:
            raise ValueError(f"num_leaves must be positive, got {num_leaves}")
        if not 0.0 < ewma <= 1.0:
            raise ValueError(f"ewma must be in (0, 1], got {ewma}")
        if min_updates < 1:
            raise ValueError(f"min_updates must be >= 1, got {min_updates}")
        if max_tenants < 0:
            raise ValueError(f"max_tenants must be >= 0, got {max_tenants}")
        self.num_leaves = num_leaves
        self.ewma = ewma
        self.min_updates = min_updates
        self.max_tenants = max_tenants
        self.n_evicted = 0
        self._warned_eviction = False
        self._profiles: Dict[str, TenantProfile] = {}

    def _touch(self, tenant: str) -> None:
        # dict insertion order doubles as the LRU order: re-inserting moves
        # the tenant to the most-recent end.
        prof = self._profiles.pop(tenant)
        self._profiles[tenant] = prof

    def _evict_to_cap(self) -> None:
        if self.max_tenants <= 0:
            return
        while len(self._profiles) > self.max_tenants:
            victim = next(iter(self._profiles))
            del self._profiles[victim]
            self.n_evicted += 1
            if not self._warned_eviction:
                self._warned_eviction = True
                warnings.warn(
                    f"RoutingProfileStore evicted tenant {victim!r}: more "
                    f"than max_tenants={self.max_tenants} distinct tenants "
                    f"seen; evicted tenants relearn from scratch (raise "
                    f"profile_max_tenants if the working set is legitimate)",
                    RuntimeWarning, stacklevel=3)

    def update(self, tenant: str, occupancy_row: np.ndarray) -> None:
        """Fold one finished request's (E,) leaf-occupancy row into the
        tenant's profile.  Zero-mass or wrong-width rows are ignored (a
        request that never produced telemetry carries no signal)."""
        row = np.asarray(occupancy_row, np.float64).reshape(-1)
        if row.size != self.num_leaves:
            return
        tot = row.sum()
        if tot <= 0 or not np.isfinite(tot):
            return
        frac = row / tot
        prof = self._profiles.get(tenant)
        if prof is None:
            self._profiles[tenant] = TenantProfile(footprint=frac.copy(),
                                                   n_updates=1)
            self._evict_to_cap()
        else:
            a = self.ewma
            prof.footprint = (1.0 - a) * prof.footprint + a * frac
            prof.n_updates += 1
            self._touch(tenant)

    def lookup(self, tenant: str) -> Optional[np.ndarray]:
        """The tenant's learned (E,) footprint (a copy — callers may
        normalize/mutate), or None until ``min_updates`` requests have
        reported."""
        prof = self._profiles.get(tenant)
        if prof is None or prof.n_updates < self.min_updates:
            return None
        self._touch(tenant)
        return prof.footprint.copy()

    def n_updates(self, tenant: str) -> int:
        prof = self._profiles.get(tenant)
        return 0 if prof is None else prof.n_updates

    def tenants(self):
        return sorted(self._profiles)

    def as_dict(self) -> dict:
        """JSON-ready snapshot: tenant -> {n_updates, footprint list,
        dominant leaf} (exported under ``--metrics-json`` for operators
        watching convergence)."""
        return {t: {"n_updates": p.n_updates,
                    "dominant_leaf": int(p.footprint.argmax()),
                    "footprint": [round(float(x), 6) for x in p.footprint]}
                for t, p in sorted(self._profiles.items())}
