"""Expert-parallel all_to_all dispatch plans (DESIGN.md §5).

Generic token->group exchange for manual (shard_map) regions whose groups
(experts / FFF leaves) are sharded across a mesh axis.  The caller brings
per-token group ids and slot ranks (``core/routing.group_slots`` — sort
ranks, never ``cumsum(one_hot)``); this module owns the send-buffer layout,
the collective exchange and its inverse, and the capacity accounting.  It has
no model knowledge: arrays in, arrays out.

Layout contract (all shapes per shard, inside ``shard_map``):

* groups are numbered globally ``0..E-1`` and owned contiguously — shard
  ``s`` of the ``M``-way axis owns groups ``[s*E/M, (s+1)*E/M)``;
* each source shard slots its ``Bl`` local tokens per (group) with capacity
  ``C`` per *(source shard, group)* pair and scatters them into an
  ``(M, E/M, C, D)`` send buffer;
* one ``all_to_all`` over the axis delivers, to each owner shard, the
  ``(M, E/M, C, D)`` buffer of its groups' tokens from every peer, viewed as
  ``(E/M, M*C, D)`` per-group runs for grouped GEMMs;
* the inverse ``all_to_all`` returns results in exactly the send layout, so
  the original scatter indices gather them back to token order.

Over-capacity tokens never occupy a slot (their scatter index is the uniform
out-of-bounds sentinel ``E*C``); exactness is the caller's job (overflow-to-
dense, DESIGN.md §8).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro import utils


class EPPlan(NamedTuple):
    """Per-source-shard dispatch plan for one all_to_all exchange.

    flat_idx:   (Bl,) int32 position ``group*C + slot`` in the flattened
                ``(E*C,)`` send buffer; dropped/invalid tokens carry the
                out-of-bounds sentinel ``E*C`` (scatter mode="drop" discards
                them, the paired gather is masked by ``kept``)
    kept:       (Bl,) bool — token is valid and under capacity
    capacity:   C, per (source shard, group)
    num_groups: E, global group count
    num_shards: M, size of the exchange axis (E % M == 0)
    """
    flat_idx: jax.Array
    kept: jax.Array
    capacity: int
    num_groups: int
    num_shards: int

    @property
    def groups_local(self) -> int:
        return self.num_groups // self.num_shards


def ep_capacity(tokens_per_shard: int, num_groups: int,
                capacity_factor: float, multiple: int = 8) -> int:
    """Per-(source shard, group) slot count: ``cf * Bl / E`` rounded up to a
    tile multiple.  Static — both ends of the a2a must agree on it."""
    return max(multiple, utils.round_up(
        int(capacity_factor * utils.cdiv(tokens_per_shard, num_groups)),
        multiple))


def make_ep_plan(group_idx: jax.Array, slot: jax.Array, valid: jax.Array,
                 num_groups: int, num_shards: int, capacity: int) -> EPPlan:
    """Build the plan from per-token group ids, slot ranks and a validity
    mask (False = padding token: capacity-neutral, never occupies a slot)."""
    if num_groups % num_shards:
        raise ValueError(f"num_groups={num_groups} must divide over "
                         f"num_shards={num_shards}")
    kept = valid & (slot < capacity)
    flat_idx = jnp.where(kept, group_idx * capacity + slot,
                         num_groups * capacity).astype(jnp.int32)
    return EPPlan(flat_idx, kept, capacity, num_groups, num_shards)


def ep_scatter(x: jax.Array, plan: EPPlan) -> jax.Array:
    """x (Bl, D) -> send buffer (M, E/M, C, D), grouped by owner shard."""
    E, C = plan.num_groups, plan.capacity
    buf = jnp.zeros((E * C, x.shape[-1]), x.dtype)
    buf = buf.at[plan.flat_idx].set(x, mode="drop")
    return buf.reshape(plan.num_shards, plan.groups_local, C, x.shape[-1])


def ep_exchange(send: jax.Array, axis_name: str, plan: EPPlan) -> jax.Array:
    """all_to_all the send buffer to group owners: (M, E/M, C, D) ->
    (E/M, M*C, D) per-local-group token runs (sources concatenated)."""
    recv = jax.lax.all_to_all(send, axis_name, split_axis=0, concat_axis=0)
    return recv.transpose(1, 0, 2, 3).reshape(
        plan.groups_local, plan.num_shards * plan.capacity, send.shape[-1])


def ep_combine(y: jax.Array, axis_name: str, plan: EPPlan) -> jax.Array:
    """Inverse exchange: per-local-group results (E/M, M*C, O) back to the
    source shards, flattened to the (E*C, O) send-buffer layout."""
    M, C = plan.num_shards, plan.capacity
    back = y.reshape(plan.groups_local, M, C, y.shape[-1]).transpose(1, 0, 2, 3)
    ysend = jax.lax.all_to_all(back, axis_name, split_axis=0, concat_axis=0)
    return ysend.reshape(plan.num_groups * C, y.shape[-1])


def ep_gather(y_flat: jax.Array, plan: EPPlan) -> jax.Array:
    """(E*C, O) -> per-token outputs (Bl, O); dropped tokens get zeros."""
    y = jnp.take(y_flat, plan.flat_idx, axis=0)
    return jnp.where(plan.kept[:, None], y, 0.0)


def ep_bytes_moved(num_groups: int, num_shards: int, dim_in: int,
                   dim_out: int, capacity: int, itemsize: int = 4, *,
                   overflow_policy: str = "drop",
                   tokens_per_shard: int = 0) -> int:
    """Cross-shard bytes per source shard for one dispatch round trip: two
    all_to_alls of the (E, C, *) buffers, of which (M-1)/M leaves the shard.
    The dispatch-locality benchmark reports this next to measured tokens/s.

    ``overflow_policy="exact_dense"`` (with ``tokens_per_shard`` > 0) adds
    the worst-case dense-repair round an overflowing dispatch pays
    (DESIGN.md §14): an all_gather of each shard's Bl token activations,
    leaf ids and drop mask over the model axis, plus the psum assembling
    the (M*Bl, O) repaired outputs.  Under "master_leaf" / "drop" the
    repair round is statically absent from the lowered program
    (``core/routing.grouped_leaf_apply_ep``), so its term here is zero —
    the collective traffic the approximate policy buys back."""
    M = max(num_shards, 1)
    slots = num_groups * capacity
    a2a = int(slots * (dim_in + dim_out) * itemsize * (num_shards - 1) / M)
    if overflow_policy != "exact_dense" or not tokens_per_shard:
        return a2a
    Bl = tokens_per_shard
    gathered = Bl * (dim_in * itemsize + 4 + 1) * (num_shards - 1)
    psum = int(2 * M * Bl * dim_out * itemsize * (num_shards - 1) / M)
    return a2a + gathered + psum
